"""BENCH scaling — multi-host scale-out with hierarchical collectives.

The PR 10 acceptance workload: word count, PageRank and k-means as dense
iterative reductions on one pool of 8 simulated devices, re-partitioned as
``("node", "data")`` meshes of 1/2/4/8 nodes (8x1-per-node down to 1x8).
Each point runs the same op twice — topology-oblivious flat collectives vs
the ``hierarchical-collectives`` rewrite — and reports walls plus the
intra-node / inter-node wire-byte split of the combine-edge model.

Simulated CPU devices share one socket, so the walls are sanity numbers,
not the scaling claim; the claim this bench pins is the *wire* one from the
paper's cross-rack argument: a flat reduce pays every combine edge on the
slow inter-node links, the hierarchical reduce pays ``n_nodes - 1`` of them
(at the narrowed width when a wire is set) and keeps the rest on fast
intra-node links.

Claims recorded as measurements:

* ``hier_cuts_inter_bytes_<workload>`` — at every non-degenerate multi-node
  split (1 < nodes < devices, i.e. 2 and 4 here) the hierarchical wire
  moves strictly fewer inter-node bytes than flat; at 8 nodes every node
  holds one device, there is no intra leg, and hier must equal flat;
* ``hier_matches_flat_<workload>`` — results agree (bit-equal for the
  integer-valued word count; <= 1e-4 relative for the float workloads);
* ``curve_complete`` — all 3 workloads measured at all of 1/2/4/8 nodes.

Run:  PYTHONPATH=src:. python -m benchmarks.bench10_scaling
Writes ``results/BENCH_scaling.json``.  ``BENCH_SCALE=smoke`` shrinks the
datasets for CI; ``BENCH_SCALE=big`` grows them.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCALE = os.environ.get("BENCH_SCALE", "default")
NODE_COUNTS = (1, 2, 4, 8)


def _sizes():
    if SCALE == "smoke":
        return {"rows": 1 << 13, "vocab": 512, "pages": 256, "k": 16,
                "dim": 8, "iters": 6}
    if SCALE == "big":
        return {"rows": 1 << 17, "vocab": 4096, "pages": 2048, "k": 64,
                "dim": 16, "iters": 16}
    return {"rows": 1 << 15, "vocab": 2048, "pages": 1024, "k": 32,
            "dim": 16, "iters": 10}


_CHILD = """
import json, os, time
import numpy as np, jax, jax.numpy as jnp
from repro.core.session import BlazeSession
from repro.launch.mesh import make_node_data_mesh

sizes = json.loads(os.environ["BENCH_SIZES"])
rows, vocab = sizes["rows"], sizes["vocab"]
pages, k, dim, iters = sizes["pages"], sizes["k"], sizes["dim"], sizes["iters"]
rng = np.random.RandomState(0)

# word count as a dense histogram (key_range known -> hier-eligible)
words = rng.zipf(1.4, rows).astype(np.int32) % vocab
# PageRank: random edges, out-degree precomputed host-side
edges = rng.randint(0, pages, (rows, 2)).astype(np.int32)
deg = np.maximum(np.bincount(edges[:, 0], minlength=pages), 1).astype(np.float32)
# k-means: clustered points, fixed initial centers
pts = (rng.randn(rows, dim) + rng.randint(0, k, rows)[:, None]).astype(np.float32)
centers0 = pts[:k].copy()


def wc_op(sess, v, hier):
    def m(i, w, emit):
        emit(w, 1)
    return sess.map_reduce(v, m, "sum", jnp.zeros((vocab,), jnp.int32),
                           return_stats=True, hierarchical=hier)


def pr_op(sess, v, hier, ranks):
    def m(i, e, emit, env):
        r, d = env
        emit(e[1], r[e[0]] / d[e[0]])
    contrib, st = sess.map_reduce(v, m, "sum", jnp.zeros((pages,), jnp.float32),
                                  env=(ranks, jnp.asarray(deg)),
                                  return_stats=True, hierarchical=hier)
    return 0.85 * contrib + 0.15 / pages, st


def km_op(sess, v, hier, centers):
    def m(i, p, emit, env):
        j = jnp.argmin(jnp.sum((env - p) ** 2, axis=1))
        emit(j, jnp.concatenate([p, jnp.ones((1,), p.dtype)]))
    acc, st = sess.map_reduce(v, m, "sum", jnp.zeros((k, dim + 1), jnp.float32),
                              env=centers, return_stats=True, hierarchical=hier)
    cnt = jnp.maximum(acc[:, dim:], 1.0)
    return acc[:, :dim] / cnt, st


def run_workload(name, sess, v, hier):
    # warm (compile), then time the iteration loop
    if name == "wordcount":
        out, st = wc_op(sess, v, hier)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(iters):
            out, st = wc_op(sess, v, hier)
        jax.block_until_ready(out)
        wall = time.perf_counter() - t0
        result = np.asarray(out)
    else:
        op = pr_op if name == "pagerank" else km_op
        state0 = (jnp.full((pages,), 1.0 / pages, jnp.float32)
                  if name == "pagerank" else jnp.asarray(centers0))
        state, st = op(sess, v, hier, state0)
        jax.block_until_ready(state)
        state = state0
        t0 = time.perf_counter()
        for _ in range(iters):
            state, st = op(sess, v, hier, state)
        jax.block_until_ready(state)
        wall = time.perf_counter() - t0
        result = np.asarray(state)
    st = st.finalize()
    return {
        "wall_s": wall,
        "intra_bytes": int(st.intra_bytes) * iters,
        "inter_bytes": int(st.inter_bytes) * iters,
        "collective": st.collective,
    }, result


report = []
for n_nodes in (1, 2, 4, 8):
    sess = BlazeSession(mesh=make_node_data_mesh(n_nodes))
    sources = {
        "wordcount": sess.distribute(words),
        "pagerank": sess.distribute(edges),
        "kmeans": sess.distribute(pts),
    }
    for name, v in sources.items():
        flat, r_flat = run_workload(name, sess, v, hier=False)
        hier, r_hier = run_workload(name, sess, v, hier=True)
        if name == "wordcount":
            match = bool(np.array_equal(r_flat, r_hier))
        else:
            scale = float(np.abs(r_flat).max()) or 1.0
            match = float(np.abs(r_flat - r_hier).max()) / scale <= 1e-4
        report.append({
            "workload": name, "nodes": n_nodes,
            "flat_wall_s": flat["wall_s"], "hier_wall_s": hier["wall_s"],
            "flat_intra_bytes": flat["intra_bytes"],
            "flat_inter_bytes": flat["inter_bytes"],
            "hier_intra_bytes": hier["intra_bytes"],
            "hier_inter_bytes": hier["inter_bytes"],
            "hier_collective": hier["collective"],
            "matches_flat": match,
        })
print(json.dumps(report))
"""


def run() -> dict:
    from repro.launch import simulate

    sizes = _sizes()
    env = simulate.simulated_env(8, pythonpath=os.path.join(ROOT, "src"))
    env["BENCH_SIZES"] = json.dumps(sizes)
    out = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True,
        env=env, timeout=1800,
    )
    if out.returncode != 0:
        raise RuntimeError(f"scaling child failed:\n{out.stderr[-3000:]}")
    rows = json.loads(out.stdout.strip().splitlines()[-1])

    claims = {"curve_complete": len(rows) == 3 * len(NODE_COUNTS)}
    for wl in ("wordcount", "pagerank", "kmeans"):
        mine = [r for r in rows if r["workload"] == wl]
        multi = [r for r in mine if 1 < r["nodes"] < 8]
        degen = [r for r in mine if r["nodes"] == 8]
        claims[f"hier_cuts_inter_bytes_{wl}"] = bool(multi) and all(
            r["hier_inter_bytes"] < r["flat_inter_bytes"] for r in multi
        ) and all(
            r["hier_inter_bytes"] == r["flat_inter_bytes"] for r in degen
        )
        claims[f"hier_matches_flat_{wl}"] = all(r["matches_flat"] for r in mine)

    return {
        "bench": "BENCH_scaling",
        "scale": SCALE,
        "workload": {
            **sizes, "devices": 8, "node_counts": "1/2/4/8",
        },
        "scaling": {"algorithms": rows},
        "claims": claims,
    }


def main() -> int:
    report = run()
    path = os.path.join(ROOT, "results", "BENCH_scaling.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
        f.write("\n")
    print(json.dumps(report, indent=1))
    return 0 if all(report["claims"].values()) else 1


if __name__ == "__main__":
    sys.exit(main())
