"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes results/bench.json.
"""
from __future__ import annotations

import json
import os
import time


def main() -> None:
    from benchmarks.paper_benchmarks import ALL

    rows = []
    print("name,us_per_call,derived")
    for bench in ALL:
        t0 = time.time()
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived}")
                rows.append(
                    {"name": name, "us_per_call": us, "derived": derived}
                )
        except Exception as e:  # noqa: BLE001
            print(f"{bench.__name__},-1,ERROR:{e}")
            rows.append({"name": bench.__name__, "error": str(e)})
        rows.append(
            {"name": f"_{bench.__name__}_wall_s", "us_per_call": 0,
             "derived": f"{time.time()-t0:.1f}s"}
        )
    os.makedirs("results", exist_ok=True)
    with open("results/bench.json", "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
