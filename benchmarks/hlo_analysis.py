"""Loop-aware HLO accounting: per-device FLOPs, matmul traffic and collective
payload bytes, with every ``while`` body weighted by its trip count.

``compiled.cost_analysis()`` counts each while body ONCE, which understates a
scanned 64-layer model by 64× and chunked attention by (Sq/bq)·(Skv/bk)×.
This parser rebuilds the numbers from the compiled (SPMD-partitioned,
per-device) HLO text:

1. split the module into computations; build a per-computation symbol table
   (op name → shape) including fusion parameters;
2. find every ``while`` op, its body/cond computations, and its trip count
   (the integer constant compared against the induction variable in cond —
   lax.scan/fori_loop always lower this way);
3. propagate multiplicity down the call tree (while bodies, fusions, calls,
   conditionals);
4. sum, per computation × multiplicity:
   * dot FLOPs: 2 · |result| · Π(contracting dims)
   * dot traffic bytes: operand + result bytes (matmul-traffic lower bound —
     assumes elementwise chains fuse, which the MXU pipeline does)
   * collective payload bytes by op kind.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)"
)
_CALL_RE = re.compile(r"(?:fusion|call)\(.*?\).*?(?:calls|to_apply)=%?([\w\.\-]+)")
_COND_RE = re.compile(
    r"conditional\(.*?(?:branch_computations=\{([^}]*)\}|"
    r"true_computation=%?([\w\.\-]+), false_computation=%?([\w\.\-]+))"
)
_DOT_RE = re.compile(r"\bdot\(")
_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")


def _shape_bytes(dtype: str, dims: list[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES.get(dtype, 4)


def _first_shape(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return None
    return dt, [int(d) for d in dims.split(",") if d]


def parse_module(txt: str) -> dict:
    """Returns {"flops": f, "dot_bytes": b, "collectives": {kind: bytes},
    "n_collectives": int} — per-device, loop-weighted."""
    # Some XLA versions print layout annotations after shapes
    # (``f32[32,32]{1,0}``); the braces confuse operand splitting, drop them.
    txt = re.sub(r"\]\{[\d,]*\}", "]", txt)
    # ---- 1. split into computations ---------------------------------------
    comps: dict[str, list[str]] = {}
    cur = None
    for line in txt.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            cur = mc.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            comps[cur].append(line)

    # symbol tables: comp → {opname: (dtype, dims)}
    symtab: dict[str, dict] = {}
    for cname, lines in comps.items():
        tab = {}
        for line in lines:
            md = _DEF_RE.match(line)
            if not md:
                continue
            shape = _first_shape(md.group(2))
            if shape:
                tab[md.group(1)] = shape
        symtab[cname] = tab

    # ---- 2/3. while trip counts + call-graph multiplicities ----------------
    edges: dict[str, list[tuple[str, int]]] = defaultdict(list)  # parent → (child, mult)
    entry = None
    for cname, lines in comps.items():
        if entry is None:
            entry = cname  # first computation printed is ENTRY in XLA dumps
        for line in lines:
            mw = _WHILE_RE.search(line)
            if mw:
                cond, body = mw.group(1), mw.group(2)
                trips = _trip_count(comps.get(cond, []))
                edges[cname].append((body, trips))
                edges[cname].append((cond, trips + 1))
                continue
            mcall = _CALL_RE.search(line)
            if mcall:
                edges[cname].append((mcall.group(1), 1))
                continue
            mcond = _COND_RE.search(line)
            if mcond:
                branches = (
                    mcond.group(1).split(",")
                    if mcond.group(1)
                    else [mcond.group(2), mcond.group(3)]
                )
                for b in branches:
                    b = b.strip().lstrip("%")
                    if b:
                        edges[cname].append((b, 1))

    # ENTRY detection: computation not referenced as a child
    children = {c for lst in edges.values() for c, _ in lst}
    roots = [c for c in comps if c not in children]
    mult: dict[str, float] = defaultdict(float)
    for r in roots:
        mult[r] += 1.0
    # propagate (computations are a DAG; iterate in dependency order)
    order = list(comps.keys())
    changed = True
    it = 0
    while changed and it < 50:
        changed = False
        it += 1
        new = defaultdict(float)
        for r in roots:
            new[r] += 1.0
        for parent in order:
            if mult.get(parent, 0) <= 0:
                continue
            for child, m in edges.get(parent, []):
                new[child] += mult[parent] * m
        if any(abs(new[k] - mult.get(k, 0)) > 0.5 for k in set(new) | set(mult)):
            changed = True
        mult = new

    # ---- 4. accumulate ------------------------------------------------------
    flops = 0.0
    dot_bytes = 0.0
    colls: dict[str, float] = defaultdict(float)
    n_coll = 0
    for cname, lines in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        tab = symtab[cname]
        for line in lines:
            md = _DEF_RE.match(line)
            if not md:
                continue
            rhs = md.group(1), md.group(2)
            name, body = rhs
            out_shape = _first_shape(body)
            if _DOT_RE.search(body):
                if out_shape is None:
                    continue
                dt, dims = out_shape
                out_elems = 1
                for d in dims:
                    out_elems *= d
                k = 1
                mc = _CONTRACT_RE.search(body)
                ops = _OPERANDS_RE.search(body[body.index("dot(") :])
                # Operands may be typed (``dot(f32[32,32] %x, ...)``) — the
                # comma inside the shape breaks naive splitting, so strip the
                # shape tokens first, then split; names may or may not carry
                # a % sigil depending on the XLA print format.
                onames = []
                if ops:
                    bare = re.sub(r"\w+\[[\d,]*\]", "", ops.group(1))
                    onames = [
                        t.strip().lstrip("%") for t in bare.split(",") if t.strip()
                    ]
                lhs_name = onames[0] if onames else None
                if mc and lhs_name and lhs_name in tab:
                    ldims = tab[lhs_name][1]
                    for ci in mc.group(1).split(","):
                        if ci != "" and int(ci) < len(ldims):
                            k *= ldims[int(ci)]
                flops += m * 2.0 * out_elems * k
                # traffic: result + operands
                tb = _shape_bytes(dt, dims)
                for oname in onames:
                    if oname in tab:
                        tb += _shape_bytes(*tab[oname])
                dot_bytes += m * tb
            else:
                mcoll = _COLL_RE.search(body)
                if mcoll and out_shape:
                    kind = mcoll.group(1)
                    colls[kind] += m * _shape_bytes(*out_shape)
                    n_coll += 1

    return {
        "flops": flops,
        "dot_bytes": dot_bytes,
        "collectives": dict(colls),
        "n_collective_sites": n_coll,
        "n_computations": len(comps),
    }


def _trip_count(cond_lines: list[str]) -> int:
    """lax.scan/fori cond: compare(iter, constant) — take that constant."""
    consts: dict[str, int] = {}
    for line in cond_lines:
        md = _DEF_RE.match(line)
        if not md:
            continue
        mm = re.search(r"constant\((\d+)\)", md.group(2))
        if mm and re.match(r"\s*[su]\d+\[\]", md.group(2)):
            consts[md.group(1)] = int(mm.group(1))
    for line in cond_lines:
        if "compare(" in line:
            ops = _OPERANDS_RE.search(line[line.index("compare(") :])
            if ops:
                for oname in ops.group(1).split(","):
                    oname = oname.strip().split(" ")[-1].lstrip("%")
                    if oname in consts:
                        return consts[oname]
    # fallback: any scalar int constant in cond
    return max(consts.values(), default=1)
