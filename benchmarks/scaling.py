"""Node-scaling benchmark — the x-axis of the paper's Figs 4–8.

Runs word count and PageRank on 1/2/4/8 (simulated) devices, each in a fresh
subprocess with ``--xla_force_host_platform_device_count=N`` (the main
process keeps 1 device).  Simulated CPU devices share one socket, so
*wall-clock* does not scale; what the paper's scaling argument rests on is
the per-device work and the wire bytes, which we report:

  eager: shuffle bytes stay ~flat with N (locally-reduced dense partials),
  naive: shuffle bytes grow with emitted pairs — the cross-rack bottleneck
  the paper's §2.3.2 targets.

Usage: PYTHONPATH=src python -m benchmarks.scaling
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD = """
import json, numpy as np, jax, jax.numpy as jnp
from repro.core import data_mesh, distribute, make_dist_hashmap, map_reduce
from repro.core.algorithms import pagerank
from repro.data.synthetic import rmat_edges, zipf_corpus

mesh = data_mesh()
n_dev = len(jax.devices())
out = {"devices": n_dev}

lines, _ = zipf_corpus(2048, 16, 20000, seed=0)
lv = distribute(lines, mesh)
def m(i, toks, emit): emit(toks, 1, mask=toks >= 0)
for engine in ("eager", "naive"):
    hm = make_dist_hashmap(mesh, 4 * 20000 // n_dev + 512, (), jnp.int32, "sum")
    hm2, st = map_reduce(lv, m, "sum", hm, mesh=mesh, engine=engine, return_stats=True)
    st = st.finalize()
    out[f"wc_{engine}_shipped_pairs"] = int(st.pairs_shipped)
    out[f"wc_{engine}_bytes"] = int(st.shuffle_payload_bytes)

edges = rmat_edges(10, 16, seed=0)
for engine in ("eager", "naive"):
    res = pagerank(edges, 1 << 10, tol=0, max_iters=2, mesh=mesh, engine=engine)
    out[f"pr_{engine}_bytes_per_iter"] = int(res.shuffle_bytes_per_iter)
print(json.dumps(out))
"""


def run_at(n_devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env.setdefault("PYTHONPATH", "src")
    p = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True, env=env,
        timeout=900,
    )
    if p.returncode != 0:
        raise RuntimeError(p.stderr[-2000:])
    return json.loads(p.stdout.strip().splitlines()[-1])


def main():
    rows = [run_at(n) for n in (1, 2, 4, 8)]
    os.makedirs("results", exist_ok=True)
    with open("results/scaling.json", "w") as f:
        json.dump(rows, f, indent=1)
    print("devices,wc_eager_bytes,wc_naive_bytes,pr_eager_B/iter,pr_naive_B/iter")
    for r in rows:
        print(
            f"{r['devices']},{r['wc_eager_bytes']},{r['wc_naive_bytes']},"
            f"{r['pr_eager_bytes_per_iter']},{r['pr_naive_bytes_per_iter']}"
        )


if __name__ == "__main__":
    main()
