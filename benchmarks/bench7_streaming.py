"""BENCH 7 — out-of-core streaming: double-buffered blocks vs synchronous.

The PR 7 acceptance workload: a k-means-style assignment/accumulation program
over a ``ChunkedDistVector`` whose blocks live host-side (zlib-compressed,
LRU-spilled past ``max_resident``), streamed through ONE compiled executable.
Measures the same epochs twice:

* ``prefetch=False`` — synchronous baseline: each dispatch is drained before
  the next block is even read (zero transfer/compute overlap);
* ``prefetch=True``  — block k+1 is read + decompressed + device_put on a
  background thread while block k reduces.

Claims recorded as measurements:

* ``one_compile`` — 1 program executable total across every block, epoch and
  both prefetch modes (the traced ``base`` offset keeps shapes static);
* ``prefetch_faster`` — double-buffered wall < synchronous wall;
* ``bit_equal`` — streamed result identical to the in-memory fused program;
* ``spilled`` — the LRU actually spilled cold blocks through the BlockStore.

Run:  PYTHONPATH=src:. python -m benchmarks.bench7_streaming
Writes ``results/BENCH_7.json``.  ``BENCH_SCALE=smoke`` shrinks the dataset
for CI; ``BENCH_SCALE=big`` grows it.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

BIG = os.environ.get("BENCH_SCALE") == "big"
SMOKE = os.environ.get("BENCH_SCALE") == "smoke"


def _sizes():
    if SMOKE:
        return {"n": 1 << 17, "dim": 16, "k": 128, "block_rows": 1 << 14,
                "epochs": 4}
    if BIG:
        return {"n": 1 << 21, "dim": 32, "k": 256, "block_rows": 1 << 17,
                "epochs": 4}
    return {"n": 1 << 19, "dim": 16, "k": 128, "block_rows": 1 << 15,
            "epochs": 3}


def _stream_program(sess, cv, k, dim, centers):
    import jax.numpy as jnp

    from repro.core.algorithms.kmeans import assign_inertia_mapper

    n_blocks = cv.n_blocks

    def step(ctx, s):
        c = s["centers"]
        part = ctx.map_reduce(
            cv, assign_inertia_mapper, "sum",
            jnp.zeros((k, dim + 2), jnp.float32), env=c,
        )
        acc = s["acc"] + part
        last = s["blk"] == n_blocks - 1
        counts = jnp.maximum(acc[:, dim:dim + 1], 1.0)
        new_c = acc[:, :dim] / counts
        return {
            "centers": jnp.where(last, new_c, c),
            "acc": jnp.where(last, jnp.zeros_like(s["acc"]), acc),
            "blk": jnp.where(last, 0, s["blk"] + 1),
        }

    state = {
        "centers": centers,
        "acc": jnp.zeros((k, dim + 2), jnp.float32),
        "blk": jnp.zeros((), jnp.int32),
    }
    return sess.program(step), state


def main():
    import jax.numpy as jnp

    from repro.core.algorithms.kmeans import assign_inertia_mapper
    from repro.core.session import BlazeSession

    sz = _sizes()
    n, dim, k = sz["n"], sz["dim"], sz["k"]
    rng = np.random.RandomState(0)
    # integer-valued f32: block reassociation keeps the sums exact, so the
    # bit-equality claim is checkable
    pts = rng.randint(-30, 30, size=(n, dim)).astype(np.float32)
    centers0 = jnp.asarray(pts[:k].copy())

    sess = BlazeSession()

    # in-memory reference: the same fused program over a resident DistVector
    pts_v = sess.distribute(pts)

    def mem_step(ctx, s):
        c = s["centers"]
        sums = ctx.map_reduce(
            pts_v, assign_inertia_mapper, "sum",
            jnp.zeros((k, dim + 2), jnp.float32), env=c,
        )
        counts = jnp.maximum(sums[:, dim:dim + 1], 1.0)
        return {"centers": sums[:, :dim] / counts}

    mem_prog = sess.program(mem_step)
    mem_state = {"centers": centers0}
    mem_state = mem_prog(mem_state, sz["epochs"])
    ref_centers = np.asarray(mem_state["centers"])

    with tempfile.TemporaryDirectory() as spill_dir:
        cv = sess.chunked(
            pts, block_rows=sz["block_rows"], compress=True,
            spill_dir=spill_dir, max_resident=2,
        )
        prog, state0 = _stream_program(sess, cv, k, dim, centers0)

        # warm the executable so both timed runs measure steady-state epochs
        _, warm = sess.run_stream(prog, state0, max_epochs=1)
        compiles = warm.compiles

        walls = {}
        infos = {}
        for label, pf in (("prefetch_off", False), ("prefetch_on", True)):
            best = float("inf")
            for _ in range(2):  # best-of-2 damps scheduler noise
                t0 = time.perf_counter()
                out, info = sess.run_stream(
                    prog, state0, max_epochs=sz["epochs"], prefetch=pf
                )
                best = min(best, time.perf_counter() - t0)
            walls[label] = best
            infos[label] = info
            compiles += info.compiles
            got_centers = np.asarray(out["centers"])

        spill_bytes = cv.stats()["spill_bytes"]

    on, off = walls["prefetch_on"], walls["prefetch_off"]
    overlap_delta_pct = 100.0 * (off - on) / off if off else 0.0
    bit_equal = bool(np.array_equal(ref_centers, got_centers))

    report = {
        "bench": "BENCH_7",
        "scale": "smoke" if SMOKE else ("big" if BIG else "default"),
        "workload": {
            "rows": n,
            "dim": dim,
            "k": k,
            "block_rows": sz["block_rows"],
            "blocks": cv.n_blocks,
            "epochs": sz["epochs"],
            "block_nbytes": cv.block_nbytes,
        },
        "streaming": {
            "wall_prefetch_on_s": on,
            "wall_prefetch_off_s": off,
            "overlap_delta_pct": overlap_delta_pct,
            "dispatches_per_run": infos["prefetch_on"].dispatches,
            "bytes_streamed_per_run": infos["prefetch_on"].bytes_streamed,
            "spill_bytes": spill_bytes,
            "compiles_total": compiles,
        },
        "claims": {
            "one_compile": compiles == 1,
            "prefetch_faster": on < off,
            "bit_equal": bit_equal,
            "spilled": spill_bytes > 0,
        },
    }
    os.makedirs("results", exist_ok=True)
    with open("results/BENCH_7.json", "w") as f:
        json.dump(report, f, indent=1)
    print(json.dumps(report, indent=1))
    assert report["claims"]["one_compile"], report["streaming"]
    assert report["claims"]["bit_equal"]
    assert report["claims"]["spilled"], report["streaming"]
    assert report["claims"]["prefetch_faster"], report["streaming"]
    return report


if __name__ == "__main__":
    main()
