"""Cross-PR benchmark regression harness (writes ``results/BENCH_8.json``).

Runs the paper's six algorithms at a PINNED smoke scale — the sizes below
are part of the cross-PR contract and must not change, or walls stop being
comparable across ``results/BENCH_*.json`` files — then:

1. records one warm wall per algorithm (compile excluded: the timed run is
   the second dispatch through one resident session),
2. measures tuned-vs-static walls for the two autotunable program drivers
   (k-means dense, word-count hash) and asserts the tuner measured each op
   exactly once and that tuned results are bit-equal to static results,
3. compares every ``regression.<alg>.wall_s`` against the BEST prior value
   for the same metric across all existing ``results/BENCH_*.json`` files
   and exits non-zero when ``current > best * (1 + threshold)``.

``BENCH_REGRESSION_THRESHOLD`` (default ``1.0`` — i.e. fail beyond 2x the
best prior wall) absorbs machine-to-machine variance; CI sets it higher
because the pallas-interpret path is slower and noisier than compiled runs.
The report is written BEFORE the threshold check, so a failing run still
leaves its walls on disk for ``tools/bench_trends.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

BENCH = "BENCH_8"

# Pinned smoke-scale workload — the cross-PR comparability contract.
WORKLOAD = {
    "n_pages": 256, "n_edges": 2048, "pagerank_iters": 10,
    "n_tokens": 4096, "vocab": 128, "wordcount_iters": 3,
    "kmeans_rows": 2048, "kmeans_dim": 8, "kmeans_k": 16, "kmeans_iters": 10,
    "gmm_rows": 512, "gmm_dim": 4, "gmm_k": 4, "gmm_iters": 4,
    "pi_samples": 65536,
    "knn_rows": 2048, "knn_dim": 8, "knn_k": 64,
    "seed": 0,
}


def _timed(fn):
    """Wall of ``fn()`` with a device sync on its pytree result."""
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(jax.tree_util.tree_leaves(out))
    return time.perf_counter() - t0, out


def _warm_and_time(fn):
    """(wall_s, result): run twice through one session — first run compiles,
    second run is the reported wall."""
    fn()
    return _timed(fn)


def run_algorithms(w: dict) -> list[dict]:
    from repro.core.algorithms.gmm import gmm_em
    from repro.core.algorithms.kmeans import kmeans
    from repro.core.algorithms.knn import knn
    from repro.core.algorithms.pagerank import pagerank
    from repro.core.algorithms.pi import estimate_pi
    from repro.core.algorithms.wordcount import wordcount
    from repro.core.session import BlazeSession

    rng = np.random.RandomState(w["seed"])
    edges = rng.randint(0, w["n_pages"], size=(w["n_edges"], 2)).astype(np.int32)
    lines = rng.randint(0, w["vocab"], size=(w["n_tokens"],)).astype(np.int32)
    pts = rng.randn(w["kmeans_rows"], w["kmeans_dim"]).astype(np.float32)
    gpts = rng.randn(w["gmm_rows"], w["gmm_dim"]).astype(np.float32)
    query = rng.randn(w["knn_dim"]).astype(np.float32)

    rows = []

    def record(name, fn):
        sess = BlazeSession()
        wall, _ = _warm_and_time(lambda: fn(sess))
        rows.append({"name": name, "wall_s": wall})
        print(f"{name:<10} wall={wall * 1e3:8.2f}ms")

    record("pagerank", lambda s: pagerank(
        edges, w["n_pages"], max_iters=w["pagerank_iters"], tol=0.0,
        engine="auto", mode="program", session=s,
    ))
    record("wordcount", lambda s: wordcount(
        lines, engine="auto", vocab_size=w["vocab"], mode="program",
        iters=w["wordcount_iters"], session=s,
    ))
    record("kmeans", lambda s: kmeans(
        pts, w["kmeans_k"], max_iters=w["kmeans_iters"], tol=0.0,
        engine="auto", mode="program", seed=w["seed"], session=s,
    ))
    record("gmm", lambda s: gmm_em(
        gpts, w["gmm_k"], max_iters=w["gmm_iters"], tol=0.0, engine="auto",
        mode="program", seed=w["seed"], session=s,
    ))
    record("pi", lambda s: estimate_pi(
        w["pi_samples"], engine="auto", mode="program", session=s,
    ))
    record("knn", lambda s: knn(
        pts[: w["knn_rows"]], query, w["knn_k"], mode="program", session=s,
    ))
    return rows


def run_tuned_vs_static(w: dict) -> dict:
    """Tuned-vs-static walls for the two autotunable program drivers.

    Static and tuned runs use fresh sessions over identical inputs; the
    claims assert (a) the tuner measured once per op (counters), and (b)
    tuned results are bit-identical to static results — integer counts for
    word count, exact one-hot matmul sums for these k-means inputs.
    """
    from repro.core import containers as C
    from repro.core.algorithms.kmeans import _program_step as _kmeans_step
    from repro.core.algorithms.wordcount import _program_step as _wc_step
    from repro.core.session import BlazeSession

    rng = np.random.RandomState(w["seed"])
    pts = rng.randint(-4, 5, size=(w["kmeans_rows"], w["kmeans_dim"])).astype(
        np.float32
    )
    lines = rng.randint(0, w["vocab"], size=(w["n_tokens"],)).astype(np.int32)
    centers0 = jnp.asarray(pts[: w["kmeans_k"]])
    out = {}
    bit_equal = True
    measured_once = True

    def kmeans_run(sess, tune):
        pts_v = C.distribute(pts, sess.mesh)
        step, state0 = _kmeans_step(pts_v, w["kmeans_k"], w["kmeans_dim"],
                                    "auto", "none")
        prog = sess.program(step, mesh=sess.mesh, tune=tune)
        state, _ = sess.run_loop(prog, state0(centers0),
                                 max_iters=w["kmeans_iters"])
        return state["centers"]

    def wc_run(sess, tune):
        lines_v = C.distribute(lines, sess.mesh)
        hm = C.make_dist_hashmap(sess.mesh, 4 * w["vocab"], (), jnp.int32,
                                 "sum")
        step, state0 = _wc_step(lines_v, hm, w["vocab"], "auto")
        prog = sess.program(step, mesh=sess.mesh, tune=tune)
        prog.build(state0)
        prog.reset_carry()
        prog(state0, 1)
        return prog.hash_result(hm)

    for name, run in (("kmeans", kmeans_run), ("wordcount", wc_run)):
        s_static = BlazeSession()
        wall_static, ref = _warm_and_time(lambda: run(s_static, False))
        s_tuned = BlazeSession()
        run(s_tuned, True)  # first dispatch: measures + compiles winner
        first_measured = s_tuned.stats.tune_measurements
        wall_tuned, got = _timed(lambda: run(s_tuned, True))
        measured_once &= first_measured > 0
        measured_once &= s_tuned.stats.tune_measurements == first_measured
        if name == "wordcount":
            rk, rv = ref.items()
            gk, gv = got.items()
            same = np.array_equal(rk, gk) and np.array_equal(rv, gv)
        else:
            same = np.array_equal(np.asarray(ref), np.asarray(got))
        bit_equal &= bool(same)
        out[name] = {
            "wall_static_s": wall_static,
            "wall_tuned_s": wall_tuned,
            "tune_measurements": first_measured,
        }
        print(
            f"{name:<10} static={wall_static * 1e3:8.2f}ms "
            f"tuned={wall_tuned * 1e3:8.2f}ms "
            f"measured={first_measured} bit_equal={bool(same)}"
        )
    out["claims"] = {
        "tuned_measured_once": measured_once, "bit_equal": bit_equal,
    }
    return out


# -- cross-PR comparison -------------------------------------------------------


def comparable_metrics(doc: dict) -> dict[str, float]:
    """Flatten a BENCH report's per-algorithm walls to bench-name-agnostic
    dotted paths, so any later BENCH_N report with the same algorithm names
    lines up against this one."""
    reg = doc.get("regression")
    if not isinstance(reg, dict):
        return {}
    out = {}
    for row in reg.get("algorithms", []):
        if isinstance(row, dict) and "name" in row and "wall_s" in row:
            out[f"regression.{row['name']}.wall_s"] = float(row["wall_s"])
    return out


def best_prior(results_dir: str, exclude: str) -> dict[str, float]:
    best: dict[str, float] = {}
    for fname in sorted(os.listdir(results_dir)):
        if not (fname.startswith("BENCH_") and fname.endswith(".json")):
            continue
        if fname == exclude:
            continue
        try:
            with open(os.path.join(results_dir, fname)) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            continue
        for k, v in comparable_metrics(doc).items():
            if k not in best or v < best[k]:
                best[k] = v
    return best


def check_regressions(current: dict[str, float], best: dict[str, float],
                      threshold: float) -> list[str]:
    failures = []
    for k, v in sorted(current.items()):
        ref = best.get(k)
        if ref is None:
            print(f"{k}: {v:.4f}s (no prior — baseline)")
            continue
        ratio = v / ref if ref > 0 else float("inf")
        status = "OK" if v <= ref * (1.0 + threshold) else "REGRESSION"
        print(f"{k}: {v:.4f}s vs best {ref:.4f}s ({ratio:.2f}x) {status}")
        if status == "REGRESSION":
            failures.append(
                f"{k}: {v:.4f}s is {ratio:.2f}x the best prior {ref:.4f}s "
                f"(threshold {1.0 + threshold:.2f}x)"
            )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=os.path.join(REPO, "results",
                                                  f"{BENCH}.json"))
    ap.add_argument(
        "--threshold", type=float,
        default=float(os.environ.get("BENCH_REGRESSION_THRESHOLD", "1.0")),
        help="fail when wall > best_prior * (1 + threshold)",
    )
    args = ap.parse_args(argv)

    algorithms = run_algorithms(WORKLOAD)
    tuned = run_tuned_vs_static(WORKLOAD)
    claims = tuned.pop("claims")
    doc = {
        "bench": BENCH,
        "scale": "smoke",
        "workload": dict(WORKLOAD),
        "regression": {
            "algorithms": algorithms,
            "wall_total_s": sum(r["wall_s"] for r in algorithms),
            "tuned_vs_static": tuned,
            "threshold": args.threshold,
        },
        "claims": {
            **claims,
            "pinned_scale": True,
        },
    }
    results_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(results_dir, exist_ok=True)
    best = best_prior(results_dir, exclude=os.path.basename(args.out))
    failures = check_regressions(comparable_metrics(doc), best,
                                 args.threshold)
    doc["claims"]["no_regression"] = not failures
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")
    if not claims["tuned_measured_once"] or not claims["bit_equal"]:
        print("FAIL: tuning claims violated "
              f"(measured_once={claims['tuned_measured_once']}, "
              f"bit_equal={claims['bit_equal']})")
        return 1
    if failures:
        print("FAIL: " + "; ".join(failures))
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
