import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Roofline analysis per (arch × shape) on the single-pod mesh.

HLO FLOPs / matmul-traffic / collective-bytes come from a loop-aware parse of
the compiled per-device SPMD module (``benchmarks.hlo_analysis``): every
``while`` body (layer scan, attention chunk scans, MoE expert scan, loss
chunks, remat recomputes) is weighted by its trip count — the numbers
``compiled.cost_analysis()`` cannot give (it counts loop bodies once).

Terms (seconds per step, TPU v5e):
  compute    = HLO_dot_FLOPs_per_device / 197e12
  memory     = matmul_traffic_bytes_per_device / 819e9   (operands + results;
               assumes elementwise chains fuse — the MXU-pipeline bound)
  collective = collective_payload_bytes_per_device / 50e9

plus MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (serve) and the
usefulness ratio MODEL_FLOPS / (HLO_FLOPs × chips), which exposes remat /
recompute / dispatch waste.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--arch A] [--shape S] [--variant V]
"""
import argparse
import json
import time

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link
CHIPS = 256  # single pod

RESULTS = "results/roofline"


def measure_cell(arch: str, shape_name: str, variant: str = "baseline") -> dict:
    import jax

    from benchmarks.hlo_analysis import parse_module
    from repro.configs.base import SHAPES, get_arch
    from repro.launch.dryrun import analytic_flops, build_cell, param_counts
    from repro.launch.mesh import make_production_mesh

    cfg0 = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=False)

    t0 = time.time()
    policy = "dots" if "dots" in variant else "full"
    fn, args, _ = build_cell(cfg0, shape, mesh, remat_policy=policy)
    from repro.compat import set_mesh

    with set_mesh(mesh):
        compiled = fn.lower(*args).compile()
    parsed = parse_module(compiled.as_text())
    ma = compiled.memory_analysis()
    peak_bytes = int(
        ma.argument_size_in_bytes
        + ma.output_size_in_bytes
        + ma.temp_size_in_bytes
        - ma.alias_size_in_bytes
    )

    flops_dev = parsed["flops"]
    bytes_dev = parsed["dot_bytes"]  # matmul-traffic bound (fused elementwise)
    coll_bytes = parsed["collectives"]
    coll_total = sum(coll_bytes.values())

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_total / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    af = analytic_flops(cfg0, shape)
    pc = param_counts(cfg0)
    hlo_global = flops_dev * CHIPS
    ratio = af["model_flops"] / hlo_global if hlo_global else float("nan")

    # step time bound & roofline fraction: useful model FLOPs per second at
    # the bound implied by the dominant term
    step_bound_s = max(terms.values())
    mfu_bound = (
        af["total"] / (step_bound_s * CHIPS * PEAK_FLOPS)
        if step_bound_s > 0
        else float("nan")
    )

    return {
        "arch": arch,
        "shape": shape_name,
        "variant": variant,
        "chips": CHIPS,
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_total,
        "collective_by_kind": coll_bytes,
        "peak_bytes_per_device": peak_bytes,
        "terms_s": terms,
        "bottleneck": bottleneck,
        "model_flops": af["model_flops"],
        "attn_flops": af["attn_flops"],
        "useful_ratio": ratio,
        "roofline_fraction": mfu_bound,
        "params": pc,
        "measure_s": time.time() - t0,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.configs.base import cells, get_arch, list_archs

    os.makedirs(RESULTS, exist_ok=True)
    archs = [args.arch] if args.arch else list_archs()
    for arch in archs:
        cfg = get_arch(arch)
        shapes = [args.shape] if args.shape else [s.name for s in cells(cfg)]
        for sname in shapes:
            out_path = os.path.join(
                RESULTS, f"{arch}_{sname}_{args.variant}.json"
            )
            if os.path.exists(out_path) and not args.force:
                continue
            try:
                rec = measure_cell(arch, sname, args.variant)
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": sname, "error": str(e)[-2000:]}
            with open(out_path, "w") as f:
                json.dump(rec, f, indent=1)
            if "error" in rec:
                print(f"[roofline] {arch} {sname}: ERROR {rec['error'][:200]}")
            else:
                t = rec["terms_s"]
                print(
                    f"[roofline] {arch} {sname}: "
                    f"C={t['compute']*1e3:.1f}ms M={t['memory']*1e3:.1f}ms "
                    f"X={t['collective']*1e3:.1f}ms → {rec['bottleneck']}"
                    f" (useful={rec['useful_ratio']:.2f}, "
                    f"roofline={rec['roofline_fraction']*100:.1f}%)",
                    flush=True,
                )


if __name__ == "__main__":
    main()
