"""Render §Dry-run / §Roofline tables for EXPERIMENTS.md from saved results.

Adds the TPU-adjusted collective term X_adj to every record:

  X_adj = [ 0.5·AG + 0.5·(2/16)·AR + 0.5·permute + 0.5·A2A ] / 50 GB/s

assumptions (stated in EXPERIMENTS.md): (1) activation/grad collectives move
bf16 on TPU where the CPU lowering placed f32 converts before the collective
(×0.5); (2) all-reduces whose consumers are sharded lower as reduce-scatter
(+ a partial gather) on TPU — the CPU partitioner lacks that pass (×2/16).

Usage: PYTHONPATH=src:. python -m benchmarks.roofline_report > results/report.md
"""
from __future__ import annotations

import glob
import json

PEAK_FLOPS = 197e12
ICI_BW = 50e9
CHIPS = 256
HBM = 16 * 2**30


def adjusted_collective_s(by_kind: dict) -> float:
    ag = by_kind.get("all-gather", 0.0)
    ar = by_kind.get("all-reduce", 0.0)
    cp = by_kind.get("collective-permute", 0.0)
    a2a = by_kind.get("all-to-all", 0.0)
    rs = by_kind.get("reduce-scatter", 0.0)
    return (0.5 * ag + 0.5 * (2 / 16) * ar + 0.5 * cp + 0.5 * a2a + 0.5 * rs) / ICI_BW


def load_roofline(variant_filter=None):
    rows = []
    for f in sorted(glob.glob("results/roofline/*.json")):
        r = json.load(open(f))
        if "error" in r:
            continue
        if variant_filter and r.get("variant") != variant_filter:
            continue
        t = r["terms_s"]
        x_adj = adjusted_collective_s(r["collective_by_kind"])
        step = max(t["compute"], t["memory"], x_adj)
        r["x_adj_s"] = x_adj
        r["step_bound_s"] = step
        r["bottleneck_adj"] = max(
            {"compute": t["compute"], "memory": t["memory"], "collective": x_adj},
            key=lambda k: {"compute": t["compute"], "memory": t["memory"],
                           "collective": x_adj}[k],
        )
        total_useful = r["model_flops"] + r["attn_flops"]
        r["roofline_adj"] = (
            total_useful / (step * CHIPS * PEAK_FLOPS) if step > 0 else 0.0
        )
        rows.append(r)
    return rows


def roofline_table(rows) -> str:
    hdr = (
        "| arch | shape | variant | C (ms) | M (ms) | X_raw (ms) | X_adj (ms) "
        "| bound (adj) | useful | roofline (adj) | peak GiB/dev |\n"
        "|---|---|---|---:|---:|---:|---:|---|---:|---:|---:|\n"
    )
    out = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["variant"])):
        t = r["terms_s"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['variant']} "
            f"| {t['compute']*1e3:.1f} | {t['memory']*1e3:.1f} "
            f"| {t['collective']*1e3:.1f} | {r['x_adj_s']*1e3:.1f} "
            f"| {r['bottleneck_adj']} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_adj']*100:.1f}% "
            f"| {r.get('peak_bytes_per_device', 0)/2**30:.1f} |\n"
        )
    return "".join(out)


def dryrun_table() -> str:
    hdr = (
        "| arch | shape | mesh | ok | compile (s) | peak GiB/dev | HLO colls |\n"
        "|---|---|---|---|---:|---:|---:|\n"
    )
    out = [hdr]
    for f in sorted(glob.glob("results/dryrun/*.json")):
        r = json.load(open(f))
        peak = r.get("memory", {}).get("peak_bytes_per_device", 0) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {'✓' if r['ok'] else '✗ ' + r.get('error', '')[:60]} "
            f"| {r.get('compile_s', 0):.0f} | {peak:.1f} "
            f"| {r.get('collectives', {}).get('n_collective_ops', 0)} |\n"
        )
    return "".join(out)


def main():
    print("## §Dry-run (all cells × both meshes)\n")
    print(dryrun_table())
    print("\n## §Roofline (single-pod, loop-aware HLO accounting)\n")
    print(roofline_table(load_roofline()))


if __name__ == "__main__":
    main()
