"""BENCH 6 — BlazeServe: multi-tenant serving of resident Blaze programs.

Drives the PR 6 acceptance workload over real HTTP — 3 tenants x 20 mixed
queries (pi, pagerank, wordcount) against one BlazeServer — and records the
serving-layer claims as measurements:

* ``compiles == 3`` — one compile per distinct plan; every other query rode
  the resident program cache (cross-request ``plan_hash`` reuse);
* ``batched_dispatches >= 1`` — compatible concurrent queries coalesced
  into micro-batched dispatches;
* ``bit_equal == true`` — served results are bit-identical to running the
  same queries serially against a fresh session;
* ``fault_isolated == true`` — an injected mapper fault failed only its own
  request while the server kept serving;
* p50/p99 latency and throughput for the concurrent phase.

Run:  BLAZE_PALLAS_INTERPRET=1 PYTHONPATH=src:. \\
          python -m benchmarks.bench6_serve
Writes ``results/BENCH_6.json``.  ``BENCH_SCALE=smoke`` shrinks datasets
for CI; ``BENCH_SCALE=big`` grows them 4x.
"""
from __future__ import annotations

import json
import os
import threading
import time

import numpy as np

BIG = os.environ.get("BENCH_SCALE") == "big"
SMOKE = os.environ.get("BENCH_SCALE") == "smoke"

TENANTS = ("alice", "bob", "carol")
N_QUERIES = 20  # per tenant


def _sizes():
    if SMOKE:
        return {"graph_scale": 6, "n_lines": 128, "vocab": 64, "pi_n": 2048}
    if BIG:
        return {"graph_scale": 12, "n_lines": 8192, "vocab": 512,
                "pi_n": 1 << 18}
    return {"graph_scale": 9, "n_lines": 1024, "vocab": 128, "pi_n": 1 << 14}


def _workload(pi_n: int) -> list[tuple[str, dict]]:
    work = []
    for i in range(N_QUERIES):
        kind = i % 3
        if kind == 0:
            work.append(("pi", {"n_samples": pi_n, "iters": 1 + i % 2}))
        elif kind == 1:
            work.append(("pagerank", {"iters": 2 + i % 4}))
        else:
            work.append(("wordcount", {"iters": 1}))
    return work


def main():
    from repro.core.session import BlazeSession
    from repro.data import synthetic as S
    from repro.serve import BlazeClient, BlazeServer, run_direct

    sz = _sizes()
    srv = BlazeServer(max_queue=256, per_tenant_inflight=64, max_batch=8)
    edges = S.rmat_edges(sz["graph_scale"], seed=0)
    lines, _ = S.zipf_corpus(sz["n_lines"], 12, sz["vocab"], seed=0)
    srv.register_dataset("edges", edges, n_pages=2 ** sz["graph_scale"])
    srv.register_dataset("lines", lines, vocab_size=sz["vocab"])
    srv.start()

    work = _workload(sz["pi_n"])
    results: dict[str, list] = {}
    t_wall0 = time.perf_counter()

    def tenant_thread(tenant: str):
        client = BlazeClient(srv.url, tenant=tenant)
        out = []
        for q, p in work:
            r, meta = client.query(q, p)
            out.append((q, p, r, meta))
        results[tenant] = out

    threads = [
        threading.Thread(target=tenant_thread, args=(t,)) for t in TENANTS
    ]
    # Hold dispatch until every tenant's first query is queued, so the
    # opening micro-batch forms deterministically (the steady state still
    # coalesces opportunistically while programs execute).
    srv.pause_dispatch()
    for t in threads:
        t.start()
    deadline = time.perf_counter() + 30
    while srv.queue_depth < len(TENANTS) and time.perf_counter() < deadline:
        time.sleep(0.01)
    srv.resume_dispatch()
    for t in threads:
        t.join()
    wall_s = time.perf_counter() - t_wall0

    snap = srv.stats.snapshot()

    # -- bit-equality vs serial direct-session execution ----------------------
    bit_equal = True
    distinct = {(q, json.dumps(p, sort_keys=True)): (q, p)
                for q, p in work}
    for q, p in distinct.values():
        direct = run_direct(BlazeSession(), srv.mesh, srv.datasets, q, p)
        for tenant in TENANTS:
            served = next(
                r for q2, p2, r, _m in results[tenant] if (q2, p2) == (q, p)
            )
            for key, want in direct.items():
                got = served[key]
                same = (got == want) if isinstance(want, float) else \
                    np.array_equal(np.asarray(got), np.asarray(want))
                if not same:
                    bit_equal = False

    # -- fault isolation: one bad request, server keeps serving ---------------
    client = BlazeClient(srv.url, tenant="mallory")
    fault_isolated = False
    try:
        client.query("pagerank", {"damping": "not-a-number"})
    except Exception:  # noqa: BLE001 — the typed rejection is the point
        ok_after, _ = client.query("pagerank", {"iters": 3})
        fault_isolated = bool(np.isfinite(ok_after["delta"]))

    srv.stop()

    report = {
        "bench": "BENCH_6",
        "scale": "smoke" if SMOKE else ("big" if BIG else "default"),
        "workload": {
            "tenants": len(TENANTS),
            "queries_per_tenant": N_QUERIES,
            "distinct_plans": 3,
            "sizes": sz,
        },
        "serving": {
            "completed": snap["completed"],
            "failed": snap["failed"],
            "compiles": snap["compiles"],
            "cache_hits": snap["cache_hits"],
            "dispatched_plans": snap["dispatched_plans"],
            "dispatches": snap["dispatches"],
            "batched_dispatches": snap["batched_dispatches"],
            "coalesced_queries": snap["coalesced_queries"],
            "dedup_hits": snap["dedup_hits"],
            "p50_ms": snap["p50_ms"],
            "p99_ms": snap["p99_ms"],
            "mean_ms": snap["mean_ms"],
            "throughput_qps": snap["completed"] / wall_s,
            "wall_s": wall_s,
        },
        "claims": {
            "one_compile_per_plan": snap["compiles"] == 3,
            "micro_batched": snap["batched_dispatches"] >= 1,
            "bit_equal": bit_equal,
            "fault_isolated": fault_isolated,
        },
    }
    os.makedirs("results", exist_ok=True)
    with open("results/BENCH_6.json", "w") as f:
        json.dump(report, f, indent=1)
    print(json.dumps(report, indent=1))
    assert report["claims"]["one_compile_per_plan"], snap
    assert report["claims"]["micro_batched"], snap
    assert report["claims"]["bit_equal"]
    assert report["claims"]["fault_isolated"]
    return report


if __name__ == "__main__":
    main()
