"""BENCH 9 — fault-tolerant execution: supervision overhead + crash/resume.

The PR 9 acceptance workload: a fused k-means-style program driven through
``run_loop`` three ways —

* **fault-free, supervised vs raw** — the same loop under
  ``retry=RetryPolicy()`` and ``retry=None``; the supervisor is a
  try/except wrapper on the dispatch path, so its fault-free overhead
  should be noise;
* **chaos** — a deterministic transient fault on every 7th ``dispatch``
  hit; bounded retry re-runs the failed dispatch (faults fire before any
  carry writes, so the result stays bit-equal) and the injection ledger
  must balance (``injected == retried + ... + fatal``);
* **crash + resume vs restart** — checkpoint every ``ckpt`` iterations,
  inject one fatal fault near the end, then resume from the latest
  checkpoint and compare against re-running from iteration zero.

Claims recorded as measurements:

* ``overhead_small`` — supervised wall within 15% of the raw wall;
* ``chaos_bit_equal`` — retried run identical to the fault-free run;
* ``resume_bit_equal`` — resumed run identical to the fault-free run;
* ``resume_faster_than_restart`` — resuming the tail beats a full rerun;
* ``ledger_balanced`` — every injected fault has exactly one disposition.

Run:  PYTHONPATH=src:. python -m benchmarks.bench9_faults
Writes ``results/BENCH_9.json``.  ``BENCH_SCALE=smoke`` shrinks the dataset
for CI; ``BENCH_SCALE=big`` grows it.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

BIG = os.environ.get("BENCH_SCALE") == "big"
SMOKE = os.environ.get("BENCH_SCALE") == "smoke"


def _sizes():
    if SMOKE:
        return {"n": 1 << 14, "dim": 8, "k": 32, "iters": 24, "ckpt": 6}
    if BIG:
        return {"n": 1 << 18, "dim": 16, "k": 128, "iters": 48, "ckpt": 8}
    return {"n": 1 << 16, "dim": 16, "k": 64, "iters": 36, "ckpt": 6}


def _loop_program(sess, pts, k, dim, centers0):
    import jax.numpy as jnp

    from repro.core.algorithms.kmeans import assign_inertia_mapper

    pts_v = sess.distribute(pts)

    def step(ctx, s):
        c = s["centers"]
        sums = ctx.map_reduce(
            pts_v, assign_inertia_mapper, "sum",
            jnp.zeros((k, dim + 2), jnp.float32), env=c,
        )
        counts = jnp.maximum(sums[:, dim:dim + 1], 1.0)
        return {"centers": sums[:, :dim] / counts}

    return sess.program(step), {"centers": jnp.asarray(centers0)}


def _timed_loop(sess, prog, state0, iters, repeats=2, **kw):
    best, out, info = float("inf"), None, None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out, info = sess.run_loop(prog, state0, max_iters=iters, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out, info


def main():
    from repro.core import faults
    from repro.core.session import BlazeSession

    sz = _sizes()
    n, dim, k, iters, ckpt = sz["n"], sz["dim"], sz["k"], sz["iters"], sz["ckpt"]
    rng = np.random.RandomState(0)
    # integer-valued f32: reassociation-free sums keep bit-equality checkable
    pts = rng.randint(-30, 30, size=(n, dim)).astype(np.float32)
    centers0 = pts[:k].copy()

    faults.reset(env=False)

    # -- phase 1: fault-free supervision overhead ---------------------------
    sup = BlazeSession(retry=faults.RetryPolicy())
    raw = BlazeSession(retry=None)
    sup_prog, sup_state = _loop_program(sup, pts, k, dim, centers0)
    raw_prog, raw_state = _loop_program(raw, pts, k, dim, centers0)
    sup.run_loop(sup_prog, sup_state, max_iters=1)  # warm both executables
    raw.run_loop(raw_prog, raw_state, max_iters=1)
    sup_wall, sup_out, _ = _timed_loop(sup, sup_prog, sup_state, iters)
    raw_wall, raw_out, _ = _timed_loop(raw, raw_prog, raw_state, iters)
    ref = np.asarray(sup_out["centers"])
    overhead_pct = 100.0 * (sup_wall - raw_wall) / raw_wall if raw_wall else 0.0

    # -- phase 2: chaos — transient dispatch faults, bounded retry ----------
    retries0 = sup.stats.retries
    with faults.inject("dispatch", every=7):
        t0 = time.perf_counter()
        chaos_out, _ = sup.run_loop(sup_prog, sup_state, max_iters=iters)
        chaos_wall = time.perf_counter() - t0
    chaos_retries = sup.stats.retries - retries0
    chaos_bit_equal = bool(np.array_equal(ref, np.asarray(chaos_out["centers"])))

    # -- phase 3: fatal crash, resume from checkpoint vs restart ------------
    crash_at = iters - 2
    with tempfile.TemporaryDirectory() as ckdir:
        crash_dir = os.path.join(ckdir, "crash")
        crashed = False
        # hit counters persist while armed, so aim past phase 2's hits
        hits0 = faults.snapshot()["hits"].get("dispatch", 0)
        with faults.inject("dispatch", at=hits0 + crash_at, fatal=True):
            try:
                sup.run_loop(sup_prog, sup_state, max_iters=iters,
                             checkpoint=crash_dir, checkpoint_every=ckpt)
            except faults.FatalFault:
                crashed = True

        # single shot: a second resume would restore the final checkpoint
        # and do zero work, so best-of-N would be a lie here
        resume_wall, res_out, res_info = _timed_loop(
            sup, sup_prog, sup_state, iters, repeats=1,
            checkpoint=crash_dir, checkpoint_every=ckpt, resume=True,
        )
        restart_dir = os.path.join(ckdir, "restart")
        restart_wall, _, _ = _timed_loop(
            sup, sup_prog, sup_state, iters,
            checkpoint=restart_dir, checkpoint_every=ckpt,
        )
    resume_bit_equal = bool(np.array_equal(ref, np.asarray(res_out["centers"])))
    resumed_from = res_info.resumed_from or 0

    ledger = faults.snapshot()
    faults.reset(env=False)

    report = {
        "bench": "BENCH_9",
        "scale": "smoke" if SMOKE else ("big" if BIG else "default"),
        "workload": {
            "rows": n,
            "dim": dim,
            "k": k,
            "iters": iters,
            "checkpoint_every": ckpt,
            "crash_at_dispatch": crash_at,
        },
        "faults": {
            "supervised_wall_s": sup_wall,
            "unsupervised_wall_s": raw_wall,
            "overhead_pct": overhead_pct,
            "chaos_wall_s": chaos_wall,
            "chaos_retries": chaos_retries,
            "resume_wall_s": resume_wall,
            "restart_wall_s": restart_wall,
            "resumed_from": resumed_from,
            "resumed_iterations": res_info.iterations,
            "injected_total": ledger["injected_total"],
            "retried": ledger["dispositions"].get("retried", 0),
            "fatal": ledger["dispositions"].get("fatal", 0),
        },
        "claims": {
            "overhead_small": overhead_pct < 15.0,
            "chaos_bit_equal": chaos_bit_equal,
            "resume_bit_equal": resume_bit_equal,
            "resume_faster_than_restart": resume_wall < restart_wall,
            "crashed": crashed,
            "ledger_balanced": bool(ledger["balanced"]),
        },
    }
    os.makedirs("results", exist_ok=True)
    with open("results/BENCH_9.json", "w") as f:
        json.dump(report, f, indent=1)
    print(json.dumps(report, indent=1))
    assert report["claims"]["crashed"], report["faults"]
    assert report["claims"]["chaos_bit_equal"]
    assert report["claims"]["resume_bit_equal"]
    assert report["claims"]["ledger_balanced"], ledger
    assert report["claims"]["resume_faster_than_restart"], report["faults"]
    return report


if __name__ == "__main__":
    main()
