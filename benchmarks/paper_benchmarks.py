"""One benchmark per paper table/figure (§3, Table 1, Figs 4–10).

Spark itself is not runnable here; the conventional-MapReduce baseline is the
in-framework ``engine="naive"`` plan (materialise all pairs → wide shuffle →
reduce at the destination), which isolates the *algorithmic* difference the
paper attributes to eager reduction + compact wire + dense fast path.  See
DESIGN.md §7.

Scale: sized for seconds-per-benchmark on CPU.  ``BENCH_SCALE=big`` for 10×,
``BENCH_SCALE=smoke`` for the CI benchmark-smoke job (tiny sizes, counters
over throughput — see ``program_fusion``'s dispatches/compiles columns).
"""
from __future__ import annotations

import inspect
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BlazeSession, data_mesh, distribute, make_dist_hashmap, map_reduce
from repro.core.algorithms import (
    estimate_pi,
    estimate_pi_handrolled,
    gmm_em,
    kmeans,
    knn,
    knn_full_sort,
    pagerank,
    wordcount,
)
from repro.core.serialization import message_sizes
from repro.data.synthetic import cluster_points, rmat_edges, zipf_corpus

BIG = os.environ.get("BENCH_SCALE") == "big"
SMOKE = os.environ.get("BENCH_SCALE") == "smoke"
S = 10 if BIG else 1
# smoke mode divides the workload sizes that dominate wall-clock; every
# benchmark still runs, so the CI job exercises each figure's code path.
D = 20 if SMOKE else 1

# One session for all iterative benchmarks: executables compile on the warmup
# run and every timed run is pure dispatch — the resident-hot-loop setting the
# paper's Spark comparison is about.
SESSION = BlazeSession()


def _timeit(fn, repeats=3):
    fn()  # warmup (paper: warmup runs before counting timings)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def table1_pi():
    """Monte-Carlo π: Blaze MapReduce vs hand-optimised parallel loop."""
    n = 1_000_000 * S // D
    t_mr = _timeit(lambda: estimate_pi(n))
    t_hand = _timeit(lambda: estimate_pi_handrolled(n))
    return [
        ("table1_pi_blaze_mapreduce", t_mr * 1e6, f"{n/t_mr/1e6:.1f}Msamples/s"),
        ("table1_pi_hand_optimized", t_hand * 1e6, f"{n/t_hand/1e6:.1f}Msamples/s"),
        ("table1_pi_ratio", 0.0, f"mapreduce/hand={t_mr/t_hand:.2f}x"),
    ]


def fig4_wordcount():
    lines, _ = zipf_corpus(2000 * S // D + 100, 16, 20000, seed=0)
    n_words = int((lines >= 0).sum())
    rows = []
    stats = {}
    for engine in ("eager", "naive"):
        def run(engine=engine):
            hm, st = wordcount(lines, engine=engine, return_stats=True)
            jax.block_until_ready(hm.table.vals)
            stats[engine] = st.finalize()

        t = _timeit(run)
        rows.append(
            (f"fig4_wordcount_{engine}", t * 1e6, f"{n_words/t/1e6:.1f}Mwords/s")
        )

    # pallas dense column: bounded vocabulary → dense [V] target, segment-
    # reduce kernel combine (interpret mode on CPU — structural, not perf).
    def run_pallas():
        counts, st = wordcount(
            lines, engine="pallas", target="dense", vocab_size=20000,
            return_stats=True, session=SESSION,
        )
        jax.block_until_ready(counts)
        stats["pallas"] = st.finalize()

    t = _timeit(run_pallas)
    occ = stats["pallas"].kernel_occupancy
    rows.append(
        (
            "fig4_wordcount_pallas", t * 1e6,
            f"{n_words/t/1e6:.1f}Mwords/s;"
            f"occupancy={occ:.2f};bn={stats['pallas'].kernel_block_n}",
        )
    )

    # pallas hash column: open vocabulary → DistHashMap target, the hash-
    # aggregation kernel replaces both unique_combines + hashmap_insert.
    # Duplicate-heavy small-vocab slice — the local-combine regime; sized so
    # interpret mode stays comparable (see bench4_hash_aggregation).
    hlines, _ = zipf_corpus(200 * S, 16, 200, seed=0)
    sess_h = BlazeSession()

    def run_pallas_hash():
        hm, st = wordcount(
            hlines, engine="pallas", return_stats=True, session=sess_h
        )
        jax.block_until_ready(hm.table.vals)
        stats["pallas_hash"] = st.finalize()

    t = _timeit(run_pallas_hash)
    sh = stats["pallas_hash"]
    rows.append(
        (
            "fig4_wordcount_pallas_hash", t * 1e6,
            f"{hlines.size/t/1e6:.1f}Mwords/s;occupancy={sh.kernel_occupancy:.2f};"
            f"cap={sh.kernel_table_cap};probes={sh.kernel_probe_depth}",
        )
    )
    rows.append(
        (
            "fig4_wordcount_wire",
            0.0,
            f"eager_bytes={stats['eager'].shuffle_payload_bytes};"
            f"naive_bytes={stats['naive'].shuffle_payload_bytes};"
            f"pallas_bytes={stats['pallas'].shuffle_payload_bytes}",
        )
    )
    return rows


def bench4_hash_aggregation():
    """The hash-path benchmark (PR 4): every engine on the same duplicate-
    heavy open-vocabulary wordcount, plus the fused program mode — and a
    machine-readable ``results/BENCH_4.json`` capturing wall time,
    dispatches, pairs shipped / wire bytes (narrowed keys vs int32) and the
    kernel's occupancy / table / probe counters, so the hash-path perf
    trajectory is tracked from this PR on.

    Sizing note: the kernel runs in *interpret mode* on CPU CI — the
    duplicate-heavy small-vocab slice is the regime where the streaming
    combine matches the sort-based eager plan even interpreted (≈16×
    duplication per key); TPU runs lift the same program unchanged.
    """
    n_lines, width, vocab = 200 * (10 if BIG else 1) // (4 if SMOKE else 1), 16, 200
    iters, unroll = 10, 5
    lines, _ = zipf_corpus(max(n_lines, 50), width, vocab, seed=0)
    n_tokens = int(lines.size)
    rows, algos = [], []

    def record(name, wall_s, counters, st=None, extra=None):
        # ``counters`` are per-invocation deltas (one algorithm call), NOT
        # cumulative session totals — _timeit runs 1 warmup + 3 reps, and
        # cross-algorithm comparisons need single-run numbers.
        entry = {
            "name": name,
            "wall_s": round(wall_s, 6),
            "tokens_per_s": round(n_tokens / max(wall_s, 1e-9)),
            **counters,
        }
        if st is not None:
            entry.update(
                pairs_emitted=st.pairs_emitted,
                pairs_shipped=st.pairs_shipped,
                shuffle_payload_bytes=st.shuffle_payload_bytes,
                overflow=st.overflow,
                kernel_occupancy=st.kernel_occupancy,
                kernel_table_cap=st.kernel_table_cap,
                kernel_probe_depth=st.kernel_probe_depth,
                kernel_block_n=st.kernel_block_n,
            )
        if extra:
            entry.update(extra)
        algos.append(entry)
        derived = ";".join(
            f"{k}={entry[k]}"
            for k in (
                "dispatches", "pairs_shipped", "shuffle_payload_bytes",
                "kernel_occupancy", "overflow",
            )
            if k in entry
        )
        rows.append((f"bench4_{name}", wall_s * 1e6, derived))

    # -- per-op engines (vocab bound known -> narrowed int16/int8 keys) -----
    for engine in ("eager", "pallas", "naive"):
        sess = BlazeSession()
        last = {}

        def run(e=engine, s=sess, last=last):
            d0, c0, h0 = (
                s.stats.dispatches, s.stats.compiles, s.stats.host_syncs
            )
            hm, st = wordcount(
                lines, engine=e, vocab_size=vocab, session=s,
                return_stats=True,
            )
            jax.block_until_ready(hm.table.vals)
            last["st"] = st.finalize()
            last["counters"] = {
                "dispatches": s.stats.dispatches - d0,
                "compiles": s.stats.compiles - c0,
                "program_compiles": 0,
                "host_syncs": s.stats.host_syncs - h0,
            }

        t = _timeit(run)
        record(f"wordcount_{engine}", t, last["counters"], last["st"])

    # -- wire narrowing delta: the same eager run shipping int32 keys -------
    sess = BlazeSession()
    hm, st = wordcount(lines, engine="eager", session=sess, return_stats=True)
    # vocab bound inferred from data => narrowed; rebuild without key_range
    from repro.core import distribute as _dist, make_dist_hashmap as _mk
    from repro.core.algorithms.wordcount import wordcount_mapper as _wm
    import jax.numpy as jnp

    hm32 = _mk(sess.mesh, max(64, 4 * vocab), (), jnp.int32, "sum")
    _, st32 = sess.map_reduce(
        _dist(lines, sess.mesh), _wm, "sum", hm32, return_stats=True
    )
    narrow_b, wide_b = st.finalize().shuffle_payload_bytes, st32.finalize().shuffle_payload_bytes
    rows.append(
        (
            "bench4_wire_narrowing", 0.0,
            f"narrow_bytes={narrow_b};int32_bytes={wide_b};"
            f"saving={1 - narrow_b / max(wide_b, 1):.0%}",
        )
    )

    # -- fused program mode: iters passes, ceil(iters/unroll) dispatches.
    # One COLD call per engine (program_fusion precedent): the driver builds
    # its program per call, so the single compile is part of the story —
    # the loop counters (1 compile, 2 dispatches, 0 syncs) are the contract.
    for engine in ("eager", "pallas"):
        sess = BlazeSession()
        t0 = time.perf_counter()
        res = wordcount(
            lines, engine=engine, mode="program", iters=iters, unroll=unroll,
            vocab_size=vocab, session=sess,
        )
        t = time.perf_counter() - t0
        record(
            f"wordcount_program_{engine}", t / iters,
            {
                "dispatches": res.dispatches,
                "compiles": res.compiles,
                "program_compiles": res.program_compiles,
                "host_syncs": res.host_syncs,
            },
            extra={
                "cold": True,  # includes the one program compile
                "iterations": res.iterations,
            },
        )

    os.makedirs("results", exist_ok=True)
    payload = {
        "bench": "BENCH_4",
        "config": {
            "n_lines": int(lines.shape[0]), "width": width, "vocab": vocab,
            "tokens": n_tokens, "iters": iters, "unroll": unroll,
            "interpret_mode": True,
        },
        "algorithms": algos,
    }
    with open("results/BENCH_4.json", "w") as f:
        json.dump(payload, f, indent=1)
    rows.append(("bench4_json", 0.0, "written=results/BENCH_4.json"))
    return rows


def fig5_pagerank():
    scale = 12 if BIG else (8 if SMOKE else 10)
    edges = rmat_edges(scale, 16, seed=0)  # 2^scale nodes, 16·2^scale links
    n = 1 << scale
    rows = []
    for engine in ("eager", "naive"):
        res = pagerank(edges, n, tol=1e-5, max_iters=30, engine=engine,
                       session=SESSION)
        t = _timeit(
            lambda e=engine: pagerank(edges, n, tol=0, max_iters=3, engine=e,
                                      session=SESSION)
        ) / 3
        rows.append(
            (
                f"fig5_pagerank_{engine}", t * 1e6,
                f"{len(edges)/t/1e6:.1f}Mlinks/s/iter;iters={res.iterations};"
                f"bytes/iter={res.shuffle_bytes_per_iter}",
            )
        )
    return rows


def fig6_kmeans():
    pts, _ = cluster_points(200_000 * S // D, 3, 5, seed=0)
    init = pts[:5].copy()
    rows = []
    for engine in ("eager", "pallas", "naive"):
        t = _timeit(
            lambda e=engine: kmeans(pts, 5, init_centers=init, max_iters=3,
                                    tol=0, engine=e, session=SESSION)
        ) / 3
        rows.append(
            (f"fig6_kmeans_{engine}", t * 1e6, f"{len(pts)/t/1e6:.1f}Mpoints/s/iter")
        )
    # fused Pallas kernel (interpret mode on CPU — structural, not perf)
    from repro.kernels.ops import kmeans_assign

    c = jnp.asarray(init)
    n_assign = 20000 // D
    t = _timeit(lambda: jax.block_until_ready(
        kmeans_assign(jnp.asarray(pts[:n_assign]), c, impl="pallas")[1]))
    rows.append(
        (f"fig6_kmeans_pallas_assign_{n_assign // 1000}k", t * 1e6,
         f"{n_assign/t/1e6:.2f}Mpoints/s(interpret)")
    )
    return rows


def fig7_gmm():
    pts, _ = cluster_points(20_000 * S // D + 500, 3, 5, seed=1)
    init = pts[:5].copy()
    t = _timeit(lambda: gmm_em(pts, 5, init_mu=init, max_iters=3, tol=0,
                               session=SESSION)) / 3
    return [("fig7_gmm_eager", t * 1e6, f"{len(pts)/t/1e6:.2f}Mpoints/s/iter")]


def fig8_knn():
    pts, _ = cluster_points(500_000 * S // D, 4, 3, seed=2)
    q = np.zeros(4, np.float32)
    t_topk = _timeit(lambda: knn(pts, q, 100))
    t_sort = _timeit(lambda: knn_full_sort(pts, q, 100))
    return [
        ("fig8_knn_topk", t_topk * 1e6, f"{len(pts)/t_topk/1e6:.1f}Mpoints/s"),
        ("fig8_knn_fullsort", t_sort * 1e6, f"{len(pts)/t_sort/1e6:.1f}Mpoints/s"),
    ]


def fig9_memory():
    """Working-set bytes per engine (shuffle buffers + table), analytic from
    the engine's own wire accounting — the quantity Fig 9 tracks."""
    lines, _ = zipf_corpus(2000, 16, 20000, seed=0)
    rows = []
    for engine in ("eager", "naive"):
        hm, st = wordcount(lines, engine=engine, return_stats=True)
        st = st.finalize()
        table_bytes = hm.table.keys.size * 4 + hm.table.vals.size * 4
        rows.append(
            (
                f"fig9_memory_wordcount_{engine}", 0.0,
                f"shuffle_bytes={st.shuffle_payload_bytes};"
                f"table_bytes={table_bytes};"
                f"pairs_live={st.pairs_shipped}",
            )
        )
    return rows


_CORE_APIS = [
    "map_reduce", "distribute", "collect", "topk", "foreach", "load_file",
    "make_dist_hashmap", "DistRange", "DistVector", "DistHashMap",
]


def fig10_cognitive():
    """Distinct parallel-API count per task (the paper's cognitive-load
    metric): Blaze-APIs referenced by each algorithm's source vs the ~30
    distinct primitives the paper counts in Spark's implementations."""
    from repro.core.algorithms import gmm, kmeans as km, knn as knn_mod
    from repro.core.algorithms import pagerank as pr, pi as pi_mod, wordcount as wc

    rows = []
    union = set()
    for name, mod in [
        ("pi", pi_mod), ("wordcount", wc), ("pagerank", pr),
        ("kmeans", km), ("gmm", gmm), ("knn", knn_mod),
    ]:
        src = inspect.getsource(mod)
        used = {a for a in _CORE_APIS if a in src}
        union |= used
        rows.append((f"fig10_apis_{name}", 0.0, f"n={len(used)}:{sorted(used)}"))
    rows.append(("fig10_apis_union_blaze", 0.0, f"n={len(union)}"))
    rows.append(("fig10_apis_spark_paper", 0.0, "n=30 (paper's count)"))
    return rows


def session_reuse():
    """Compiled-executable reuse across iterations (the session tentpole):
    first iteration pays compile, steady state is pure dispatch."""
    scale = 8 if SMOKE else 10
    edges = rmat_edges(scale, 16, seed=0)
    n = 1 << scale
    rows = []

    sess = BlazeSession()
    t0 = time.perf_counter()
    pagerank(edges, n, tol=0, max_iters=1, session=sess)
    t_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    pagerank(edges, n, tol=0, max_iters=10, session=sess)
    t_steady = (time.perf_counter() - t0) / 10
    info = sess.cache_info()
    rows.append(
        (
            "session_pagerank_first_iter", t_first * 1e6,
            f"compiles={info['compiles']};entries={info['entries']}",
        )
    )
    rows.append(
        (
            "session_pagerank_steady_iter", t_steady * 1e6,
            f"hit_rate={info['hit_rate']:.2f};speedup={t_first/t_steady:.1f}x",
        )
    )

    pts, _ = cluster_points(50_000 // D, 3, 5, seed=0)
    init = pts[:5].copy()
    sess2 = BlazeSession()
    t0 = time.perf_counter()
    kmeans(pts, 5, init_centers=init, tol=0, max_iters=1, session=sess2)
    t_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    kmeans(pts, 5, init_centers=init, tol=0, max_iters=10, session=sess2)
    t_steady = (time.perf_counter() - t0) / 10
    rows.append(
        (
            "session_kmeans_steady_iter", t_steady * 1e6,
            f"compiles={sess2.stats.compiles};"
            f"speedup={t_first/t_steady:.1f}x",
        )
    )
    return rows


def program_fusion():
    """Fused iteration programs vs per-op dispatch (the program tentpole):
    the same 10 iterations as per-op MapReduce calls and as ONE
    ``session.program`` executable driven by ``run_loop(unroll=5)``.  The
    derived column publishes the assertable counters — program compiles,
    executable dispatches and host syncs per algorithm — which the CI
    benchmark-smoke job lifts into its job summary."""
    iters, unroll = 10, 5
    rows = []

    def run_both(name, fn):
        # One cold run per mode (compile included for both — per-op compiles
        # its 3–4 executables, program compiles 1 fused one), counters from
        # the same run.
        for mode, unr in (("per_op", 1), ("program", unroll)):
            sess = BlazeSession()
            t0 = time.perf_counter()
            res = fn(mode, unr, sess)
            t = (time.perf_counter() - t0) / iters
            rows.append(
                (
                    f"program_{name}_{mode}", t * 1e6,
                    f"iters={res.iterations};compiles={res.compiles};"
                    f"program_compiles={res.program_compiles};"
                    f"dispatches={res.dispatches};host_syncs={res.host_syncs}",
                )
            )

    scale = 8 if SMOKE else 10
    edges = rmat_edges(scale, 16, seed=0)
    n = 1 << scale
    run_both(
        "pagerank",
        lambda m, u, s: pagerank(
            edges, n, tol=0, max_iters=iters, mode=m, unroll=u, session=s
        ),
    )

    pts, _ = cluster_points(50_000 // D, 3, 5, seed=0)
    init = pts[:5].copy()
    run_both(
        "kmeans",
        lambda m, u, s: kmeans(
            pts, 5, init_centers=init, tol=0, max_iters=iters, mode=m,
            unroll=u, session=s,
        ),
    )

    gpts, _ = cluster_points(5_000 // D + 500, 3, 5, seed=1)
    ginit = gpts[:5].copy()
    run_both(
        "gmm",
        lambda m, u, s: gmm_em(
            gpts, 5, init_mu=ginit, tol=0, max_iters=iters, mode=m,
            unroll=u, session=s,
        ),
    )
    return rows


def bench5_plan_batching():
    """The plan-optimizer benchmark (PR 5): every program-able algorithm
    built twice from the same step function — once through the full
    optimizer (collective batching + CSE + pruning) and once with
    ``passes=()`` — reporting collectives-per-iteration before/after plus
    fused-block wall time, and writing machine-readable
    ``results/BENCH_5.json`` so the batching pass's effect is tracked from
    this PR on.  GMM is the headline: its EM round's 4 independent psums
    fuse into 2 collectives."""
    import importlib

    iters = 10
    rows, algos = [], []
    _alg = "repro.core.algorithms."
    pr_mod = importlib.import_module(_alg + "pagerank")
    km_mod = importlib.import_module(_alg + "kmeans")
    gmm_mod = importlib.import_module(_alg + "gmm")
    wc_mod = importlib.import_module(_alg + "wordcount")
    pi_mod = importlib.import_module(_alg + "pi")

    from repro.core import distribute as _dist, make_dist_hashmap as _mk
    from repro.data.synthetic import zipf_corpus

    sess = BlazeSession()

    # (name, (step_fn, state)) builders — all six shapes that can fuse
    cases = []
    scale = 8 if SMOKE else 10
    edges = rmat_edges(scale, 16, seed=0)
    n = 1 << scale
    deg = jnp.asarray(np.bincount(edges[:, 0], minlength=n).astype(np.int32))
    step, st0 = pr_mod._program_step(
        _dist(edges.astype(np.int32), sess.mesh), deg, n, 0.85, "eager",
        "none",
    )
    cases.append(("pagerank", step,
                  st0(jnp.full((n,), 1.0 / n, jnp.float32))))

    pts, _ = cluster_points(50_000 // D, 3, 5, seed=0)
    step, st0 = km_mod._program_step(
        _dist(pts.astype(np.float32), sess.mesh), 5, 3, "eager", "none"
    )
    cases.append(("kmeans", step, st0(jnp.asarray(pts[:5], jnp.float32))))

    gpts, _ = cluster_points(5_000 // D + 500, 3, 5, seed=1)
    grows = np.concatenate(
        [gpts, np.zeros((len(gpts), 5), np.float32)], axis=1
    )
    step, st0 = gmm_mod._program_step(
        _dist(grows.astype(np.float32), sess.mesh), 5, 3, len(gpts), "eager"
    )
    cases.append(("gmm", step, st0(
        np.full(5, 0.2, np.float32), gpts[:5].astype(np.float32),
        np.tile(np.eye(3, dtype=np.float32), (5, 1, 1)),
    )))

    lines, _ = zipf_corpus(200, 16, 200, seed=0)
    hm = _mk(sess.mesh, 4 * 200, (), jnp.int32, "sum")
    step, st0 = wc_mod._program_step(
        _dist(lines, sess.mesh), hm, 200, "eager"
    )
    cases.append(("wordcount", step, st0))

    step, st0 = pi_mod._program_step(100_000 // D, "eager")
    cases.append(("pi", step, st0))

    for name, step, state in cases:
        entry = {"name": name, "iters": iters}
        for label, passes in (("optimized", None), ("unbatched", ())):
            prog = sess.program(step, passes=passes)
            plan = prog.build(state)
            t0 = time.perf_counter()
            out = prog(state, iters)
            jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
            wall = time.perf_counter() - t0
            entry[label] = {
                "collectives_per_iter": plan.collectives_per_iter,
                "cse_hits": plan.cse_hits,
                "pruned_sources": plan.pruned_sources,
                "plan_hash": plan.hash,
                "wall_s_cold_block": round(wall, 6),
            }
        algos.append(entry)
        before = entry["unbatched"]["collectives_per_iter"]
        after = entry["optimized"]["collectives_per_iter"]
        rows.append(
            (
                f"bench5_{name}",
                entry["optimized"]["wall_s_cold_block"] * 1e6 / iters,
                f"collectives/iter={after} (unbatched {before});"
                f"plan={entry['optimized']['plan_hash']}",
            )
        )

    os.makedirs("results", exist_ok=True)
    payload = {
        "bench": "BENCH_5",
        "config": {"iters": iters, "smoke": SMOKE},
        "algorithms": algos,
    }
    with open("results/BENCH_5.json", "w") as f:
        json.dump(payload, f, indent=1)
    rows.append(("bench5_json", 0.0, "written=results/BENCH_5.json"))
    return rows


def sec232_serialization():
    """§2.3.2 claim: small-int pairs are 2 B (tag-free) vs 4 B (Protobuf)."""
    rng = np.random.RandomState(0)
    small = rng.randint(0, 100, 10_000)
    sizes = message_sizes(small, np.ones_like(small))
    per_pair_blaze = sizes["blaze_bytes"] / len(small)
    per_pair_proto = sizes["protobuf_bytes"] / len(small)
    return [
        (
            "sec232_serialization_small_ints", 0.0,
            f"blaze={per_pair_blaze:.2f}B/pair;protobuf={per_pair_proto:.2f}B/pair;"
            f"saving={1-per_pair_blaze/per_pair_proto:.0%}",
        )
    ]


ALL = [
    table1_pi,
    fig4_wordcount,
    bench4_hash_aggregation,
    fig5_pagerank,
    fig6_kmeans,
    fig7_gmm,
    fig8_knn,
    fig9_memory,
    fig10_cognitive,
    session_reuse,
    program_fusion,
    bench5_plan_batching,
    sec232_serialization,
]
