"""Validate every ``results/BENCH_*.json`` against the unified report shape.

One schema for all cross-PR benchmark reports (BENCH_6 serving, BENCH_7
streaming, BENCH_8 regression, BENCH_scaling multi-host, and whatever comes
next).  Numbered and named reports alike (``BENCH_\\w+``) must carry:

* ``bench``   — string matching the file name (``BENCH_8`` in
  ``BENCH_8.json``, ``BENCH_scaling`` in ``BENCH_scaling.json``), so a
  copied report can't masquerade as another PR's;
* ``scale``   — non-empty string (``smoke`` / ``default`` / ``big``);
* ``workload``— non-empty object of scalars: the pinned sizes that make
  walls comparable across files;
* exactly ONE payload section — any other key mapping to an object — that
  contains at least one numeric wall metric (a key containing ``wall`` or
  ``_ms``/``_s``-suffixed latency), because a report without a wall can't
  participate in trend/regression comparison;
* ``claims``  — non-empty object of booleans.

Exit status is the number of invalid files.  CI runs this in the
bench-smoke job right after the reports are (re)generated.

Usage: ``python tools/check_bench_schema.py [files...]``
(defaults to every ``results/BENCH_*.json``).
"""
from __future__ import annotations

import json
import os
import re
import sys

RESULTS = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "results"
)
META_KEYS = ("bench", "scale", "workload", "claims")


def _is_wall_key(k: str) -> bool:
    return "wall" in k or k.endswith("_ms") or k.endswith("_s")


def _numeric_walls(body) -> int:
    """Count numeric wall metrics in a payload section, including one level
    of nesting and ``algorithms``-style row lists."""
    count = 0
    items = []
    if isinstance(body, dict):
        items = list(body.items())
    for k, v in items:
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            count += _is_wall_key(k)
        elif isinstance(v, dict):
            count += _numeric_walls(v)
        elif isinstance(v, list):
            for row in v:
                count += _numeric_walls(row)
    return count


def check_report(path: str) -> list[str]:
    errors = []
    name = os.path.basename(path)
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [f"unreadable JSON: {e}"]
    if not isinstance(doc, dict):
        return ["top level must be an object"]

    m = re.fullmatch(r"(BENCH_\w+)\.json", name)
    expect = m.group(1) if m else None
    if doc.get("bench") != expect:
        errors.append(
            f"bench must be {expect!r} (the file name), got {doc.get('bench')!r}"
        )
    if not (isinstance(doc.get("scale"), str) and doc["scale"]):
        errors.append(f"scale must be a non-empty string, got {doc.get('scale')!r}")
    wl = doc.get("workload")
    if not (isinstance(wl, dict) and wl):
        errors.append("workload must be a non-empty object")
    claims = doc.get("claims")
    if not (isinstance(claims, dict) and claims):
        errors.append("claims must be a non-empty object")
    elif not all(isinstance(v, bool) for v in claims.values()):
        bad = {k: v for k, v in claims.items() if not isinstance(v, bool)}
        errors.append(f"claims values must be booleans, got {bad!r}")

    payload = {
        k: v for k, v in doc.items()
        if k not in META_KEYS and isinstance(v, dict)
    }
    stray = [
        k for k in doc
        if k not in META_KEYS and not isinstance(doc[k], dict)
    ]
    if stray:
        errors.append(f"non-object top-level keys besides meta: {stray}")
    if len(payload) != 1:
        errors.append(
            f"expected exactly one payload section, got {sorted(payload) or 'none'}"
        )
    else:
        ((section, body),) = payload.items()
        if _numeric_walls(body) == 0:
            errors.append(f"payload section {section!r} has no numeric wall metric")
    return errors


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    paths = argv or sorted(
        os.path.join(RESULTS, f)
        for f in os.listdir(RESULTS)
        if re.fullmatch(r"BENCH_\w+\.json", f)
    )
    if not paths:
        print("no BENCH_*.json reports to check")
        return 0
    bad = 0
    for p in paths:
        errors = check_report(p)
        if errors:
            bad += 1
            for e in errors:
                print(f"{os.path.basename(p)}: FAIL: {e}")
        else:
            print(f"{os.path.basename(p)}: ok")
    return bad


if __name__ == "__main__":
    sys.exit(main())
