#!/usr/bin/env python
"""Golden snapshots of ``session.explain()`` for the six paper algorithms.

Builds each algorithm's fused program on a fixed 1-device mesh with fixed
(data-independent) shapes, renders the optimized logical plan, and diffs it
against ``tests/goldens/explain_<algo>.txt``.  CI runs this after the test
suite (``--check`` is the default); regenerate with ``--update`` after an
intentional plan change.

Everything in the rendering is deterministic: node descriptions use mapper
qualnames and abstract shapes (never object ids), plan hashes digest those
same strings, and the mesh is pinned to one device so shard counts match on
any machine.

Usage:
    PYTHONPATH=src python tools/check_explain_goldens.py [--update]
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

GOLDEN_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "tests", "goldens",
)


def build_plans() -> dict[str, str]:
    """{algorithm: rendered explain text} for all six paper algorithms."""
    import jax.numpy as jnp
    import numpy as np

    import importlib

    from repro.core import BlazeSession, distribute, make_dist_hashmap
    from repro.core.containers import data_mesh

    # algorithms/__init__ re-exports driver *functions* under the module
    # names, so fetch the submodules explicitly
    _alg = "repro.core.algorithms."
    gmm = importlib.import_module(_alg + "gmm")
    kmeans = importlib.import_module(_alg + "kmeans")
    knn = importlib.import_module(_alg + "knn")
    pagerank = importlib.import_module(_alg + "pagerank")
    pi = importlib.import_module(_alg + "pi")
    wordcount = importlib.import_module(_alg + "wordcount")

    mesh = data_mesh(1)  # pinned: goldens must not depend on device count
    sess = BlazeSession(mesh)
    out: dict[str, str] = {}

    # -- pi: one static-key dense sum ----------------------------------------
    step, state = pi._program_step(100_000, "eager")
    out["pi"] = sess.program(step, mesh=mesh).build(state).render()

    # -- pagerank: 3 dense ops; sink+contribution psums batch ----------------
    edges = np.zeros((512, 2), np.int32)
    deg = jnp.zeros((64,), jnp.int32)
    step, state0 = pagerank._program_step(
        distribute(edges, mesh), deg, 64, 0.85, "eager", "none"
    )
    out["pagerank"] = sess.program(step, mesh=mesh).build(
        state0(jnp.full((64,), 1.0 / 64, jnp.float32))
    ).render()

    # -- kmeans: ONE [K, dim+2] op carries sums, counts AND inertia ----------
    pts_v = distribute(np.zeros((256, 3), np.float32), mesh)
    step, state0 = kmeans._program_step(pts_v, 4, 3, "eager", "none")
    out["kmeans"] = sess.program(step, mesh=mesh).build(
        state0(jnp.zeros((4, 3), jnp.float32))
    ).render()

    # -- gmm: 2 foreach + 4 dense ops; ll/Nk/Σwx batch into one psum ---------
    rows_v = distribute(np.zeros((256, 5), np.float32), mesh)  # [x(2) | w(3)]
    step, state0 = gmm._program_step(rows_v, 3, 2, 256, "eager")
    out["gmm"] = sess.program(step, mesh=mesh).build(
        state0(
            np.full(3, 1 / 3, np.float32),
            np.zeros((3, 2), np.float32),
            np.tile(np.eye(2, dtype=np.float32), (3, 1, 1)),
        )
    ).render()

    # -- wordcount: one hash-target node, table threaded through the loop ----
    lines_v = distribute(np.zeros((32, 8), np.int32), mesh)
    hm = make_dist_hashmap(mesh, 256, (), jnp.int32, "sum")
    step, state = wordcount._program_step(lines_v, hm, 50, "pallas")
    out["wordcount"] = sess.program(step, mesh=mesh).build(state).render()

    # -- knn: container-level topk node; the engine request is surfaced ------
    pts_v = distribute(np.zeros((256, 3), np.float32), mesh)
    step = knn._program_step(pts_v, 8, "pallas")
    state = {
        "q": jnp.zeros((3,), jnp.float32),
        "neighbors": jnp.zeros((8, 3), jnp.float32),
        "scores": jnp.full((8,), -jnp.inf, jnp.float32),
    }
    out["knn"] = sess.program(step, mesh=mesh).build(state).render()

    return out


def main() -> int:
    update = "--update" in sys.argv
    plans = build_plans()
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    failed = []
    for name, text in sorted(plans.items()):
        path = os.path.join(GOLDEN_DIR, f"explain_{name}.txt")
        if update:
            with open(path, "w") as f:
                f.write(text + "\n")
            print(f"wrote {path}")
            continue
        if not os.path.exists(path):
            failed.append((name, "golden file missing — run with --update"))
            continue
        want = open(path).read().rstrip("\n")
        if text != want:
            import difflib

            diff = "\n".join(difflib.unified_diff(
                want.splitlines(), text.splitlines(),
                fromfile=f"goldens/explain_{name}.txt", tofile="current",
                lineterm="",
            ))
            failed.append((name, diff))
    if failed:
        for name, detail in failed:
            print(f"\n== explain golden mismatch: {name} ==\n{detail}")
        print(
            f"\n{len(failed)} golden(s) out of date. If the plan change is "
            "intentional: PYTHONPATH=src python tools/check_explain_goldens.py --update"
        )
        return 1
    print(f"all {len(plans)} explain goldens match")
    return 0


if __name__ == "__main__":
    sys.exit(main())
