"""Render a cross-PR benchmark trend table from ``results/BENCH_*.json``.

Prints GitHub-flavoured markdown (CI appends it to the job summary): one
row per metric, one column per BENCH file, newest column last, with the
per-metric best value marked.  Metrics are the same bench-name-agnostic
dotted paths ``benchmarks/bench_regression.py`` compares against — plus
each report's headline wall section — so the table shows exactly what the
regression gate sees.

Usage: ``python tools/bench_trends.py [--results results/]``
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys


def _num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def flatten_walls(doc: dict) -> dict[str, float]:
    """Every numeric wall/latency/throughput metric in the report, as
    ``section.metric`` paths (``regression.algorithms`` rows become
    ``regression.<name>.wall_s`` — the comparable form)."""
    out: dict[str, float] = {}
    for section, body in doc.items():
        if section in ("bench", "scale", "workload", "claims"):
            continue
        if not isinstance(body, dict):
            continue
        for k, v in body.items():
            if k == "algorithms" and isinstance(v, list):
                for row in v:
                    if isinstance(row, dict) and "name" in row:
                        for mk, mv in row.items():
                            if mk != "name" and _num(mv):
                                out[f"{section}.{row['name']}.{mk}"] = mv
            elif isinstance(v, dict):
                for mk, mv in v.items():
                    if _num(mv):
                        out[f"{section}.{k}.{mk}"] = mv
            elif _num(v):
                out[f"{section}.{k}"] = v
    return out


def load_reports(results_dir: str) -> list[tuple[str, dict]]:
    reports = []
    for fname in os.listdir(results_dir):
        m = re.fullmatch(r"BENCH_(\d+)\.json", fname)
        if not m:
            continue
        try:
            with open(os.path.join(results_dir, fname)) as f:
                reports.append((int(m.group(1)), json.load(f)))
        except (OSError, ValueError) as e:
            print(f"<!-- skipped {fname}: {e} -->")
    return [(f"BENCH_{n}", doc) for n, doc in sorted(reports)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--results", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "results",
    ))
    args = ap.parse_args(argv)
    reports = load_reports(args.results)
    if not reports:
        print("no BENCH_*.json reports found")
        return 0

    cols = [name for name, _ in reports]
    tables = [flatten_walls(doc) for _, doc in reports]
    metrics = sorted({k for t in tables for k in t})

    print("### Benchmark trends\n")
    print("| metric | " + " | ".join(cols) + " |")
    print("|---|" + "---|" * len(cols))
    for mk in metrics:
        vals = [t.get(mk) for t in tables]
        present = [v for v in vals if v is not None]
        best = min(present) if present else None
        cells = []
        for v in vals:
            if v is None:
                cells.append("—")
            elif v == best and len(present) > 1:
                cells.append(f"**{v:.4g}**")
            else:
                cells.append(f"{v:.4g}")
        print(f"| `{mk}` | " + " | ".join(cells) + " |")

    print("\n### Claims\n")
    print("| report | claims |")
    print("|---|---|")
    for name, doc in reports:
        claims = doc.get("claims", {})
        rendered = ", ".join(
            f"{k}={'✅' if v else '❌'}" for k, v in sorted(claims.items())
        )
        print(f"| {name} | {rendered} |")
    return 0


if __name__ == "__main__":
    sys.exit(main())
