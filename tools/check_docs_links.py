#!/usr/bin/env python
"""Docs link check: every relative markdown link and file anchor resolves.

Scans README.md and docs/*.md for

* ``[text](relative/path.md)`` links — the target file must exist;
* `` `path/to/file.py:123` `` code anchors — the file must exist and have
  at least that many lines (so refactors that move code fail the build
  instead of silently rotting the docs).

Exit code 0 iff everything resolves. No third-party deps.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
ANCHOR_RE = re.compile(r"`((?:src|tests|benchmarks|examples|tools)/[\w./\-]+\.py)(?::(\d+))?`")


def check(md: pathlib.Path) -> list[str]:
    errors = []
    text = md.read_text()
    for m in LINK_RE.finditer(text):
        href = m.group(1).split("#")[0]
        if not href or href.startswith(("http://", "https://", "mailto:")):
            continue
        if not (md.parent / href).resolve().exists():
            errors.append(f"{md.relative_to(ROOT)}: broken link -> {href}")
    for m in ANCHOR_RE.finditer(text):
        path, line = m.group(1), m.group(2)
        target = ROOT / path
        if not target.exists():
            errors.append(f"{md.relative_to(ROOT)}: missing file anchor -> {path}")
            continue
        if line is not None:
            n_lines = len(target.read_text().splitlines())
            if int(line) > n_lines:
                errors.append(
                    f"{md.relative_to(ROOT)}: stale anchor {path}:{line} "
                    f"(file has {n_lines} lines)"
                )
    return errors


def main() -> int:
    docs = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
    errors: list[str] = []
    n = 0
    for md in docs:
        if md.exists():
            n += 1
            errors.extend(check(md))
    if errors:
        print("\n".join(errors))
        return 1
    print(f"docs link check: {n} files OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
