"""The paper's §3 applications end-to-end: PageRank, k-means, GMM, 100-NN.

Run:  PYTHONPATH=src python examples/data_mining.py
"""
import numpy as np

from repro.core import BlazeSession
from repro.core.algorithms import gmm_em, kmeans, knn, pagerank
from repro.data.synthetic import cluster_points, rmat_edges

# One session for the whole job: it owns the mesh and the compiled-executable
# cache, so every iterative algorithm below compiles each of its MapReduce
# configurations exactly once, no matter how many iterations run.
sess = BlazeSession()

# PageRank on an R-MAT (graph500-style) power-law graph -----------------------
edges = rmat_edges(scale=10, edges_per_node=16, seed=0)  # 1024 nodes, 16k links
res = pagerank(edges, 1 << 10, tol=1e-5, session=sess)
top = np.argsort(-res.scores)[:5]
print(f"PageRank: {res.iterations} iters, converged={res.converged}, "
      f"compiles={res.compiles}")
print("  top pages:", top.tolist(), "scores:", res.scores[top].round(5).tolist())
print(f"  shuffle bytes/iter (eager): {res.shuffle_bytes_per_iter}")

# k-means ---------------------------------------------------------------------
pts, true_centers = cluster_points(50_000, 3, 5, seed=0)
km = kmeans(pts, 5, max_iters=30, session=sess)
print(f"k-means: {km.iterations} iters, inertia={km.inertia:.1f}, "
      f"compiles={km.compiles}")
print("  centers:\n", km.centers.round(2))

# Expectation-Maximization (GMM) ----------------------------------------------
pts2, _ = cluster_points(5_000, 2, 3, seed=1)
gm = gmm_em(pts2, 3, max_iters=20, session=sess)
print(f"GMM: {gm.iterations} iters, loglik={gm.log_likelihood:.1f}, "
      f"alpha={gm.alpha.round(3).tolist()}, compiles={gm.compiles}")

# Fused iteration program: the whole PageRank iteration (3 MapReduce ops +
# the score-update glue) as ONE executable, 5 iterations per dispatch --------
pr2 = pagerank(edges, 1 << 10, tol=1e-5, session=sess, mode="program",
               unroll=5)
assert np.abs(pr2.scores - res.scores).max() < 1e-5
print(f"PageRank (fused program): {pr2.iterations} iters in "
      f"{pr2.dispatches} dispatches / {pr2.host_syncs} host syncs, "
      f"program_compiles={pr2.program_compiles} "
      f"(per-op loop above: {res.dispatches} dispatches, "
      f"{res.host_syncs} syncs)")

# 100 nearest neighbours --------------------------------------------------------
pts3, _ = cluster_points(200_000, 4, 3, seed=2)
nn = knn(pts3, np.zeros(4, np.float32), k=100, session=sess)
print(f"100-NN: farthest of the 100 at distance {nn.distances.max():.3f}; "
      f"{nn.wire_candidates} candidate rows crossed the wire "
      f"(vs {len(pts3)} for a full shuffle)")

print("session totals:", sess.cache_info())
