"""Multi-pass streaming aggregation as ONE fused program.

The word-count shape of the paper's resident hot loop: every round a batch of
lines is counted into a ``DistHashMap`` (unbounded keys — the hash path,
kernel-combined under ``engine="pallas"``), and a *second* pass reads the
updated table in place to maintain a count-of-counts histogram — all inside
one ``session.program`` executable.  The hash table is per-shard state
threaded through the device-resident loop (like int8 error-feedback
residuals), so N rounds cost 1 program compile, ``⌈N/unroll⌉`` dispatches and
zero per-round host syncs; the table never leaves the devices between rounds.

Run:  PYTHONPATH=src python examples/streaming_aggregation.py
"""
import collections

import jax.numpy as jnp
import numpy as np

from repro.core import BlazeSession, make_dist_hashmap
from repro.core.algorithms.wordcount import wordcount_mapper

VOCAB = 2000
ROUNDS, UNROLL = 10, 5

rng = np.random.RandomState(0)
lines = rng.zipf(1.5, size=(256, 16)).clip(max=VOCAB - 1).astype(np.int32)

sess = BlazeSession()
lines_v = sess.distribute(lines)
counts_hm = make_dist_hashmap(sess.mesh, 4 * VOCAB, (), jnp.int32, "sum")


def hist_mapper(word, count, emit):
    # histogram bucket = floor(log2(count)), capped — reads the hash table
    emit(jnp.minimum(jnp.log2(jnp.maximum(count, 1)).astype(jnp.int32), 15), 1)


def step(ctx, s):
    # pass 1: count this round's batch into the shared hash table
    counts = ctx.map_reduce(
        lines_v, wordcount_mapper, "sum", counts_hm,
        engine="pallas", key_range=VOCAB,
    )
    # pass 2: re-derive the count-of-counts histogram from the UPDATED table
    # (a LocalHashMap source — no collective, nothing leaves the executable)
    hist = ctx.map_reduce(
        counts, hist_mapper, "sum", jnp.zeros((16,), jnp.int32),
    )
    return {"hist": hist, "round": s["round"] + 1}


prog = sess.program(step)
state = {"hist": jnp.zeros((16,), jnp.int32), "round": jnp.zeros((), jnp.int32)}
state, info = sess.run_loop(prog, state, max_iters=ROUNDS, unroll=UNROLL)

counts = prog.hash_result(counts_hm)
ref = collections.Counter(lines.reshape(-1).tolist())
got = counts.to_dict()
assert {int(k): int(v) for k, v in got.items()} == {
    k: ROUNDS * v for k, v in ref.items()
}

print(f"rounds={info.iterations}  program_compiles={info.compiles}  "
      f"dispatches={info.dispatches}  host_syncs={info.host_syncs}")
print(f"distinct words={counts.size()}  overflow={counts.total_overflow()}")
print("count-of-counts (log2 buckets):",
      {i: int(v) for i, v in enumerate(np.asarray(state['hist'])) if v})
assert info.compiles == 1 and info.dispatches == ROUNDS // UNROLL
assert info.host_syncs == 0
print("OK — streaming aggregation fused: 1 compile, "
      f"{info.dispatches} dispatches for {ROUNDS} rounds")
