"""Serving example: batched prefill + greedy decode with KV/SSM caches,
across three different architecture families (attention, hybrid, RWKV).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.launch.serve_lm import generate
from repro.models import model as M

for arch in ("qwen3-0.6b", "zamba2-7b", "rwkv6-1.6b"):
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init(key, cfg)
    prompts = jax.random.randint(key, (4, 16), 0, cfg.vocab, dtype=jnp.int32)
    toks, dt = generate(cfg, params, prompts, max_len=64, gen=24)
    print(f"{arch:14s} generated {toks.shape} in {dt:.2f}s "
          f"({4*24/dt:.0f} tok/s) sample={toks[0,:8].tolist()}")
