"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps with the full production stack — deterministic sharded data
pipeline, AdamW, checkpoint/auto-resume, straggler monitor — and the Blaze
gradient path (eager microbatch accumulation).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(defaults are sized for a CPU container; ~100M params, real optimization)
"""
import argparse
import dataclasses
import tempfile

from repro.configs.base import get_arch
from repro.data.pipeline import TokenPipeline
from repro.optim.adamw import AdamW, warmup_cosine
from repro.runtime.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--grad-accum", type=int, default=2)
    args = ap.parse_args()

    # ~100M params: qwen3 geometry scaled to d=512, 8 layers, 32k vocab
    cfg = dataclasses.replace(
        get_arch("qwen3-0.6b"),
        name="qwen3-100m",
        d_model=512, n_heads=8, n_kv_heads=4, d_head=64, d_ff=1536,
        vocab=32_768, n_stages=8, n_layers=8,
        param_dtype="float32", compute_dtype="float32",
    )
    pipe = TokenPipeline(cfg, batch=args.batch, seq_len=args.seq, seed=0)
    opt = AdamW(lr=warmup_cosine(3e-4, args.steps // 10, args.steps))

    with tempfile.TemporaryDirectory() as ckpt_dir:
        res = train(
            cfg,
            steps=args.steps,
            batch=args.batch,
            seq_len=args.seq,
            pipeline=pipe,
            ckpt_dir=ckpt_dir,
            ckpt_every=max(args.steps // 5, 25),
            optimizer=opt,
            grad_accum=args.grad_accum,
        )
    print(f"steps: {res.final_step}  restarts: {res.restarts}")
    print(f"loss: {res.losses[0]:.3f} → {res.losses[-1]:.3f}")
    print(f"step-time: median {res.straggler['median_s']*1e3:.0f} ms, "
          f"p99 {res.straggler['p99_s']*1e3:.0f} ms, "
          f"stragglers flagged: {res.straggler['stragglers']}")
    assert res.losses[-1] < res.losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
