"""Quickstart: the Blaze MapReduce API in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BlazeSession,
    DistRange,
    data_mesh,
    distribute,
    make_dist_hashmap,
    map_reduce,
    topk,
)

# ---------------------------------------------------------------------------
# 1. Monte-Carlo π — the paper's Appendix A.2, small fixed key range
# ---------------------------------------------------------------------------
from repro.core.algorithms import estimate_pi

print("π ≈", estimate_pi(1_000_000))

# ---------------------------------------------------------------------------
# 2. Word count — the paper's Appendix A.1, DistHashMap target
# ---------------------------------------------------------------------------
lines = np.array(
    [[3, 1, 4, 1], [5, 9, 2, 6], [5, 3, 5, -1]], dtype=np.int32
)  # token ids, -1 = padding
lines_v = distribute(lines)


def wordcount_mapper(line_idx, tokens, emit):
    emit(tokens, 1, mask=tokens >= 0)  # batched emit, masked lanes


counts = make_dist_hashmap(data_mesh(), 64, (), jnp.int32, "sum")
counts = map_reduce(lines_v, wordcount_mapper, "sum", counts)
print("word counts:", dict(sorted(counts.to_dict().items())))

# ---------------------------------------------------------------------------
# 3. Custom mapper over a DistRange with a dense target
# ---------------------------------------------------------------------------


def squares_mapper(v, emit):
    emit(v % 4, v * v)  # key = v mod 4, value = v²


sums = map_reduce(DistRange(0, 100, 1), squares_mapper, "sum",
                  jnp.zeros((4,), jnp.int32))
print("Σ v² by v%4:", [int(x) for x in sums])

# ---------------------------------------------------------------------------
# 4. Distributed top-k with a custom score
# ---------------------------------------------------------------------------
pts = distribute(np.random.RandomState(0).randn(10_000, 3).astype(np.float32))
closest = topk(pts, 5, score_fn=lambda x: -jnp.sum(x * x))  # nearest to 0
print("5 points nearest the origin:\n", closest)

# ---------------------------------------------------------------------------
# 5. Iterative MapReduce with a BlazeSession — one compile, N dispatches
# ---------------------------------------------------------------------------
# Thread iteration-varying state through ``env`` (the mapper object stays
# static) and the session reuses one compiled executable for every iteration.
sess = BlazeSession()


def scaled_sum_mapper(v, emit, env):
    emit(0, v * env)  # env = this iteration's scale factor


scale = jnp.asarray(1.0)
for _ in range(10):
    total = sess.map_reduce(
        DistRange(0, 1000, 1), scaled_sum_mapper, "sum",
        jnp.zeros((1,), jnp.float32), env=scale,
    )
    scale = scale * 0.5
print("session after 10 iterations:", sess.cache_info())  # compiles=1
