"""BlazeServe example: three tenants querying all six paper algorithms
against one resident server over local HTTP.

The server compiles each distinct plan once; every later query — from any
tenant — rides the resident program cache, and compatible concurrent
queries coalesce into micro-batched dispatches.  The closing /stats
snapshot shows the ledger: compiles vs cache hits, batched dispatches,
p50/p99 latency.

Run:  BLAZE_PALLAS_INTERPRET=1 PYTHONPATH=src python examples/serve_queries.py
"""
import threading

from repro.launch.serve import build_server
from repro.serve import BlazeClient

server = build_server(scale="smoke", max_queue=128, per_tenant=32).start()
print(f"serving {sorted(server.queries)} at {server.url}\n")

QUERIES = [
    ("pi", {"n_samples": 4096, "iters": 2}),
    ("pagerank", {"iters": 10}),
    ("wordcount", {"iters": 1}),
    ("kmeans", {"k": 4, "iters": 5}),
    ("gmm", {"k": 2, "iters": 3}),
    ("knn", {"k": 5, "query": [0.0, 0.0, 0.0, 0.0]}),
]


def describe(query, result):
    if query == "pi":
        return f"pi~{result['pi']:.4f}"
    if query == "pagerank":
        return f"delta={result['delta']:.2e}"
    if query == "wordcount":
        return f"{len(result['keys'])} distinct words"
    if query == "kmeans":
        return f"inertia={result['inertia']:.1f}"
    if query == "gmm":
        return f"ll={result['log_likelihood']:.1f}"
    return f"nearest at d={result['distances'][0]:.3f}"


def tenant(name):
    client = BlazeClient(server.url, tenant=name)
    for query, params in QUERIES:
        result, meta = client.query(query, params)
        print(f"  {name:6s} {query:10s} {describe(query, result):24s} "
              f"cache={meta['cache']:8s} plan={meta['plan_hash']}")


threads = [
    threading.Thread(target=tenant, args=(n,))
    for n in ("alice", "bob", "carol")
]
for t in threads:
    t.start()
for t in threads:
    t.join()

snap = server.stats_snapshot()
print(
    f"\n{snap['completed']} queries, {snap['compiles']} compiles, "
    f"{snap['cache_hits']} cache hits, "
    f"{snap['batched_dispatches']} micro-batched dispatches "
    f"({snap['coalesced_queries']} coalesced); "
    f"p50={snap['p50_ms']:.1f}ms p99={snap['p99_ms']:.1f}ms "
    f"({snap['throughput_qps']:.1f} q/s)"
)
server.stop()
