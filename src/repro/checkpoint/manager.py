"""Fault-tolerant checkpointing: atomic commit, keep-N, async save, elastic
restore.

Checkpoints store LOGICAL arrays (host-gathered), not per-device blobs, plus a
manifest of tree structure and shapes.  Restore therefore works on any device
count / mesh shape — elastic scaling is a ``device_put`` with the new
sharding, not a resharding pass.  Multi-host note: on a real cluster each
process gathers only its addressable shards and process 0 owns the manifest;
the layout below is that protocol collapsed to one process.

Atomicity: write to ``step_N.tmp-<nonce>/`` then ``rename`` — a crash mid-save
never corrupts the latest checkpoint; ``restore_latest`` skips unfinished
directories.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import uuid
from typing import Any

import jax
import numpy as np

_SENTINEL = "MANIFEST.json"


def _flatten(tree) -> tuple[list[np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [np.asarray(jax.device_get(x)) for x in leaves], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: threading.Thread | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, *, blocking: bool = True) -> str:
        leaves, treedef = _flatten(tree)
        if blocking:
            return self._write(step, leaves, str(treedef))
        self.wait()
        self._pending = threading.Thread(
            target=self._write, args=(step, leaves, str(treedef)), daemon=True
        )
        self._pending.start()
        return self._path(step)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _write(self, step: int, leaves, treedef_str: str) -> str:
        final = self._path(step)
        tmp = f"{final}.tmp-{uuid.uuid4().hex[:8]}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(
            os.path.join(tmp, "arrays.npz"),
            **{f"leaf_{i}": x for i, x in enumerate(leaves)},
        )
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "treedef": treedef_str,
            "shapes": [list(x.shape) for x in leaves],
            "dtypes": [str(x.dtype) for x in leaves],
        }
        with open(os.path.join(tmp, _SENTINEL), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._path(s), ignore_errors=True)
        # drop orphaned tmp dirs from crashed saves
        for name in os.listdir(self.dir):
            if ".tmp-" in name:
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in sorted(os.listdir(self.dir)):
            full = os.path.join(self.dir, name)
            if (
                name.startswith("step_")
                and ".tmp-" not in name
                and os.path.exists(os.path.join(full, _SENTINEL))
            ):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like, shardings=None):
        """Restore into the structure of ``like`` (elastic: any mesh/devices).

        ``shardings``: optional matching pytree of NamedSharding — arrays go
        straight to their (possibly different-count) devices.
        """
        path = self._path(step)
        with open(os.path.join(path, _SENTINEL)) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves = [data[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
        like_leaves, treedef = jax.tree_util.tree_flatten(like)
        if len(like_leaves) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves, target has {len(like_leaves)}"
            )
        shard_leaves = (
            jax.tree_util.tree_flatten(
                shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
            )[0]
            if shardings is not None
            else [None] * len(leaves)
        )
        out = []
        for arr, likel, sh in zip(leaves, like_leaves, shard_leaves):
            arr = arr.astype(likel.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, like, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, like, shardings)
