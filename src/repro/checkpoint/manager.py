"""Fault-tolerant checkpointing: atomic commit, keep-N, async save, elastic
restore.

Checkpoints store LOGICAL arrays (host-gathered), not per-device blobs, plus a
manifest of tree structure and shapes.  Restore therefore works on any device
count / mesh shape — elastic scaling is a ``device_put`` with the new
sharding, not a resharding pass.  Multi-host note: on a real cluster each
process gathers only its addressable shards and process 0 owns the manifest;
the layout below is that protocol collapsed to one process.

Atomicity: write to ``step_N.tmp-<nonce>/``, then commit with a rename-aside
swap — ``rename(final, final.old-<nonce>)``; ``rename(tmp, final)``;
``rmtree(old)`` — so at every crash point some COMPLETE checkpoint for the
step exists on disk (the old one until the new one is in place).  The former
``rmtree(final); rename(tmp, final)`` sequence had a window where a crash
left neither.  ``_recover`` rolls an interrupted swap back (``.old-`` →
final) on startup/restore; ``restore_latest`` skips unfinished ``.tmp-`` /
``.old-`` directories and retries if a concurrent async-save ``_gc`` sweeps
the step it just picked.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import uuid
from typing import Any

import jax
import numpy as np

from repro.core import faults

_SENTINEL = "MANIFEST.json"


def _flatten(tree) -> tuple[list[np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [np.asarray(jax.device_get(x)) for x in leaves], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: threading.Thread | None = None
        # Serialises the commit swap, _gc, and _recover against each other
        # (async save runs _write on a background thread while the training
        # loop may call restore_latest).
        self._io_lock = threading.Lock()
        self._recover()

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, *, blocking: bool = True) -> str:
        # Fired on the caller's thread (not the async writer) so injected
        # write faults surface to whoever supervises the save.
        faults.fault_point("checkpoint.write")
        leaves, treedef = _flatten(tree)
        if blocking:
            return self._write(step, leaves, str(treedef))
        self.wait()
        self._pending = threading.Thread(
            target=self._write, args=(step, leaves, str(treedef)), daemon=True
        )
        self._pending.start()
        return self._path(step)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _write(self, step: int, leaves, treedef_str: str) -> str:
        final = self._path(step)
        tmp = f"{final}.tmp-{uuid.uuid4().hex[:8]}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(
            os.path.join(tmp, "arrays.npz"),
            **{f"leaf_{i}": x for i, x in enumerate(leaves)},
        )
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "treedef": treedef_str,
            "shapes": [list(x.shape) for x in leaves],
            "dtypes": [str(x.dtype) for x in leaves],
        }
        with open(os.path.join(tmp, _SENTINEL), "w") as f:
            json.dump(manifest, f)
        # Rename-aside swap: (1) move the previous checkpoint aside, (2) move
        # the new one in, (3) delete the old.  A crash after (1) leaves the
        # old checkpoint complete under ``.old-<nonce>`` (rolled back by
        # _recover); a crash after (2) leaves the new one committed.  There
        # is no instant at which neither exists.
        old = None
        with self._io_lock:
            if os.path.exists(final):
                old = f"{final}.old-{uuid.uuid4().hex[:8]}"
                os.rename(final, old)
            os.rename(tmp, final)
            if old is not None:
                shutil.rmtree(old, ignore_errors=True)
        self._gc()
        return final

    def _recover(self):
        """Roll back swaps interrupted between rename-aside and commit.

        A complete ``step_N.old-<nonce>`` whose ``step_N`` is missing is the
        previous checkpoint orphaned mid-swap: rename it back.  If the final
        exists, the swap committed and the ``.old-`` dir is garbage.
        """
        with self._io_lock:
            for name in os.listdir(self.dir):
                if ".old-" not in name:
                    continue
                full = os.path.join(self.dir, name)
                final = os.path.join(self.dir, name.split(".old-")[0])
                if os.path.exists(final):
                    shutil.rmtree(full, ignore_errors=True)
                elif os.path.exists(os.path.join(full, _SENTINEL)):
                    try:
                        os.rename(full, final)
                    except OSError:
                        pass
                else:
                    shutil.rmtree(full, ignore_errors=True)

    def _gc(self):
        self._recover()
        with self._io_lock:
            steps = self.all_steps()
            for s in steps[: -self.keep] if self.keep else []:
                shutil.rmtree(self._path(s), ignore_errors=True)
            # drop orphaned tmp dirs from crashed saves (.old- dirs are
            # handled by _recover above — deleting them here could destroy
            # the only complete copy of a step)
            for name in os.listdir(self.dir):
                if ".tmp-" in name:
                    shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in sorted(os.listdir(self.dir)):
            full = os.path.join(self.dir, name)
            if (
                name.startswith("step_")
                and ".tmp-" not in name
                and ".old-" not in name
                and os.path.exists(os.path.join(full, _SENTINEL))
            ):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like, shardings=None):
        """Restore into the structure of ``like`` (elastic: any mesh/devices).

        ``shardings``: optional matching pytree of NamedSharding — arrays go
        straight to their (possibly different-count) devices.
        """
        path = self._path(step)
        with open(os.path.join(path, _SENTINEL)) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves = [data[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
        like_leaves, treedef = jax.tree_util.tree_flatten(like)
        if len(like_leaves) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves, target has {len(like_leaves)}"
            )
        shard_leaves = (
            jax.tree_util.tree_flatten(
                shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
            )[0]
            if shardings is not None
            else [None] * len(leaves)
        )
        out = []
        for arr, likel, sh in zip(leaves, like_leaves, shard_leaves):
            arr = arr.astype(likel.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, like, shardings=None):
        self._recover()
        # Retry: a concurrent async-save _gc may sweep the step between our
        # listing and our read — the next listing sees the newer step.
        for _ in range(8):
            step = self.latest_step()
            if step is None:
                # An unlocked listing can also race _gc (listdir saw only the
                # step being swept, the manifest check then found it gone).
                # Under _io_lock no swap/sweep is mid-flight, so an empty
                # locked listing means genuinely no complete checkpoint.
                with self._io_lock:
                    step = self.latest_step()
                if step is None:
                    return None, None
            try:
                return step, self.restore(step, like, shardings)
            except (FileNotFoundError, NotADirectoryError):
                continue
        raise RuntimeError(
            f"restore_latest: checkpoints in {self.dir} kept disappearing "
            "mid-read (gc churn?)"
        )


# ---------------------------------------------------------------------------
# BlockStore: atomic byte-level block spill for out-of-core containers
# ---------------------------------------------------------------------------


class BlockStore:
    """Crash-safe named byte blobs — the spill target for cold blocks of
    ``repro.core.containers.ChunkedDistVector``.

    Reuses the checkpoint commit idiom: write ``<name>.tmp-<nonce>`` then
    atomically ``os.replace`` into place, so a crash mid-spill never leaves a
    torn block and readers only ever see complete blobs.
    """

    def __init__(self, directory: str):
        os.makedirs(directory, exist_ok=True)
        self.dir = directory
        self.bytes_written = 0

    def _path(self, name: str) -> str:
        return os.path.join(self.dir, f"{name}.blk")

    def put(self, name: str, data: bytes) -> int:
        final = self._path(name)
        tmp = f"{final}.tmp-{uuid.uuid4().hex[:8]}"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, final)
        self.bytes_written += len(data)
        return len(data)

    def get(self, name: str) -> bytes:
        with open(self._path(name), "rb") as f:
            return f.read()

    def has(self, name: str) -> bool:
        return os.path.exists(self._path(name))

    def delete(self, name: str) -> None:
        try:
            os.remove(self._path(name))
        except FileNotFoundError:
            pass
