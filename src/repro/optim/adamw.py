"""AdamW with configurable moment dtype (ZeRO-sharded via the param policy).

Moments stored in bf16 for the giant configs (grok-1's 314 B params would not
fit fp32 m/v on a single pod) — the optimizer-state version of the paper's
fast-serialization byte-narrowing, with the same error profile as 8-bit Adam
variants.  State is a plain dict pytree so the checkpoint manager and the
sharding policy treat it like any other tree.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[Array], Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    moment_dtype: str = "float32"

    def init(self, params) -> dict:
        mdt = jnp.dtype(self.moment_dtype)
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
            "step": jnp.zeros((), jnp.int32),
        }

    def _lr(self, step: Array) -> Array:
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)

    def update(self, grads, state, params):
        """Returns (new_params, new_state)."""
        step = state["step"] + 1
        lr = self._lr(step)
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        mdt = jnp.dtype(self.moment_dtype)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
            vf = v.astype(jnp.float32) * b2 + gf * gf * (1 - b2)
            mhat = mf / c1
            vhat = vf / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decay matrices only (norms/bias excluded)
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, mf.astype(mdt), vf.astype(mdt)

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v, "step": step}


def warmup_cosine(
    peak: float, warmup_steps: int, total_steps: int, floor: float = 0.1
) -> Callable[[Array], Array]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup_steps, 1)
        frac = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)

    return sched
