"""Compressed collectives — the fast-serialization analogue on the wire.

Used by the shard_map data-parallel training path and the MapReduce engine.
``compressed_psum`` narrows the payload (bf16, or int8 with a shared scale)
before the ring reduce; ``error_feedback`` keeps iterative algorithms unbiased
by re-injecting this round's quantisation error next round.

**Hierarchical (topology-aware) mode.**  On a 2-D ``("node", "data")`` mesh
intra-node links are an order of magnitude faster than inter-node links, so
a flat compressed reduce narrows exactly where narrowing is cheap and keeps
full precision where it is expensive.  Passing ``intra_axis=`` inverts that:
a full-precision ``psum`` runs over the fast intra-node axis first, then
only the node-level partials cross the slow ``axis`` hop compressed — fewer
quantisation addends (one per node instead of one per device) *and* fewer
bytes on the only links that are actually slow.  ``core/mapreduce.py``'s
``RealCollectives`` routes its hierarchical reduces through these entry
points.

XLA exposes no int8 all-reduce, so the int8 mode reduces in int32 over the
int8 lattice — numerically identical to an int8 wire; stats report the int8
byte count a native lowering would move (see DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

Axis = "str | tuple[str, ...]"  # collectives accept one name or a tuple


def compressed_psum(
    x: Array, axis, *, wire: str = "none", intra_axis=None
) -> Array:
    """Sum over ``axis`` with the payload narrowed per ``wire``.

    With ``intra_axis`` the reduce is hierarchical: full-precision ``psum``
    over ``intra_axis`` (fast links) first, then the compressed reduce over
    ``axis`` (slow links) on the node-level partials.
    """
    if intra_axis is not None:
        x = jax.lax.psum(x, intra_axis)
    if wire == "none":
        return jax.lax.psum(x, axis)
    if wire == "bf16":
        return jax.lax.psum(x.astype(jnp.bfloat16), axis).astype(x.dtype)
    if wire == "int8":
        absmax = jax.lax.pmax(jnp.max(jnp.abs(x.astype(jnp.float32))), axis)
        scale = jnp.maximum(absmax / 127.0, 1e-30)
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
        s = jax.lax.psum(q.astype(jnp.int32), axis)
        return (s.astype(jnp.float32) * scale).astype(x.dtype)
    raise ValueError(f"unknown wire {wire!r}")


def psum_with_feedback(
    x: Array, residual: Array, axis, *, wire: str, intra_axis=None
) -> tuple[Array, Array]:
    """(reduced, new_residual): error feedback around the lossy reduce.

    Hierarchical (``intra_axis``) mode folds the fast axis at full precision
    first, so the residual tracks exactly the loss of the one lossy hop; the
    residual is then replicated within a node (every member computes the
    same node-level error) and re-injected into the node partial next round.
    """
    if intra_axis is not None:
        x = jax.lax.psum(x, intra_axis)
    target = x.astype(jnp.float32) + residual
    reduced = compressed_psum(target, axis, wire=wire)
    # Exact per-addend feedback requires echoing each participant's own
    # quantised value; with a shared scale, quantisation is deterministic,
    # so we recompute it locally instead of echoing:
    if wire == "int8":
        absmax = jax.lax.pmax(jnp.max(jnp.abs(target)), axis)
        scale = jnp.maximum(absmax / 127.0, 1e-30)
        q = jnp.clip(jnp.round(target / scale), -127, 127)
        new_residual = target - q * scale
    elif wire == "bf16":
        new_residual = target - target.astype(jnp.bfloat16).astype(jnp.float32)
    else:
        new_residual = jnp.zeros_like(target)
    return reduced, new_residual


#: Narrowed wire widths; every other mode derives from the tensor dtype.
_WIRE_ITEMSIZE = {"bf16": 2, "int8": 1}

#: One f32 scale accompanies each int8 frame (shared-scale quantisation,
#: matching ``compressed_psum``/``serialization.quantize``'s per-block scale).
_INT8_SCALE_BYTES = 4


def wire_bytes(x, wire: str, *, n_scales: int = 1) -> int:
    """Payload bytes one ring pass moves for this tensor.

    ``wire="none"`` derives the element width from the dtype (an f64 or
    int16 tensor reports 8/2 bytes per element, not a hardcoded 4);
    ``wire="int8"`` accounts the full frame the quantised payload actually
    ships — the int8 lattice plus ``n_scales`` f32 scales (1 for the
    shared-scale collective; ``ceil(n / block)`` for the per-block
    serialization format).
    """
    if wire not in ("none",) and wire not in _WIRE_ITEMSIZE:
        raise ValueError(f"unknown wire {wire!r}")
    n = int(np.prod(np.shape(x), dtype=np.int64)) if np.ndim(x) else 1
    if wire == "none":
        per = np.dtype(getattr(x, "dtype", np.asarray(x).dtype)).itemsize
        return n * per
    payload = n * _WIRE_ITEMSIZE[wire]
    if wire == "int8":
        if n_scales < 1:
            raise ValueError(f"n_scales must be >= 1, got {n_scales}")
        payload += n_scales * _INT8_SCALE_BYTES
    return payload
