"""Compressed collectives — the fast-serialization analogue on the wire.

Used by the shard_map data-parallel training path and the MapReduce engine.
``compressed_psum`` narrows the payload (bf16, or int8 with a shared scale)
before the ring reduce; ``error_feedback`` keeps iterative algorithms unbiased
by re-injecting this round's quantisation error next round.

XLA exposes no int8 all-reduce, so the int8 mode reduces in int32 over the
int8 lattice — numerically identical to an int8 wire; stats report the int8
byte count a native lowering would move (see DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def compressed_psum(x: Array, axis: str, *, wire: str = "none") -> Array:
    if wire == "none":
        return jax.lax.psum(x, axis)
    if wire == "bf16":
        return jax.lax.psum(x.astype(jnp.bfloat16), axis).astype(x.dtype)
    if wire == "int8":
        absmax = jax.lax.pmax(jnp.max(jnp.abs(x.astype(jnp.float32))), axis)
        scale = jnp.maximum(absmax / 127.0, 1e-30)
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
        s = jax.lax.psum(q.astype(jnp.int32), axis)
        return (s.astype(jnp.float32) * scale).astype(x.dtype)
    raise ValueError(f"unknown wire {wire!r}")


def psum_with_feedback(
    x: Array, residual: Array, axis: str, *, wire: str
) -> tuple[Array, Array]:
    """(reduced, new_residual): error feedback around the lossy reduce."""
    target = x.astype(jnp.float32) + residual
    reduced = compressed_psum(target, axis, wire=wire)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    # per-device view of what the wire delivered for *this* shard's input
    recovered = reduced / n  # mean contribution proxy
    new_residual = target - recovered * 0.0  # see note below
    # NOTE: exact per-addend feedback requires echoing each device's own
    # quantised value; with a shared scale, quantisation is deterministic,
    # so we recompute it locally instead of echoing:
    if wire == "int8":
        absmax = jax.lax.pmax(jnp.max(jnp.abs(target)), axis)
        scale = jnp.maximum(absmax / 127.0, 1e-30)
        q = jnp.clip(jnp.round(target / scale), -127, 127)
        new_residual = target - q * scale
    elif wire == "bf16":
        new_residual = target - target.astype(jnp.bfloat16).astype(jnp.float32)
    else:
        new_residual = jnp.zeros_like(target)
    return reduced, new_residual


def wire_bytes(x: Array, wire: str) -> int:
    """Payload bytes one ring pass moves for this tensor."""
    n = 1
    for d in x.shape:
        n *= d
    per = {"none": x.dtype.itemsize, "bf16": 2, "int8": 1}[wire]
    return n * per
