"""Explicit data-parallel training with compressed gradient all-reduce.

The GSPMD path (launch/train, dryrun) reduces gradients implicitly; this
module is the *explicit* Blaze gradient path — shard_map over the data axis
with ``psum_with_feedback`` on every gradient leaf:

  map    = per-shard backward pass                (the mapper)
  reduce = compressed psum (bf16 / int8 + shared scale)   (fast serialization)
  key    = parameter index (dense, positional)    (small fixed key range)
  error feedback residuals keep SGD/Adam unbiased over steps.

Used by tests/benchmarks to show convergence parity between exact and
compressed wires, and to count the wire bytes saved.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro.distributed.collectives import psum_with_feedback, wire_bytes
from repro.optim.adamw import AdamW


def make_dp_train_step(
    loss_fn: Callable,  # loss_fn(params, inputs, labels) → scalar (per-shard mean)
    optimizer: AdamW,
    mesh: Mesh,
    *,
    wire: str = "none",
) -> Callable:
    """Returns step(params, opt_state, residuals, batch) → (..., loss).

    params/opt_state replicated; batch sharded on axis 0 over "data";
    residuals: pytree like params (f32) carrying quantisation error.
    """

    def shard_fn(params, opt_state, residuals, inputs, labels):
        loss, grads = jax.value_and_grad(loss_fn)(params, inputs, labels)
        n = jax.lax.psum(jnp.ones(()), "data")
        loss = jax.lax.psum(loss, "data") / n

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_r = jax.tree_util.tree_flatten(residuals)[0]
        red, new_r = [], []
        for g, r in zip(flat_g, flat_r):
            gr, rr = psum_with_feedback(
                g.astype(jnp.float32) / n, r, "data", wire=wire
            )
            red.append(gr.astype(g.dtype))
            new_r.append(rr)
        grads = jax.tree_util.tree_unflatten(treedef, red)
        residuals = jax.tree_util.tree_unflatten(treedef, new_r)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, residuals, loss

    rep = P()
    dp = P("data")
    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(rep, rep, rep, dp, dp),
        out_specs=(rep, rep, rep, rep),
        check_vma=False,
    )
    return jax.jit(lambda p, o, r, b: fn(p, o, r, b["inputs"], b["labels"]))


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def grad_wire_bytes(params, wire: str) -> int:
    """Bytes one gradient reduce moves per device under ``wire``."""
    return sum(wire_bytes(p, wire) for p in jax.tree.leaves(params))
