"""Sharding policy: parameter / optimizer / batch / cache PartitionSpecs.

Scheme (designed for 1000+ nodes; see DESIGN.md §3):

* parameters — FSDP-shard the "reduction" dim over the data(+pod) axes and
  TP-shard the "parallel" dim over model: wq/wk/wv/w_gate/w_up ``(fsdp, model)``,
  wo/w_down ``(model, fsdp)``, embed ``(model, fsdp)`` (vocab over model),
  MoE experts ``(None, fsdp, model)`` (E small; d/d_ff carry the sharding);
* optimizer state mirrors parameters;
* batch — tokens over the dp axes;
* caches — batch over dp when divisible, else sequence over dp; KV heads over
  model when divisible, else sequence takes model too (context-parallel
  layout for the B=1 half-million-token cell).

Every axis application is guarded by ``_fit``: a dim only takes a mesh axis
whose size divides it — so the same policy serves full configs, reduced smoke
configs, and both mesh shapes without special-casing.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import model as M


@dataclasses.dataclass(frozen=True)
class MeshInfo:
    mesh: Mesh
    fsdp: tuple[str, ...]  # ("data",) or ("pod", "data")
    model: str = "model"

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model]

    @property
    def dp_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.fsdp]))

    def axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            return self.mesh.shape[axes]
        return int(np.prod([self.mesh.shape[a] for a in axes]))


def make_mesh_info(mesh: Mesh) -> MeshInfo:
    fsdp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return MeshInfo(mesh=mesh, fsdp=fsdp)


def _fit(spec_axes: tuple, shape: tuple, mi: MeshInfo) -> P:
    """Drop axes that don't divide their dim (or don't exist in the mesh)."""
    out = []
    for dim, ax in zip(shape, spec_axes):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        axes = tuple(a for a in axes if a in mi.mesh.axis_names)
        size = mi.axis_size(axes) if axes else 1
        if size > 1 and dim % size == 0:
            out.append(axes[0] if len(axes) == 1 else axes)
        else:
            out.append(None)
    return P(*out)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

_COL = "col"  # (fsdp, model): d_in → fsdp, d_out → model
_ROW = "row"  # (model, fsdp)
_REP = "rep"

_PARAM_RULES: dict[tuple[str, str], str] = {
    # (parent, key) → layout; "*" matches any parent
    ("*", "embed"): "embed",
    ("*", "lm_head"): _COL,
    ("attn", "wq"): _COL,
    ("attn", "wk"): _COL,
    ("attn", "wv"): _COL,
    ("attn", "wo"): _ROW,
    ("mlp", "w_gate"): _COL,
    ("mlp", "w_up"): _COL,
    ("mlp", "w_down"): _ROW,
    ("moe", "router"): "router",
    ("moe", "w_gate"): "expert_col",
    ("moe", "w_up"): "expert_col",
    ("moe", "w_down"): "expert_row",
    ("mamba", "in_proj"): _COL,
    ("mamba", "out_proj"): _ROW,
    ("mamba", "conv_w"): "conv",
    ("mamba", "conv_b"): "vec_model",
    ("tm", "wr"): _COL,
    ("tm", "wk"): _COL,
    ("tm", "wv"): _COL,
    ("tm", "wg"): _COL,
    ("tm", "wo"): _ROW,
    ("tm", "mix_w1"): "col_rep",
    ("cm", "wk"): _COL,
    ("cm", "wv"): _ROW,
    ("cm", "wr"): _COL,
}


def _path_keys(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
    return out


def param_pspecs(cfg: ArchConfig, param_shapes, mi: MeshInfo, *, serving: bool = False):
    """PartitionSpec pytree matching ``model.init``'s parameter tree.

    ``serving=True`` drops the FSDP dim (params replicated over data, TP over
    model): decode steps then never all-gather weights — inference holds
    params resident, the ZeRO sharding is a training-side trick.
    """
    FS, MD = (None, mi.model) if serving else (mi.fsdp, mi.model)

    def one(path, leaf):
        keys = _path_keys(path)
        key = keys[-1] if keys else ""
        parent = keys[-2] if len(keys) > 1 else ""
        stacked = keys and keys[0] == "stages"
        shape = leaf.shape
        core = shape[1:] if stacked else shape

        rule = _PARAM_RULES.get((parent, key)) or _PARAM_RULES.get(("*", key))
        if rule == "embed":
            axes = (MD, FS)
        elif rule == _COL:
            axes = (FS, MD)
        elif rule == _ROW:
            axes = (MD, FS)
        elif rule == "router":
            axes = (FS, None)
        elif rule == "expert_col":
            axes = (None, FS, MD)
        elif rule == "expert_row":
            axes = (None, MD, FS)
        elif rule == "conv":
            axes = (None, MD)
        elif rule == "vec_model":
            axes = (MD,)
        elif rule == "col_rep":
            axes = (FS, None)
        else:
            axes = (None,) * len(core)
        axes = tuple(axes[: len(core)]) + (None,) * (len(core) - len(axes))
        spec = _fit(axes, core, mi)
        if stacked:
            spec = P(*((None,) + tuple(spec)))
        return spec

    return jax.tree_util.tree_map_with_path(one, param_shapes)


def opt_pspecs(param_specs, opt_state_shapes):
    """Optimizer moments mirror parameter sharding; scalars replicated."""

    def one(path, leaf):
        keys = _path_keys(path)
        if keys and keys[0] in ("m", "v", "residual"):
            sub = keys[1:]
            node = param_specs
            try:
                for k in sub:
                    if isinstance(node, (list, tuple)):
                        node = node[int(k)]
                    elif isinstance(node, dict):
                        node = node[k]
                    else:
                        node = getattr(node, k)
                if isinstance(node, P):
                    return node
            except (KeyError, IndexError, AttributeError, ValueError):
                pass
        return P()

    return jax.tree_util.tree_map_with_path(one, opt_state_shapes)


# ---------------------------------------------------------------------------
# Batch / cache
# ---------------------------------------------------------------------------


def batch_pspecs(cfg: ArchConfig, batch_shapes, mi: MeshInfo):
    def one(leaf):
        return _fit((mi.fsdp,) + (None,) * (len(leaf.shape) - 1), leaf.shape, mi)

    return jax.tree.map(one, batch_shapes)


def cache_pspecs(
    cfg: ArchConfig, batch: int, max_len: int, mi: MeshInfo, kind: str = "decode"
):
    """Spec pytree parallel to ``model.make_caches(..., spec=True)``.

    KV layout policy (when kv-heads don't divide the model axis):
    * decode — shard **d_head**: the per-token cache update and the PV matmul
      stay device-local; only per-chunk logit partial-sums (q_len=1 → tiny)
      cross the wire.  (Sequence-sharded caches all-gather the entire cache
      every token: 170 GiB/step for gemma2 — the measured baseline.)
    * prefill — shard sequence: with q_len=S the dh-sharded layout would psum
      a [B,H,bq,bk] tile per block pair, which is far worse than one
      seq-gather; prefill→decode hand-off does one cache reshard (recorded in
      EXPERIMENTS.md §Perf).
    """
    FS, MD = mi.fsdp, mi.model
    b_ok = batch % mi.dp_size == 0
    heads_ok = cfg.n_kv_heads % mi.model_size == 0
    dh_ok = cfg.d_head % mi.model_size == 0

    b_ax = FS if b_ok else None
    use_dh = (not heads_ok) and dh_ok and kind == "decode"
    # sequence picks up whatever batch/heads leave unused
    s_axes = []
    if not b_ok:
        s_axes.extend(FS)
    if not heads_ok and not use_dh:
        s_axes.append(MD)
    s_ax = tuple(s_axes) if s_axes else None
    h_ax = MD if heads_ok else None
    dh_ax = MD if use_dh else None

    def kv_spec(kind_):
        shape = (batch, max_len, cfg.n_kv_heads, cfg.d_head)
        return M.A.KVCache(
            k=_fit((b_ax, s_ax, h_ax, dh_ax), shape, mi),
            v=_fit((b_ax, s_ax, h_ax, dh_ax), shape, mi),
        )

    def mamba_spec():
        d_inner, h, conv_dim = M.SSM._dims(cfg)
        return M.SSM.MambaCache(
            conv=_fit((b_ax, None, MD), (batch, cfg.conv_width - 1, conv_dim), mi),
            h=_fit((b_ax, MD, None, None), (batch, h, cfg.ssm_head_dim, cfg.ssm_state), mi),
        )

    def rwkv_spec():
        d = cfg.d_model
        h = d // cfg.rwkv_head_dim
        return M.RW.RWKVCache(
            shift_tm=_fit((b_ax, MD), (batch, d), mi),
            shift_cm=_fit((b_ax, MD), (batch, d), mi),
            state=_fit((b_ax, MD, None, None), (batch, h, cfg.rwkv_head_dim, cfg.rwkv_head_dim), mi),
        )

    def block_spec(kind):
        if kind in M._ATTN_KINDS:
            return kv_spec(kind)
        if kind == M.MAMBA2:
            return mamba_spec()
        return rwkv_spec()

    def prepend_none(spec_tree):
        return jax.tree.map(
            lambda s: P(*((None,) + tuple(s))), spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    return {
        "stages": [prepend_none(block_spec(k)) for k in cfg.stage_pattern],
        "tail": [block_spec(k) for k in cfg.tail_pattern],
    }


def constrain(x, *axes):
    """Sharding-constrain ``x`` if a mesh is active and every axis divides.

    ``axes`` — one entry per dim: None, an axis name, or a tuple of names.
    Outside a mesh context (unit tests, CPU runs) this is a no-op, so model
    code can annotate unconditionally.
    """
    from repro.compat import get_abstract_mesh

    am = get_abstract_mesh()
    if am is None or not am.axis_names:
        return x
    fitted = []
    for dim, ax in zip(x.shape, axes):
        if ax is None:
            fitted.append(None)
            continue
        names = (ax,) if isinstance(ax, str) else tuple(ax)
        names = tuple(a for a in names if a in am.axis_names)
        size = int(np.prod([am.shape[a] for a in names])) if names else 1
        if size > 1 and dim % size == 0:
            fitted.append(names[0] if len(names) == 1 else names)
        else:
            fitted.append(None)
    if all(f is None for f in fitted):
        return x
    return jax.lax.with_sharding_constraint(x, P(*fitted))


def named(tree, mi: MeshInfo):
    """P pytree → NamedSharding pytree."""
    return jax.tree.map(
        lambda s: NamedSharding(mi.mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )
