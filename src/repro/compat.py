"""JAX version-compatibility shims.

The codebase is written against the modern JAX surface (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.set_mesh``); this module makes
those spellings work on every JAX back to 0.4.x so the package imports and
runs on whatever the container ships.  All internal code imports these names
from here, never from ``jax`` directly:

* ``shard_map``   — ``jax.shard_map`` when present, else
                    ``jax.experimental.shard_map.shard_map``; the replication
                    check flag (``check_rep`` pre-0.5, ``check_vma`` after) is
                    normalised so callers may pass either.
* ``make_mesh``   — drops ``axis_types`` when the installed ``jax.make_mesh``
                    predates it (Auto is the old default behaviour anyway).
* ``set_mesh``    — ``jax.set_mesh`` when present, else the 0.4.x ambient
                    mesh context (``Mesh`` is itself a context manager).
* ``AxisType``    — ``jax.sharding.AxisType`` or a placeholder enum.
"""
from __future__ import annotations

import enum
import inspect
from typing import Any, Callable, Sequence

import jax

__all__ = [
    "AxisType",
    "distributed_initialize",
    "get_abstract_mesh",
    "make_mesh",
    "process_count",
    "process_index",
    "set_mesh",
    "shard_map",
]


# -- multi-process bring-up --------------------------------------------------


def distributed_initialize(
    coordinator_address: str | None = None,
    num_processes: int | None = None,
    process_id: int | None = None,
    **kwargs: Any,
) -> bool:
    """``jax.distributed.initialize`` gated for single-process and old JAX.

    Returns True iff a multi-process runtime actually came up.  A
    single-process launch (no coordinator, ``num_processes`` absent or 1) is
    a silent no-op — the same code path then runs on the local mesh, which
    is what lets the simulated-topology harness and a real cluster share one
    entry point (``repro.launch.mesh.init_distributed``).
    """
    single = coordinator_address is None and num_processes in (None, 1)
    dist = getattr(jax, "distributed", None)
    if single or dist is None or not hasattr(dist, "initialize"):
        return False
    dist.initialize(
        coordinator_address=coordinator_address,
        num_processes=num_processes,
        process_id=process_id,
        **kwargs,
    )
    return True


def process_count() -> int:
    return jax.process_count() if hasattr(jax, "process_count") else 1


def process_index() -> int:
    return jax.process_index() if hasattr(jax, "process_index") else 0


# -- shard_map ---------------------------------------------------------------

if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
else:  # JAX <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map_impl).parameters)


def shard_map(
    f: Callable,
    *,
    mesh,
    in_specs,
    out_specs,
    check_vma: bool | None = None,
    check_rep: bool | None = None,
    **kwargs: Any,
):
    """``jax.shard_map`` on every supported JAX version.

    Accepts both spellings of the replication-check flag and forwards
    whichever one the installed JAX understands.
    """
    check = check_vma if check_vma is not None else check_rep
    if check is not None:
        for name in ("check_vma", "check_rep"):
            if name in _SHARD_MAP_PARAMS:
                kwargs[name] = check
                break
    return _shard_map_impl(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )


# -- AxisType ----------------------------------------------------------------

if hasattr(jax.sharding, "AxisType"):
    AxisType = jax.sharding.AxisType
else:

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        """Placeholder for ``jax.sharding.AxisType`` on old JAX, where every
        mesh axis implicitly behaves like ``Auto``."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


# -- make_mesh ---------------------------------------------------------------

_MAKE_MESH_PARAMS = (
    frozenset(inspect.signature(jax.make_mesh).parameters)
    if hasattr(jax, "make_mesh")
    else frozenset()
)


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    axis_types=None,
    devices=None,
):
    if hasattr(jax, "make_mesh"):
        kwargs: dict[str, Any] = {}
        if devices is not None:
            kwargs["devices"] = devices
        if axis_types is not None and "axis_types" in _MAKE_MESH_PARAMS:
            kwargs["axis_types"] = axis_types
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
    import numpy as np

    devs = list(devices) if devices is not None else jax.devices()
    n = int(np.prod(axis_shapes))
    return jax.sharding.Mesh(
        np.asarray(devs[:n]).reshape(tuple(axis_shapes)), tuple(axis_names)
    )


# -- get_abstract_mesh -------------------------------------------------------

if hasattr(jax.sharding, "get_abstract_mesh"):
    get_abstract_mesh = jax.sharding.get_abstract_mesh
else:

    def get_abstract_mesh():  # type: ignore[misc]
        """Old-JAX fallback: the ambient physical mesh installed by
        ``with mesh:`` exposes the same ``.axis_names`` / ``.shape`` surface
        (empty mesh ⇒ ``axis_names == ()``, matching "no mesh active")."""
        from jax._src import mesh as mesh_lib

        return mesh_lib.thread_resources.env.physical_mesh


# -- set_mesh ----------------------------------------------------------------

if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
else:

    def set_mesh(mesh):  # type: ignore[misc]
        """Old-JAX fallback: ``Mesh`` is a context manager that installs the
        ambient mesh, which is what ``jax.set_mesh`` does on new JAX."""
        return mesh
