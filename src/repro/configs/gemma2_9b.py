"""gemma2-9b — local+global alternating attention, logit softcaps
[arXiv:2408.00118].

42L, d_model=3584, 16 heads (GQA kv=8, d_head=256), d_ff=14336, vocab=256000.
Stage = (local SWA-4096 layer, global layer) × 21.  Global layers are full
attention ⇒ long_500k skipped.
"""
from repro.configs.base import ATTN, ATTN_LOCAL, ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="gemma2-9b",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        d_head=256,
        d_ff=14336,
        vocab=256000,
        stage_pattern=(ATTN_LOCAL, ATTN),
        n_stages=21,
        window=4096,
        attn_softcap=50.0,
        final_softcap=30.0,
        tie_embeddings=True,
        supports_long_context=False,
    )
)
