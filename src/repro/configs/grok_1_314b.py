"""grok-1-314b — MoE, 8 experts top-2 [hf:xai-org/grok-1].

64L, d_model=6144, 48 heads (GQA kv=8, d_head=128), expert d_ff=32768,
vocab=131072.  Full attention ⇒ long_500k skipped (see DESIGN.md).
"""
from repro.configs.base import ATTN_MOE, ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="grok-1-314b",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_head=128,
        d_ff=32768,
        vocab=131072,
        stage_pattern=(ATTN_MOE,),
        n_stages=64,
        n_experts=8,
        top_k=2,
        supports_long_context=False,
    )
)
