"""stablelm-3b — dense MHA [hf:stabilityai/stablelm-2-1_6b family].

32L, d_model=2560, 32 heads (kv=32, d_head=80), d_ff=6912, vocab=50304.
"""
from repro.configs.base import ATTN, ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="stablelm-3b",
        n_layers=32,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_head=80,
        d_ff=6912,
        vocab=50304,
        stage_pattern=(ATTN,),
        n_stages=32,
        supports_long_context=False,
    )
)
