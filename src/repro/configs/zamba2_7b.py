"""zamba2-7b — hybrid Mamba-2 + shared attention [arXiv:2411.15242].

81 layer slots, d_model=3584, 32 heads (MHA), d_ff=14336, vocab=32000,
ssm_state=64.  Every 7th slot applies the SHARED attention block (one set of
parameters reused across all its applications — Zamba's signature trick);
the rest are Mamba-2 blocks.  Sub-quadratic (SSM) ⇒ runs long_500k.
"""
from repro.configs.base import ATTN, MAMBA2, SHARED_ATTN, ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="zamba2-7b",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        d_head=112,
        d_ff=14336,
        vocab=32000,
        stage_pattern=(MAMBA2,) * 6 + (SHARED_ATTN,),
        n_stages=11,  # 77 slots
        tail_pattern=(MAMBA2,) * 4,  # 81 total
        ssm_state=64,
        ssm_head_dim=64,
        ssm_groups=2,
        ssm_expand=2,
        supports_long_context=True,
        notes="shared attention params across all SHARED_ATTN applications",
    )
)
