"""Architecture + shape configuration for the assigned model pool.

Every architecture is described by an ``ArchConfig``; the repeating layer
pattern is a list of block kinds (one *stage* = one scan step), so scan over
stages keeps the HLO small for 28–81-layer models.  Shapes are the four
assigned input regimes.  ``reduced()`` derives the CPU smoke-test config.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp

# Block kinds (per layer slot within a stage)
ATTN = "attn"  # global self-attention + dense MLP
ATTN_LOCAL = "attn_local"  # sliding-window self-attention + dense MLP
ATTN_MOE = "attn_moe"  # global self-attention + MoE MLP
ATTN_LOCAL_MOE = "attn_local_moe"  # SWA + MoE MLP (mixtral)
MAMBA2 = "mamba2"  # Mamba-2 SSD block
RWKV6 = "rwkv6"  # RWKV-6 time-mix + channel-mix
SHARED_ATTN = "shared_attn"  # zamba2: shared-parameter attention block


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    n_layers: int  # total layer slots (stages × len(stage_pattern) + tail)
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    stage_pattern: tuple[str, ...]  # block kinds repeated by the scan
    n_stages: int  # scan length
    tail_pattern: tuple[str, ...] = ()  # leftover layers after the scan
    # attention options
    window: int | None = None  # sliding-window size for *_local blocks
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, ...] | None = None  # qwen2-vl M-RoPE
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # Mamba-2
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_expand: int = 2
    conv_width: int = 4
    # RWKV-6
    rwkv_head_dim: int = 64
    # embeddings / head
    tie_embeddings: bool = False
    embed_inputs: bool = True  # False: frontend STUB feeds [B, S, d] embeds
    # dtypes
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # long-context eligibility (sub-quadratic decode memory/compute)
    supports_long_context: bool = False
    notes: str = ""

    @property
    def layers_total(self) -> int:
        return self.n_stages * len(self.stage_pattern) + len(self.tail_pattern)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def has_kind(self, *kinds: str) -> bool:
        return any(k in self.stage_pattern + self.tail_pattern for k in kinds)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        scale = dict(
            d_model=min(self.d_model, 64),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_head=16,
            d_ff=min(self.d_ff, 128),
            vocab=min(self.vocab, 512),
            n_stages=min(self.n_stages, 2),
            window=min(self.window, 16) if self.window else None,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            rwkv_head_dim=16,
            param_dtype="float32",
            compute_dtype="float32",
            # no token dropping at smoke-test scale → prefill/decode and
            # full-forward paths agree exactly
            capacity_factor=8.0 if self.n_experts else self.capacity_factor,
        )
        if scale["n_kv_heads"] > scale["n_heads"]:
            scale["n_kv_heads"] = scale["n_heads"]
        if self.mrope_sections is not None:
            scale["mrope_sections"] = (2, 3, 3)  # sums to d_head/2 = 8
        return dataclasses.replace(
            self, name=self.name + "-reduced",
            n_layers=scale["n_stages"] * len(self.stage_pattern) + len(self.tail_pattern),
            **scale,
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

SHAPES: dict[str, ShapeSpec] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}

_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    from repro.configs import (  # noqa: F401
        gemma2_9b,
        grok_1_314b,
        mixtral_8x22b,
        musicgen_medium,
        qwen2_vl_2b,
        qwen3_0_6b,
        rwkv6_1_6b,
        stablelm_3b,
        starcoder2_15b,
        zamba2_7b,
    )


def cells(arch: ArchConfig) -> list[ShapeSpec]:
    """The shape cells this arch runs (long_500k only if sub-quadratic)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if arch.supports_long_context:
        out.append(LONG_500K)
    return out
