"""mixtral-8x22b — MoE 8 experts top-2, sliding-window attention
[arXiv:2401.04088].

56L, d_model=6144, 48 heads (GQA kv=8, d_head=128), expert d_ff=16384,
vocab=32768, window=4096.  SWA ⇒ decode KV is O(window): runs long_500k.
"""
from repro.configs.base import ATTN_LOCAL_MOE, ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="mixtral-8x22b",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_head=128,
        d_ff=16384,
        vocab=32768,
        stage_pattern=(ATTN_LOCAL_MOE,),
        n_stages=56,
        window=4096,
        n_experts=8,
        top_k=2,
        supports_long_context=True,
        notes="SWA bounds the decode KV cache to the window",
    )
)
