"""starcoder2-15b — dense, GQA kv=4, RoPE [arXiv:2402.19173].

40L, d_model=6144, 48 heads (d_head=128), d_ff=24576, vocab=49152.
"""
from repro.configs.base import ATTN, ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="starcoder2-15b",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        d_head=128,
        d_ff=24576,
        vocab=49152,
        stage_pattern=(ATTN,),
        n_stages=40,
        rope_theta=100_000.0,
        supports_long_context=False,
    )
)
