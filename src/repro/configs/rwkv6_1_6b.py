"""rwkv6-1.6b (Finch) — attention-free, data-dependent decay [arXiv:2404.05892].

24L, d_model=2048, d_ff=7168, vocab=65536.  32 wkv heads of dim 64.
Attention-free recurrence ⇒ O(1) decode state: runs long_500k.
"""
from repro.configs.base import RWKV6, ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="rwkv6-1.6b",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_head=64,
        d_ff=7168,
        vocab=65536,
        stage_pattern=(RWKV6,),
        n_stages=24,
        rwkv_head_dim=64,
        supports_long_context=True,
    )
)
