"""qwen3-0.6b — dense GQA with qk-norm [hf:Qwen/Qwen3-0.6B].

28L, d_model=1024, 16 heads (GQA kv=8, d_head=128), d_ff=3072, vocab=151936.
"""
from repro.configs.base import ATTN, ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="qwen3-0.6b",
        n_layers=28,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_head=128,
        d_ff=3072,
        vocab=151936,
        stage_pattern=(ATTN,),
        n_stages=28,
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        supports_long_context=False,
    )
)
