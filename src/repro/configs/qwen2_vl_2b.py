"""qwen2-vl-2b — VLM backbone with M-RoPE [arXiv:2409.12191].

Backbone only: 28L, d_model=1536, 12 heads (GQA kv=2, d_head=128), d_ff=8960,
vocab=151936.  The vision frontend (dynamic-resolution patcher) is a STUB —
``input_specs`` provides precomputed patch embeddings + (t, h, w) position
triples for M-RoPE.
"""
from repro.configs.base import ATTN, ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="qwen2-vl-2b",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_head=128,
        d_ff=8960,
        vocab=151936,
        stage_pattern=(ATTN,),
        n_stages=28,
        mrope_sections=(16, 24, 24),  # sums to d_head/2 = 64
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        embed_inputs=False,  # patch-embedding stub frontend
        supports_long_context=False,
    )
)
