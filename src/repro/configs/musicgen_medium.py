"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284].

Backbone only: 48L, d_model=1536, 24 heads (MHA, d_head=64), d_ff=6144,
vocab=2048.  The EnCodec/codebook frontend is a STUB — ``input_specs``
provides precomputed frame embeddings ([B, S, d]), per the harness contract.
"""
from repro.configs.base import ATTN, ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="musicgen-medium",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        d_head=64,
        d_ff=6144,
        vocab=2048,
        stage_pattern=(ATTN,),
        n_stages=48,
        embed_inputs=False,  # frame-embedding stub frontend
        supports_long_context=False,
    )
)
