"""Synthetic datasets matching the paper's benchmark inputs.

The paper uses: the Bible + Shakespeare repeated 200× (~0.4 B words) for word
count, a graph500 (R-MAT) generator for PageRank (10 M links), random points
around 5 cluster centres for k-means (100 M) and GMM (1 M), and 200 M random
points for 100-NN.  This container has no corpus files and far less RAM, so we
generate statistically-matched stand-ins at configurable scale:

* ``zipf_corpus``  — Zipf-distributed word-id lines (word frequencies in real
                     English text are Zipfian, which is exactly what stresses
                     the eager-reduction path: few hot keys, long tail).
* ``rmat_edges``   — R-MAT/Kronecker power-law digraph (the graph500 core).
* ``cluster_points`` — Gaussian blobs around K centres.
"""
from __future__ import annotations

import numpy as np


def zipf_corpus(
    n_lines: int,
    words_per_line: int,
    vocab_size: int,
    *,
    zipf_a: float = 1.3,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Return (lines [n_lines, words_per_line] int32, true_counts [vocab])."""
    rng = np.random.RandomState(seed)
    ranks = rng.zipf(zipf_a, size=(n_lines, words_per_line))
    ids = np.minimum(ranks - 1, vocab_size - 1).astype(np.int32)
    # Per-line ragged lengths: pad tail with -1 (masked by the mapper).
    lens = rng.randint(max(1, words_per_line // 2), words_per_line + 1, n_lines)
    mask = np.arange(words_per_line)[None, :] < lens[:, None]
    ids = np.where(mask, ids, -1).astype(np.int32)
    counts = np.bincount(ids[ids >= 0], minlength=vocab_size)
    return ids, counts


def rmat_edges(
    scale: int,
    edges_per_node: int = 16,
    *,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
) -> np.ndarray:
    """R-MAT digraph (graph500 defaults): returns edges [E, 2] int32, N=2**scale."""
    rng = np.random.RandomState(seed)
    n_edges = (1 << scale) * edges_per_node
    src = np.zeros(n_edges, np.int64)
    dst = np.zeros(n_edges, np.int64)
    for bit in range(scale):
        r = rng.rand(n_edges)
        # quadrant probabilities (a, b, c, d) with slight noise per level
        src_bit = r >= a + b
        dst_bit = ((r >= a) & (r < a + b)) | (r >= a + b + c)
        src |= src_bit.astype(np.int64) << bit
        dst |= dst_bit.astype(np.int64) << bit
    return np.stack([src, dst], axis=1).astype(np.int32)


def cluster_points(
    n_points: int,
    dim: int,
    k: int,
    *,
    spread: float = 0.35,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Gaussian blobs around ``k`` centres → (points [n, dim], centres [k, dim])."""
    rng = np.random.RandomState(seed)
    centers = rng.randn(k, dim).astype(np.float32) * 2.0
    assign = rng.randint(0, k, n_points)
    pts = centers[assign] + rng.randn(n_points, dim).astype(np.float32) * spread
    return pts.astype(np.float32), centers
