"""Text loading + tokenization — the paper's ``load_file`` utility.

``load_file(path)`` reads a text file into fixed-width rows of int32 word ids
(padding = −1) ready for ``distribute`` + the word-count mapper, plus the
id→word vocabulary for decoding results — the TPU-static analogue of the
paper's "distributed vector of lines".  Words are interned on the host
(first-seen order), so ids are dense and the DistHashMap stays small.
"""
from __future__ import annotations

import numpy as np


def tokenize_lines(
    lines: list[str], *, max_words_per_line: int | None = None
) -> tuple[np.ndarray, dict[int, str]]:
    vocab: dict[str, int] = {}
    toks: list[list[int]] = []
    for line in lines:
        row = []
        for w in line.split():
            w = w.strip().lower()
            if not w:
                continue
            if w not in vocab:
                vocab[w] = len(vocab)
            row.append(vocab[w])
        toks.append(row)
    width = max_words_per_line or max((len(r) for r in toks), default=1)
    out = np.full((len(toks), max(width, 1)), -1, np.int32)
    for i, r in enumerate(toks):
        out[i, : min(len(r), width)] = r[:width]
    return out, {i: w for w, i in vocab.items()}


def load_file(
    path: str, *, max_words_per_line: int | None = None
) -> tuple[np.ndarray, dict[int, str]]:
    """Paper's ``blaze::util::load_file``: text file → (token rows, vocab)."""
    with open(path, "r", errors="replace") as f:
        lines = [ln for ln in f.read().splitlines() if ln.strip()]
    return tokenize_lines(lines, max_words_per_line=max_words_per_line)
