"""Data pipeline: deterministic sharded token stream with host prefetch.

Determinism is the fault-tolerance contract: batch ``i`` is a pure function of
``(seed, i)``, so a restarted (or replacement) host regenerates exactly the
stream it missed — no data-loss bookkeeping, any straggler is replaceable.
The Zipf token stream matches the word-frequency profile the paper's word
count benchmark stresses.

``prefetch`` runs generation on a background thread with a bounded queue so
host data work overlaps device steps (the data-side analogue of
compute/communication overlap).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import ArchConfig


class TokenPipeline:
    def __init__(
        self,
        cfg: ArchConfig,
        batch: int,
        seq_len: int,
        *,
        seed: int = 0,
        sharding: NamedSharding | None = None,
    ):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq_len
        self.seed = seed
        self.sharding = sharding

    def host_batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 1_000_003 + step) % (2**31 - 1))
        ranks = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        toks = np.minimum(ranks - 1, self.cfg.vocab - 1).astype(np.int32)
        return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}

    def device_batch(self, step: int) -> dict[str, jax.Array]:
        hb = self.host_batch(step)
        if self.sharding is None:
            return {k: jax.device_put(v) for k, v in hb.items()}
        return {k: jax.device_put(v, self.sharding) for k, v in hb.items()}

    def prefetch(self, start_step: int, n_steps: int, depth: int = 2) -> Iterator:
        """Background-thread generation, bounded queue of ``depth`` batches."""
        q: queue.Queue = queue.Queue(maxsize=depth)

        def worker():
            for s in range(start_step, start_step + n_steps):
                q.put((s, self.device_batch(s)))
            q.put(None)

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is None:
                return
            yield item
