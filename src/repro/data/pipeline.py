"""Data pipeline: deterministic sharded token stream with host prefetch.

Determinism is the fault-tolerance contract: batch ``i`` is a pure function of
``(seed, i)``, so a restarted (or replacement) host regenerates exactly the
stream it missed — no data-loss bookkeeping, any straggler is replaceable.
The Zipf token stream matches the word-frequency profile the paper's word
count benchmark stresses.

``prefetch`` runs generation on a background thread with a bounded queue so
host data work overlaps device steps (the data-side analogue of
compute/communication overlap).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterable, Iterator

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import ArchConfig
from repro.core import faults

_DONE = object()
_PREFETCH_THREAD_NAME = "blaze-prefetch"
#: Block reads are pure functions of the block index, so the worker may
#: retry an injected read fault in place — results stay bit-equal.
_READ_RETRIES = 3


class _PrefetchFailure:
    """Error sentinel: carries a worker exception across the queue."""

    __slots__ = ("exc",)

    def __init__(self, exc: BaseException):
        self.exc = exc


def prefetch_iter(
    produce: Callable, items: Iterable, depth: int = 2
) -> Iterator[tuple]:
    """Yield ``(item, produce(item))`` with bounded background production.

    The double-buffering primitive shared by ``TokenPipeline.prefetch`` and
    the out-of-core streaming loop (``core.program.Program.run_stream``): a
    worker thread keeps up to ``depth`` results queued while the consumer
    processes the current one.

    Failure contract (both sides of the old prefetch hang):

    * if ``produce`` raises, the exception is re-raised at the consumer's
      next pull — the worker never dies silently leaving the consumer
      blocked on an empty queue;
    * if the consumer abandons the iterator early (``break``, ``close()``,
      GC), a stop event unblocks the worker's bounded ``put`` so it exits
      instead of blocking forever on a full queue.

    Each read passes the ``prefetch.read`` fault point.  Because ``produce``
    is deterministic in its item, a :class:`~repro.core.faults.TransientFault`
    is retried in the worker (bounded); a fatal fault — or an exhausted
    retry budget — crosses the queue like any other worker exception.
    """
    q: queue.Queue = queue.Queue(maxsize=max(1, depth))
    stop = threading.Event()

    def _read(it):
        tries = 0
        while True:
            try:
                faults.fault_point("prefetch.read")
                return produce(it)
            except faults.FatalFault as e:
                faults.record("fatal", e)
                raise
            except faults.TransientFault as e:
                tries += 1
                if tries >= _READ_RETRIES:
                    faults.record("fatal", e)
                    raise
                faults.record("retried", e)

    def _put(x) -> bool:
        # Bounded put that gives up once the consumer has gone away.
        while not stop.is_set():
            try:
                q.put(x, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def worker():
        try:
            for it in items:
                if stop.is_set():
                    return
                if not _put((it, _read(it))):
                    return
            _put(_DONE)
        except BaseException as e:  # noqa: BLE001 — must cross the thread
            _put(_PrefetchFailure(e))

    t = threading.Thread(target=worker, daemon=True, name=_PREFETCH_THREAD_NAME)
    t.start()
    try:
        while True:
            got = q.get()
            if got is _DONE:
                return
            if isinstance(got, _PrefetchFailure):
                raise got.exc
            yield got
    finally:
        stop.set()
        t.join(timeout=10.0)


class TokenPipeline:
    def __init__(
        self,
        cfg: ArchConfig,
        batch: int,
        seq_len: int,
        *,
        seed: int = 0,
        sharding: NamedSharding | None = None,
    ):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq_len
        self.seed = seed
        self.sharding = sharding

    def host_batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 1_000_003 + step) % (2**31 - 1))
        ranks = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        toks = np.minimum(ranks - 1, self.cfg.vocab - 1).astype(np.int32)
        return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}

    def device_batch(self, step: int) -> dict[str, jax.Array]:
        hb = self.host_batch(step)
        if self.sharding is None:
            return {k: jax.device_put(v) for k, v in hb.items()}
        return {k: jax.device_put(v, self.sharding) for k, v in hb.items()}

    def prefetch(self, start_step: int, n_steps: int, depth: int = 2) -> Iterator:
        """Background-thread generation, bounded queue of ``depth`` batches.

        Worker exceptions propagate to the consumer; abandoning the iterator
        early shuts the worker down cleanly (see ``prefetch_iter``).
        """
        yield from prefetch_iter(
            self.device_batch, range(start_step, start_step + n_steps), depth=depth
        )
