"""Attention block: GQA/MHA with RoPE / M-RoPE, qk-norm, softcap, SWA, KV cache.

The attention math runs through ``kernels.ops.attention`` — the Pallas flash
kernel on TPU, the chunked online-softmax jnp path elsewhere (identical
memory profile, no S² buffer, so 32k/500k contexts lower cleanly).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models.layers import (
    apply_mrope,
    apply_rope,
    dense_init,
    rmsnorm,
    rmsnorm_init,
)

Array = jax.Array


class KVCache(NamedTuple):
    k: Array  # [B, S_max, Hkv, Dh]
    v: Array  # [B, S_max, Hkv, Dh]


def attn_init(key, cfg: ArchConfig) -> dict:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, hq * dh, cfg.pdtype),
        "wk": dense_init(ks[1], d, hkv * dh, cfg.pdtype),
        "wv": dense_init(ks[2], d, hkv * dh, cfg.pdtype),
        "wo": dense_init(ks[3], hq * dh, d, cfg.pdtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(dh, cfg.pdtype)
        p["k_norm"] = rmsnorm_init(dh, cfg.pdtype)
    return p


def attn_apply(
    params: dict,
    cfg: ArchConfig,
    x: Array,  # [B, S, d]
    positions: Array,  # [B, S] or [3, B, S] for M-RoPE
    *,
    local: bool = False,
    cache: KVCache | None = None,
    cache_len: Array | int | None = None,
    attn_impl: str = "auto",
) -> tuple[Array, KVCache | None]:
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head

    from repro.distributed.sharding import constrain

    dp = ("pod", "data")
    q = (x @ params["wq"]).reshape(b, s, hq, dh)
    k = (x @ params["wk"]).reshape(b, s, hkv, dh)
    v = (x @ params["wv"]).reshape(b, s, hkv, dh)

    # Layout policy must match the KV-cache policy (sharding.cache_pspecs):
    # decode with kv-heads that don't divide the model axis uses a
    # d_head-sharded cache, so q/k/v align on d_head (the QK^T contraction
    # then partial-psums tiny [B,H,1,bk] tiles instead of resharding the
    # whole cache every chunk).  Everywhere else: TP over heads — the seq
    # all-gather then moves small per-head tensors, never an f32 residual.
    from repro.compat import get_abstract_mesh

    am = get_abstract_mesh()
    msize = am.shape.get("model", 1) if am is not None and am.axis_names else 1
    decode_like = cache is not None and s <= 8
    if decode_like and msize > 1 and hkv % msize != 0 and dh % msize == 0:
        shard_hint = "dh"
        q = constrain(q, dp, None, None, "model")
        k = constrain(k, dp, None, None, "model")
        v = constrain(v, dp, None, None, "model")
    else:
        shard_hint = "heads" if msize > 1 and hq % msize == 0 else None
        q = constrain(q, dp, None, "model", None)
        k = constrain(k, dp, None, "model", None)
        v = constrain(v, dp, None, "model", None)

    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)

    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        # Decode/chunked-prefill: write new K/V at cache_len, attend over cache.
        idx = jnp.asarray(cache_len, jnp.int32)
        ck = jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype), (0, idx, 0, 0)
        )
        cv = jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype), (0, idx, 0, 0)
        )
        new_cache = KVCache(ck, cv)
        k_all, v_all = ck, cv
        q_offset = idx
        if local and cfg.window is not None and ck.shape[1] > cfg.window + s:
            # Decode/short-step fast path: only the last `window + s` cache
            # rows can be in-window — slice them so compute is O(window),
            # not O(cache).  Positions shift consistently via q_offset.
            sw = cfg.window + s
            start = jnp.clip(idx + s - sw, 0, ck.shape[1] - sw)
            k_all = jax.lax.dynamic_slice_in_dim(ck, start, sw, axis=1)
            v_all = jax.lax.dynamic_slice_in_dim(cv, start, sw, axis=1)
            q_offset = idx - start
    else:
        k_all, v_all = k, v
        q_offset = 0

    window = cfg.window if local else None
    out = ops.attention(
        q.transpose(0, 2, 1, 3),
        k_all.transpose(0, 2, 1, 3),
        v_all.transpose(0, 2, 1, 3),
        causal=True,
        window=window,
        softcap=cfg.attn_softcap,
        q_offset=q_offset,
        impl=attn_impl,
        shard_hint=shard_hint,
    )  # [B, Hq, S, Dh]
    out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * dh)
    return (out @ params["wo"]).astype(x.dtype), new_cache


def make_cache(cfg: ArchConfig, batch: int, max_len: int) -> KVCache:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return KVCache(
        k=jnp.zeros(shape, cfg.cdtype), v=jnp.zeros(shape, cfg.cdtype)
    )


def cache_spec(cfg: ArchConfig, batch: int, max_len: int) -> KVCache:
    """ShapeDtypeStruct stand-in (dry-run input_specs)."""
    shape = (batch, max_len, cfg.n_kv_heads, cfg.d_head)
    sds = jax.ShapeDtypeStruct(shape, cfg.cdtype)
    return KVCache(k=sds, v=sds)
