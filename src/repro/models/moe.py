"""Mixture-of-Experts FFN (grok-1, mixtral): top-k routing with capacity.

Dispatch is *group-local*: tokens are reshaped to ``[G, T/G]`` where G is the
data-parallel degree, and ranking/sorting happens along axis 1 — each group's
rows live on one device, so under GSPMD the sort/cumsum/gather never cross
devices.  This is the Blaze small-fixed-key-range MapReduce shape (key =
expert id, E=8): per-device eager combine into dense per-expert buffers,
then dense batched einsums over ``[E, C, d]``.  Router statistics (counts /
importance per expert) are the π-style dense accumulator.

Token-dropping semantics: per (group, expert) capacity
``C = ceil(T_g · k / E · capacity_factor)``; overflow tokens pass through the
residual only (standard GShard/Switch behaviour).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init

Array = jax.Array


def moe_init(key, cfg: ArchConfig) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_gate": jnp.stack(
            [dense_init(k, d, ff, cfg.pdtype) for k in jax.random.split(ks[1], e)]
        ),
        "w_up": jnp.stack(
            [dense_init(k, d, ff, cfg.pdtype) for k in jax.random.split(ks[2], e)]
        ),
        "w_down": jnp.stack(
            [dense_init(k, ff, d, cfg.pdtype) for k in jax.random.split(ks[3], e)]
        ),
    }


def moe_apply(
    params: dict,
    cfg: ArchConfig,
    x: Array,  # [B, S, d]
    *,
    dispatch_groups: int = 1,
) -> tuple[Array, Array]:
    """Returns (output [B, S, d], load-balance aux loss scalar)."""
    from repro.distributed.sharding import constrain

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    g = dispatch_groups if t % dispatch_groups == 0 and b % dispatch_groups == 0 else 1
    tg = t // g
    # Gather the sequence-parallel residual to batch-only sharding first: the
    # [G, Tg] reshape must fold whole batch rows into each dispatch group so
    # GSPMD can keep groups device-local (group-local sort/gather = the
    # Blaze machine-local eager combine; no cross-device shuffle here).
    x = constrain(x, ("pod", "data"), None, None)
    xt = x.reshape(g, tg, d)
    xt = constrain(xt, ("pod", "data"), None, None)

    logits = (xt.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # [G, Tg, E]
    top_p, top_e = jax.lax.top_k(probs, k)  # [G, Tg, k]
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # ---- aux loss (Switch/GShard): E · Σ_e f_e · p̄_e --------------------
    onehot = jax.nn.one_hot(top_e[..., 0], e)  # primary-choice fractions
    f_e = jnp.mean(onehot, axis=(0, 1))
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(f_e * p_e)

    # ---- group-local dispatch (rank within expert by sorted order) -------
    # Flat (group-major) indexing: one 2-D scatter/gather instead of a
    # vmapped batch — identical semantics, far cleaner lowering.
    cap = max(1, math.ceil(tg * k / e * cfg.capacity_factor))
    cap = min(cap, tg)
    flat_e = top_e.reshape(g, tg * k)  # expert of each (token, choice)
    flat_w = top_p.reshape(g, tg * k)
    flat_tok = jnp.broadcast_to(
        jnp.arange(tg)[:, None], (tg, k)
    ).reshape(tg * k)

    order = jnp.argsort(flat_e, axis=1)  # [G, Tg·k] stable per group
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    sorted_w = jnp.take_along_axis(flat_w, order, axis=1)
    sorted_tok = flat_tok[order]  # [G, Tg·k]
    first = jax.vmap(lambda se: jnp.searchsorted(se, se, side="left"))(sorted_e)
    rank = jnp.arange(tg * k)[None, :] - first
    keep = rank < cap
    slot = jnp.where(keep, sorted_e * cap + rank, e * cap)  # [G, Tg·k]

    # Scalar scatter builds the slot→token map; token rows then move by pure
    # GATHERS (no row-payload scatter anywhere — GSPMD keeps the batched
    # gather group-local, and TPU/CPU lowerings stay clean).
    token_of_slot = jnp.full((g, e * cap + 1), tg, jnp.int32)
    token_of_slot = jax.vmap(
        lambda tos, sl, tok: tos.at[sl].set(tok, mode="drop")
    )(token_of_slot, slot, sorted_tok)  # [G, E·C+1] int32

    xt_pad = jnp.concatenate([xt, jnp.zeros((g, 1, d), xt.dtype)], axis=1)
    tos = token_of_slot[:, : e * cap].reshape(g, e, cap)

    # ---- expert FFN: scan over experts (remat body) ------------------------
    # One expert's tiles live at a time — bounds transients to [G, C, ·] and
    # keeps each dot MXU-sized without an [G, E, C, ff] monolith.
    def expert_ffn(_, ew):
        wg, wu, wd, tos_e = ew  # [d, ff], [d, ff], [ff, d], [g, cap]
        xe = jax.vmap(lambda xg, t: jnp.take(xg, t, axis=0))(xt_pad, tos_e)
        xe = constrain(xe, ("pod", "data"), None, None)
        gate = jax.nn.silu(jnp.einsum("gcd,df->gcf", xe, wg.astype(x.dtype)))
        up = jnp.einsum("gcd,df->gcf", xe, wu.astype(x.dtype))
        ye_e = jnp.einsum("gcf,fd->gcd", gate * up, wd.astype(x.dtype))
        return None, ye_e

    expert_ffn = jax.checkpoint(expert_ffn, policy=None)
    _, ye = jax.lax.scan(
        expert_ffn,
        None,
        (
            params["w_gate"], params["w_up"], params["w_down"],
            tos.transpose(1, 0, 2),
        ),
    )  # ye: [E, G, C, d]
    ye = ye.transpose(1, 0, 2, 3)  # [G, E, C, d]

    # ---- combine: gather-only --------------------------------------------
    # Invert the dispatch order so each token sees its k slots, then gather
    # its k expert outputs and mix:  out[t] = Σ_j w[t,j] · ye[slot(t,j)].
    inv = jnp.argsort(order, axis=1)  # [G, Tg·k]
    slot_by_tok = jnp.take_along_axis(slot, inv, axis=1).reshape(g, tg, k)
    w_by_tok = jnp.take_along_axis(sorted_w, inv, axis=1).reshape(g, tg, k)

    ye_pad = jnp.concatenate(
        [ye.reshape(g, e * cap, d), jnp.zeros((g, 1, d), ye.dtype)], axis=1
    )  # drop slot (= e·cap) reads the zero row
    picked = jax.vmap(lambda yg, sl: jnp.take(yg, sl.reshape(-1), axis=0))(
        ye_pad, slot_by_tok
    ).reshape(g, tg, k, d)
    # elementwise mix (not a dot — avoids CPU bf16-GEMM convert blowups)
    out = jnp.sum(picked * w_by_tok[..., None].astype(picked.dtype), axis=2)
    out = constrain(out, ("pod", "data"), None, None)

    return out.reshape(b, s, d).astype(x.dtype), aux
