"""Model assembly for the architecture pool: blocks → stages → scan → LM.

One scan step = one *stage* (the arch's repeating layer pattern), so an
81-layer hybrid lowers as an 11-step scan over a 7-slot stage + a 4-slot tail
— compact HLO at any depth.  zamba2's shared attention block is a closure
constant (one param set, many applications), scanned caches stay per-slot.

Public entry points:
  init(key, cfg)                                  → params
  forward(params, cfg, tokens|embeds, ...)        → hidden [B, S, d]
  logits_fn / loss_fn (chunked over S — no [B, S, V] peak)
  prefill(...) / decode_step(...)                 → serving path with caches
  make_caches / cache_specs                       → cache pytrees (alloc/SDS)
  param_count / active_param_count                → 6·N·D roofline terms
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    ATTN,
    ATTN_LOCAL,
    ATTN_LOCAL_MOE,
    ATTN_MOE,
    MAMBA2,
    RWKV6,
    SHARED_ATTN,
    ArchConfig,
)
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import rwkv as RW
from repro.models import ssm as SSM
from repro.models.layers import embed_init, dense_init, mlp, mlp_init, rmsnorm, rmsnorm_init

Array = jax.Array

_ATTN_KINDS = (ATTN, ATTN_LOCAL, ATTN_MOE, ATTN_LOCAL_MOE, SHARED_ATTN)


@dataclasses.dataclass(frozen=True)
class ParallelCfg:
    """Model-visible parallel info (dispatch grouping for MoE)."""

    dispatch_groups: int = 1


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def block_init(key, cfg: ArchConfig, kind: str) -> dict:
    ks = jax.random.split(key, 3)
    if kind in (ATTN, ATTN_LOCAL, SHARED_ATTN):
        return {
            "ln1": rmsnorm_init(cfg.d_model, cfg.pdtype),
            "attn": A.attn_init(ks[0], cfg),
            "ln2": rmsnorm_init(cfg.d_model, cfg.pdtype),
            "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.pdtype),
        }
    if kind in (ATTN_MOE, ATTN_LOCAL_MOE):
        return {
            "ln1": rmsnorm_init(cfg.d_model, cfg.pdtype),
            "attn": A.attn_init(ks[0], cfg),
            "ln2": rmsnorm_init(cfg.d_model, cfg.pdtype),
            "moe": MOE.moe_init(ks[1], cfg),
        }
    if kind == MAMBA2:
        return {
            "ln1": rmsnorm_init(cfg.d_model, cfg.pdtype),
            "mamba": SSM.mamba_init(ks[0], cfg),
        }
    if kind == RWKV6:
        return {
            "ln1": rmsnorm_init(cfg.d_model, cfg.pdtype),
            "ln2": rmsnorm_init(cfg.d_model, cfg.pdtype),
            "rwkv": RW.rwkv_init(ks[0], cfg),
        }
    raise ValueError(kind)


def block_apply(
    params: dict,
    cfg: ArchConfig,
    kind: str,
    h: Array,
    positions: Array,
    *,
    cache: Any = None,
    cache_len: Any = None,
    par: ParallelCfg = ParallelCfg(),
    attn_impl: str = "auto",
):
    """Returns (h, new_cache, aux)."""
    from repro.distributed.sharding import constrain

    def norm_sp(ln, x):
        # keep the f32 internals of the norm in the sequence-sharded domain;
        # any gather the next op needs then moves bf16, not f32
        return constrain(
            rmsnorm(ln, x), ("pod", "data"), "model", None
        )

    def out_sp(x):
        # constrain block outputs back to sequence-sharded BEFORE the
        # residual add: the row-parallel matmul's partial-sum then lowers to
        # reduce-scatter (1/model_size the wire bytes of an all-reduce)
        return constrain(x, ("pod", "data"), "model", None)

    aux = jnp.zeros((), jnp.float32)
    if kind in _ATTN_KINDS:
        local = kind in (ATTN_LOCAL, ATTN_LOCAL_MOE)
        a_out, new_kv = A.attn_apply(
            params["attn"], cfg, norm_sp(params["ln1"], h), positions,
            local=local, cache=cache, cache_len=cache_len, attn_impl=attn_impl,
        )
        h = h + out_sp(a_out)
        if kind in (ATTN_MOE, ATTN_LOCAL_MOE):
            m_out, aux = MOE.moe_apply(
                params["moe"], cfg, norm_sp(params["ln2"], h),
                dispatch_groups=par.dispatch_groups,
            )
        else:
            m_out = mlp(params["mlp"], norm_sp(params["ln2"], h))
        return h + out_sp(m_out), new_kv, aux
    if kind == MAMBA2:
        m_out, new_cache = SSM.mamba_apply(
            params["mamba"], cfg, norm_sp(params["ln1"], h), cache=cache
        )
        return h + out_sp(m_out), new_cache, aux
    if kind == RWKV6:
        tm_out, shift_tm, state = RW.time_mix(
            params["rwkv"]["tm"], cfg, norm_sp(params["ln1"], h),
            cache,
        )
        h = h + out_sp(tm_out)
        cm_out, shift_cm = RW.channel_mix(
            params["rwkv"]["cm"], cfg, norm_sp(params["ln2"], h),
            cache,
        )
        h = h + out_sp(cm_out)
        new_cache = (
            RW.RWKVCache(shift_tm, shift_cm, state) if cache is not None else None
        )
        return h, new_cache, aux
    raise ValueError(kind)


def _block_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int, spec: bool):
    if kind in _ATTN_KINDS:
        # NOTE: local (SWA) layers allocate the full max_len buffer in the
        # baseline; a window-sized ring buffer is a recorded hillclimb.
        return (
            A.cache_spec(cfg, batch, max_len)
            if spec
            else A.make_cache(cfg, batch, max_len)
        )
    if kind == MAMBA2:
        c = SSM.make_mamba_cache(cfg, batch)
    elif kind == RWKV6:
        c = RW.make_rwkv_cache(cfg, batch)
    else:
        raise ValueError(kind)
    if spec:
        return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), c)
    return c


def make_caches(cfg: ArchConfig, batch: int, max_len: int, *, spec: bool = False):
    """Cache pytree: {"stages": per-slot stacked [n_stages, ...], "tail": [...]}"""

    def stacked(kind):
        one = _block_cache(cfg, kind, batch, max_len, spec)
        if spec:
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((cfg.n_stages,) + s.shape, s.dtype),
                one,
            )
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_stages,) + x.shape), one
        )

    return {
        "stages": [stacked(kind) for kind in cfg.stage_pattern],
        "tail": [
            _block_cache(cfg, kind, batch, max_len, spec)
            for kind in cfg.tail_pattern
        ],
    }


# ---------------------------------------------------------------------------
# Model init / forward
# ---------------------------------------------------------------------------


def init(key, cfg: ArchConfig) -> dict:
    n_slots = len(cfg.stage_pattern)
    keys = jax.random.split(key, cfg.n_stages * n_slots + len(cfg.tail_pattern) + 4)
    ki = iter(range(len(keys)))

    has_shared = SHARED_ATTN in cfg.stage_pattern + cfg.tail_pattern

    def stage_params():
        out = []
        for si in range(cfg.n_stages):
            slots = {}
            for j, kind in enumerate(cfg.stage_pattern):
                if kind == SHARED_ATTN:
                    continue  # shared params live outside the scan
                slots[f"slot{j}"] = block_init(keys[next(ki)], cfg, kind)
            out.append(slots)
        # stack over stages
        return jax.tree.map(lambda *xs: jnp.stack(xs), *out)

    params: dict = {"stages": stage_params()}
    if has_shared:
        params["shared_attn"] = block_init(keys[next(ki)], cfg, SHARED_ATTN)
    params["tail"] = [
        block_init(keys[next(ki)], cfg, kind) for kind in cfg.tail_pattern
    ]
    params["embed"] = embed_init(keys[next(ki)], cfg.vocab, cfg.d_model, cfg.pdtype)
    params["final_norm"] = rmsnorm_init(cfg.d_model, cfg.pdtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[next(ki)], cfg.d_model, cfg.vocab, cfg.pdtype)
    return params


def forward(
    params: dict,
    cfg: ArchConfig,
    inputs: Array,  # tokens [B, S] int32, or embeds [B, S, d] if not embed_inputs
    *,
    positions: Array | None = None,
    caches: Any = None,
    cache_len: Any = None,
    par: ParallelCfg = ParallelCfg(),
    attn_impl: str = "auto",
    remat: bool = False,
    remat_policy: str = "full",  # "full" | "dots"
    scan_layers: bool = True,
):
    """Returns (hidden [B, S, d], new_caches, aux).

    ``scan_layers=False`` unrolls the stage loop (python loop over stage
    indices) — bigger HLO, but ``cost_analysis``/collective counts then
    reflect every layer (scan bodies are counted once), which the roofline
    pass needs.
    """
    if cfg.embed_inputs:
        from repro.distributed.sharding import constrain as _c

        # vocab-sharded embedding gather produces a partial-sum; reshard the
        # small bf16 result to (dp, seq/model) immediately so the psum runs
        # at [B/dp, S, d] rather than full-batch f32
        h = jnp.take(params["embed"], inputs, axis=0).astype(cfg.cdtype)
        h = _c(h, ("pod", "data"), "model", None)
    else:
        h = inputs.astype(cfg.cdtype)
    b, s = h.shape[0], h.shape[1]

    if positions is None:
        base = jnp.arange(s, dtype=jnp.int32)[None, :] + (
            0 if cache_len is None else jnp.asarray(cache_len, jnp.int32)
        )
        base = jnp.broadcast_to(base, (b, s))
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(base[None], (3, b, s))
        else:
            positions = base

    shared = params.get("shared_attn")
    use_cache = caches is not None

    from repro.distributed.sharding import constrain

    # Sequence-parallel residual stream: the per-stage saved activation (the
    # remat boundary) is sharded over (dp, model) — for a 64×d6144 model this
    # is the difference between 51 GiB and 3.2 GiB of checkpointed carries.
    def sp(h):
        return constrain(h, ("pod", "data"), "model", None)

    h = sp(h)

    def run_slots(h, slot_params, slot_caches):
        new_caches, aux_total = [], jnp.zeros((), jnp.float32)
        for j, kind in enumerate(cfg.stage_pattern):
            p = shared if kind == SHARED_ATTN else slot_params[f"slot{j}"]
            c = slot_caches[j] if use_cache else None
            h, nc, aux = block_apply(
                p, cfg, kind, h, positions,
                cache=c, cache_len=cache_len, par=par, attn_impl=attn_impl,
            )
            h = sp(h)
            new_caches.append(nc)
            aux_total = aux_total + aux
        return h, new_caches, aux_total

    if remat:
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if remat_policy == "dots"
            else None
        )
        run_slots = jax.checkpoint(run_slots, policy=policy)

    if use_cache:
        # caches["stages"]: list (per slot) of stacked [n_stages, ...] pytrees
        def stage_fn(carry, xs):
            h, aux = carry
            slot_params, slot_caches = xs
            h, new_caches, aux_s = run_slots(h, slot_params, slot_caches)
            return (h, aux + aux_s), new_caches

        if scan_layers:
            (h, aux), new_stage_caches = jax.lax.scan(
                stage_fn,
                (h, jnp.zeros((), jnp.float32)),
                (params["stages"], caches["stages"]),
            )
        else:
            aux = jnp.zeros((), jnp.float32)
            per_stage_caches = []
            for i in range(cfg.n_stages):
                xs_i = jax.tree.map(
                    lambda x: x[i], (params["stages"], caches["stages"])
                )
                (h, aux), nc = stage_fn((h, aux), xs_i)
                per_stage_caches.append(nc)
            new_stage_caches = jax.tree.map(
                lambda *xs: jnp.stack(xs), *per_stage_caches
            )
        new_tail = []
        for tp, kind, tc in zip(params["tail"], cfg.tail_pattern, caches["tail"]):
            h, nc, aux_t = block_apply(
                tp, cfg, kind, h, positions,
                cache=tc, cache_len=cache_len, par=par, attn_impl=attn_impl,
            )
            new_tail.append(nc)
            aux = aux + aux_t
        new_caches = {"stages": new_stage_caches, "tail": new_tail}
    else:

        def stage_fn(carry, slot_params):
            h, aux = carry
            h, _, aux_s = run_slots(h, slot_params, None)
            return (h, aux + aux_s), None

        if scan_layers:
            (h, aux), _ = jax.lax.scan(
                stage_fn, (h, jnp.zeros((), jnp.float32)), params["stages"]
            )
        else:
            aux = jnp.zeros((), jnp.float32)
            for i in range(cfg.n_stages):
                sp_i = jax.tree.map(lambda x: x[i], params["stages"])
                (h, aux), _ = stage_fn((h, aux), sp_i)
        for tp, kind in zip(params["tail"], cfg.tail_pattern):
            h, _, aux_t = block_apply(
                tp, cfg, kind, h, positions, par=par, attn_impl=attn_impl
            )
            aux = aux + aux_t
        new_caches = None

    h = rmsnorm(params["final_norm"], h)
    return h, new_caches, aux


def _head_matrix(params: dict, cfg: ArchConfig) -> Array:
    if cfg.tie_embeddings:
        return params["embed"].T  # [d, V]
    return params["lm_head"]


def logits_fn(params: dict, cfg: ArchConfig, hidden: Array) -> Array:
    logits = hidden.astype(jnp.float32) @ _head_matrix(params, cfg).astype(jnp.float32)
    if cfg.final_softcap > 0:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


def loss_fn(
    params: dict,
    cfg: ArchConfig,
    inputs: Array,
    labels: Array,  # [B, S] int32
    *,
    par: ParallelCfg = ParallelCfg(),
    aux_coef: float = 0.01,
    remat: bool = True,
    remat_policy: str = "full",
    loss_chunk: int = 512,
    scan_layers: bool = True,
) -> Array:
    hidden, _, aux = forward(
        params, cfg, inputs, par=par, remat=remat, remat_policy=remat_policy,
        scan_layers=scan_layers,
    )
    b, s, d = hidden.shape
    w = _head_matrix(params, cfg)

    # Chunked softmax-xent over the sequence: peak live logits are
    # [B, chunk, V] instead of [B, S, V].
    c = min(loss_chunk, s)
    s_pad = -(-s // c) * c
    hp = jnp.pad(hidden, ((0, 0), (0, s_pad - s), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, s_pad - s)), constant_values=-1)
    h_chunks = hp.reshape(b, s_pad // c, c, d).transpose(1, 0, 2, 3)
    l_chunks = lp.reshape(b, s_pad // c, c).transpose(1, 0, 2)

    def chunk_loss(carry, hc_lc):
        hc, lc = hc_lc
        logits = hc.astype(jnp.float32) @ w.astype(jnp.float32)
        if cfg.final_softcap > 0:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1
        )[..., 0]
        valid = lc >= 0
        nll = jnp.where(valid, lse - gold, 0.0)
        return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

    (total, count), _ = jax.lax.scan(
        chunk_loss, (jnp.zeros(()), jnp.zeros((), jnp.int32)), (h_chunks, l_chunks)
    )
    return total / jnp.maximum(count, 1) + aux_coef * aux


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def prefill(
    params, cfg: ArchConfig, inputs: Array, caches, *,
    par: ParallelCfg = ParallelCfg(), attn_impl: str = "auto",
):
    """Populate caches from a prompt; returns (last-token logits, caches)."""
    hidden, caches, _ = forward(
        params, cfg, inputs, caches=caches, cache_len=0, par=par,
        attn_impl=attn_impl,
    )
    logits = logits_fn(params, cfg, hidden[:, -1:])
    return logits[:, 0], caches


def decode_step(
    params, cfg: ArchConfig, inputs: Array, caches, cache_len, *,
    par: ParallelCfg = ParallelCfg(), attn_impl: str = "auto",
):
    """One token for every sequence.  inputs: [B, 1] tokens or [B, 1, d]."""
    hidden, caches, _ = forward(
        params, cfg, inputs, caches=caches, cache_len=cache_len, par=par,
        attn_impl=attn_impl,
    )
    logits = logits_fn(params, cfg, hidden[:, -1:])
    return logits[:, 0], caches


# ---------------------------------------------------------------------------
# Accounting
# ---------------------------------------------------------------------------


def param_count(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


def active_param_count(params, cfg: ArchConfig) -> int:
    """MoE-aware: experts contribute top_k/E of their params (6·N_active·D)."""
    total = 0
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        if any("moe" in str(p) for p in path) and any(
            str(getattr(p, "key", "")) in ("w_gate", "w_up", "w_down") for p in path
        ):
            n = n * cfg.top_k // max(cfg.n_experts, 1)
        total += n
    return total
