"""RWKV-6 "Finch" block: time-mix (wkv recurrence with data-dependent decay)
+ channel-mix, both with token-shift.

The wkv recurrence runs through ``kernels.ops.rwkv6`` (chunked matmul form).
Data-dependent components (the ddlerp token-shift interpolators and the decay
``w``) use the paper's low-rank adapters.  Decode carries an ``RWKVCache``:
two token-shift rows + the [B, H, K, V] wkv state — O(1) per-token state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init

Array = jax.Array

_LORA = 32  # low-rank width for the ddlerp / decay adapters
_MIX = 5  # r, k, v, w, g token-shift lanes


class RWKVCache(NamedTuple):
    shift_tm: Array  # [B, d]   last token entering time-mix
    shift_cm: Array  # [B, d]   last token entering channel-mix
    state: Array  # [B, H, K, V] wkv state


def rwkv_init(key, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    hk = cfg.rwkv_head_dim
    h = d // hk
    ks = jax.random.split(key, 12)
    return {
        "tm": {
            "mix_base": jnp.zeros((_MIX, d), cfg.pdtype),
            "mix_w1": dense_init(ks[0], d, _MIX * _LORA, cfg.pdtype),
            "mix_w2": (
                jax.random.normal(ks[1], (_MIX, _LORA, d), jnp.float32) * 0.02
            ).astype(cfg.pdtype),
            "wr": dense_init(ks[2], d, d, cfg.pdtype),
            "wk": dense_init(ks[3], d, d, cfg.pdtype),
            "wv": dense_init(ks[4], d, d, cfg.pdtype),
            "wg": dense_init(ks[5], d, d, cfg.pdtype),
            "w0": jnp.full((d,), -6.0, jnp.float32),  # decay bias (slow decay)
            "w_lora1": dense_init(ks[6], d, _LORA, cfg.pdtype),
            "w_lora2": dense_init(ks[7], _LORA, d, cfg.pdtype),
            "u": (jax.random.normal(ks[8], (h, hk), jnp.float32) * 0.1),
            "ln_x": rmsnorm_init(d, cfg.pdtype),
            "wo": dense_init(ks[9], d, d, cfg.pdtype),
        },
        "cm": {
            "mix_k": jnp.zeros((d,), cfg.pdtype),
            "mix_r": jnp.zeros((d,), cfg.pdtype),
            "wk": dense_init(ks[10], d, cfg.d_ff, cfg.pdtype),
            "wv": dense_init(ks[11], cfg.d_ff, d, cfg.pdtype),
            "wr": dense_init(ks[0], d, d, cfg.pdtype),
        },
    }


def _token_shift(x: Array, last: Array | None) -> Array:
    """shift(x)[t] = x[t-1]; position 0 takes ``last`` (decode) or zeros."""
    first = (
        jnp.zeros_like(x[:, :1]) if last is None else last[:, None].astype(x.dtype)
    )
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def time_mix(
    p: dict, cfg: ArchConfig, x: Array, cache: RWKVCache | None
) -> tuple[Array, Array, Array]:
    """Returns (out, new_shift_row, new_state)."""
    b, s, d = x.shape
    hk = cfg.rwkv_head_dim
    h = d // hk
    sx = _token_shift(x, cache.shift_tm if cache is not None else None)
    delta = sx - x

    # ddlerp: per-lane data-dependent interpolation between x and shift(x)
    base = x + delta * p["mix_base"][0][None, None]  # shared first-stage mix
    lora = jnp.tanh(base @ p["mix_w1"]).reshape(b, s, _MIX, _LORA)
    dyn = jnp.einsum("bsml,mld->bsmd", lora, p["mix_w2"].astype(x.dtype))
    mixed = (
        x[:, :, None] + delta[:, :, None] * (p["mix_base"][None, None] + dyn)
    )  # [B, S, 5, d]
    xr, xk, xv, xw, xg = [mixed[:, :, i] for i in range(_MIX)]

    r = (xr @ p["wr"]).reshape(b, s, h, hk)
    k = (xk @ p["wk"]).reshape(b, s, h, hk)
    v = (xv @ p["wv"]).reshape(b, s, h, hk)
    g = xg @ p["wg"]
    # data-dependent decay w ∈ (0, 1): exp(−exp(w0 + lora(xw)))
    wlog = p["w0"][None, None] + jnp.tanh(xw @ p["w_lora1"]) @ p["w_lora2"]
    w = jnp.exp(-jnp.exp(wlog.astype(jnp.float32))).reshape(b, s, h, hk)

    state0 = cache.state if cache is not None else None
    y, state = ops.rwkv6(r, k, v, w, p["u"], init_state=state0, impl="chunked")
    y = y.reshape(b, s, d)
    y = rmsnorm(p["ln_x"], y) * jax.nn.silu(g)
    out = (y @ p["wo"]).astype(x.dtype)
    return out, x[:, -1], state


def channel_mix(
    p: dict, cfg: ArchConfig, x: Array, cache: RWKVCache | None
) -> tuple[Array, Array]:
    sx = _token_shift(x, cache.shift_cm if cache is not None else None)
    delta = sx - x
    xk = x + delta * p["mix_k"][None, None]
    xr = x + delta * p["mix_r"][None, None]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    kv = k @ p["wv"]
    out = (jax.nn.sigmoid(xr @ p["wr"]) * kv).astype(x.dtype)
    return out, x[:, -1]


def make_rwkv_cache(cfg: ArchConfig, batch: int) -> RWKVCache:
    d = cfg.d_model
    hk = cfg.rwkv_head_dim
    h = d // hk
    return RWKVCache(
        shift_tm=jnp.zeros((batch, d), cfg.cdtype),
        shift_cm=jnp.zeros((batch, d), cfg.cdtype),
        state=jnp.zeros((batch, h, hk, hk), jnp.float32),
    )
