"""Shared model layers: norms, rotary embeddings (incl. M-RoPE), init helpers.

Models are pure-functional: parameters are nested dicts of jax arrays; every
layer is an ``init(key, ...) -> params`` + ``apply(params, x, ...)`` pair.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype) -> Array:
    scale = (1.0 / d_in) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(params: dict, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings — standard and M-RoPE (qwen2-vl)
# ---------------------------------------------------------------------------


def _rope_freqs(d_head: int, theta: float) -> Array:
    return 1.0 / (
        theta ** (jnp.arange(0, d_head // 2, dtype=jnp.float32) / (d_head // 2))
    )


def apply_rope(
    x: Array,  # [B, S, H, D]
    positions: Array,  # [B, S] int32
    theta: float = 10_000.0,
) -> Array:
    d = x.shape[-1]
    freqs = _rope_freqs(d, theta)  # [D/2]
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]  # [B, S, 1, D/2]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: Array,  # [B, S, H, D]
    positions: Array,  # [3, B, S] int32 — (t, h, w) triples
    sections: tuple[int, ...],  # per-axis rotary dims, sums to D/2
    theta: float = 10_000.0,
) -> Array:
    """Qwen2-VL multimodal RoPE: the D/2 rotary dim pairs are split into
    sections, each rotated by a different position coordinate."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = _rope_freqs(d, theta)  # [D/2]
    # Select which positional axis drives each frequency slot (static).
    import numpy as np

    axis_of_slot = jnp.asarray(
        np.repeat(np.arange(len(sections)), np.asarray(sections))
    )  # [D/2]
    pos_per_slot = jnp.take(
        positions.astype(jnp.float32), axis_of_slot, axis=0
    )  # [D/2, B, S]
    angles = jnp.moveaxis(pos_per_slot, 0, -1) * freqs  # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU — the pool's default FFN)
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, d_ff, dtype),
        "w_up": dense_init(k2, d, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d, dtype),
    }


def mlp(params: dict, x: Array) -> Array:
    gate = jax.nn.silu(x @ params["w_gate"])
    return ((gate * (x @ params["w_up"])) @ params["w_down"]).astype(x.dtype)
