"""Mamba-2 block (zamba2's SSM component).

in_proj → split (z gate | xBC | dt) → causal depthwise conv on xBC → SSD
(chunked matmul form via ``kernels.ops.ssd``) → gated RMSNorm → out_proj.

Decode carries a ``MambaCache``: the conv tail (last ``conv_width−1`` xBC
rows) and the SSD state ``[B, H, P, N]`` — O(1) per-token state, which is why
the hybrid runs the 500k-context cell.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init

Array = jax.Array


class MambaCache(NamedTuple):
    conv: Array  # [B, conv_width-1, conv_dim]
    h: Array  # [B, H, P, N]


def _dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return d_inner, n_heads, conv_dim


def mamba_init(key, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    d_inner, h, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    d_proj = 2 * d_inner + 2 * cfg.ssm_groups * cfg.ssm_state + h
    return {
        "in_proj": dense_init(ks[0], d, d_proj, cfg.pdtype),
        "conv_w": (
            jax.random.normal(ks[1], (cfg.conv_width, conv_dim), jnp.float32)
            * (1.0 / cfg.conv_width) ** 0.5
        ).astype(cfg.pdtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.pdtype),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)
        ),  # A = −exp(a_log) ∈ [−16, −1]
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": rmsnorm_init(d_inner, cfg.pdtype),
        "out_proj": dense_init(ks[3], d_inner, d, cfg.pdtype),
    }


def _causal_conv(xbc: Array, w: Array, b: Array, tail: Array | None) -> tuple[Array, Array]:
    """Depthwise causal conv along S.  Returns (out [B,S,C], new tail)."""
    cw = w.shape[0]
    hist = (
        jnp.zeros((xbc.shape[0], cw - 1, xbc.shape[2]), xbc.dtype)
        if tail is None
        else tail.astype(xbc.dtype)
    )
    full = jnp.concatenate([hist, xbc], axis=1)  # [B, S+cw-1, C]
    # windowed dot: out[t] = Σ_j w[j]·full[t+j]
    out = sum(
        full[:, j : j + xbc.shape[1]] * w[j][None, None, :] for j in range(cw)
    )
    new_tail = full[:, -(cw - 1) :] if cw > 1 else full[:, :0]
    return jax.nn.silu(out + b[None, None, :]), new_tail


def mamba_apply(
    params: dict,
    cfg: ArchConfig,
    x: Array,  # [B, S, d]
    *,
    cache: MambaCache | None = None,
) -> tuple[Array, MambaCache | None]:
    b, s, d = x.shape
    d_inner, h, conv_dim = _dims(cfg)
    g, n, p = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_head_dim

    proj = x @ params["in_proj"]  # [B, S, d_proj]
    z, xbc, dt = jnp.split(proj, [d_inner, d_inner + conv_dim], axis=-1)

    conv_tail = cache.conv if cache is not None else None
    xbc, new_tail = _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_tail)

    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)
    xs = xs.reshape(b, s, h, p)
    bmat = bmat.reshape(b, s, g, n)
    cmat = cmat.reshape(b, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    a = -jnp.exp(params["a_log"])  # [H]

    init_h = cache.h if cache is not None else None
    y, h_new = ops.ssd(
        xs, dt, a, bmat, cmat, init_state=init_h,
        impl="chunked",
    )  # [B, S, H, P]
    y = y + params["d_skip"][None, None, :, None] * xs
    y = y.reshape(b, s, d_inner)
    y = rmsnorm(params["norm"], y) * jax.nn.silu(z)
    out = (y @ params["out_proj"]).astype(x.dtype)

    new_cache = None
    if cache is not None:
        new_cache = MambaCache(conv=new_tail.astype(cache.conv.dtype), h=h_new)
    return out, new_cache


def make_mamba_cache(cfg: ArchConfig, batch: int) -> MambaCache:
    d_inner, h, conv_dim = _dims(cfg)
    return MambaCache(
        conv=jnp.zeros((batch, cfg.conv_width - 1, conv_dim), cfg.cdtype),
        h=jnp.zeros((batch, h, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
    )
