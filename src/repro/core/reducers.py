"""Built-in and custom reducers (commutative monoids) for Blaze MapReduce.

The paper ships ``"sum"``, ``"prod"``, ``"min"``, ``"max"`` as built-in reducers
selectable by name, plus user-supplied reduce functions.  On TPU a reducer must
additionally expose:

* ``identity(dtype)``      — the monoid identity, used to initialise dense
                             accumulators and to pad masked-out emits,
* ``combine(a, b)``        — elementwise merge of two partials (jnp),
* ``segment(vals, ids, n)``— reduce-by-key into a dense ``[n, ...]`` accumulator
                             (the eager-reduction primitive),
* ``collective(x, axis)``  — the matching mesh collective (``psum`` & friends),

so the same user-visible name drives the thread-local (VMEM), device-local
(HBM) and cross-device (ICI/DCN) levels of the reduction tree.  Built-ins
additionally carry ``pallas_segment`` — the same reduce-by-key contract
lowered through the Pallas one-hot/select-scatter kernel
(``repro.kernels.segment_reduce``) — which ``engine="pallas"`` uses for the
device-local level; custom reducers leave it ``None`` and fall back to
``segment``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.kernels.hash_combine import hash_aggregate as _pallas_hash_aggregate
from repro.kernels.segment_reduce import segment_reduce as _pallas_segment_reduce

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Reducer:
    """A commutative monoid usable at every level of the reduction tree."""

    name: str
    identity_fn: Callable[[Any], Array]
    combine: Callable[[Array, Array], Array]
    segment: Callable[[Array, Array, int], Array]
    collective: Callable[[Array, str], Array]
    # fused whole-axis reduction (jnp.sum/min/…) for the static-key fast
    # path (§2.3.3: no id arrays when the key is known at trace time);
    # None → fall back to the segment path
    axis_reduce: Callable[..., Array] | None = None
    # reduce-by-key through the Pallas kernel: (ids [N], vals [N, V], n) →
    # dense [n, V] in the kernel's accumulator dtype (f32/i32).  ids outside
    # [0, n) are dropped.  None → engine="pallas" falls back to ``segment``.
    pallas_segment: Callable[..., Array] | None = None
    # the unbounded-key mirror: reduce-by-key into an open-addressing VMEM
    # hash table (repro.kernels.hash_combine.hash_aggregate) — what
    # ``engine="pallas"`` runs for ``DistHashMap`` targets.  None → the
    # eager sort-based plan.
    pallas_hash: Callable[..., Array] | None = None

    def identity(self, dtype) -> Array:
        return self.identity_fn(dtype)

    def tree_combine(self, a, b):
        return jax.tree.map(self.combine, a, b)


def _seg_sum(vals: Array, ids: Array, n: int) -> Array:
    return jax.ops.segment_sum(vals, ids, num_segments=n)


def _seg_prod(vals: Array, ids: Array, n: int) -> Array:
    return jax.ops.segment_prod(vals, ids, num_segments=n)


def _seg_min(vals: Array, ids: Array, n: int) -> Array:
    return jax.ops.segment_min(vals, ids, num_segments=n)


def _seg_max(vals: Array, ids: Array, n: int) -> Array:
    return jax.ops.segment_max(vals, ids, num_segments=n)


def _minval(dtype) -> Array:
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(-jnp.inf, dtype)
    return jnp.asarray(jnp.iinfo(dtype).min, dtype)


def _maxval(dtype) -> Array:
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(jnp.inf, dtype)
    return jnp.asarray(jnp.iinfo(dtype).max, dtype)


def _prod_collective(x: Array, ax: str) -> Array:
    # NOT exp(psum(log x)): that breaks for negatives, zeros and ints.  The
    # gathered fold is exact for any sign/dtype; K-sized partials are tiny.
    return jnp.prod(jax.lax.all_gather(x, ax), axis=0)


def _kernel_segment(reducer_name: str) -> Callable[..., Array]:
    return functools.partial(_pallas_segment_reduce, reducer=reducer_name)


def _kernel_hash(reducer_name: str) -> Callable[..., Array]:
    return functools.partial(_pallas_hash_aggregate, reducer=reducer_name)


SUM = Reducer(
    name="sum",
    identity_fn=lambda dt: jnp.asarray(0, dt),
    combine=jnp.add,
    segment=_seg_sum,
    collective=lambda x, ax: jax.lax.psum(x, ax),
    axis_reduce=jnp.sum,
    pallas_segment=_kernel_segment("sum"),
    pallas_hash=_kernel_hash("sum"),
)

PROD = Reducer(
    name="prod",
    identity_fn=lambda dt: jnp.asarray(1, dt),
    combine=jnp.multiply,
    segment=_seg_prod,
    collective=_prod_collective,
    axis_reduce=jnp.prod,
    pallas_segment=_kernel_segment("prod"),
    pallas_hash=_kernel_hash("prod"),
)

MIN = Reducer(
    name="min",
    identity_fn=_maxval,
    combine=jnp.minimum,
    segment=_seg_min,
    collective=lambda x, ax: jax.lax.pmin(x, ax),
    axis_reduce=jnp.min,
    pallas_segment=_kernel_segment("min"),
    pallas_hash=_kernel_hash("min"),
)

MAX = Reducer(
    name="max",
    identity_fn=_minval,
    combine=jnp.maximum,
    segment=_seg_max,
    collective=lambda x, ax: jax.lax.pmax(x, ax),
    axis_reduce=jnp.max,
    pallas_segment=_kernel_segment("max"),
    pallas_hash=_kernel_hash("max"),
)

_BUILTIN: dict[str, Reducer] = {r.name: r for r in (SUM, PROD, MIN, MAX)}


def custom_reducer(
    name: str,
    combine: Callable[[Array, Array], Array],
    identity_fn: Callable[[Any], Array],
) -> Reducer:
    """Build a reducer from a user combine fn (the paper's custom-reducer API).

    The segment / collective levels are synthesised from ``combine`` via an
    associative scan over sorted keys and an ``all_gather`` + fold — correct for
    any commutative monoid, at the cost of not using the fused psum fast path.
    """

    def _segment(vals: Array, ids: Array, n: int) -> Array:
        order = jnp.argsort(ids)
        svals, sids = jnp.take(vals, order, axis=0), jnp.take(ids, order)
        # Segmented inclusive scan with ``combine``: reset at segment starts.
        starts = jnp.concatenate([jnp.ones((1,), bool), sids[1:] != sids[:-1]])

        def op(a, b):
            av, af = a
            bv, bf = b
            return jnp.where(bf, bv, combine(av, bv)), af | bf

        scanned, _ = jax.lax.associative_scan(op, (svals, starts), axis=0)
        # Last element of each segment holds its total.
        is_last = jnp.concatenate([sids[1:] != sids[:-1], jnp.ones((1,), bool)])
        ident = identity_fn(vals.dtype)
        out = jnp.full((n,) + vals.shape[1:], ident, vals.dtype)
        safe_ids = jnp.where(is_last, sids, n)  # drop non-last into the void
        return out.at[safe_ids].set(scanned, mode="drop")

    def _collective(x: Array, axis: str) -> Array:
        gathered = jax.lax.all_gather(x, axis)  # [n_dev, ...]

        def fold(carry, nxt):
            return combine(carry, nxt), None

        first, rest = gathered[0], gathered[1:]
        out, _ = jax.lax.scan(fold, first, rest)
        return out

    return Reducer(name, identity_fn, combine, _segment, _collective)


def get_reducer(reducer: str | Reducer) -> Reducer:
    """Resolve a reducer by name (paper API: pass ``"sum"`` etc.) or instance."""
    if isinstance(reducer, Reducer):
        return reducer
    try:
        return _BUILTIN[reducer]
    except KeyError:
        raise ValueError(
            f"unknown reducer {reducer!r}; built-ins: {sorted(_BUILTIN)}"
        ) from None
