"""The Blaze logical-plan IR: explicit plans, optimizer passes, EXPLAIN.

The paper's pitch is that ONE MapReduce function plus three utilities beats
Spark's ~30 primitives — but Spark keeps one decisive advantage:
*introspection*.  A Spark job is a logical plan you can optimize and
``EXPLAIN``; a Blaze job is a C++ call tree you can only run.  Until PR 5 this
reproduction had the same blind spot: the program layer traced a whole
iteration and then consumed the discovered structure *inline* — engine
choice, wire narrowing and op ordering were decided ad hoc per op, and no
optimization could look across ops.

This module is the missing plan:

* ``Plan`` — a DAG of :class:`MapReduceNode` / :class:`ForeachNode` /
  :class:`ContainerOpNode` / :class:`GlueNode` nodes in call order, plus the
  source table, residual/hash-state edges, batch groups and pass log.
  ``repro.core.program`` *builds* one during discovery instead of consuming
  the trace; both executors consume it — standalone ``map_reduce`` wraps a
  single-node plan (``single_op_plan``), ``Program`` lowers the full DAG.
* **Passes** — the optimizations an explicit plan makes possible:

  - ``resolve-engines``  (:func:`resolve_engine`, moved here from
    ``session.py``): engines are chosen *per node*, so one program can mix
    pallas-dense, pallas-hash and eager ops;
  - ``batch-collectives``: independent dense reductions with the same
    (reducer, wire, dtype) in one iteration are concatenated into ONE fused
    collective — GMM's EM round used to issue 4 separate psums, now 2
    (asserted via the new ``collectives_per_iter`` stat).  This is the BSP
    "batch the whole superstep" fix (Pace, arXiv:1203.2081) for the
    dispatch/collective overhead Li (arXiv:1811.04875) identifies;
  - ``cse``: two ops with identical (source, mapper, reducer, target,
    engine, wire, env) run once — the second reuses the first's result;
  - ``prune-dead-sources``: ops whose results are provably unused are
    dropped, and sources referenced only by dropped ops are never shipped
    into the executable.

* ``Plan.render()`` — the Spark-``EXPLAIN`` analogue: nodes, resolved
  engines, wire dtypes, batched collective groups, pass effects.  Golden
  snapshots for all six paper algorithms live in ``tests/goldens/`` and are
  diffed in CI (``tools/check_explain_goldens.py``).
* **Plan hashes as cache keys** — every node carries a stable digest
  (``node.hash``) and an identity-faithful cache signature
  (``node.cache_sig``); the session's executable cache is keyed on the
  latter, and the per-op and program paths provably agree because both
  derive their keys from the same node builder (asserted in
  ``tests/test_plan.py``).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import containers as C
from repro.core import cost as cost_mod
from repro.core.cost import PALLAS_AUTO_MAX_KEYS, TunedConfig, TuningCache
from repro.core.reducers import Reducer

__all__ = [
    "DEFAULT_PASSES",
    "ENGINES",
    "PALLAS_AUTO_MAX_KEYS",
    "ContainerOpNode",
    "ForeachNode",
    "GlueNode",
    "MapReduceNode",
    "Plan",
    "SourceInfo",
    "abstract_sig",
    "apply_hierarchical",
    "apply_tuned",
    "build_mapreduce_node",
    "hier_collective_desc",
    "node_key_count",
    "resolve_engine",
    "single_op_plan",
]

ENGINES = ("eager", "pallas", "naive", "auto")

# The optimizer passes a Program runs by default, in order.  resolve-engines
# is not optional (a node without a resolved engine cannot lower); the other
# three can be switched off per program (``session.program(..., passes=())``)
# — which is how benchmarks measure the before/after of collective batching.
DEFAULT_PASSES = ("cse", "batch-collectives", "prune-dead-sources")

# PALLAS_AUTO_MAX_KEYS now lives in repro.core.cost as the fallback cost
# model's calibration anchor (re-exported here for back-compat): the modelled
# eager-vs-pallas crossover sits at exactly K == 4096 keys, so engine="auto"
# keeps the policy PR 2's differential matrix pinned.


def node_key_count(target) -> int:
    """Accumulator rows ``k`` the cost model prices a node by: the dense key
    range, or the hash table's per-shard capacity.  0 when unknowable."""
    if isinstance(target, C.DistHashMap):
        return target.capacity_per_shard
    return jnp.asarray(target).shape[0] if jnp.ndim(target) else 0


def resolve_engine(engine: str, target, reducer: Reducer) -> str:
    """The per-node engine-resolution pass (``engine="auto"`` policy plus
    reducer-compatibility fallbacks).

    Every target kind has a kernel: dense targets run the segment-reduce
    kernel (``Reducer.pallas_segment``), ``DistHashMap`` targets the
    hash-aggregation kernel (``Reducer.pallas_hash``).  Only a *custom*
    reducer — which carries neither — falls back to the eager plan
    (``engine="pallas"`` degrades rather than erroring, so drivers can pass
    one engine for mixed pipelines, and the resolved name in
    ``MapReduceStats.engine`` / ``MapReduceNode.engine`` matches the plan
    that runs).

    ``"auto"`` asks the calibrated fallback cost model
    (``cost.pick_engine``): the modelled-cheaper engine over ``k``
    accumulator rows (dense key range / hash ``capacity_per_shard``), whose
    calibration puts the eager/pallas crossover at exactly
    ``k == PALLAS_AUTO_MAX_KEYS`` — deterministic, and pinned against the
    old static rule by the PR 2 differential matrix.  Lives here (not in
    ``session.py``) since PR 5: resolution is a planning pass applied
    node-by-node, which is what lets one fused program mix engines.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; choose from {ENGINES}")
    hash_target = isinstance(target, C.DistHashMap)
    kernel = reducer.pallas_hash if hash_target else reducer.pallas_segment
    if engine == "pallas" and kernel is None:
        return "eager"
    if engine != "auto":
        return engine
    if kernel is None:
        return "eager"
    return cost_mod.pick_engine(node_key_count(target))


def abstract_sig(tree) -> tuple:
    """Hashable (treedef, shapes/dtypes) signature — cheap cache key.

    (Moved from ``repro.core.mapreduce`` so the plan layer sits below the
    engine; ``mapreduce._abstract`` re-exports it.)
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return treedef, tuple(
        (getattr(x, "shape", ()), str(getattr(x, "dtype", type(x))))
        for x in leaves
    )


def _dtype_name(dt) -> str:
    return str(jnp.dtype(dt))


def _fn_name(fn: Callable) -> str:
    mod = getattr(fn, "__module__", "?")
    qual = getattr(fn, "__qualname__", getattr(fn, "__name__", repr(fn)))
    return f"{mod}.{qual}"


def _sig_desc(sig: tuple) -> str:
    """Render an ``abstract_sig`` compactly and deterministically."""
    _, leaves = sig
    if not leaves:
        return "-"
    return ",".join(f"{dt}[{'x'.join(map(str, sh))}]" for sh, dt in leaves)


def source_desc(kind: str, source) -> str:
    """Stable human-readable description of a plan source."""
    if kind == "range":
        return f"range[{source.start}:{source.stop}:{source.step}]"
    if kind == "vector":
        d = source.data
        return (
            f"vector {_dtype_name(d.dtype)}[{'x'.join(map(str, d.shape))}]"
            f" n={source.n}"
        )
    if kind == "chunked":
        tail = "x".join(map(str, getattr(source, "shape_tail", ())))
        shape = f"{source.block_rows}{'x' + tail if tail else ''}"
        return (
            f"chunked {_dtype_name(source.dtype)}[{shape}]"
            f" n={source.n} blocks={source.n_blocks}"
        )
    t = source.table
    return (
        f"hashmap cap={t.keys.shape[-1]} "
        f"{_dtype_name(t.vals.dtype)}[{'x'.join(map(str, t.vals.shape[2:]))}]"
    )


@dataclasses.dataclass
class SourceInfo:
    """One entry of the plan's source table (what the executable ships)."""

    key: tuple  # identity key (repro.core.program._source_key)
    desc: str  # stable rendering for explain/hash
    source: Any  # the container object (operands are derived from it)
    pruned: bool = False  # no live node references it -> not shipped


@dataclasses.dataclass
class MapReduceNode:
    """One MapReduce op: sources, reducer, target, wire — and what the
    passes decided for it (engine, batch group, CSE, deadness)."""

    idx: int  # call-order index within the plan
    kind: str  # source kind: range | vector | hashmap (incl. program-locals)
    src: str  # stable source description ("local[i]" for program locals)
    source_key: tuple | None  # source-table key (None for program locals)
    mapper: Callable
    reducer: str
    target_kind: str  # "dense" | "hash"
    target_desc: str  # e.g. "dense float32[4x3]" / "hash cap=256 int32"
    engine_requested: str
    engine: str  # after the resolve-engines pass
    wire: str
    key_range: int | None = None
    env_sig: tuple = ()
    feedback: bool = False  # int8 error-feedback sum (never batched/CSE'd)
    residual_spec: tuple | None = None  # (shape, dtype) when feedback
    # -- pass annotations ----------------------------------------------------
    group: int | None = None  # batched-collective group id (size > 1 only)
    cse_of: int | None = None  # idx of the identical earlier node it reuses
    dead: bool = False  # result provably unused -> op pruned
    collective: str = ""  # what carries this op's shuffle
    cache_sig: tuple | None = None  # identity-faithful executable cache key
    # -- cost-model / autotuning annotations (NOT part of stable_desc: the
    # tuning cache is keyed by the hash of the un-tuned node, so applying a
    # cached winner must not move the key it was cached under) --------------
    cost_estimate: float | None = None  # model units for the resolved engine
    tune_key: str = ""  # node hash at resolve time, before any tuned override
    tuned: TunedConfig | None = None  # the applied winner (measured or loaded)
    # -- fault-supervision provenance: the engine a kernel fault degraded
    # this node FROM (None = never degraded).  Like tuned, not part of
    # stable_desc — but degradation rewrites ``engine``, which is.
    degraded_from: str | None = None
    # -- hierarchical-collectives pass: True when the node's collective was
    # rewritten to the two-hop (intra-node full precision, inter-node wire)
    # topology.  Rendered into stable_desc ONLY when set, so 1-D plans hash
    # and render exactly as before the pass existed.
    hier: bool = False

    def stable_desc(self) -> str:
        desc = (
            f"map_reduce {self.reducer} fn={_fn_name(self.mapper)} "
            f"src={self.kind}:{self.src} "
            f"-> {self.target_desc} engine={self.engine} wire={self.wire} "
            f"key_range={self.key_range} env={_sig_desc(self.env_sig)}"
        )
        if self.hier:
            desc += " hier"
        return desc

    @property
    def hash(self) -> str:
        """Stable digest of everything that shapes this op's plan — equal for
        the per-op and program spellings of the same op (tested)."""
        return hashlib.sha1(self.stable_desc().encode()).hexdigest()[:12]


@dataclasses.dataclass
class ForeachNode:
    """Elementwise map over a vector source; output stays shard-local."""

    idx: int
    src: str
    source_key: tuple | None
    fn: Callable

    def stable_desc(self) -> str:
        return f"foreach src={self.src} fn={_fn_name(self.fn)}"


@dataclasses.dataclass
class ContainerOpNode:
    """A container-level plan node (``topk``): the op's plan is fixed by the
    container, so an ``engine=`` request cannot change it — the node records
    the request and surfaces that it was ignored instead of dropping it."""

    idx: int
    op: str  # "topk"
    src: str
    source_key: tuple | None
    params: str  # e.g. "k=100 score=_neg_sq_dist"
    engine_requested: str | None = None  # surfaced, never applied

    def stable_desc(self) -> str:
        return f"{self.op} src={self.src} {self.params}"


@dataclasses.dataclass
class GlueNode:
    """The user's interstitial jnp glue (opaque; stays in the step fn)."""

    idx: int
    desc: str

    def stable_desc(self) -> str:
        return f"glue {self.desc}"


@dataclasses.dataclass
class Plan:
    """An optimized logical plan: what ``session.explain`` renders and what
    both executors lower."""

    nodes: list
    sources: list[SourceInfo]
    state_desc: str
    n_shards: int
    passes: tuple[str, ...]
    n_nodes: int = 1  # simulated/real host rows of the mesh (1 = 1-D mesh)
    groups: dict[int, list[int]] = dataclasses.field(default_factory=dict)
    group_keys: dict[int, tuple] = dataclasses.field(default_factory=dict)
    collectives_per_iter: int = 0  # after batching/CSE/pruning
    collectives_unbatched: int = 0  # the same plan, one collective per op
    cse_hits: int = 0
    dead_ops: int = 0
    pruned_sources: int = 0
    residual_specs: list[tuple] = dataclasses.field(default_factory=list)
    hash_targets: dict = dataclasses.field(default_factory=dict)
    # node idx -> (target_kind, k, v, reducer_name, dtype_str, key_range,
    # has_kernel): the candidate-grid parameters the program autotuner needs
    # to rebuild measurement variants without re-tracing.  Not part of the
    # plan hash — it describes the same ops the hashed descs already cover.
    tune_info: dict = dataclasses.field(default_factory=dict)

    @property
    def hash(self) -> str:
        """Stable digest of the whole optimized plan (nodes + sources +
        state + groups) — the program-level cache identity."""
        parts = [self.state_desc, f"shards={self.n_shards}"]
        if self.n_nodes > 1:  # absent on 1-D meshes: legacy hashes unchanged
            parts.append(f"nodes={self.n_nodes}")
        parts += [n.stable_desc() for n in self.nodes]
        parts += [s.desc for s in self.sources if not s.pruned]
        parts += [f"group{g}={idxs}" for g, idxs in sorted(self.groups.items())]
        return hashlib.sha1("\n".join(parts).encode()).hexdigest()[:12]

    def live_sources(self) -> list[SourceInfo]:
        return [s for s in self.sources if not s.pruned]

    def mapreduce_nodes(self) -> list[MapReduceNode]:
        return [n for n in self.nodes if isinstance(n, MapReduceNode)]

    # -- EXPLAIN -------------------------------------------------------------

    def render(self, title: str = "Blaze logical plan") -> str:
        lines = [f"== {title} (hash {self.hash}) =="]
        if self.n_nodes > 1:
            per = self.n_shards // self.n_nodes
            lines.append(f"mesh: node[{self.n_nodes}]×data[{per}]")
        else:
            lines.append(f"mesh: data[{self.n_shards}]")
        lines.append(f"state: {self.state_desc}")
        lines.append(
            "passes: resolve-engines"
            + (", hierarchical-collectives" if self.n_nodes > 1 else "")
            + ("".join(f", {p}" for p in self.passes))
        )
        lines.append("nodes:")
        for n in self.nodes:
            flags = []
            if isinstance(n, MapReduceNode):
                if n.dead:
                    flags.append("DEAD (pruned)")
                if n.cse_of is not None:
                    flags.append(f"CSE -> node [{n.cse_of}]")
                if n.group is not None:
                    flags.append(f"group {chr(ord('A') + n.group)}")
                if n.feedback:
                    flags.append("int8 feedback")
                if n.degraded_from is not None:
                    flags.append(
                        f"degraded {n.degraded_from!r} -> {n.engine!r} "
                        "(kernel fault)"
                    )
                elif n.engine_requested != n.engine and n.tuned is None:
                    flags.append(f"requested {n.engine_requested!r}")
                if n.tuned is not None:
                    cfg = n.tuned
                    wall = (
                        f" {cfg.wall_s * 1e3:.2f}ms"
                        if cfg.wall_s is not None
                        else ""
                    )
                    flags.append(f"tuned {cfg.source}: {cfg.describe()}{wall}")
                mapper_name = _fn_name(n.mapper).rsplit(".", 1)[-1]
                body = (
                    f"map_reduce {n.reducer:<4} fn={mapper_name} "
                    f"src={n.kind}:{n.src} -> "
                    f"{n.target_desc}  engine={n.engine} wire={n.wire}"
                )
                if n.cost_estimate is not None:
                    body += f" cost~{int(n.cost_estimate)}"
                if n.key_range is not None:
                    body += f" key_range={n.key_range}"
                if n.collective and not n.dead and n.cse_of is None:
                    body += f"  via {n.collective}"
            elif isinstance(n, ForeachNode):
                body = f"foreach    src={n.src}  fn={_fn_name(n.fn).rsplit('.', 1)[-1]}"
            elif isinstance(n, ContainerOpNode):
                body = f"{n.op:<10} src={n.src}  {n.params}"
                if n.engine_requested and n.engine_requested != "auto":
                    flags.append(
                        f"engine={n.engine_requested!r} ignored "
                        "(container-level plan)"
                    )
            else:
                body = f"glue       {n.desc}"
            suffix = f"   [{'; '.join(flags)}]" if flags else ""
            lines.append(f"  [{n.idx}] {body}{suffix}")
        if self.sources:
            lines.append("sources:")
            for s in self.sources:
                mark = "  (pruned: no live consumer)" if s.pruned else ""
                lines.append(f"  - {s.desc}{mark}")
        stream = [
            s for s in self.sources
            if not s.pruned and s.desc.startswith("chunked ")
        ]
        if stream:
            lines.append("stream schedule (out-of-core, one executable):")
            for s in stream:
                blocks = getattr(s.source, "n_blocks", "?")
                rows = getattr(s.source, "block_rows", "?")
                lines.append(
                    f"  - {s.desc}: {blocks} block dispatches of {rows} rows"
                    " each; block k+1 prefetched (host thread) while block k"
                    " reduces on device"
                )
        if self.groups:
            lines.append("batched collective groups:")
            for g, idxs in sorted(self.groups.items()):
                # Key is (red, wire, dtype) plus, on multi-node meshes, the
                # hier flag — groups never mix hierarchical and flat reduces.
                key = self.group_keys.get(g, ("?", "?", "?"))
                red, wire, dt = key[:3]
                hier = len(key) > 3 and key[3]
                lines.append(
                    f"  {chr(ord('A') + g)}: {red}/{wire}/{dt}"
                    + ("/hier" if hier else "")
                    + f" carries nodes {idxs} ({len(idxs)} collectives -> 1)"
                )
        lines.append(
            f"collectives/iter: {self.collectives_per_iter} "
            f"(unbatched: {self.collectives_unbatched})"
            + (f"; cse hits: {self.cse_hits}" if self.cse_hits else "")
            + (f"; dead ops pruned: {self.dead_ops}" if self.dead_ops else "")
            + (
                f"; sources pruned: {self.pruned_sources}"
                if self.pruned_sources
                else ""
            )
        )
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Node builders (shared by the per-op and program paths)
# ---------------------------------------------------------------------------


def target_desc_of(target) -> tuple[str, str]:
    """(target_kind, stable description) for a dense array or DistHashMap."""
    if isinstance(target, C.DistHashMap):
        t = target.table
        return "hash", (
            f"hash cap={t.keys.shape[-1]} {_dtype_name(t.vals.dtype)}"
        )
    t = jnp.asarray(target)
    return "dense", f"dense {_dtype_name(t.dtype)}[{'x'.join(map(str, t.shape))}]"


def apply_tuned(node: MapReduceNode, red: Reducer, cfg: TunedConfig) -> None:
    """Apply a tuning-cache winner to a freshly built node: override the
    resolved engine (when the reducer actually carries the kernel the config
    asks for) and attach the kernel config for the stage builders.  The
    override is applied *after* ``tune_key`` was captured, so the node's
    cache identity in the tuning cache is unchanged."""
    kernel = (
        red.pallas_hash if node.target_kind == "hash" else red.pallas_segment
    )
    if cfg.engine == "pallas" and kernel is None:
        return  # custom reducer: the config cannot lower; keep the fallback
    node.engine = cfg.engine
    node.tuned = cfg


def hier_collective_desc(reducer_name: str, wire: str) -> str:
    """EXPLAIN rendering of a hierarchical collective, e.g.
    ``psum[node×data, hier, wire=int8@inter]``: the intra-node hop always
    runs at full precision; ``@inter`` marks where wire narrowing applies."""
    op = "psum" if reducer_name == "sum" else f"{reducer_name}-reduce"
    desc = f"{op}[node×data, hier"
    if wire != "none" and reducer_name == "sum":
        desc += f", wire={wire}@inter"
    return desc + "]"


def apply_hierarchical(node: MapReduceNode, n_nodes: int) -> bool:
    """The ``hierarchical-collectives`` pass, applied per node.

    Rewrites an eligible node's collective to the two-hop topology: a
    full-precision intra-node reduce over the fast links first, then the
    inter-node reduce over node-level partials — with wire narrowing (when
    requested) applied only to the slow inter-node hop.  Eligible nodes are
    dense reductions on the eager/pallas plans (``naive`` all-gathers raw
    pairs and hash targets shuffle point-to-point — neither has a reduction
    tree to reshape).  A no-op on 1-D meshes (``n_nodes <= 1``), so every
    pre-existing plan hash and explain golden is unchanged.  Composes with
    ``batch-collectives``: batched groups carry the member nodes' shared
    ``hier`` flag through one concatenated two-hop reduce.
    """
    if (
        n_nodes <= 1
        or node.target_kind != "dense"
        or node.engine not in ("eager", "pallas")
    ):
        return False
    node.hier = True
    node.collective = hier_collective_desc(node.reducer, node.wire)
    return True


def degrade_node(node: MapReduceNode) -> None:
    """Degrade a kernel-faulted node to the always-available eager engine.

    Records where the node came FROM (rendered by ``explain`` and surfaced
    as ``MapReduceStats.degraded_engine``) and drops any tuned kernel config
    — a pinned Pallas geometry cannot lower the eager plan.  The rewritten
    ``engine`` moves ``node.hash``/``cache_sig`` so the degraded executable
    caches beside, never over, the faulted one; ``tune_key`` was captured
    before any override and stays put.
    """
    if node.engine == "eager":
        return
    node.degraded_from = node.engine
    node.engine = "eager"
    node.tuned = None


def build_mapreduce_node(
    idx: int,
    kind: str,
    src: str,
    source_key: tuple | None,
    mapper: Callable,
    red: Reducer,
    target,
    engine: str,
    wire: str,
    key_range: int | None,
    env: Any,
    tuning: TuningCache | None = None,
    degraded: set | None = None,
    n_nodes: int = 1,
    hierarchical: bool = True,
) -> MapReduceNode:
    """Build a MapReduce node and run the resolve-engines pass on it.

    This is THE node constructor: ``BlazeSession.map_reduce`` builds its
    single-node plan through it and ``ProgramContext`` builds every program
    node through it, which is why the two paths produce identical node
    hashes for the same op.  When a ``tuning`` cache is passed, a cached
    measured winner for this node (keyed by its un-tuned hash) is applied
    before the node is returned — the resolve-engines pass consulting the
    measured cost model instead of the analytic fallback.

    On multi-node meshes (``n_nodes > 1``) the ``hierarchical-collectives``
    pass runs here too — per node, like resolve-engines — unless the caller
    opts out (``hierarchical=False``, the flat-topology A/B baseline).  It
    runs BEFORE ``tune_key`` is captured: a hierarchical node is a
    different plan, so it must not inherit flat-topology tuning winners.
    """
    target_kind, tdesc = target_desc_of(target)
    if target_kind == "hash":
        wire = "none"  # wire narrowing is a dense-target concept
    resolved = resolve_engine(engine, target, red)
    if target_kind == "dense":
        t = jnp.asarray(target)
        n_elems = int(np.prod(t.shape)) if t.shape else 1
        vb = {"bf16": 2, "int8": 1}.get(wire, jnp.dtype(t.dtype).itemsize)
        if resolved == "naive":
            collective = "all_gather[raw pairs]"
        else:
            collective = f"psum[{n_elems}x{vb}B]" if red.name == "sum" else (
                f"{red.name}-reduce[{n_elems}]"
            )
    else:
        from repro.core.serialization import narrowest_int_dtype

        kb = (
            narrowest_int_dtype(key_range).itemsize
            if key_range is not None
            else 4
        )
        vb = jnp.dtype(target.table.vals.dtype).itemsize
        collective = f"all_to_all[pairs x {kb + vb}B]"
    node = MapReduceNode(
        idx=idx,
        kind=kind,
        src=src,
        source_key=source_key,
        mapper=mapper,
        reducer=red.name,
        target_kind=target_kind,
        target_desc=tdesc,
        engine_requested=engine,
        engine=resolved,
        wire=wire,
        key_range=key_range,
        env_sig=abstract_sig(env),
        collective=collective,
    )
    if hierarchical:
        apply_hierarchical(node, n_nodes)
    if resolved in ("eager", "pallas"):
        node.cost_estimate = cost_mod.node_cost(
            resolved, node_key_count(target)
        )
    node.tune_key = node.hash  # identity BEFORE any tuned override
    if tuning is not None:
        cfg = tuning.get(node.tune_key)
        if cfg is not None:
            apply_tuned(node, red, cfg)
    # A node the session supervisor already degraded stays degraded: the
    # rebuilt node resolves straight to eager and hits the executable the
    # recovery dispatch compiled (the no-cache-poisoning contract).
    if degraded and node.tune_key in degraded:
        degrade_node(node)
    return node


def single_op_plan(node: MapReduceNode, n_shards: int, n_nodes: int = 1) -> Plan:
    """The standalone ``map_reduce`` path: one op is a one-node plan."""
    return Plan(
        nodes=[node],
        sources=[],
        state_desc="-",
        n_shards=n_shards,
        n_nodes=n_nodes,
        passes=(),
        collectives_per_iter=1,
        collectives_unbatched=1,
    )
