"""Deterministic, seeded fault injection for the Blaze runtime.

Fault tolerance is only trustworthy if every failure mode the supervisor
claims to handle can be reproduced on demand.  This module provides the
injection side of that contract: *named fault points* compiled into the
runtime's host-side dispatch paths, and a process-wide registry of *rules*
that decide — deterministically — which hits of which points raise.

The named points (see ``POINTS``) cover every layer that can fail:

==================  ====================================================
``dispatch``        a per-op or fused-program dispatch (``mapreduce.py``,
                    ``program.py``)
``collective``      tracing a cross-shard collective (``RealCollectives``)
``collective.inter``the inter-node hop of a hierarchical reduce (the slow
                    cross-host leg; fires only on multi-node meshes)
``kernel.segment``  the Pallas segment kernel path of a dense dispatch
``kernel.hash``     the Pallas hash-combine path of a hash dispatch
``prefetch.read``   a block read inside the prefetch worker
                    (``data/pipeline.py``)
``checkpoint.write``a checkpoint write (``checkpoint/manager.py``)
``tuning.measure``  one autotuner candidate measurement
==================  ====================================================

Rules trigger on an exact hit number (``at=``), periodically (``every=``),
or probabilistically (``p=``) from a rule-local ``random.Random`` seeded
from ``seed ^ crc32(point)`` — the same schedule replays bit-identically
across runs, which is what lets the chaos suite assert *results under
faults are bit-equal to fault-free runs*.  Rules come from the
``BLAZE_FAULTS`` environment variable (``"dispatch:at=3;kernel.hash:p=0.1,
seed=42,fatal"``) or from the API (:func:`configure` / :func:`inject`).

A fired rule raises :class:`TransientFault` (retryable) or
:class:`FatalFault` (must propagate).  The registry also keeps the
*recovery ledger*: every injected fault is eventually disposed exactly once
(``retried`` / ``degraded`` / ``escalated`` / ``fatal`` / ``absorbed``) by
whichever supervisor caught it, so the conservation law

    ``injected_total == retried + degraded + escalated + fatal + absorbed``

is checkable from :func:`snapshot` after any run.  :func:`record` marks the
fault instance itself, so a fault handed across threads (e.g. out of the
prefetch worker) cannot be double-counted.

When no rules are armed, :func:`fault_point` is a single attribute check —
the fault-free overhead budget of ``benchmarks/bench9_faults.py`` depends
on that fast path.

Import discipline: stdlib only (like ``cost.py``), so kernels, the data
pipeline, and the checkpoint manager can all import this module without
cycles.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import random
import threading
import zlib

__all__ = [
    "DISPOSITIONS",
    "FatalFault",
    "FaultRegistry",
    "FaultRule",
    "InjectedFault",
    "POINTS",
    "RetryPolicy",
    "TransientFault",
    "configure",
    "fault_point",
    "inject",
    "record",
    "registry",
    "reset",
    "snapshot",
]

#: The canonical fault points threaded through the runtime.  The registry
#: accepts arbitrary names (new subsystems can add points without touching
#: this module), but these are the ones the test suite and docs rely on.
POINTS = (
    "dispatch",
    "collective",
    "collective.inter",
    "kernel.segment",
    "kernel.hash",
    "prefetch.read",
    "checkpoint.write",
    "tuning.measure",
)

#: Terminal outcomes a supervisor can assign to an injected fault.
DISPOSITIONS = ("retried", "degraded", "escalated", "fatal", "absorbed")

ENV_VAR = "BLAZE_FAULTS"


class InjectedFault(RuntimeError):
    """Base of every injected failure.  ``point`` names the fault point,
    ``hit`` is the 1-based hit count at which the rule fired, and ``fatal``
    tells the supervisor whether retrying is allowed."""

    fatal = False

    def __init__(self, point: str, hit: int):
        kind = "fatal" if self.fatal else "transient"
        super().__init__(f"injected {kind} fault at {point!r} (hit {hit})")
        self.point = point
        self.hit = hit
        self._disposed = False


class TransientFault(InjectedFault):
    """An injected failure a supervisor may retry, degrade, or absorb."""

    fatal = False


class FatalFault(InjectedFault):
    """An injected failure that must propagate — the chaos suite uses it to
    simulate a process crash mid-run."""

    fatal = True


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounds for supervised dispatch: at most ``attempts`` tries, sleeping
    ``backoff_s * multiplier**k`` between them, never past ``deadline_s``
    from the first attempt (``None`` = no deadline)."""

    attempts: int = 3
    backoff_s: float = 0.005
    multiplier: float = 2.0
    deadline_s: float | None = 30.0

    def __post_init__(self):
        if self.attempts < 1:
            raise ValueError("attempts must be >= 1")
        if self.backoff_s < 0 or self.multiplier < 1.0:
            raise ValueError("backoff_s must be >= 0 and multiplier >= 1")


@dataclasses.dataclass
class FaultRule:
    """One armed trigger.  Exactly one of ``at`` / ``every`` / ``p`` should
    be set; ``times`` caps total firings (``None`` = unlimited)."""

    point: str
    at: int | None = None
    every: int | None = None
    p: float = 0.0
    times: int | None = None
    seed: int = 0
    fatal: bool = False
    fired: int = 0

    def __post_init__(self):
        modes = (self.at is not None) + (self.every is not None) + (self.p > 0)
        if modes != 1:
            raise ValueError(
                f"rule for {self.point!r} needs exactly one of at=/every=/p=, "
                f"got at={self.at} every={self.every} p={self.p}"
            )
        if self.at is not None and self.at < 1:
            raise ValueError("at= is a 1-based hit number")
        if self.every is not None and self.every < 1:
            raise ValueError("every= must be >= 1")
        if not (0.0 <= self.p <= 1.0):
            raise ValueError("p= must be in [0, 1]")
        # Rule-local RNG: seeded from (seed, point) so two rules with the
        # same seed on different points draw independent — but replayable —
        # schedules.
        self._rng = random.Random(
            (self.seed << 32) ^ zlib.crc32(self.point.encode())
        )

    def should_fire(self, hit: int) -> bool:
        if self.times is not None and self.fired >= self.times:
            return False
        if self.at is not None:
            return hit == self.at
        if self.every is not None:
            return hit % self.every == 0
        return self._rng.random() < self.p


class FaultRegistry:
    """Process-wide rule store, hit counters, and the recovery ledger.

    ``armed`` is a plain attribute read without the lock on the
    :func:`fault_point` fast path; it only ever flips under the lock.
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._rules: list[FaultRule] = []
        self._hits: dict[str, int] = {}
        self._injected: dict[str, int] = {}
        self._dispositions = dict.fromkeys(DISPOSITIONS, 0)
        self.armed = False

    # -- configuration ---------------------------------------------------

    def configure(self, point: str, **kw) -> FaultRule:
        """Arm a rule at ``point``; see :class:`FaultRule` for the knobs."""
        rule = FaultRule(point, **kw)
        with self._lock:
            self._rules.append(rule)
            self.armed = True
        return rule

    def remove(self, rule: FaultRule) -> None:
        with self._lock:
            if rule in self._rules:
                self._rules.remove(rule)
            self.armed = bool(self._rules)

    def reset(self, *, env: bool = True) -> None:
        """Drop every rule and counter, then re-arm from ``BLAZE_FAULTS``
        (unless ``env=False``)."""
        with self._lock:
            self._rules = []
            self._hits = {}
            self._injected = {}
            self._dispositions = dict.fromkeys(DISPOSITIONS, 0)
            self.armed = False
        if env:
            spec = os.environ.get(ENV_VAR, "")
            for point, kw in _parse_env(spec):
                self.configure(point, **kw)

    # -- firing ----------------------------------------------------------

    def fire(self, point: str) -> None:
        """Count a hit at ``point`` and raise if an armed rule triggers."""
        with self._lock:
            hit = self._hits.get(point, 0) + 1
            self._hits[point] = hit
            for rule in self._rules:
                if rule.point != point or not rule.should_fire(hit):
                    continue
                rule.fired += 1
                self._injected[point] = self._injected.get(point, 0) + 1
                cls = FatalFault if rule.fatal else TransientFault
                raise cls(point, hit)

    # -- ledger ----------------------------------------------------------

    def record(self, disposition: str, fault: BaseException) -> None:
        """Dispose an injected fault.  No-op for real (non-injected)
        exceptions and for faults already disposed — each injected fault
        counts exactly once, whichever supervisor saw it first."""
        if disposition not in DISPOSITIONS:
            raise ValueError(
                f"unknown disposition {disposition!r}; one of {DISPOSITIONS}"
            )
        if not isinstance(fault, InjectedFault):
            return
        with self._lock:
            if fault._disposed:
                return
            fault._disposed = True
            self._dispositions[disposition] += 1

    def snapshot(self) -> dict:
        """Counters + the conservation verdict, for ``/stats`` and tests."""
        with self._lock:
            injected = dict(self._injected)
            dispositions = dict(self._dispositions)
            total = sum(injected.values())
            disposed = sum(dispositions.values())
            return {
                "armed": self.armed,
                "rules": len(self._rules),
                "hits": dict(self._hits),
                "injected": injected,
                "injected_total": total,
                "dispositions": dispositions,
                "disposed_total": disposed,
                "balanced": total == disposed,
            }


def _parse_env(spec: str) -> list[tuple[str, dict]]:
    """``"dispatch:at=3;kernel.hash:p=0.1,seed=42,fatal"`` → rule kwargs."""
    rules = []
    for part in spec.split(";"):
        part = part.strip()
        if not part:
            continue
        point, _, conf = part.partition(":")
        point = point.strip()
        if not point:
            raise ValueError(f"{ENV_VAR}: empty fault point in {part!r}")
        kw: dict = {}
        for item in conf.split(","):
            item = item.strip()
            if not item:
                continue
            key, eq, val = item.partition("=")
            key = key.strip()
            if not eq:
                if key == "fatal":
                    kw["fatal"] = True
                    continue
                raise ValueError(f"{ENV_VAR}: bare flag {key!r} (only 'fatal')")
            val = val.strip()
            if key in ("at", "every", "times", "seed"):
                kw[key] = int(val)
            elif key == "p":
                kw[key] = float(val)
            elif key == "fatal":
                kw[key] = val.lower() in ("1", "true", "yes", "on")
            else:
                raise ValueError(f"{ENV_VAR}: unknown knob {key!r} in {part!r}")
        rules.append((point, kw))
    return rules


#: The process-wide registry every fault point consults.
registry = FaultRegistry()


def fault_point(name: str) -> None:
    """Hit the named fault point.  A no-op attribute check when nothing is
    armed; raises :class:`TransientFault` / :class:`FatalFault` when a rule
    triggers."""
    if not registry.armed:
        return
    registry.fire(name)


def configure(point: str, **kw) -> FaultRule:
    return registry.configure(point, **kw)


@contextlib.contextmanager
def inject(point: str, **kw):
    """Scoped injection: arm one rule, yield the registry, disarm on exit.
    Counters survive the block so tests can assert on :func:`snapshot`."""
    rule = registry.configure(point, **kw)
    try:
        yield registry
    finally:
        registry.remove(rule)


def record(disposition: str, fault: BaseException) -> None:
    registry.record(disposition, fault)


def reset(*, env: bool = True) -> None:
    registry.reset(env=env)


def snapshot() -> dict:
    return registry.snapshot()


# Arm from the environment at import, so `BLAZE_FAULTS=... pytest` works
# without any test-side setup.
if os.environ.get(ENV_VAR):
    registry.reset()
