"""BlazeSession — the long-lived driver context for iterative MapReduce.

The paper's wins on iterative data mining (PageRank, k-means, GMM/EM) come
from keeping the hot loop resident: pay lowering + compilation once per
(algorithm, shape) configuration, then run N iterations that only dispatch.
``BlazeSession`` is the seam that makes this true and observable:

* it **owns the mesh** — one 1-D ``data`` mesh per session by default, shared
  by every ``map_reduce`` it runs;
* it **memoizes compiled executables**, keyed on (source container spec,
  mapper identity, reducer, target spec, engine, wire, env spec) — the same
  key the engine builds in ``repro.core.mapreduce``.  Iteration-varying state
  (scores, centroids, mixture parameters) must flow through ``env`` so the
  key, and therefore the executable, stays fixed across iterations;
* it **counts compiles and cache hits** — cumulatively in ``session.stats``
  and per call in ``MapReduceStats.compiles`` / ``.cache_hits`` — so "10
  iterations, 1 compile per configuration" is an assertable property, not a
  docstring promise (see ``tests/test_session.py``).

The free function ``repro.core.map_reduce`` is a thin wrapper over a lazily
created process-wide default session, so existing one-shot code keeps
working; iterative drivers take an optional ``session=`` and algorithms
create/receive one explicitly.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import containers as C
from repro.core import mapreduce as _mr
from repro.core import plan as plan_mod
# The engine-resolution policy moved to repro.core.plan in PR 5 (it is the
# plan optimizer's resolve-engines pass, applied per node); these re-exports
# keep the long-standing session spellings working.
from repro.core.plan import ENGINES, PALLAS_AUTO_MAX_KEYS, resolve_engine
from repro.core.reducers import Reducer, get_reducer

__all__ = [
    "BlazeSession",
    "ENGINES",
    "PALLAS_AUTO_MAX_KEYS",
    "SessionStats",
    "get_default_session",
    "reset_default_session",
    "resolve",
    "resolve_engine",
    "set_default_session",
]


@dataclasses.dataclass
class SessionStats:
    """Cumulative executable-reuse + dispatch/sync counters for one session.

    ``dispatches`` and ``host_syncs`` make the fusion contract assertable:
    N per-op iterations cost ~3–4 dispatches and 1 host sync *each*, while
    ``run_loop`` over a fused program costs ≤ ⌈N/unroll⌉ of both.
    """

    calls: int = 0  # map_reduce invocations routed through the session
    compiles: int = 0  # calls that lowered + compiled a new executable
    cache_hits: int = 0  # calls served by a memoized executable
    dispatches: int = 0  # executable launches (per-op calls + program blocks)
    host_syncs: int = 0  # blocking host materialisations (host_value/cond)
    program_compiles: int = 0  # fused-program executables built
    program_dispatches: int = 0  # fused-program blocks launched

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / self.calls if self.calls else 0.0


class BlazeSession:
    """Owns a mesh and a compiled-executable cache for Blaze MapReduce.

    >>> sess = BlazeSession()
    >>> for _ in range(10):
    ...     scores = sess.map_reduce(edges, contrib_mapper, "sum",
    ...                              jnp.zeros((n,), jnp.float32), env=scores)
    >>> sess.stats.compiles   # 1 — nine of the ten calls reused it
    """

    def __init__(self, mesh: Mesh | None = None):
        self._mesh = mesh
        self._exec_cache: dict = {}
        self.stats = SessionStats()
        # Session state (exec cache, stats, program carries) is not safe to
        # mutate from concurrent threads.  Multi-threaded front-ends — the
        # serving layer's dispatcher, notably — serialize all session work
        # under this lock; single-threaded drivers never need to take it.
        self.lock = threading.RLock()

    @property
    def mesh(self) -> Mesh:
        """The session's mesh (built lazily over all visible devices)."""
        if self._mesh is None:
            self._mesh = C.data_mesh()
        return self._mesh

    # -- the paper's API, session-scoped ------------------------------------

    def map_reduce(
        self,
        source,
        mapper: Callable,
        reducer: str | Reducer,
        target,
        *,
        mesh: Mesh | None = None,
        engine: str = "eager",
        wire: str = "none",
        env: Any = None,
        shuffle_slack: float = 2.0,
        key_range: int | None = None,
        return_stats: bool = False,
    ):
        """Run one MapReduce op, reusing this session's compiled executables.

        Same contract as the free ``repro.core.map_reduce``; ``mesh``
        overrides the session mesh for this call only (the override is part
        of the cache key, so mixed-mesh sessions stay correct).  ``engine``
        is one of ``"eager" | "pallas" | "naive" | "auto"``; ``"auto"`` (and
        the custom-reducer fallback for ``"pallas"``) resolves via
        ``resolve_engine`` *before* the cache key is built, so the resolved
        engine — reported in ``MapReduceStats.engine`` — is what keys the
        executable.  ``key_range`` (hash targets only) promises keys lie in
        ``[0, key_range)``: the shuffle then ships narrowed bucket keys and
        the pallas kernel sizes its combine table by the distinct-key bound.

        Since PR 5 this path wraps the call in a single-node logical plan
        (``repro.core.plan``): the resolve-engines pass runs on the node, the
        executable cache is keyed on the node's cache signature, and
        ``MapReduceStats.plan_hash`` carries the node's stable digest — equal
        to the hash the same op gets inside a fused program.
        """
        red = get_reducer(reducer)
        mesh = mesh or self.mesh
        n_shards = mesh.shape[C.DATA_AXIS]
        kind = _mr._source_kind(source)
        node = plan_mod.build_mapreduce_node(
            idx=0, kind=kind, src=plan_mod.source_desc(kind, source),
            source_key=None, mapper=mapper, red=red, target=target,
            engine=engine, wire=wire, key_range=key_range, env=env,
        )
        engine = node.engine

        if isinstance(source, C.ChunkedDistVector):
            return self._map_reduce_chunked(
                source, mapper, red, target, mesh, n_shards, engine, wire,
                env, shuffle_slack, key_range, node, return_stats,
            )
        if isinstance(target, C.DistHashMap):
            out, stats = _mr._map_reduce_hash(
                kind, source, mapper, red, target, mesh, n_shards, engine,
                shuffle_slack, env, key_range=key_range,
                cache=self._exec_cache, node=node,
            )
        else:
            out, stats = _mr._map_reduce_dense(
                kind, source, mapper, red, jnp.asarray(target), mesh,
                n_shards, engine, wire, env, return_stats,
                cache=self._exec_cache, node=node,
            )
        self.stats.calls += 1
        self.stats.compiles += stats.compiles
        self.stats.cache_hits += stats.cache_hits
        self.stats.dispatches += stats.dispatches
        return (out, stats) if return_stats else out

    def _map_reduce_chunked(
        self, source, mapper, red, target, mesh, n_shards, engine, wire,
        env, shuffle_slack, key_range, node, return_stats, prefetch=True,
    ):
        """Out-of-core standalone map_reduce: one dispatch per block.

        Streams the chunked source block-at-a-time through ONE memoized
        executable (the ``BlockView``'s traced ``base`` keeps the cache key
        fixed across blocks), merging each block's locally-reduced result
        into the running target — the paper's merged-into target semantics
        make block accumulation free.  Block k+1 is prefetched (disk read /
        decompress / host→device transfer on a background thread) while
        block k reduces.
        """
        import dataclasses as _dc

        from repro.data.pipeline import prefetch_iter

        hash_target = isinstance(target, C.DistHashMap)
        out = target if hash_target else jnp.asarray(target)
        emitted = shipped = payload = 0
        compiles = cache_hits = 0
        last_stats = None

        def produce(b):
            return source.block_view(b, mesh)

        blocks = (
            prefetch_iter(produce, range(source.n_blocks), depth=2)
            if prefetch
            else ((b, produce(b)) for b in range(source.n_blocks))
        )
        for _b, bv in blocks:
            if hash_target:
                out, st = _mr._map_reduce_hash(
                    "chunked", bv, mapper, red, out, mesh, n_shards, engine,
                    shuffle_slack, env, key_range=key_range,
                    cache=self._exec_cache, node=node,
                )
            else:
                out, st = _mr._map_reduce_dense(
                    "chunked", bv, mapper, red, out, mesh, n_shards, engine,
                    wire, env, return_stats, cache=self._exec_cache,
                    node=node,
                )
            emitted = emitted + st.pairs_emitted
            shipped = shipped + st.pairs_shipped
            payload = payload + st.shuffle_payload_bytes
            compiles += st.compiles
            cache_hits += st.cache_hits
            last_stats = st
        stats = _dc.replace(
            last_stats,
            pairs_emitted=emitted,
            pairs_shipped=shipped,
            shuffle_payload_bytes=payload,
            compiles=compiles,
            cache_hits=cache_hits,
            dispatches=source.n_blocks,
        )
        self.stats.calls += 1
        self.stats.compiles += stats.compiles
        self.stats.cache_hits += stats.cache_hits
        self.stats.dispatches += stats.dispatches
        return (out, stats) if return_stats else out

    # -- fused iteration programs (see repro.core.program) -------------------

    def program(self, step_fn: Callable, *, mesh=None, passes=None):
        """Lower ``step_fn(ctx, state) -> state`` — a whole iteration of
        MapReduce ops plus elementwise glue — into ONE optimized executable.

        ``ctx`` mirrors the session API in-trace (``ctx.map_reduce``,
        ``ctx.foreach``, ``ctx.topk``); iteration-varying values go through
        ``state`` (a pytree that must keep its structure/shapes across
        steps).  Discovery builds an explicit logical plan
        (``repro.core.plan``) and runs the optimizer passes on it — per-node
        engine resolution, collective batching, CSE, dead-source pruning;
        ``passes=()`` disables the optional three for A/B comparisons.  Run
        the result with ``program(state, n_iters)`` or ``run_loop``; render
        the plan with ``session.explain(program)``.
        """
        from repro.core.program import Program

        return Program(self, step_fn, mesh=mesh or self.mesh, passes=passes)

    def explain(self, program, state=None) -> str:
        """Render ``program``'s optimized logical plan, Spark-EXPLAIN-style:
        nodes with resolved engines and wire dtypes, the source table,
        batched collective groups, CSE/prune effects and the plan hash.

        The plan is built lazily per state signature; pass ``state`` to
        build it without dispatching (cheap — compilation stays lazy under
        jit), or call after the program has run at least once.
        """
        plan = program.build(state) if state is not None else program.plan
        if plan is None:
            raise ValueError(
                "program has no plan yet — pass state= (or dispatch it once)"
            )
        return plan.render()

    def run_loop(
        self,
        program,
        state,
        *,
        cond: Callable | None = None,
        max_iters: int,
        unroll: int = 1,
    ):
        """Drive a fused ``Program``: ``unroll`` iterations per dispatch.

        Each dispatch runs a device-resident ``fori_loop`` block; the
        convergence test ``cond(state) -> bool`` (truthy = converged, stop)
        is evaluated on the host only *between* blocks — one host sync per
        ``unroll`` iterations instead of one per iteration.  Returns
        ``(state, LoopInfo)``; ``LoopInfo`` carries the assertable counters
        (iterations, dispatches, host_syncs, compiles).
        """
        from repro.core.program import LoopInfo

        if unroll < 1:
            raise ValueError(f"unroll must be >= 1, got {unroll}")
        compiles0 = program.stats.compiles
        it = dispatches = host_syncs = 0
        converged = False
        while it < max_iters:
            u = min(unroll, max_iters - it)
            state = program(state, u)
            dispatches += 1
            it += u
            if cond is not None:
                self.stats.host_syncs += 1
                host_syncs += 1
                if bool(cond(state)):
                    converged = True
                    break
        return state, LoopInfo(
            iterations=it,
            dispatches=dispatches,
            host_syncs=host_syncs,
            converged=converged,
            compiles=program.stats.compiles - compiles0,
        )

    def run_stream(
        self,
        program,
        state,
        *,
        cond: Callable | None = None,
        max_epochs: int = 1,
        prefetch: bool = True,
        depth: int = 2,
    ):
        """Drive a fused ``Program`` over its chunked (out-of-core) sources.

        The ``run_loop`` analogue one level down the memory hierarchy: each
        *epoch* streams every host-resident block through the program's ONE
        executable (block k+1 prefetched while block k reduces), and
        ``cond(state)`` is evaluated once per epoch.  Returns
        ``(state, StreamInfo)``.
        """
        return program.run_stream(
            state, max_epochs=max_epochs, cond=cond, prefetch=prefetch,
            depth=depth,
        )

    def host_value(self, x):
        """Materialise ``x`` on the host (the driver's explicit sync point),
        counting it in ``stats.host_syncs`` so per-op loops and fused
        ``run_loop`` blocks are comparable."""
        self.stats.host_syncs += 1
        return jax.device_get(x)

    def foreach(self, v: C.DistVector, fn: Callable, env: Any = None) -> C.DistVector:
        """Session-scoped ``foreach`` (same executable-reuse contract via
        ``env``; the elementwise cache is shared process-wide)."""
        return C.foreach(v, fn, env=env)

    def topk(
        self, v: C.DistVector, k: int, score_fn: Callable | None = None,
        env: Any = None, mesh: Mesh | None = None,
    ):
        """Session-scoped ``topk``: selects on-device, then materialises the
        ``k·n_shards`` candidates on the host — a blocking sync, counted in
        ``stats.host_syncs`` (drivers that bypassed this used to undercount;
        see ``knn``)."""
        self.stats.host_syncs += 1
        return C.topk(v, k, score_fn=score_fn, mesh=mesh or self.mesh, env=env)

    def distribute(self, x, mesh: Mesh | None = None) -> C.DistVector:
        """``distribute`` onto this session's mesh."""
        return C.distribute(x, mesh or self.mesh)

    def chunked(
        self, x, block_rows: int, mesh: Mesh | None = None, **kwargs
    ) -> C.ChunkedDistVector:
        """``distribute`` for datasets that don't fit on device: host array →
        out-of-core blocks on this session's mesh (``compress=`` /
        ``spill_dir=`` / ``max_resident=`` control the byte provider)."""
        return C.chunked(x, block_rows, mesh or self.mesh, **kwargs)

    # -- observability -------------------------------------------------------

    def cache_info(self) -> dict:
        """Executable-cache snapshot: entries + cumulative counters."""
        return {
            "entries": len(self._exec_cache),
            "calls": self.stats.calls,
            "compiles": self.stats.compiles,
            "cache_hits": self.stats.cache_hits,
            "hit_rate": self.stats.hit_rate,
            "dispatches": self.stats.dispatches,
            "host_syncs": self.stats.host_syncs,
            "program_compiles": self.stats.program_compiles,
            "program_dispatches": self.stats.program_dispatches,
        }

    def clear_cache(self) -> None:
        """Drop all memoized executables (counters keep accumulating)."""
        self._exec_cache.clear()


# -- process-wide default session --------------------------------------------

_default_lock = threading.Lock()
_default_session: BlazeSession | None = None


def get_default_session() -> BlazeSession:
    """The lazily created session backing the free ``map_reduce``."""
    global _default_session
    if _default_session is None:
        with _default_lock:
            if _default_session is None:
                _default_session = BlazeSession()
    return _default_session


def set_default_session(session: BlazeSession) -> BlazeSession | None:
    """Install ``session`` as the process default; returns the previous one."""
    global _default_session
    with _default_lock:
        prev, _default_session = _default_session, session
    return prev


def reset_default_session() -> None:
    """Forget the default session (a fresh one is built on next use)."""
    global _default_session
    with _default_lock:
        _default_session = None


def resolve(
    session: BlazeSession | None, mesh: Mesh | None
) -> tuple[BlazeSession, Mesh]:
    """(session or default, mesh or session's) — the driver entry idiom."""
    sess = session if session is not None else get_default_session()
    return sess, (mesh or sess.mesh)
