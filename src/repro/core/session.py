"""BlazeSession — the long-lived driver context for iterative MapReduce.

The paper's wins on iterative data mining (PageRank, k-means, GMM/EM) come
from keeping the hot loop resident: pay lowering + compilation once per
(algorithm, shape) configuration, then run N iterations that only dispatch.
``BlazeSession`` is the seam that makes this true and observable:

* it **owns the mesh** — one 1-D ``data`` mesh per session by default, shared
  by every ``map_reduce`` it runs;
* it **memoizes compiled executables**, keyed on (source container spec,
  mapper identity, reducer, target spec, engine, wire, env spec) — the same
  key the engine builds in ``repro.core.mapreduce``.  Iteration-varying state
  (scores, centroids, mixture parameters) must flow through ``env`` so the
  key, and therefore the executable, stays fixed across iterations;
* it **counts compiles and cache hits** — cumulatively in ``session.stats``
  and per call in ``MapReduceStats.compiles`` / ``.cache_hits`` — so "10
  iterations, 1 compile per configuration" is an assertable property, not a
  docstring promise (see ``tests/test_session.py``).

The free function ``repro.core.map_reduce`` is a thin wrapper over a lazily
created process-wide default session, so existing one-shot code keeps
working; iterative drivers take an optional ``session=`` and algorithms
create/receive one explicitly.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import containers as C
from repro.core import cost as cost_mod
from repro.core import faults
from repro.core import mapreduce as _mr
from repro.core import plan as plan_mod
# The engine-resolution policy moved to repro.core.plan in PR 5 (it is the
# plan optimizer's resolve-engines pass, applied per node); these re-exports
# keep the long-standing session spellings working.
from repro.core.plan import ENGINES, PALLAS_AUTO_MAX_KEYS, resolve_engine
from repro.core.reducers import Reducer, get_reducer

__all__ = [
    "BlazeSession",
    "ENGINES",
    "PALLAS_AUTO_MAX_KEYS",
    "SessionStats",
    "get_default_session",
    "reset_default_session",
    "resolve",
    "resolve_engine",
    "set_default_session",
]


@dataclasses.dataclass
class SessionStats:
    """Cumulative executable-reuse + dispatch/sync counters for one session.

    ``dispatches`` and ``host_syncs`` make the fusion contract assertable:
    N per-op iterations cost ~3–4 dispatches and 1 host sync *each*, while
    ``run_loop`` over a fused program costs ≤ ⌈N/unroll⌉ of both.
    """

    calls: int = 0  # map_reduce invocations routed through the session
    compiles: int = 0  # calls that lowered + compiled a new executable
    cache_hits: int = 0  # calls served by a memoized executable
    dispatches: int = 0  # executable launches (per-op calls + program blocks)
    host_syncs: int = 0  # blocking host materialisations (host_value/cond)
    program_compiles: int = 0  # fused-program executables built
    program_dispatches: int = 0  # fused-program blocks launched
    tune_measurements: int = 0  # candidate configs timed by the autotuner
    retries: int = 0  # transient-fault dispatches re-attempted
    degraded_nodes: int = 0  # pallas nodes demoted to eager after a kernel fault
    escalations: int = 0  # hash targets regrown after overflow

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / self.calls if self.calls else 0.0


# Default supervision policy: 3 attempts, 5 ms initial backoff, 30 s deadline.
# A module-level constant (not a fresh instance per session) so the default is
# introspectable and tests can compare against it.
_DEFAULT_RETRY = faults.RetryPolicy()


class BlazeSession:
    """Owns a mesh and a compiled-executable cache for Blaze MapReduce.

    >>> sess = BlazeSession()
    >>> for _ in range(10):
    ...     scores = sess.map_reduce(edges, contrib_mapper, "sum",
    ...                              jnp.zeros((n,), jnp.float32), env=scores)
    >>> sess.stats.compiles   # 1 — nine of the ten calls reused it
    """

    def __init__(
        self, mesh: Mesh | None = None, *, tuning_path: str | None = None,
        retry: faults.RetryPolicy | None = _DEFAULT_RETRY,
        escalate_overflow: bool = False, max_escalations: int = 3,
    ):
        self._mesh = mesh
        self._exec_cache: dict = {}
        self.stats = SessionStats()
        # Supervision: every dispatch the session issues (per-op, chunked
        # block, fused-program block, served batch) runs under ``retry`` —
        # transient faults are re-attempted with exponential backoff, kernel
        # faults demote the node's engine to eager, and (with
        # ``escalate_overflow=True``) hash overflow regrows the target along
        # the cost grid.  Escalation is opt-in because counted-and-dropped
        # overflow is itself a documented contract (see the differential
        # tests' near-capacity invariants).  ``retry=None`` disables
        # supervision (dispatch exceptions propagate raw, as before PR 9).
        self.retry = retry
        self.escalate_overflow = escalate_overflow
        self.max_escalations = max_escalations
        # tune_keys of nodes demoted to eager after a pallas kernel fault.
        # Consulted by every node build (per-op, program discovery, serve),
        # so a node degraded once stays degraded for the session — and its
        # eager executable caches under a *different* signature, leaving the
        # faulted pallas entry's cache slots untouched (no poisoning).
        self._degraded: set = set()
        # Measured autotuning winners, keyed by node plan-hash.  Populated by
        # tune=True dispatches; consulted by EVERY node build (per-op,
        # program discovery, serve), so a winner measured once is reused by
        # all later dispatches of the same plan.  ``tuning_path`` preloads a
        # cache persisted beside checkpoints (``save_tuning``).
        self.tuning = cost_mod.TuningCache()
        self._tuning_path = tuning_path
        if tuning_path and os.path.exists(tuning_path):
            self.tuning.load(tuning_path)
        # Session state (exec cache, stats, program carries) is not safe to
        # mutate from concurrent threads.  Multi-threaded front-ends — the
        # serving layer's dispatcher, notably — serialize all session work
        # under this lock; single-threaded drivers never need to take it.
        self.lock = threading.RLock()

    @property
    def mesh(self) -> Mesh:
        """The session's mesh (built lazily over all visible devices)."""
        if self._mesh is None:
            self._mesh = C.data_mesh()
        return self._mesh

    # -- the paper's API, session-scoped ------------------------------------

    def map_reduce(
        self,
        source,
        mapper: Callable,
        reducer: str | Reducer,
        target,
        *,
        mesh: Mesh | None = None,
        engine: str = "eager",
        wire: str = "none",
        env: Any = None,
        shuffle_slack: float = 2.0,
        key_range: int | None = None,
        return_stats: bool = False,
        tune: bool = False,
        hierarchical: bool = True,
    ):
        """Run one MapReduce op, reusing this session's compiled executables.

        Same contract as the free ``repro.core.map_reduce``; ``mesh``
        overrides the session mesh for this call only (the override is part
        of the cache key, so mixed-mesh sessions stay correct).  ``engine``
        is one of ``"eager" | "pallas" | "naive" | "auto"``; ``"auto"`` (and
        the custom-reducer fallback for ``"pallas"``) resolves via
        ``resolve_engine`` *before* the cache key is built, so the resolved
        engine — reported in ``MapReduceStats.engine`` — is what keys the
        executable.  ``key_range`` (hash targets only) promises keys lie in
        ``[0, key_range)``: the shuffle then ships narrowed bucket keys and
        the pallas kernel sizes its combine table by the distinct-key bound.

        Since PR 5 this path wraps the call in a single-node logical plan
        (``repro.core.plan``): the resolve-engines pass runs on the node, the
        executable cache is keyed on the node's cache signature, and
        ``MapReduceStats.plan_hash`` carries the node's stable digest — equal
        to the hash the same op gets inside a fused program.

        ``tune=True`` enables first-dispatch autotuning: if this node's plan
        hash has no measured winner yet, a small candidate grid (engine ∈
        {eager, pallas} × kernel block/capacity configs from the shared
        ``cost`` grids) is timed once, and the winner is cached in
        ``session.tuning`` — every later dispatch of the same plan (tuned or
        not, per-op or inside a program) reuses it.

        On a multi-node ``("node", "data")`` mesh the
        ``hierarchical-collectives`` pass rewrites eligible dense reductions
        to the topology-aware two-hop plan (intra-node full precision,
        inter-node wire-compressed); ``hierarchical=False`` keeps the flat
        collective — the A/B baseline ``benchmarks/bench10_scaling.py``
        measures against.  A no-op on 1-D meshes either way.
        """
        red = get_reducer(reducer)
        mesh = mesh or self.mesh
        n_shards = C.shard_count(mesh)
        kind = _mr._source_kind(source)
        node = plan_mod.build_mapreduce_node(
            idx=0, kind=kind, src=plan_mod.source_desc(kind, source),
            source_key=None, mapper=mapper, red=red, target=target,
            engine=engine, wire=wire, key_range=key_range, env=env,
            tuning=self.tuning, degraded=self._degraded,
            n_nodes=C.n_nodes(mesh), hierarchical=hierarchical,
        )
        if (
            tune
            and node.tuned is None
            and kind != "chunked"
            and self._tunable(node, red, target)
        ):
            self._tune_map_reduce(
                kind, source, mapper, red, target, mesh, n_shards, wire,
                env, shuffle_slack, key_range, node,
            )
            cfg = self.tuning.peek(node.tune_key)
            if cfg is not None:
                plan_mod.apply_tuned(node, red, cfg)
        engine = node.engine

        if isinstance(source, C.ChunkedDistVector):
            return self._map_reduce_chunked(
                source, mapper, red, target, mesh, n_shards, engine, wire,
                env, shuffle_slack, key_range, node, return_stats,
            )
        if isinstance(target, C.DistHashMap):
            def dispatch_hash(tgt):
                return _mr._map_reduce_hash(
                    kind, source, mapper, red, tgt, mesh, n_shards,
                    node.engine, shuffle_slack, env, key_range=key_range,
                    cache=self._exec_cache, node=node, tuned=node.tuned,
                )

            out, stats = self._dispatch_supervised(
                lambda: dispatch_hash(target), node
            )
            out, stats = self._maybe_escalate(
                out, stats, target, red, node, dispatch_hash
            )
        else:
            out, stats = self._dispatch_supervised(
                lambda: _mr._map_reduce_dense(
                    kind, source, mapper, red, jnp.asarray(target), mesh,
                    n_shards, node.engine, wire, env, return_stats,
                    cache=self._exec_cache, node=node, tuned=node.tuned,
                    hier=node.hier,
                ),
                node,
            )
        self.stats.calls += 1
        self.stats.compiles += stats.compiles
        self.stats.cache_hits += stats.cache_hits
        self.stats.dispatches += stats.dispatches
        return (out, stats) if return_stats else out

    def _map_reduce_chunked(
        self, source, mapper, red, target, mesh, n_shards, engine, wire,
        env, shuffle_slack, key_range, node, return_stats, prefetch=True,
    ):
        """Out-of-core standalone map_reduce: one dispatch per block.

        Streams the chunked source block-at-a-time through ONE memoized
        executable (the ``BlockView``'s traced ``base`` keeps the cache key
        fixed across blocks), merging each block's locally-reduced result
        into the running target — the paper's merged-into target semantics
        make block accumulation free.  Block k+1 is prefetched (disk read /
        decompress / host→device transfer on a background thread) while
        block k reduces.
        """
        import dataclasses as _dc

        from repro.data.pipeline import prefetch_iter

        hash_target = isinstance(target, C.DistHashMap)
        out = target if hash_target else jnp.asarray(target)
        emitted = shipped = payload = intra = inter = 0
        compiles = cache_hits = retries = 0
        last_stats = None

        def produce(b):
            return source.block_view(b, mesh)

        blocks = (
            prefetch_iter(produce, range(source.n_blocks), depth=2)
            if prefetch
            else ((b, produce(b)) for b in range(source.n_blocks))
        )
        for _b, bv in blocks:
            if hash_target:
                out, st = self._dispatch_supervised(
                    lambda bv=bv, out=out: _mr._map_reduce_hash(
                        "chunked", bv, mapper, red, out, mesh, n_shards,
                        node.engine, shuffle_slack, env, key_range=key_range,
                        cache=self._exec_cache, node=node, tuned=node.tuned,
                    ),
                    node,
                )
            else:
                out, st = self._dispatch_supervised(
                    lambda bv=bv, out=out: _mr._map_reduce_dense(
                        "chunked", bv, mapper, red, out, mesh, n_shards,
                        node.engine, wire, env, return_stats,
                        cache=self._exec_cache, node=node, tuned=node.tuned,
                        hier=node.hier,
                    ),
                    node,
                )
            emitted = emitted + st.pairs_emitted
            shipped = shipped + st.pairs_shipped
            payload = payload + st.shuffle_payload_bytes
            intra = intra + st.intra_bytes
            inter = inter + st.inter_bytes
            compiles += st.compiles
            cache_hits += st.cache_hits
            retries += st.retries
            last_stats = st
        stats = _dc.replace(
            last_stats,
            pairs_emitted=emitted,
            pairs_shipped=shipped,
            shuffle_payload_bytes=payload,
            intra_bytes=intra,
            inter_bytes=inter,
            compiles=compiles,
            cache_hits=cache_hits,
            retries=retries,
            dispatches=source.n_blocks,
        )
        self.stats.calls += 1
        self.stats.compiles += stats.compiles
        self.stats.cache_hits += stats.cache_hits
        self.stats.dispatches += stats.dispatches
        return (out, stats) if return_stats else out

    # -- supervised dispatch (fault recovery) --------------------------------

    def supervised(self, attempt: Callable, *, program=None):
        """Run one dispatch ``attempt()`` under the session's retry policy.

        The recovery state machine (see docs/architecture.md):

        * ``faults.FatalFault`` — recorded and re-raised immediately;
        * a *kernel* fault (injected ``kernel.*``, or any real exception
          while a pallas node is live) — if ``program`` is given and still
          has pallas nodes, those nodes are demoted to eager
          (``program.degrade()``) and the dispatch re-attempted.  Live carry
          is preserved: all fault points fire before the executable runs, so
          the retry replays the exact same block;
        * any other ``faults.TransientFault`` — re-attempted up to
          ``retry.attempts`` times with exponential backoff, bounded by
          ``retry.deadline_s``; exhaustion records the fault as fatal and
          re-raises.

        Every injected fault is recorded in ``faults.registry`` under exactly
        one disposition, so the chaos suite's conservation law
        (injected == retried + degraded + escalated + fatal + absorbed)
        holds across any schedule.
        """
        policy = self.retry
        if policy is None:
            return attempt()
        t0 = time.monotonic()
        delay = policy.backoff_s
        tries = 0
        while True:
            try:
                return attempt()
            except faults.FatalFault as e:
                faults.record("fatal", e)
                raise
            except Exception as e:  # noqa: BLE001 — classified below
                transient = isinstance(e, faults.TransientFault)
                kernel = transient and e.point.startswith("kernel.")
                real = not isinstance(e, faults.InjectedFault)
                if (kernel or real) and program is not None:
                    if program.degrade() > 0:
                        faults.record("degraded", e)
                        self.stats.degraded_nodes += 1
                        continue
                if not transient:
                    raise
                tries += 1
                deadline_hit = (
                    policy.deadline_s is not None
                    and time.monotonic() - t0 + delay > policy.deadline_s
                )
                if tries >= policy.attempts or deadline_hit:
                    faults.record("fatal", e)
                    raise
                faults.record("retried", e)
                self.stats.retries += 1
                if delay > 0:
                    time.sleep(delay)
                delay *= policy.multiplier

    def _degrade_op_node(self, node, e) -> None:
        """Demote a per-op node to eager after a kernel fault.

        The tune_key lands in ``self._degraded`` so every later build of the
        same logical node (per-op, program, serve) is born degraded; the
        faulted pallas executable's cache entry is dropped, and the eager
        rebuild caches under the node's *new* signature (engine is part of
        ``stable_desc``), so the pallas entry can never be served again —
        and nothing else in the cache is touched.
        """
        self._degraded.add(node.tune_key)
        if node.cache_sig is not None:
            self._exec_cache.pop(node.cache_sig, None)
        plan_mod.degrade_node(node)
        faults.record("degraded", e)
        self.stats.degraded_nodes += 1

    def _dispatch_supervised(self, dispatch: Callable, node):
        """``supervised`` specialised to one per-op node: kernel faults
        degrade just this node (not a whole program) and the returned
        ``MapReduceStats`` carries the recovery provenance
        (``degraded_engine``, ``retries``)."""
        policy = self.retry
        if policy is None:
            return dispatch()
        t0 = time.monotonic()
        delay = policy.backoff_s
        tries = retries = 0
        while True:
            try:
                out, stats = dispatch()
                if retries or node.degraded_from is not None:
                    stats = dataclasses.replace(
                        stats, retries=retries,
                        degraded_engine=node.degraded_from,
                    )
                return out, stats
            except faults.FatalFault as e:
                faults.record("fatal", e)
                raise
            except Exception as e:  # noqa: BLE001 — classified below
                transient = isinstance(e, faults.TransientFault)
                kernel = transient and e.point.startswith("kernel.")
                real = not isinstance(e, faults.InjectedFault)
                if (kernel or real) and node.engine == "pallas":
                    self._degrade_op_node(node, e)
                    continue
                if not transient:
                    raise
                tries += 1
                deadline_hit = (
                    policy.deadline_s is not None
                    and time.monotonic() - t0 + delay > policy.deadline_s
                )
                if tries >= policy.attempts or deadline_hit:
                    faults.record("fatal", e)
                    raise
                faults.record("retried", e)
                self.stats.retries += 1
                retries += 1
                if delay > 0:
                    time.sleep(delay)
                delay *= policy.multiplier

    def _maybe_escalate(self, out, stats, target, red, node, dispatch):
        """Hash-overflow recovery: if the dispatch dropped pairs (overflow
        grew), regrow the target to the next capacity on the cost grid and
        re-dispatch the *same* op against the grown original.

        ``map_reduce`` is functional (merged-into-target returns a NEW
        container) and ``shard_of_key`` is capacity-independent, so the
        re-dispatch is exact — the failed output is simply discarded.
        Bounded by ``max_escalations``; each round is counted in
        ``MapReduceStats.escalations`` and ``session.stats.escalations``.
        """
        if self.retry is None or not self.escalate_overflow:
            return out, stats
        base = target.total_overflow()
        new = out.total_overflow()
        escal = 0
        cur = target
        while new > base and escal < self.max_escalations:
            cap = cost_mod.next_capacity(cur.capacity_per_shard)
            if cap is None:
                break
            cur = self._grow_hash_target(cur, cap, red)
            escal += 1
            out, st = self._dispatch_supervised(
                lambda tgt=cur: dispatch(tgt), node
            )
            stats = dataclasses.replace(
                st,
                escalations=escal,
                compiles=stats.compiles + st.compiles,
                cache_hits=stats.cache_hits + st.cache_hits,
                dispatches=stats.dispatches + st.dispatches,
                retries=stats.retries + st.retries,
            )
            base = cur.total_overflow()
            new = out.total_overflow()
        if escal:
            self.stats.escalations += escal
        return out, stats

    def _grow_hash_target(self, target: C.DistHashMap, new_cap: int, red):
        """Rebuild ``target`` with ``new_cap`` slots per shard, re-inserting
        every live entry on its original shard (``shard_of_key`` does not
        depend on capacity, so entries never migrate between shards).
        Historical per-shard overflow counters are carried over so the
        caller's overflow-delta test sees only *new* drops."""
        keys = np.asarray(jax.device_get(target.table.keys))
        vals = np.asarray(jax.device_get(target.table.vals))
        ovf = np.asarray(jax.device_get(target.table.overflow))
        val_shape = vals.shape[2:]
        grown = C.make_dist_hashmap(
            self.mesh, new_cap, val_shape=val_shape,
            val_dtype=target.table.vals.dtype, reducer=red.name,
        )
        nk = np.array(jax.device_get(grown.table.keys))
        nv = np.array(jax.device_get(grown.table.vals))
        no = np.array(jax.device_get(grown.table.overflow))
        for s in range(target.n_shards):
            valid = keys[s] != C.EMPTY_KEY
            if not valid.any():
                no[s] = no[s] + ovf[s]
                continue
            t = C.hashmap_insert(
                C.HashTable(
                    jnp.asarray(nk[s]), jnp.asarray(nv[s]),
                    jnp.asarray(no[s]),
                ),
                jnp.asarray(keys[s]), jnp.asarray(vals[s]),
                jnp.asarray(valid), red, max_probes=64,
            )
            nk[s] = np.asarray(jax.device_get(t.keys))
            nv[s] = np.asarray(jax.device_get(t.vals))
            no[s] = np.asarray(jax.device_get(t.overflow)) + ovf[s]
        table = C.HashTable(
            jax.device_put(jnp.asarray(nk), grown.table.keys.sharding),
            jax.device_put(jnp.asarray(nv), grown.table.vals.sharding),
            jax.device_put(jnp.asarray(no), grown.table.overflow.sharding),
        )
        return dataclasses.replace(grown, table=table)

    # -- measured autotuning (tune=True) -------------------------------------

    @staticmethod
    def _tunable(node, red: Reducer, target) -> bool:
        """Nodes the measured autotuner can act on: a builtin reducer whose
        kernel exists for the target kind, and no ``naive`` request (naive is
        a benchmarking baseline, not a candidate)."""
        kernel = (
            red.pallas_hash
            if isinstance(target, C.DistHashMap)
            else red.pallas_segment
        )
        return kernel is not None and node.engine_requested != "naive"

    def _candidates_for(self, red: Reducer, target, key_range):
        """The measurement grid for one node, off the shared cost grids."""
        if isinstance(target, C.DistHashMap):
            val_shape = target.table.vals.shape[2:]
            v = int(np.prod(val_shape)) if val_shape else 1
            return cost_mod.hash_tuning_candidates(
                v, red.name, target.table.vals.dtype, key_range=key_range
            )
        t = jnp.asarray(target)
        k = t.shape[0] if t.ndim else 0
        v = int(np.prod(t.shape[1:])) if t.ndim > 1 else 1
        return cost_mod.dense_tuning_candidates(k, v, red.name, t.dtype)

    def _tune_map_reduce(
        self, kind, source, mapper, red, target, mesh, n_shards, wire, env,
        shuffle_slack, key_range, node,
    ):
        """Time the candidate grid for ``node`` and cache the winner.

        Each candidate is dispatched twice — once to compile + warm, once
        timed to completion (``block_until_ready``) — through the normal
        engine entry points, so candidate executables land in the session's
        executable cache and the winning config's executable is already warm
        for the real dispatch that follows.  ``map_reduce`` is functional
        (the target is merged into a *new* container), so the measurement
        outputs are simply discarded.
        """
        hash_target = isinstance(target, C.DistHashMap)
        candidates = self._candidates_for(red, target, key_range)
        best_cfg, best_wall = None, float("inf")
        measured = 0
        for cfg in candidates:
            tuned = cfg if cfg.engine == "pallas" else None

            def run():
                if hash_target:
                    return _mr._map_reduce_hash(
                        kind, source, mapper, red, target, mesh, n_shards,
                        cfg.engine, shuffle_slack, env, key_range=key_range,
                        cache=self._exec_cache, tuned=tuned,
                    )
                return _mr._map_reduce_dense(
                    kind, source, mapper, red, jnp.asarray(target), mesh,
                    n_shards, cfg.engine, wire, env, False,
                    cache=self._exec_cache, tuned=tuned, hier=node.hier,
                )

            try:
                faults.fault_point("tuning.measure")
                out, st = run()  # compile + warm
                leaves = (
                    (out.table.keys, out.table.vals, out.table.overflow)
                    if hash_target
                    else out
                )
                jax.block_until_ready(leaves)
                t0 = time.perf_counter()
                out, st2 = run()
                leaves = (
                    (out.table.keys, out.table.vals, out.table.overflow)
                    if hash_target
                    else out
                )
                jax.block_until_ready(leaves)
                wall = time.perf_counter() - t0
            except faults.InjectedFault as e:
                # A faulted measurement just loses the race — the candidate
                # is skipped, nothing retries, and the ledger records the
                # injection as absorbed.
                faults.record("absorbed", e)
                continue
            except Exception:  # noqa: BLE001 — a failed candidate just loses
                continue
            measured += 1
            self.stats.compiles += st.compiles + st2.compiles
            self.stats.cache_hits += st.cache_hits + st2.cache_hits
            if wall < best_wall:
                best_cfg, best_wall = cfg, wall
        self.tuning.record_measurements(measured)
        self.stats.tune_measurements += measured
        if best_cfg is not None:
            self.tuning.put(
                node.tune_key,
                dataclasses.replace(
                    best_cfg, source="measured", wall_s=best_wall
                ),
            )

    def save_tuning(self, path: str | None = None) -> str:
        """Persist the tuning cache (JSON, atomic) — call it beside your
        checkpoint writes.  Defaults to the session's ``tuning_path``."""
        path = path or self._tuning_path
        if not path:
            raise ValueError("no path given and session has no tuning_path")
        self.tuning.save(path)
        return path

    def load_tuning(self, path: str | None = None) -> int:
        """Merge a persisted tuning cache into this session; returns the
        number of entries loaded."""
        path = path or self._tuning_path
        if not path:
            raise ValueError("no path given and session has no tuning_path")
        return self.tuning.load(path)

    # -- fused iteration programs (see repro.core.program) -------------------

    def program(self, step_fn: Callable, *, mesh=None, passes=None,
                tune: bool = False, hierarchical: bool = True):
        """Lower ``step_fn(ctx, state) -> state`` — a whole iteration of
        MapReduce ops plus elementwise glue — into ONE optimized executable.

        ``ctx`` mirrors the session API in-trace (``ctx.map_reduce``,
        ``ctx.foreach``, ``ctx.topk``); iteration-varying values go through
        ``state`` (a pytree that must keep its structure/shapes across
        steps).  Discovery builds an explicit logical plan
        (``repro.core.plan``) and runs the optimizer passes on it — per-node
        engine resolution, collective batching, CSE, dead-source pruning;
        ``passes=()`` disables the optional three for A/B comparisons, and
        ``hierarchical=False`` keeps collectives flat on a multi-node mesh
        (the scaling bench's baseline).  Run the result with
        ``program(state, n_iters)`` or ``run_loop``; render the plan with
        ``session.explain(program)``.

        ``tune=True``: on the program's first build, any tunable node without
        a measured winner triggers one measurement sweep — throwaway program
        variants with candidate engine/kernel configs are each dispatched for
        one timed iteration, and the per-node winners land in
        ``session.tuning``, shared with every later program, per-op call and
        BlazeServe query over the same plan.
        """
        from repro.core.program import Program

        return Program(
            self, step_fn, mesh=mesh or self.mesh, passes=passes, tune=tune,
            hierarchical=hierarchical,
        )

    def explain(self, program, state=None) -> str:
        """Render ``program``'s optimized logical plan, Spark-EXPLAIN-style:
        nodes with resolved engines and wire dtypes, the source table,
        batched collective groups, CSE/prune effects and the plan hash.

        The plan is built lazily per state signature; pass ``state`` to
        build it without dispatching (cheap — compilation stays lazy under
        jit), or call after the program has run at least once.
        """
        plan = program.build(state) if state is not None else program.plan
        if plan is None:
            raise ValueError(
                "program has no plan yet — pass state= (or dispatch it once)"
            )
        return plan.render()

    def run_loop(
        self,
        program,
        state,
        *,
        cond: Callable | None = None,
        max_iters: int,
        unroll: int = 1,
        checkpoint=None,
        checkpoint_every: int | None = None,
        resume: bool = False,
    ):
        """Drive a fused ``Program``: ``unroll`` iterations per dispatch.

        Each dispatch runs a device-resident ``fori_loop`` block; the
        convergence test ``cond(state) -> bool`` (truthy = converged, stop)
        is evaluated on the host only *between* blocks — one host sync per
        ``unroll`` iterations instead of one per iteration.  Returns
        ``(state, LoopInfo)``; ``LoopInfo`` carries the assertable counters
        (iterations, dispatches, host_syncs, compiles).

        ``checkpoint=`` (a ``CheckpointManager`` or directory path) with
        ``checkpoint_every=k`` saves program state + carry + position every
        k iterations at dispatch boundaries; ``resume=True`` restores the
        latest checkpoint first and continues from its iteration — the
        resumed run is bit-equal to the uninterrupted one
        (``LoopInfo.resumed_from`` carries the restored position).
        Dispatches run supervised (see ``supervised``).
        """
        from repro.core.program import LoopInfo, _as_checkpoint_manager

        if unroll < 1:
            raise ValueError(f"unroll must be >= 1, got {unroll}")
        manager = _as_checkpoint_manager(checkpoint)
        if resume and manager is None:
            raise ValueError("resume=True requires checkpoint=")
        compiles0 = program.stats.compiles
        it = dispatches = host_syncs = 0
        resumed_from = None
        if resume:
            state, pos = program.restore_checkpoint(manager, state)
            if pos is not None:
                resumed_from = it = pos
        start_it = it
        last_saved = it
        converged = False
        while it < max_iters:
            u = min(unroll, max_iters - it)
            state = self.supervised(
                lambda state=state, u=u: program(state, u), program=program
            )
            dispatches += 1
            it += u
            if manager is not None and checkpoint_every:
                if it - last_saved >= checkpoint_every:
                    program.save_checkpoint(manager, state, it)
                    last_saved = it
            if cond is not None:
                self.stats.host_syncs += 1
                host_syncs += 1
                if bool(cond(state)):
                    converged = True
                    break
        return state, LoopInfo(
            iterations=it - start_it,
            dispatches=dispatches,
            host_syncs=host_syncs,
            converged=converged,
            compiles=program.stats.compiles - compiles0,
            resumed_from=resumed_from,
        )

    def run_stream(
        self,
        program,
        state,
        *,
        cond: Callable | None = None,
        max_epochs: int = 1,
        prefetch: bool = True,
        depth: int = 2,
        checkpoint=None,
        checkpoint_every: int | None = None,
        resume: bool = False,
    ):
        """Drive a fused ``Program`` over its chunked (out-of-core) sources.

        The ``run_loop`` analogue one level down the memory hierarchy: each
        *epoch* streams every host-resident block through the program's ONE
        executable (block k+1 prefetched while block k reduces), and
        ``cond(state)`` is evaluated once per epoch.  Returns
        ``(state, StreamInfo)``.

        ``checkpoint=`` / ``checkpoint_every=`` / ``resume=`` mirror
        ``run_loop`` at epoch granularity: the stream position saved is the
        epoch count, and a resumed run replays the remaining epochs
        bit-equal to the uninterrupted one (``StreamInfo.resumed_from``).
        """
        return program.run_stream(
            state, max_epochs=max_epochs, cond=cond, prefetch=prefetch,
            depth=depth, checkpoint=checkpoint,
            checkpoint_every=checkpoint_every, resume=resume,
        )

    def host_value(self, x):
        """Materialise ``x`` on the host (the driver's explicit sync point),
        counting it in ``stats.host_syncs`` so per-op loops and fused
        ``run_loop`` blocks are comparable."""
        self.stats.host_syncs += 1
        return jax.device_get(x)

    def foreach(self, v: C.DistVector, fn: Callable, env: Any = None) -> C.DistVector:
        """Session-scoped ``foreach`` (same executable-reuse contract via
        ``env``; the elementwise cache is shared process-wide)."""
        return C.foreach(v, fn, env=env)

    def topk(
        self, v: C.DistVector, k: int, score_fn: Callable | None = None,
        env: Any = None, mesh: Mesh | None = None,
    ):
        """Session-scoped ``topk``: selects on-device, then materialises the
        ``k·n_shards`` candidates on the host — a blocking sync, counted in
        ``stats.host_syncs`` (drivers that bypassed this used to undercount;
        see ``knn``)."""
        self.stats.host_syncs += 1
        return C.topk(v, k, score_fn=score_fn, mesh=mesh or self.mesh, env=env)

    def distribute(self, x, mesh: Mesh | None = None) -> C.DistVector:
        """``distribute`` onto this session's mesh."""
        return C.distribute(x, mesh or self.mesh)

    def chunked(
        self, x, block_rows: int, mesh: Mesh | None = None, **kwargs
    ) -> C.ChunkedDistVector:
        """``distribute`` for datasets that don't fit on device: host array →
        out-of-core blocks on this session's mesh (``compress=`` /
        ``spill_dir=`` / ``max_resident=`` control the byte provider)."""
        return C.chunked(x, block_rows, mesh or self.mesh, **kwargs)

    # -- observability -------------------------------------------------------

    def cache_info(self) -> dict:
        """Executable-cache snapshot: entries + cumulative counters."""
        return {
            "entries": len(self._exec_cache),
            "calls": self.stats.calls,
            "compiles": self.stats.compiles,
            "cache_hits": self.stats.cache_hits,
            "hit_rate": self.stats.hit_rate,
            "dispatches": self.stats.dispatches,
            "host_syncs": self.stats.host_syncs,
            "program_compiles": self.stats.program_compiles,
            "program_dispatches": self.stats.program_dispatches,
            "retries": self.stats.retries,
            "degraded_nodes": self.stats.degraded_nodes,
            "escalations": self.stats.escalations,
        }

    def clear_cache(self) -> None:
        """Drop all memoized executables (counters keep accumulating)."""
        self._exec_cache.clear()


# -- process-wide default session --------------------------------------------

_default_lock = threading.Lock()
_default_session: BlazeSession | None = None


def get_default_session() -> BlazeSession:
    """The lazily created session backing the free ``map_reduce``."""
    global _default_session
    if _default_session is None:
        with _default_lock:
            if _default_session is None:
                _default_session = BlazeSession()
    return _default_session


def set_default_session(session: BlazeSession) -> BlazeSession | None:
    """Install ``session`` as the process default; returns the previous one."""
    global _default_session
    with _default_lock:
        prev, _default_session = _default_session, session
    return prev


def reset_default_session() -> None:
    """Forget the default session (a fresh one is built on next use)."""
    global _default_session
    with _default_lock:
        _default_session = None


def resolve(
    session: BlazeSession | None, mesh: Mesh | None
) -> tuple[BlazeSession, Mesh]:
    """(session or default, mesh or session's) — the driver entry idiom."""
    sess = session if session is not None else get_default_session()
    return sess, (mesh or sess.mesh)
