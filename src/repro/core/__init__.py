"""Blaze core: in-memory MapReduce + distributed containers on SPMD JAX."""
from repro.core.containers import (
    EMPTY_KEY,
    DistHashMap,
    DistRange,
    DistVector,
    collect,
    data_mesh,
    distribute,
    foreach,
    make_dist_hashmap,
    topk,
)
from repro.core.mapreduce import MapReduceStats, map_reduce
from repro.core.plan import Plan
from repro.core.program import (
    LocalHashMap,
    LocalVector,
    LoopInfo,
    PlanValue,
    Program,
    ProgramStats,
)
from repro.core.session import (
    PALLAS_AUTO_MAX_KEYS,
    BlazeSession,
    SessionStats,
    get_default_session,
    reset_default_session,
    resolve_engine,
    set_default_session,
)
from repro.data.text import load_file
from repro.core.reducers import Reducer, custom_reducer, get_reducer

__all__ = [
    "EMPTY_KEY",
    "PALLAS_AUTO_MAX_KEYS",
    "BlazeSession",
    "DistHashMap",
    "DistRange",
    "DistVector",
    "LocalHashMap",
    "LocalVector",
    "LoopInfo",
    "MapReduceStats",
    "Plan",
    "PlanValue",
    "Program",
    "ProgramStats",
    "Reducer",
    "SessionStats",
    "collect",
    "custom_reducer",
    "data_mesh",
    "distribute",
    "foreach",
    "get_default_session",
    "get_reducer",
    "load_file",
    "make_dist_hashmap",
    "map_reduce",
    "reset_default_session",
    "resolve_engine",
    "set_default_session",
    "topk",
]
