"""Blaze core: in-memory MapReduce + distributed containers on SPMD JAX."""
from repro.core.containers import (
    EMPTY_KEY,
    DistHashMap,
    DistRange,
    DistVector,
    collect,
    data_mesh,
    distribute,
    foreach,
    make_dist_hashmap,
    topk,
)
from repro.core.mapreduce import MapReduceStats, map_reduce
from repro.data.text import load_file
from repro.core.reducers import Reducer, custom_reducer, get_reducer

__all__ = [
    "EMPTY_KEY",
    "DistHashMap",
    "DistRange",
    "DistVector",
    "MapReduceStats",
    "Reducer",
    "collect",
    "custom_reducer",
    "data_mesh",
    "distribute",
    "foreach",
    "get_reducer",
    "load_file",
    "make_dist_hashmap",
    "map_reduce",
    "topk",
]
