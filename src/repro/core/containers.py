"""Blaze distributed containers, adapted to SPMD JAX.

The paper's three containers map onto sharded ``jax.Array``s:

* ``DistRange``   — start/stop/step only; local values are synthesised from
                    ``iota`` + the device's mesh coordinate (no storage, as in
                    the paper).
* ``DistVector``  — an array sharded on axis 0 over the ``data`` mesh axis,
                    with ``foreach``, ``topk`` (O(n + k log k) time, O(k·shards)
                    wire bytes), and ``distribute``/``collect`` conversions.
* ``DistHashMap`` — a fixed-capacity open-addressing (linear probing) table
                    per shard.  XLA needs static shapes, so the dynamic C++
                    hash map becomes a capacity-bounded table with fully
                    vectorised round-based probing (see ``hashmap_insert``).

Everything here is pure-functional: containers are pytrees, and all mutation
returns new containers.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.reducers import Reducer, get_reducer

Array = jax.Array

EMPTY_KEY = np.iinfo(np.int32).min  # open-addressing "slot free" sentinel
DATA_AXIS = "data"
NODE_AXIS = "node"


# ---------------------------------------------------------------------------
# Mesh helpers
#
# Containers shard their leading dim over ALL data-parallel mesh axes: the
# 1-D ``("data",)`` mesh of a single host, or the 2-D ``("node", "data")``
# mesh of a multi-host launch (``repro.launch.mesh.make_node_data_mesh``),
# where ``node`` is the slow inter-host axis and ``data`` the fast
# intra-host axis.  Shard indices are flattened node-major: shard
# ``node_idx * n_data + data_idx``.
# ---------------------------------------------------------------------------


def data_mesh(n_devices: int | None = None) -> Mesh:
    """A 1-D mesh over (up to) all visible devices, axis name ``data``."""
    devs = jax.devices()
    if n_devices is not None:
        devs = devs[:n_devices]
    return Mesh(np.asarray(devs), (DATA_AXIS,))


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes container leading dims shard over, slowest (node) first."""
    if NODE_AXIS in mesh.axis_names:
        return (NODE_AXIS, DATA_AXIS)
    return (DATA_AXIS,)


def data_pspec(mesh: Mesh) -> P:
    """PartitionSpec sharding a leading dim over every data-parallel axis."""
    axes = data_axes(mesh)
    return P(axes) if len(axes) > 1 else P(DATA_AXIS)


def n_nodes(mesh: Mesh) -> int:
    """Simulated/real host count: the ``node`` axis size (1 on 1-D meshes)."""
    return mesh.shape[NODE_AXIS] if NODE_AXIS in mesh.axis_names else 1


def _nshards(mesh: Mesh) -> int:
    n = 1
    for ax in data_axes(mesh):
        n *= mesh.shape[ax]
    return n


def shard_count(mesh: Mesh) -> int:
    """Total data-parallel shards: product over ``data_axes(mesh)``."""
    return _nshards(mesh)


# ---------------------------------------------------------------------------
# Hashing (splitmix32 finaliser — cheap, good avalanche, uint32-wrap native)
# ---------------------------------------------------------------------------


def hash32(x: Array) -> Array:
    """Vectorised splitmix32-style integer hash → uint32."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def shard_of_key(keys: Array, n_shards: int) -> Array:
    """Ownership partition: which shard owns each key (high bits of the hash)."""
    return (hash32(keys) >> 16) % jnp.uint32(n_shards)


# ---------------------------------------------------------------------------
# Eager local combine: sort + segmented scan, first-class (paper §2.3.1)
# ---------------------------------------------------------------------------


def unique_combine(
    keys: Array, vals: Array, mask: Array, reducer: Reducer
) -> tuple[Array, Array, Array]:
    """Combine duplicate keys locally; returns same-length (keys, vals, valid).

    Sorts live entries first (by key), runs a segmented inclusive scan with
    the reducer's combine, and keeps only the last element of each run.
    Masked-out or duplicate slots come back with ``key == EMPTY_KEY`` and
    ``valid == False``.  This is the device-local *eager reduction*
    primitive: it is applied before any bytes go on the wire.

    The mask rides through the sort as its own lexsort column instead of
    being encoded into the key: the old ``key := INT32_MAX if masked``
    encoding conflated genuine ``INT32_MAX`` keys with masked-out slots
    (folding garbage values into their run), and a genuine ``EMPTY_KEY``
    key is now emitted with ``valid == True`` — ``valid``, not the key
    value, is the liveness contract for downstream consumers.
    """
    n = keys.shape[0]
    if n == 0:
        return keys, vals, mask
    # Live entries first (sorted by key), masked entries at the end.  The
    # mask is a sort column, so no key VALUE can collide with the "masked"
    # encoding.
    order = jnp.lexsort((keys, ~mask))
    skeys = jnp.take(keys, order)
    svals = jnp.take(vals, order, axis=0)
    smask = jnp.take(mask, order)

    # Segment boundaries: key change, live/masked transition, and every
    # masked slot is its own segment (masked keys are unsorted garbage —
    # never fold them together or into a live run).
    newseg = (skeys[1:] != skeys[:-1]) | (smask[1:] != smask[:-1]) | ~smask[1:]
    starts = jnp.concatenate([jnp.ones((1,), bool), newseg])

    def op(a, b):
        av, af = a
        bv, bf = b
        bcast = bf.reshape(bf.shape + (1,) * (av.ndim - bf.ndim))
        return jnp.where(bcast, bv, reducer.combine(av, bv)), af | bf

    scanned, _ = jax.lax.associative_scan(op, (svals, starts), axis=0)
    is_last = jnp.concatenate([newseg, jnp.ones((1,), bool)])
    valid = is_last & smask
    out_keys = jnp.where(valid, skeys, EMPTY_KEY)
    ident = reducer.identity(vals.dtype)
    vb = valid.reshape(valid.shape + (1,) * (svals.ndim - 1))
    out_vals = jnp.where(vb, scanned, ident)
    return out_keys, out_vals, valid


# ---------------------------------------------------------------------------
# DistHashMap: static-capacity open addressing with round-based probing
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HashTable:
    """One shard's table. ``keys[C]`` int32 (EMPTY_KEY = free), ``vals[C, ...]``."""

    keys: Array
    vals: Array
    overflow: Array  # scalar int32: #pairs dropped because probing exhausted

    @property
    def capacity(self) -> int:
        return self.keys.shape[0]


def make_table(capacity: int, val_shape: tuple, val_dtype, reducer: Reducer) -> HashTable:
    return HashTable(
        keys=jnp.full((capacity,), EMPTY_KEY, jnp.int32),
        vals=jnp.full((capacity,) + tuple(val_shape), reducer.identity(val_dtype), val_dtype),
        overflow=jnp.zeros((), jnp.int32),
    )


def hashmap_insert(
    table: HashTable,
    keys: Array,
    vals: Array,
    valid: Array,
    reducer: Reducer,
    max_probes: int = 16,
) -> HashTable:
    """Insert/merge a batch of pairs with *unique* keys into the table.

    Vectorised linear probing, one scatter round per probe distance:

      round r:  slot_i = (h_i + r) mod C for every unplaced pair i
        1. pairs whose key already sits at slot_i deposit (gather-combine-set,
           safe because batch keys are unique: ≤1 pair matches a slot),
        2. pairs whose slot is FREE race to claim it via scatter-max on the
           hashed key (deterministic winner); winners deposit next round
           re-check (their key is now at the slot),
        3. losers continue to round r+1.

    Callers must pre-combine duplicates (``unique_combine``) — that is the
    eager-reduction invariant, so it is free by construction.
    """
    cap = table.capacity
    h = (hash32(keys) % jnp.uint32(cap)).astype(jnp.int32)
    tkeys, tvals = table.keys, table.vals
    active = valid

    def round_body(r, state):
        tkeys, tvals, active = state
        slot = ((h + r) % cap).astype(jnp.int32)
        slot_key = jnp.take(tkeys, slot)

        # (2) claim free slots: scatter-max of (key ^ sign) — any deterministic
        # tie-break works; we use max of the raw key with EMPTY_KEY as floor.
        want = active & (slot_key == EMPTY_KEY)
        claim = jnp.full((cap,), EMPTY_KEY, jnp.int32)
        claim = claim.at[jnp.where(want, slot, cap)].max(
            jnp.where(want, keys, EMPTY_KEY), mode="drop"
        )
        tkeys = jnp.where(claim != EMPTY_KEY, claim, tkeys)

        # (1)+(2) deposit where our key is now resident at our slot.
        slot_key = jnp.take(tkeys, slot)
        deposit = active & (slot_key == keys)
        cur = jnp.take(tvals, slot, axis=0)
        merged = reducer.combine(cur, vals)
        db = deposit.reshape(deposit.shape + (1,) * (vals.ndim - 1))
        new_at_slot = jnp.where(db, merged, cur)
        tvals = tvals.at[jnp.where(deposit, slot, cap)].set(new_at_slot, mode="drop")

        active = active & ~deposit
        return tkeys, tvals, active

    tkeys, tvals, active = jax.lax.fori_loop(
        0, max_probes, round_body, (tkeys, tvals, active)
    )
    overflow = table.overflow + jnp.sum(active).astype(jnp.int32)
    return HashTable(tkeys, tvals, overflow)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DistHashMap:
    """Distributed hash map: one ``HashTable`` shard per device on ``data``.

    ``table.keys``/``table.vals`` have a leading [n_shards] dim sharded over
    the data axis.  Key ownership: ``shard_of_key(k, n_shards)``.
    """

    table: HashTable
    reducer_name: str = dataclasses.field(metadata=dict(static=True))

    @property
    def capacity_per_shard(self) -> int:
        return self.table.keys.shape[-1]

    @property
    def n_shards(self) -> int:
        return self.table.keys.shape[0]

    def items(self) -> tuple[np.ndarray, np.ndarray]:
        """Live entries as host arrays ``(keys [n], vals [n, ...])``.

        Fully vectorised (one mask + ``flatnonzero`` over the flattened
        table — no Python loop over slots), so benchmarks and bulk consumers
        can take the arrays directly instead of round-tripping a dict.
        Entry order is table order, not key order.
        """
        keys = np.asarray(jax.device_get(self.table.keys)).reshape(-1)
        vals = np.asarray(jax.device_get(self.table.vals))
        vals = vals.reshape((-1,) + vals.shape[2:])
        live = np.flatnonzero(keys != EMPTY_KEY)
        return keys[live], vals[live]

    def to_dict(self) -> dict[int, np.ndarray]:
        """Host-side materialisation (the paper's ``collect``)."""
        keys, vals = self.items()
        return dict(zip(keys.tolist(), vals))

    def size(self) -> int:
        keys = np.asarray(jax.device_get(self.table.keys))
        return int((keys != EMPTY_KEY).sum())

    def total_overflow(self) -> int:
        return int(np.asarray(jax.device_get(self.table.overflow)).sum())


def make_dist_hashmap(
    mesh: Mesh,
    capacity_per_shard: int,
    val_shape: tuple = (),
    val_dtype=jnp.float32,
    reducer: str | Reducer = "sum",
) -> DistHashMap:
    red = get_reducer(reducer)
    n = _nshards(mesh)
    sharding = NamedSharding(mesh, data_pspec(mesh))
    keys = jax.device_put(
        jnp.full((n, capacity_per_shard), EMPTY_KEY, jnp.int32), sharding
    )
    vals = jax.device_put(
        jnp.full(
            (n, capacity_per_shard) + tuple(val_shape),
            red.identity(val_dtype),
            val_dtype,
        ),
        sharding,
    )
    overflow = jax.device_put(jnp.zeros((n,), jnp.int32), sharding)
    return DistHashMap(
        HashTable(keys, vals, overflow), reducer_name=red.name
    )


# ---------------------------------------------------------------------------
# DistRange / DistVector
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DistRange:
    """start/stop/step — no storage; shards synthesise their local subrange."""

    start: int = dataclasses.field(metadata=dict(static=True))
    stop: int = dataclasses.field(metadata=dict(static=True))
    step: int = dataclasses.field(metadata=dict(static=True))

    def __len__(self) -> int:
        return max(0, -(-(self.stop - self.start) // self.step))

    def local_values(self, shard_idx: Array, n_shards: int) -> tuple[Array, Array]:
        """(values, valid) for this shard: contiguous block partitioning."""
        n = len(self)
        per = -(-n // n_shards)
        local_i = jnp.arange(per) + shard_idx * per
        valid = local_i < n
        vals = self.start + local_i * self.step
        return vals.astype(jnp.int32), valid


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DistVector:
    """Array sharded on axis 0 across ``data``; ``n`` true (pre-pad) length."""

    data: Array
    n: int = dataclasses.field(metadata=dict(static=True))

    def __len__(self) -> int:
        return self.n

    def local_mask(self, shard_idx: Array, n_shards: int) -> Array:
        per = self.data.shape[0] // n_shards
        idx = jnp.arange(per) + shard_idx * per
        return idx < self.n


def distribute(x: np.ndarray | Array, mesh: Mesh | None = None) -> DistVector:
    """Paper's ``distribute``: host array → DistVector (pads to shard multiple)."""
    mesh = mesh or data_mesh()
    x = np.asarray(x)
    n = x.shape[0]
    shards = _nshards(mesh)
    pad = (-n) % shards
    if pad:
        x = np.concatenate([x, np.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    arr = jax.device_put(x, NamedSharding(mesh, data_pspec(mesh)))
    return DistVector(arr, n)


def collect(v: DistVector) -> np.ndarray:
    """Paper's ``collect``: DistVector → host array (drops padding)."""
    return np.asarray(jax.device_get(v.data))[: v.n]


_FOREACH_CACHE: dict = {}


def foreach(v: DistVector, fn: Callable, env=None) -> DistVector:
    """Apply ``fn`` to each element in parallel (may mutate the element).

    ``fn(x)`` or ``fn(x, env)`` — iteration-varying state goes through ``env``
    so a single compiled executable serves every iteration (same contract as
    ``map_reduce``).
    """
    env_sig = "|".join(
        f"{getattr(x, 'shape', ())}{getattr(x, 'dtype', type(x))}"
        for x in jax.tree.leaves(env)
    )
    key = (fn, v.data.shape, str(v.data.dtype), env is None, env_sig)
    if key not in _FOREACH_CACHE:
        if env is None:
            _FOREACH_CACHE[key] = jax.jit(lambda d, e: jax.vmap(fn)(d))
        else:
            _FOREACH_CACHE[key] = jax.jit(
                lambda d, e: jax.vmap(lambda x: fn(x, e))(d)
            )
    out = _FOREACH_CACHE[key](v.data, env)
    return DistVector(out, v.n)


_TOPK_CACHE: dict = {}
_TOPK_CACHE_MAX = 64  # fresh-closure callers evict oldest instead of leaking


def _topk_local(score_fn, kk: int, shards: int, has_env: bool):
    """Memoized per-shard top-k executable.

    The old implementation built a fresh ``@jax.jit`` closure on every call,
    so every ``topk`` re-traced and re-compiled.  The executable is keyed on
    everything that shapes the plan — ``(score_fn, kk, shards, has_env)``
    here plus jit's own signature on the operand shapes; ``nvalid`` and
    ``env`` are traced operands, so varying ``v.n`` or the query does not
    retrace.  Repeated calls are dispatch-only (asserted in
    ``tests/test_program.py``).
    """
    key = (score_fn, kk, shards, has_env)
    if key not in _TOPK_CACHE:
        if len(_TOPK_CACHE) >= _TOPK_CACHE_MAX:
            _TOPK_CACHE.pop(next(iter(_TOPK_CACHE)))

        @jax.jit
        def _local(data, nvalid, env):
            def per_shard(x, base):
                if score_fn is None:
                    scores = x.astype(jnp.float32)
                elif has_env:
                    scores = jax.vmap(lambda r: score_fn(r, env))(x)
                else:
                    scores = jax.vmap(score_fn)(x)
                idx_in = jnp.arange(x.shape[0]) + base
                scores = jnp.where(idx_in < nvalid, scores, -jnp.inf)
                s, i = jax.lax.top_k(scores, kk)
                return s, jnp.take(x, i, axis=0)

            per = data.shape[0] // shards
            xs = data.reshape((shards, per) + data.shape[1:])
            bases = jnp.arange(shards) * per
            return jax.vmap(per_shard)(xs, bases)

        _TOPK_CACHE[key] = _local
    return _TOPK_CACHE[key]


def topk(
    v: DistVector,
    k: int,
    score_fn: Callable[..., Array] | None = None,
    mesh: Mesh | None = None,
    env=None,
) -> np.ndarray:
    """Paper's DistVector.topk: local top-k per shard, then top-k of candidates.

    O(n + k log k) work and O(k · n_shards) wire bytes — the shuffle moves only
    locally-selected candidates, never the full vector (eager reduction again,
    with ``top_k`` as the monoid).  The local-selection executable is memoized
    (``_topk_local``): callers compile once per (shape, dtype, k, score_fn)
    configuration.  As with ``foreach``/``map_reduce``, call-varying state
    (the kNN query point) goes through ``env`` — ``score_fn(x, env)`` — so a
    static module-level ``score_fn`` keeps the executable cached across
    queries.
    """
    mesh = mesh or data_mesh()
    shards = _nshards(mesh)
    kk = min(k, v.data.shape[0] // shards)

    fn = _topk_local(score_fn, kk, shards, env is not None)
    s, cand = fn(v.data, jnp.int32(v.n), env)
    s = np.asarray(jax.device_get(s)).reshape(-1)
    cand = np.asarray(jax.device_get(cand))
    cand = cand.reshape((-1,) + cand.shape[2:])
    order = np.argsort(-s)[:k]
    return cand[order]


# ---------------------------------------------------------------------------
# Out-of-core: chunked shards as host-resident byte-provider blocks
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BlockView:
    """One device-resident block of a :class:`ChunkedDistVector`.

    ``data`` is the block's rows, padded to ``block_rows`` and sharded on
    axis 0 over ``data``; ``base`` is a *traced* int32 scalar holding the
    block's global row offset (traced so every block reuses one compiled
    executable); ``n`` is the TOTAL true row count of the parent dataset —
    mappers see global indices and ``idx < n`` masks block padding exactly
    like ``DistVector`` padding.
    """

    data: Array
    base: Array
    n: int = dataclasses.field(metadata=dict(static=True))

    def __len__(self) -> int:
        return self.n


class HostBlockStore:
    """Byte-provider for chunked shards: host blocks, optional zlib
    compression, and LRU spill of cold blocks to disk.

    Blocks are stored encoded (raw ``ndarray`` or zlib bytes).  With a
    ``spill`` target (a ``repro.checkpoint.manager.BlockStore``) and a
    ``max_resident`` bound, only the hottest ``max_resident`` blocks stay in
    host memory; colder ones live on disk and are re-read on demand.  All
    blocks share one (shape, dtype) so bytes decode without per-block
    metadata.
    """

    def __init__(
        self,
        blocks: list[np.ndarray],
        *,
        compress: bool = False,
        spill=None,
        max_resident: int | None = None,
    ):
        if not blocks:
            raise ValueError("HostBlockStore needs at least one block")
        self.block_shape = blocks[0].shape
        self.dtype = blocks[0].dtype
        for b in blocks:
            if b.shape != self.block_shape or b.dtype != self.dtype:
                raise ValueError("all blocks must share one shape/dtype")
        self.compress = compress
        self.spill = spill
        self.max_resident = max_resident
        self.n_blocks = len(blocks)
        # counters (read via ChunkedDistVector.stats())
        self.loads_from_disk = 0
        self.decompressions = 0
        self.spill_bytes = 0
        self.compressed_bytes = 0
        self.raw_bytes = sum(int(b.nbytes) for b in blocks)
        self._resident: dict[int, Any] = {}  # insertion order == LRU order
        for i, b in enumerate(blocks):
            self._admit(i, self._encode(b))

    def _encode(self, arr: np.ndarray):
        if self.compress:
            payload = zlib.compress(np.ascontiguousarray(arr).tobytes(), 1)
            self.compressed_bytes += len(payload)
            return payload
        return arr

    def _payload_bytes(self, payload) -> bytes:
        if isinstance(payload, bytes):
            return payload
        return np.ascontiguousarray(payload).tobytes()

    def _admit(self, i: int, payload):
        self._resident[i] = payload
        if self.max_resident is None or self.spill is None:
            return
        while len(self._resident) > max(1, self.max_resident):
            victim, vpayload = next(iter(self._resident.items()))
            del self._resident[victim]
            if not self.spill.has(f"block_{victim:06d}"):
                self.spill_bytes += self.spill.put(
                    f"block_{victim:06d}", self._payload_bytes(vpayload)
                )

    def get(self, i: int) -> np.ndarray:
        """Block ``i`` as a host array (loading/decompressing as needed)."""
        if i in self._resident:
            payload = self._resident.pop(i)
            self._resident[i] = payload  # refresh LRU position
        else:
            self.loads_from_disk += 1
            raw = self.spill.get(f"block_{i:06d}")
            payload = raw if self.compress else np.frombuffer(
                raw, dtype=self.dtype
            ).reshape(self.block_shape)
            self._admit(i, payload)
        if self.compress:
            self.decompressions += 1
            raw = zlib.decompress(self._payload_bytes(payload))
            return np.frombuffer(raw, dtype=self.dtype).reshape(self.block_shape)
        return payload

    def stats(self) -> dict:
        return {
            "n_blocks": self.n_blocks,
            "raw_bytes": self.raw_bytes,
            "compressed_bytes": self.compressed_bytes if self.compress else 0,
            "spill_bytes": self.spill_bytes,
            "loads_from_disk": self.loads_from_disk,
            "decompressions": self.decompressions,
            "resident_blocks": len(self._resident),
        }


class ChunkedDistVector:
    """Out-of-core ``DistVector``: shards are sequences of host blocks.

    The device never holds more than one block at a time.  Streaming
    consumers (``session.map_reduce`` with a chunked source, or
    ``program.run_stream``) dispatch one compiled executable per block —
    eager reduction *per block* — while the next block is prefetched on a
    background thread (``repro.data.pipeline.prefetch_iter``).

    Not a pytree: this is a host-side container.  ``block_view(b)`` yields
    the pytree :class:`BlockView` that actually enters compiled code.
    """

    def __init__(
        self,
        provider: HostBlockStore,
        n: int,
        block_rows: int,
        mesh: Mesh | None = None,
    ):
        self.provider = provider
        self.n = n
        self.block_rows = block_rows
        self.mesh = mesh or data_mesh()
        if block_rows % _nshards(self.mesh):
            raise ValueError(
                f"block_rows={block_rows} must be a multiple of "
                f"{_nshards(self.mesh)} shards"
            )

    # -- construction -------------------------------------------------------

    @classmethod
    def from_array(
        cls,
        x: np.ndarray,
        block_rows: int,
        mesh: Mesh | None = None,
        *,
        compress: bool = False,
        spill_dir: str | None = None,
        max_resident: int | None = None,
    ) -> "ChunkedDistVector":
        """Split a host array into blocks (pads block_rows to a shard
        multiple and the last block with zeros)."""
        mesh = mesh or data_mesh()
        if block_rows <= 0:
            raise ValueError(f"block_rows must be positive, got {block_rows}")
        x = np.asarray(x)
        n = x.shape[0]
        shards = _nshards(mesh)
        block_rows = max(shards, -(-block_rows // shards) * shards)
        n_blocks = max(1, -(-n // block_rows))
        blocks = []
        for b in range(n_blocks):
            blk = x[b * block_rows : (b + 1) * block_rows]
            if blk.shape[0] < block_rows:
                pad = np.zeros(
                    (block_rows - blk.shape[0],) + x.shape[1:], x.dtype
                )
                blk = np.concatenate([blk, pad], axis=0)
            blocks.append(np.ascontiguousarray(blk))
        spill = None
        if spill_dir is not None:
            from repro.checkpoint.manager import BlockStore

            spill = BlockStore(spill_dir)
        provider = HostBlockStore(
            blocks, compress=compress, spill=spill, max_resident=max_resident
        )
        return cls(provider, n, block_rows, mesh)

    # -- geometry ------------------------------------------------------------

    @property
    def n_blocks(self) -> int:
        return self.provider.n_blocks

    @property
    def shape_tail(self) -> tuple:
        return tuple(self.provider.block_shape[1:])

    @property
    def dtype(self):
        return self.provider.dtype

    @property
    def block_nbytes(self) -> int:
        return int(
            self.block_rows
            * int(np.prod(self.shape_tail, dtype=np.int64) or 1)
            * np.dtype(self.dtype).itemsize
        )

    def __len__(self) -> int:
        return self.n

    def block_true_rows(self, b: int) -> int:
        return max(0, min(self.block_rows, self.n - b * self.block_rows))

    # -- access --------------------------------------------------------------

    def block_host(self, b: int) -> np.ndarray:
        return self.provider.get(b)

    def block_view(self, b: int, mesh: Mesh | None = None) -> BlockView:
        """Transfer block ``b`` to the device(s), sharded over ``data``."""
        mesh = mesh or self.mesh
        data = jax.device_put(
            self.block_host(b), NamedSharding(mesh, data_pspec(mesh))
        )
        base = jnp.asarray(b * self.block_rows, jnp.int32)
        return BlockView(data=data, base=base, n=self.n)

    def collect(self) -> np.ndarray:
        """Host materialisation (drops padding) — small datasets/tests."""
        out = np.concatenate(
            [self.block_host(b) for b in range(self.n_blocks)], axis=0
        )
        return out[: self.n]

    def stats(self) -> dict:
        return self.provider.stats()


def chunked(
    x: np.ndarray,
    block_rows: int,
    mesh: Mesh | None = None,
    *,
    compress: bool = False,
    spill_dir: str | None = None,
    max_resident: int | None = None,
) -> ChunkedDistVector:
    """Paper's ``distribute`` for datasets that don't fit on device: host
    array → chunked blocks streamed one at a time (see ChunkedDistVector)."""
    return ChunkedDistVector.from_array(
        x,
        block_rows,
        mesh,
        compress=compress,
        spill_dir=spill_dir,
        max_resident=max_resident,
    )
