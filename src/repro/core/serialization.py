"""Fast serialization, adapted from wire formats to TPU collectives.

The paper's fast serialization strips Protobuf's per-field tags and wire types
(fields are always serialized in a fixed order), halving small-message sizes —
for an (int, int) key/value pair: 2 bytes instead of Protobuf's 4.

Under XLA there is no user-visible byte stream: the controllable quantities are
the *element type* and *element count* that collectives move over ICI/DCN.
This module is therefore two things:

1. **The TPU analogue** — dtype narrowing and quantization used by
   ``distributed.collectives.compressed_psum`` and by the MapReduce shuffle:
   * positional (dense) keys: key bytes on the wire are ZERO — the accumulator
     index *is* the key, the logical endpoint of "no tags, fixed field order";
   * narrow explicit keys: int64 → smallest int dtype covering the key range;
   * value narrowing: f32 → bf16, or int8 + per-block scale, with
     error-feedback residuals so iterative algorithms stay unbiased.

2. **A faithful host-side reference** of the paper's byte-level format
   (varint, tag-free, fixed field order) next to a Protobuf-style tagged
   encoding, used by ``benchmarks/bench_serialization.py`` to reproduce the
   paper's message-size claims analytically.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# ---------------------------------------------------------------------------
# 1) TPU-side narrowing / quantization (used on the collective path)
# ---------------------------------------------------------------------------


def narrowest_int_dtype(key_range: int) -> jnp.dtype:
    """Smallest integer dtype that can index ``key_range`` dense keys."""
    if key_range <= (1 << 7):
        return jnp.dtype(jnp.int8)
    if key_range <= (1 << 15):
        return jnp.dtype(jnp.int16)
    if key_range <= (1 << 31):
        return jnp.dtype(jnp.int32)
    return jnp.dtype(jnp.int64)


@dataclasses.dataclass(frozen=True)
class Quantized:
    """A value tensor narrowed for the wire, plus what is needed to undo it."""

    payload: Array  # narrow dtype, same shape as the original
    scale: Array | None  # per-block scales for int8 mode, else None
    mode: str  # "none" | "bf16" | "int8"

    def wire_bytes(self) -> int:
        n = int(np.prod(self.payload.shape)) * self.payload.dtype.itemsize
        if self.scale is not None:
            n += int(np.prod(self.scale.shape)) * self.scale.dtype.itemsize
        return n


def quantize(x: Array, mode: str, block: int = 256) -> Quantized:
    """Narrow ``x`` for the wire. ``mode`` in {"none", "bf16", "int8"}."""
    if mode == "none":
        return Quantized(x, None, "none")
    if mode == "bf16":
        return Quantized(x.astype(jnp.bfloat16), None, "bf16")
    if mode == "int8":
        flat = x.reshape(-1)
        pad = (-flat.shape[0]) % block
        flat = jnp.pad(flat, (0, pad))
        blocks = flat.reshape(-1, block)
        scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, jnp.finfo(x.dtype).tiny)
        q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
        return Quantized(q, scale.astype(jnp.float32), "int8")
    raise ValueError(f"unknown quantization mode {mode!r}")


def dequantize(q: Quantized, like: Array) -> Array:
    if q.mode == "none":
        return q.payload
    if q.mode == "bf16":
        return q.payload.astype(like.dtype)
    blocks = q.payload.astype(jnp.float32) * q.scale
    flat = blocks.reshape(-1)[: int(np.prod(like.shape))]
    return flat.reshape(like.shape).astype(like.dtype)


def quantize_with_feedback(
    x: Array, residual: Array, mode: str, block: int = 256
) -> tuple[Quantized, Array]:
    """Quantize ``x + residual``; return (wire payload, new residual).

    Error feedback keeps iterative reductions (gradient descent, PageRank power
    iteration) unbiased: what this round's narrowing dropped is re-injected
    next round instead of being lost.
    """
    target = x + residual
    q = quantize(target, mode, block)
    recovered = dequantize(q, target)
    return q, target - recovered


# ---------------------------------------------------------------------------
# 2) Host-side reference of the paper's byte format (for benchmarks/analysis)
# ---------------------------------------------------------------------------


def _varint_len(v: int) -> int:
    v = int(v)
    if v < 0:
        return 10  # protobuf semantics: negatives take the full 10 bytes
    n = 1
    while v >= 0x80:
        v >>= 7
        n += 1
    return n


def varint_encode(v: int) -> bytes:
    """LEB128 varint (shared by both formats below)."""
    v = int(v)
    if v < 0:
        v += 1 << 64
    out = bytearray()
    while True:
        b = v & 0x7F
        v >>= 7
        if v:
            out.append(b | 0x80)
        else:
            out.append(b)
            return bytes(out)


def varint_decode(buf: bytes, pos: int) -> tuple[int, int]:
    shift, result = 0, 0
    while True:
        b = buf[pos]
        pos += 1
        result |= (b & 0x7F) << shift
        if not b & 0x80:
            if result >= 1 << 63:
                result -= 1 << 64
            return result, pos
        shift += 7


def blaze_encode_pairs(keys: np.ndarray, vals: np.ndarray) -> bytes:
    """The paper's format: varints in fixed field order, NO tags/wire-types."""
    out = bytearray()
    for k, v in zip(keys.tolist(), vals.tolist()):
        out += varint_encode(k)
        out += varint_encode(v)
    return bytes(out)


def blaze_decode_pairs(buf: bytes, n: int) -> tuple[np.ndarray, np.ndarray]:
    keys, vals, pos = np.empty(n, np.int64), np.empty(n, np.int64), 0
    for i in range(n):
        keys[i], pos = varint_decode(buf, pos)
        vals[i], pos = varint_decode(buf, pos)
    return keys, vals


def protobuf_encode_pairs(keys: np.ndarray, vals: np.ndarray) -> bytes:
    """Protobuf-style encoding: each field prefixed by a (tag, wire-type) byte."""
    out = bytearray()
    for k, v in zip(keys.tolist(), vals.tolist()):
        out.append((1 << 3) | 0)  # field 1, varint
        out += varint_encode(k)
        out.append((2 << 3) | 0)  # field 2, varint
        out += varint_encode(v)
    return bytes(out)


def message_sizes(keys: np.ndarray, vals: np.ndarray) -> dict[str, int]:
    """Analytical byte counts reproducing the paper's §2.3.2 comparison."""
    blaze = sum(_varint_len(k) + _varint_len(v) for k, v in zip(keys, vals))
    proto = blaze + 2 * len(keys)  # one tag byte per field, two fields per pair
    return {"blaze_bytes": int(blaze), "protobuf_bytes": int(proto)}
