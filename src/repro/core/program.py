"""Fused iteration programs: a whole iteration as ONE compiled executable.

The paper's iterative data-mining wins (PageRank, k-means, GMM/EM) come from
keeping the hot loop resident.  ``BlazeSession`` already makes iteration
*N > 1* compile-free, but a driver written as per-op ``map_reduce`` calls
still pays, per iteration, one executable **dispatch** per op (3–4 for the
paper's algorithms) plus a blocking **host sync** for the convergence test
(``float(delta)``).  Per Li (arXiv:1811.04875), exactly this dispatch/sync
overhead is what separates in-memory MapReduce from MPI/OpenMP on iterative
workloads — and BSP supersteps (Pace, arXiv:1203.2081) are the classical fix:
batch the whole superstep, synchronise once.

This module is that fix on SPMD JAX:

* ``Program`` (built by ``BlazeSession.program(step_fn)``) traces a user
  ``step_fn(ctx, state) -> state`` that may call several MapReduce ops plus
  elementwise glue, and lowers the **entire iteration** into one
  ``jit(shard_map(...))`` executable.  The ops compose because the engine
  emits pure shard stages (``mapreduce.dense_shard_stage``) instead of
  sealed executables — each op's local combine *and* its collective run
  inline in the one shard body.
* ``BlazeSession.run_loop(program, state, cond=..., max_iters=N, unroll=U)``
  runs ``U`` iterations per dispatch via a device-resident ``lax.fori_loop``
  (trip count is a *traced* scalar, so every block size shares one
  executable) and evaluates the convergence test on the host only every
  ``U`` steps.  N iterations therefore cost **1 compile**, ``≤ ⌈N/U⌉``
  dispatches and ``≤ ⌈N/U⌉`` host syncs — counters asserted in
  ``tests/test_session.py``.

How a program is built (two traces, no user-visible difference):

1. **Discovery** — ``step_fn`` runs once under ``jax.eval_shape`` with
   ``AbstractCollectives`` (shape-faithful local stand-ins, since no mesh
   axis is bound outside ``shard_map``).  This records, in call order, which
   source containers the step reads, which ops need an error-feedback
   residual (``wire="int8"`` sums), and validates that the state pytree is a
   fixed point (same treedef/shapes/dtypes out as in — required by
   ``fori_loop``).
2. **Execution** — one ``shard_map`` whose body binds ``RealCollectives``,
   maps each source to its shard-local operands, and runs
   ``fori_loop(0, n_iters, step)`` with the user state (replicated) plus the
   per-shard feedback residuals as carry.  ``jax.jit`` around it makes the
   whole block a single dispatch.

Iteration-varying values live in ``state``; distributed inputs (the edge
list, the point set) are read through the captured source containers and
enter as sharded operands.  Per-iteration *sharded* intermediates (GMM's
densities/memberships) stay on-shard as ``LocalVector``s produced by
``ctx.foreach`` — they never cross the wire and never leave the executable.

Hash targets (``DistHashMap``) are per-shard state, while the user state
pytree is replicated — so their tables are threaded through the fused loop
the same way int8 error-feedback residuals are: discovery records each
target (keyed by the identity of its backing buffers), the executable takes
the per-shard ``HashTable`` arrays as sharded operands, carries them through
the ``fori_loop``, and returns them updated; ``Program`` keeps the returned
tables across dispatches and ``program.hash_result(hm)`` materialises the
accumulated ``DistHashMap``.  Inside the step, ``ctx.map_reduce`` on a hash
target returns a ``LocalHashMap`` — this shard's updated table — usable as a
source for later ops in the same iteration (multi-pass aggregation without
leaving the executable).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import containers as C
from repro.core import mapreduce as _mr
from repro.core.reducers import get_reducer

Array = jax.Array

__all__ = [
    "LocalHashMap",
    "LocalVector",
    "LoopInfo",
    "Program",
    "ProgramContext",
    "ProgramStats",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LocalVector:
    """A shard-local vector inside a program trace (``ctx.foreach`` output).

    ``data`` is THIS shard's rows (``[per_shard, ...]``); ``n`` is the global
    true (pre-padding) length.  Usable as a ``map_reduce``/``foreach`` source
    within the same program — it never materialises globally.
    """

    data: Array
    n: int = dataclasses.field(metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LocalHashMap:
    """THIS shard's view of a hash target inside a program trace.

    Returned by ``ctx.map_reduce`` when the target is a ``DistHashMap``:
    ``table`` is the shard's updated ``HashTable`` (post-shuffle, post-merge).
    Usable as a source for later ops in the same program — the second pass
    reads the table in place, no collective, nothing leaves the executable.
    """

    table: C.HashTable
    reducer_name: str = dataclasses.field(metadata=dict(static=True))


@dataclasses.dataclass
class ProgramStats:
    """Per-program counters (mirrored cumulatively on ``SessionStats``)."""

    compiles: int = 0  # executables built (one per state signature)
    dispatches: int = 0  # blocks launched
    iterations: int = 0  # fused iterations run across all dispatches


@dataclasses.dataclass
class LoopInfo:
    """What one ``run_loop`` cost: the assertable fusion contract."""

    iterations: int  # iterations actually run
    dispatches: int  # executable launches (≤ ⌈iterations/unroll⌉ + exact)
    host_syncs: int  # blocking host materialisations (cond evaluations)
    converged: bool  # cond() went True before max_iters
    compiles: int  # program executables built during this loop (0 or 1)


def _source_key(kind: str, source) -> tuple:
    """Stable identity for a source across the discovery and execution traces.

    ``DistRange`` is keyed by value (drivers re-create it freely); array-backed
    containers are keyed by the identity of their backing buffers, so
    re-wrapping the same data in a fresh dataclass still resolves.
    """
    if kind == "range":
        return ("range", source.start, source.stop, source.step)
    if kind == "vector":
        return ("vector", id(source.data), source.n)
    return ("hashmap", id(source.table.keys), id(source.table.vals))


class ProgramContext:
    """What ``step_fn`` sees: session-API lookalikes that compose in-trace.

    ``ctx.map_reduce`` / ``ctx.foreach`` mirror the ``BlazeSession`` methods
    but run *inside* the fused program's shard body — no jit, no dispatch,
    no stats; the collective of each op is inlined.  The same user code
    therefore reads identically in per-op and program form (see the three
    algorithm drivers).
    """

    def __init__(
        self, n_shards: int, mode: str, coll=None, operands=None,
        residuals=None, hash_tables=None,
    ):
        self._n_shards = n_shards
        self._mode = mode  # "discover" | "execute"
        self._coll = coll if coll is not None else _mr.AbstractCollectives(n_shards)
        self._operands = operands or {}  # source key -> local operand tuple
        self._sources: dict[tuple, Any] = {}  # discover: key -> source, ordered
        self._residual_specs: list[tuple] = []  # discover: feedback op shapes
        self._residuals = residuals if residuals is not None else []
        self._res_i = 0
        # hash-target state: key -> this shard's HashTable (current value).
        # Discover mode also records key -> the original DistHashMap in
        # ``_hash_targets`` (op order = dict order).
        self._hash_tables: dict[tuple, C.HashTable] = (
            hash_tables if hash_tables is not None else {}
        )
        self._hash_targets: dict[tuple, Any] = {}

    # -- source resolution ----------------------------------------------------

    def _local_for(self, kind: str, source):
        if self._mode == "discover":
            self._sources.setdefault(_source_key(kind, source), source)
            if kind == "range":
                return None
            if kind == "vector":
                per = source.data.shape[0] // self._n_shards
                return (
                    jnp.zeros((per,) + source.data.shape[1:], source.data.dtype),
                    source.n,
                )
            keys, vals = source.table.keys, source.table.vals
            return (
                jnp.full(keys.shape[1:], C.EMPTY_KEY, keys.dtype),
                jnp.zeros(vals.shape[1:], vals.dtype),
            )
        if kind == "range":
            return None
        return _mr._local_view(
            kind, source, self._operands[_source_key(kind, source)]
        )

    # -- the in-program API ---------------------------------------------------

    @property
    def shard_index(self) -> Array:
        """This shard's mesh coordinate (0 under discovery)."""
        return self._coll.axis_index()

    def _resolve_program_source(self, source):
        """(kind, static source, local view) for any in-program source —
        the session containers plus the program-local ``LocalVector`` /
        ``LocalHashMap`` intermediates."""
        if isinstance(source, LocalVector):
            return "vector", None, (source.data, source.n)
        if isinstance(source, LocalHashMap):
            return "hashmap", None, (source.table.keys, source.table.vals)
        kind = _mr._source_kind(source)
        return kind, source, self._local_for(kind, source)

    def map_reduce(
        self, source, mapper: Callable, reducer, target, *,
        engine: str = "eager", wire: str = "none", env: Any = None,
        shuffle_slack: float = 2.0, key_range: int | None = None,
    ):
        """One MapReduce op, fused into the surrounding program.

        Same contract as ``BlazeSession.map_reduce``, except the result is a
        traced value inside the program and no per-op stats exist — the
        whole program is one dispatch.  Dense targets return the merged
        array (merge into ``target`` included).  ``DistHashMap`` targets
        return a ``LocalHashMap`` — this shard's updated table, readable as
        a source by later ops in the same iteration; the table itself is
        per-shard state threaded through the fused loop and across
        dispatches (``Program.hash_result`` materialises it).
        ``wire="int8"`` sums additionally get error feedback: the per-shard
        quantization residual is carried through the device-resident loop
        *and* across dispatches (the executable returns it and the next
        block feeds it back in), so iterative reductions stay unbiased for
        the lifetime of the program (``RealCollectives.reduce_feedback``).
        """
        from repro.core.session import resolve_engine

        red = get_reducer(reducer)
        if isinstance(target, C.DistHashMap):
            return self._map_reduce_hash(
                source, mapper, red, target, engine=engine, env=env,
                shuffle_slack=shuffle_slack, key_range=key_range,
            )
        target = jnp.asarray(target)
        engine = resolve_engine(engine, target, red)
        kind, src_static, local = self._resolve_program_source(source)

        feedback = (
            wire == "int8" and red.name == "sum"
            and engine in ("eager", "pallas")
        )
        stage, _ = _mr.dense_shard_stage(
            kind, src_static, mapper, red, target, engine, wire,
            self._n_shards, with_stats=False, feedback=feedback,
        )
        residual = None
        if feedback:
            if self._mode == "discover":
                self._residual_specs.append(
                    (tuple(target.shape), jnp.float32)
                )
                residual = jnp.zeros(target.shape, jnp.float32)
            else:
                residual = self._residuals[self._res_i]
        total, _live, _kp, new_residual = stage(env, local, self._coll, residual)
        if feedback:
            if self._mode == "execute":
                self._residuals[self._res_i] = new_residual
            self._res_i += 1
        return red.combine(target, total.astype(target.dtype))

    def _map_reduce_hash(
        self, source, mapper, red, target, *, engine, env, shuffle_slack,
        key_range,
    ):
        """Hash-target op inside a program: per-shard table state.

        The target is identified by its backing buffers (stable across
        iterations — drivers capture the same ``DistHashMap``); its table is
        fetched from / written back to the threaded hash state, so several
        ops (or iterations) targeting the same map compose sequentially.
        """
        from repro.core.session import resolve_engine

        engine = resolve_engine(engine, target, red)
        kind, src_static, local = self._resolve_program_source(source)
        tkey = ("hashtarget",) + _source_key("hashmap", target)[1:]
        if tkey not in self._hash_tables:
            if self._mode != "discover":
                raise ValueError(
                    "hash target not registered during discovery — targets "
                    "must be the same DistHashMap objects across iterations"
                )
            # Shape-faithful per-shard stand-in (strip the [n_shards] dim).
            keys, vals = target.table.keys, target.table.vals
            self._hash_tables[tkey] = C.HashTable(
                jnp.full(keys.shape[1:], C.EMPTY_KEY, keys.dtype),
                jnp.full(
                    vals.shape[1:], red.identity(vals.dtype), vals.dtype
                ),
                jnp.zeros((), jnp.int32),
            )
        self._hash_targets.setdefault(tkey, target)
        table = self._hash_tables[tkey]
        stage, _meta = _mr.hash_shard_stage(
            kind, src_static, mapper, red, target.table.vals.dtype, engine,
            shuffle_slack, self._n_shards, key_range=key_range,
        )
        table, _le, _ls, _kp = stage(env, table, local, self._coll)
        self._hash_tables[tkey] = table
        return LocalHashMap(table, red.name)

    def foreach(self, v, fn: Callable, env: Any = None) -> LocalVector:
        """Elementwise map over a ``DistVector`` source or a ``LocalVector``.

        Returns a ``LocalVector`` — the result stays on-shard, feeding later
        ops in the same program without any collective.
        """
        if isinstance(v, LocalVector):
            data, n = v.data, v.n
        elif isinstance(v, C.DistVector):
            data, n = self._local_for("vector", v)
        else:
            raise TypeError(
                f"ctx.foreach needs a DistVector or LocalVector, got {type(v)}"
            )
        out = jax.vmap(fn)(data) if env is None else jax.vmap(
            lambda x: fn(x, env)
        )(data)
        return LocalVector(out, n)


class Program:
    """A user step function lowered to one executable per state signature.

    Built by ``BlazeSession.program(step_fn)``; ``step_fn(ctx, state)`` must
    return a state pytree with the same structure/shapes/dtypes (it is a
    ``fori_loop`` carry).  Call ``program(state, n_iters)`` for one dispatch
    of ``n_iters`` fused iterations, or drive it with
    ``session.run_loop(...)``.  The trip count is traced, so full blocks and
    the remainder block share the single compiled executable.
    """

    def __init__(self, session, step_fn: Callable, *, mesh: Mesh | None = None):
        self._session = session
        self._step_fn = step_fn
        self._mesh = mesh if mesh is not None else session.mesh
        self._n_shards = self._mesh.shape[C.DATA_AXIS]
        self._cache: dict = {}  # state signature -> (jitted fused fn, operands)
        # state signature -> live per-shard error-feedback residuals, carried
        # ACROSS dispatches for the lifetime of this Program
        self._residual_state: dict = {}
        # state signature -> (hash-target key order, tuple of per-target
        # (keys, vals, overflow) sharded arrays) — like residuals, hash
        # tables are per-shard state that outlives each dispatch
        self._hash_state: dict = {}
        self._last_sig = None  # signature of the most recent dispatch
        self.stats = ProgramStats()
        self.feedback_slots = 0  # error-feedback residual slots (int8 sums)
        self.hash_slots = 0  # hash-target table slots threaded per iteration

    # -- build ---------------------------------------------------------------

    def _discover(self, state):
        ctx = ProgramContext(self._n_shards, "discover")
        out = jax.eval_shape(lambda s: self._step_fn(ctx, s), state)
        in_flat, in_tree = jax.tree_util.tree_flatten(state)
        out_flat, out_tree = jax.tree_util.tree_flatten(out)
        if in_tree != out_tree:
            raise ValueError(
                "step_fn must return a state pytree with the same structure "
                f"it was given (got {out_tree}, want {in_tree})"
            )
        for i, (a, b) in enumerate(zip(in_flat, out_flat)):
            a_shape, a_dt = jnp.shape(a), jnp.asarray(a).dtype
            if (a_shape, a_dt) != (b.shape, b.dtype):
                raise ValueError(
                    "step_fn must preserve state leaf shapes/dtypes (it is a "
                    f"fori_loop carry); leaf {i} went from {a_shape}/{a_dt} "
                    f"to {b.shape}/{b.dtype}"
                )
        return (
            list(ctx._sources.values()),
            list(ctx._residual_specs),
            dict(ctx._hash_targets),
        )

    def _build(self, state):
        key = _mr._abstract(state)
        if key in self._cache:
            return self._cache[key]
        sources, residual_specs, hash_targets = self._discover(state)
        self.feedback_slots = len(residual_specs)
        self.hash_slots = len(hash_targets)
        axis = C.DATA_AXIS
        n_shards = self._n_shards
        step_fn = self._step_fn

        operands: list = []
        specs: list = []
        source_keys: list[tuple] = []
        sizes: list[int] = []
        for s in sources:
            kind = _mr._source_kind(s)
            ops, sp = _mr._source_operands(kind, s)
            operands.extend(ops)
            specs.extend(sp)
            source_keys.append(_source_key(kind, s))
            sizes.append(len(ops))
        n_res = len(residual_specs)
        hash_keys = list(hash_targets)
        n_hash = len(hash_keys)

        def shard_body(state_, n_iters, *flat):
            # flat = per-op feedback residuals, then per-target hash tables
            # (both sharded: each shard carries its own), then the source
            # operands.
            res_in = flat[:n_res]
            hash_in = flat[n_res:n_res + 3 * n_hash]
            flat_ops = flat[n_res + 3 * n_hash:]
            coll = _mr.RealCollectives(axis, n_shards)
            op_map, i = {}, 0
            for sk, k in zip(source_keys, sizes):
                op_map[sk] = tuple(flat_ops[i:i + k])
                i += k

            def one_step(_, carry):
                st, residuals, tables = carry
                ctx = ProgramContext(
                    n_shards, "execute", coll=coll, operands=op_map,
                    residuals=list(residuals),
                    hash_tables=dict(zip(hash_keys, tables)),
                )
                new_st = step_fn(ctx, st)
                return (
                    new_st,
                    tuple(ctx._residuals),
                    tuple(ctx._hash_tables[hk] for hk in hash_keys),
                )

            res0 = tuple(r[0] for r in res_in)  # drop the local shard dim
            h0 = tuple(
                C.HashTable(
                    hash_in[3 * i_][0], hash_in[3 * i_ + 1][0],
                    hash_in[3 * i_ + 2][0],
                )
                for i_ in range(n_hash)
            )
            out_state, res_out, h_out = jax.lax.fori_loop(
                0, n_iters, one_step, (state_, res0, h0)
            )
            return (
                out_state,
                tuple(r[None] for r in res_out),
                tuple(
                    (t.keys[None], t.vals[None], t.overflow[None])
                    for t in h_out
                ),
            )

        d = P(C.DATA_AXIS)
        fused = shard_map(
            shard_body,
            mesh=self._mesh,
            in_specs=(P(), P()) + (d,) * (n_res + 3 * n_hash) + tuple(specs),
            out_specs=(P(), d, d),
            check_vma=False,
        )
        # Residual AND hash-table state outlive the dispatch: the executable
        # returns the updated per-shard arrays and the next dispatch feeds
        # them back in, so both stay live across blocks (even unroll=1).
        self._residual_state[key] = tuple(
            jnp.zeros((n_shards,) + shape, dtype)
            for shape, dtype in residual_specs
        )
        self._hash_state[key] = (
            hash_keys,
            tuple(
                (hm.table.keys, hm.table.vals, hm.table.overflow)
                for hm in hash_targets.values()
            ),
        )
        entry = (jax.jit(fused), tuple(operands))
        self._cache[key] = entry
        self.stats.compiles += 1
        self._session.stats.program_compiles += 1
        return entry

    # -- run -----------------------------------------------------------------

    def __call__(self, state, n_iters: int = 1):
        """One dispatch: ``n_iters`` fused iterations, device-resident."""
        key = _mr._abstract(state)
        fn, operands = self._build(state)
        residuals = self._residual_state[key]
        hash_keys, hash_tuples = self._hash_state[key]
        flat_hash = [a for t in hash_tuples for a in t]
        out, new_residuals, new_hash = fn(
            state, jnp.asarray(n_iters, jnp.int32), *residuals, *flat_hash,
            *operands,
        )
        self._residual_state[key] = new_residuals
        self._hash_state[key] = (hash_keys, tuple(new_hash))
        self._last_sig = key
        self.stats.dispatches += 1
        self.stats.iterations += int(n_iters)
        self._session.stats.dispatches += 1
        self._session.stats.program_dispatches += 1
        return out

    def hash_result(self, target: C.DistHashMap) -> C.DistHashMap:
        """The accumulated state of a hash target used by this program.

        ``target`` must be the same ``DistHashMap`` object the step function
        captured; the returned map holds the tables as of the most recent
        dispatch (the original object is never mutated).
        """
        tkey = ("hashtarget",) + _source_key("hashmap", target)[1:]
        sig = self._last_sig
        if sig is None or sig not in self._hash_state:
            raise ValueError("program has not dispatched yet")
        hash_keys, hash_tuples = self._hash_state[sig]
        if tkey not in hash_keys:
            raise KeyError(
                "not a hash target of this program (targets are keyed by "
                "the identity of their backing buffers)"
            )
        keys, vals, ovf = hash_tuples[hash_keys.index(tkey)]
        return C.DistHashMap(
            C.HashTable(keys, vals, ovf), reducer_name=target.reducer_name
        )
