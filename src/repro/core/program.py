"""Fused iteration programs: a whole iteration as ONE optimized executable.

The paper's iterative data-mining wins (PageRank, k-means, GMM/EM) come from
keeping the hot loop resident.  ``BlazeSession`` already makes iteration
*N > 1* compile-free, but a driver written as per-op ``map_reduce`` calls
still pays, per iteration, one executable **dispatch** per op (3–4 for the
paper's algorithms) plus a blocking **host sync** for the convergence test
(``float(delta)``).  Per Li (arXiv:1811.04875), exactly this dispatch/sync
overhead is what separates in-memory MapReduce from MPI/OpenMP on iterative
workloads — and BSP supersteps (Pace, arXiv:1203.2081) are the classical fix:
batch the whole superstep, synchronise once.

This module is that fix on SPMD JAX, built around an explicit logical plan
(``repro.core.plan``) since PR 5:

* **Discovery builds a ``Plan``.** ``step_fn`` runs once under
  ``jax.eval_shape`` with shape-faithful collective stand-ins
  (``AbstractCollectives``).  Instead of consuming the trace inline, the
  context records every ``ctx.map_reduce`` / ``ctx.foreach`` / ``ctx.topk``
  call as a plan node — sources, reducers, wire formats, residual and
  hash-state edges — and the optimizer passes run on that plan:

  - *resolve-engines*: each node gets its own resolved engine
    (``repro.core.plan.resolve_engine``), so one program can mix
    pallas-dense, pallas-hash and eager ops;
  - *batch-collectives*: dense results come back as **lazy plan values**
    (``PlanValue``).  The collective is deferred until the step function
    actually consumes the result; everything pending at that moment with the
    same (reducer, wire, dtype) is concatenated and reduced in ONE
    collective.  GMM's EM round drops from 4 psums to 2 this way — asserted
    via ``Plan.collectives_per_iter``;
  - *cse*: a node identical to an earlier one (same source, mapper,
    reducer, target, engine, wire, env) reuses its result instead of
    recomputing and re-reducing;
  - *prune-dead-sources*: nodes whose results are provably never consumed
    (their lazy value is never forced and not part of the returned state)
    are dropped, and sources referenced only by dropped nodes are never
    shipped into the executable.

* **Execution lowers the plan.** One ``shard_map`` whose body binds
  ``RealCollectives``, maps each *live* source to its shard-local operands,
  and runs ``fori_loop(0, n_iters, step)`` with the user state (replicated)
  plus per-shard feedback residuals and hash tables as carry.  ``jax.jit``
  around it makes the whole block a single dispatch.  The execution context
  replays the same step function against the plan: pruned nodes are skipped,
  CSE'd nodes reuse results, and pending partials flush through the same
  batched collectives the plan recorded.

``session.explain(program)`` renders the optimized plan Spark-EXPLAIN-style;
golden snapshots for the paper's six algorithms live in ``tests/goldens/``.

Iteration-varying values live in ``state``; distributed inputs (the edge
list, the point set) are read through the captured source containers and
enter as sharded operands.  Per-iteration *sharded* intermediates (GMM's
densities/memberships) stay on-shard as ``LocalVector``s produced by
``ctx.foreach`` — they never cross the wire and never leave the executable.

Hash targets (``DistHashMap``) are per-shard state, while the user state
pytree is replicated — so their tables are threaded through the fused loop
the same way int8 error-feedback residuals are: the plan records each target
(keyed by the identity of its backing buffers), the executable takes the
per-shard ``HashTable`` arrays as sharded operands, carries them through
the ``fori_loop``, and returns them updated; ``Program`` keeps the returned
tables across dispatches and ``program.hash_result(hm)`` materialises the
accumulated ``DistHashMap``.  Inside the step, ``ctx.map_reduce`` on a hash
target returns a ``LocalHashMap`` — this shard's updated table, usable as a
source for later ops in the same iteration (multi-pass aggregation without
leaving the executable).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import containers as C
from repro.core import faults
from repro.core import mapreduce as _mr
from repro.core import plan as plan_mod
from repro.core.plan import (
    ContainerOpNode,
    DEFAULT_PASSES,
    ForeachNode,
    GlueNode,
    MapReduceNode,
    Plan,
    SourceInfo,
)
from repro.core.reducers import _BUILTIN, get_reducer

Array = jax.Array

__all__ = [
    "LocalHashMap",
    "LocalVector",
    "LoopInfo",
    "PlanValue",
    "Program",
    "ProgramContext",
    "ProgramStats",
    "StreamInfo",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LocalVector:
    """A shard-local vector inside a program trace (``ctx.foreach`` output).

    ``data`` is THIS shard's rows (``[per_shard, ...]``); ``n`` is the global
    true (pre-padding) length.  Usable as a ``map_reduce``/``foreach``/
    ``topk`` source within the same program — it never materialises globally.
    """

    data: Array
    n: int = dataclasses.field(metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LocalHashMap:
    """THIS shard's view of a hash target inside a program trace.

    Returned by ``ctx.map_reduce`` when the target is a ``DistHashMap``:
    ``table`` is the shard's updated ``HashTable`` (post-shuffle, post-merge).
    Usable as a source for later ops in the same program — the second pass
    reads the table in place, no collective, nothing leaves the executable.
    """

    table: C.HashTable
    reducer_name: str = dataclasses.field(metadata=dict(static=True))


@dataclasses.dataclass
class ProgramStats:
    """Per-program counters (mirrored cumulatively on ``SessionStats``)."""

    compiles: int = 0  # executables built (one per state signature)
    dispatches: int = 0  # blocks launched
    iterations: int = 0  # fused iterations run across all dispatches


@dataclasses.dataclass
class LoopInfo:
    """What one ``run_loop`` cost: the assertable fusion contract."""

    iterations: int  # iterations actually run
    dispatches: int  # executable launches (≤ ⌈iterations/unroll⌉ + exact)
    host_syncs: int  # blocking host materialisations (cond evaluations)
    converged: bool  # cond() went True before max_iters
    compiles: int  # program executables built during this loop (0 or 1)
    resumed_from: int | None = None  # checkpointed iteration restored, if any


@dataclasses.dataclass
class StreamInfo:
    """What one ``run_stream`` cost: the out-of-core streaming contract.

    ``compiles`` must be ≤ 1 regardless of block count — every block goes
    through the same executable (traced ``base`` offset, static shapes).
    """

    epochs: int  # full passes over the chunked source(s)
    n_blocks: int  # blocks per epoch
    dispatches: int  # block dispatches total (epochs x n_blocks)
    host_syncs: int  # cond evaluations (one per completed epoch)
    converged: bool  # cond() went True before max_epochs
    compiles: int  # program executables built during this stream (0 or 1)
    prefetch: bool  # double-buffered background transfer was on
    bytes_streamed: int  # host->device block bytes moved across dispatches
    resumed_from: int | None = None  # checkpointed epoch restored, if any


def _source_key(kind: str, source) -> tuple:
    """Stable identity for a source across the discovery and execution traces.

    ``DistRange`` is keyed by value (drivers re-create it freely); array-backed
    containers are keyed by the identity of their backing buffers, so
    re-wrapping the same data in a fresh dataclass still resolves.
    """
    if kind == "range":
        return ("range", source.start, source.stop, source.step)
    if kind == "vector":
        return ("vector", id(source.data), source.n)
    if kind == "chunked":
        # Host container identity: blocks are streamed in per dispatch, so
        # no backing device buffer exists to key on.
        return ("chunked", id(source), source.n)
    return ("hashmap", id(source.table.keys), id(source.table.vals))


class PlanValue:
    """A lazy dense MapReduce result inside a program trace.

    ``ctx.map_reduce`` returns one for batchable dense ops: the per-shard
    partial is computed eagerly, but the *collective* is deferred until the
    step function consumes the value — at which point every pending partial
    with the same (reducer, wire, dtype) ships in ONE concatenated
    collective (the plan's ``batch-collectives`` pass).  Consumption happens
    through the ``__jax_array__`` protocol (any jnp binary op / ``asarray``)
    or the arithmetic dunders below; ``[...]`` indexing is itself lazy, so
    ``ctx.map_reduce(...)[0]`` does not force an early flush.  A value that
    is never consumed marks its op dead (``prune-dead-sources``).
    """

    __slots__ = ("_ctx", "_idx", "_post")

    def __init__(self, ctx, idx: int, post: tuple = ()):
        self._ctx = ctx
        self._idx = idx
        self._post = post

    def _force(self) -> Array:
        base = self._ctx._materialise(self._idx)
        for f in self._post:
            base = f(base)
        return base

    # -- the JAX conversion protocol (jnp.asarray / binary ops) --------------
    def __jax_array__(self) -> Array:
        return self._force()

    def __getitem__(self, item) -> "PlanValue":
        return PlanValue(
            self._ctx, self._idx, self._post + ((lambda a, it=item: a[it]),)
        )

    def astype(self, dtype) -> Array:
        return self._force().astype(dtype)

    def reshape(self, *shape) -> Array:
        return self._force().reshape(*shape)

    # -- arithmetic: force, then defer to jnp --------------------------------
    def _bin(self, other, op, reverse=False):
        a = self._force()
        b = other._force() if isinstance(other, PlanValue) else other
        return op(b, a) if reverse else op(a, b)

    def __add__(self, o):
        return self._bin(o, jnp.add)

    def __radd__(self, o):
        return self._bin(o, jnp.add, reverse=True)

    def __sub__(self, o):
        return self._bin(o, jnp.subtract)

    def __rsub__(self, o):
        return self._bin(o, jnp.subtract, reverse=True)

    def __mul__(self, o):
        return self._bin(o, jnp.multiply)

    def __rmul__(self, o):
        return self._bin(o, jnp.multiply, reverse=True)

    def __truediv__(self, o):
        return self._bin(o, jnp.divide)

    def __rtruediv__(self, o):
        return self._bin(o, jnp.divide, reverse=True)

    def __pow__(self, o):
        return self._bin(o, jnp.power)

    def __neg__(self):
        return -self._force()

    def __lt__(self, o):
        return self._bin(o, jnp.less)

    def __le__(self, o):
        return self._bin(o, jnp.less_equal)

    def __gt__(self, o):
        return self._bin(o, jnp.greater)

    def __ge__(self, o):
        return self._bin(o, jnp.greater_equal)

    # == / != must be elementwise like every other comparison — the default
    # identity semantics would silently return False for `result == 0`.
    def __eq__(self, o):
        return self._bin(o, jnp.equal)

    def __ne__(self, o):
        return self._bin(o, jnp.not_equal)

    __hash__ = object.__hash__  # identity hash stays valid (no value hash)


# jnp functions are jit-wrapped: their argument flattening runs before any
# __jax_array__ conversion could.  Registering PlanValue as a pytree node
# whose flatten *forces* the value makes every jit boundary (jnp.maximum,
# jnp.sum, user helpers, ...) materialise it transparently — so a lazy plan
# value is a drop-in stand-in for the array inside step functions.
jax.tree_util.register_pytree_node(
    PlanValue,
    lambda pv: ((pv._force(),), None),
    lambda _aux, children: children[0],
)


def _is_plan_value(x) -> bool:
    return isinstance(x, PlanValue)


class _CountingCollectives:
    """Wraps a collectives object and counts collective *launches* — the
    quantity ``Plan.collectives_per_iter`` reports.  Used on the discovery
    trace, so the count reflects the optimized plan (batched flushes count
    once per group)."""

    def __init__(self, inner):
        self._inner = inner
        self.count = 0

    def axis_index(self):
        return self._inner.axis_index()

    def all_gather_tiled(self, x):
        self.count += 1
        return self._inner.all_gather_tiled(x)

    def all_to_all_tiled(self, x):
        self.count += 1
        return self._inner.all_to_all_tiled(x)

    def reduce(self, partial, red, wire, hier=False):
        self.count += 1
        return self._inner.reduce(partial, red, wire, hier=hier)

    def reduce_feedback(self, partial, red, wire, residual, hier=False):
        self.count += 1
        return self._inner.reduce_feedback(
            partial, red, wire, residual, hier=hier
        )


class ProgramContext:
    """What ``step_fn`` sees: session-API lookalikes that compose in-trace.

    ``ctx.map_reduce`` / ``ctx.foreach`` / ``ctx.topk`` mirror the
    ``BlazeSession`` methods but run *inside* the fused program's shard body
    — no jit, no dispatch, no per-op stats; each op's collective is inlined
    (and possibly batched with its neighbours').  The same user code
    therefore reads identically in per-op and program form.

    Two modes share this class: ``"discover"`` *builds* the logical plan
    (nodes, sources, batch groups, CSE aliases, dead ops) while tracing under
    ``jax.eval_shape``; ``"execute"`` *consumes* a finished plan inside the
    fused ``shard_map`` body — skipping pruned nodes, reusing CSE'd results,
    and flushing the same batched collectives.
    """

    def __init__(
        self, n_shards: int, mode: str, coll=None, operands=None,
        residuals=None, hash_tables=None, plan: Plan | None = None,
        passes: tuple = DEFAULT_PASSES, tuning=None, overrides=None,
        degraded=None, n_nodes: int = 1, hierarchical: bool = True,
    ):
        self._n_shards = n_shards
        self._n_nodes = n_nodes
        self._hierarchical = hierarchical
        self._mode = mode  # "discover" | "execute"
        # discover-mode autotuning hooks: ``tuning`` is the session's
        # TuningCache (cached winners apply to every node built), and
        # ``overrides`` maps tune_key -> candidate TunedConfig for the
        # throwaway measurement variants Program._maybe_tune builds.
        # ``degraded`` is the session's set of kernel-faulted tune_keys:
        # nodes matching it resolve straight to eager on (re)discovery.
        self._tuning = tuning
        self._overrides = overrides or {}
        self._degraded = degraded
        self._tune_info: dict[int, tuple] = {}  # idx -> candidate-grid params
        inner = (
            coll if coll is not None
            else _mr.AbstractCollectives(n_shards, n_nodes=n_nodes)
        )
        if mode == "discover":
            inner = _CountingCollectives(inner)
        self._coll = inner
        self._operands = operands or {}  # source key -> local operand tuple
        self._plan = plan  # execute mode: the optimized plan to replay
        self._passes = tuple(passes)
        self._batch = "batch-collectives" in self._passes
        self._cse = "cse" in self._passes
        self._prune = "prune-dead-sources" in self._passes
        # -- discover-mode plan-building state --------------------------------
        self._nodes: list = []  # call-order plan nodes
        self._sources: dict[tuple, Any] = {}  # key -> source, ordered
        self._local_producers: dict[int, int] = {}  # id(array) -> node idx
        self._cse_index: dict[tuple, int] = {}  # cse key -> node idx
        self._groups: dict[int, list[int]] = {}
        self._group_keys: dict[int, tuple] = {}
        self._hash_targets: dict[tuple, Any] = {}
        # -- shared runtime state ---------------------------------------------
        self._call_i = 0  # ctx-op call counter (node index)
        self._pending: list[int] = []  # deferred ops awaiting their collective
        self._partials: dict[int, tuple] = {}  # idx -> (partial, red, wire, hier)
        self._totals: dict[int, Array] = {}  # idx -> reduced (pre-merge) total
        self._results: dict[int, Array] = {}  # idx -> target-merged result
        self._meta: dict[int, tuple] = {}  # idx -> (red, target) for the merge
        self._residuals = residuals if residuals is not None else []
        self._res_i = 0
        # hash-target state: key -> this shard's HashTable (current value)
        self._hash_tables: dict[tuple, C.HashTable] = (
            hash_tables if hash_tables is not None else {}
        )

    # -- source resolution ----------------------------------------------------

    def _local_for(self, kind: str, source):
        if self._mode == "discover":
            self._sources.setdefault(_source_key(kind, source), source)
            if kind == "range":
                return None
            if kind == "vector":
                per = source.data.shape[0] // self._n_shards
                return (
                    jnp.zeros((per,) + source.data.shape[1:], source.data.dtype),
                    source.n,
                )
            if kind == "chunked":
                # Shape-faithful stand-in for ONE resident block: the
                # executable only ever sees a block's worth of rows plus the
                # traced base offset.
                per = source.block_rows // self._n_shards
                return (
                    jnp.zeros((per,) + source.shape_tail, source.dtype),
                    source.n,
                    jnp.zeros((), jnp.int32),
                )
            keys, vals = source.table.keys, source.table.vals
            return (
                jnp.full(keys.shape[1:], C.EMPTY_KEY, keys.dtype),
                jnp.zeros(vals.shape[1:], vals.dtype),
            )
        if kind == "range":
            return None
        return _mr._local_view(
            kind, source, self._operands[_source_key(kind, source)]
        )

    def _resolve_program_source(self, source):
        """(kind, static source, local view, src desc, source key) for any
        in-program source — the session containers plus the program-local
        ``LocalVector`` / ``LocalHashMap`` intermediates."""
        if isinstance(source, LocalVector):
            prod = self._local_producers.get(id(source.data), "?")
            return "vector", None, (source.data, source.n), f"local[{prod}]", None
        if isinstance(source, LocalHashMap):
            prod = self._local_producers.get(id(source.table.keys), "?")
            return (
                "hashmap", None,
                (source.table.keys, source.table.vals), f"local[{prod}]", None,
            )
        kind = _mr._source_kind(source)
        key = _source_key(kind, source)
        desc = plan_mod.source_desc(kind, source)
        return kind, source, self._local_for(kind, source), desc, key

    def _resolve_vector_source(self, v, what: str):
        """(data, n, src desc, source key) for the vector-only ctx ops
        (``foreach``, ``topk``): a ``DistVector`` or a ``LocalVector``."""
        if isinstance(v, LocalVector):
            prod = self._local_producers.get(id(v.data), "?")
            return v.data, v.n, f"local[{prod}]", None
        if isinstance(v, C.DistVector):
            data, n = self._local_for("vector", v)
            return (
                data, n, plan_mod.source_desc("vector", v),
                _source_key("vector", v),
            )
        raise TypeError(
            f"{what} needs a DistVector or LocalVector, got {type(v)}"
        )

    # -- plan-node bookkeeping -------------------------------------------------

    def _next_node(self, expect_type=None):
        """Execute mode: the plan node matching this ctx call."""
        idx = self._call_i
        self._call_i += 1
        if self._plan is None:
            return idx, None
        node = self._plan.nodes[idx]
        if expect_type is not None and not isinstance(node, expect_type):
            raise RuntimeError(
                f"program trace diverged from its plan at node {idx}: "
                f"expected {expect_type.__name__}, found {type(node).__name__}"
            )
        return idx, node

    def _cse_key(self, kind, source_key, local, mapper, red, target, engine,
                 wire, key_range, env):
        """Identity of a node's *reduced total* — the part CSE can share.

        The target merge is applied per node at materialisation (totals, not
        merged results, are cached), so two ops differing only in their
        target arrays still dedupe.  Dynamic inputs are compared by tracer
        identity: the same state leaf or ``foreach`` output reused across ops
        keys equal; anything recomputed keys distinct (conservative).
        """
        if source_key is not None:
            src_ident = source_key
        elif isinstance(local, tuple):  # local view (data, n) / (keys, vals)
            src_ident = ("local",) + tuple(id(x) for x in local)
        else:
            src_ident = ("local", id(local))
        env_ids = tuple(id(x) for x in jax.tree_util.tree_leaves(env))
        target = jnp.asarray(target)
        return (
            kind, src_ident, mapper, id(red), engine, wire, key_range,
            tuple(target.shape), str(target.dtype), env_ids,
        )

    # -- deferred collectives (the batch-collectives pass) ---------------------

    def _total_of(self, idx: int) -> Array:
        """The op's reduced total (pre target-merge) — the sharable part."""
        if idx in self._totals:
            return self._totals[idx]
        node = (
            self._plan.nodes[idx] if self._plan is not None else
            (self._nodes[idx] if idx < len(self._nodes) else None)
        )
        if isinstance(node, MapReduceNode) and node.cse_of is not None:
            return self._total_of(node.cse_of)
        if idx in self._pending:
            # Mid-step consumption: flush EVERYTHING pending — independent
            # reductions that happen to be in flight batch into one
            # collective per (reducer, wire, dtype).
            self._flush()
            return self._totals[idx]
        raise RuntimeError(f"plan node {idx} has no result to materialise")

    def _materialise(self, idx: int) -> Array:
        if idx in self._results:
            return self._results[idx]
        node = (
            self._plan.nodes[idx] if self._plan is not None else
            (self._nodes[idx] if idx < len(self._nodes) else None)
        )
        if (
            isinstance(node, MapReduceNode) and node.dead
            and self._mode == "execute"
        ):
            raise RuntimeError(
                f"plan node {idx} was pruned as dead but its result was "
                "consumed — the execution trace diverged from discovery"
            )
        red, target = self._meta[idx]
        total = self._total_of(idx)
        out = red.combine(target, total.astype(target.dtype))
        self._results[idx] = out
        return out

    def _flush(self, needed: set | None = None):
        idxs = [i for i in self._pending if needed is None or i in needed]
        if not idxs:
            return
        self._pending = [i for i in self._pending if i not in set(idxs)]
        by_key: dict[tuple, list[int]] = {}
        for i in idxs:
            partial, red, wire, hier = self._partials[i]
            by_key.setdefault(
                (red.name, wire, str(partial.dtype), hier), []
            ).append(i)
        for key, members in by_key.items():
            if len(members) == 1 or not self._batch:
                for i in members:
                    partial, red, wire, hier = self._partials[i]
                    self._totals[i] = self._coll.reduce(
                        partial, red, wire, hier=hier
                    )
                continue
            # One fused collective for the whole group: flatten, concatenate,
            # reduce once, split.  Exact for every built-in reducer — psum /
            # pmin / pmax and the gathered prod fold are all elementwise, so
            # reducing the concatenation is bit-identical to reducing each
            # buffer alone.
            _p0, red, wire, hier = self._partials[members[0]]
            flats = [self._partials[i][0].reshape(-1) for i in members]
            sizes = [f.shape[0] for f in flats]
            total_cat = self._coll.reduce(
                jnp.concatenate(flats), red, wire, hier=hier
            )
            off = 0
            for i, sz in zip(members, sizes):
                partial, _r, _w, _h = self._partials[i]
                self._totals[i] = total_cat[off:off + sz].reshape(partial.shape)
                off += sz
            if self._mode == "discover":
                gid = len(self._groups)
                self._groups[gid] = list(members)
                self._group_keys[gid] = key
                for i in members:
                    self._nodes[i].group = gid

    def _finalize_state(self, out):
        """Materialise every plan value the step returns; whatever is still
        pending afterwards was never consumed — the op is dead."""
        needed: set[int] = set()

        def _collect(x):
            if isinstance(x, PlanValue):
                tgt = x._idx
                node = (
                    self._plan.nodes[tgt] if self._plan is not None
                    else self._nodes[tgt]
                )
                if isinstance(node, MapReduceNode) and node.cse_of is not None:
                    needed.add(node.cse_of)
                needed.add(tgt)
            return x

        jax.tree_util.tree_map(_collect, out, is_leaf=_is_plan_value)
        # With pruning on, flush only what the state needs (the rest is
        # dead); with it off, every op's collective still runs.
        self._flush(needed=needed if self._prune else None)
        out = jax.tree_util.tree_map(
            lambda x: x._force() if isinstance(x, PlanValue) else x,
            out, is_leaf=_is_plan_value,
        )
        if self._mode == "discover":
            for i in self._pending:
                self._nodes[i].dead = True
        self._pending = []
        return out

    # -- the in-program API ---------------------------------------------------

    @property
    def shard_index(self) -> Array:
        """This shard's mesh coordinate (0 under discovery)."""
        return self._coll.axis_index()

    def map_reduce(
        self, source, mapper: Callable, reducer, target, *,
        engine: str = "eager", wire: str = "none", env: Any = None,
        shuffle_slack: float = 2.0, key_range: int | None = None,
    ):
        """One MapReduce op, fused into the surrounding program.

        Same contract as ``BlazeSession.map_reduce``, except the result is a
        traced value inside the program and no per-op stats exist — the
        whole program is one dispatch.  Dense targets return the merged
        result (merge into ``target`` included) as a lazy :class:`PlanValue`
        whose collective is deferred and batched with its neighbours'
        (plain jnp use materialises it transparently).  ``DistHashMap``
        targets return a ``LocalHashMap`` — this shard's updated table,
        readable as a source by later ops in the same iteration; the table
        itself is per-shard state threaded through the fused loop and across
        dispatches (``Program.hash_result`` materialises it).
        ``wire="int8"`` sums additionally get error feedback: the per-shard
        quantization residual is carried through the device-resident loop
        *and* across dispatches (the executable returns it and the next
        block feeds it back in), so iterative reductions stay unbiased for
        the lifetime of the program (``RealCollectives.reduce_feedback``).
        """
        red = get_reducer(reducer)
        env = jax.tree_util.tree_map(
            lambda x: x._force() if isinstance(x, PlanValue) else x,
            env, is_leaf=_is_plan_value,
        )
        if isinstance(target, C.DistHashMap):
            return self._map_reduce_hash(
                source, mapper, red, target, engine=engine, env=env,
                shuffle_slack=shuffle_slack, key_range=key_range,
            )
        target = jnp.asarray(target)
        if self._mode == "execute" and self._plan is not None:
            # Pruned/CSE'd nodes are skipped BEFORE source resolution — a
            # source only they read is never shipped into the executable.
            peek = self._plan.nodes[self._call_i]
            if isinstance(peek, MapReduceNode) and (
                peek.dead or peek.cse_of is not None
            ):
                idx, _ = self._next_node(MapReduceNode)
                self._meta[idx] = (red, target)
                return PlanValue(self, idx)
        kind, src_static, local, src_desc, source_key = (
            self._resolve_program_source(source)
        )

        if self._mode == "discover":
            node = plan_mod.build_mapreduce_node(
                idx=self._call_i, kind=kind, src=src_desc,
                source_key=source_key, mapper=mapper, red=red, target=target,
                engine=engine, wire=wire, key_range=key_range, env=env,
                tuning=self._tuning, degraded=self._degraded,
                n_nodes=self._n_nodes, hierarchical=self._hierarchical,
            )
            ov = self._overrides.get(node.tune_key)
            if ov is not None:
                plan_mod.apply_tuned(node, red, ov)
            self._call_i += 1
            self._nodes.append(node)
            self._meta[node.idx] = (red, target)
            v = math.prod(target.shape[1:]) if target.ndim > 1 else 1
            self._tune_info[node.idx] = (
                "dense", target.shape[0] if target.ndim else 0, v, red.name,
                str(target.dtype), None, red.pallas_segment is not None,
            )
            if self._cse and not (
                wire == "int8" and red.name == "sum"
            ):
                ck = self._cse_key(
                    kind, source_key, local, mapper, red, target,
                    node.engine, wire, key_range, env,
                )
                hit = self._cse_index.get(ck)
                if hit is not None:
                    node.cse_of = hit
                    return PlanValue(self, node.idx)
                self._cse_index[ck] = node.idx
        else:
            idx, node = self._next_node(MapReduceNode)
            self._meta[idx] = (red, target)
            if node is None:
                node = plan_mod.build_mapreduce_node(
                    idx=idx, kind=kind, src=src_desc, source_key=source_key,
                    mapper=mapper, red=red, target=target, engine=engine,
                    wire=wire, key_range=key_range, env=env,
                    n_nodes=self._n_nodes, hierarchical=self._hierarchical,
                )
            elif node.cse_of is not None:
                return PlanValue(self, node.idx)
            elif node.dead:
                return PlanValue(self, node.idx)

        resolved = node.engine
        feedback = (
            wire == "int8" and red.name == "sum"
            and resolved in ("eager", "pallas")
        )
        node.feedback = feedback
        # Deferrable (and therefore batchable/prunable): a built-in
        # reducer's eager or pallas plan without error feedback — exactly
        # the ops whose collective is one elementwise reduce of a partial.
        deferrable = (
            resolved in ("eager", "pallas")
            and not feedback
            and red is _BUILTIN.get(red.name)
            and (self._batch or self._prune)
        )
        stage, _ = _mr.dense_shard_stage(
            kind, src_static, mapper, red, target, resolved, wire,
            self._n_shards, with_stats=False, feedback=feedback,
            collect=not deferrable, tuned=getattr(node, "tuned", None),
            hier=node.hier,
        )
        residual = None
        if feedback:
            if self._mode == "discover":
                node.residual_spec = (tuple(target.shape), jnp.float32)
                residual = jnp.zeros(target.shape, jnp.float32)
            else:
                residual = self._residuals[self._res_i]
        total, _live, _kp, new_residual = stage(env, local, self._coll, residual)
        if feedback:
            if self._mode == "execute":
                self._residuals[self._res_i] = new_residual
            self._res_i += 1
        if deferrable:
            self._partials[node.idx] = (total, red, wire, node.hier)
            self._pending.append(node.idx)
            return PlanValue(self, node.idx)
        self._totals[node.idx] = total
        self._results[node.idx] = red.combine(target, total.astype(target.dtype))
        return self._results[node.idx]

    def _map_reduce_hash(
        self, source, mapper, red, target, *, engine, env, shuffle_slack,
        key_range,
    ):
        """Hash-target op inside a program: per-shard table state.

        The target is identified by its backing buffers (stable across
        iterations — drivers capture the same ``DistHashMap``); its table is
        fetched from / written back to the threaded hash state, so several
        ops (or iterations) targeting the same map compose sequentially.
        Never deferred, CSE'd or pruned: the op *mutates* threaded state.
        """
        kind, src_static, local, src_desc, source_key = (
            self._resolve_program_source(source)
        )
        if self._mode == "discover":
            node = plan_mod.build_mapreduce_node(
                idx=self._call_i, kind=kind, src=src_desc,
                source_key=source_key, mapper=mapper, red=red, target=target,
                engine=engine, wire="none", key_range=key_range, env=env,
                tuning=self._tuning, degraded=self._degraded,
                n_nodes=self._n_nodes, hierarchical=self._hierarchical,
            )
            ov = self._overrides.get(node.tune_key)
            if ov is not None:
                plan_mod.apply_tuned(node, red, ov)
            self._call_i += 1
            self._nodes.append(node)
            vals = target.table.vals
            v = math.prod(vals.shape[2:]) if vals.ndim > 2 else 1
            self._tune_info[node.idx] = (
                "hash", 0, v, red.name, str(vals.dtype), key_range,
                red.pallas_hash is not None,
            )
        else:
            _, node = self._next_node(MapReduceNode)
        resolved = node.engine if node is not None else plan_mod.resolve_engine(
            engine, target, red
        )
        tkey = ("hashtarget",) + _source_key("hashmap", target)[1:]
        if tkey not in self._hash_tables:
            if self._mode != "discover":
                raise ValueError(
                    "hash target not registered during discovery — targets "
                    "must be the same DistHashMap objects across iterations"
                )
            # Shape-faithful per-shard stand-in (strip the [n_shards] dim).
            keys, vals = target.table.keys, target.table.vals
            self._hash_tables[tkey] = C.HashTable(
                jnp.full(keys.shape[1:], C.EMPTY_KEY, keys.dtype),
                jnp.full(
                    vals.shape[1:], red.identity(vals.dtype), vals.dtype
                ),
                jnp.zeros((), jnp.int32),
            )
        if self._mode == "discover":
            self._hash_targets.setdefault(tkey, target)
        table = self._hash_tables[tkey]
        stage, _meta = _mr.hash_shard_stage(
            kind, src_static, mapper, red, target.table.vals.dtype, resolved,
            shuffle_slack, self._n_shards, key_range=key_range,
            tuned=getattr(node, "tuned", None),
        )
        table, _le, _ls, _kp = stage(env, table, local, self._coll)
        self._hash_tables[tkey] = table
        if self._mode == "discover" and node is not None:
            self._local_producers[id(table.keys)] = node.idx
        return LocalHashMap(table, red.name)

    def foreach(self, v, fn: Callable, env: Any = None) -> LocalVector:
        """Elementwise map over a ``DistVector`` source or a ``LocalVector``.

        Returns a ``LocalVector`` — the result stays on-shard, feeding later
        ops in the same program without any collective.
        """
        env = jax.tree_util.tree_map(
            lambda x: x._force() if isinstance(x, PlanValue) else x,
            env, is_leaf=_is_plan_value,
        )
        data, n, src_desc, source_key = self._resolve_vector_source(
            v, "ctx.foreach"
        )
        if self._mode == "discover":
            node = ForeachNode(
                idx=self._call_i, src=src_desc, source_key=source_key, fn=fn
            )
            self._call_i += 1
            self._nodes.append(node)
            idx = node.idx
        else:
            idx, _ = self._next_node(ForeachNode)
        out = jax.vmap(fn)(data) if env is None else jax.vmap(
            lambda x: fn(x, env)
        )(data)
        if self._mode == "discover":
            self._local_producers[id(out)] = idx
        return LocalVector(out, n)

    def topk(
        self, v, k: int, score_fn: Callable | None = None, env: Any = None,
        engine: str | None = None,
    ) -> tuple[Array, Array]:
        """Container-level top-k inside a program: per-shard ``lax.top_k``,
        one all_gather of ``k·n_shards`` candidates, global re-select.

        Returns replicated ``(rows [m, ...], scores [m])`` with
        ``m = min(k, kk·n_shards)``.  The plan records this as a
        :class:`ContainerOpNode`; an ``engine=`` request is *surfaced* on the
        node (and in ``explain``) rather than silently dropped — a container
        op's plan is fixed by the container, no engine can change it.
        """
        env = jax.tree_util.tree_map(
            lambda x: x._force() if isinstance(x, PlanValue) else x,
            env, is_leaf=_is_plan_value,
        )
        data, n, src_desc, source_key = self._resolve_vector_source(
            v, "ctx.topk"
        )
        if self._mode == "discover":
            score_name = (
                "value" if score_fn is None
                else getattr(score_fn, "__qualname__", repr(score_fn))
            )
            self._nodes.append(ContainerOpNode(
                idx=self._call_i, op="topk", src=src_desc,
                source_key=source_key, params=f"k={k} score={score_name}",
                engine_requested=engine,
            ))
            self._call_i += 1
        else:
            self._next_node(ContainerOpNode)
        per = data.shape[0]
        kk = min(k, per)
        base = self._coll.axis_index() * per
        if score_fn is None:
            scores = data.astype(jnp.float32)
        elif env is None:
            scores = jax.vmap(score_fn)(data)
        else:
            scores = jax.vmap(lambda x: score_fn(x, env))(data)
        idx_in = jnp.arange(per) + base
        scores = jnp.where(idx_in < n, scores, -jnp.inf)
        s, i = jax.lax.top_k(scores, kk)
        cand = jnp.take(data, i, axis=0)
        gs = self._coll.all_gather_tiled(s)
        gc = self._coll.all_gather_tiled(cand)
        m = min(k, gs.shape[0])
        s2, i2 = jax.lax.top_k(gs, m)
        return jnp.take(gc, i2, axis=0), s2

    # -- plan assembly (discover mode) ----------------------------------------

    def build_plan(self, state_desc: str, passes: tuple) -> Plan:
        nodes = list(self._nodes)
        nodes.append(GlueNode(idx=len(nodes), desc="state update (user glue)"))
        # prune-dead-sources: a source is live iff some live node reads it.
        live_keys: set[tuple] = set()
        for n in nodes:
            if isinstance(n, MapReduceNode) and (n.dead or n.cse_of is not None):
                continue
            sk = getattr(n, "source_key", None)
            if sk is not None:
                live_keys.add(sk)
        sources = [
            SourceInfo(
                key=k,
                desc=plan_mod.source_desc(_mr._source_kind(s), s),
                source=s,
                pruned=self._prune and k not in live_keys,
            )
            for k, s in self._sources.items()
        ]
        dead = sum(
            1 for n in nodes
            if isinstance(n, MapReduceNode) and n.dead
        )
        cse_hits = sum(
            1 for n in nodes
            if isinstance(n, MapReduceNode) and n.cse_of is not None
        )
        n_coll = self._coll.count  # _CountingCollectives in discover mode
        unbatched = n_coll + sum(
            len(g) - 1 for g in self._groups.values()
        )
        residual_specs = [
            n.residual_spec
            for n in nodes
            if isinstance(n, MapReduceNode) and n.residual_spec is not None
        ]
        return Plan(
            nodes=nodes,
            sources=sources,
            state_desc=state_desc,
            n_shards=self._n_shards,
            passes=passes,
            groups=dict(self._groups),
            group_keys=dict(self._group_keys),
            n_nodes=self._n_nodes,
            collectives_per_iter=n_coll,
            collectives_unbatched=unbatched,
            cse_hits=cse_hits,
            dead_ops=dead,
            pruned_sources=sum(1 for s in sources if s.pruned),
            residual_specs=residual_specs,
            hash_targets=dict(self._hash_targets),
            tune_info=dict(self._tune_info),
        )


def _as_checkpoint_manager(checkpoint):
    """Accept a ``CheckpointManager``, a directory path, or ``None``."""
    if checkpoint is None:
        return None
    if isinstance(checkpoint, str):
        from repro.checkpoint.manager import CheckpointManager

        return CheckpointManager(checkpoint)
    return checkpoint


def _state_desc(state) -> str:
    leaves, treedef = jax.tree_util.tree_flatten(state)
    descs = ",".join(
        f"{str(jnp.asarray(x).dtype)}[{'x'.join(map(str, jnp.shape(x)))}]"
        for x in leaves
    )
    return f"{treedef.num_leaves} leaves: {descs}"


class Program:
    """A user step function planned, optimized and lowered to one executable
    per state signature.

    Built by ``BlazeSession.program(step_fn)``; ``step_fn(ctx, state)`` must
    return a state pytree with the same structure/shapes/dtypes (it is a
    ``fori_loop`` carry).  Call ``program(state, n_iters)`` for one dispatch
    of ``n_iters`` fused iterations, or drive it with
    ``session.run_loop(...)``.  The trip count is traced, so full blocks and
    the remainder block share the single compiled executable.

    ``program.plan`` (after :meth:`build` or the first dispatch) is the
    optimized :class:`repro.core.plan.Plan`; ``session.explain(program)``
    renders it.  ``passes=()`` disables the optimizer (CSE, collective
    batching, dead-source pruning) for apples-to-apples comparisons —
    ``benchmarks/paper_benchmarks.py::bench5_plan_batching`` uses exactly
    that to report collectives-per-iteration before/after.
    """

    def __init__(
        self, session, step_fn: Callable, *, mesh: Mesh | None = None,
        passes: tuple | None = None, tune: bool = False,
        overrides: dict | None = None, hierarchical: bool = True,
    ):
        self._session = session
        self._step_fn = step_fn
        self._mesh = mesh if mesh is not None else session.mesh
        self._n_shards = C.shard_count(self._mesh)
        # ``hierarchical=False`` keeps collectives flat even on a multi-node
        # mesh — the A/B baseline the scaling bench compares against.
        self._hierarchical = bool(hierarchical)
        self._n_nodes = C.n_nodes(self._mesh) if self._hierarchical else 1
        self._passes = DEFAULT_PASSES if passes is None else tuple(passes)
        # ``tune``: on first build per state signature, measure the candidate
        # grid for every tunable op (see _maybe_tune) and cache winners in
        # the session's TuningCache.  ``overrides`` pins tune_key -> config
        # for the throwaway measurement variants — such a variant never
        # recursively tunes.
        self._tune = bool(tune)
        self._overrides = overrides
        self._cache: dict = {}  # state signature -> (jitted fused fn, operands)
        self._plans: dict = {}  # state signature -> optimized Plan
        # state signature -> live per-shard error-feedback residuals, carried
        # ACROSS dispatches for the lifetime of this Program
        self._residual_state: dict = {}
        # state signature -> (hash-target key order, tuple of per-target
        # (keys, vals, overflow) sharded arrays) — like residuals, hash
        # tables are per-shard state that outlives each dispatch
        self._hash_state: dict = {}
        # state signature -> (stream-source key order, chunked containers):
        # out-of-core sources whose (data, base) operands arrive per
        # dispatch (run_stream) instead of being baked into the cache entry
        self._stream_state: dict = {}
        self._last_sig = None  # signature of the most recent dispatch
        self.plan: Plan | None = None  # most recently built plan
        self.stats = ProgramStats()
        self.feedback_slots = 0  # error-feedback residual slots (int8 sums)
        self.hash_slots = 0  # hash-target table slots threaded per iteration

    # -- build ---------------------------------------------------------------

    def _discover(self, state) -> Plan:
        ctx = ProgramContext(
            self._n_shards, "discover", passes=self._passes,
            tuning=self._session.tuning, overrides=self._overrides,
            degraded=getattr(self._session, "_degraded", None),
            n_nodes=self._n_nodes, hierarchical=self._hierarchical,
        )

        def run(s):
            out = self._step_fn(ctx, s)
            return ctx._finalize_state(out)

        out = jax.eval_shape(run, state)
        in_flat, in_tree = jax.tree_util.tree_flatten(state)
        out_flat, out_tree = jax.tree_util.tree_flatten(out)
        if in_tree != out_tree:
            raise ValueError(
                "step_fn must return a state pytree with the same structure "
                f"it was given (got {out_tree}, want {in_tree})"
            )
        for i, (a, b) in enumerate(zip(in_flat, out_flat)):
            a_shape, a_dt = jnp.shape(a), jnp.asarray(a).dtype
            if (a_shape, a_dt) != (b.shape, b.dtype):
                raise ValueError(
                    "step_fn must preserve state leaf shapes/dtypes (it is a "
                    f"fori_loop carry); leaf {i} went from {a_shape}/{a_dt} "
                    f"to {b.shape}/{b.dtype}"
                )
        return ctx.build_plan(_state_desc(state), self._passes)

    def _maybe_tune(self, state) -> None:
        """First-dispatch autotuning: measure the candidate grid and cache
        the winners in the session's TuningCache.

        A probe discovery finds every tunable op (kernel available, not
        ``naive``, not already measured for its ``tune_key``).  Candidate
        configurations are index-aligned across ops — variant ``j`` pins
        each op to its ``min(j, len-1)``-th candidate — and each variant is
        a throwaway ``Program`` with ``overrides`` set, dispatched once to
        warm/compile and once timed end-to-end.  The fastest variant's
        per-op configs are stored keyed by ``tune_key``, so the real build
        that follows (and any later program/map_reduce/serve dispatch with
        the same op) picks them up from the cache.  Streamed (chunked-
        source) programs are skipped: their operands arrive per dispatch.
        """
        from repro.core import cost as cost_mod

        session = self._session
        tuning = session.tuning
        probe = self._discover(state)
        if any(
            _mr._source_kind(s.source) == "chunked"
            for s in probe.live_sources()
        ):
            return
        cand_lists: list[tuple[str, list]] = []
        seen: set[str] = set()
        for n in probe.mapreduce_nodes():
            if n.dead or n.cse_of is not None:
                continue
            if n.tuned is not None or n.tune_key in seen:
                continue
            if tuning.peek(n.tune_key) is not None:
                continue
            info = probe.tune_info.get(n.idx)
            if info is None:
                continue
            tkind, k, v, red_name, dtype_s, key_range, has_kernel = info
            if not has_kernel or n.engine_requested == "naive":
                continue
            dtype = jnp.dtype(dtype_s)
            if tkind == "hash":
                cands = cost_mod.hash_tuning_candidates(
                    v, red_name, dtype, key_range=key_range
                )
            else:
                cands = cost_mod.dense_tuning_candidates(k, v, red_name, dtype)
            if len(cands) < 2:
                continue
            seen.add(n.tune_key)
            cand_lists.append((n.tune_key, cands))
        if not cand_lists:
            return
        n_variants = max(len(c) for _, c in cand_lists)
        best_wall, best_set = None, None
        measured = 0
        for j in range(n_variants):
            ov = {
                tk: cands[min(j, len(cands) - 1)] for tk, cands in cand_lists
            }
            variant = Program(
                session, self._step_fn, mesh=self._mesh, passes=self._passes,
                overrides=ov, hierarchical=self._hierarchical,
            )
            try:
                faults.fault_point("tuning.measure")
                out = variant(state, 1)
                jax.block_until_ready(jax.tree_util.tree_leaves(out))
                t0 = time.perf_counter()
                out = variant(state, 1)
                jax.block_until_ready(jax.tree_util.tree_leaves(out))
                wall = time.perf_counter() - t0
            except faults.InjectedFault as e:
                # A faulted candidate is simply not measured — tuning is an
                # optimisation, so the fault is absorbed, never retried.
                faults.record("absorbed", e)
                continue
            except Exception:
                continue
            measured += 1
            if best_wall is None or wall < best_wall:
                best_wall, best_set = wall, ov
        tuning.record_measurements(measured)
        session.stats.tune_measurements += measured
        if best_set is None:
            return
        for tk, cfg in best_set.items():
            tuning.put(
                tk,
                dataclasses.replace(cfg, source="measured", wall_s=best_wall),
            )

    def build(self, state) -> Plan:
        """Discover, optimize and lower the plan for ``state``'s signature
        WITHOUT dispatching (compilation itself stays lazy under jit).
        Returns the optimized :class:`Plan` — what ``session.explain``
        renders."""
        key = _mr._abstract(state)
        self._build(state)
        return self._plans[key]

    def _build(self, state):
        key = _mr._abstract(state)
        if key in self._cache:
            self.plan = self._plans[key]
            return self._cache[key]
        if self._tune and self._overrides is None:
            self._maybe_tune(state)
        plan = self._discover(state)
        self._plans[key] = plan
        self.plan = plan
        self.feedback_slots = len(plan.residual_specs)
        self.hash_slots = len(plan.hash_targets)
        n_shards = self._n_shards
        n_nodes = self._n_nodes
        hierarchical = self._hierarchical
        mesh = self._mesh
        step_fn = self._step_fn
        passes = self._passes

        operands: list = []
        specs: list = []
        source_keys: list[tuple] = []
        sizes: list[int] = []
        stream_keys: list[tuple] = []
        stream_sources: list = []
        for s in plan.live_sources():
            kind = _mr._source_kind(s.source)
            if kind == "chunked":
                # Out-of-core source: its (data, base) operands are supplied
                # fresh per dispatch by run_stream — never baked into the
                # cache entry like device-resident containers below.
                stream_keys.append(s.key)
                stream_sources.append(s.source)
                continue
            ops, sp = _mr._source_operands(kind, s.source, mesh)
            operands.extend(ops)
            specs.extend(sp)
            source_keys.append(s.key)
            sizes.append(len(ops))
        n_res = len(plan.residual_specs)
        hash_keys = list(plan.hash_targets)
        n_hash = len(hash_keys)
        n_stream = len(stream_keys)

        def shard_body(state_, n_iters, *flat):
            # flat = per-op feedback residuals, then per-target hash tables
            # (both sharded: each shard carries its own), then (data, base)
            # per streamed block source, then the live source operands.
            res_in = flat[:n_res]
            hash_in = flat[n_res:n_res + 3 * n_hash]
            stream_in = flat[n_res + 3 * n_hash:n_res + 3 * n_hash + 2 * n_stream]
            flat_ops = flat[n_res + 3 * n_hash + 2 * n_stream:]
            # Spans both mesh axes on a 2-D mesh; whether a given reduce is
            # hierarchical is per-node (``hier=`` on each call), so the flat
            # A/B baseline shares this same object.
            coll = _mr.make_collectives(mesh, n_shards)
            op_map, i = {}, 0
            for sk, k in zip(source_keys, sizes):
                op_map[sk] = tuple(flat_ops[i:i + k])
                i += k
            for j, sk in enumerate(stream_keys):
                op_map[sk] = (stream_in[2 * j], stream_in[2 * j + 1])

            def one_step(_, carry):
                st, residuals, tables = carry
                ctx = ProgramContext(
                    n_shards, "execute", coll=coll, operands=op_map,
                    residuals=list(residuals),
                    hash_tables=dict(zip(hash_keys, tables)),
                    plan=plan, passes=passes,
                    n_nodes=n_nodes, hierarchical=hierarchical,
                )
                new_st = ctx._finalize_state(step_fn(ctx, st))
                return (
                    new_st,
                    tuple(ctx._residuals),
                    tuple(ctx._hash_tables[hk] for hk in hash_keys),
                )

            res0 = tuple(r[0] for r in res_in)  # drop the local shard dim
            h0 = tuple(
                C.HashTable(
                    hash_in[3 * i_][0], hash_in[3 * i_ + 1][0],
                    hash_in[3 * i_ + 2][0],
                )
                for i_ in range(n_hash)
            )
            out_state, res_out, h_out = jax.lax.fori_loop(
                0, n_iters, one_step, (state_, res0, h0)
            )
            return (
                out_state,
                tuple(r[None] for r in res_out),
                tuple(
                    (t.keys[None], t.vals[None], t.overflow[None])
                    for t in h_out
                ),
            )

        d = C.data_pspec(self._mesh)
        stream_specs: tuple = ()
        for _ in stream_keys:
            stream_specs += (d, P())  # block rows sharded, base replicated
        fused = shard_map(
            shard_body,
            mesh=self._mesh,
            in_specs=(
                (P(), P()) + (d,) * (n_res + 3 * n_hash)
                + stream_specs + tuple(specs)
            ),
            out_specs=(P(), d, d),
            check_vma=False,
        )
        # Residual AND hash-table state outlive the dispatch: the executable
        # returns the updated per-shard arrays and the next dispatch feeds
        # them back in, so both stay live across blocks (even unroll=1).
        # A rebuild for an already-carried signature (engine degradation
        # dropped the executable mid-run) keeps the live carry — degradation
        # must not lose accumulated state.
        if key not in self._residual_state:
            self._residual_state[key] = tuple(
                jnp.zeros((n_shards,) + shape, dtype)
                for shape, dtype in plan.residual_specs
            )
        if key not in self._hash_state:
            self._hash_state[key] = (
                hash_keys,
                tuple(
                    (hm.table.keys, hm.table.vals, hm.table.overflow)
                    for hm in plan.hash_targets.values()
                ),
            )
        self._stream_state[key] = (tuple(stream_keys), tuple(stream_sources))
        entry = (jax.jit(fused), tuple(operands))
        self._cache[key] = entry
        self.stats.compiles += 1
        self._session.stats.program_compiles += 1
        return entry

    @property
    def plan_hash(self) -> str | None:
        """Stable digest of the most recently built plan (``None`` before
        the first build) — the cross-request cache identity the serving
        layer keys on."""
        return None if self.plan is None else self.plan.hash

    def reset_carry(self) -> None:
        """Reset per-shard carry state (error-feedback residuals and hash
        tables) to pristine for every built signature, WITHOUT dropping
        compiled executables.

        Long-lived owners — notably the serving layer — call this between
        logically independent queries that share one resident program, so
        one query's accumulated hash-table contents or residuals cannot
        leak into the next.  ``hash_result`` reflects only dispatches made
        since the most recent reset.
        """
        for key, plan in self._plans.items():
            self._residual_state[key] = tuple(
                jnp.zeros((self._n_shards,) + shape, dtype)
                for shape, dtype in plan.residual_specs
            )
            self._hash_state[key] = (
                list(plan.hash_targets),
                tuple(
                    (hm.table.keys, hm.table.vals, hm.table.overflow)
                    for hm in plan.hash_targets.values()
                ),
            )

    # -- fault supervision ----------------------------------------------------

    def degrade(self) -> int:
        """Degrade every live Pallas node of this program to eager.

        Called by the session supervisor on a kernel fault: the faulted
        nodes' ``tune_key``s go into the session's degraded set (so every
        later build — this program's, a per-op call's, or another
        program's — resolves them straight to eager) and the compiled
        executables are dropped so the next dispatch rebuilds.  Carry state
        (residuals, hash tables) survives the rebuild; the tuning cache is
        never touched.  Returns how many nodes were degraded.
        """
        degraded = getattr(self._session, "_degraded", None)
        if degraded is None:
            return 0
        n = 0
        for key, plan in self._plans.items():
            hit = False
            for node in plan.mapreduce_nodes():
                if (
                    node.engine == "pallas"
                    and not node.dead
                    and node.cse_of is None
                ):
                    degraded.add(node.tune_key)
                    hit = True
                    n += 1
            if hit:
                self._cache.pop(key, None)
        return n

    # -- carry export/restore (epoch-granular resume) -------------------------

    def export_carry(self, state) -> dict:
        """The program's cross-dispatch carry for ``state``'s signature, as
        a checkpointable pytree: error-feedback residuals and hash-target
        tables.  Together with the user state and the loop position this
        fully determines the remainder of a run — the resume payload of
        ``run_loop``/``run_stream``."""
        key = _mr._abstract(state)
        self._build(state)
        _hash_keys, hash_tuples = self._hash_state[key]
        return {
            "residual": list(self._residual_state[key]),
            "hash": [list(t) for t in hash_tuples],
        }

    def import_carry(self, state, carry: dict) -> None:
        """Overwrite the carry for ``state``'s signature with a previously
        exported (and checkpoint-restored) one."""
        key = _mr._abstract(state)
        self._build(state)
        self._residual_state[key] = tuple(carry["residual"])
        hash_keys, _old = self._hash_state[key]
        self._hash_state[key] = (
            hash_keys,
            tuple(tuple(t) for t in carry["hash"]),
        )

    def checkpoint_payload(self, state, pos: int) -> dict:
        """The full resume payload: user state + carry + position."""
        return {
            "state": state,
            "carry": self.export_carry(state),
            "pos": jnp.asarray(pos, jnp.int32),
        }

    def save_checkpoint(self, manager, state, pos: int) -> str:
        """Supervised checkpoint save: transient ``checkpoint.write`` faults
        are retried (bounded), fatal ones propagate."""
        payload = self.checkpoint_payload(state, pos)
        tries = 0
        while True:
            try:
                return manager.save(pos, payload)
            except faults.FatalFault as e:
                faults.record("fatal", e)
                raise
            except faults.TransientFault as e:
                tries += 1
                if tries >= 3:
                    faults.record("fatal", e)
                    raise
                faults.record("retried", e)

    def restore_checkpoint(self, manager, state):
        """Restore the latest checkpoint into ``(state, position)``; returns
        ``(state, None)`` when no checkpoint exists.  The carry is installed
        on this program as a side effect."""
        template = self.checkpoint_payload(state, 0)
        step, restored = manager.restore_latest(template)
        if step is None:
            return state, None
        state = restored["state"]
        self.import_carry(state, restored["carry"])
        return state, int(jax.device_get(restored["pos"]))

    # -- run -----------------------------------------------------------------

    def __call__(self, state, n_iters: int = 1, *, stream_blocks=None):
        """One dispatch: ``n_iters`` fused iterations, device-resident.

        Programs reading chunked (out-of-core) sources take the resident
        block per dispatch via ``stream_blocks`` — a dict mapping each
        stream-source key to its ``(data, base)`` device operands.  Use
        :meth:`run_stream` rather than passing this by hand.
        """
        key = _mr._abstract(state)
        fn, operands = self._build(state)
        # Fault points fire BEFORE the executable runs or any carry is
        # written back, so a supervised retry of this dispatch is exact.
        faults.fault_point("dispatch")
        if self.plan is not None and faults.registry.armed:
            for node in self.plan.mapreduce_nodes():
                if node.engine != "pallas" or node.dead or node.cse_of is not None:
                    continue
                faults.fault_point(
                    "kernel.hash" if node.target_kind == "hash"
                    else "kernel.segment"
                )
        residuals = self._residual_state[key]
        hash_keys, hash_tuples = self._hash_state[key]
        flat_hash = [a for t in hash_tuples for a in t]
        stream_keys, _stream_sources = self._stream_state[key]
        if stream_keys and stream_blocks is None:
            raise ValueError(
                "program reads chunked (out-of-core) sources — drive it "
                "with program.run_stream(...) / session.run_stream(...)"
            )
        flat_stream = (
            [a for sk in stream_keys for a in stream_blocks[sk]]
            if stream_keys
            else []
        )
        out, new_residuals, new_hash = fn(
            state, jnp.asarray(n_iters, jnp.int32), *residuals, *flat_hash,
            *flat_stream, *operands,
        )
        self._residual_state[key] = new_residuals
        self._hash_state[key] = (hash_keys, tuple(new_hash))
        self._last_sig = key
        self.stats.dispatches += 1
        self.stats.iterations += int(n_iters)
        self._session.stats.dispatches += 1
        self._session.stats.program_dispatches += 1
        return out

    def run_stream(
        self,
        state,
        *,
        max_epochs: int = 1,
        cond: Callable | None = None,
        prefetch: bool = True,
        depth: int = 2,
        checkpoint=None,
        checkpoint_every: int | None = None,
        resume: bool = False,
    ):
        """Out-of-core epochs: stream every block through ONE executable.

        One *epoch* dispatches the program once per block of its chunked
        source(s), in order — the step function sees one resident block per
        dispatch (global indices via the traced ``base`` offset) and carries
        its accumulation in ``state`` / hash-table state.  ``prefetch=True``
        produces block k+1 (disk read, decompress, host→device transfer) on
        a background thread while block k reduces — double-buffered, depth
        bounded by ``depth``.  ``prefetch=False`` is the synchronous
        baseline: each dispatch is drained (``block_until_ready``) before
        the next block is even read, i.e. zero compute/transfer overlap —
        the A/B the streaming benchmark measures.

        ``cond(state) -> bool`` is evaluated once per epoch (one host sync),
        mirroring ``run_loop``.  Returns ``(state, StreamInfo)``.

        Epoch-granular fault tolerance: with ``checkpoint=`` (a
        ``CheckpointManager`` or a directory) and ``checkpoint_every=K``,
        the user state + program carry + epoch position are saved every K
        completed epochs; ``resume=True`` restores the latest checkpoint and
        continues from its epoch — bit-equal to the uninterrupted run,
        because the carry and position fully determine the remainder (a
        crash mid-epoch replays that epoch from its boundary).  Per-block
        dispatches run under the session's retry policy, so transient
        injected faults are absorbed in place.
        """
        from repro.data.pipeline import prefetch_iter

        manager = _as_checkpoint_manager(checkpoint)
        if resume and manager is None:
            raise ValueError("resume=True needs checkpoint=")
        compiles0 = self.stats.compiles
        self._build(state)
        key = _mr._abstract(state)
        stream_keys, stream_sources = self._stream_state[key]
        if not stream_keys:
            raise ValueError(
                "program has no chunked sources — use run_loop/__call__"
            )
        counts = {src.n_blocks for src in stream_sources}
        if len(counts) != 1:
            raise ValueError(
                f"chunked sources disagree on block count: {sorted(counts)}"
            )
        n_blocks = counts.pop()
        mesh = self._mesh
        bytes_per_block = sum(src.block_nbytes for src in stream_sources)

        def produce(b):
            views = {}
            for sk, src in zip(stream_keys, stream_sources):
                bv = src.block_view(b, mesh)
                views[sk] = (bv.data, bv.base)
            return views

        resumed_from = None
        if resume:
            state, pos = self.restore_checkpoint(manager, state)
            if pos is not None:
                resumed_from = pos
        epochs = resumed_from or 0
        blocks = syncs = 0
        converged = False
        supervised = getattr(self._session, "supervised", None)
        while epochs < max_epochs:
            if prefetch:
                it = prefetch_iter(produce, range(n_blocks), depth=depth)
            else:
                it = ((b, produce(b)) for b in range(n_blocks))
            for _b, views in it:
                if supervised is not None:
                    state = supervised(
                        lambda: self(state, 1, stream_blocks=views),
                        program=self,
                    )
                else:
                    state = self(state, 1, stream_blocks=views)
                blocks += 1
                if not prefetch:
                    jax.block_until_ready(jax.tree_util.tree_leaves(state))
            epochs += 1
            if manager is not None and checkpoint_every:
                if epochs % checkpoint_every == 0:
                    self.save_checkpoint(manager, state, epochs)
            if cond is not None:
                self._session.stats.host_syncs += 1
                syncs += 1
                if bool(cond(state)):
                    converged = True
                    break
        return state, StreamInfo(
            epochs=epochs,
            n_blocks=n_blocks,
            dispatches=blocks,
            host_syncs=syncs,
            converged=converged,
            compiles=self.stats.compiles - compiles0,
            prefetch=prefetch,
            bytes_streamed=blocks * bytes_per_block,
            resumed_from=resumed_from,
        )

    def hash_result(self, target: C.DistHashMap) -> C.DistHashMap:
        """The accumulated state of a hash target used by this program.

        ``target`` must be the same ``DistHashMap`` object the step function
        captured; the returned map holds the tables as of the most recent
        dispatch (the original object is never mutated).
        """
        tkey = ("hashtarget",) + _source_key("hashmap", target)[1:]
        sig = self._last_sig
        if sig is None or sig not in self._hash_state:
            raise ValueError("program has not dispatched yet")
        hash_keys, hash_tuples = self._hash_state[sig]
        if tkey not in hash_keys:
            raise KeyError(
                "not a hash target of this program (targets are keyed by "
                "the identity of their backing buffers)"
            )
        keys, vals, ovf = hash_tuples[hash_keys.index(tkey)]
        return C.DistHashMap(
            C.HashTable(keys, vals, ovf), reducer_name=target.reducer_name
        )
