"""Blaze MapReduce on SPMD JAX — eager reduction, compact wire, dense fast path.

``map_reduce(source, mapper, reducer, target)`` mirrors the paper's four-arg
functional API:

* **source** — ``DistRange`` | ``DistVector`` | ``DistHashMap`` |
  ``ChunkedDistVector`` (out-of-core: the session streams it one resident
  block at a time through ONE cached executable, prefetching block k+1
  while block k reduces — mappers still see global indices).
* **mapper** — paper-style emit-handler function, traced under ``vmap``:
    - ``DistRange``:   ``mapper(value, emit)``            (+ ``env`` if given)
    - ``DistVector``:  ``mapper(index, value, emit)``     (+ ``env`` if given)
    - ``DistHashMap``: ``mapper(key, value, emit)``       (+ ``env`` if given)
  ``emit(key, value, mask=True)`` may be called any static number of times;
  ``key``/``value`` may be scalars or 1-D batches (a line's worth of words),
  ``mask`` marks which emitted lanes are real.
* **reducer** — ``"sum" | "prod" | "min" | "max"`` or a custom ``Reducer``.
* **target** — a dense array of shape ``[K, ...]`` (the paper's small fixed
  key range / ``std::vector`` target: key == index) or a ``DistHashMap``.
  Per the paper, the target is *merged into*, never cleared.
* **env** — optional pytree of iteration-varying state (PageRank scores,
  k-means centroids, …) broadcast to every shard.  Keeping the mapper object
  static and threading state through ``env`` lets the engine reuse one
  compiled executable across iterations — executables are memoized per
  ``BlazeSession`` (see ``repro.core.session``), keyed on the abstract
  signature of everything that shapes the plan; the free ``map_reduce``
  routes through a process-wide default session.

Engines:

* ``engine="eager"`` (Blaze): duplicate keys are combined **on-device before
  any collective** (sort + segmented scan, or a dense ``[K]`` accumulator when
  the key range is small and fixed), then the shuffle moves locally-reduced
  data only — ``psum`` for dense targets, hash-partitioned ``all_to_all`` of
  unique pairs for hash targets.
* ``engine="pallas"`` (Blaze, kernel combine): the eager plan with every
  per-shard combine lowered through a Pallas kernel (interpret mode off-TPU).
  Dense targets run the segment-reduce kernel (``Reducer.pallas_segment`` —
  one-hot matmul on the MXU, VMEM-resident ``[K, V]`` accumulator); hash
  targets run the hash-aggregation kernel (``Reducer.pallas_hash`` — an
  open-addressing VMEM table that replaces both sort-based
  ``unique_combine`` passes *and* the ``hashmap_insert`` scatter loop).
  The static-key fast path and the shuffle collectives are identical to
  eager.  ``MapReduceStats`` additionally reports the kernel launch: block
  size, lane occupancy, and (hash) table capacity + probe depth.
* ``engine="naive"`` (conventional MapReduce / Spark's wide shuffle): every
  emitted pair goes on the wire unreduced; reduction happens only at the
  destination shard.
* ``engine="auto"``: resolved by the planner (``repro.core.plan``'s
  resolve-engines pass, applied per plan node) — pallas for built-in
  reducers whose accumulator (dense ``[K]`` / hash table) stays VMEM-sized,
  eager otherwise.

``wire`` ∈ {"none", "bf16", "int8"} applies the fast-serialization analogue to
the collective payload (dense-sum targets).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro.core import containers as C
from repro.core import faults
from repro.core.plan import abstract_sig as _abstract, hier_collective_desc
from repro.core.reducers import Reducer, get_reducer
from repro.core.serialization import narrowest_int_dtype

Array = jax.Array


@dataclasses.dataclass
class MapReduceStats:
    """Wire accounting + runtime counters for one map_reduce call.

    Runtime fields hold device arrays until ``finalize()`` — the engine never
    blocks dispatch to materialise statistics.
    """

    engine: str
    collective: str  # which collective carried the shuffle
    pairs_emitted: Any  # live emitted pairs (device array until finalize)
    pairs_shipped: Any  # pairs that went on the wire post eager-combine
    shuffle_payload_bytes: Any  # bytes the shuffle moves (global, one call)
    # Topology split of the shuffle payload (combine-edge model): a reduce
    # over P participants has P-1 combine edges; hierarchical mode keeps
    # `n_shards - n_nodes` of them on fast intra-node links at FULL
    # precision and only `n_nodes - 1` on slow inter-node links at wire
    # precision, while a flat reduce on a multi-node mesh pays every edge
    # inter-node.  Both zero on 1-node meshes' inter side.
    intra_bytes: Any = 0  # bytes crossing intra-node links
    inter_bytes: Any = 0  # bytes crossing inter-node links
    overflow: Any = None  # hash-table / bucket drops
    compiles: int = 0  # 1 iff this call lowered+compiled a new executable
    cache_hits: int = 0  # 1 iff this call reused a session-cached executable
    dispatches: int = 1  # executable launches this call (always 1 standalone;
    #                      fused programs amortise N ops over one dispatch)
    # engine="pallas" only: the kernel's launch accounting (segment-reduce
    # for dense targets, hash-aggregation for DistHashMap targets).
    kernel_block_n: int | None = None  # pair-block size the kernel ran with
    kernel_lanes: int | None = None  # padded pair-lanes processed (global)
    kernel_pairs: Any = None  # live pairs entering the kernel (device array)
    kernel_occupancy: float | None = None  # kernel_pairs / kernel_lanes
    # hash-aggregation kernel only: table geometry + probe depth.
    kernel_table_cap: int | None = None  # pre-shuffle combine table capacity
    kernel_probe_depth: int | None = None  # configured max probe rounds
    # stable digest of this op's plan node (repro.core.plan) — identical for
    # the per-op and program spellings of the same op.
    plan_hash: str | None = None
    # supervised-dispatch provenance (repro.core.faults / session supervisor):
    # the engine this node was degraded FROM (None = never degraded), dispatch
    # retries absorbed, and hash-capacity escalations taken for this call.
    degraded_engine: str | None = None
    retries: int = 0
    escalations: int = 0

    def finalize(self) -> "MapReduceStats":
        def _get(x):
            if isinstance(x, (jax.Array, np.ndarray)):
                return int(np.asarray(jax.device_get(x)).sum())
            return x

        kernel_pairs = _get(self.kernel_pairs)
        occupancy = (
            kernel_pairs / self.kernel_lanes
            if self.kernel_lanes and kernel_pairs is not None
            else None
        )
        return MapReduceStats(
            engine=self.engine,
            collective=self.collective,
            pairs_emitted=_get(self.pairs_emitted),
            pairs_shipped=_get(self.pairs_shipped),
            shuffle_payload_bytes=_get(self.shuffle_payload_bytes),
            intra_bytes=_get(self.intra_bytes),
            inter_bytes=_get(self.inter_bytes),
            overflow=_get(self.overflow),
            compiles=self.compiles,
            cache_hits=self.cache_hits,
            dispatches=self.dispatches,
            kernel_block_n=self.kernel_block_n,
            kernel_lanes=self.kernel_lanes,
            kernel_pairs=kernel_pairs,
            kernel_occupancy=occupancy,
            kernel_table_cap=self.kernel_table_cap,
            kernel_probe_depth=self.kernel_probe_depth,
            plan_hash=self.plan_hash,
            degraded_engine=self.degraded_engine,
            retries=self.retries,
            escalations=self.escalations,
        )


class _Emitter:
    """Collects emit() calls during the vmapped mapper trace.

    Keys passed as Python ints are *static* (known at trace time): the dense
    engine then skips id arrays entirely and uses a fused whole-axis
    reduction — the paper's §2.3.3 per-thread scalar accumulator, at compile
    time.  (Monte-Carlo π's ``emit(0, …)``, PageRank's sink/delta sums and
    the GMM log-likelihood all take this path.)
    """

    def __init__(self):
        self.keys: list[Array] = []
        self.vals: list[Array] = []
        self.masks: list[Array] = []
        self.static_keys: list[int | None] = []

    def __call__(self, key, value, mask=True):
        static = int(key) if isinstance(key, (int, np.integer)) else None
        key = jnp.asarray(key, jnp.int32)
        value = jnp.asarray(value)
        mask = jnp.asarray(mask, bool)
        if key.ndim == 0:
            key = key[None]
        width = key.shape[0]
        if value.ndim == 0 or value.shape[:1] != (width,):
            value = jnp.broadcast_to(value, (width,) + value.shape)
        mask = jnp.broadcast_to(mask, (width,))
        self.keys.append(key)
        self.vals.append(value)
        self.masks.append(mask)
        self.static_keys.append(static)

    def structured(self):
        if not self.keys:
            raise ValueError("mapper emitted nothing (statically)")
        return tuple(zip(self.keys, self.vals, self.masks))


def _run_mapper_structured(
    source_kind, source_static, mapper, shard_idx, local, n_shards, env
):
    """vmap the emit-style mapper → (per-emit entries, static keys).

    entries: tuple of (keys [n,w], vals [n,w,...], mask [n,w]) per emit call;
    static_keys: per-emit Python int if the key was trace-time constant.
    """
    extra = (env,) if env is not None else ()
    meta: dict = {}

    def trace(*args):
        em = _Emitter()
        mapper(*args, em, *extra)
        meta["static"] = em.static_keys
        return em.structured()

    if source_kind == "range":
        values, valid = source_static.local_values(shard_idx, n_shards)
        entries = jax.vmap(trace)(values)
        elem_mask = valid
    elif source_kind == "vector":
        data, n_true = local
        per = data.shape[0]
        idx = jnp.arange(per) + shard_idx * per
        elem_mask = idx < n_true
        entries = jax.vmap(trace)(idx, data)
    elif source_kind == "chunked":
        # One block of an out-of-core dataset: ``base`` (traced) shifts this
        # shard's rows to their GLOBAL indices; ``idx < n_total`` masks both
        # last-block padding and shard padding, exactly like "vector".
        data, n_total, base = local
        per = data.shape[0]
        idx = base + jnp.arange(per) + shard_idx * per
        elem_mask = idx < n_total
        entries = jax.vmap(trace)(idx, data)
    elif source_kind == "hashmap":
        tkeys, tvals = local
        elem_mask = tkeys != C.EMPTY_KEY
        entries = jax.vmap(trace)(tkeys, tvals)
    else:
        raise TypeError(f"unsupported source kind {source_kind}")

    entries = [
        (k, v, m & elem_mask[:, None]) for (k, v, m) in entries
    ]
    return entries, meta["static"]


def _flatten_entries(entries):
    """Structured emits → flat (keys, vals, mask) arrays (shuffle paths)."""
    keys = jnp.concatenate([k.reshape(-1) for k, _, _ in entries])
    vals = jnp.concatenate(
        [v.reshape((-1,) + v.shape[2:]) for _, v, _ in entries], axis=0
    )
    masks = jnp.concatenate([m.reshape(-1) for _, _, m in entries])
    return keys, vals, masks


def _run_mapper(source_kind, source_static, mapper, shard_idx, local, n_shards, env):
    entries, _ = _run_mapper_structured(
        source_kind, source_static, mapper, shard_idx, local, n_shards, env
    )
    return _flatten_entries(entries)


# ---------------------------------------------------------------------------
# Shuffle plumbing: bucket pairs by destination shard, fixed capacity
# ---------------------------------------------------------------------------


def bucket_by_dest(
    keys: Array, vals: Array, valid: Array, n_dest: int, cap: int, ident
) -> tuple[Array, Array, Array]:
    """Pack pairs into a ``[n_dest, cap]`` buffer keyed by hash ownership.

    Returns (bkeys, bvals, n_dropped).  Position within a bucket is the pair's
    rank among same-destination pairs (stable sort + first-occurrence index) —
    fully vectorised, no host round-trip.
    """
    n = keys.shape[0]
    dest = jnp.where(valid, C.shard_of_key(keys, n_dest).astype(jnp.int32), n_dest)
    # Rank-within-bucket (and which pairs survive a full bucket) depends on
    # the sort preserving emission order among equal destinations — request
    # stability explicitly rather than relying on the backend default.
    order = jnp.argsort(dest, stable=True)
    sdest = jnp.take(dest, order)
    skeys = jnp.take(keys, order)
    svals = jnp.take(vals, order, axis=0)
    first = jnp.searchsorted(sdest, sdest, side="left")
    rank = jnp.arange(n) - first
    ok = (sdest < n_dest) & (rank < cap)
    flat = jnp.where(ok, sdest * cap + rank, n_dest * cap)
    bkeys = jnp.full((n_dest * cap,), C.EMPTY_KEY, jnp.int32)
    bkeys = bkeys.at[flat].set(jnp.where(ok, skeys, C.EMPTY_KEY), mode="drop")
    bvals = jnp.full((n_dest * cap,) + vals.shape[1:], ident, vals.dtype)
    bvals = bvals.at[flat].set(svals, mode="drop")
    dropped = jnp.sum((sdest < n_dest) & ~ok).astype(jnp.int32)
    return (
        bkeys.reshape(n_dest, cap),
        bvals.reshape((n_dest, cap) + vals.shape[1:]),
        dropped,
    )


# ---------------------------------------------------------------------------
# Collectives indirection
#
# A shard stage never names ``jax.lax`` collectives directly: it goes through
# a small collectives object, so the *same* stage body serves two tracing
# contexts —
#
# * ``RealCollectives``     — inside ``shard_map``, bound to the mesh axis;
# * ``AbstractCollectives`` — the program-discovery trace (``jax.eval_shape``
#   with no mesh axis in scope): shape-faithful local stand-ins, so a whole
#   iteration can be traced for structure before the fused executable exists.
# ---------------------------------------------------------------------------


class RealCollectives:
    """Mesh collectives bound to the data-parallel axes — valid inside
    ``shard_map``.

    ``axis`` is the fast intra-node axis; on a 2-D ``("node", "data")`` mesh
    ``node_axis``/``n_nodes`` describe the slow inter-node axis and flat
    collectives run over the ``(node, data)`` tuple (shard indices flatten
    node-major, matching the containers' leading-dim sharding).  ``reduce``
    and ``reduce_feedback`` additionally take ``hier=True``: intra-node
    reduction first at full precision, then only the node-level partials
    cross the inter-node hop (wire-compressed when requested) — routed
    through ``distributed.collectives``'s hierarchical entry points.
    """

    def __init__(
        self,
        axis: str,
        n_shards: int,
        *,
        node_axis: str | None = None,
        n_nodes: int = 1,
    ):
        self.axis = axis
        self.n_shards = n_shards
        self.node_axis = node_axis
        self.n_nodes = n_nodes
        self.all_axes = (node_axis, axis) if node_axis is not None else axis

    def _is_hier(self, hier: bool) -> bool:
        return bool(hier) and self.node_axis is not None and self.n_nodes > 1

    def axis_index(self) -> Array:
        return jax.lax.axis_index(self.all_axes)

    def all_gather_tiled(self, x: Array) -> Array:
        return jax.lax.all_gather(x, self.all_axes, tiled=True)

    def all_to_all_tiled(self, x: Array) -> Array:
        return jax.lax.all_to_all(
            x, self.all_axes, split_axis=0, concat_axis=0, tiled=True
        )

    def reduce(
        self, partial: Array, red: Reducer, wire: str, hier: bool = False
    ) -> Array:
        # Host code running during trace: an injected collective fault
        # surfaces as a compile-time failure of the dispatch that traced it.
        faults.fault_point("collective")
        if self._is_hier(hier):
            if wire != "none" and red.name == "sum":
                faults.fault_point("collective.inter")
                from repro.distributed.collectives import compressed_psum

                return compressed_psum(
                    partial, self.node_axis, wire=wire, intra_axis=self.axis
                )
            intra = _collective_reduce(partial, red, self.axis, "none")
            faults.fault_point("collective.inter")
            return _collective_reduce(intra, red, self.node_axis, wire)
        return _collective_reduce(partial, red, self.all_axes, wire)

    def reduce_feedback(
        self,
        partial: Array,
        red: Reducer,
        wire: str,
        residual: Array,
        hier: bool = False,
    ) -> tuple[Array, Array]:
        """``wire="int8"`` with error feedback (``quantize_with_feedback``).

        Quantizes ``partial + residual`` per 256-element block, psums the
        dequantized lattice (the wire payload a TPU lowering moves is the
        int8 blocks + scales, as in ``_collective_reduce``), and returns what
        this round's narrowing dropped as the next round's residual — the
        iterative path stays unbiased instead of accumulating rounding bias.

        Hierarchical mode folds the intra-node axis at full precision
        BEFORE quantisation, so only ``n_nodes`` addends (not ``n_shards``)
        pass through the int8 lattice and the residual tracks exactly the
        one lossy hop (every node member computes the same node-level
        residual — deterministic, no echo needed).
        """
        if wire != "int8" or red.name != "sum":
            return self.reduce(partial, red, wire, hier=hier), residual
        from repro.core.serialization import dequantize, quantize_with_feedback

        p32 = partial.astype(jnp.float32)
        axes = self.all_axes
        if self._is_hier(hier):
            p32 = jax.lax.psum(p32, self.axis)  # full-precision intra hop
            faults.fault_point("collective.inter")
            axes = self.node_axis
        q, new_residual = quantize_with_feedback(p32, residual, "int8")
        deq = dequantize(q, p32)
        total = jax.lax.psum(deq, axes).astype(partial.dtype)
        return total, new_residual


class AbstractCollectives:
    """Shape-faithful stand-ins for the discovery trace (no mesh axis bound).

    Every per-shard reduction collective (``psum``/``pmin``/``pmax``, the
    gather-fold of ``prod`` and custom reducers) preserves shape, so identity
    is a faithful abstraction; ``all_gather(tiled)`` concatenates
    ``n_shards`` copies; ``all_to_all(tiled)`` over equal splits is
    shape-preserving.  Values computed under these are never used — only
    their shapes/dtypes (``jax.eval_shape``) and the op-recording side
    effects of the trace.  The hierarchical flag is shape-invisible, so
    both modes share one abstraction.
    """

    def __init__(self, n_shards: int, *, n_nodes: int = 1):
        self.n_shards = n_shards
        self.n_nodes = n_nodes

    def axis_index(self) -> Array:
        return jnp.zeros((), jnp.int32)

    def all_gather_tiled(self, x: Array) -> Array:
        return jnp.concatenate([x] * self.n_shards, axis=0)

    def all_to_all_tiled(self, x: Array) -> Array:
        return x

    def reduce(
        self, partial: Array, red: Reducer, wire: str, hier: bool = False
    ) -> Array:
        return partial

    def reduce_feedback(self, partial, red, wire, residual, hier=False):
        return partial, residual


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


def _source_kind(source) -> str:
    if isinstance(source, C.DistRange):
        return "range"
    if isinstance(source, C.DistVector):
        return "vector"
    if isinstance(source, C.DistHashMap):
        return "hashmap"
    if isinstance(source, (C.ChunkedDistVector, C.BlockView)):
        return "chunked"
    raise TypeError(f"unsupported source {type(source)}")


def map_reduce(
    source,
    mapper: Callable,
    reducer: str | Reducer,
    target,
    *,
    mesh: Mesh | None = None,
    engine: str = "eager",
    wire: str = "none",
    env: Any = None,
    shuffle_slack: float = 2.0,
    key_range: int | None = None,
    return_stats: bool = False,
    session=None,
):
    """The paper's four-arg functional API, as a thin session wrapper.

    Routes through ``session`` (or the process-wide default ``BlazeSession``),
    which owns the mesh and the compiled-executable cache — N iterative calls
    with the same (source spec, mapper, reducer, target spec, engine, wire)
    compile exactly once.  See ``repro.core.session``.  ``key_range`` (hash
    targets: keys promised to lie in ``[0, key_range)``) narrows the shuffle
    key dtype and sizes the pallas combine table.
    """
    from repro.core.session import get_default_session

    sess = session if session is not None else get_default_session()
    return sess.map_reduce(
        source, mapper, reducer, target, mesh=mesh, engine=engine, wire=wire,
        env=env, shuffle_slack=shuffle_slack, key_range=key_range,
        return_stats=return_stats,
    )


def _source_operands(kind, source, mesh=None):
    """(device operands, in_specs) for shard_map, per source kind.

    For ``kind="chunked"`` the dispatch-time source is a ``BlockView``
    (one resident block): data sharded over ``data`` plus the replicated
    traced ``base`` offset — per-block values vary, abstract signature
    doesn't, so every block reuses one executable.  Specs shard over every
    data-parallel mesh axis (``node`` and ``data`` on 2-D meshes).
    """
    d = C.data_pspec(mesh) if mesh is not None else P(C.DATA_AXIS)
    if kind == "range":
        return (), ()
    if kind == "vector":
        return (source.data,), (d,)
    if kind == "chunked":
        return (source.data, source.base), (d, P())
    return (source.table.keys, source.table.vals), (d, d)


def _local_view(kind, source, operands):
    if kind == "range":
        return None
    if kind == "vector":
        return (operands[0], source.n)
    if kind == "chunked":
        return (operands[0], source.n, operands[1])
    return (operands[0][0], operands[1][0])


def dense_shard_stage(
    kind, source, mapper, red, target, engine, wire, n_shards,
    with_stats=True, feedback=False, collect=True, tuned=None, hier=False,
):
    """Build a pure, composable shard stage for a dense ``[K, ...]`` target.

    The stage is the whole per-shard plan — mapper trace, local combine
    (static-key fast path / segmented reduce / Pallas kernel), and the
    shuffle collective — as a *function*, not a sealed ``jit(shard_map(...))``:

        ``stage(env, local, coll, residual=None)
            -> (total, live, kernel_pairs, residual')``

    * ``env``      — the iteration-varying pytree (broadcast, replicated);
    * ``local``    — this shard's operand view (``_local_view``), or a
      program-supplied local vector;
    * ``coll``     — a collectives object (``RealCollectives`` inside
      ``shard_map``, ``AbstractCollectives`` under program discovery);
    * ``residual`` — per-shard error-feedback carry when ``feedback=True``
      (``wire="int8"`` sums in an iterative program), else passed through.

    ``hier=True`` (multi-node meshes, set by the plan layer's
    ``hierarchical-collectives`` pass) makes the stage's collective
    topology-aware: intra-node reduce first at full precision, wire
    narrowing only on the inter-node hop (``RealCollectives.reduce``).

    ``collect=False`` (eager/pallas only) makes the stage stop at the
    per-shard PARTIAL: ``total`` comes back *unreduced* and the caller owns
    the collective.  This is the seam the plan optimizer's
    ``batch-collectives`` pass rides — a program flushes several pending
    partials through ONE concatenated collective (``repro.core.program``).

    ``total`` is the merged (replicated) dense result *excluding* the target
    — callers fold it in with ``red.combine(target, total)``.  Standalone
    ``map_reduce`` wraps one stage in ``shard_map`` + ``jit``
    (``_map_reduce_dense``); ``repro.core.program`` composes several stages
    plus elementwise glue inside ONE ``shard_map`` body, which is what lets
    a whole iteration fuse into a single executable.

    Returns ``(stage, kernel_meta)``; ``kernel_meta`` is filled at trace time
    with the Pallas launch geometry (``block_n``, ``lanes``) when the kernel
    runs.  ``tuned`` (a ``cost.TunedConfig``) pins the kernel's ``block_n``
    instead of the analytic tuner — the measured-autotuning override.
    """
    K = target.shape[0]
    tuned_bn = getattr(tuned, "block_n", None) if engine == "pallas" else None
    target_dtype = target.dtype
    kernel_meta: dict = {}

    def stage(env_, local, coll, residual=None):
        entries, static_keys = _run_mapper_structured(
            kind, source, mapper, coll.axis_index(), local, n_shards, env_
        )
        live = (
            sum(jnp.sum(m) for _, _, m in entries).astype(jnp.int32)
            if with_stats or engine == "naive"
            else jnp.zeros((), jnp.int32)
        )
        kernel_pairs = jnp.zeros((), jnp.int32)

        if engine in ("eager", "pallas"):
            # §2.3.3 static-key fast path: trace-time-constant keys get a
            # fused whole-axis reduction — no id arrays, the exact plan a
            # hand-written parallel-for emits.  (Shared by both engines:
            # a kernel cannot beat a fused scalar reduction.)
            val_shape = entries[0][1].shape[2:]
            ident = red.identity(target_dtype)
            partial = jnp.full((K,) + val_shape, ident, target_dtype)
            dynamic = []
            for (keys, vals, mask), sk in zip(entries, static_keys):
                vals = vals.astype(target_dtype)
                if (
                    sk is not None
                    and 0 <= sk < K
                    and red.axis_reduce is not None
                ):
                    mb = mask.reshape(mask.shape + (1,) * len(val_shape))
                    contrib = red.axis_reduce(
                        jnp.where(mb, vals, ident), axis=(0, 1)
                    )
                    partial = partial.at[sk].set(
                        red.combine(partial[sk], contrib)
                    )
                else:
                    dynamic.append((keys, vals, mask))
            if dynamic:
                dkeys, dvals, dmask = _flatten_entries(dynamic)
                dvals = dvals.astype(target_dtype)
                if engine == "pallas" and red.pallas_segment is not None:
                    # Device-local combine on the MXU: invalid lanes get
                    # id −1, which the kernel drops (their values never
                    # reach the accumulator, so no masking of dvals).
                    ids = jnp.where(
                        dmask & (dkeys >= 0) & (dkeys < K), dkeys, -1
                    )
                    flat = dvals.reshape((dvals.shape[0], -1))
                    seg = red.pallas_segment(ids, flat, K, block_n=tuned_bn)
                    seg = seg.reshape((K,) + dvals.shape[1:])
                    from repro.kernels.segment_reduce import (
                        segment_reduce_lanes,
                    )

                    bn, lanes = segment_reduce_lanes(
                        flat.shape[0], K, flat.shape[1], red.name,
                        flat.dtype, block_n=tuned_bn,
                    )
                    kernel_meta["block_n"] = bn
                    kernel_meta["lanes"] = lanes * n_shards
                    kernel_pairs = jnp.sum(
                        dmask & (dkeys >= 0) & (dkeys < K)
                    ).astype(jnp.int32)
                else:
                    # eager, or a custom reducer without a kernel impl:
                    # XLA's segmented reduce.
                    ids = jnp.where(
                        dmask & (dkeys >= 0) & (dkeys < K), dkeys, K
                    )
                    seg = red.segment(dvals, ids, K + 1)[:K]
                partial = red.combine(partial, seg.astype(target_dtype))
            if not collect:
                total = partial  # caller runs the (possibly batched) collective
            elif feedback:
                total, residual = coll.reduce_feedback(
                    partial, red, wire, residual, hier=hier
                )
            else:
                total = coll.reduce(partial, red, wire, hier=hier)
        else:
            # Conventional plan: ship ALL raw pairs (padded lanes and all);
            # reduce only at the destination.  all_gather of the raw pair
            # stream is the dense-target equivalent of a wide shuffle.
            keys, vals, valid = _flatten_entries(entries)
            vals = vals.astype(target_dtype)
            gk = coll.all_gather_tiled(keys)
            gv = coll.all_gather_tiled(vals)
            gm = coll.all_gather_tiled(valid)
            ids_g = jnp.where(gm & (gk >= 0) & (gk < K), gk, K)
            total = red.segment(gv, ids_g, K + 1)[:K]
        return total, live, kernel_pairs, residual

    return stage, kernel_meta


def make_collectives(mesh, n_shards: int) -> "RealCollectives":
    """The mesh's ``RealCollectives`` (topology-aware on 2-D meshes)."""
    nodes = C.n_nodes(mesh)
    return RealCollectives(
        C.DATA_AXIS,
        n_shards,
        node_axis=C.NODE_AXIS if nodes > 1 else None,
        n_nodes=nodes,
    )


def reduce_edge_bytes(
    n_elems: int,
    full_bytes: int,
    wire_val_bytes: int,
    n_shards: int,
    n_nodes: int,
    hier: bool,
) -> tuple[int, int]:
    """(intra_bytes, inter_bytes) of one dense reduction, combine-edge model.

    A reduction over P participants moves P-1 combine edges.  Hierarchical
    mode keeps ``n_shards - n_nodes`` edges intra-node at FULL element width
    and ``n_nodes - 1`` inter-node at wire width; a flat reduce on a
    multi-node mesh is topology-oblivious and pays every edge inter-node at
    wire width; on a 1-node mesh everything is intra and inter is 0.
    """
    if n_nodes > 1 and hier:
        intra = n_elems * full_bytes * (n_shards - n_nodes)
        inter = n_elems * wire_val_bytes * (n_nodes - 1)
    elif n_nodes > 1:
        intra = 0
        inter = n_elems * wire_val_bytes * (n_shards - 1)
    else:
        intra = n_elems * wire_val_bytes * (n_shards - 1)
        inter = 0
    return intra, inter


def _map_reduce_dense(
    kind, source, mapper, red, target, mesh, n_shards, engine, wire, env,
    with_stats=True, cache=None, node=None, tuned=None, hier=False,
):
    """Dense [K, ...] target — the paper's small fixed key range fast path."""
    K = target.shape[0]
    cache = cache if cache is not None else {}
    if engine not in ("eager", "pallas", "naive"):
        raise ValueError(f"unknown engine {engine!r}")
    nodes = C.n_nodes(mesh)
    hier = bool(hier) and nodes > 1 and engine in ("eager", "pallas")

    # The executable cache key IS the plan node's identity-faithful cache
    # signature: everything that shapes the lowered plan, with the mapper and
    # reducer kept by object (two lambdas with one qualname stay distinct).
    # A tuned kernel config bakes into the lowered kernel, so it is part of
    # the identity (TunedConfig equality ignores measurement outcomes).
    cache_key = (
        "dense", mapper, red.name, red, engine, wire, mesh, kind, with_stats,
        _abstract(_source_operands(kind, source)[0]),
        getattr(source, "n", None) if kind in ("vector", "chunked") else
        (source.start, source.stop, source.step) if kind == "range" else None,
        _abstract(target), _abstract(env), tuned,
    ) + (("hier",) if hier else ())
    if node is not None:
        node.cache_sig = cache_key

    compiled_now = cache_key not in cache
    if compiled_now:
        stage, kernel_meta = dense_shard_stage(
            kind, source, mapper, red, target, engine, wire, n_shards,
            with_stats=with_stats, tuned=tuned, hier=hier,
        )
        d = C.data_pspec(mesh)

        def shard_fn(env_, *operands):
            coll = make_collectives(mesh, n_shards)
            local = _local_view(kind, source, operands)
            total, live, kernel_pairs, _ = stage(env_, local, coll)
            return total, live[None], kernel_pairs[None]

        fn = shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(P(),) + tuple(_source_operands(kind, source, mesh)[1]),
            out_specs=(P(), d, d),
            check_vma=False,
        )

        def run(env_, target_, *operands):
            total, live, kpairs = fn(env_, *operands)
            return red.combine(target_, total.astype(target_.dtype)), live, kpairs

        cache[cache_key] = (jax.jit(run), kernel_meta)

    run_fn, kernel_meta = cache[cache_key]
    operands, _ = _source_operands(kind, source)
    faults.fault_point("dispatch")
    if engine == "pallas":
        faults.fault_point("kernel.segment")
    merged, live, kernel_pairs = run_fn(env, target, *operands)

    val_bytes = {"bf16": 2, "int8": 1}.get(wire, jnp.dtype(target.dtype).itemsize)
    full_bytes = jnp.dtype(target.dtype).itemsize
    key_bytes = narrowest_int_dtype(K).itemsize
    n_elems = int(np.prod(target.shape))
    if engine in ("eager", "pallas"):
        payload = n_elems * val_bytes * n_shards
        coll = (
            hier_collective_desc(red.name, wire)
            if hier
            else f"psum[{K}x{val_bytes}B]"
        )
        shipped = n_elems * n_shards
        intra_b, inter_b = reduce_edge_bytes(
            n_elems, full_bytes, val_bytes, n_shards, nodes, hier
        )
    else:
        payload = live  # finalized below: pairs * (key+val) bytes
        coll = f"all_gather[pairs x {key_bytes + val_bytes}B]"
        shipped = live
        intra_b = inter_b = 0  # replaced below once live pairs are known
    stats = MapReduceStats(
        engine=engine,
        collective=coll,
        pairs_emitted=live,
        pairs_shipped=shipped,
        shuffle_payload_bytes=payload,
        intra_bytes=intra_b,
        inter_bytes=inter_b,
        compiles=int(compiled_now),
        cache_hits=int(not compiled_now),
        kernel_block_n=kernel_meta.get("block_n"),
        kernel_lanes=kernel_meta.get("lanes"),
        kernel_pairs=kernel_pairs if kernel_meta else None,
        plan_hash=node.hash if node is not None else None,
    )
    if engine == "naive":
        naive_payload = jnp.sum(live) * (key_bytes + val_bytes) * n_shards
        # all_gather edges: every shard's pairs reach all n_shards-1 peers;
        # with per-node rows of n_shards/nodes shards, the inter fraction of
        # peer links is (n_shards - n_shards/nodes) / (n_shards - 1).
        if nodes > 1 and n_shards > 1:
            inter_frac = (n_shards - n_shards // nodes) / (n_shards - 1)
        else:
            inter_frac = 0.0
        stats = dataclasses.replace(
            stats,
            shuffle_payload_bytes=naive_payload,
            intra_bytes=naive_payload * (1.0 - inter_frac),
            inter_bytes=naive_payload * inter_frac,
        )
    return merged, stats


def _collective_reduce(partial: Array, red: Reducer, axis, wire: str) -> Array:
    """One reduction hop over ``axis`` (a name or tuple of names).

    Narrowed sums route through ``distributed.collectives.compressed_psum``
    (shared-scale int8 over the int8 lattice / bf16 cast — see there); every
    other (reducer, wire) pair is the reducer's own collective.
    """
    if wire == "none" or red.name != "sum":
        return red.collective(partial, axis)
    if wire not in ("bf16", "int8"):
        raise ValueError(f"unknown wire mode {wire!r}")
    from repro.distributed.collectives import compressed_psum

    return compressed_psum(partial, axis, wire=wire)


def _wire_key_dtype(key_range: int | None) -> jnp.dtype:
    """Key dtype the hash shuffle ships: narrowed when the range is known
    (the §2.3.2 fast-serialization analogue for *explicit* keys)."""
    if key_range is None:
        return jnp.dtype(jnp.int32)
    return narrowest_int_dtype(key_range)


def hash_shard_stage(
    kind, source, mapper, red, val_dtype, engine, slack, n_shards,
    key_range=None, tuned=None,
):
    """Build the composable shard stage for a ``DistHashMap`` target.

    Same contract as ``dense_shard_stage`` — the whole per-shard plan
    (mapper trace, eager local combine, destination bucketing, ``all_to_all``
    shuffle, table merge) as a pure function of this shard's inputs:

        ``stage(env, table, local, coll)
            -> (table', live_emitted, live_shipped, kernel_pairs)``

    ``table`` is this shard's ``HashTable``; the returned table has the
    shuffled pairs merged in and bucket drops added to ``overflow``.

    * ``engine="eager"`` combines locally with the sort-based
      ``unique_combine`` before the shuffle and merges received pairs with a
      second ``unique_combine`` + ``hashmap_insert`` scatter loop.
    * ``engine="pallas"`` lowers BOTH combines through the hash-aggregation
      kernel (``repro.kernels.hash_combine``): the pre-shuffle combine
      streams raw pairs into a fresh VMEM-resident table (duplicates fold
      in-kernel — no sort), and the post-shuffle merge streams received
      pairs straight into the target shard's table (``init=``), replacing
      the ``unique_combine`` + 16-round ``hashmap_insert`` pair.
    * ``engine="naive"`` ships every raw pair and reduces at the
      destination only.

    ``key_range`` (keys known to lie in ``[0, key_range)``) narrows the
    bucket-key dtype on the wire and sizes the kernel's combine table by the
    distinct-key bound instead of the stream length.

    Standalone ``map_reduce`` wraps one stage in ``shard_map`` + ``jit``
    (``_map_reduce_hash``); ``repro.core.program`` composes it into fused
    iteration bodies with the shard's table threaded through the loop carry.
    Returns ``(stage, kernel_meta)`` — ``kernel_meta`` is filled at trace
    time with the kernel launch geometry when the kernel runs.
    """
    from repro.kernels import hash_combine as HK

    use_kernel = engine == "pallas" and red.pallas_hash is not None
    kernel_meta: dict = {}

    def stage(env_, table, local, coll):
        keys, vals, valid = _run_mapper(
            kind, source, mapper, coll.axis_index(), local, n_shards, env_
        )
        vals = vals.astype(val_dtype)
        n_emit = keys.shape[0]
        live_emitted = jnp.sum(valid).astype(jnp.int32)
        kernel_pairs = jnp.zeros((), jnp.int32)
        pre_drop = jnp.zeros((), jnp.int32)

        if use_kernel:
            # Kernel local combine: raw pairs → fresh VMEM hash table.  The
            # table's live rows *are* the locally-reduced pairs (at most one
            # per key), so the sort-based unique_combine disappears.
            vflat = vals.reshape((n_emit, -1))
            if tuned is not None and tuned.table_cap:
                # Measured override: the full (cap, block, probes) triple is
                # pinned (only offered when key_range bounds the distinct
                # keys, so the pinned capacity cannot overflow).
                cap = tuned.table_cap
                bn = max(8, min(tuned.block_n or 8, max(8, n_emit)))
                probes = min(cap, tuned.probe_depth or
                             HK.choose_probe_depth(n_emit, cap))
            else:
                cap, bn, probes = HK.choose_table_cap(
                    n_emit, vflat.shape[1], red.name, vflat.dtype,
                    distinct_hint=key_range,
                )
            mkeys = jnp.where(valid, keys, HK.EMPTY_KEY)
            tk, tv, pre_drop = red.pallas_hash(
                mkeys, vflat, cap, max_probes=probes, block_n=bn
            )
            keys, valid = tk, tk != HK.EMPTY_KEY
            vals = tv.reshape((cap,) + vals.shape[1:]).astype(val_dtype)
            kernel_pairs = live_emitted
            _, lanes = HK.hash_aggregate_lanes(
                n_emit, cap, vflat.shape[1], red.name, vflat.dtype,
                block_n=bn,
            )
            kernel_meta.update(
                block_n=bn, lanes=lanes * n_shards, table_cap=cap,
                probe_depth=probes,
            )
        elif engine == "eager":
            keys, vals, valid = C.unique_combine(keys, vals, valid, red)
        live_shipped = jnp.sum(valid).astype(jnp.int32)

        n_stream = keys.shape[0]
        bucket_cap = max(1, int(math.ceil(slack * n_emit / n_shards)))
        bucket_cap = min(bucket_cap, n_stream)
        ident = red.identity(vals.dtype)
        bkeys, bvals, dropped = bucket_by_dest(
            keys, vals, valid, n_shards, bucket_cap, ident
        )
        # Narrowed keys on the wire: the shuffle ships the smallest int
        # dtype covering [0, key_range); EMPTY_KEY maps to the narrow
        # dtype's own min sentinel and back.
        wire_dtype = _wire_key_dtype(key_range)
        if wire_dtype.itemsize < 4:
            sentinel = int(jnp.iinfo(wire_dtype).min)
            nk = jnp.where(bkeys == C.EMPTY_KEY, sentinel, bkeys)
            rk = coll.all_to_all_tiled(nk.astype(wire_dtype))
            rkeys = rk.astype(jnp.int32).reshape(-1)
            rkeys = jnp.where(rkeys == sentinel, C.EMPTY_KEY, rkeys)
        else:
            rkeys = coll.all_to_all_tiled(bkeys).reshape(-1)
        rvals = coll.all_to_all_tiled(bvals)
        rvals = rvals.reshape((-1,) + rvals.shape[2:])
        rvalid = rkeys != C.EMPTY_KEY
        table = C.HashTable(
            table.keys, table.vals, table.overflow + dropped + pre_drop
        )
        if use_kernel:
            # Kernel merge into the target shard's table: received pairs may
            # repeat across source shards, and the kernel folds duplicates
            # natively — the second unique_combine and the hashmap_insert
            # scatter loop both disappear.
            n_recv = rkeys.shape[0]
            mk = jnp.where(rvalid, rkeys, HK.EMPTY_KEY)
            rflat = rvals.astype(val_dtype).reshape((n_recv, -1))
            merge_probes = max(16, HK.choose_probe_depth(n_recv, table.capacity))
            tk, tv, ovf = red.pallas_hash(
                mk, rflat, table.capacity,
                init=(
                    table.keys,
                    table.vals.reshape((table.capacity, -1)),
                    table.overflow,
                ),
                max_probes=merge_probes,
            )
            table = C.HashTable(
                tk, tv.reshape(table.vals.shape).astype(val_dtype), ovf
            )
            kernel_meta.setdefault("merge_probe_depth", merge_probes)
        else:
            ukeys, uvals, uvalid = C.unique_combine(rkeys, rvals, rvalid, red)
            # Same adaptive probe depth as the kernel merge: near-capacity
            # tables need more rounds to *find* the free slots that exist.
            merge_probes = max(
                16, HK.choose_probe_depth(rkeys.shape[0], table.capacity)
            )
            table = C.hashmap_insert(
                table, ukeys, uvals, uvalid, red, max_probes=merge_probes
            )
        return table, live_emitted, live_shipped, kernel_pairs

    return stage, kernel_meta


def _map_reduce_hash(
    kind, source, mapper, red, target, mesh, n_shards, engine, slack, env,
    key_range=None, cache=None, node=None, tuned=None,
):
    """DistHashMap target: local combine → hash-partition → all_to_all → merge."""
    cache = cache if cache is not None else {}
    nodes = C.n_nodes(mesh)

    cache_key = (
        "hash", mapper, red.name, red, engine, slack, mesh, kind, key_range,
        _abstract(_source_operands(kind, source)[0]),
        getattr(source, "n", None) if kind in ("vector", "chunked") else
        (source.start, source.stop, source.step) if kind == "range" else None,
        _abstract((target.table.keys, target.table.vals)), _abstract(env),
        tuned,
    )
    if node is not None:
        node.cache_sig = cache_key

    compiled_now = cache_key not in cache
    if compiled_now:
        stage, kernel_meta = hash_shard_stage(
            kind, source, mapper, red, target.table.vals.dtype, engine,
            slack, n_shards, key_range=key_range, tuned=tuned,
        )

        def shard_fn(env_, tkeys, tvals, tovf, *operands):
            coll = make_collectives(mesh, n_shards)
            local = _local_view(kind, source, operands)
            table = C.HashTable(tkeys[0], tvals[0], tovf[0])
            table, live_emitted, live_shipped, kernel_pairs = stage(
                env_, table, local, coll
            )
            return (
                table.keys[None],
                table.vals[None],
                table.overflow[None],
                live_emitted[None],
                live_shipped[None],
                kernel_pairs[None],
            )

        d = C.data_pspec(mesh)
        in_specs = (P(), d, d, d) + tuple(_source_operands(kind, source, mesh)[1])
        cache[cache_key] = (
            jax.jit(
                shard_map(
                    shard_fn,
                    mesh=mesh,
                    in_specs=in_specs,
                    out_specs=(d, d, d, d, d, d),
                    check_vma=False,
                )
            ),
            kernel_meta,
        )

    run_fn, kernel_meta = cache[cache_key]
    operands, _ = _source_operands(kind, source)
    faults.fault_point("dispatch")
    if engine == "pallas":
        faults.fault_point("kernel.hash")
    nk, nv, novf, emitted, shipped, kernel_pairs = run_fn(
        env, target.table.keys, target.table.vals, target.table.overflow, *operands
    )
    out = C.DistHashMap(C.HashTable(nk, nv, novf), reducer_name=red.name)
    val_bytes = jnp.dtype(target.table.vals.dtype).itemsize
    key_bytes = _wire_key_dtype(key_range).itemsize
    payload = jnp.sum(shipped) * (key_bytes + val_bytes)
    # all_to_all is point-to-point: with hash-uniform destinations, the
    # fraction of pairs leaving their node row is (n_shards - n_data)/n_shards
    # — no hierarchical rewrite applies, only honest topology accounting.
    inter_frac = (
        (n_shards - n_shards // nodes) / n_shards
        if nodes > 1 and n_shards > 1
        else 0.0
    )
    stats = MapReduceStats(
        engine=engine,
        collective=f"all_to_all[pairs x {key_bytes + val_bytes}B]",
        pairs_emitted=emitted,
        pairs_shipped=shipped,
        shuffle_payload_bytes=payload,
        intra_bytes=payload * (1.0 - inter_frac),
        inter_bytes=payload * inter_frac,
        overflow=novf,
        compiles=int(compiled_now),
        cache_hits=int(not compiled_now),
        kernel_block_n=kernel_meta.get("block_n"),
        kernel_lanes=kernel_meta.get("lanes"),
        kernel_pairs=kernel_pairs if kernel_meta else None,
        kernel_table_cap=kernel_meta.get("table_cap"),
        kernel_probe_depth=kernel_meta.get("probe_depth"),
        plan_hash=node.hash if node is not None else None,
    )
    return out, stats
