"""Cost-based planning: per-node cost model, kernel-config candidate grids,
and the first-dispatch autotuning cache.

Until PR 8 the planner made its one load-bearing choice — eager XLA reduce vs
the Pallas VMEM-resident kernel — with a single static rule
(``K <= PALLAS_AUTO_MAX_KEYS``), and the two kernel autotuners
(``segment_reduce.choose_block_n``, ``hash_combine.choose_table_cap``)
duplicated the VMEM-budget arithmetic while scoring candidates with analytic
formulas that never saw a measurement.  This module closes ROADMAP open item
2 in three layers:

* **Candidate grids** (``segment_block_candidates`` /
  ``hash_table_candidates``): ONE implementation of the VMEM working-set
  arithmetic, exposing every config the greedy tuners consider together with
  its working-set score.  The kernels' ``choose_*`` functions are now thin
  argmax-style picks over these grids (bit-identical to the pre-PR-8 greedy
  loops), and the measured autotuner times a small slice of the same grid
  instead of re-deriving one.
* **Calibrated fallback model** (``node_cost`` / ``pick_engine``): the
  no-measurement engine policy.  Costs are in abstract *accumulator-row
  units*: the kernel pays ~2 rows of VMEM traffic per key (accumulate +
  writeback) while eager's segment-sort path pays ~1 row per key plus a
  fixed ``EAGER_FIXED_ROWS`` lowering/sort overhead.  The crossover is
  exactly ``K == PALLAS_AUTO_MAX_KEYS`` — the policy ``engine="auto"``
  shipped with since PR 2 — so resolution stays deterministic and the PR 2
  differential matrix keeps pinning it.
* **Measured autotuning** (``TunedConfig`` / ``TuningCache``): with
  ``tune=True`` the session times the candidate grid on the first dispatch
  of a plan and caches the winner keyed by the node's plan hash; every later
  dispatch — per-op, ``run_loop`` block, or BlazeServe query — reuses the
  measured config.  The cache is JSON-persistable beside checkpoints
  (``save``/``load``).

Import discipline: this module imports ONLY jax/numpy/stdlib — never
``repro.*`` — so the kernels (which sit *below* ``repro.core`` in the import
order) can import it at module level without a cycle.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import threading
import warnings
from typing import Iterator

import jax.numpy as jnp

__all__ = [
    "EAGER_FIXED_ROWS",
    "PALLAS_AUTO_MAX_KEYS",
    "VMEM_BUDGET",
    "TunedConfig",
    "TuningCache",
    "acc_dtype",
    "choose_block_n",
    "choose_probe_depth",
    "choose_table_cap",
    "dense_tuning_candidates",
    "hash_table_candidates",
    "hash_tuning_candidates",
    "next_capacity",
    "node_cost",
    "pick_engine",
    "segment_block_candidates",
    "use_matmul",
]

# Default VMEM budget for both kernel autotuners (bytes).  Real cores have
# ~16 MB; leave room for the accumulator tile and double-buffered inputs.
VMEM_BUDGET = 4 * 1024 * 1024

# The fallback cost model's calibration anchor.  The kernel pays ~2
# accumulator-row units per key, eager pays ~1 unit per key plus this fixed
# sort/lowering overhead — so the modelled crossover sits at K == 4096 keys,
# the threshold ``engine="auto"`` has shipped with (and been differential-
# tested at) since PR 2.  4096 keys x 128 f32 lanes ~= 2 MB: comfortably
# VMEM-resident; beyond that eager's XLA segmented reduce wins anyway.
PALLAS_AUTO_MAX_KEYS = 4096
EAGER_FIXED_ROWS = PALLAS_AUTO_MAX_KEYS


# ---------------------------------------------------------------------------
# Strategy helpers (shared by both kernels' working-set arithmetic)
# ---------------------------------------------------------------------------


def acc_dtype(dtype):
    """Accumulator dtype: f32 for floats (bf16 upcast), i32 for ints — the
    widths the MXU/VPU natively accumulate in."""
    if jnp.issubdtype(jnp.dtype(dtype), jnp.floating):
        return jnp.float32
    return jnp.int32


def use_matmul(reducer: str, acc) -> bool:
    """One-hot-matmul (MXU) strategy applies to float sums only; everything
    else takes the select-scatter VPU fold."""
    return reducer == "sum" and acc == jnp.float32


# ---------------------------------------------------------------------------
# Candidate grids + scores (the deduplicated tuner logic)
# ---------------------------------------------------------------------------


def segment_block_candidates(
    n: int, num_segments: int, v: int, reducer: str = "sum",
    dtype=jnp.float32, vmem_budget: int = VMEM_BUDGET,
) -> list[tuple[int, int]]:
    """Every ``block_n`` the dense-kernel tuner considers, with its score.

    Returns ``[(block_n, working_set_bytes), ...]`` in ascending block order:
    power-of-two blocks from 8 up to 2048 whose per-step working set fits the
    budget (the minimum block 8 is always offered).  Working set per block
    row: ``(K + V) * 4`` bytes for the one-hot-matmul strategy (onehot
    ``[bn, K]`` + vals ``[bn, V]``, both f32) or ``K * V * 4`` for the
    select-scatter fold (masked ``[bn, K, V]``).
    """
    per_row = (
        (num_segments + v) * 4
        if use_matmul(reducer, acc_dtype(dtype))
        else num_segments * max(v, 1) * 4
    )
    cands = [(8, 8 * per_row)]
    bn = 8
    while bn < 2048 and (2 * bn) * per_row <= vmem_budget:
        bn *= 2
        cands.append((bn, bn * per_row))
    return cands


def choose_block_n(
    n: int, num_segments: int, v: int, reducer: str = "sum",
    dtype=jnp.float32, vmem_budget: int = VMEM_BUDGET,
) -> int:
    """Largest candidate block that fits, clamped to the stream length —
    exactly the pre-PR-8 greedy tuner, now a pick over the shared grid."""
    bn = segment_block_candidates(
        n, num_segments, v, reducer, dtype, vmem_budget
    )[-1][0]
    return max(8, min(bn, max(8, n)))


def hash_working_set(
    cap: int, bn: int, v: int, reducer: str = "sum", dtype=jnp.float32
) -> int:
    """Bytes resident per probe round of the hash kernel at ``(cap, bn)``:
    the ``[C, V]`` + ``[C]`` table plus ~4 ``[bn, C]`` probe intermediates
    (matmul strategy) or the ``[bn, C, V]`` select-scatter fold."""
    table = cap * (max(v, 1) + 1) * 4
    if use_matmul(reducer, acc_dtype(dtype)):
        per_round = 4 * bn * cap * 4 + bn * max(v, 1) * 4
    else:
        per_round = bn * cap * max(v, 1) * 4 + 2 * bn * cap * 4
    return table + per_round


def choose_probe_depth(n: int, table_cap: int) -> int:
    """Probe rounds to configure for ``n`` pairs into a ``table_cap`` table.

    Linear-probing cluster lengths grow with the load factor α = n/C: ~16
    probes cover α ≤ 0.5 comfortably, near-full tables need more rounds to
    *find* the free slots that do exist.
    """
    alpha = min(1.0, n / max(1, table_cap))
    if alpha <= 0.5:
        depth = 16
    elif alpha <= 0.75:
        depth = 32
    else:
        depth = 64
    return min(table_cap, depth)


def hash_table_candidates(
    n: int,
    v: int,
    reducer: str = "sum",
    dtype=jnp.float32,
    *,
    distinct_hint: int | None = None,
    vmem_budget: int = VMEM_BUDGET,
) -> list[tuple[int, int, int, int]]:
    """Every ``(cap, block_n)`` pair the hash-kernel tuner considers.

    Returns ``[(table_cap, block_n, max_probes, working_set_bytes), ...]``:
    the capacity is fixed first (load factor ≤ 0.5 over the distinct-key
    bound, power of two, shrunk until the minimum block fits the budget),
    then every power-of-two block that keeps the *next doubling* in budget
    is offered — the same frontier the pre-PR-8 greedy loop walked.
    """
    distinct = min(n, distinct_hint) if distinct_hint else n
    cap = 128
    while cap < 2 * max(1, distinct) and cap < (1 << 20):
        cap *= 2

    def fits(cap_: int, bn_: int) -> bool:
        return hash_working_set(cap_, bn_, v, reducer, dtype) <= vmem_budget

    while cap > 128 and not fits(cap, 8):
        cap //= 2
    cands = [(cap, 8, choose_probe_depth(n, cap),
              hash_working_set(cap, 8, v, reducer, dtype))]
    bn = 8
    while bn < 1024 and bn < n and fits(cap, 2 * bn):
        bn *= 2
        cands.append((cap, bn, choose_probe_depth(n, cap),
                      hash_working_set(cap, bn, v, reducer, dtype)))
    return cands


def choose_table_cap(
    n: int,
    v: int,
    reducer: str = "sum",
    dtype=jnp.float32,
    *,
    distinct_hint: int | None = None,
    vmem_budget: int = VMEM_BUDGET,
) -> tuple[int, int, int]:
    """(table_cap, block_n, max_probes): the largest-block candidate from the
    shared grid, clamped to the stream length — exactly the pre-PR-8 greedy
    tuner."""
    cap, bn, probes, _ = hash_table_candidates(
        n, v, reducer, dtype, distinct_hint=distinct_hint,
        vmem_budget=vmem_budget,
    )[-1]
    return cap, max(8, min(bn, max(8, n))), probes


def next_capacity(cap: int, *, limit: int = 1 << 20) -> int | None:
    """The next rung of the hash-capacity grid above ``cap``.

    The grid is the same one ``hash_table_candidates`` walks: powers of two
    from 128 up to ``limit``.  Overflow escalation climbs it one rung per
    re-dispatch; ``None`` means the grid is exhausted and the supervisor must
    stop escalating (overflow stays counted, as before).
    """
    if cap >= limit:
        return None
    nxt = 128
    while nxt <= cap:
        nxt *= 2
    return min(nxt, limit)


# ---------------------------------------------------------------------------
# Calibrated fallback model (the no-measurement engine policy)
# ---------------------------------------------------------------------------


def node_cost(engine: str, k: int) -> float:
    """Modelled cost of one shard-local combine over ``k`` accumulator rows,
    in abstract accumulator-row units.

    ``pallas``: the VMEM kernel touches every accumulator row roughly twice
    per pass (monoid accumulate + final writeback) → ``2k``.  ``eager``: the
    XLA segmented reduce touches each row once but pays a fixed
    sort/lowering overhead (``EAGER_FIXED_ROWS``) regardless of ``k`` →
    ``k + EAGER_FIXED_ROWS``.  ``naive`` ships raw pairs and re-reduces
    everywhere — modelled as an order of magnitude over eager.
    """
    if engine == "pallas":
        return 2.0 * k
    if engine == "naive":
        return 10.0 * (k + EAGER_FIXED_ROWS)
    return float(k) + EAGER_FIXED_ROWS


def pick_engine(k: int) -> str:
    """The fallback resolution for ``engine="auto"``: the modelled-cheaper
    engine, eager when ``k`` is unknown (``k <= 0``).  The calibration makes
    the crossover exactly ``k == PALLAS_AUTO_MAX_KEYS``, preserving the PR 2
    policy bit-for-bit."""
    if k <= 0:
        return "eager"
    return "pallas" if node_cost("pallas", k) <= node_cost("eager", k) else "eager"


# ---------------------------------------------------------------------------
# Measured autotuning: configs, candidate enumeration, cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """One execution config for a MapReduce node — a measurement candidate,
    and (once timed) the cached winner.

    ``wall_s`` and ``source`` are measurement *outcomes*, excluded from
    equality/hash so a config's identity — and with it the executable-cache
    key it participates in — depends only on what actually lowers.
    """

    engine: str  # "eager" | "pallas"
    block_n: int | None = None  # dense/hash kernel block override
    table_cap: int | None = None  # hash kernel: capacity override
    probe_depth: int | None = None  # hash kernel: probe rounds override
    source: str = dataclasses.field(default="fallback", compare=False)
    wall_s: float | None = dataclasses.field(default=None, compare=False)

    def describe(self) -> str:
        parts = [self.engine]
        if self.table_cap:
            parts.append(f"cap={self.table_cap}")
        if self.block_n:
            parts.append(f"bn={self.block_n}")
        if self.probe_depth:
            parts.append(f"probes={self.probe_depth}")
        return " ".join(parts)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TunedConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


def dense_tuning_candidates(
    k: int, v: int, reducer: str, dtype, *, vmem_budget: int = VMEM_BUDGET,
) -> list[TunedConfig]:
    """The measurement grid for a dense-target node: eager, the kernel at
    its analytic default block, and the kernel one block step down/up the
    shared candidate frontier.  Every candidate reduces with the same monoid
    over the same pairs — results are bit-identical for exact inputs."""
    cands = [TunedConfig(engine="eager")]
    grid = [bn for bn, _ in segment_block_candidates(
        1 << 30, k, v, reducer, dtype, vmem_budget
    )]
    default = grid[-1]
    picks = [default]
    if default // 2 in grid:
        picks.append(default // 2)
    if default // 4 in grid:
        picks.append(default // 4)
    cands += [TunedConfig(engine="pallas", block_n=bn) for bn in picks]
    return cands


def hash_tuning_candidates(
    v: int, reducer: str, dtype, *, key_range: int | None,
    vmem_budget: int = VMEM_BUDGET,
) -> list[TunedConfig]:
    """The measurement grid for a hash-target node.

    With a ``key_range`` the distinct-key bound is known statically, so full
    ``(cap, block_n, probes)`` triples off the shared grid are safe to pin
    (capacity stays ≥ 2x the distinct bound — no overflow risk, results stay
    bit-identical across candidates).  Without one, capacity must follow the
    runtime stream length, so only the engine is tuned and the in-stage
    analytic tuner keeps picking the kernel config.
    """
    cands = [TunedConfig(engine="eager")]
    if key_range is None:
        cands.append(TunedConfig(engine="pallas"))
        return cands
    grid = hash_table_candidates(
        1 << 30, v, reducer, dtype, distinct_hint=key_range,
        vmem_budget=vmem_budget,
    )
    seen: set[tuple] = set()
    for cap, bn, probes, _ in (grid[-1], grid[len(grid) // 2], grid[0]):
        if (cap, bn) in seen:
            continue
        seen.add((cap, bn))
        cands.append(TunedConfig(
            engine="pallas", block_n=bn, table_cap=cap, probe_depth=probes
        ))
    return cands


class TuningCache:
    """Measured winners keyed by node plan-hash (``MapReduceNode.tune_key``).

    Thread-safe (BlazeServe prepares plans under concurrent submissions).
    ``measurements`` counts candidate timings performed, ``hits``/``misses``
    count lookups — the counters the measure-exactly-once tests pin.
    """

    def __init__(self) -> None:
        self._entries: dict[str, TunedConfig] = {}
        self._lock = threading.Lock()
        self.measurements = 0
        self.hits = 0
        self.misses = 0

    def get(self, key: str) -> TunedConfig | None:
        with self._lock:
            cfg = self._entries.get(key)
            if cfg is None:
                self.misses += 1
            else:
                self.hits += 1
            return cfg

    def peek(self, key: str) -> TunedConfig | None:
        with self._lock:
            return self._entries.get(key)

    def put(self, key: str, cfg: TunedConfig) -> None:
        with self._lock:
            self._entries[key] = cfg

    def record_measurements(self, n: int) -> None:
        with self._lock:
            self.measurements += n

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._entries

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def items(self) -> Iterator[tuple[str, TunedConfig]]:
        with self._lock:
            return iter(sorted(self._entries.items()))

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._entries),
                "measurements": self.measurements,
                "hits": self.hits,
                "misses": self.misses,
                "configs": {
                    k: cfg.to_dict()
                    for k, cfg in sorted(self._entries.items())
                },
            }

    # -- persistence (beside checkpoints) -----------------------------------

    def save(self, path: str) -> None:
        """Atomic JSON dump (tmp + rename, same discipline as checkpoints)."""
        doc = {
            "version": 1,
            "entries": {
                k: cfg.to_dict() for k, cfg in sorted(self._entries.items())
            },
        }
        d = os.path.dirname(os.path.abspath(path)) or "."
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".tuning-")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
                # fsync before the rename: os.replace orders the directory
                # entry, not the data blocks — without the sync a crash can
                # commit a truncated file under the final name.
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def load(self, path: str) -> int:
        """Merge entries from ``path`` (loaded winners keep their recorded
        ``source``/``wall_s``); returns how many were loaded.

        A truncated, corrupt, or otherwise unreadable cache is a warning,
        not a crash: tuning is an optimisation, so the session starts with
        whatever loaded (usually nothing) and re-measures on demand.
        """
        try:
            with open(path) as f:
                doc = json.load(f)
            entries = doc.get("entries", {})
            items = [
                (k, TunedConfig.from_dict(d)) for k, d in entries.items()
            ]
        except (OSError, ValueError, TypeError, UnicodeDecodeError) as e:
            warnings.warn(
                f"ignoring unreadable tuning cache {path!r}: {e}",
                RuntimeWarning,
                stacklevel=2,
            )
            return 0
        with self._lock:
            for k, cfg in items:
                self._entries[k] = cfg
        return len(items)
