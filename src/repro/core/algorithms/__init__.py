"""The paper's §3 applications, each expressed with the Blaze MapReduce API."""
from repro.core.algorithms.gmm import GMMResult, gmm_em, gmm_em_reference
from repro.core.algorithms.kmeans import KMeansResult, kmeans, kmeans_reference
from repro.core.algorithms.knn import KNNResult, knn, knn_full_sort
from repro.core.algorithms.pagerank import (
    PageRankResult,
    pagerank,
    pagerank_reference,
)
from repro.core.algorithms.pi import estimate_pi, estimate_pi_handrolled
from repro.core.algorithms.wordcount import counts_dict, wordcount

__all__ = [
    "GMMResult",
    "KMeansResult",
    "KNNResult",
    "PageRankResult",
    "counts_dict",
    "estimate_pi",
    "estimate_pi_handrolled",
    "gmm_em",
    "gmm_em_reference",
    "kmeans",
    "kmeans_reference",
    "knn",
    "knn_full_sort",
    "pagerank",
    "pagerank_reference",
    "wordcount",
]
