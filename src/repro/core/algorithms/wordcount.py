"""Word frequency count (paper §3.1.1, Fig. 4, Appendix A.1).

Input lines arrive as fixed-width int32 token-id rows (padding = -1), i.e. the
output of ``data.synthetic.zipf_corpus`` or ``data.text.load_and_tokenize``.
The mapper emits one ``(word_id, 1)`` pair per live token — a batched emit, the
TPU shape of the paper's per-word ``emit(word, 1)`` loop.  Target is a
``DistHashMap`` keyed by word id.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import (
    DistHashMap,
    distribute,
    make_dist_hashmap,
    map_reduce,
)
from repro.core.session import BlazeSession, resolve


def wordcount_mapper(i, tokens, emit):
    emit(tokens, 1, mask=tokens >= 0)


def wordcount(
    lines: np.ndarray,
    *,
    mesh: Mesh | None = None,
    engine: str = "eager",
    capacity_per_shard: int | None = None,
    return_stats: bool = False,
    session: BlazeSession | None = None,
):
    """Count token occurrences; returns a DistHashMap (and optional stats)."""
    sess, mesh = resolve(session, mesh)
    vocab_bound = int(lines.max()) + 1 if lines.size else 1
    if capacity_per_shard is None:
        capacity_per_shard = max(64, 4 * vocab_bound)
    lines_v = distribute(lines, mesh)
    hm = make_dist_hashmap(mesh, capacity_per_shard, (), jnp.int32, "sum")
    return sess.map_reduce(
        lines_v,
        wordcount_mapper,
        "sum",
        hm,
        mesh=mesh,
        engine=engine,
        return_stats=return_stats,
    )


def counts_dict(hm: DistHashMap) -> dict[int, int]:
    return {k: int(v) for k, v in hm.to_dict().items()}
