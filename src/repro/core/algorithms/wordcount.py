"""Word frequency count (paper §3.1.1, Fig. 4, Appendix A.1).

Input lines arrive as fixed-width int32 token-id rows (padding = -1), i.e. the
output of ``data.synthetic.zipf_corpus`` or ``data.text.load_and_tokenize``.
The mapper emits one ``(word_id, 1)`` pair per live token — a batched emit, the
TPU shape of the paper's per-word ``emit(word, 1)`` loop.  Target is a
``DistHashMap`` keyed by word id.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import (
    DistHashMap,
    distribute,
    make_dist_hashmap,
    map_reduce,
)
from repro.core.session import BlazeSession, resolve


def wordcount_mapper(i, tokens, emit):
    emit(tokens, 1, mask=tokens >= 0)


def wordcount(
    lines: np.ndarray,
    *,
    mesh: Mesh | None = None,
    engine: str = "eager",
    capacity_per_shard: int | None = None,
    target: str = "hash",
    vocab_size: int | None = None,
    return_stats: bool = False,
    session: BlazeSession | None = None,
):
    """Count token occurrences.

    ``target="hash"`` (default) returns a ``DistHashMap`` — the open-ended
    vocabulary plan.  ``target="dense"`` counts into a dense ``[vocab_size]``
    int32 array (key == token id) — the paper's small-fixed-key-range plan
    when the vocabulary is bounded, and the shape ``engine="pallas"``/``"auto"``
    accelerates with the segment-reduce kernel.
    """
    if target not in ("hash", "dense"):
        raise ValueError(f"unknown target {target!r}; choose 'hash' or 'dense'")
    sess, mesh = resolve(session, mesh)
    lines_v = distribute(lines, mesh)
    if target == "dense":
        vocab = (
            vocab_size if vocab_size is not None
            else (int(lines.max()) + 1 if lines.size else 1)
        )
        counts = jnp.zeros((vocab,), jnp.int32)
        return sess.map_reduce(
            lines_v,
            wordcount_mapper,
            "sum",
            counts,
            mesh=mesh,
            engine=engine,
            return_stats=return_stats,
        )
    vocab_bound = int(lines.max()) + 1 if lines.size else 1
    if capacity_per_shard is None:
        capacity_per_shard = max(64, 4 * vocab_bound)
    hm = make_dist_hashmap(mesh, capacity_per_shard, (), jnp.int32, "sum")
    return sess.map_reduce(
        lines_v,
        wordcount_mapper,
        "sum",
        hm,
        mesh=mesh,
        engine=engine,
        return_stats=return_stats,
    )


def counts_dict(hm: DistHashMap) -> dict[int, int]:
    return {k: int(v) for k, v in hm.to_dict().items()}
