"""Word frequency count (paper §3.1.1, Fig. 4, Appendix A.1).

Input lines arrive as fixed-width int32 token-id rows (padding = -1), i.e. the
output of ``data.synthetic.zipf_corpus`` or ``data.text.load_and_tokenize``.
The mapper emits one ``(word_id, 1)`` pair per live token — a batched emit, the
TPU shape of the paper's per-word ``emit(word, 1)`` loop.  Target is a
``DistHashMap`` keyed by word id (``target="dense"`` for a bounded vocabulary).

Execution modes:

* ``mode="per_op"`` (default) — one ``map_reduce`` dispatch per pass; with
  ``iters > 1`` (the streaming-aggregation setting: the same batch re-counted
  each round) that is one dispatch *per pass*.
* ``mode="program"`` — the counting pass is lowered by ``session.program``
  into ONE executable whose hash table is threaded through a device-resident
  ``fori_loop``; ``run_loop(unroll=U)`` then drives ``iters`` passes in
  ``⌈iters/U⌉`` dispatches with zero per-iteration host syncs.  This is the
  word-count shape of the paper's resident hot loop — only possible now that
  hash targets thread through fused programs.

The known vocabulary bound is passed as ``key_range`` so the shuffle ships
narrowed keys and ``engine="pallas"`` sizes its combine table by distinct
words, not emitted tokens.

Out-of-core corpora: pass a ``ChunkedDistVector`` (``session.chunked``) as
``lines`` and the count streams block-at-a-time — ``mode="per_op"`` loops the
session's chunked dispatch, ``mode="program"`` drives ``run_stream`` so every
block of every pass goes through ONE executable (``iters`` becomes epochs).
``vocab_size`` is required for chunked input (the corpus is never resident to
scan for a max token id).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import (
    ChunkedDistVector,
    DistHashMap,
    distribute,
    make_dist_hashmap,
    map_reduce,
)
from repro.core.session import BlazeSession, resolve


def wordcount_mapper(i, tokens, emit):
    emit(tokens, 1, mask=tokens >= 0)


def _program_step(lines_v, hm, vocab_bound: int, engine: str):
    """(step_fn, initial state) for the planned streaming word count: one
    hash-target node per pass, the table threaded through the fused loop."""

    def step(ctx, s):
        ctx.map_reduce(
            lines_v, wordcount_mapper, "sum", hm,
            engine=engine, key_range=vocab_bound,
        )
        return {"it": s["it"] + 1}

    return step, {"it": jnp.zeros((), jnp.int32)}


@dataclasses.dataclass
class WordCountResult:
    """Multi-pass (streaming) word count: counts + the fusion counters."""

    counts: DistHashMap
    iterations: int
    compiles: int = 0  # per-op map_reduce executables compiled
    program_compiles: int = 0  # fused-program executables (mode="program")
    dispatches: int = 0  # executable launches across the loop
    host_syncs: int = 0  # blocking host materialisations across the loop


def wordcount(
    lines: np.ndarray,
    *,
    mesh: Mesh | None = None,
    engine: str = "eager",
    capacity_per_shard: int | None = None,
    target: str = "hash",
    vocab_size: int | None = None,
    mode: str = "per_op",
    iters: int = 1,
    unroll: int = 1,
    return_stats: bool = False,
    session: BlazeSession | None = None,
):
    """Count token occurrences.

    ``target="hash"`` (default) returns a ``DistHashMap`` — the open-ended
    vocabulary plan, and the shape ``engine="pallas"``/``"auto"`` accelerates
    with the hash-aggregation kernel.  ``target="dense"`` counts into a dense
    ``[vocab_size]`` int32 array (key == token id) — the paper's
    small-fixed-key-range plan, accelerated by the segment-reduce kernel.

    ``mode="program"`` (hash target only) fuses the pass into one executable
    and runs ``iters`` passes ``unroll`` at a time, returning a
    ``WordCountResult``; ``mode="per_op"`` with ``iters > 1`` runs the same
    loop per-op for comparison (also a ``WordCountResult``).  With the
    defaults (``per_op``, ``iters=1``) the return is the counts container
    alone — or ``(counts, MapReduceStats)`` under ``return_stats=True``.
    """
    if target not in ("hash", "dense"):
        raise ValueError(f"unknown target {target!r}; choose 'hash' or 'dense'")
    if mode not in ("per_op", "program"):
        raise ValueError(f"unknown mode {mode!r}; choose 'per_op' or 'program'")
    sess, mesh = resolve(session, mesh)
    is_chunked = isinstance(lines, ChunkedDistVector)
    if is_chunked:
        if vocab_size is None:
            raise ValueError(
                "chunked (out-of-core) wordcount needs an explicit vocab_size"
            )
        lines_v = lines
        size = lines.n
    else:
        lines_v = distribute(lines, mesh)
        size = lines.size
    if target == "dense":
        if mode == "program":
            raise ValueError(
                "mode='program' wordcount targets the hash path; use the "
                "generic session.program for dense iteration"
            )
        vocab = (
            vocab_size if vocab_size is not None
            else (int(lines.max()) + 1 if size else 1)
        )
        counts = jnp.zeros((vocab,), jnp.int32)
        return sess.map_reduce(
            lines_v,
            wordcount_mapper,
            "sum",
            counts,
            mesh=mesh,
            engine=engine,
            return_stats=return_stats,
        )
    vocab_bound = (
        vocab_size if vocab_size is not None
        else (int(lines.max()) + 1 if size else 1)
    )
    if capacity_per_shard is None:
        capacity_per_shard = max(64, 4 * vocab_bound)
    hm = make_dist_hashmap(mesh, capacity_per_shard, (), jnp.int32, "sum")
    compiles0 = sess.stats.compiles
    dispatches0 = sess.stats.dispatches
    syncs0 = sess.stats.host_syncs

    if mode == "program":
        step, state = _program_step(lines_v, hm, vocab_bound, engine)
        prog = sess.program(step, mesh=mesh)
        if is_chunked:
            # Out-of-core: each epoch streams every block through the one
            # fused executable; the hash table accumulates across dispatches
            # exactly as it does across loop iterations.
            state, info = sess.run_stream(prog, state, max_epochs=iters)
            return WordCountResult(
                counts=prog.hash_result(hm),
                iterations=info.epochs,
                compiles=sess.stats.compiles - compiles0,
                program_compiles=info.compiles,
                dispatches=sess.stats.dispatches - dispatches0,
                host_syncs=sess.stats.host_syncs - syncs0,
            )
        state, info = sess.run_loop(
            prog, state, max_iters=iters, unroll=unroll
        )
        return WordCountResult(
            counts=prog.hash_result(hm),
            iterations=info.iterations,
            compiles=sess.stats.compiles - compiles0,
            program_compiles=info.compiles,
            dispatches=sess.stats.dispatches - dispatches0,
            host_syncs=sess.stats.host_syncs - syncs0,
        )

    stats = None
    for _ in range(iters):
        hm, stats = sess.map_reduce(
            lines_v,
            wordcount_mapper,
            "sum",
            hm,
            mesh=mesh,
            engine=engine,
            key_range=vocab_bound,
            return_stats=True,
        )
    if iters > 1:
        return WordCountResult(
            counts=hm,
            iterations=iters,
            compiles=sess.stats.compiles - compiles0,
            dispatches=sess.stats.dispatches - dispatches0,
            host_syncs=sess.stats.host_syncs - syncs0,
        )
    return (hm, stats) if return_stats else hm


def counts_dict(hm: DistHashMap) -> dict[int, int]:
    return {k: int(v) for k, v in hm.to_dict().items()}
