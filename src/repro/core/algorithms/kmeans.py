"""K-Means (paper §3.1.3, Fig. 6) — one MapReduce per assignment step.

The mapper assigns a point to its nearest centre and emits
``(centre, [x…, 1])`` — per-centre sums and counts accumulate in one dense
``[K, dim+1]`` target (small fixed key range).  The refinement step is serial,
exactly as in the paper.  Centres are threaded via ``env``.

``engine=`` accepts ``"eager" | "pallas" | "naive" | "auto"``: with pallas
(or auto, since K is small) the per-shard sums-and-counts combine runs
through the segment-reduce kernel's VMEM accumulator.

``mode="program"`` fuses the assignment MapReduce *and* the serial
refinement glue into one executable (``session.program``) and runs
``unroll`` iterations per dispatch device-resident (``session.run_loop``):
1 program compile, ``≤ ⌈iters/unroll⌉`` dispatches/host-syncs, vs one
dispatch + one sync per iteration in ``mode="per_op"``.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import DistVector, distribute
from repro.core.session import BlazeSession, resolve


def assign_mapper(i, x, emit, centers):
    d2 = jnp.sum((centers - x[None, :]) ** 2, axis=1)
    c = jnp.argmin(d2)
    emit(c, jnp.concatenate([x, jnp.ones((1,), x.dtype)]))


def inertia_mapper(i, x, emit, centers):
    d2 = jnp.sum((centers - x[None, :]) ** 2, axis=1)
    emit(0, jnp.min(d2))


@dataclasses.dataclass
class KMeansResult:
    centers: np.ndarray
    iterations: int
    converged: bool
    inertia: float
    shuffle_bytes_per_iter: int
    compiles: int = 0  # map_reduce executables compiled across ALL iterations
    program_compiles: int = 0  # fused-program executables (mode="program")
    dispatches: int = 0  # executable launches across the loop
    host_syncs: int = 0  # blocking host materialisations across the loop


def kmeans(
    points: np.ndarray | DistVector,
    k: int,
    *,
    init_centers: np.ndarray | None = None,
    tol: float = 1e-4,
    max_iters: int = 50,
    mesh: Mesh | None = None,
    engine: str = "eager",
    wire: str = "none",
    mode: str = "per_op",
    unroll: int = 1,
    seed: int = 0,
    session: BlazeSession | None = None,
) -> KMeansResult:
    if mode not in ("per_op", "program"):
        raise ValueError(f"unknown mode {mode!r}; choose 'per_op' or 'program'")
    sess, mesh = resolve(session, mesh)
    if isinstance(points, DistVector):
        pts_v = points
        dim = points.data.shape[1]
    else:
        pts_v = distribute(points.astype(np.float32), mesh)
        dim = points.shape[1]
    if init_centers is None:
        rng = np.random.RandomState(seed)
        init_centers = np.asarray(pts_v.data)[
            rng.choice(min(len(pts_v), 4096), k, replace=False)
        ]
    centers = jnp.asarray(init_centers, jnp.float32)
    compiles0 = sess.stats.compiles
    dispatches0 = sess.stats.dispatches
    syncs0 = sess.stats.host_syncs

    if mode == "program":

        def step(ctx, s):
            c = s["centers"]
            sums = ctx.map_reduce(
                pts_v, assign_mapper, "sum",
                jnp.zeros((k, dim + 1), jnp.float32),
                engine=engine, wire=wire, env=c,
            )
            counts = jnp.maximum(sums[:, dim:], 1.0)
            new_c = sums[:, :dim] / counts  # serial refinement step, fused
            move = jnp.max(jnp.sum((new_c - c) ** 2, axis=1))
            return {"centers": new_c, "move": move}

        prog = sess.program(step, mesh=mesh)
        state = {"centers": centers, "move": jnp.asarray(jnp.inf, jnp.float32)}
        state, info = sess.run_loop(
            prog, state, cond=lambda s: float(s["move"]) < tol * tol,
            max_iters=max_iters, unroll=unroll,
        )
        centers = state["centers"]
        inertia = sess.map_reduce(
            pts_v, inertia_mapper, "sum", jnp.zeros((1,), jnp.float32),
            mesh=mesh, engine=engine, env=centers,
        )[0]
        return KMeansResult(
            centers=np.asarray(centers),
            iterations=info.iterations,
            converged=info.converged,
            inertia=float(inertia),
            shuffle_bytes_per_iter=0,
            compiles=sess.stats.compiles - compiles0,
            program_compiles=info.compiles,
            # session delta, not info.dispatches: includes the final per-op
            # inertia pass, so per_op and program rows compare like-for-like
            dispatches=sess.stats.dispatches - dispatches0,
            host_syncs=sess.stats.host_syncs - syncs0,
        )

    it, converged, stats = 0, False, None
    for it in range(1, max_iters + 1):
        sums, stats = sess.map_reduce(
            pts_v, assign_mapper, "sum", jnp.zeros((k, dim + 1), jnp.float32),
            mesh=mesh, engine=engine, wire=wire, env=centers, return_stats=True,
        )
        counts = jnp.maximum(sums[:, dim:], 1.0)
        new_centers = sums[:, :dim] / counts  # serial refinement step
        move = float(np.asarray(sess.host_value(
            jnp.max(jnp.sum((new_centers - centers) ** 2, axis=1))
        )))
        centers = new_centers
        if move < tol * tol:
            converged = True
            break

    # Final inertia via one more MapReduce (dense [1] target).
    inertia = sess.map_reduce(
        pts_v, inertia_mapper, "sum", jnp.zeros((1,), jnp.float32),
        mesh=mesh, engine=engine, env=centers,
    )[0]
    fs = stats.finalize() if stats is not None else None
    return KMeansResult(
        centers=np.asarray(centers),
        iterations=it,
        converged=converged,
        inertia=float(inertia),
        shuffle_bytes_per_iter=fs.shuffle_payload_bytes if fs else 0,
        compiles=sess.stats.compiles - compiles0,
        dispatches=sess.stats.dispatches - dispatches0,
        host_syncs=sess.stats.host_syncs - syncs0,
    )


def kmeans_reference(
    points: np.ndarray, init_centers: np.ndarray, tol: float = 1e-4,
    max_iters: int = 50,
) -> tuple[np.ndarray, int]:
    """numpy oracle (same init, same convergence rule)."""
    centers = init_centers.astype(np.float64).copy()
    k = centers.shape[0]
    for it in range(1, max_iters + 1):
        d2 = ((points[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
        assign = d2.argmin(1)
        new = np.stack(
            [
                points[assign == j].mean(0) if (assign == j).any() else centers[j]
                for j in range(k)
            ]
        )
        move = ((new - centers) ** 2).sum(1).max()
        centers = new
        if move < tol * tol:
            break
    return centers.astype(np.float32), it
