"""K-Means (paper §3.1.3, Fig. 6) — one MapReduce per assignment step.

The mapper assigns a point to its nearest centre and emits
``(centre, [x…, 1])`` — per-centre sums and counts accumulate in one dense
``[K, dim+1]`` target (small fixed key range).  The refinement step is serial,
exactly as in the paper.  Centres are threaded via ``env``.

``engine=`` accepts ``"eager" | "pallas" | "naive" | "auto"``: with pallas
(or auto, since K is small) the per-shard sums-and-counts combine runs
through the segment-reduce kernel's VMEM accumulator.

``mode="program"`` fuses the assignment MapReduce *and* the serial
refinement glue into one executable (``session.program``) and runs
``unroll`` iterations per dispatch device-resident (``session.run_loop``):
1 program compile, ``≤ ⌈iters/unroll⌉`` dispatches/host-syncs, vs one
dispatch + one sync per iteration in ``mode="per_op"``.

In program mode the **inertia rides the assignment pass**: the step's mapper
emits ``(centre, [x…, 1, min_d2])`` into one ``[K, dim+2]`` target, so the
distance computation that picks the centre also yields the point's inertia
contribution — the separate ``inertia_mapper`` pass (which recomputed every
distance) disappears from the plan.  The final inertia w.r.t. the CONVERGED
centres comes from one extra dispatch of the same fused executable (its
centre update is discarded): no per-op executable is ever built, so
10-iteration program k-means reports 0 map_reduce compiles and
``⌈10/unroll⌉ + 1`` dispatches.

``mode="stream"`` is the out-of-core variant: ``points`` is a
``ChunkedDistVector`` and one k-means *iteration* becomes one *epoch* of
``session.run_stream`` — each block dispatch accumulates its partial
``[K, dim+2]`` sums into streamed state, and the refinement step fires only
on the epoch's last block (``jnp.where`` on the block counter).  Still ONE
program compile regardless of block count or iteration count; convergence is
tested once per epoch.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import ChunkedDistVector, DistVector, distribute
from repro.core.session import BlazeSession, resolve


def assign_mapper(i, x, emit, centers):
    d2 = jnp.sum((centers - x[None, :]) ** 2, axis=1)
    c = jnp.argmin(d2)
    emit(c, jnp.concatenate([x, jnp.ones((1,), x.dtype)]))


def assign_inertia_mapper(i, x, emit, centers):
    """Program-mode mapper: one distance computation serves both the centre
    assignment AND the point's inertia contribution (``min d²``) — emitted
    together as ``(centre, [x…, 1, min_d2])`` into a ``[K, dim+2]`` target."""
    d2 = jnp.sum((centers - x[None, :]) ** 2, axis=1)
    c = jnp.argmin(d2)
    emit(c, jnp.concatenate([x, jnp.ones((1,), x.dtype), jnp.min(d2)[None]]))


def inertia_mapper(i, x, emit, centers):
    d2 = jnp.sum((centers - x[None, :]) ** 2, axis=1)
    emit(0, jnp.min(d2))


@dataclasses.dataclass
class KMeansResult:
    centers: np.ndarray
    iterations: int
    converged: bool
    inertia: float
    shuffle_bytes_per_iter: int
    compiles: int = 0  # map_reduce executables compiled across ALL iterations
    program_compiles: int = 0  # fused-program executables (mode="program")
    dispatches: int = 0  # executable launches across the loop
    host_syncs: int = 0  # blocking host materialisations across the loop
    collectives_per_iter: int = 0  # optimized plan's collectives (program mode)


def _program_step(pts_v: DistVector, k: int, dim: int, engine: str, wire: str):
    """(step_fn, state builder) for the planned k-means iteration: ONE
    ``[K, dim+2]`` MapReduce (sums | counts | inertia) + the refinement glue."""

    def step(ctx, s):
        c = s["centers"]
        sums = ctx.map_reduce(
            pts_v, assign_inertia_mapper, "sum",
            jnp.zeros((k, dim + 2), jnp.float32),
            engine=engine, wire=wire, env=c,
        )
        counts = jnp.maximum(sums[:, dim:dim + 1], 1.0)
        new_c = sums[:, :dim] / counts  # serial refinement step, fused
        move = jnp.max(jnp.sum((new_c - c) ** 2, axis=1))
        # inertia of the CURRENT centres — the same distances that chose them
        inertia = jnp.sum(sums[:, dim + 1])
        return {"centers": new_c, "move": move, "inertia": inertia}

    def state0(centers):
        return {
            "centers": centers,
            "move": jnp.asarray(jnp.inf, jnp.float32),
            "inertia": jnp.asarray(0.0, jnp.float32),
        }

    return step, state0


def _stream_step(pts_c: ChunkedDistVector, k: int, dim: int, engine: str,
                 wire: str):
    """(step_fn, state builder) for the out-of-core k-means epoch.

    Each dispatch sees ONE resident block: its partial ``[K, dim+2]`` sums
    accumulate into ``acc``; the serial refinement (centre update, move,
    inertia) fires only on the epoch's last block, after which ``acc`` resets
    and the block counter wraps — the accumulate/finalize-on-last-block
    pattern that lets one executable serve every block of every epoch.
    """
    n_blocks = pts_c.n_blocks

    def step(ctx, s):
        c = s["centers"]
        part = ctx.map_reduce(
            pts_c, assign_inertia_mapper, "sum",
            jnp.zeros((k, dim + 2), jnp.float32),
            engine=engine, wire=wire, env=c,
        )
        acc = s["acc"] + part
        last = s["blk"] == n_blocks - 1
        counts = jnp.maximum(acc[:, dim:dim + 1], 1.0)
        new_c = acc[:, :dim] / counts  # refinement — meaningful on last block
        move = jnp.max(jnp.sum((new_c - c) ** 2, axis=1))
        inertia = jnp.sum(acc[:, dim + 1])
        return {
            "centers": jnp.where(last, new_c, c),
            "move": jnp.where(last, move, s["move"]),
            "inertia": jnp.where(last, inertia, s["inertia"]),
            "acc": jnp.where(last, jnp.zeros_like(acc), acc),
            "blk": jnp.where(last, 0, s["blk"] + 1),
        }

    def state0(centers):
        return {
            "centers": centers,
            "move": jnp.asarray(jnp.inf, jnp.float32),
            "inertia": jnp.asarray(0.0, jnp.float32),
            "acc": jnp.zeros((k, dim + 2), jnp.float32),
            "blk": jnp.zeros((), jnp.int32),
        }

    return step, state0


def kmeans(
    points: np.ndarray | DistVector,
    k: int,
    *,
    init_centers: np.ndarray | None = None,
    tol: float = 1e-4,
    max_iters: int = 50,
    mesh: Mesh | None = None,
    engine: str = "eager",
    wire: str = "none",
    mode: str = "per_op",
    unroll: int = 1,
    seed: int = 0,
    session: BlazeSession | None = None,
) -> KMeansResult:
    if mode not in ("per_op", "program", "stream"):
        raise ValueError(
            f"unknown mode {mode!r}; choose 'per_op', 'program' or 'stream'"
        )
    sess, mesh = resolve(session, mesh)
    if isinstance(points, ChunkedDistVector):
        if mode == "program":
            raise ValueError(
                "chunked points need mode='stream' (the out-of-core program "
                "loop) or mode='per_op'"
            )
        pts_v = points
        dim = points.shape_tail[0]
    elif isinstance(points, DistVector):
        pts_v = points
        dim = points.data.shape[1]
    else:
        pts_v = distribute(points.astype(np.float32), mesh)
        dim = points.shape[1]
    if init_centers is None:
        rng = np.random.RandomState(seed)
        if isinstance(pts_v, ChunkedDistVector):
            pool = pts_v.block_host(0)[: pts_v.block_true_rows(0)]
            init_centers = pool[rng.choice(min(len(pool), 4096), k, replace=False)]
        else:
            init_centers = np.asarray(pts_v.data)[
                rng.choice(min(len(pts_v), 4096), k, replace=False)
            ]
    centers = jnp.asarray(init_centers, jnp.float32)
    compiles0 = sess.stats.compiles
    dispatches0 = sess.stats.dispatches
    syncs0 = sess.stats.host_syncs

    if mode == "stream":
        if not isinstance(pts_v, ChunkedDistVector):
            raise ValueError(
                "mode='stream' needs ChunkedDistVector points "
                "(see session.chunked)"
            )
        step, state0 = _stream_step(pts_v, k, dim, engine, wire)
        prog = sess.program(step, mesh=mesh)
        state, info = sess.run_stream(
            prog, state0(centers),
            cond=lambda s: float(s["move"]) < tol * tol,
            max_epochs=max_iters,
        )
        centers = state["centers"]
        # Inertia w.r.t. the FINAL centres: one more epoch of the same
        # executable — its refinement output is discarded, mirroring the
        # in-memory program mode's probe dispatch.
        probe, _ = sess.run_stream(prog, state, max_epochs=1)
        inertia = float(np.asarray(sess.host_value(probe["inertia"])))
        return KMeansResult(
            centers=np.asarray(centers),
            iterations=info.epochs,
            converged=info.converged,
            inertia=inertia,
            shuffle_bytes_per_iter=0,
            compiles=sess.stats.compiles - compiles0,
            program_compiles=info.compiles,
            dispatches=sess.stats.dispatches - dispatches0,
            host_syncs=sess.stats.host_syncs - syncs0,
            collectives_per_iter=prog.plan.collectives_per_iter,
        )

    if mode == "program":
        step, state0 = _program_step(pts_v, k, dim, engine, wire)
        prog = sess.program(step, mesh=mesh)
        state, info = sess.run_loop(
            prog, state0(centers),
            cond=lambda s: float(s["move"]) < tol * tol,
            max_iters=max_iters, unroll=unroll,
        )
        centers = state["centers"]
        # Inertia w.r.t. the FINAL centres: one more dispatch of the same
        # fused executable — its assignment pass IS the inertia pass (the
        # centre update it also computes is discarded).  No per-op
        # executable is ever built for k-means in program mode.
        probe = prog(state, 1)
        inertia = float(np.asarray(sess.host_value(probe["inertia"])))
        return KMeansResult(
            centers=np.asarray(centers),
            iterations=info.iterations,
            converged=info.converged,
            inertia=inertia,
            shuffle_bytes_per_iter=0,
            compiles=sess.stats.compiles - compiles0,
            program_compiles=info.compiles,
            # session delta, not info.dispatches: includes the final inertia
            # probe, so per_op and program rows compare like-for-like
            dispatches=sess.stats.dispatches - dispatches0,
            host_syncs=sess.stats.host_syncs - syncs0,
            collectives_per_iter=prog.plan.collectives_per_iter,
        )

    it, converged, stats = 0, False, None
    for it in range(1, max_iters + 1):
        sums, stats = sess.map_reduce(
            pts_v, assign_mapper, "sum", jnp.zeros((k, dim + 1), jnp.float32),
            mesh=mesh, engine=engine, wire=wire, env=centers, return_stats=True,
        )
        counts = jnp.maximum(sums[:, dim:], 1.0)
        new_centers = sums[:, :dim] / counts  # serial refinement step
        move = float(np.asarray(sess.host_value(
            jnp.max(jnp.sum((new_centers - centers) ** 2, axis=1))
        )))
        centers = new_centers
        if move < tol * tol:
            converged = True
            break

    # Final inertia via one more MapReduce (dense [1] target), materialised
    # through the session so the sync is counted.
    inertia = sess.map_reduce(
        pts_v, inertia_mapper, "sum", jnp.zeros((1,), jnp.float32),
        mesh=mesh, engine=engine, env=centers,
    )[0]
    inertia = float(np.asarray(sess.host_value(inertia)))
    fs = stats.finalize() if stats is not None else None
    return KMeansResult(
        centers=np.asarray(centers),
        iterations=it,
        converged=converged,
        inertia=inertia,
        shuffle_bytes_per_iter=fs.shuffle_payload_bytes if fs else 0,
        compiles=sess.stats.compiles - compiles0,
        dispatches=sess.stats.dispatches - dispatches0,
        host_syncs=sess.stats.host_syncs - syncs0,
    )


def kmeans_reference(
    points: np.ndarray, init_centers: np.ndarray, tol: float = 1e-4,
    max_iters: int = 50,
) -> tuple[np.ndarray, int]:
    """numpy oracle (same init, same convergence rule)."""
    centers = init_centers.astype(np.float64).copy()
    k = centers.shape[0]
    for it in range(1, max_iters + 1):
        d2 = ((points[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
        assign = d2.argmin(1)
        new = np.stack(
            [
                points[assign == j].mean(0) if (assign == j).any() else centers[j]
                for j in range(k)
            ]
        )
        move = ((new - centers) ** 2).sum(1).max()
        centers = new
        if move < tol * tol:
            break
    return centers.astype(np.float32), it
