"""Expectation-Maximization for Gaussian Mixtures (paper §3.1.4, Fig. 7).

Six MapReduce-family operations per iteration, exactly the paper's plan:

  1. densities  p_ik  (Eq. 2)  — ``foreach`` over points (elementwise map)
  2. membership w_ik  (Eq. 3)  — ``foreach``
  3. N_k = Σ_i w_ik            — MapReduce, dense [K] "sum"
  4. Σ_i w_ik x_i    (Eq. 5)   — MapReduce, dense [K, d] "sum"
  5. Σ_i w_ik (x−μ)(x−μ)ᵀ (Eq. 6) — MapReduce, dense [K, d, d] "sum"
  6. log-likelihood  (Eq. 7)   — MapReduce, dense [1] "sum"

All K-keyed targets are small-fixed-key-range dense accumulators, so each op
lowers to a per-device dense partial + one ``psum`` — the hand-written plan.
``engine=`` accepts ``"eager" | "pallas" | "naive" | "auto"``; ops 3–5 emit
``jnp.arange(k)`` keys (dynamic), which pallas/auto route through the
segment-reduce kernel.
Points are stored distributedly; per-point state (densities/memberships) lives
beside the point in one DistVector of rows ``[x | p-or-w]``.

``mode="program"`` fuses all six ops of one EM round — two ``ctx.foreach``
elementwise maps (whose per-point results stay on-shard as ``LocalVector``s,
never crossing the wire), four MapReduce collectives, and the M-step glue
(``jnp.linalg.inv``/``slogdet`` on the tiny [K, d, d] mixture state) — into
ONE executable via ``session.program``, with ``unroll`` EM rounds per
dispatch (``session.run_loop``).  ``mode="per_op"`` keeps the paper-shaped
six-dispatch loop with its per-round host syncs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import DistVector, distribute
from repro.core.session import BlazeSession, resolve


def _gauss_env(alpha, mu, sigma):
    """Precompute per-component precision + normalisation (host, K is tiny)."""
    k, d = mu.shape
    prec = np.linalg.inv(sigma)
    logdet = np.linalg.slogdet(sigma)[1]
    logcoef = -0.5 * (d * np.log(2 * np.pi) + logdet)
    return (
        jnp.asarray(alpha, jnp.float32),
        jnp.asarray(mu, jnp.float32),
        jnp.asarray(prec, jnp.float32),
        jnp.asarray(logcoef, jnp.float32),
    )


def density_fn(row, env):
    """foreach #1: fill the p-block with Gaussian densities p_ik (Eq. 2)."""
    alpha, mu, prec, logcoef = env
    d = mu.shape[1]
    x = row[:d]
    diff = x[None, :] - mu  # [K, d]
    maha = jnp.einsum("kd,kde,ke->k", diff, prec, diff)
    logp = logcoef - 0.5 * maha
    return jnp.concatenate([x, logp])


def membership_fn(row, env):
    """foreach #2: p-block → w-block (Eq. 3), numerically via log-sum-exp."""
    alpha, mu, prec, logcoef = env
    d = mu.shape[1]
    x, logp = row[:d], row[d:]
    logw = logp + jnp.log(jnp.maximum(alpha, 1e-30))
    logw = logw - jax.nn.logsumexp(logw)
    return jnp.concatenate([x, jnp.exp(logw)])


def nk_mapper(i, row, emit, mu):
    k = mu.shape[0]
    w = row[-k:]
    emit(jnp.arange(k), w)


def musum_mapper(i, row, emit, mu):
    k, d = mu.shape
    x, w = row[:d], row[-k:]
    emit(jnp.arange(k), w[:, None] * x[None, :])


def sigmasum_mapper(i, row, emit, mu):
    k, d = mu.shape
    x, w = row[:d], row[-k:]
    diff = x[None, :] - mu  # [K, d]
    outer = diff[:, :, None] * diff[:, None, :]
    emit(jnp.arange(k), w[:, None, None] * outer)


def loglik_mapper(i, row, emit, alpha):
    k = alpha.shape[0]
    logp = row[-k:]
    emit(0, jax.nn.logsumexp(logp + jnp.log(jnp.maximum(alpha, 1e-30))))


@dataclasses.dataclass
class GMMResult:
    alpha: np.ndarray
    mu: np.ndarray
    sigma: np.ndarray
    log_likelihood: float
    iterations: int
    converged: bool
    shuffle_bytes_per_iter: int
    compiles: int = 0  # map_reduce executables compiled across ALL iterations
    program_compiles: int = 0  # fused-program executables (mode="program")
    dispatches: int = 0  # executable launches across the loop
    host_syncs: int = 0  # blocking host materialisations across the loop
    collectives_per_iter: int = 0  # optimized plan's collectives (program mode)


def _program_step(rows_v, k: int, d: int, n: int, engine: str):
    """(step_fn, state builder) for the planned EM round.

    The round's four dense reductions issue only TWO collectives under the
    plan optimizer: the log-likelihood, N_k and Σwx psums are independent
    f32 sums and batch into one fused collective (their results are first
    consumed together at the M-step glue); Σw(x−μ)(x−μ)ᵀ depends on the new
    mean and ships alone.  ``Plan.collectives_per_iter`` asserts 2 vs the
    4 an unoptimized plan issues (``tests/test_plan.py``).
    """
    eye = jnp.eye(d, dtype=jnp.float32)

    def step(ctx, s):
        alpha_, mu_, sigma_ = s["alpha"], s["mu"], s["sigma"]
        # _gauss_env, on-device (K is tiny; inv/slogdet fuse into the step)
        prec = jnp.linalg.inv(sigma_).astype(jnp.float32)
        logdet = jnp.linalg.slogdet(sigma_)[1]
        logcoef = (
            -0.5 * (d * jnp.log(2.0 * jnp.pi) + logdet)
        ).astype(jnp.float32)
        env = (alpha_, mu_, prec, logcoef)
        rows_p = ctx.foreach(rows_v, density_fn, env=env)  # op 1
        ll = ctx.map_reduce(  # op 6 (current model, reads the p-block)
            rows_p, loglik_mapper, "sum", jnp.zeros((1,), jnp.float32),
            engine=engine, env=alpha_,
        )[0]
        rows_w = ctx.foreach(rows_p, membership_fn, env=env)  # op 2
        nk = ctx.map_reduce(  # op 3
            rows_w, nk_mapper, "sum", jnp.zeros((k,), jnp.float32),
            engine=engine, env=mu_,
        )
        musum = ctx.map_reduce(  # op 4
            rows_w, musum_mapper, "sum", jnp.zeros((k, d), jnp.float32),
            engine=engine, env=mu_,
        )
        nk_c = jnp.maximum(nk, 1e-8)  # first consumption: ll/nk/musum flush
        new_mu = musum / nk_c[:, None]
        sigsum = ctx.map_reduce(  # op 5 (depends on new_mu -> own collective)
            rows_w, sigmasum_mapper, "sum",
            jnp.zeros((k, d, d), jnp.float32),
            engine=engine, env=new_mu,
        )
        new_sigma = sigsum / nk_c[:, None, None] + 1e-4 * eye
        return {
            "alpha": (nk_c / n).astype(jnp.float32),
            "mu": new_mu,
            "sigma": new_sigma,
            "ll": jnp.asarray(ll).reshape(()),
            "prev_ll": s["ll"],
        }

    def state0(alpha, mu, sigma):
        return {
            "alpha": jnp.asarray(alpha),
            "mu": jnp.asarray(mu),
            "sigma": jnp.asarray(sigma),
            "ll": jnp.asarray(-jnp.inf, jnp.float32),
            "prev_ll": jnp.asarray(-jnp.inf, jnp.float32),
        }

    return step, state0


def gmm_em(
    points: np.ndarray,
    k: int,
    *,
    init_mu: np.ndarray | None = None,
    tol: float = 1e-4,
    max_iters: int = 50,
    mesh: Mesh | None = None,
    engine: str = "eager",
    mode: str = "per_op",
    unroll: int = 1,
    seed: int = 0,
    session: BlazeSession | None = None,
) -> GMMResult:
    if mode not in ("per_op", "program"):
        raise ValueError(f"unknown mode {mode!r}; choose 'per_op' or 'program'")
    sess, mesh = resolve(session, mesh)
    n, d = points.shape
    rng = np.random.RandomState(seed)
    if init_mu is None:
        init_mu = points[rng.choice(n, k, replace=False)]
    alpha = np.full(k, 1.0 / k, np.float32)
    mu = init_mu.astype(np.float32).copy()
    sigma = np.tile(np.eye(d, dtype=np.float32), (k, 1, 1))

    rows0 = np.concatenate([points, np.zeros((n, k), np.float32)], axis=1)
    rows_v = distribute(rows0.astype(np.float32), mesh)
    compiles0 = sess.stats.compiles
    dispatches0 = sess.stats.dispatches
    syncs0 = sess.stats.host_syncs

    if mode == "program":
        step, state0 = _program_step(rows_v, k, d, n, engine)

        def cond(s):
            ll_, prev = float(s["ll"]), float(s["prev_ll"])
            return abs(ll_ - prev) < tol * max(1.0, abs(prev))

        prog = sess.program(step, mesh=mesh)
        state, info = sess.run_loop(
            prog, state0(alpha, mu, sigma), cond=cond, max_iters=max_iters,
            unroll=unroll,
        )
        return GMMResult(
            alpha=np.asarray(state["alpha"]),
            mu=np.asarray(state["mu"]),
            sigma=np.asarray(state["sigma"]),
            log_likelihood=float(state["ll"]),
            iterations=info.iterations,
            converged=info.converged,
            shuffle_bytes_per_iter=0,
            compiles=sess.stats.compiles - compiles0,
            program_compiles=info.compiles,
            dispatches=sess.stats.dispatches - dispatches0,
            host_syncs=sess.stats.host_syncs - syncs0,
            collectives_per_iter=prog.plan.collectives_per_iter,
        )

    prev_ll, it, converged, stats = -np.inf, 0, False, None
    for it in range(1, max_iters + 1):
        env = _gauss_env(alpha, mu, sigma)
        rows_p = sess.foreach(rows_v, density_fn, env=env)  # op 1
        # op 6 (log-likelihood of the CURRENT model) reads the p-block:
        ll = sess.map_reduce(
            rows_p, loglik_mapper, "sum", jnp.zeros((1,), jnp.float32),
            mesh=mesh, engine=engine, env=env[0],
        )[0]
        rows_w = sess.foreach(rows_p, membership_fn, env=env)  # op 2
        nk = sess.map_reduce(  # op 3
            rows_w, nk_mapper, "sum", jnp.zeros((k,), jnp.float32),
            mesh=mesh, engine=engine, env=env[1],
        )
        musum, stats = sess.map_reduce(  # op 4
            rows_w, musum_mapper, "sum", jnp.zeros((k, d), jnp.float32),
            mesh=mesh, engine=engine, env=env[1], return_stats=True,
        )
        nk_np = np.maximum(np.asarray(sess.host_value(nk)), 1e-8)
        new_mu = np.asarray(sess.host_value(musum)) / nk_np[:, None]
        sigsum = sess.map_reduce(  # op 5
            rows_w, sigmasum_mapper, "sum", jnp.zeros((k, d, d), jnp.float32),
            mesh=mesh, engine=engine, env=jnp.asarray(new_mu), return_stats=False,
        )
        alpha = (nk_np / n).astype(np.float32)
        mu = new_mu.astype(np.float32)
        sigma = (
            np.asarray(sess.host_value(sigsum)) / nk_np[:, None, None]
            + 1e-4 * np.eye(d, dtype=np.float32)
        ).astype(np.float32)

        ll = float(np.asarray(sess.host_value(ll)))
        if abs(ll - prev_ll) < tol * max(1.0, abs(prev_ll)):
            converged = True
            break
        prev_ll = ll

    fs = stats.finalize() if stats is not None else None
    return GMMResult(
        alpha=alpha, mu=mu, sigma=sigma, log_likelihood=float(ll),
        iterations=it, converged=converged,
        shuffle_bytes_per_iter=fs.shuffle_payload_bytes if fs else 0,
        compiles=sess.stats.compiles - compiles0,
        dispatches=sess.stats.dispatches - dispatches0,
        host_syncs=sess.stats.host_syncs - syncs0,
    )


def gmm_em_reference(points, k, init_mu, tol=1e-4, max_iters=50):
    """numpy oracle with the same update rules + regularisation."""
    n, d = points.shape
    alpha = np.full(k, 1.0 / k)
    mu = init_mu.astype(np.float64).copy()
    sigma = np.tile(np.eye(d), (k, 1, 1))
    prev_ll = -np.inf
    for it in range(1, max_iters + 1):
        prec = np.linalg.inv(sigma)
        logdet = np.linalg.slogdet(sigma)[1]
        diff = points[:, None, :] - mu[None]  # [n,k,d]
        maha = np.einsum("nkd,kde,nke->nk", diff, prec, diff)
        logp = -0.5 * (d * np.log(2 * np.pi) + logdet)[None] - 0.5 * maha
        logw = logp + np.log(alpha)[None]
        ll = np.log(np.exp(logw - logw.max(1, keepdims=True)).sum(1)).sum() + logw.max(1).sum()
        w = np.exp(logw - logw.max(1, keepdims=True))
        w /= w.sum(1, keepdims=True)
        nk = np.maximum(w.sum(0), 1e-8)
        new_mu = (w[:, :, None] * points[:, None, :]).sum(0) / nk[:, None]
        diff2 = points[:, None, :] - new_mu[None]
        sigma = (
            np.einsum("nk,nkd,nke->kde", w, diff2, diff2) / nk[:, None, None]
            + 1e-4 * np.eye(d)
        )
        alpha = nk / n
        mu = new_mu
        if abs(ll - prev_ll) < tol * max(1.0, abs(prev_ll)):
            break
        prev_ll = ll
    return alpha, mu, sigma, ll, it
