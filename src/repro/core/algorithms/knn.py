"""Nearest-100-neighbours (paper §3.1.5, Fig. 8).

Implemented, as in the paper, with the distributed container's ``topk`` and a
custom comparison (negative Euclidean distance to the query): each shard
selects its local top-k, and only k·n_shards candidates cross the wire —
O(n + k log k) work, O(k) space.  ``knn_full_sort`` is the naive baseline that
materialises and sorts every distance (what a shuffle-everything plan does).

kNN's plan is **container-level**: the ``topk`` container fixes the whole
execution plan, so an ``engine=`` request cannot change anything.  The
driver used to validate the argument and silently drop it; now the request
is *surfaced* — ``KNNResult.engine`` reports ``"container:topk"`` with the
ignored request in ``KNNResult.engine_requested``, and ``mode="program"``
shows the same on the plan's ``topk`` node in ``session.explain``.

``mode="program"`` routes the selection through the planner
(``session.program`` + ``ctx.topk``): per-shard ``lax.top_k``, one
all_gather of candidates, global re-select — all inside one executable.
Either mode materialises results through the session (``session.topk`` /
``session.host_value``), so ``stats.host_syncs`` counts kNN's blocking sync
(raw ``device_get`` used to bypass the counter).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import DistVector, distribute
from repro.core.session import BlazeSession, resolve


def _neg_sq_dist(x, q):
    """topk score: negative squared Euclidean distance to the query ``q``."""
    return -jnp.sum((x - q) ** 2)


@dataclasses.dataclass
class KNNResult:
    neighbors: np.ndarray  # [k, dim]
    distances: np.ndarray  # [k]
    wire_candidates: int  # how many rows crossed the wire
    engine: str = "container:topk"  # the plan is fixed by the container
    engine_requested: str = "auto"  # surfaced, never applied


def _program_step(pts_v: DistVector, k: int, engine: str):
    """step_fn for the planned spelling of kNN (one ``ctx.topk`` node)."""

    def step(ctx, s):
        nbrs, scores = ctx.topk(
            pts_v, k, score_fn=_neg_sq_dist, env=s["q"], engine=engine,
        )
        return {"q": s["q"], "neighbors": nbrs, "scores": scores}

    return step


def knn(
    points: np.ndarray | DistVector,
    query: np.ndarray,
    k: int = 100,
    *,
    mesh: Mesh | None = None,
    engine: str = "auto",
    mode: str = "per_op",
    session: BlazeSession | None = None,
) -> KNNResult:
    # Uniform driver interface: knn's plan is container-level (``topk``), so
    # the engine choice cannot change it — validate, then SURFACE the
    # request in the result/plan instead of accepting-and-dropping it.
    from repro.core.plan import ENGINES

    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; choose from {ENGINES}")
    if mode not in ("per_op", "program"):
        raise ValueError(f"unknown mode {mode!r}; choose 'per_op' or 'program'")
    sess, mesh = resolve(session, mesh)
    if isinstance(points, DistVector):
        pts_v = points
    else:
        pts_v = distribute(points.astype(np.float32), mesh)
    q = jnp.asarray(query, jnp.float32)
    n_shards = mesh.shape.get("data", 1)

    if mode == "program":
        per = pts_v.data.shape[0] // n_shards
        kk = min(k, per)
        m = min(k, kk * n_shards)
        dim = pts_v.data.shape[1]
        step = _program_step(pts_v, k, engine)
        prog = sess.program(step, mesh=mesh)
        state = {
            "q": q,
            "neighbors": jnp.zeros((m, dim), pts_v.data.dtype),
            "scores": jnp.full((m,), -jnp.inf, jnp.float32),
        }
        state, _info = sess.run_loop(prog, state, max_iters=1)
        host = sess.host_value((state["neighbors"], state["scores"]))
        nbrs = np.asarray(host[0])
        d = np.sqrt(np.maximum(-np.asarray(host[1]), 0.0))
        return KNNResult(
            neighbors=nbrs, distances=d, wire_candidates=kk * n_shards,
            engine="container:topk", engine_requested=engine,
        )

    # Query goes through env (a traced operand), keeping the topk executable
    # memoized across calls with different query points.  session.topk counts
    # the blocking candidate materialisation in stats.host_syncs.
    nbrs = sess.topk(pts_v, k, score_fn=_neg_sq_dist, mesh=mesh, env=q)
    d = np.sqrt(((nbrs - np.asarray(query)[None]) ** 2).sum(1))
    return KNNResult(
        neighbors=nbrs, distances=d,
        wire_candidates=k * max(n_shards, 1),
        engine="container:topk", engine_requested=engine,
    )


def knn_full_sort(points: np.ndarray, query: np.ndarray, k: int = 100) -> KNNResult:
    """Naive oracle: full distance sort on the host."""
    d2 = ((points - query[None]) ** 2).sum(1)
    idx = np.argsort(d2)[:k]
    return KNNResult(
        neighbors=points[idx],
        distances=np.sqrt(d2[idx]),
        wire_candidates=len(points),
    )
