"""Nearest-100-neighbours (paper §3.1.5, Fig. 8).

Implemented, as in the paper, with the distributed container's ``topk`` and a
custom comparison (negative Euclidean distance to the query): each shard
selects its local top-k, and only k·n_shards candidates cross the wire —
O(n + k log k) work, O(k) space.  ``knn_full_sort`` is the naive baseline that
materialises and sorts every distance (what a shuffle-everything plan does).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import DistVector, distribute, topk
from repro.core.session import BlazeSession


def _neg_sq_dist(x, q):
    """topk score: negative squared Euclidean distance to the query ``q``."""
    return -jnp.sum((x - q) ** 2)


@dataclasses.dataclass
class KNNResult:
    neighbors: np.ndarray  # [k, dim]
    distances: np.ndarray  # [k]
    wire_candidates: int  # how many rows crossed the wire


def knn(
    points: np.ndarray | DistVector,
    query: np.ndarray,
    k: int = 100,
    *,
    mesh: Mesh | None = None,
    engine: str = "auto",
    session: BlazeSession | None = None,
) -> KNNResult:
    # Uniform driver interface: knn's plan is container-level (``topk``), so
    # the engine choice cannot change it — validate and move on.
    from repro.core.session import ENGINES

    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; choose from {ENGINES}")
    if mesh is None and session is not None:
        mesh = session.mesh
    if isinstance(points, DistVector):
        pts_v = points
    else:
        pts_v = distribute(points.astype(np.float32), mesh) if mesh else distribute(
            points.astype(np.float32)
        )
    q = jnp.asarray(query, jnp.float32)
    # Query goes through env (a traced operand), keeping the topk executable
    # memoized across calls with different query points.
    nbrs = topk(pts_v, k, score_fn=_neg_sq_dist, mesh=mesh, env=q)
    d = np.sqrt(((nbrs - np.asarray(query)[None]) ** 2).sum(1))
    n_shards = 1 if mesh is None else mesh.shape.get("data", 1)
    return KNNResult(neighbors=nbrs, distances=d, wire_candidates=k * max(n_shards, 1))


def knn_full_sort(points: np.ndarray, query: np.ndarray, k: int = 100) -> KNNResult:
    """Naive oracle: full distance sort on the host."""
    d2 = ((points - query[None]) ** 2).sum(1)
    idx = np.argsort(d2)[:k]
    return KNNResult(
        neighbors=points[idx],
        distances=np.sqrt(d2[idx]),
        wire_candidates=len(points),
    )
