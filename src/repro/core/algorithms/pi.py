"""Monte-Carlo π estimation (paper §2.3.3, Table 1, Appendix A.2).

The canonical small-fixed-key-range workload: a DistRange of sample indices,
a mapper that emits ``(0, 1)`` for in-circle samples, a ``"sum"`` reducer and
a 1-element dense target.  With eager reduction the execution plan is exactly
a hand-optimised parallel-for + tree reduce: each device keeps one dense
counter and a single scalar crosses the wire.

Randomness is counter-based (splitmix32 of the sample index) so the mapper is
stateless — the TPU version of the paper's "std::random is not thread safe"
remark.

``engine=`` accepts ``"eager" | "pallas" | "naive" | "auto"``; the ``emit(0,
…)`` key is trace-time constant, so eager/pallas/auto all lower to the same
fused whole-axis reduction (the kernel only enters for dynamic keys).

``mode="program"`` routes the same single op through the planner
(``session.program``): the op becomes a one-node logical plan whose node
hash equals the per-op call's ``MapReduceStats.plan_hash`` — the
per-op/program agreement the plan IR guarantees (see ``tests/test_plan.py``).
Either mode materialises the count through ``session.host_value``, so
``stats.host_syncs`` counts π's one blocking sync (it used to bypass the
session with a raw ``float(...)``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import DistRange
from repro.core.containers import hash32
from repro.core.session import BlazeSession, resolve


def _uniform01(x: jnp.ndarray, salt: int) -> jnp.ndarray:
    h = hash32(x.astype(jnp.uint32) ^ jnp.uint32(salt))
    return h.astype(jnp.float32) * (1.0 / 4294967296.0)


def pi_mapper(v, emit):
    x = _uniform01(v, 0x9E3779B9)
    y = _uniform01(v, 0x85EBCA6B)
    emit(0, jnp.where(x * x + y * y < 1.0, 1, 0))


def _program_step(n_samples: int, engine: str):
    """(step_fn, initial state) for the fused/planned spelling of π."""

    def step(ctx, s):
        counts = ctx.map_reduce(
            DistRange(0, n_samples, 1), pi_mapper, "sum",
            jnp.zeros((1,), jnp.int32), engine=engine,
        )
        return {"counts": jnp.asarray(counts)}

    return step, {"counts": jnp.zeros((1,), jnp.int32)}


def estimate_pi(
    n_samples: int,
    *,
    mesh=None,
    engine: str = "eager",
    mode: str = "per_op",
    return_stats: bool = False,
    session: BlazeSession | None = None,
):
    if mode not in ("per_op", "program"):
        raise ValueError(f"unknown mode {mode!r}; choose 'per_op' or 'program'")
    sess, mesh = resolve(session, mesh)
    if mode == "program":
        if return_stats:
            raise ValueError(
                "return_stats is a per-op feature; inside a program the op "
                "has no standalone stats — inspect session.explain instead"
            )
        step, state = _program_step(n_samples, engine)
        prog = sess.program(step, mesh=mesh)
        state, _info = sess.run_loop(prog, state, max_iters=1)
        counts = sess.host_value(state["counts"])
        return 4.0 * float(counts[0]) / n_samples
    out = sess.map_reduce(
        DistRange(0, n_samples, 1),
        pi_mapper,
        "sum",
        jnp.zeros((1,), jnp.int32),
        mesh=mesh,
        engine=engine,
        return_stats=return_stats,
    )
    if return_stats:
        counts, stats = out
    else:
        counts, stats = out, None
    # The blocking materialisation goes through the session so host_syncs
    # counts it (the raw float(...) spelling undercounted).
    pi = 4.0 * float(sess.host_value(counts)[0]) / n_samples
    return (pi, stats) if return_stats else pi


@functools.partial(jax.jit, static_argnums=0)
def _handrolled_count(n_samples: int):
    idx = jnp.arange(n_samples, dtype=jnp.uint32)
    x = _uniform01(idx, 0x9E3779B9)
    y = _uniform01(idx, 0x85EBCA6B)
    return jnp.sum(x * x + y * y < 1.0)


def estimate_pi_handrolled(n_samples: int) -> float:
    """The 'hand-optimised parallel for loop' baseline from Table 1 — one
    fused jitted reduction, no MapReduce machinery."""
    return 4.0 * float(_handrolled_count(n_samples)) / n_samples
