"""PageRank (paper §3.1.2, Fig. 5) — three MapReduce ops per iteration.

Exactly the paper's decomposition:

  MR1  total score of all sinks               (dense [1] target, "sum")
  MR2  new scores from Eq. 1                  (dense [N] target, "sum")
  MR3  max |Δscore| for the convergence test  (dense [1] target, "max")

Links are stored distributedly (DistVector of [E, 2] edges); scores are a
dense array threaded through ``env`` so one compiled executable serves every
iteration.  ``engine=`` accepts ``"eager" | "pallas" | "naive" | "auto"`` —
MR2's contribution scatter is the dynamic-key combine the pallas kernel
accelerates; MR1/MR3 emit static keys and keep the fused fast path under
every engine.  The paper's Eq. 1 writes the damping constant as d = 0.15; the
conventional damping is 0.85 — ``damping`` is a parameter (default 0.85) and
the benchmark reports both conventions.

Two execution modes:

* ``mode="per_op"`` (default) — one dispatch per MapReduce op plus a blocking
  host sync per iteration for the convergence test: 3 dispatches + 1 sync
  per iteration, 3 compiles total.
* ``mode="program"`` — the whole iteration (all three ops + the score update
  glue) is fused by ``session.program`` into ONE executable and driven by
  ``session.run_loop`` with ``unroll`` iterations per dispatch: 1 program
  compile, ``≤ ⌈iters/unroll⌉`` dispatches and host syncs.  With
  ``wire="int8"`` the fused loop carries quantization error-feedback
  residuals across iterations, keeping the power iteration unbiased.
* ``mode="stream"`` — the out-of-core variant: ``edges`` is a
  ``ChunkedDistVector`` (graphs whose edge list exceeds device memory) and
  one power iteration becomes one ``session.run_stream`` epoch.  Each block
  dispatch accumulates its partial incoming-contribution vector; the score
  update and convergence delta fire only on the epoch's last block.  Still 1
  program compile regardless of block count; out-degrees are computed
  host-side from the blocks before streaming starts.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import ChunkedDistVector, DistRange, DistVector, distribute
from repro.core.session import BlazeSession, resolve


def sink_mapper(p, emit, env):
    scores, deg = env
    emit(0, jnp.where(deg[p] == 0, scores[p], 0.0))


def contrib_mapper(i, edge, emit, env):
    scores, deg = env
    src, dst = edge[0], edge[1]
    emit(dst, scores[src] / jnp.maximum(deg[src], 1).astype(scores.dtype))


def delta_mapper(p, emit, env):
    old, new = env
    emit(0, jnp.abs(new[p] - old[p]))


@dataclasses.dataclass
class PageRankResult:
    scores: np.ndarray
    iterations: int
    converged: bool
    shuffle_bytes_per_iter: int
    pairs_shipped_per_iter: int
    compiles: int = 0  # map_reduce executables compiled across ALL iterations
    program_compiles: int = 0  # fused-program executables (mode="program")
    dispatches: int = 0  # executable launches across the loop
    host_syncs: int = 0  # blocking host materialisations across the loop
    collectives_per_iter: int = 0  # optimized plan's collectives (program mode)


def _program_step(edges_v, deg, n_pages: int, damping: float, engine: str,
                  wire: str):
    """(step_fn, state builder) for the planned PageRank iteration.

    The optimizer batches the sink-sum and contribution-sum psums into one
    collective (both f32 sums, same wire) — the delta pmax stays separate —
    so the plan reports 2 collectives/iter instead of 3 (``wire="none"``).
    """
    pages = DistRange(0, n_pages, 1)
    d = damping

    def step(ctx, s):
        sc = s["scores"]
        sink = ctx.map_reduce(
            pages, sink_mapper, "sum", jnp.zeros((1,), jnp.float32),
            engine=engine, env=(sc, deg),
        )[0]
        incoming = ctx.map_reduce(
            edges_v, contrib_mapper, "sum",
            jnp.zeros((n_pages,), jnp.float32),
            engine=engine, wire=wire, env=(sc, deg),
        )
        new = (1.0 - d) / n_pages + d * (incoming + sink / n_pages)
        delta = ctx.map_reduce(
            pages, delta_mapper, "max", jnp.zeros((1,), jnp.float32),
            engine=engine, env=(sc, new),
        )[0]
        return {"scores": new, "delta": jnp.asarray(delta)}

    def state0(scores):
        return {"scores": scores, "delta": jnp.asarray(jnp.inf, jnp.float32)}

    return step, state0


def _stream_step(edges_c: ChunkedDistVector, deg, n_pages: int,
                 damping: float, engine: str, wire: str):
    """(step_fn, state builder) for the out-of-core PageRank epoch.

    Per block dispatch: MR2 over the resident edge block accumulates into
    ``acc``; the sink sum (MR1), Eq. 1 update and delta test (MR3) are traced
    every dispatch but only *committed* on the epoch's last block, where
    ``acc`` holds the full incoming vector — the accumulate/finalize-on-
    last-block pattern, one executable for every block of every epoch.
    """
    pages = DistRange(0, n_pages, 1)
    d = damping
    n_blocks = edges_c.n_blocks

    def step(ctx, s):
        sc = s["scores"]
        part = ctx.map_reduce(
            edges_c, contrib_mapper, "sum",
            jnp.zeros((n_pages,), jnp.float32),
            engine=engine, wire=wire, env=(sc, deg),
        )
        acc = s["acc"] + part
        last = s["blk"] == n_blocks - 1
        sink = ctx.map_reduce(
            pages, sink_mapper, "sum", jnp.zeros((1,), jnp.float32),
            engine=engine, env=(sc, deg),
        )[0]
        new = (1.0 - d) / n_pages + d * (acc + sink / n_pages)
        delta = ctx.map_reduce(
            pages, delta_mapper, "max", jnp.zeros((1,), jnp.float32),
            engine=engine, env=(sc, new),
        )[0]
        return {
            "scores": jnp.where(last, new, sc),
            "delta": jnp.where(last, jnp.asarray(delta), s["delta"]),
            "acc": jnp.where(last, jnp.zeros_like(s["acc"]), acc),
            "blk": jnp.where(last, 0, s["blk"] + 1),
        }

    def state0(scores):
        return {
            "scores": scores,
            "delta": jnp.asarray(jnp.inf, jnp.float32),
            "acc": jnp.zeros((n_pages,), jnp.float32),
            "blk": jnp.zeros((), jnp.int32),
        }

    return step, state0


def pagerank(
    edges: np.ndarray,
    n_pages: int,
    *,
    damping: float = 0.85,
    tol: float = 1e-5,
    max_iters: int = 100,
    mesh: Mesh | None = None,
    engine: str = "eager",
    wire: str = "none",
    mode: str = "per_op",
    unroll: int = 1,
    session: BlazeSession | None = None,
) -> PageRankResult:
    if mode not in ("per_op", "program", "stream"):
        raise ValueError(
            f"unknown mode {mode!r}; choose 'per_op', 'program' or 'stream'"
        )
    sess, mesh = resolve(session, mesh)
    if isinstance(edges, ChunkedDistVector):
        if mode == "program":
            raise ValueError(
                "chunked edges need mode='stream' (the out-of-core program "
                "loop) or mode='per_op'"
            )
        edges_v = edges
        # Out-degrees host-side, one block at a time — the edge list itself
        # never needs to be resident.
        deg_np = np.zeros((n_pages,), np.int64)
        for b in range(edges.n_blocks):
            blk = edges.block_host(b)[: edges.block_true_rows(b)]
            deg_np += np.bincount(blk[:, 0], minlength=n_pages)
        deg = jnp.asarray(deg_np.astype(np.int32))
    else:
        edges_v = distribute(edges.astype(np.int32), mesh)
        deg = jnp.asarray(
            np.bincount(edges[:, 0], minlength=n_pages).astype(np.int32)
        )
    pages = DistRange(0, n_pages, 1)
    scores = jnp.full((n_pages,), 1.0 / n_pages, jnp.float32)
    d = damping
    compiles0 = sess.stats.compiles
    dispatches0 = sess.stats.dispatches
    syncs0 = sess.stats.host_syncs

    if mode == "stream":
        if not isinstance(edges_v, ChunkedDistVector):
            raise ValueError(
                "mode='stream' needs ChunkedDistVector edges "
                "(see session.chunked)"
            )
        step, state0 = _stream_step(edges_v, deg, n_pages, d, engine, wire)
        prog = sess.program(step, mesh=mesh)
        state, info = sess.run_stream(
            prog, state0(scores),
            cond=lambda s: float(s["delta"]) < tol,
            max_epochs=max_iters,
        )
        return PageRankResult(
            scores=np.asarray(state["scores"]),
            iterations=info.epochs,
            converged=info.converged,
            shuffle_bytes_per_iter=0,
            pairs_shipped_per_iter=0,
            compiles=sess.stats.compiles - compiles0,
            program_compiles=info.compiles,
            dispatches=sess.stats.dispatches - dispatches0,
            host_syncs=sess.stats.host_syncs - syncs0,
            collectives_per_iter=prog.plan.collectives_per_iter,
        )

    if mode == "program":
        step, state0 = _program_step(edges_v, deg, n_pages, d, engine, wire)
        prog = sess.program(step, mesh=mesh)
        state, info = sess.run_loop(
            prog, state0(scores),
            cond=lambda s: float(s["delta"]) < tol,  # counted by run_loop
            max_iters=max_iters, unroll=unroll,
        )
        return PageRankResult(
            scores=np.asarray(state["scores"]),
            iterations=info.iterations,
            converged=info.converged,
            shuffle_bytes_per_iter=0,  # per-op stats don't exist inside a program
            pairs_shipped_per_iter=0,
            compiles=sess.stats.compiles - compiles0,
            program_compiles=info.compiles,
            dispatches=sess.stats.dispatches - dispatches0,
            host_syncs=sess.stats.host_syncs - syncs0,
            collectives_per_iter=prog.plan.collectives_per_iter,
        )

    it, converged = 0, False
    stats2 = None
    for it in range(1, max_iters + 1):
        sink_total = sess.map_reduce(
            pages, sink_mapper, "sum", jnp.zeros((1,), jnp.float32),
            mesh=mesh, engine=engine, env=(scores, deg),
        )[0]
        incoming, stats2 = sess.map_reduce(
            edges_v, contrib_mapper, "sum", jnp.zeros((n_pages,), jnp.float32),
            mesh=mesh, engine=engine, wire=wire, env=(scores, deg),
            return_stats=True,
        )
        new_scores = (1.0 - d) / n_pages + d * (incoming + sink_total / n_pages)
        delta = sess.map_reduce(
            pages, delta_mapper, "max", jnp.zeros((1,), jnp.float32),
            mesh=mesh, engine=engine, env=(scores, new_scores),
        )[0]
        scores = new_scores
        if float(np.asarray(sess.host_value(delta))) < tol:
            converged = True
            break

    fs = stats2.finalize() if stats2 is not None else None
    return PageRankResult(
        scores=np.asarray(scores),
        iterations=it,
        converged=converged,
        shuffle_bytes_per_iter=fs.shuffle_payload_bytes if fs else 0,
        pairs_shipped_per_iter=fs.pairs_shipped if fs else 0,
        compiles=sess.stats.compiles - compiles0,
        dispatches=sess.stats.dispatches - dispatches0,
        host_syncs=sess.stats.host_syncs - syncs0,
    )


def pagerank_reference(
    edges: np.ndarray, n_pages: int, damping: float = 0.85,
    tol: float = 1e-5, max_iters: int = 100,
) -> np.ndarray:
    """Dense numpy oracle for tests."""
    deg = np.bincount(edges[:, 0], minlength=n_pages)
    scores = np.full(n_pages, 1.0 / n_pages, np.float64)
    for _ in range(max_iters):
        sink_total = scores[deg == 0].sum()
        incoming = np.zeros(n_pages)
        np.add.at(incoming, edges[:, 1], scores[edges[:, 0]] / np.maximum(deg[edges[:, 0]], 1))
        new = (1 - damping) / n_pages + damping * (incoming + sink_total / n_pages)
        if np.abs(new - scores).max() < tol:
            scores = new
            break
        scores = new
    return scores.astype(np.float32)
