"""Fault-tolerant training loop.

* auto-resume from the latest complete checkpoint (atomic manager),
* deterministic data (step-indexed) ⇒ restart-consistent streams,
* gradient-accumulation microbatching via ``lax.scan`` with EAGER local
  accumulation (sum locally, reduce once — the Blaze eager-reduction plan for
  gradients; ``accum_mode="per_microbatch"`` is the conventional baseline that
  reduces every microbatch, kept for the benchmark contrast),
* straggler monitor: per-step wall times, flags steps > ``k × median`` (on a
  real cluster this table is per-host; deterministic data makes any flagged
  host replaceable),
* failure injection (``crash_at_step``) for the restart tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.optim.adamw import AdamW


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.0
    times: list = dataclasses.field(default_factory=list)
    flagged: list = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float):
        self.times.append(dt)
        if len(self.times) >= 8:
            med = float(np.median(self.times[-64:]))
            if dt > self.threshold * med:
                self.flagged.append((step, dt, med))

    def summary(self) -> dict:
        if not self.times:
            return {"steps": 0}
        return {
            "steps": len(self.times),
            "median_s": float(np.median(self.times)),
            "p99_s": float(np.percentile(self.times, 99)),
            "stragglers": len(self.flagged),
        }


def make_train_step(
    cfg: ArchConfig,
    optimizer: AdamW,
    *,
    par: M.ParallelCfg = M.ParallelCfg(),
    grad_accum: int = 1,
    accum_mode: str = "eager",
    remat: bool = True,
) -> Callable:
    """Returns train_step(params, opt_state, batch) → (params, opt, loss)."""

    def loss_of(params, inputs, labels):
        return M.loss_fn(params, cfg, inputs, labels, par=par, remat=remat)

    if grad_accum == 1:

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_of)(
                params, batch["inputs"], batch["labels"]
            )
            params, opt_state = optimizer.update(grads, opt_state, params)
            return params, opt_state, loss

        return train_step

    def train_step(params, opt_state, batch):
        # [B, S] → [A, B/A, S] microbatches
        def split(x):
            b = x.shape[0]
            return x.reshape((grad_accum, b // grad_accum) + x.shape[1:])

        mb = jax.tree.map(split, batch)

        def micro(carry, mbatch):
            gsum, lsum = carry
            loss, g = jax.value_and_grad(loss_of)(
                params, mbatch["inputs"], mbatch["labels"]
            )
            if accum_mode == "per_microbatch":
                # conventional: materialise the reduced gradient every
                # microbatch (an all-reduce per microbatch in DP lowering)
                g = jax.tree.map(lambda x: x * (1.0 / grad_accum), g)
                gsum = jax.tree.map(jnp.add, gsum, g)
            else:  # eager: local sum only; one reduce at the end
                gsum = jax.tree.map(
                    lambda a, x: a + x * (1.0 / grad_accum), gsum, g
                )
            return (gsum, lsum + loss / grad_accum), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), _ = jax.lax.scan(micro, (g0, jnp.zeros(())), mb)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, loss

    return train_step


@dataclasses.dataclass
class TrainResult:
    steps_run: int
    final_step: int
    losses: list
    restarts: int
    straggler: dict


def train(
    cfg: ArchConfig,
    *,
    steps: int,
    batch: int,
    seq_len: int,
    pipeline,
    ckpt_dir: str,
    optimizer: AdamW | None = None,
    ckpt_every: int = 50,
    seed: int = 0,
    grad_accum: int = 1,
    crash_at_step: int | None = None,
    max_restarts: int = 2,
    params=None,
    jit: bool = True,
) -> TrainResult:
    """Run (and if needed, resume) a training job to ``steps``."""
    optimizer = optimizer or AdamW(lr=3e-4)
    mgr = CheckpointManager(ckpt_dir, keep=3)
    monitor = StragglerMonitor()
    losses: list[float] = []
    restarts = 0

    step_fn = make_train_step(cfg, optimizer, grad_accum=grad_accum)
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    def fresh_state():
        p = params if params is not None else M.init(jax.random.PRNGKey(seed), cfg)
        return p, optimizer.init(p)

    while True:
        p0, o0 = fresh_state()
        start, restored = mgr.restore_latest({"params": p0, "opt": o0})
        if restored is not None:
            state_p, state_o = restored["params"], restored["opt"]
            start_step = start
        else:
            state_p, state_o = p0, o0
            start_step = 0

        try:
            step = start_step
            while step < steps:
                t0 = time.perf_counter()
                b = pipeline.device_batch(step)
                if crash_at_step is not None and step == crash_at_step and restarts == 0:
                    restarts += 1
                    raise SimulatedFailure(f"injected failure at step {step}")
                state_p, state_o, loss = step_fn(state_p, state_o, b)
                losses.append(float(loss))
                step += 1
                monitor.record(step, time.perf_counter() - t0)
                if step % ckpt_every == 0 or step == steps:
                    mgr.save(step, {"params": state_p, "opt": state_o})
            mgr.wait()
            return TrainResult(
                steps_run=len(losses),
                final_step=step,
                losses=losses,
                restarts=restarts,
                straggler=monitor.summary(),
            )
        except SimulatedFailure:
            if restarts > max_restarts:
                raise
            continue  # auto-restart path: restore-from-latest and keep going
