"""Single-machine multi-host simulation: the XLA host-device-count preamble.

JAX locks the device count at first backend initialisation, so the
``--xla_force_host_platform_device_count=N`` flag MUST be in ``XLA_FLAGS``
before anything touches a backend (importing jax is fine; calling
``jax.devices()`` is not).  Every simulated-topology entry point used to
copy-paste that two-line trap; this module is the one place it lives:

* ``force_host_device_count(n)``   — in-process: mutate ``XLA_FLAGS`` (call
  it before importing anything that initialises jax — module top, like
  ``launch/dryrun.py``).
* ``simulated_env(n)``             — subprocess: a patched environment for
  worker processes (used by ``tests/test_multidevice.py`` /
  ``tests/test_multihost.py`` and the scaling bench's CI job).

Stdlib-only on purpose: importing this module never imports jax, so the
flag always lands before the backend can come up.
"""
from __future__ import annotations

import os
import sys

_FLAG = "--xla_force_host_platform_device_count"


def host_device_flags(n: int, base: str = "") -> str:
    """``base`` XLA_FLAGS with the host-device-count flag forced to ``n``."""
    if n < 1:
        raise ValueError(f"device count must be >= 1, got {n}")
    flags = [f for f in base.split() if not f.startswith(_FLAG + "=")]
    flags.append(f"{_FLAG}={n}")
    return " ".join(flags)


def forced_host_device_count(env=None) -> int | None:
    """The forced count already present in ``XLA_FLAGS``, or None."""
    env = os.environ if env is None else env
    for flag in env.get("XLA_FLAGS", "").split():
        if flag.startswith(_FLAG + "="):
            try:
                return int(flag.split("=", 1)[1])
            except ValueError:
                return None
    return None


def force_host_device_count(n: int) -> None:
    """Make this process see ``n`` simulated CPU devices.

    Must run before the first jax backend init.  If jax is already imported
    the call can still be fine (import alone does not lock the count), but a
    backend that already came up ignores the flag — raise loudly in the one
    detectable slice of that window instead of silently simulating nothing.
    """
    jaxlib = sys.modules.get("jax")
    if jaxlib is not None:
        try:
            backends = sys.modules["jax._src.xla_bridge"]._backends  # type: ignore[union-attr]
        except (KeyError, AttributeError):
            backends = None
        if backends:
            raise RuntimeError(
                "force_host_device_count called after a jax backend "
                "initialised; set XLA_FLAGS before first device use "
                "(see launch/dryrun.py for the import-order contract)"
            )
    os.environ["XLA_FLAGS"] = host_device_flags(
        n, os.environ.get("XLA_FLAGS", "")
    )


def simulated_env(n: int, base_env=None, *, pythonpath: str | None = None):
    """A subprocess environment simulating ``n`` host devices.

    Copies ``base_env`` (default ``os.environ``), forces the device count in
    ``XLA_FLAGS``, and optionally prepends ``pythonpath`` — the exact recipe
    the multi-device test harnesses spawn workers with.
    """
    env = dict(os.environ if base_env is None else base_env)
    env["XLA_FLAGS"] = host_device_flags(n, env.get("XLA_FLAGS", ""))
    if pythonpath is not None:
        old = env.get("PYTHONPATH", "")
        env["PYTHONPATH"] = (
            pythonpath + os.pathsep + old if old else pythonpath
        )
    return env
