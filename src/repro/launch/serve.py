"""BlazeServe launcher: a long-lived multi-tenant query service.

Starts a :class:`~repro.serve.server.BlazeServer` with the three standard
synthetic datasets registered (``edges``, ``lines``, ``points``) and serves
the six built-in prepared queries over local HTTP until interrupted:

  PYTHONPATH=src python -m repro.launch.serve --port 8787

  curl -s localhost:8787/health
  curl -s -X POST localhost:8787/query -d \\
      '{"tenant": "alice", "query": "pagerank", "params": {"iters": 10}}'
  curl -s localhost:8787/stats

See ``examples/serve_queries.py`` for a multi-tenant Python client driving
all six queries, and ``docs/architecture.md`` (Serving layer) for the
admission → micro-batch → dispatch pipeline.

Before PR 6 this module was the LM decode launcher; that now lives at
``repro.launch.serve_lm`` and ``--arch`` invocations are forwarded there
(with a deprecation note) so existing commands keep working.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

# Backward-compat: ``from repro.launch.serve import generate`` predates the
# PR 6 split and must keep working.
from repro.launch.serve_lm import generate  # noqa: F401

__all__ = ["build_server", "generate", "main", "register_standard_datasets"]


def register_standard_datasets(server, *, scale: str = "smoke",
                               seed: int = 0) -> None:
    """Register the three synthetic datasets the built-in queries default
    to: ``edges`` (R-MAT graph), ``lines`` (Zipf token corpus), ``points``
    (Gaussian clusters)."""
    from repro.data import synthetic as S

    if scale == "smoke":
        graph_scale, n_lines, n_points, dim = 8, 512, 2048, 4
    else:
        graph_scale, n_lines, n_points, dim = 12, 8192, 1 << 15, 8
    edges = S.rmat_edges(graph_scale, seed=seed)
    lines, _true = S.zipf_corpus(n_lines, 16, 256, seed=seed)
    points, _centers = S.cluster_points(n_points, dim, 8, seed=seed)
    server.register_dataset("edges", edges, n_pages=2 ** graph_scale)
    server.register_dataset("lines", lines, vocab_size=256)
    server.register_dataset("points", points)


def build_server(*, host: str = "127.0.0.1", port: int = 0,
                 max_queue: int = 64, per_tenant: int = 8, max_batch: int = 8,
                 scale: str = "smoke", seed: int = 0):
    """A ready-to-start server with the standard datasets registered."""
    from repro.serve import BlazeServer

    server = BlazeServer(
        host=host, port=port, max_queue=max_queue,
        per_tenant_inflight=per_tenant, max_batch=max_batch,
    )
    register_standard_datasets(server, scale=scale, seed=seed)
    return server


def main(argv=None):
    argv = sys.argv[1:] if argv is None else list(argv)
    if any(a == "--arch" or a.startswith("--arch=") for a in argv):
        print(
            "note: the LM decode launcher moved to repro.launch.serve_lm; "
            "forwarding (use `python -m repro.launch.serve_lm` directly).",
            file=sys.stderr,
        )
        from repro.launch import serve_lm

        return serve_lm.main(argv)

    ap = argparse.ArgumentParser(description="BlazeServe query service")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8787)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--per-tenant", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--scale", choices=("smoke", "full"), default="smoke")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    server = build_server(
        host=args.host, port=args.port, max_queue=args.max_queue,
        per_tenant=args.per_tenant, max_batch=args.max_batch,
        scale=args.scale, seed=args.seed,
    )
    server.start()
    print(json.dumps({
        "serving": server.url,
        "queries": server.queries,
        "datasets": sorted(server.datasets),
        "mesh_shards": server.mesh.shape.get("data", 1),
    }))
    sys.stdout.flush()
    try:
        while True:
            time.sleep(1.0)
    except KeyboardInterrupt:
        pass
    finally:
        server.stop()
        print(json.dumps(server.stats_snapshot(), default=str))


if __name__ == "__main__":
    main()
