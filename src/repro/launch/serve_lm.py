"""LM serving launcher: batched prefill + decode with KV/SSM caches.

Laptop-scale real generation on a reduced config:

  PYTHONPATH=src python -m repro.launch.serve_lm --arch qwen3-0.6b \\
      --batch 4 --prompt-len 32 --gen 32

(Lived at ``repro.launch.serve`` before PR 6; that module is now the
BlazeServe query-service entry point and forwards ``--arch`` invocations
here.)
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.models import model as M


def generate(cfg, params, prompts, max_len: int, gen: int, *, greedy=True, seed=0):
    b, plen = prompts.shape[0], prompts.shape[1]
    caches = M.make_caches(cfg, b, max_len)
    prefill = jax.jit(lambda p, x, c: M.prefill(p, cfg, x, c))
    step = jax.jit(lambda p, x, c, n: M.decode_step(p, cfg, x, c, n))

    logits, caches = prefill(params, prompts, caches)
    out = []
    key = jax.random.PRNGKey(seed)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(gen):
        out.append(tok)
        logits, caches = step(params, tok, caches, plen + i)
        if greedy:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits)[:, None].astype(jnp.int32)
    dt = time.perf_counter() - t0
    return jnp.concatenate(out, axis=1), dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = M.init(key, cfg)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab, dtype=jnp.int32
    )
    toks, dt = generate(
        cfg, params, prompts, args.prompt_len + args.gen + 1, args.gen
    )
    print(
        json.dumps(
            {
                "arch": cfg.name,
                "generated_shape": list(toks.shape),
                "decode_steps": args.gen,
                "decode_s": dt,
                "tok_per_s": args.batch * args.gen / dt,
                "sample": toks[0, :16].tolist(),
            },
            indent=1,
        )
    )


if __name__ == "__main__":
    main()
