"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (jax locks the device count on first backend init, and the
dry-run must set XLA_FLAGS before that).
"""
from __future__ import annotations

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def fsdp_axes(mesh) -> tuple[str, ...]:
    """Parameter-sharding (FSDP/ZeRO) axes: data, plus pod when present."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_axes(mesh) -> tuple[str, ...]:
    """Batch-sharding axes (same as FSDP axes in this framework)."""
    return fsdp_axes(mesh)
