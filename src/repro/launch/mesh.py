"""Production mesh construction and multi-host bring-up.

Everything here is a FUNCTION, not a module-level constant — importing this
module never touches jax device state (jax locks the device count on first
backend init, and the dry-run / simulated-topology harnesses must set
XLA_FLAGS before that; see ``repro.launch.simulate``).

Multi-host entry points:

* ``init_distributed(...)``      — gated ``jax.distributed.initialize``
  bring-up (no-op on a single process), returns whether a cluster came up.
* ``make_node_data_mesh(n)``     — the MapReduce engine's 2-D
  ``("node", "data")`` mesh: ``node`` is the slow inter-host axis (one row
  per process on a real cluster; simulated rows under
  ``--xla_force_host_platform_device_count``), ``data`` the fast intra-host
  axis.  The engine's hierarchical collectives reduce over ``data`` at full
  precision first and cross ``node`` second (see ``core/mapreduce.py``).
"""
from __future__ import annotations

from repro.compat import (
    AxisType,
    distributed_initialize,
    make_mesh,
    process_count,
)


def init_distributed(
    coordinator_address: str | None = None,
    num_processes: int | None = None,
    process_id: int | None = None,
    **kwargs,
) -> bool:
    """Bring up the multi-process runtime (returns False when single-process).

    Call once, before any device use, on every process of a real cluster:

        init_distributed("host0:1234", num_processes=8, process_id=rank)
        mesh = make_node_data_mesh()

    On one process (tests, notebooks, the simulated harness) it is a no-op
    and ``make_node_data_mesh(n)`` simulates the node axis instead.
    """
    return distributed_initialize(
        coordinator_address=coordinator_address,
        num_processes=num_processes,
        process_id=process_id,
        **kwargs,
    )


def make_node_data_mesh(n_nodes: int | None = None, *, devices=None):
    """A 2-D ``("node", "data")`` mesh over all visible devices.

    ``n_nodes`` defaults to ``jax.process_count()`` — one node row per host
    on a real multi-process launch.  Pass it explicitly to simulate a
    multi-node topology on one machine (the device count must divide
    evenly; pair with ``simulate.force_host_device_count``).
    """
    import jax

    from repro.core import containers as C

    devs = list(devices) if devices is not None else jax.devices()
    nodes = int(n_nodes) if n_nodes is not None else max(1, process_count())
    if nodes < 1 or len(devs) % nodes:
        raise ValueError(
            f"cannot split {len(devs)} devices into {nodes} node rows"
        )
    return make_mesh(
        (nodes, len(devs) // nodes),
        (C.NODE_AXIS, C.DATA_AXIS),
        axis_types=(AxisType.Auto, AxisType.Auto),
        devices=devs,
    )


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def fsdp_axes(mesh) -> tuple[str, ...]:
    """Parameter-sharding (FSDP/ZeRO) axes: data, plus pod when present."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_axes(mesh) -> tuple[str, ...]:
    """Batch-sharding axes (same as FSDP axes in this framework)."""
    return fsdp_axes(mesh)
