"""Training launcher.

Laptop-scale real run (reduced config) or cluster-scale structure (full
config under the production mesh — the dry-run proves that path compiles).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --steps 200 \\
      --batch 8 --seq 128 --ckpt-dir /tmp/ckpt [--reduced]
"""
from __future__ import annotations

import argparse
import json

from repro.configs.base import get_arch
from repro.data.pipeline import TokenPipeline
from repro.optim.adamw import AdamW, warmup_cosine
from repro.runtime.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    pipe = TokenPipeline(cfg, batch=args.batch, seq_len=args.seq, seed=args.seed)
    opt = AdamW(lr=warmup_cosine(args.lr, args.steps // 10, args.steps))
    res = train(
        cfg,
        steps=args.steps,
        batch=args.batch,
        seq_len=args.seq,
        pipeline=pipe,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        optimizer=opt,
        grad_accum=args.grad_accum,
        seed=args.seed,
    )
    print(
        json.dumps(
            {
                "arch": cfg.name,
                "steps": res.final_step,
                "loss_first": res.losses[0],
                "loss_last": res.losses[-1],
                "restarts": res.restarts,
                "straggler": res.straggler,
            },
            indent=1,
        )
    )


if __name__ == "__main__":
    main()
