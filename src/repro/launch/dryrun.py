from repro.launch.simulate import force_host_device_count
force_host_device_count(512)
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST precede every other import (jax locks the device
count at first backend init — ``launch/simulate.py`` owns that contract):
the dry-run — and only the dry-run — sees 512 placeholder CPU devices so
``jax.make_mesh`` can build the production meshes.

Per cell this lowers the REAL program (train_step including the AdamW update,
or prefill / decode serve steps with full caches) from ShapeDtypeStruct
stand-ins (zero allocation), compiles it under GSPMD, and records:

* ``compiled.memory_analysis()``  — per-device bytes (proves it fits HBM),
* ``compiled.cost_analysis()``    — HLO FLOPs / bytes accessed,
* collective payload bytes by op kind, parsed from the compiled HLO
  (while-loop bodies are attributed with their known trip counts),
* compile wall-time, HLO op histogram.

Results stream to ``results/dryrun/<cell>.json`` as they finish, so a crashed
sweep resumes where it left off (``--force`` recomputes).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
  PYTHONPATH=src python -m repro.launch.dryrun --arch X --shape Y --unroll  # roofline-grade counts
"""
import argparse
import json
import os
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs.base import SHAPES, ArchConfig, ShapeSpec, cells, get_arch, list_archs
from repro.distributed import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.optim.adamw import AdamW
from repro.runtime.train_loop import make_train_step

RESULTS_DIR = "results/dryrun"

# dtype → wire bytes for collective accounting
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_RE = re.compile(
    r"=\s*(\w+)\[([\d,]*)\][^=]*?\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.embed_inputs:
            inputs = jax.ShapeDtypeStruct((b, s), jnp.int32)
        else:
            inputs = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.cdtype)
        return {
            "inputs": inputs,
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
    if shape.kind == "prefill":
        if cfg.embed_inputs:
            inputs = jax.ShapeDtypeStruct((b, s), jnp.int32)
        else:
            inputs = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.cdtype)
        return {
            "inputs": inputs,
            "caches": M.make_caches(cfg, b, s, spec=True),
        }
    # decode: one new token against a cache of seq_len
    if cfg.embed_inputs:
        inputs = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    else:
        inputs = jax.ShapeDtypeStruct((b, 1, cfg.d_model), cfg.cdtype)
    return {
        "inputs": inputs,
        "caches": M.make_caches(cfg, b, s, spec=True),
        "cache_len": jax.ShapeDtypeStruct((), jnp.int32),
    }


def parse_collectives(hlo_text: str, trip_counts: dict[str, int]) -> dict:
    """Sum collective payload bytes from compiled HLO.

    Ops inside a while-loop body computation are multiplied by that loop's
    trip count; ``trip_counts`` maps substrings of computation names (or
    "default") to multipliers.  We use the known structural trip counts
    (stage scan, loss chunks, attention chunks) supplied by the caller.
    """
    by_kind: dict[str, float] = {}
    count = 0
    # split into computations: lines like "%name (param: ...) -> ... {"
    comp = "default"
    comp_mult = 1
    for line in hlo_text.splitlines():
        m_comp = re.match(r"\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->", line)
        if m_comp:
            comp = m_comp.group(1)
            comp_mult = 1
            for frag, mult in trip_counts.items():
                if frag != "default" and frag in comp:
                    comp_mult = mult
                    break
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        by_kind[kind] = by_kind.get(kind, 0.0) + float(n * nbytes * comp_mult)
        count += 1
    by_kind["n_collective_ops"] = count
    return by_kind


def analytic_flops(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """MODEL_FLOPS: 6·N·D (train) / 2·N·D (fwd-only), MoE-active-aware,
    plus attention score/PV FLOPs (not in 6ND)."""
    params = param_counts(cfg)
    n_active = params["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        base = 2 * n_active * tokens
    else:
        tokens = shape.global_batch  # one token each
        base = 2 * n_active * tokens

    # attention score+PV term: 2·2·Hq·dh·Sq·Skv_eff per layer per batch elem
    attn = 0
    mult = 3 if shape.kind == "train" else 1
    for kind in cfg.stage_pattern * cfg.n_stages + cfg.tail_pattern:
        if kind not in M._ATTN_KINDS:
            continue
        local = kind in ("attn_local", "attn_local_moe")
        s_q = 1 if shape.is_decode else shape.seq_len
        s_kv = shape.seq_len
        if local and cfg.window:
            s_kv = min(s_kv, cfg.window)
        if not shape.is_decode and not (local and cfg.window):
            s_kv_eff = s_kv / 2  # causal half
        else:
            s_kv_eff = s_kv
        attn += (
            4 * cfg.n_heads * cfg.d_head * s_q * s_kv_eff * shape.global_batch
        ) * mult
    return {"model_flops": float(base), "attn_flops": float(attn),
            "total": float(base + attn)}


def param_counts(cfg: ArchConfig) -> dict:
    shapes = jax.eval_shape(lambda k: M.init(k, cfg), jax.random.PRNGKey(0))
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    active = M.active_param_count(shapes, cfg)
    return {"total": total, "active": active}


def build_cell(cfg, shape, mesh, *, unroll=False, opt_moment_dtype=None,
               remat_policy="full"):
    """Returns (jitted fn lowered-ready, example args, trip_counts)."""
    mi = SH.make_mesh_info(mesh)
    pshapes = jax.eval_shape(lambda k: M.init(k, cfg), jax.random.PRNGKey(0))
    # decode: TP-only (weights resident, no per-step FSDP gathers) whenever
    # the TP-sharded copy fits HBM; giant MoE configs keep FSDP (EP is the
    # recorded follow-up)
    n_param_bytes = sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree.leaves(pshapes)
    )
    serving = (
        shape.kind == "decode"
        and n_param_bytes / mi.model_size < 12 * 2**30
    )
    pspecs = SH.param_pspecs(cfg, pshapes, mi, serving=serving)
    pshard = SH.named(pspecs, mi)
    par = M.ParallelCfg(dispatch_groups=mi.dp_size)
    specs = input_specs(cfg, shape)
    scan_layers = not unroll

    trip = {"default": 1}
    if scan_layers:
        trip["while"] = cfg.n_stages  # best-effort attribution

    if shape.kind == "train":
        if opt_moment_dtype is None:
            opt_moment_dtype = "bfloat16" if param_counts(cfg)["total"] > 3e10 else "float32"
        opt = AdamW(lr=1e-4, moment_dtype=opt_moment_dtype)
        oshapes = jax.eval_shape(opt.init, pshapes)
        ospecs = SH.opt_pspecs(pspecs, oshapes)
        oshard = SH.named(ospecs, mi)
        bspecs = SH.batch_pspecs(cfg, specs, mi)
        bshard = SH.named(bspecs, mi)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: M.loss_fn(
                    p, cfg, batch["inputs"], batch["labels"], par=par,
                    remat=True, remat_policy=remat_policy,
                    scan_layers=scan_layers,
                )
            )(params)
            params, opt_state = opt.update(grads, opt_state, params)
            return params, opt_state, loss

        fn = jax.jit(
            train_step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, SH.named(jax.sharding.PartitionSpec(), mi)),
        )
        args = (pshapes, oshapes, specs)
        return fn, args, trip

    cspecs = SH.cache_pspecs(
        cfg, shape.global_batch, shape.seq_len, mi, kind=shape.kind
    )
    cshard = SH.named(cspecs, mi)
    in_shard = SH.named(SH.batch_pspecs(cfg, specs["inputs"], mi), mi)
    P = jax.sharding.PartitionSpec
    logits_shard = SH.named(P(mi.fsdp if shape.global_batch % mi.dp_size == 0 else None, "model"), mi)

    if shape.kind == "prefill":

        def prefill_step(params, inputs, caches):
            return M.prefill(params, cfg, inputs, caches, par=par)

        fn = jax.jit(
            prefill_step,
            in_shardings=(pshard, in_shard, cshard),
            out_shardings=(logits_shard, cshard),
        )
        args = (pshapes, specs["inputs"], specs["caches"])
        return fn, args, trip

    def serve_step(params, inputs, caches, cache_len):
        return M.decode_step(params, cfg, inputs, caches, cache_len, par=par)

    fn = jax.jit(
        serve_step,
        in_shardings=(pshard, in_shard, cshard, SH.named(P(), mi)),
        out_shardings=(logits_shard, cshard),
    )
    args = (pshapes, specs["inputs"], specs["caches"], specs["cache_len"])
    return fn, args, trip


def run_cell(
    arch: str, shape_name: str, *, multi_pod: bool, unroll: bool = False,
    variant: str = "baseline", out_dir: str = RESULTS_DIR, force: bool = False,
) -> dict:
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}_{shape_name}_{mesh_tag}_{variant}" + ("_unroll" if unroll else "")
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "variant": variant, "unroll": unroll, "ok": False,
    }
    t_start = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        fn, args, trip = build_cell(cfg, shape, mesh, unroll=unroll)
        with set_mesh(mesh):
            t0 = time.time()
            lowered = fn.lower(*args)
            rec["lower_s"] = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = time.time() - t0

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_per_device": int(
                ma.argument_size_in_bytes
                + ma.output_size_in_bytes
                + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes
            ),
        }
        ca = compiled.cost_analysis() or {}
        rec["cost"] = {
            "hlo_flops_per_device": float(ca.get("flops", -1)),
            "hlo_bytes_per_device": float(ca.get("bytes accessed", -1)),
        }
        txt = compiled.as_text()
        rec["collectives"] = parse_collectives(txt, trip)
        rec["hlo_bytes_len"] = len(txt)
        rec["params"] = param_counts(cfg)
        rec["analytic_flops"] = analytic_flops(cfg, shape)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = time.time() - t_start

    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    status = "OK" if rec["ok"] else "FAIL"
    mem = rec.get("memory", {}).get("peak_bytes_per_device", 0) / 2**30
    print(
        f"[dryrun] {cell_id}: {status} "
        f"(lower {rec.get('lower_s', 0):.0f}s compile {rec.get('compile_s', 0):.0f}s "
        f"peak {mem:.2f} GiB/dev)",
        flush=True,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--unroll", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]

    n_ok = n_fail = 0
    for arch in archs:
        cfg = get_arch(arch)
        shape_list = (
            [SHAPES[args.shape]] if args.shape else cells(cfg)
        )
        for shape in shape_list:
            if shape.name == "long_500k" and not cfg.supports_long_context:
                continue
            for mp in pods:
                rec = run_cell(
                    arch, shape.name, multi_pod=mp, unroll=args.unroll,
                    variant=args.variant, out_dir=args.out, force=args.force,
                )
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
