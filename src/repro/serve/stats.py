"""``ServerStats`` — the serving layer's observability surface.

Counters are grouped by the invariants the property suite holds them to
(``tests/test_serve_property.py``), which are also the operator's sanity
checks on ``/stats``:

* **conservation** — every submission ends in exactly one bucket:
  ``completed + failed + queued == submitted`` at every instant (updates
  that move a request between buckets happen under one lock);
* **plan accounting** — every *executed* plan resolution either hit the
  server's program cache or compiled: ``cache_hits + compiles ==
  dispatched_plans`` (deduplicated requests ride a batchmate's execution
  and are counted in ``dedup_hits``/``coalesced_queries`` instead);
* **ordering** — ``p50_ms <= p99_ms`` (both cut from one snapshot of the
  same latency window).

``queued`` is the admission gauge: requests admitted but not yet finished
(pending *or* executing) — what a load balancer would shed on.  Latency is
measured submit→fulfil over a sliding window of the most recent
``window`` completed requests; throughput is completed requests per second
of server uptime.
"""
from __future__ import annotations

import threading
import time
from collections import deque

import numpy as np

__all__ = ["ServerStats"]


class ServerStats:
    """Thread-safe serving counters + latency percentiles.

    All transitions take the single internal lock, so any two counters read
    in one :meth:`snapshot` are mutually consistent.
    """

    def __init__(self, window: int = 4096):
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()
        self._latencies: deque[float] = deque(maxlen=window)
        # -- conservation: submitted == completed + failed + queued ----------
        self.submitted = 0  # every request that reached admission control
        self.queued = 0  # admitted, not yet finished (pending or executing)
        self.completed = 0  # finished with a result
        self.failed = 0  # finished with a typed error (incl. rejections)
        # -- rejection detail (subsets of failed) ----------------------------
        self.rejected_queue_full = 0
        self.rejected_tenant_limit = 0
        # -- dispatch / micro-batching ---------------------------------------
        self.dispatches = 0  # dispatcher cycles (one batch each)
        self.batched_dispatches = 0  # cycles that served >= 2 requests
        self.coalesced_queries = 0  # requests served beyond a batch's first
        self.dedup_hits = 0  # requests that shared an identical execution
        # -- plan accounting: cache_hits + compiles == dispatched_plans ------
        self.dispatched_plans = 0  # executed plan resolutions
        self.cache_hits = 0  # resolutions served by an existing program
        self.compiles = 0  # resolutions that compiled a new program
        # -- transport -------------------------------------------------------
        self.disconnects = 0  # clients gone before their response was written
        # -- fault recovery (PR 9) -------------------------------------------
        self.retries = 0  # supervised batch dispatches that re-attempted
        self.degraded = 0  # batch dispatches that demoted pallas -> eager

    # -- transitions ---------------------------------------------------------

    def on_admitted(self) -> None:
        with self._lock:
            self.submitted += 1
            self.queued += 1

    def on_rejected(self, code: str) -> None:
        with self._lock:
            self.submitted += 1
            self.failed += 1
            if code == "QUEUE_FULL":
                self.rejected_queue_full += 1
            elif code == "TENANT_LIMIT":
                self.rejected_tenant_limit += 1

    def on_finished(self, ok: bool, latency_s: float) -> None:
        with self._lock:
            self.queued -= 1
            if ok:
                self.completed += 1
                self._latencies.append(latency_s)
            else:
                self.failed += 1

    def on_dispatch(self, served: int, dedup: int) -> None:
        with self._lock:
            self.dispatches += 1
            if served >= 2:
                self.batched_dispatches += 1
                self.coalesced_queries += served - 1
            self.dedup_hits += dedup

    def on_plan(self, cache_hit: bool) -> None:
        with self._lock:
            self.dispatched_plans += 1
            if cache_hit:
                self.cache_hits += 1
            else:
                self.compiles += 1

    def on_disconnect(self) -> None:
        with self._lock:
            self.disconnects += 1

    def on_recovery(self, retried: int, degraded: int) -> None:
        with self._lock:
            self.retries += retried
            self.degraded += degraded

    # -- reads ---------------------------------------------------------------

    def percentiles(self) -> tuple[float, float]:
        """(p50, p99) latency in milliseconds over the sliding window."""
        with self._lock:
            lat = list(self._latencies)
        if not lat:
            return 0.0, 0.0
        a = np.asarray(lat) * 1e3
        return float(np.percentile(a, 50)), float(np.percentile(a, 99))

    def snapshot(self) -> dict:
        """One consistent view of every counter plus derived gauges —
        the ``/stats`` endpoint's payload."""
        with self._lock:
            lat = np.asarray(self._latencies) * 1e3
            uptime = time.perf_counter() - self._t0
            snap = {
                "submitted": self.submitted,
                "queued": self.queued,
                "completed": self.completed,
                "failed": self.failed,
                "rejected_queue_full": self.rejected_queue_full,
                "rejected_tenant_limit": self.rejected_tenant_limit,
                "dispatches": self.dispatches,
                "batched_dispatches": self.batched_dispatches,
                "coalesced_queries": self.coalesced_queries,
                "dedup_hits": self.dedup_hits,
                "dispatched_plans": self.dispatched_plans,
                "cache_hits": self.cache_hits,
                "compiles": self.compiles,
                "disconnects": self.disconnects,
                "retries": self.retries,
                "degraded": self.degraded,
                "uptime_s": uptime,
            }
        if lat.size:
            snap["p50_ms"] = float(np.percentile(lat, 50))
            snap["p99_ms"] = float(np.percentile(lat, 99))
            snap["mean_ms"] = float(lat.mean())
        else:
            snap["p50_ms"] = snap["p99_ms"] = snap["mean_ms"] = 0.0
        snap["throughput_qps"] = (
            snap["completed"] / uptime if uptime > 0 else 0.0
        )
        return snap
