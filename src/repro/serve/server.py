"""``BlazeServer`` — the long-lived multi-tenant front door to a resident
``BlazeSession``.

After PR 5 the stack is shaped like a database engine (session → plan IR →
optimizer → compiled programs) with no way in; this module is the front
door.  One server owns ONE resident session holding distributed datasets
and compiled programs, and serves concurrent clients over local HTTP:

* **accept path** (HTTP handler threads): parse → validate → admission
  (``repro.serve.admission``).  Never touches the session, never syncs —
  a submission either queues or gets an immediate typed rejection.
* **dispatch path** (one dispatcher thread): takes plan-compatible
  micro-batches off the queue (``repro.serve.batching``), resolves each to
  the resident program cache (a second client submitting an
  already-compiled plan is a cache hit — 0 compiles, asserted in
  ``tests/test_serve.py``), dispatches every execution asynchronously, and
  blocks on the host ONCE per batch before fulfilling futures.  All session
  access happens on this thread, serialized under ``session.lock`` — the
  session stays single-writer by construction.
* **isolation**: each execution gets ``program.reset_carry()`` first, so
  queries sharing a resident program (hash-table or error-feedback carry)
  cannot observe each other's state; a query that faults — at plan build,
  dispatch, or result shaping — fails only its own request(s) with a typed
  ``QUERY_ERROR`` while the server keeps serving
  (``tests/test_serve_faults.py``).

Endpoints: ``POST /query`` (``{"tenant", "query", "params"}`` →
``{"ok", "result", "meta"}``), ``GET /stats`` (``ServerStats.snapshot``),
``GET /health``.  Results travel bit-faithfully (``repro.serve.codec``).
"""
from __future__ import annotations

import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any

import jax

from repro.core import containers as C
from repro.core import faults
from repro.core.session import BlazeSession
from repro.serve import batching
from repro.serve.admission import (
    AdmissionQueue,
    BadParamsError,
    MalformedRequestError,
    QueryExecutionError,
    Request,
    RequestTimeoutError,
    ServeError,
    ServerClosedError,
    UnknownQueryError,
)
from repro.serve.codec import encode_payload
from repro.serve.queries import (
    DatasetEntry,
    PreparedQuery,
    QuerySpec,
    ServeResources,
    builtin_specs,
    canonical_params,
)
from repro.serve.stats import ServerStats

__all__ = ["BlazeServer"]


class BlazeServer:
    """A resident-session query server (construct → register → ``start``).

    >>> server = BlazeServer(max_queue=64, per_tenant_inflight=8)
    >>> server.register_dataset("edges", edges, n_pages=n)
    >>> server.start()
    >>> BlazeClient(server.url).query("pagerank", {"iters": 10})

    ``max_queue`` bounds the pending queue (admission returns a typed
    ``QUEUE_FULL`` beyond it), ``per_tenant_inflight`` bounds one tenant's
    admitted-but-unfinished requests, ``max_batch`` caps how many
    plan-compatible requests one dispatcher cycle serves, and
    ``request_timeout`` bounds how long the HTTP layer waits for a result.
    """

    def __init__(
        self,
        session: BlazeSession | None = None,
        *,
        mesh=None,
        host: str = "127.0.0.1",
        port: int = 0,
        max_queue: int = 64,
        per_tenant_inflight: int = 8,
        max_batch: int = 8,
        request_timeout: float = 120.0,
        queries: dict[str, QuerySpec] | None = None,
        tune: bool = False,
    ):
        self.session = session if session is not None else BlazeSession(mesh)
        self.mesh = mesh if mesh is not None else self.session.mesh
        self.stats = ServerStats()
        self.max_batch = max_batch
        self.request_timeout = request_timeout
        self._host, self._port = host, port
        self._queue = AdmissionQueue(max_queue, per_tenant_inflight)
        self._specs = builtin_specs() if queries is None else dict(queries)
        self._datasets: dict[str, DatasetEntry] = {}
        # ``tune=True``: every query's first prepare measures its candidate
        # engine/block configs (program autotuning) and caches winners in
        # the resident session's TuningCache — later prepares of plans
        # containing the same ops reuse them without re-measuring.
        self._resources = ServeResources(
            self.session, self.mesh, self._datasets, tune=tune
        )
        self._programs: dict[tuple, PreparedQuery] = {}  # the plan cache
        self._running = False
        self._paused = threading.Event()
        # Requests the dispatcher has taken but not yet finished (keyed by
        # request id — Request is an unhashable mutable dataclass) — what
        # the shutdown drain sweeps.  ``_finish_lock`` also guards the
        # per-request ``finished`` flag, making _finish idempotent.
        self._inflight: dict[str, Request] = {}
        self._finish_lock = threading.Lock()
        self._dispatcher: threading.Thread | None = None
        self._httpd: ThreadingHTTPServer | None = None
        self._http_thread: threading.Thread | None = None

    # -- registration (before or after start) ---------------------------------

    def register_dataset(self, name: str, value, **meta) -> None:
        """Make ``value`` resident under ``name`` (metadata like ``n_pages``
        or ``vocab_size`` rides along for the query specs)."""
        self._datasets[name] = DatasetEntry(name, value, dict(meta))

    def register_query(self, spec: QuerySpec) -> None:
        self._specs[spec.name] = spec

    @property
    def queries(self) -> list[str]:
        return sorted(self._specs)

    @property
    def datasets(self) -> dict[str, DatasetEntry]:
        return self._datasets

    # -- lifecycle ------------------------------------------------------------

    def start(self) -> "BlazeServer":
        if self._running:
            return self
        self._running = True
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="blaze-dispatch", daemon=True
        )
        self._dispatcher.start()
        self._httpd = _BlazeHTTPServer((self._host, self._port), _Handler)
        self._httpd.blaze = self
        self._http_thread = threading.Thread(
            target=self._httpd.serve_forever, name="blaze-http", daemon=True
        )
        self._http_thread.start()
        return self

    def stop(self, drain_timeout: float = 5.0) -> None:
        """Graceful shutdown: refuse new admissions, answer everything still
        queued with a typed ``SHUTDOWN``, let the dispatcher finish the batch
        it holds for up to ``drain_timeout`` seconds, then answer any
        straggler it didn't fulfil with ``SHUTDOWN`` too — no waiter is left
        hanging until its request timeout."""
        if not self._running:
            return
        self._running = False
        for req in self._queue.close():
            if self._finish(req, ok=False):
                req.fail(ServerClosedError("server stopped before dispatch"))
        if self._dispatcher is not None:
            self._dispatcher.join(timeout=drain_timeout)
        # Stragglers: taken by the dispatcher but not finished inside the
        # drain deadline (or orphaned by a dispatcher crash).
        with self._finish_lock:
            stragglers = [
                r for r in self._inflight.values() if not r.finished
            ]
        for req in stragglers:
            if self._finish(req, ok=False):
                req.fail(ServerClosedError("server shut down mid-flight"))
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
        if self._http_thread is not None:
            self._http_thread.join(timeout=10)

    def __enter__(self) -> "BlazeServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def url(self) -> str:
        assert self._httpd is not None, "server not started"
        host, port = self._httpd.server_address[:2]
        return f"http://{host}:{port}"

    def pause_dispatch(self) -> None:
        """Stop draining the queue (admission keeps running) — the test /
        maintenance hook that makes queue saturation and micro-batch
        formation deterministic."""
        self._paused.set()

    def resume_dispatch(self) -> None:
        self._paused.clear()

    @property
    def queue_depth(self) -> int:
        return self._queue.depth

    # -- the accept path (no session access, no syncs) ------------------------

    def submit(self, tenant: str, query: str, params: dict | None = None
               ) -> Request:
        """Validate + admit one query; returns the pending :class:`Request`
        (wait on ``req.done``) or raises a typed :class:`ServeError`."""
        params = {} if params is None else params
        try:
            if not isinstance(tenant, str) or not tenant:
                raise MalformedRequestError("tenant must be a non-empty string")
            if not isinstance(params, dict):
                raise MalformedRequestError("params must be an object")
            spec = self._specs.get(query)
            if spec is None:
                raise UnknownQueryError(
                    f"no query {query!r}; registered: {self.queries}"
                )
            plan_key = spec.plan_key(params)
            req = Request(
                tenant=tenant, query=query, params=params, plan_key=plan_key,
                exec_key=(plan_key, canonical_params(params)),
            )
            self._queue.submit(req)
        except ServeError as e:
            self.stats.on_rejected(e.code)
            raise
        self.stats.on_admitted()
        return req

    def submit_and_wait(self, tenant: str, query: str,
                        params: dict | None = None,
                        timeout: float | None = None):
        """Blocking convenience: submit, wait, return ``(result, meta)`` or
        raise the request's typed error."""
        req = self.submit(tenant, query, params)
        if not req.done.wait(
            self.request_timeout if timeout is None else timeout
        ):
            raise RequestTimeoutError(f"request {req.id} still pending")
        if req.error is not None:
            raise req.error
        return req.result, req.meta

    # -- the dispatch path (sole session user) --------------------------------

    def _dispatch_loop(self) -> None:
        while self._running:
            if self._paused.is_set():
                time.sleep(0.02)  # stay responsive to resume/stop
                continue
            batch = self._queue.take_batch(self.max_batch, timeout=0.1)
            if not batch:
                continue
            if self._paused.is_set():
                # Pause landed while we were inside take_batch — put the
                # batch back so pause_dispatch() really holds the backlog.
                for req in self._queue.requeue(batch):
                    if self._finish(req, ok=False):
                        req.fail(ServerClosedError("server stopped"))
                continue
            self._execute_batch(batch)

    def _prepared_for(self, req: Request) -> tuple[PreparedQuery, bool]:
        """(prepared query, was it a plan-cache hit) — the cross-request
        plan-cache reuse point."""
        prepared = self._programs.get(req.plan_key)
        if prepared is not None:
            return prepared, True
        spec = self._specs[req.query]
        prepared = spec.prepare(self._resources, req.params)
        self._programs[req.plan_key] = prepared
        return prepared, False

    def _execute_batch(self, batch: list[Request]) -> None:
        with self._finish_lock:
            for req in batch:
                self._inflight[req.id] = req
        groups = batching.dedup_groups(batch)
        executed: list[tuple[list[Request], PreparedQuery, Any, str]] = []
        served = 0
        # Phase 1: resolve + dispatch every execution group, NO host sync.
        # Each group dispatch runs supervised: transient faults retry with
        # backoff, kernel faults demote the program's pallas nodes to eager
        # and re-dispatch — the query still answers, and the degradation is
        # visible in /stats (recovery block) and the plan's explain().
        for group in groups:
            lead = group[0]
            try:
                with self.session.lock:
                    compiles0 = self.session.stats.program_compiles
                    retries0 = self.session.stats.retries
                    degraded0 = self.session.stats.degraded_nodes
                    prepared, cached = self._prepared_for(lead)
                    # Isolation: shared resident programs carry per-shard
                    # state (hash tables, int8 residuals) across dispatches.
                    prepared.program.reset_carry()
                    dev = self.session.supervised(
                        lambda prepared=prepared, lead=lead:
                            prepared.run(lead.params),
                        program=prepared.program,
                    )
                    compiled = self.session.stats.program_compiles - compiles0
                    retried = self.session.stats.retries - retries0
                    degraded = self.session.stats.degraded_nodes - degraded0
                if retried or degraded:
                    self.stats.on_recovery(retried, degraded)
                self.stats.on_plan(cache_hit=(cached and compiled == 0))
                cache = "hit" if (cached and compiled == 0) else "compile"
                executed.append((group, prepared, dev, cache))
                served += len(group)
            except ServeError as e:
                self._fail_group(group, e)
            except Exception as e:  # noqa: BLE001 — fault isolation boundary
                self._fail_group(group, QueryExecutionError(
                    f"{req_desc(lead)} failed: {type(e).__name__}: {e}"
                ))
        # Phase 2: ONE host sync for the whole batch.
        leaves = [
            leaf
            for _g, _p, dev, _c in executed
            for leaf in jax.tree_util.tree_leaves(dev)
        ]
        try:
            jax.block_until_ready(leaves)
        except Exception as e:  # noqa: BLE001 — device-side failure
            err = QueryExecutionError(f"batch sync failed: {e}")
            for group, _p, _d, _c in executed:
                self._fail_group(group, err)
            executed = []
        # Phase 3: materialise payloads and fan results out (dedup members
        # share their leader's payload).
        dedup = 0
        for group, prepared, dev, cache in executed:
            try:
                payload = prepared.finish(dev)
            except Exception as e:  # noqa: BLE001 — per-group fault isolation
                self._fail_group(group, QueryExecutionError(
                    f"result materialisation failed: {type(e).__name__}: {e}"
                ))
                continue
            for j, req in enumerate(group):
                # Account the finish BEFORE releasing the waiter, so "done
                # is set" implies "counted in stats" (the property suite's
                # drain check relies on this ordering).  A request the
                # shutdown sweep already answered is skipped.
                if not self._finish(req, ok=True):
                    continue
                req.succeed(payload, {
                    "plan_hash": prepared.plan_hash,
                    "cache": cache if j == 0 else "dedup",
                    "batch_size": served,
                    "coalesced": served > 1,
                })
            dedup += len(group) - 1
        if served:
            self.stats.on_dispatch(served, dedup)

    def _fail_group(self, group: list[Request], err: ServeError) -> None:
        for req in group:
            if self._finish(req, ok=False):
                req.fail(err)

    def _finish(self, req: Request, *, ok: bool) -> bool:
        """Account one request's completion exactly once.  Returns False if
        it was already finished (the shutdown sweep racing the dispatcher) —
        the caller must then skip ``succeed``/``fail`` too."""
        with self._finish_lock:
            if req.finished:
                return False
            req.finished = True
            self._inflight.pop(req.id, None)
        self._queue.release(req)
        self.stats.on_finished(ok, time.perf_counter() - req.t_submit)
        return True

    # -- observability ---------------------------------------------------------

    def stats_snapshot(self) -> dict:
        snap = self.stats.snapshot()
        snap["pending_queue"] = self._queue.depth
        snap["resident_programs"] = len(self._programs)
        snap["session"] = self.session.cache_info()
        snap["queries"] = self.queries
        snap["datasets"] = sorted(self._datasets)
        snap["mesh_shards"] = C.shard_count(self.mesh)
        snap["mesh_nodes"] = C.n_nodes(self.mesh)
        snap["tuning"] = self._tuning_snapshot()
        snap["recovery"] = self._recovery_snapshot()
        return snap

    def _recovery_snapshot(self) -> dict:
        """Fault-recovery provenance for operators: what was injected, how
        each injection was disposed (the conservation ledger), and how often
        this server's dispatches retried or degraded.  ``balanced`` is the
        invariant the chaos suite pins: every injected fault was disposed
        exactly once."""
        ledger = faults.snapshot()
        return {
            "retried_batches": self.stats.retries,
            "degraded_batches": self.stats.degraded,
            "session_retries": self.session.stats.retries,
            "session_degraded_nodes": self.session.stats.degraded_nodes,
            "session_escalations": self.session.stats.escalations,
            "faults_injected": ledger["injected_total"],
            "dispositions": ledger["dispositions"],
            "balanced": ledger["balanced"],
        }

    def _tuning_snapshot(self) -> dict:
        """Per-resident-plan engine/config provenance.

        A plan is "tuned" when at least one of its ops runs a measured (or
        disk-loaded) winner; otherwise it runs entirely on the calibrated
        cost model ("fallback").  ``tuned_plans + fallback_plans`` always
        equals ``resident_programs`` — the conservation the serve tests pin.
        """
        tuned_plans = 0
        per_plan = {}
        for prep in self._programs.values():
            plan = prep.program.plan
            ops, measured = [], False
            for n in (plan.mapreduce_nodes() if plan is not None else []):
                if n.dead or n.cse_of is not None:
                    continue
                cfg = n.tuned
                if cfg is not None:
                    measured = measured or cfg.source in ("measured", "loaded")
                    ops.append({
                        "op": n.idx, "engine": n.engine,
                        "config": cfg.describe(), "source": cfg.source,
                        "wall_ms": (
                            None if cfg.wall_s is None
                            else round(cfg.wall_s * 1e3, 3)
                        ),
                    })
                else:
                    ops.append({
                        "op": n.idx, "engine": n.engine, "config": None,
                        "source": "model",
                        "cost_estimate": n.cost_estimate,
                    })
            if measured:
                tuned_plans += 1
            per_plan[prep.plan_hash] = {
                "query": prep.plan_key[0], "tuned": measured, "ops": ops,
            }
        return {
            "tuned_plans": tuned_plans,
            "fallback_plans": len(self._programs) - tuned_plans,
            "cache": self.session.tuning.snapshot(),
            "plans": per_plan,
        }


def req_desc(req: Request) -> str:
    return f"query {req.query!r} (tenant {req.tenant!r}, id {req.id})"


# -- HTTP layer ----------------------------------------------------------------


class _BlazeHTTPServer(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True
    blaze: BlazeServer


class _Handler(BaseHTTPRequestHandler):
    server_version = "BlazeServe/6.0"
    protocol_version = "HTTP/1.1"

    # The accept path must stay quiet in tests/benchmarks.
    def log_message(self, fmt, *args):  # noqa: D102
        pass

    def _send_json(self, status: int, obj: dict) -> None:
        body = json.dumps(obj).encode()
        try:
            self.send_response(status)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)
        except (BrokenPipeError, ConnectionResetError):
            # Client went away mid-flight: count it, keep serving.
            self.server.blaze.stats.on_disconnect()

    def do_GET(self):  # noqa: N802 — BaseHTTPRequestHandler API
        srv = self.server.blaze
        if self.path == "/stats":
            self._send_json(200, srv.stats_snapshot())
        elif self.path == "/health":
            self._send_json(200, {
                "ok": True, "queries": srv.queries,
                "datasets": sorted(srv.datasets),
            })
        else:
            self._send_json(404, {"ok": False, "error": "NOT_FOUND",
                                  "message": self.path})

    def do_POST(self):  # noqa: N802 — BaseHTTPRequestHandler API
        srv = self.server.blaze
        if self.path != "/query":
            self._send_json(404, {"ok": False, "error": "NOT_FOUND",
                                  "message": self.path})
            return
        try:
            length = int(self.headers.get("Content-Length") or 0)
            raw = self.rfile.read(length)
            body = json.loads(raw.decode() or "null")
            if not isinstance(body, dict) or not isinstance(
                body.get("query"), str
            ):
                raise MalformedRequestError(
                    'body must be {"query": str, "params"?: obj, '
                    '"tenant"?: str}'
                )
            req = srv.submit(
                body.get("tenant", "default"), body["query"],
                body.get("params") or {},
            )
        except ServeError as e:
            self._send_json(e.http_status, e.payload())
            return
        except (ValueError, UnicodeDecodeError) as e:
            err = MalformedRequestError(f"invalid JSON body: {e}")
            srv.stats.on_rejected(err.code)
            self._send_json(err.http_status, err.payload())
            return
        if not req.done.wait(srv.request_timeout):
            e = RequestTimeoutError(f"request {req.id} still pending")
            self._send_json(e.http_status, e.payload())
            return
        if req.error is not None:
            self._send_json(req.error.http_status, req.error.payload())
            return
        self._send_json(200, {
            "ok": True,
            "result": encode_payload(req.result),
            "meta": req.meta,
        })
