"""Micro-batching policy: which concurrent queries coalesce, and how.

The dispatcher serves the queue in **supersteps** (one batch per cycle)
rather than request-at-a-time — the BSP-style fix for per-request dispatch
overhead (Pace, arXiv:1203.2081) applied across *requests* instead of
across iterations:

1. **Plan grouping** (``AdmissionQueue.take_batch``): the head request plus
   every queued request with the same ``plan_key`` — they share one
   resident compiled program, so serving them together means one program
   lookup, zero additional compiles, and back-to-back dispatches of one
   executable.
2. **Dedup** (:func:`dedup_groups`, here): within the batch, requests with
   equal ``exec_key`` (same plan AND same parameters) are the *same*
   computation — one execution's result fans out to all of them.
3. **One sync** (``BlazeServer._execute_batch``): every execution in the
   batch is dispatched asynchronously (JAX enqueues on device without
   blocking); the host blocks **once** for the whole batch
   (``jax.block_until_ready``) before any result is materialised.  The
   accept loop never syncs at all — admission happens on HTTP threads that
   do no session work.

``ServerStats`` counts a cycle that served ≥ 2 requests as a
``batched_dispatch`` and every request beyond the first as ``coalesced``.
"""
from __future__ import annotations

from repro.serve.admission import Request

__all__ = ["dedup_groups"]


def dedup_groups(batch: list[Request]) -> list[list[Request]]:
    """Partition a plan-compatible batch into execution groups.

    Requests with equal ``exec_key`` land in one group (first-submitted
    first); each group costs exactly one execution, and members beyond the
    leader are dedup hits.  Group order preserves submission order of the
    leaders.
    """
    groups: dict[tuple, list[Request]] = {}
    order: list[tuple] = []
    for req in batch:
        if req.exec_key not in groups:
            groups[req.exec_key] = []
            order.append(req.exec_key)
        groups[req.exec_key].append(req)
    return [groups[k] for k in order]
