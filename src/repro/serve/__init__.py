"""BlazeServe: a long-lived multi-tenant query service over one resident
:class:`~repro.core.session.BlazeSession`.

The session/plan/program stack (PRs 1-5) made single-driver jobs fast; this
package makes that investment *shared*: datasets stay device-resident,
compiled programs are reused across requests and tenants (``plan_hash``
keyed), and compatible concurrent queries micro-batch into one dispatch.

Layered as::

    client.py     BlazeClient / RemoteServeError      (wire, stdlib HTTP)
    server.py     BlazeServer                         (accept + dispatch)
    admission.py  AdmissionQueue + typed ServeErrors  (bounded, per-tenant)
    batching.py   dedup_groups                        (micro-batch policy)
    queries.py    QuerySpec / PreparedQuery           (prepared statements)
    stats.py      ServerStats                         (/stats invariants)
    codec.py      encode/decode_payload               (bit-faithful arrays)

Entry point: ``python -m repro.launch.serve`` (see ``examples/serve_queries.py``
for a multi-tenant client driving all six built-in algorithms).
"""
from repro.serve.admission import (
    AdmissionQueue,
    BadParamsError,
    MalformedRequestError,
    QueryExecutionError,
    QueueFullError,
    Request,
    RequestTimeoutError,
    ServeError,
    ServerClosedError,
    TenantLimitError,
    UnknownDatasetError,
    UnknownQueryError,
)
from repro.serve.client import BlazeClient, RemoteServeError
from repro.serve.codec import decode_payload, encode_payload
from repro.serve.queries import (
    DatasetEntry,
    PreparedQuery,
    QuerySpec,
    ServeResources,
    builtin_specs,
    run_direct,
)
from repro.serve.server import BlazeServer
from repro.serve.stats import ServerStats

__all__ = [
    "AdmissionQueue",
    "BadParamsError",
    "BlazeClient",
    "BlazeServer",
    "DatasetEntry",
    "MalformedRequestError",
    "PreparedQuery",
    "QueryExecutionError",
    "QuerySpec",
    "QueueFullError",
    "RemoteServeError",
    "Request",
    "RequestTimeoutError",
    "ServeError",
    "ServeResources",
    "ServerClosedError",
    "ServerStats",
    "TenantLimitError",
    "UnknownDatasetError",
    "UnknownQueryError",
    "builtin_specs",
    "decode_payload",
    "encode_payload",
    "run_direct",
]
