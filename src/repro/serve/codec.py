"""Bit-faithful JSON payload codec for query results.

JSON's only number is a double, and float32 results that round-trip through
it can silently stop being bit-equal to the arrays the session produced —
which would make the serving layer's core contract ("results bit-equal to
direct ``session`` execution") untestable over the wire.  Arrays therefore
travel as raw little-endian bytes, base64-encoded, with dtype and shape
alongside::

    {"__nd__": {"dtype": "float32", "shape": [64], "data": "<base64>"}}

``encode_payload`` maps any pytree-ish result (dicts, lists/tuples, numpy /
JAX arrays, numpy scalars, plain scalars) into JSON-safe structures;
``decode_payload`` inverts it exactly (arrays come back as numpy).  Tuples
become lists — JSON has no tuple — so servers should shape results as dicts
of named fields.
"""
from __future__ import annotations

import base64

import numpy as np

__all__ = ["decode_payload", "encode_payload"]


def _encode_array(a: np.ndarray) -> dict:
    a = np.ascontiguousarray(a)
    if a.dtype.byteorder == ">":  # normalise to little-endian on the wire
        a = a.astype(a.dtype.newbyteorder("<"))
    return {
        "__nd__": {
            "dtype": a.dtype.name,
            "shape": list(a.shape),
            "data": base64.b64encode(a.tobytes()).decode("ascii"),
        }
    }


def encode_payload(obj):
    """Recursively JSON-encode a result payload, arrays as tagged bytes."""
    if obj is None or isinstance(obj, (bool, int, str)):
        return obj
    if isinstance(obj, float):
        return obj
    if isinstance(obj, np.generic):  # numpy scalar -> python scalar
        return obj.item()
    if isinstance(obj, np.ndarray):
        return _encode_array(obj)
    if isinstance(obj, dict):
        return {str(k): encode_payload(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [encode_payload(v) for v in obj]
    # JAX arrays (and anything else array-like) go through numpy.
    arr = np.asarray(obj)
    if arr.ndim == 0:
        return arr.item()
    return _encode_array(arr)


def decode_payload(obj):
    """Invert :func:`encode_payload`; tagged arrays come back as numpy."""
    if isinstance(obj, dict):
        nd = obj.get("__nd__")
        if nd is not None and set(nd) == {"dtype", "shape", "data"}:
            raw = base64.b64decode(nd["data"])
            a = np.frombuffer(raw, dtype=np.dtype(nd["dtype"]))
            return a.reshape(nd["shape"]).copy()
        return {k: decode_payload(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [decode_payload(v) for v in obj]
    return obj
