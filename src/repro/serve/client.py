"""Minimal stdlib HTTP client for a :class:`~repro.serve.server.BlazeServer`.

Uses only ``http.client`` so examples, tests, and benchmarks can hammer the
server from many threads without extra dependencies.  Typed server errors
come back as :class:`RemoteServeError` carrying the server's error ``code``
(``QUEUE_FULL``, ``QUERY_ERROR``, ...) and HTTP status, so callers can
branch on failure kind exactly like in-process callers branch on
``ServeError`` subclasses.
"""
from __future__ import annotations

import http.client
import json
import urllib.parse

from repro.serve.codec import decode_payload

__all__ = ["BlazeClient", "RemoteServeError"]


class RemoteServeError(RuntimeError):
    """A typed error relayed from the server (``.code``, ``.status``)."""

    def __init__(self, code: str, status: int, message: str):
        super().__init__(f"[{code}] {message}")
        self.code = code
        self.status = status


class BlazeClient:
    """One tenant's connection-per-call view of a running server.

    >>> c = BlazeClient(server.url, tenant="alice")
    >>> result, meta = c.query("pi", {"n_samples": 1 << 16, "iters": 4})
    >>> c.stats()["completed"]
    """

    def __init__(self, url: str, tenant: str = "default",
                 timeout: float = 300.0):
        parsed = urllib.parse.urlparse(url)
        self.host = parsed.hostname or "127.0.0.1"
        self.port = parsed.port or 80
        self.tenant = tenant
        self.timeout = timeout

    def _request(self, method: str, path: str, body: dict | None = None):
        conn = http.client.HTTPConnection(
            self.host, self.port, timeout=self.timeout
        )
        try:
            payload = None if body is None else json.dumps(body).encode()
            headers = {"Content-Type": "application/json"} if payload else {}
            conn.request(method, path, body=payload, headers=headers)
            resp = conn.getresponse()
            data = json.loads(resp.read().decode() or "{}")
            return resp.status, data
        finally:
            conn.close()

    def query(self, query: str, params: dict | None = None,
              tenant: str | None = None):
        """Run one query; returns ``(result, meta)`` with arrays decoded
        bit-exactly, or raises :class:`RemoteServeError`."""
        status, data = self._request("POST", "/query", {
            "tenant": self.tenant if tenant is None else tenant,
            "query": query,
            "params": params or {},
        })
        if status != 200 or not data.get("ok"):
            raise RemoteServeError(
                data.get("error", "HTTP_ERROR"), status,
                data.get("message", f"HTTP {status}"),
            )
        return decode_payload(data["result"]), data.get("meta", {})

    def stats(self) -> dict:
        status, data = self._request("GET", "/stats")
        if status != 200:
            raise RemoteServeError("STATS_ERROR", status, str(data))
        return data

    def health(self) -> dict:
        status, data = self._request("GET", "/health")
        if status != 200:
            raise RemoteServeError("HEALTH_ERROR", status, str(data))
        return data
