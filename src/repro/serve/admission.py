"""Request admission for BlazeServe: typed errors, a bounded pending queue,
and per-tenant in-flight limits.

Admission is the half of the server that must never block and never touch
the session: it runs on the accept path (HTTP handler threads), so the only
things it may do are O(1) bookkeeping under a lock and an immediate typed
verdict.  Overload is a *response*, not a hang — a full queue raises
:class:`QueueFullError` and a tenant over its in-flight budget raises
:class:`TenantLimitError`, both of which the HTTP layer turns into a 429
with a machine-readable ``error`` code (asserted in ``tests/test_serve.py``:
saturating the queue returns typed rejections in bounded time).

The pending queue is deliberately a plain list under a condition variable
rather than ``queue.Queue``: the micro-batcher (``repro.serve.batching``)
needs to *scan* the backlog for plan-compatible requests, not just pop the
head.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any

__all__ = [
    "AdmissionQueue",
    "BadParamsError",
    "MalformedRequestError",
    "QueryExecutionError",
    "QueueFullError",
    "Request",
    "RequestTimeoutError",
    "ServeError",
    "ServerClosedError",
    "TenantLimitError",
    "UnknownDatasetError",
    "UnknownQueryError",
]


class ServeError(Exception):
    """Base of every typed serving error.

    ``code`` is the stable machine-readable identifier (what clients and
    tests match on); ``http_status`` is what the HTTP layer sends.  The
    string message is advisory detail only.
    """

    code = "SERVE_ERROR"
    http_status = 500

    def payload(self) -> dict:
        return {"ok": False, "error": self.code, "message": str(self)}


class QueueFullError(ServeError):
    """The bounded pending queue is at capacity — back off and retry."""

    code = "QUEUE_FULL"
    http_status = 429


class TenantLimitError(ServeError):
    """This tenant already has its full in-flight budget admitted."""

    code = "TENANT_LIMIT"
    http_status = 429


class UnknownQueryError(ServeError):
    """No registered query spec under that name."""

    code = "UNKNOWN_QUERY"
    http_status = 404


class UnknownDatasetError(ServeError):
    """The query referenced a dataset the server does not hold."""

    code = "UNKNOWN_DATASET"
    http_status = 400


class BadParamsError(ServeError):
    """Parameters failed the query spec's validation."""

    code = "BAD_PARAMS"
    http_status = 400


class MalformedRequestError(ServeError):
    """The request body was not a well-formed query submission."""

    code = "MALFORMED"
    http_status = 400


class QueryExecutionError(ServeError):
    """The query failed while building or running its plan.  Scoped to the
    one request that carried the fault — the server keeps serving."""

    code = "QUERY_ERROR"
    http_status = 500


class RequestTimeoutError(ServeError):
    """The client-side wait expired before the result arrived."""

    code = "TIMEOUT"
    http_status = 504


class ServerClosedError(ServeError):
    """The server is shutting down; the request was not (fully) served."""

    code = "SHUTDOWN"
    http_status = 503


_req_ids = itertools.count(1)


@dataclasses.dataclass
class Request:
    """One admitted query: identity, plan key, and its completion latch.

    ``plan_key`` is the query's *structural* identity (computed by the query
    spec at admission, before any session access): requests with equal
    ``plan_key`` share one compiled program and may micro-batch into one
    dispatch.  ``exec_key`` additionally folds in the non-structural
    parameters — requests with equal ``exec_key`` are the *same* computation
    and coalesce to a single execution (dedup).
    """

    tenant: str
    query: str
    params: dict
    plan_key: tuple
    exec_key: tuple
    id: int = dataclasses.field(default_factory=lambda: next(_req_ids))
    t_submit: float = dataclasses.field(default_factory=time.perf_counter)
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    result: Any = None
    meta: dict = dataclasses.field(default_factory=dict)
    error: ServeError | None = None
    # Set by the server's ``_finish`` (under its lock) the first time the
    # request is accounted; makes finishing idempotent so the shutdown path
    # can sweep stragglers without double-counting a race with the
    # dispatcher's own fulfilment.
    finished: bool = False

    def succeed(self, result: Any, meta: dict) -> None:
        self.result = result
        self.meta = meta
        self.done.set()

    def fail(self, error: ServeError) -> None:
        self.error = error
        self.done.set()


class AdmissionQueue:
    """Bounded FIFO of pending requests with per-tenant in-flight accounting.

    * ``submit`` admits or raises — it never blocks.  A tenant's in-flight
      count covers queued *and* executing requests and is released only by
      ``release`` (the dispatcher calls it when the request finishes), so a
      tenant cannot monopolise the queue by racing the dispatcher.
    * ``take_batch`` is the dispatcher's blocking pop: the head request plus
      every queued request sharing its ``plan_key`` (scan order preserved),
      up to ``max_batch`` — the raw material of a micro-batched dispatch.
    """

    def __init__(self, max_depth: int = 64, per_tenant: int = 8):
        if max_depth < 1 or per_tenant < 1:
            raise ValueError("max_depth and per_tenant must be >= 1")
        self.max_depth = max_depth
        self.per_tenant = per_tenant
        self._items: list[Request] = []
        self._lock = threading.Lock()
        self._nonempty = threading.Condition(self._lock)
        self._inflight: dict[str, int] = {}
        self._closed = False

    @property
    def depth(self) -> int:
        with self._lock:
            return len(self._items)

    def inflight(self, tenant: str) -> int:
        with self._lock:
            return self._inflight.get(tenant, 0)

    def submit(self, req: Request) -> None:
        with self._nonempty:
            if self._closed:
                raise ServerClosedError("server is shutting down")
            if self._inflight.get(req.tenant, 0) >= self.per_tenant:
                raise TenantLimitError(
                    f"tenant {req.tenant!r} already has "
                    f"{self.per_tenant} requests in flight"
                )
            if len(self._items) >= self.max_depth:
                raise QueueFullError(
                    f"pending queue is at capacity ({self.max_depth})"
                )
            self._inflight[req.tenant] = self._inflight.get(req.tenant, 0) + 1
            self._items.append(req)
            self._nonempty.notify()

    def take_batch(self, max_batch: int, timeout: float) -> list[Request]:
        """Pop the head request plus all queued plan-compatible requests
        (same ``plan_key``), up to ``max_batch``; ``[]`` on timeout."""
        with self._nonempty:
            if not self._items:
                self._nonempty.wait(timeout)
            if not self._items:
                return []
            head = self._items.pop(0)
            batch = [head]
            i = 0
            while len(batch) < max_batch and i < len(self._items):
                if self._items[i].plan_key == head.plan_key:
                    batch.append(self._items.pop(i))
                else:
                    i += 1
            return batch

    def requeue(self, reqs: list[Request]) -> list[Request]:
        """Reinsert already-admitted requests at the queue head (the
        dispatcher noticed a pause after taking them).  Bypasses admission
        limits — their budgets are still held.  If the queue has closed in
        the meantime the requests cannot be requeued and are returned for
        the caller to fail."""
        with self._nonempty:
            if self._closed:
                return list(reqs)
            self._items[:0] = reqs
            self._nonempty.notify()
            return []

    def release(self, req: Request) -> None:
        """The request finished (either way): return its tenant budget."""
        with self._lock:
            n = self._inflight.get(req.tenant, 0) - 1
            if n > 0:
                self._inflight[req.tenant] = n
            else:
                self._inflight.pop(req.tenant, None)

    def close(self) -> list[Request]:
        """Refuse further admissions; drain and return whatever is queued."""
        with self._nonempty:
            self._closed = True
            drained, self._items = self._items, []
            self._nonempty.notify_all()
            return drained
