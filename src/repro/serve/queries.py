"""Prepared queries: named, parameterised Blaze programs the server serves.

A client cannot ship a Python mapper over the wire; what it *can* ship is a
name plus parameters — the prepared-statement model.  A :class:`QuerySpec`
is the server-side half of that contract:

* ``plan_key(params)`` — validate the parameters and return the query's
  **structural identity**: everything that shapes the compiled program
  (dataset, key counts, engine, wire format, damping baked into glue...).
  Two requests with equal plan keys share ONE resident compiled program and
  can micro-batch into one dispatch.  Non-structural parameters (iteration
  counts — the trip count is traced; query points and seeds — they flow
  through ``state``) deliberately stay out of the key: that is what makes
  "same plan, different inputs" coalescible.
* ``prepare(res, params)`` — build the :class:`PreparedQuery` once per plan
  key: the ``session.program`` (plan discovered, optimizer passes run,
  ``plan_hash`` taken from the optimized plan), a ``run`` that dispatches
  one request's state through it WITHOUT any host sync, and a ``finish``
  that materialises the host payload after the batch-level sync.

The six paper algorithms are provided as built-ins, reusing each driver's
``_program_step`` — the serving path and the direct ``session`` path lower
literally the same plan, which is why ``run_direct`` (the reference used by
``tests/test_serve.py``) is bit-equal to served results.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

# The algorithms package __init__ rebinds submodule names to driver
# functions, so pull each planned step builder straight from its module.
from repro.core import containers as C
from repro.core.algorithms.gmm import _program_step as _gmm_step
from repro.core.algorithms.kmeans import _program_step as _kmeans_step
from repro.core.algorithms.knn import _program_step as _knn_step
from repro.core.algorithms.pagerank import _program_step as _pagerank_step
from repro.core.algorithms.pi import _program_step as _pi_step
from repro.core.algorithms.wordcount import _program_step as _wordcount_step
from repro.core.plan import ENGINES
from repro.serve.admission import (
    BadParamsError,
    UnknownDatasetError,
)

__all__ = [
    "BUILTIN_SPECS",
    "DatasetEntry",
    "PreparedQuery",
    "QuerySpec",
    "ServeResources",
    "builtin_specs",
    "canonical_params",
    "run_direct",
]


def canonical_params(params: dict) -> str:
    """Deterministic rendering of a params dict (the dedup half of
    ``Request.exec_key``)."""
    return json.dumps(params, sort_keys=True, separators=(",", ":"))


@dataclasses.dataclass
class DatasetEntry:
    """One resident dataset: the raw host value plus registration metadata
    (e.g. ``n_pages`` for an edge list, ``vocab_size`` for token lines)."""

    name: str
    value: np.ndarray
    meta: dict


class ServeResources:
    """What ``prepare`` sees: the resident session/mesh, the dataset table,
    and a cache for *derived* distributed objects (the ``DistVector`` built
    from a dataset must be built once and reused — program source identity
    is keyed on the backing buffers)."""

    def __init__(self, session, mesh, datasets: dict[str, DatasetEntry],
                 tune: bool = False):
        self.session = session
        self.mesh = mesh
        self.datasets = datasets
        self.tune = tune  # first-prepare autotuning for every built program
        self._derived: dict[tuple, Any] = {}

    def dataset(self, name) -> DatasetEntry:
        if not isinstance(name, str):
            raise BadParamsError(f"dataset must be a string, got {name!r}")
        entry = self.datasets.get(name)
        if entry is None:
            raise UnknownDatasetError(
                f"no dataset {name!r}; registered: {sorted(self.datasets)}"
            )
        return entry

    def derived(self, key: tuple, build: Callable[[], Any]):
        if key not in self._derived:
            self._derived[key] = build()
        return self._derived[key]


@dataclasses.dataclass
class PreparedQuery:
    """A resident compiled query: the program plus its run/finish halves.

    ``run(params)`` dispatches one request through the program and returns a
    pytree of *device* values — it must not block on the host (the
    dispatcher syncs once per micro-batch).  ``finish(dev)`` runs after that
    sync and shapes the host payload.
    """

    plan_key: tuple
    plan_hash: str
    program: Any
    run: Callable[[dict], Any]
    finish: Callable[[Any], dict]


class QuerySpec:
    """Base query spec; subclass or instantiate the built-ins below."""

    name: str = "?"

    def plan_key(self, params: dict) -> tuple:
        raise NotImplementedError

    def prepare(self, res: ServeResources, params: dict) -> PreparedQuery:
        raise NotImplementedError


# -- parameter validation helpers ---------------------------------------------


def _int(params: dict, key: str, default: int, lo: int) -> int:
    v = params.get(key, default)
    if not isinstance(v, int) or isinstance(v, bool) or v < lo:
        raise BadParamsError(f"{key} must be an int >= {lo}, got {v!r}")
    return v


def _float(params: dict, key: str, default: float) -> float:
    v = params.get(key, default)
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        raise BadParamsError(f"{key} must be a number, got {v!r}")
    return float(v)


def _engine(params: dict, default: str = "eager") -> str:
    v = params.get("engine", default)
    if v not in ENGINES:
        raise BadParamsError(f"unknown engine {v!r}; choose from {ENGINES}")
    return v


def _wire(params: dict) -> str:
    v = params.get("wire", "none")
    if v not in ("none", "bf16", "int8"):
        raise BadParamsError(f"unknown wire {v!r}")
    return v


# -- built-in specs: the paper's six algorithms as prepared queries ------------


class PiQuery(QuerySpec):
    """Monte-Carlo π.  Structural: sample count + engine (the DistRange and
    plan depend on both)."""

    name = "pi"

    def plan_key(self, params):
        return ("pi", _int(params, "n_samples", 4096, 1), _engine(params))

    def prepare(self, res, params):
        n = _int(params, "n_samples", 4096, 1)
        step, state0 = _pi_step(n, _engine(params))
        prog = res.session.program(step, mesh=res.mesh, tune=res.tune)
        plan = prog.build(state0)

        def run(p):
            return prog(state0, _int(p, "iters", 1, 1))

        def finish(dev):
            counts = np.asarray(jax.device_get(dev["counts"]))
            return {"pi": 4.0 * float(counts[0]) / n, "counts": counts}

        return PreparedQuery(self.plan_key(params), plan.hash, prog, run, finish)


class PageRankQuery(QuerySpec):
    """PageRank over a registered edge-list dataset.  Structural: dataset,
    damping (baked into the fused glue), engine, wire.  ``iters`` is the
    traced trip count — requests differing only in ``iters`` share the plan
    and micro-batch."""

    name = "pagerank"

    def plan_key(self, params):
        return (
            "pagerank", str(params.get("dataset", "edges")),
            _float(params, "damping", 0.85), _engine(params), _wire(params),
        )

    def prepare(self, res, params):
        entry = res.dataset(params.get("dataset", "edges"))
        edges = entry.value
        n_pages = int(entry.meta.get(
            "n_pages", (edges.max() + 1) if edges.size else 1
        ))
        damping = _float(params, "damping", 0.85)

        def build():
            edges_v = C.distribute(edges.astype(np.int32), res.mesh)
            deg = jnp.asarray(
                np.bincount(edges[:, 0], minlength=n_pages).astype(np.int32)
            )
            return edges_v, deg

        edges_v, deg = res.derived(("pagerank", entry.name), build)
        step, state0 = _pagerank_step(
            edges_v, deg, n_pages, damping, _engine(params), _wire(params)
        )
        prog = res.session.program(step, mesh=res.mesh, tune=res.tune)
        init = state0(jnp.full((n_pages,), 1.0 / n_pages, jnp.float32))
        plan = prog.build(init)

        def run(p):
            return prog(init, _int(p, "iters", 10, 1))

        def finish(dev):
            return {
                "scores": np.asarray(jax.device_get(dev["scores"])),
                "delta": float(jax.device_get(dev["delta"])),
            }

        return PreparedQuery(self.plan_key(params), plan.hash, prog, run, finish)


class WordCountQuery(QuerySpec):
    """Streaming word count over registered token lines (hash target).  The
    hash table is per-program carried state, so the dispatcher resets the
    program carry before every request — queries are isolated even though
    they share one resident executable."""

    name = "wordcount"

    def plan_key(self, params):
        return (
            "wordcount", str(params.get("dataset", "lines")), _engine(params),
        )

    def prepare(self, res, params):
        entry = res.dataset(params.get("dataset", "lines"))
        lines = entry.value
        vocab_bound = int(entry.meta.get(
            "vocab_size", (lines.max() + 1) if lines.size else 1
        ))
        lines_v = res.derived(
            ("wordcount", entry.name),
            lambda: C.distribute(lines.astype(np.int32), res.mesh),
        )
        hm = C.make_dist_hashmap(
            res.mesh, max(64, 4 * vocab_bound), (), jnp.int32, "sum"
        )
        step, state0 = _wordcount_step(
            lines_v, hm, vocab_bound, _engine(params)
        )
        prog = res.session.program(step, mesh=res.mesh, tune=res.tune)
        plan = prog.build(state0)

        def run(p):
            state = prog(state0, _int(p, "iters", 1, 1))
            return {"state": state, "hash": prog.hash_result(hm)}

        def finish(dev):
            keys, vals = dev["hash"].items()
            order = np.argsort(keys, kind="stable")
            return {"keys": keys[order], "counts": vals[order]}

        return PreparedQuery(self.plan_key(params), plan.hash, prog, run, finish)


class KMeansQuery(QuerySpec):
    """K-means over a registered point set.  Structural: dataset, k, engine,
    wire.  Seeded initial centres flow through ``state`` (non-structural);
    ``iters`` is the traced trip count."""

    name = "kmeans"

    def plan_key(self, params):
        return (
            "kmeans", str(params.get("dataset", "points")),
            _int(params, "k", 4, 1), _engine(params), _wire(params),
        )

    def prepare(self, res, params):
        entry = res.dataset(params.get("dataset", "points"))
        pts = entry.value
        k = _int(params, "k", 4, 1)
        dim = pts.shape[1]
        pts_v = res.derived(
            ("points", entry.name),
            lambda: C.distribute(pts.astype(np.float32), res.mesh),
        )
        step, state0 = _kmeans_step(
            pts_v, k, dim, _engine(params), _wire(params)
        )
        prog = res.session.program(step, mesh=res.mesh, tune=res.tune)

        def init_for(p):
            rng = np.random.RandomState(_int(p, "seed", 0, 0))
            centers = pts[rng.choice(min(len(pts), 4096), k, replace=False)]
            return state0(jnp.asarray(centers, jnp.float32))

        plan = prog.build(init_for(params))

        def run(p):
            return prog(init_for(p), _int(p, "iters", 10, 1))

        def finish(dev):
            return {
                "centers": np.asarray(jax.device_get(dev["centers"])),
                "inertia": float(jax.device_get(dev["inertia"])),
            }

        return PreparedQuery(self.plan_key(params), plan.hash, prog, run, finish)


class GMMQuery(QuerySpec):
    """GMM/EM over a registered point set.  Structural: dataset, k, engine."""

    name = "gmm"

    def plan_key(self, params):
        return (
            "gmm", str(params.get("dataset", "points")),
            _int(params, "k", 2, 1), _engine(params),
        )

    def prepare(self, res, params):
        entry = res.dataset(params.get("dataset", "points"))
        pts = entry.value
        k = _int(params, "k", 2, 1)
        n, d = pts.shape

        def build():
            rows0 = np.concatenate(
                [pts, np.zeros((n, k), np.float32)], axis=1
            )
            return C.distribute(rows0.astype(np.float32), res.mesh)

        rows_v = res.derived(("gmm", entry.name, k), build)
        step, state0 = _gmm_step(rows_v, k, d, n, _engine(params))
        prog = res.session.program(step, mesh=res.mesh, tune=res.tune)

        def init_for(p):
            rng = np.random.RandomState(_int(p, "seed", 0, 0))
            mu = pts[rng.choice(n, k, replace=False)].astype(np.float32)
            alpha = np.full(k, 1.0 / k, np.float32)
            sigma = np.tile(np.eye(d, dtype=np.float32), (k, 1, 1))
            return state0(alpha, mu, sigma)

        plan = prog.build(init_for(params))

        def run(p):
            return prog(init_for(p), _int(p, "iters", 5, 1))

        def finish(dev):
            return {
                "alpha": np.asarray(jax.device_get(dev["alpha"])),
                "mu": np.asarray(jax.device_get(dev["mu"])),
                "sigma": np.asarray(jax.device_get(dev["sigma"])),
                "log_likelihood": float(jax.device_get(dev["ll"])),
            }

        return PreparedQuery(self.plan_key(params), plan.hash, prog, run, finish)


class KNNQuery(QuerySpec):
    """k-nearest-neighbours via the container-level ``topk`` plan.  The
    query point flows through ``state`` — every kNN request against one
    (dataset, k) shares the plan and micro-batches."""

    name = "knn"

    def plan_key(self, params):
        return (
            "knn", str(params.get("dataset", "points")),
            _int(params, "k", 10, 1),
        )

    def prepare(self, res, params):
        entry = res.dataset(params.get("dataset", "points"))
        pts = entry.value
        k = _int(params, "k", 10, 1)
        dim = pts.shape[1]
        pts_v = res.derived(
            ("points", entry.name),
            lambda: C.distribute(pts.astype(np.float32), res.mesh),
        )
        n_shards = res.mesh.shape.get("data", 1)
        per = pts_v.data.shape[0] // n_shards
        kk = min(k, per)
        m = min(k, kk * n_shards)
        step = _knn_step(pts_v, k, "auto")
        prog = res.session.program(step, mesh=res.mesh, tune=res.tune)

        def state_for(p):
            q = p.get("query")
            if (
                not isinstance(q, (list, tuple)) or len(q) != dim
                or not all(isinstance(x, (int, float)) for x in q)
            ):
                raise BadParamsError(
                    f"query must be a list of {dim} numbers, got {q!r}"
                )
            return {
                "q": jnp.asarray(q, jnp.float32),
                "neighbors": jnp.zeros((m, dim), jnp.float32),
                "scores": jnp.full((m,), -jnp.inf, jnp.float32),
            }

        plan = prog.build(state_for({"query": [0.0] * dim, **params}))

        def run(p):
            return prog(state_for(p), 1)

        def finish(dev):
            nbrs = np.asarray(jax.device_get(dev["neighbors"]))
            scores = np.asarray(jax.device_get(dev["scores"]))
            return {
                "neighbors": nbrs,
                "distances": np.sqrt(np.maximum(-scores, 0.0)),
            }

        return PreparedQuery(self.plan_key(params), plan.hash, prog, run, finish)


BUILTIN_SPECS: dict[str, QuerySpec] = {
    s.name: s
    for s in (
        PiQuery(), PageRankQuery(), WordCountQuery(), KMeansQuery(),
        GMMQuery(), KNNQuery(),
    )
}


def builtin_specs() -> dict[str, QuerySpec]:
    """A fresh copy of the built-in registry (servers may mutate theirs)."""
    return dict(BUILTIN_SPECS)


def run_direct(session, mesh, datasets: dict[str, DatasetEntry],
               query: str, params: dict, *, specs=None) -> dict:
    """Execute one query synchronously against ``session`` — the serving
    layer's reference semantics.  Tests compare served results bit-for-bit
    against this (same spec, same program lowering, fresh session)."""
    specs = BUILTIN_SPECS if specs is None else specs
    spec = specs[query]
    res = ServeResources(session, mesh, datasets)
    prepared = spec.prepare(res, params)
    prepared.program.reset_carry()
    dev = prepared.run(params)
    jax.block_until_ready(jax.tree_util.tree_leaves(dev))
    return prepared.finish(dev)
