"""Pallas fused k-means assignment + statistics (the paper's k-means hot loop).

One pass over a point tile does everything the assignment step needs:

    d²  = ‖x‖² − 2 x·cᵀ + ‖c‖²      (MXU matmul; the ‖x‖² term is dropped —
                                      it does not change the argmin)
    a   = argmin_k d²                 (VPU)
    acc[K, D+1] += onehotᵀ @ [x | 1]  (MXU; eager reduction)

so the per-cluster Σx and counts — the entire MapReduce payload — accumulate
in a VMEM-resident ``[K, D+1]`` tile across the sequential grid, and the
points are read from HBM exactly once.  This is the kernel-level form of the
paper's eager reduction: emit→reduce fused into the map body.  The scatter
itself is ``onehot_accumulate`` — the same one-hot-matmul accumulator the
generalized segment-reduce kernel uses — applied to points with a ones
column appended, so Σx and the counts come out of a single matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.segment_reduce import onehot_accumulate


def _kmeans_kernel(pts_ref, ctr_ref, assign_ref, stats_ref, *, k, bn, n_true):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        stats_ref[...] = jnp.zeros_like(stats_ref)

    x = pts_ref[...].astype(jnp.float32)  # [bn, D]
    c = ctr_ref[...].astype(jnp.float32)  # [K, D]
    # −2 x·cᵀ + ‖c‖²  (argmin-equivalent distance)
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [bn, K]
    d2 = jnp.sum(c * c, axis=1)[None, :] - 2.0 * xc
    assign = jnp.argmin(d2, axis=1).astype(jnp.int32)  # [bn]

    row = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn,), 0)
    valid = row < n_true
    assign_ref[...] = jnp.where(valid, assign, -1)

    x1 = jnp.concatenate([x, jnp.ones((bn, 1), jnp.float32)], axis=1)
    stats_ref[...] += onehot_accumulate(assign, x1, k, valid=valid)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign(
    points: jax.Array,  # [N, D]
    centers: jax.Array,  # [K, D]
    *,
    block_n: int = 1024,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (assignments [N] int32, stats [K, D+1] = [Σx | count])."""
    from repro.kernels.segment_reduce import pallas_interpret_default

    if interpret is None:
        interpret = pallas_interpret_default()
    n, d = points.shape
    k = centers.shape[0]
    bn = min(block_n, n)
    n_pad = -(-n // bn) * bn
    pts_p = jnp.pad(points, ((0, n_pad - n), (0, 0)))

    kernel = functools.partial(_kmeans_kernel, k=k, bn=bn, n_true=n)
    assign, stats = pl.pallas_call(
        kernel,
        grid=(n_pad // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((k, d + 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((k, d + 1), jnp.float32),
        ],
        interpret=interpret,
    )(pts_p, centers)
    return assign[:n], stats
