"""Public kernel wrappers with backend dispatch.

Three tiers per op:

* ``impl="pallas"``       — the Pallas TPU kernel (``interpret=True`` on CPU);
* ``impl="chunked"``      — a pure-jnp blocked formulation with the same
                            O(memory) profile as the kernel.  This is what the
                            models lower in the multi-pod dry-run: no S²
                            buffer, scan-structured so XLA can schedule it;
* ``impl="ref"``          — the naive oracle (tests only).

``impl="auto"`` resolves to pallas on TPU and chunked elsewhere.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import ref as R

Array = jax.Array


def _auto() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "chunked"


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention(
    q: Array, k: Array, v: Array, *,
    causal: bool = True,
    window: int | None = None,
    softcap: float = 0.0,
    scale: float | None = None,
    q_offset: int | None = None,
    impl: str = "auto",
    block_q: int = 256,
    block_k: int = 512,
    shard_hint: str | None = None,
) -> Array:
    impl = _auto() if impl == "auto" else impl
    if impl == "pallas":
        from repro.kernels.flash_attention import flash_attention

        return flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, q_offset=q_offset, block_q=block_q, block_k=block_k,
            interpret=jax.default_backend() != "tpu",
        )
    if impl == "ref":
        return R.attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, scale=scale,
        )
    return attention_chunked(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        q_offset=q_offset, block_q=block_q, block_k=block_k,
        shard_hint=shard_hint,
    )


def attention_chunked(
    q: Array,  # [B, Hq, Sq, D]
    k: Array,  # [B, Hkv, Skv, D]
    v: Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float = 0.0,
    scale: float | None = None,
    q_offset: int | None = None,
    block_q: int = 256,
    block_k: int = 512,
    shard_hint: str | None = None,  # None | "heads" | "dh"
) -> Array:
    """Online-softmax attention, scan over q-blocks × kv-blocks.

    Peak live intermediate is one [B, H_local, bq, bk] f32 logits tile —
    flash-attention's memory profile in pure jnp, so 32k/500k contexts lower
    without any S² buffer.
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    rep = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    off = (skv - sq) if q_offset is None else q_offset

    bq = min(block_q, sq)
    bk = min(block_k, skv)
    sq_pad = -(-sq // bq) * bq
    skv_pad = -(-skv // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0)))
    nq, nk = sq_pad // bq, skv_pad // bk

    # [nk, B, Hkv, bk, D] — scan operand; [nq, B, Hq, bq, D] — outer scan.
    k_chunks = kp.reshape(b, hkv, nk, bk, d).transpose(2, 0, 1, 3, 4)
    v_chunks = vp.reshape(b, hkv, nk, bk, d).transpose(2, 0, 1, 3, 4)
    q_chunks = qp.reshape(b, hq, nq, bq, d).transpose(2, 0, 1, 3, 4)

    dp = ("pod", "data")
    if shard_hint is not None:
        from repro.distributed.sharding import constrain as _c

        ax = (
            (None, dp, "model", None, None)
            if shard_hint == "heads"
            else (None, dp, None, None, "model")
        )
        k_chunks = _c(k_chunks, *ax)
        v_chunks = _c(v_chunks, *ax)
        q_chunks = _c(q_chunks, *ax)

    def q_step(_, q_blk_idx):
        q_blk, iq = q_blk_idx  # [B, Hq, bq, D], scalar
        q_start = iq * bq + off

        @functools.partial(jax.checkpoint, policy=None)
        def kv_step(carry, kv_blk):
            m, l, acc = carry
            k_blk, v_blk, ik = kv_blk
            k_start = ik * bk
            # keep operands in model dtype; accumulate in f32 via the matmul
            # (a wholesale .astype(f32) gets hoisted out of the scan by LICM
            # and materialises an f32 copy of the entire K/V stream)
            kb = jnp.repeat(k_blk, rep, axis=1)
            vb = jnp.repeat(v_blk, rep, axis=1)
            if shard_hint is not None:
                from repro.distributed.sharding import constrain as _c

                ax = (
                    (dp, "model", None, None)
                    if shard_hint == "heads"
                    else (dp, None, None, "model")
                )
                kb = _c(kb, *ax)
                vb = _c(vb, *ax)
            s = jnp.einsum(
                "bhqd,bhkd->bhqk", q_blk, kb,
                preferred_element_type=jnp.float32,
            ) * scale
            if shard_hint == "dh":
                # scores are dh-contracted partial-sums: replicate over model
                from repro.distributed.sharding import constrain as _c

                s = _c(s, dp, None, None, None)
            if softcap > 0.0:
                s = softcap * jnp.tanh(s / softcap)
            qpos = q_start + jnp.arange(bq)[:, None]
            kpos = k_start + jnp.arange(bk)[None, :]
            mask = kpos < skv
            if causal:
                mask &= kpos <= qpos
            if window is not None:
                mask &= kpos > qpos - window
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(q.dtype), vb,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l, acc), None

        m0 = jnp.full((b, hq, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hq, bq), jnp.float32)
        a0 = jnp.zeros((b, hq, bq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (k_chunks, v_chunks, jnp.arange(nk))
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    # remat on both scan bodies: the backward recomputes the logits tiles
    # instead of saving one [B, H, bq, bk] f32 tile per (iq, ik) pair —
    # the flash-attention memory profile, forwards AND backwards.
    q_step = jax.checkpoint(q_step, policy=None)
    _, outs = jax.lax.scan(q_step, None, (q_chunks, jnp.arange(nq)))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, hq, sq_pad, d)
    return out[:, :, :sq]


# ---------------------------------------------------------------------------
# segment_reduce / kmeans_assign
# ---------------------------------------------------------------------------


def segment_reduce(ids, vals, num_segments, *, impl="auto", block_n=1024):
    impl = _auto() if impl == "auto" else impl
    if impl == "pallas":
        from repro.kernels.segment_reduce import segment_reduce as sr

        return sr(
            ids, vals, num_segments, block_n=block_n,
            interpret=jax.default_backend() != "tpu",
        )
    return R.segment_reduce_ref(ids, vals, num_segments)


def kmeans_assign(points, centers, *, impl="auto", block_n=1024):
    impl = _auto() if impl == "auto" else impl
    if impl == "pallas":
        from repro.kernels.kmeans_assign import kmeans_assign as ka

        return ka(
            points, centers, block_n=block_n,
            interpret=jax.default_backend() != "tpu",
        )
    return R.kmeans_assign_ref(points, centers)


# ---------------------------------------------------------------------------
# Mamba-2 SSD — chunked (matmul-form) implementation
# ---------------------------------------------------------------------------


def ssd(
    x: Array, dt: Array, a: Array, b: Array, c: Array, *,
    init_state: Array | None = None,
    chunk: int = 128,
    impl: str = "auto",
) -> tuple[Array, Array]:
    impl = _auto() if impl == "auto" else impl
    if impl == "pallas":
        try:
            from repro.kernels.ssd_scan import ssd_scan

            return ssd_scan(
                x, dt, a, b, c, init_state=init_state, chunk=chunk,
                interpret=jax.default_backend() != "tpu",
            )
        except ImportError:
            pass
    if impl == "ref":
        return R.ssd_ref(x, dt, a, b, c, init_state=init_state)
    return ssd_chunked(x, dt, a, b, c, init_state=init_state, chunk=chunk)


def ssd_chunked(
    x: Array,  # [B, S, H, P]
    dt: Array,  # [B, S, H]
    a: Array,  # [H] (negative)
    b: Array,  # [B, S, G, N]
    c: Array,  # [B, S, G, N]
    *,
    init_state: Array | None = None,
    chunk: int = 128,
) -> tuple[Array, Array]:
    """Mamba-2 SSD in chunked matmul form (the TPU-native formulation):

    intra-chunk  Y₁[t] = Σ_{s≤t} exp(Δ_t − Δ_s) (C_t·B_s) dt_s x_s   (MXU)
    inter-chunk  Y₂[t] = exp(Δ_t) C_t·h_prev ;  h carried by a scan over chunks
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    L = min(chunk, S)
    S_pad = -(-S // L) * L
    pad = S_pad - S
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # padded dt=0 → decay 1, input 0
    bp = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
    cp = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nch = S_pad // L

    bb = jnp.repeat(bp, rep, axis=2)  # [B, S, H, N]
    cc = jnp.repeat(cp, rep, axis=2)

    # SSD is embarrassingly parallel over heads: pin the H axis to the model
    # mesh axis through the chunk reshape (which would otherwise lose the
    # sequence sharding and replicate every chunked operand).
    from repro.distributed.sharding import constrain as _constrain

    def chunk_view(t):  # [B, S, ...] → [nch, B, L, ...]
        out = t.reshape((B, nch, L) + t.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, t.ndim + 1))
        )
        if out.ndim >= 4:  # [nch, B, L, H, ...]: H → model
            spec = (None, ("pod", "data"), None, "model") + (None,) * (out.ndim - 4)
            out = _constrain(out, *spec)
        return out

    xs = (
        chunk_view(xp).astype(jnp.float32),
        chunk_view(dtp).astype(jnp.float32),
        chunk_view(bb).astype(jnp.float32),
        chunk_view(cc).astype(jnp.float32),
    )
    h0 = (
        jnp.zeros((B, H, P, N), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )
    af = a.astype(jnp.float32)

    def step(h, inp):
        xc, dtc, bc, cchunk = inp  # [B,L,H,P], [B,L,H], [B,L,H,N], [B,L,H,N]
        adt = af[None, None, :] * dtc  # [B,L,H] (negative)
        cum = jnp.cumsum(adt, axis=1)  # Δ_t  [B,L,H]
        total = cum[:, -1]  # [B,H]
        # intra-chunk: M[t,s] = exp(Δ_t − Δ_s)·(C_t·B_s), s ≤ t
        cb = jnp.einsum("blhn,bshn->bhls", cchunk, bc)  # [B,H,L,L]
        # exponent clamped at 0: upper-triangle (s > t) entries would be
        # exp(+large) = inf before the mask (inf · 0 = NaN); valid entries
        # always have non-positive exponent (cum is non-increasing).
        dec = jnp.exp(
            jnp.minimum(
                cum.transpose(0, 2, 1)[:, :, :, None]
                - cum.transpose(0, 2, 1)[:, :, None, :],
                0.0,
            )
        )  # [B,H,L,L]
        tri = jnp.tril(jnp.ones((L, L), jnp.float32))
        m = cb * dec * tri[None, None]
        dx = dtc[..., None] * xc  # [B,L,H,P]
        y_intra = jnp.einsum("bhls,bshp->blhp", m, dx)
        # inter-chunk: read previous state
        y_inter = jnp.einsum(
            "blhn,bhpn,blh->blhp", cchunk, h, jnp.exp(cum)
        )
        # state update: h' = exp(total)·h + Σ_s exp(total − Δ_s) dx_s ⊗ B_s
        sdec = jnp.exp(total[:, None, :] - cum)  # [B,L,H]
        h_new = h * jnp.exp(total)[..., None, None] + jnp.einsum(
            "blhp,blhn,blh->bhpn", dx, bc, sdec
        )
        return h_new, (y_intra + y_inter)

    hT, ys = jax.lax.scan(step, h0, xs)  # ys [nch, B, L, H, P]
    ys = _constrain(ys, None, ("pod", "data"), None, "model", None)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S_pad, H, P)[:, :S]
    return y.astype(x.dtype), hT


# ---------------------------------------------------------------------------
# RWKV-6 — chunked implementation
# ---------------------------------------------------------------------------


def rwkv6(
    r: Array, k: Array, v: Array, w: Array, u: Array, *,
    init_state: Array | None = None,
    chunk: int = 64,
    impl: str = "auto",
) -> tuple[Array, Array]:
    impl = _auto() if impl == "auto" else impl
    if impl == "pallas":
        try:
            from repro.kernels.rwkv6_scan import rwkv6_scan

            return rwkv6_scan(
                r, k, v, w, u, init_state=init_state, chunk=chunk,
                interpret=jax.default_backend() != "tpu",
            )
        except ImportError:
            pass
    if impl == "ref":
        return R.rwkv6_ref(r, k, v, w, u, init_state=init_state)
    return rwkv6_chunked(r, k, v, w, u, init_state=init_state, chunk=chunk)


def rwkv6_chunked(
    r: Array,  # [B, S, H, K]
    k: Array,  # [B, S, H, K]
    v: Array,  # [B, S, H, V]
    w: Array,  # [B, S, H, K] decay in (0, 1)
    u: Array,  # [H, K] bonus
    *,
    init_state: Array | None = None,
    chunk: int = 64,
) -> tuple[Array, Array]:
    """RWKV-6 wkv in chunked form.  Per chunk (log-space cumulative decay λ):

    out_t = r_t·(Λ_t ∘ S_prev) + Σ_{s<t} (r_t ∘ Λ_t/Λ_{s+1})·k_s v_s
            + (r_t ∘ u)·k_t v_t
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    L = min(chunk, S)
    S_pad = -(-S // L) * L
    pad = S_pad - S

    def padt(t):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))

    rp, kp, vp = padt(r), padt(k), padt(v)
    wp = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    nch = S_pad // L

    def chunk_view(t):
        return t.reshape((B, nch, L) + t.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, t.ndim + 1))
        ).astype(jnp.float32)

    xs = (chunk_view(rp), chunk_view(kp), chunk_view(vp), chunk_view(wp))
    s0 = (
        jnp.zeros((B, H, K, V), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )
    uf = u.astype(jnp.float32)

    def step(s, inp):
        rc, kc, vc, wc = inp  # [B,L,H,K] ×3, [B,L,H,V] for vc
        # Per-step decay floored at e^(−88/L): contributions that decay
        # below f32 range within one chunk underflow to 0 either way, and
        # the floor keeps the factored exp(±λ) terms finite (no inf·0).
        logw = jnp.maximum(jnp.log(jnp.maximum(wc, 1e-30)), -88.0 / L)
        lam = jnp.cumsum(logw, axis=1)  # λ_t = Σ_{s≤t} log w_s
        # inter-chunk: out_t += (r_t ∘ exp(λ_{t-1}))·S_prev   (λ up to t−1)
        lam_prev = lam - logw  # λ_{t-1}
        r_dec = rc * jnp.exp(lam_prev)
        out = jnp.einsum("blhk,bhkv->blhv", r_dec, s)
        # intra-chunk, strictly-lower-triangular pairs (s < t):
        # decay from s+1 .. t−1+1 = exp(λ_{t-1} − λ_s)
        q_t = rc * jnp.exp(lam_prev)  # [B,L,H,K]
        k_s = kc * jnp.exp(-lam)  # [B,L,H,K]
        scores = jnp.einsum("blhk,bshk->bhls", q_t, k_s)  # [B,H,L,L]
        tri = jnp.tril(jnp.ones((L, L), jnp.float32), k=-1)
        out = out + jnp.einsum("bhls,bshv->blhv", scores * tri[None, None], vc)
        # diagonal bonus term
        diag = jnp.einsum("blhk,blhk->blh", rc * uf[None, None], kc)
        out = out + diag[..., None] * vc
        # state update: S' = (Π w) ∘ S + Σ_s exp(λ_L − λ_s) k_s v_sᵀ
        lam_tot = lam[:, -1]  # [B,H,K]
        k_dec = kc * jnp.exp(lam_tot[:, None] - lam)  # [B,L,H,K]
        s = s * jnp.exp(lam_tot)[..., None] + jnp.einsum(
            "blhk,blhv->bhkv", k_dec, vc
        )
        return s, out

    sT, ys = jax.lax.scan(step, s0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S_pad, H, V)[:, :S]
    return y.astype(v.dtype), sT
