"""Pallas hash-aggregation: eager reduction for *unbounded* key ranges.

The segment-reduce kernel (``segment_reduce.py``) is the paper's §2.3.3
small-fixed-key-range accumulator: key == index into a dense ``[K, V]`` VMEM
tile.  Word-count-shaped workloads break its premise — the key space is open
(any int32 word id) — and the hash path previously paid for it three times per
MapReduce: a sort-based ``unique_combine`` before the shuffle, another one
after it, and a 16-round scatter ``fori_loop`` (``hashmap_insert``) to merge
into the target table.

``hash_aggregate`` replaces all three with ONE streaming pass: an
open-addressing (linear probing) hash table — ``keys [C]`` + ``vals [C, V]``
— resident in VMEM for the whole pass, fed pair-blocks by the grid.  Per
block, per probe round:

1. every unplaced lane computes its slot ``(h + r) mod C`` and *gathers* the
   resident key via a one-hot max over the table axis (no dynamic indexing);
2. lanes whose slot is FREE race to claim it — the winner is the max key
   among claimants (deterministic, matches ``containers.hashmap_insert``);
3. lanes whose key is now resident at their slot *deposit*: the block's
   contributions are folded into the table rows with the reducer monoid —
   a one-hot matmul on the MXU for float sums, a select-scatter VPU fold for
   min/max/prod and exact integer sums (the same two strategies as
   ``segment_reduce``).  Duplicate keys within a block all deposit in the
   same round, so no pre-combine (``unique_combine``) is ever needed;
4. losers (slot taken by a different key) continue to round ``r+1``.

The probe loop is a ``while_loop`` with an all-placed early exit: duplicate-
heavy streams (word counts) finish most blocks in one or two rounds
regardless of the configured ``max_probes``.  Lanes still unplaced after
``max_probes`` rounds are *counted* into the overflow output, never silently
dropped.  An existing table can be passed as ``init`` — the kernel then
*merges* into it (the post-shuffle use), bit-compatible with
``hashmap_insert``'s probe sequence, so eager- and kernel-built tables place
keys identically.

``choose_table_cap`` autotunes (capacity, block size, probe depth) under a
VMEM budget; ``interpret=None`` resolves via ``pallas_interpret_default`` —
interpret off-TPU, forced either way by ``BLAZE_PALLAS_INTERPRET`` — so CPU
CI runs the exact kernel program TPUs run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.segment_reduce import (
    _acc_dtype,
    _combine,
    _fold,
    _identity,
    _use_matmul,
    pallas_interpret_default,
)

REDUCERS = ("sum", "prod", "min", "max")

# "slot free" sentinel — MUST match repro.core.containers.EMPTY_KEY (importing
# it would be cyclic: containers → reducers → kernels).  Asserted equal in
# tests/test_hash_kernel.py.
EMPTY_KEY = np.iinfo(np.int32).min

# The (capacity, block, probe-depth) tuner arithmetic is shared with the
# dense tuner and the measured autotuner in repro.core.cost; the delegates
# import lazily at call time (a module-level import would re-enter
# repro.core.__init__ mid-import — same constraint as segment_reduce).


def choose_probe_depth(n: int, table_cap: int) -> int:
    """Probe rounds for ``n`` pairs into a ``table_cap`` table (load-factor
    tiers; see ``cost.choose_probe_depth``)."""
    from repro.core.cost import choose_probe_depth as f

    return f(n, table_cap)


def choose_table_cap(
    n: int,
    v: int,
    reducer: str = "sum",
    dtype=jnp.float32,
    *,
    distinct_hint: int | None = None,
    vmem_budget: int | None = None,
) -> tuple[int, int, int]:
    """(table_cap, block_n, max_probes) for a fresh-table combine of ``n``
    pairs — the pick over ``cost.hash_table_candidates`` (shared grid)."""
    from repro.core import cost

    return cost.choose_table_cap(
        n, v, reducer, dtype, distinct_hint=distinct_hint,
        vmem_budget=cost.VMEM_BUDGET if vmem_budget is None else vmem_budget,
    )


def hash32(x: jax.Array) -> jax.Array:
    """splitmix32 finaliser → uint32.  Kernel-side copy of
    ``containers.hash32`` — identical constants, so kernel- and eager-built
    tables agree on every slot."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def _hash_kernel(
    keys_ref, vals_ref, ikeys_ref, ivals_ref, iovf_ref,
    okeys_ref, ovals_ref, oovf_ref, *, cap, bn, probes, reducer, acc_dtype,
):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        okeys_ref[...] = ikeys_ref[...]
        ovals_ref[...] = ivals_ref[...].astype(acc_dtype)
        oovf_ref[...] = iovf_ref[...]

    keys = keys_ref[...]  # [bn] int32; EMPTY_KEY marks a dead lane
    vals = vals_ref[...].astype(acc_dtype)  # [bn, V]
    ident = _identity(reducer, acc_dtype)
    active0 = keys != EMPTY_KEY
    if _use_matmul(reducer, acc_dtype):
        # Zero dead-lane values up front: an all-False one-hot row still
        # contracts 0·NaN = NaN into every slot (same hazard as the dense
        # kernel).
        vals = jnp.where(active0[:, None], vals, 0)
    h = (hash32(keys) % jnp.uint32(cap)).astype(jnp.int32)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (bn, cap), 1)

    def gather_slot_keys(tkeys, onehot):
        # tkeys[slot_i] for every lane, without dynamic indexing: a masked
        # max over the table axis (EMPTY_KEY = int32 min is the floor).
        return jnp.max(
            jnp.where(onehot, tkeys[None, :], EMPTY_KEY), axis=1
        )

    def probe_round(carry):
        r, tkeys, tvals, active = carry
        slot = (h + r) % cap  # [bn]
        onehot = slot[:, None] == iota_c  # [bn, C]
        slot_key = gather_slot_keys(tkeys, onehot)

        # Claim free slots: winner per slot = max key among claimants —
        # deterministic, and the same tie-break hashmap_insert uses.
        want = active & (slot_key == EMPTY_KEY)
        claim = jnp.max(
            jnp.where(onehot & want[:, None], keys[:, None], EMPTY_KEY),
            axis=0,
        )  # [C]
        tkeys = jnp.where(
            (tkeys == EMPTY_KEY) & (claim != EMPTY_KEY), claim, tkeys
        )

        # Deposit where our key is now resident at our slot.  Duplicate keys
        # in the block all match the same row and are folded together by the
        # monoid — the kernel subsumes unique_combine.
        slot_key = gather_slot_keys(tkeys, onehot)
        deposit = active & (slot_key == keys)
        match = onehot & deposit[:, None]  # [bn, C]
        if _use_matmul(reducer, acc_dtype):
            tvals = tvals + jax.lax.dot_general(
                match.astype(acc_dtype), vals,
                (((0,), (0,)), ((), ())),
                preferred_element_type=acc_dtype,
            )
        else:
            masked = jnp.where(match[:, :, None], vals[:, None, :], ident)
            tvals = _combine(reducer)(tvals, _fold(reducer)(masked, axis=0))
        return r + 1, tkeys, tvals, active & ~deposit

    def keep_probing(carry):
        r, _, _, active = carry
        return (r < probes) & jnp.any(active)

    _, tkeys, tvals, active = jax.lax.while_loop(
        keep_probing, probe_round,
        (jnp.zeros((), jnp.int32), okeys_ref[...], ovals_ref[...], active0),
    )
    okeys_ref[...] = tkeys
    ovals_ref[...] = tvals
    oovf_ref[...] = oovf_ref[...] + jnp.sum(active).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=(
        "table_cap", "reducer", "max_probes", "block_n", "interpret"
    ),
)
def hash_aggregate(
    keys: jax.Array,  # [N] int32; lanes with key == EMPTY_KEY are dead
    vals: jax.Array,  # [N, V]
    table_cap: int,
    *,
    reducer: str = "sum",
    init: tuple[jax.Array, jax.Array, jax.Array] | None = None,
    max_probes: int | None = None,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Reduce-by-key into an open-addressing table; duplicates welcome.

    Returns ``(tkeys [C] int32, tvals [C, V] acc-dtype, overflow [] int32)``
    — free slots hold ``EMPTY_KEY`` / the reducer identity; ``overflow``
    counts lanes that exhausted ``max_probes`` (plus whatever ``init``
    carried).  ``init=(keys, vals, overflow)`` merges into an existing table
    with the same probe sequence as ``containers.hashmap_insert``.
    """
    if reducer not in REDUCERS:
        raise ValueError(f"unknown reducer {reducer!r}; supported: {REDUCERS}")
    n = keys.shape[0]
    v = vals.shape[1]
    acc = _acc_dtype(vals.dtype)
    if init is None:
        ikeys = jnp.full((table_cap,), EMPTY_KEY, jnp.int32)
        ivals = jnp.full((table_cap, v), _identity(reducer, acc), acc)
        iovf = jnp.zeros((), jnp.int32)
    else:
        ikeys, ivals, iovf = init
        ikeys = ikeys.astype(jnp.int32)
        ivals = ivals.astype(acc)
    if n == 0:
        return ikeys, ivals, iovf.astype(jnp.int32)
    if interpret is None:
        interpret = pallas_interpret_default()
    if max_probes is None:
        max_probes = choose_probe_depth(n, table_cap)
    if block_n is None:
        _, block_n, _ = choose_table_cap(n, v, reducer, vals.dtype)
    bn = min(block_n, n)
    n_pad = -(-n // bn) * bn
    keys_p = jnp.pad(keys, (0, n_pad - n), constant_values=EMPTY_KEY)
    vals_p = jnp.pad(vals, ((0, n_pad - n), (0, 0)))

    kernel = functools.partial(
        _hash_kernel, cap=table_cap, bn=bn, probes=max_probes,
        reducer=reducer, acc_dtype=acc,
    )
    tkeys, tvals, ovf = pl.pallas_call(
        kernel,
        grid=(n_pad // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn, v), lambda i: (i, 0)),
            pl.BlockSpec((table_cap,), lambda i: (0,)),
            pl.BlockSpec((table_cap, v), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((table_cap,), lambda i: (0,)),
            pl.BlockSpec((table_cap, v), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((table_cap,), jnp.int32),
            jax.ShapeDtypeStruct((table_cap, v), acc),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ),
        interpret=interpret,
    )(keys_p, vals_p, ikeys, ivals, iovf.astype(jnp.int32)[None])
    return tkeys, tvals, ovf[0]


def hash_aggregate_lanes(
    n: int, table_cap: int, v: int, reducer: str = "sum", dtype=jnp.float32,
    block_n: int | None = None,
) -> tuple[int, int]:
    """(block_n, padded lane count) one ``hash_aggregate`` pass processes for
    ``n`` pairs — the static half of the hash-kernel occupancy accounting."""
    if block_n is None:
        _, block_n, _ = choose_table_cap(n, v, reducer, dtype)
    bn = min(block_n, max(n, 1))
    return bn, -(-max(n, 1) // bn) * bn
