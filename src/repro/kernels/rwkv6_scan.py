"""Pallas RWKV-6 wkv kernel: chunked recurrence with VMEM-resident state.

Grid = (B·H, S/L); the [K, V] state stays in VMEM scratch across chunks.
Per chunk: log-space cumulative decay (VPU), factored intra-chunk scores
(two MXU matmuls), diagonal bonus, and a decayed outer-product state update
(MXU) — same decomposition as ``kernels.ops.rwkv6_chunked``, which is the
oracle-checked reference for this kernel.

Training-path kernel (zero initial state); decode uses the chunked-jnp path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sout_ref, s_scr,
                  *, L, K, V, nch):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, :, 0].astype(jnp.float32)  # [L, K]
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)  # [L, V]
    w = w_ref[0, :, 0].astype(jnp.float32)  # [L, K]
    u = u_ref[0].astype(jnp.float32)  # [K]

    logw = jnp.maximum(jnp.log(jnp.maximum(w, 1e-30)), -88.0 / L)
    lam = jnp.cumsum(logw, axis=0)  # [L, K]
    lam_prev = lam - logw
    s = s_scr[...]  # [K, V]

    # inter-chunk + intra-chunk (strict lower triangle) + diagonal bonus
    r_dec = r * jnp.exp(lam_prev)  # [L, K]
    out = jax.lax.dot_general(
        r_dec, s, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [L, V]
    k_dec = k * jnp.exp(-lam)  # [L, K]
    scores = jax.lax.dot_general(
        r_dec, k_dec, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [L, L]
    li = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    scores = scores * (si < li).astype(jnp.float32)
    out += jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    diag = jnp.sum(r * u[None, :] * k, axis=1)  # [L]
    out += diag[:, None] * v
    y_ref[0, :, 0] = out.astype(y_ref.dtype)

    # state: S' = (Π w) ∘ S + Σ_s exp(λ_L − λ_s) k_s v_sᵀ
    lam_tot = lam[L - 1]  # [K]
    k_up = k * jnp.exp(lam_tot[None, :] - lam)  # [L, K]
    upd = jax.lax.dot_general(
        k_up, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [K, V]
    s_scr[...] = s * jnp.exp(lam_tot)[:, None] + upd

    @pl.when(ic == nch - 1)
    def _emit():
        sout_ref[0, 0] = s_scr[...].astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(
    r: jax.Array,  # [B, S, H, K]
    k: jax.Array,
    v: jax.Array,  # [B, S, H, V]
    w: jax.Array,  # [B, S, H, K]
    u: jax.Array,  # [H, K]
    *,
    init_state=None,
    chunk: int = 64,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    if init_state is not None:
        raise NotImplementedError("kernel covers the zero-init training path")
    B, S, H, K = r.shape
    V = v.shape[-1]
    L = min(chunk, S)
    S_pad = -(-S // L) * L
    pad = S_pad - S

    def padt(t, cval=0.0):
        return jnp.pad(
            t, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=cval
        )

    rp, kp, vp = padt(r), padt(k), padt(v)
    wp = padt(w, 1.0)
    nch = S_pad // L

    kernel = functools.partial(_rwkv6_kernel, L=L, K=K, V=V, nch=nch)
    spec_in = pl.BlockSpec((1, L, 1, K), lambda bh, ic, H=H: (bh // H, ic, bh % H, 0))
    spec_v = pl.BlockSpec((1, L, 1, V), lambda bh, ic, H=H: (bh // H, ic, bh % H, 0))
    y, sT = pl.pallas_call(
        kernel,
        grid=(B * H, nch),
        in_specs=[
            spec_in, spec_in, spec_v, spec_in,
            pl.BlockSpec((1, K), lambda bh, ic, H=H: (bh % H, 0)),
        ],
        out_specs=[
            spec_v,
            pl.BlockSpec((1, 1, K, V), lambda bh, ic, H=H: (bh // H, bh % H, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S_pad, H, V), v.dtype),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=[_vmem((K, V), jnp.float32)],
        interpret=interpret,
    )(rp, kp, vp, wp, u)
    return y[:, :S], sT


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
