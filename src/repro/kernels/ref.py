"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth: small, obviously-correct, O(n²)
where that is the clearest formulation.  Tests sweep shapes/dtypes and
``assert_allclose`` kernels (run under ``interpret=True`` on CPU) against
these.  They are NOT the implementations models use at scale — see
``kernels.ops`` for the dispatching wrappers and the chunked jnp paths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# ---------------------------------------------------------------------------
# segment_reduce — the eager-reduction combiner (paper §2.3.1/§2.3.3)
# ---------------------------------------------------------------------------


def segment_reduce_ref(ids: Array, vals: Array, num_segments: int) -> Array:
    """Sum ``vals`` rows into ``num_segments`` dense buckets; ids<0 dropped."""
    safe = jnp.where(ids >= 0, ids, num_segments)
    return jax.ops.segment_sum(vals, safe, num_segments=num_segments + 1)[
        :num_segments
    ]


# ---------------------------------------------------------------------------
# attention — full-materialisation oracle with every masking mode we support
# ---------------------------------------------------------------------------


def attention_ref(
    q: Array,  # [B, Hq, Sq, D]
    k: Array,  # [B, Hkv, Skv, D]
    v: Array,  # [B, Hkv, Skv, D]
    *,
    causal: bool = True,
    window: int | None = None,  # sliding window size (None = full)
    softcap: float = 0.0,  # gemma2-style logit soft-capping (0 = off)
    q_offset: int | None = None,  # absolute position of q[0] (decode: Skv-Sq)
    scale: float | None = None,
) -> Array:
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    rep = hq // hkv
    kk = jnp.repeat(k, rep, axis=1)
    vv = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    off = skv - sq if q_offset is None else q_offset
    qpos = jnp.arange(sq)[:, None] + off
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# kmeans_assign — fused assignment + per-cluster statistics
# ---------------------------------------------------------------------------


def kmeans_assign_ref(points: Array, centers: Array) -> tuple[Array, Array]:
    """Returns (assignments [N], stats [K, D+1]) — per-cluster Σx and count."""
    d2 = (
        jnp.sum(points**2, 1, keepdims=True)
        - 2.0 * points @ centers.T
        + jnp.sum(centers**2, 1)[None, :]
    )
    assign = jnp.argmin(d2, axis=1)
    k = centers.shape[0]
    onehot = jax.nn.one_hot(assign, k, dtype=points.dtype)  # [N, K]
    sums = onehot.T @ points  # [K, D]
    counts = jnp.sum(onehot, axis=0)[:, None]  # [K, 1]
    return assign, jnp.concatenate([sums, counts], axis=1)


# ---------------------------------------------------------------------------
# Mamba-2 SSD — naive per-step recurrence oracle
# ---------------------------------------------------------------------------


def ssd_ref(
    x: Array,  # [B, S, H, P]   (P = head dim)
    dt: Array,  # [B, S, H]      (softplus-activated step size)
    a: Array,  # [H]            (negative decay rate, A = -exp(a_log))
    b: Array,  # [B, S, G, N]   (input matrix, G groups broadcast over H)
    c: Array,  # [B, S, G, N]   (output matrix)
    *,
    init_state: Array | None = None,  # [B, H, P, N]
) -> tuple[Array, Array]:
    """y[t] = C_t · h_t,  h_t = exp(A·dt_t)·h_{t-1} + dt_t · B_t x_tᵀ."""
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    bb = jnp.repeat(b, rep, axis=2)  # [B, S, H, N]
    cc = jnp.repeat(c, rep, axis=2)
    decay = jnp.exp(a[None, None, :] * dt)  # [B, S, H]
    h0 = (
        jnp.zeros((B, H, P, N), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(h, inp):
        xt, dtt, dct, bt, ct = inp  # [B,H,P],[B,H],[B,H],[B,H,N],[B,H,N]
        dx = (dtt[..., None] * xt).astype(jnp.float32)  # [B,H,P]
        h = h * dct[..., None, None] + dx[..., :, None] * bt[..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, ct.astype(jnp.float32))
        return h, y

    xs = (
        x.transpose(1, 0, 2, 3),
        dt.transpose(1, 0, 2),
        decay.transpose(1, 0, 2),
        bb.transpose(1, 0, 2, 3),
        cc.transpose(1, 0, 2, 3),
    )
    hT, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), hT


# ---------------------------------------------------------------------------
# RWKV-6 — naive per-step recurrence oracle
# ---------------------------------------------------------------------------


def rwkv6_ref(
    r: Array,  # [B, S, H, K]   receptance
    k: Array,  # [B, S, H, K]   key
    v: Array,  # [B, S, H, V]   value
    w: Array,  # [B, S, H, K]   data-dependent decay, in (0, 1)
    u: Array,  # [H, K]         bonus for the current token
    *,
    init_state: Array | None = None,  # [B, H, K, V]
) -> tuple[Array, Array]:
    """out_t = r_t · (S_{t-1} + u ⊙ k_t v_tᵀ);  S_t = diag(w_t) S_{t-1} + k_t v_tᵀ."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    s0 = (
        jnp.zeros((B, H, K, V), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(s, inp):
        rt, kt, vt, wt = inp  # [B,H,K],[B,H,K],[B,H,V],[B,H,K]
        kv = kt.astype(jnp.float32)[..., :, None] * vt.astype(jnp.float32)[..., None, :]
        out = jnp.einsum(
            "bhk,bhkv->bhv", rt.astype(jnp.float32),
            s + u.astype(jnp.float32)[None, :, :, None] * kv,
        )
        s = s * wt.astype(jnp.float32)[..., :, None] + kv
        return s, out

    xs = (
        r.transpose(1, 0, 2, 3),
        k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        w.transpose(1, 0, 2, 3),
    )
    sT, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3).astype(v.dtype), sT
