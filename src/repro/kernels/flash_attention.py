"""Fused flash attention for TPU (Pallas), covering every attention variant in
the assigned architecture pool:

* causal / bidirectional
* GQA (kv-head broadcast by index-map, no materialised repeat)
* sliding window (mistral/gemma2 local layers) — out-of-window KV blocks are
  skipped as whole blocks (predicated), the in-window diagonal is masked
* logit soft-capping (gemma2)
* decode (Sq=1..8 with a long KV cache) — same kernel, bq = Sq

Streaming-softmax accumulation runs across the LAST grid axis (TPU grids are
sequential over trailing axes) with running (m, l, acc) in VMEM scratch.
BlockSpecs tile HBM→VMEM as (1, 1, bq, D) q-tiles against (1, 1, bk, D)
kv-tiles; with bq=bk=512 and D=128 the working set is
(512·128·4)·4 ≈ 1.0 MB + the 512×512 f32 logits tile ≈ 1 MB — comfortably
inside the ~16 MB VMEM budget, with the matmul dims MXU-aligned (≥128).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, causal, window, softcap, bq, bk, sq_true, skv_true, q_offset, nk,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level relevance: positions are absolute (q_offset for decode).
    q_start = iq * bq + q_offset
    q_end = q_start + bq - 1
    k_start = ik * bk
    k_end = k_start + bk - 1

    relevant = k_start < skv_true
    if causal:
        relevant &= k_start <= q_end
    if window is not None:
        relevant &= k_end > q_start - window

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)  # [bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bk]
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < skv_true
        mask &= qpos < sq_true + q_offset
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # [bq, 1]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "scale", "block_q", "block_k",
        "q_offset", "interpret",
    ),
)
def flash_attention(
    q: jax.Array,  # [B, Hq, Sq, D]
    k: jax.Array,  # [B, Hkv, Skv, D]
    v: jax.Array,  # [B, Hkv, Skv, D]
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float = 0.0,
    scale: float | None = None,
    q_offset: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    rep = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    off = (skv - sq) if q_offset is None else q_offset

    bq = min(block_q, sq)
    bk = min(block_k, skv)
    sq_pad = -(-sq // bq) * bq
    skv_pad = -(-skv // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0)))
    nq, nk = sq_pad // bq, skv_pad // bk

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        bq=bq, bk=bk, sq_true=sq, skv_true=skv, q_offset=off, nk=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, iq, ik, rep=rep: (b_, h // rep, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, iq, ik, rep=rep: (b_, h // rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_pad, d), q.dtype),
        scratch_shapes=[
            _vmem((bq, 1), jnp.float32),
            _vmem((bq, 1), jnp.float32),
            _vmem((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :sq]


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
