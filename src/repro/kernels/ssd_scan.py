"""Pallas Mamba-2 SSD kernel: chunked scan with VMEM-resident state.

Grid = (B·H, S/L): the chunk axis is the trailing (sequential) grid dim, so
the [P, N] state lives in VMEM scratch across the whole sequence — HBM sees
each input exactly once and the state never spills.  Per chunk the work is
three MXU matmuls (C·Bᵀ, M·X, Xᵀ·B) over an (L, L) tile plus VPU cumsums —
the TPU-native formulation of the SSD block decomposition.

Training-path kernel (zero initial state); the decode path (init_state
carry) uses the chunked-jnp formulation in ``kernels.ops``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, state_scr,
    *, L, P, N, nch,
):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0].astype(jnp.float32)  # [L, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # [L]
    a = a_ref[0]  # scalar
    b = b_ref[0, :, 0].astype(jnp.float32)  # [L, N]
    c = c_ref[0, :, 0].astype(jnp.float32)  # [L, N]

    adt = a * dt  # [L] (negative)
    cum = jnp.cumsum(adt)  # Δ_l
    total = cum[L - 1]

    # intra-chunk: M[l,s] = exp(Δ_l − Δ_s)·(C_l·B_s), s ≤ l
    cb = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [L, L]
    dec = jnp.exp(jnp.minimum(cum[:, None] - cum[None, :], 0.0))
    li = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    m = cb * dec * (si <= li).astype(jnp.float32)
    dx = dt[:, None] * x  # [L, P]
    y = jax.lax.dot_general(
        m, dx, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # inter-chunk: exp(Δ_l)·C_l·h_prevᵀ
    h = state_scr[...]  # [P, N]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    # state update: h' = exp(total)·h + Σ_s exp(total − Δ_s)·dx_s ⊗ B_s
    sdec = jnp.exp(total - cum)  # [L]
    upd = jax.lax.dot_general(
        dx * sdec[:, None], b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [P, N]
    state_scr[...] = h * jnp.exp(total) + upd

    @pl.when(ic == nch - 1)
    def _emit_state():
        hout_ref[0, 0] = state_scr[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,  # [B, S, H, P]
    dt: jax.Array,  # [B, S, H]
    a: jax.Array,  # [H]
    b: jax.Array,  # [B, S, G, N]
    c: jax.Array,  # [B, S, G, N]
    *,
    init_state=None,
    chunk: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    if init_state is not None:
        raise NotImplementedError("kernel covers the zero-init training path")
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    L = min(chunk, S)
    S_pad = -(-S // L) * L
    pad = S_pad - S
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    bp = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
    cp = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nch = S_pad // L

    kernel = functools.partial(_ssd_kernel, L=L, P=P, N=N, nch=nch)
    y, hT = pl.pallas_call(
        kernel,
        grid=(B * H, nch),
        in_specs=[
            pl.BlockSpec((1, L, 1, P), lambda bh, ic, H=H: (bh // H, ic, bh % H, 0)),
            pl.BlockSpec((1, L, 1), lambda bh, ic, H=H: (bh // H, ic, bh % H)),
            pl.BlockSpec((1,), lambda bh, ic, H=H: (bh % H,)),
            pl.BlockSpec(
                (1, L, 1, N), lambda bh, ic, H=H, rep=rep: (bh // H, ic, (bh % H) // rep, 0)
            ),
            pl.BlockSpec(
                (1, L, 1, N), lambda bh, ic, H=H, rep=rep: (bh // H, ic, (bh % H) // rep, 0)
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, L, 1, P), lambda bh, ic, H=H: (bh // H, ic, bh % H, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bh, ic, H=H: (bh // H, bh % H, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S_pad, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[_vmem((P, N), jnp.float32)],
        interpret=interpret,
    )(xp, dtp, a.astype(jnp.float32), bp, cp)
    return y[:, :S], hT


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
