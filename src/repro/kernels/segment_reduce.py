"""Pallas segment-reduce: the eager-reduction combiner as a TPU kernel.

Reduces a stream of (id, value-row) pairs into a dense ``[K, V]`` accumulator
that lives in VMEM for the whole pass — the TPU shape of the paper's
*thread-local cache for a small fixed key range* (§2.3.3), generalized from
``sum`` to the full ``Reducer`` monoid surface (sum / min / max / prod).

Two in-kernel strategies, chosen statically per (reducer, dtype):

* **one-hot matmul** (float sum): the scatter-add is expressed as a one-hot
  matmul so the MXU does the reduction:

      onehot[bn, K] = (ids[:, None] == iota_K)   →   acc += onehotᵀ @ vals

* **select-scatter** (min / max / prod, and integer sum, which must stay
  exact): broadcast the block against the key axis, select each lane into
  its key's row (identity elsewhere), and fold the block axis on the VPU:

      masked[bn, K, V] = where(onehot, vals, identity)  →  acc = op(acc, fold(masked))

Grid iterates over pair-blocks (sequential on TPU); the output BlockSpec maps
every step to the same ``[K, V]`` tile, so the accumulator never leaves VMEM
between steps.  Negative ids and ids ``>= K`` never match the iota and are
dropped (masked lanes).  ``choose_block_n`` autotunes the block size against
a VMEM budget per strategy; ``interpret=None`` resolves via
``pallas_interpret_default()`` (interpret off-TPU, overridable with the
``BLAZE_PALLAS_INTERPRET`` env var) so CPU CI exercises the same kernel.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

REDUCERS = ("sum", "prod", "min", "max")

# The VMEM-budget/candidate-scoring arithmetic lives in repro.core.cost
# (shared with the hash-combine tuner and the measured autotuner).  The
# delegates below import it lazily at call time: a module-level import would
# re-enter repro.core.__init__ while this module is itself being imported by
# the containers → reducers → kernels chain.


def _acc_dtype(dtype):
    """Accumulator dtype: f32 for floats (bf16 upcast), i32 for ints."""
    from repro.core.cost import acc_dtype

    return acc_dtype(dtype)


def _use_matmul(reducer: str, acc_dtype) -> bool:
    from repro.core.cost import use_matmul

    return use_matmul(reducer, acc_dtype)


def choose_block_n(
    n: int, num_segments: int, v: int, reducer: str = "sum",
    dtype=jnp.float32, vmem_budget: int | None = None,
) -> int:
    """Largest power-of-two block (8..2048) whose per-step working set fits
    — the pick over ``cost.segment_block_candidates`` (shared grid)."""
    from repro.core import cost

    return cost.choose_block_n(
        n, num_segments, v, reducer, dtype,
        cost.VMEM_BUDGET if vmem_budget is None else vmem_budget,
    )


def pallas_interpret_default() -> bool:
    """Run kernels in interpret mode?  True off-TPU; ``BLAZE_PALLAS_INTERPRET``
    (``"1"``/``"0"``) forces either way — the CI knob for the CPU kernel job."""
    env = os.environ.get("BLAZE_PALLAS_INTERPRET")
    if env is not None and env != "":
        return env not in ("0", "false", "no")
    return jax.default_backend() != "tpu"


def _identity(reducer: str, dtype):
    dtype = jnp.dtype(dtype)
    if reducer == "sum":
        return jnp.asarray(0, dtype)
    if reducer == "prod":
        return jnp.asarray(1, dtype)
    lo, hi = (
        (-jnp.inf, jnp.inf)
        if jnp.issubdtype(dtype, jnp.floating)
        else (jnp.iinfo(dtype).min, jnp.iinfo(dtype).max)
    )
    return jnp.asarray(hi if reducer == "min" else lo, dtype)


def _combine(reducer: str):
    return {
        "sum": jnp.add,
        "prod": jnp.multiply,
        "min": jnp.minimum,
        "max": jnp.maximum,
    }[reducer]


def _fold(reducer: str):
    return {
        "sum": jnp.sum,
        "prod": jnp.prod,
        "min": jnp.min,
        "max": jnp.max,
    }[reducer]


def onehot_accumulate(ids, vals, k: int, *, valid=None, acc_dtype=jnp.float32):
    """One-hot-matmul scatter-add: ``[bn]`` ids × ``[bn, V]`` vals → ``[K, V]``.

    The shared eager-reduction accumulator pattern (MXU path) used by both the
    segment-reduce kernel and the fused k-means assignment kernel.  Lanes with
    ``ids`` outside ``[0, k)`` (or ``valid == False``) contribute nothing.
    """
    bn = ids.shape[0]
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (bn, k), 1)
    onehot = ids[:, None] == iota_k  # [bn, K]
    if valid is not None:
        onehot &= valid[:, None]
    return jax.lax.dot_general(
        onehot.astype(acc_dtype), vals.astype(acc_dtype),
        (((0,), (0,)), ((), ())),
        preferred_element_type=acc_dtype,
    )  # [K, V]


def _segment_reduce_kernel(
    ids_ref, vals_ref, out_ref, *, k, bn, reducer, acc_dtype
):
    i = pl.program_id(0)
    ident = _identity(reducer, acc_dtype)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, ident)

    ids = ids_ref[...]  # [bn]
    vals = vals_ref[...].astype(acc_dtype)  # [bn, V]
    if _use_matmul(reducer, acc_dtype):
        # Zero the values of dropped lanes, not just their one-hot rows: an
        # all-zero onehot column still contracts 0·NaN = NaN into every key.
        in_range = (ids >= 0) & (ids < k)
        vals = jnp.where(in_range[:, None], vals, 0)
        out_ref[...] += onehot_accumulate(ids, vals, k, acc_dtype=acc_dtype)
    else:
        iota_k = jax.lax.broadcasted_iota(jnp.int32, (bn, k), 1)
        onehot = ids[:, None] == iota_k  # [bn, K]
        masked = jnp.where(onehot[:, :, None], vals[:, None, :], ident)
        out_ref[...] = _combine(reducer)(
            out_ref[...], _fold(reducer)(masked, axis=0)
        )


@functools.partial(
    jax.jit, static_argnames=("num_segments", "reducer", "block_n", "interpret")
)
def segment_reduce(
    ids: jax.Array,  # [N] int32; ids outside [0, num_segments) are dropped
    vals: jax.Array,  # [N, V]
    num_segments: int,
    *,
    reducer: str = "sum",
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Dense ``[K, V]`` reduce-by-key; returns the accumulator dtype
    (f32 for float inputs, i32 for ints)."""
    if reducer not in REDUCERS:
        raise ValueError(f"unknown reducer {reducer!r}; supported: {REDUCERS}")
    n, v = vals.shape
    acc = _acc_dtype(vals.dtype)
    if n == 0:  # empty pair stream → the identity accumulator
        return jnp.full((num_segments, v), _identity(reducer, acc), acc)
    if interpret is None:
        interpret = pallas_interpret_default()
    if block_n is None:
        block_n = choose_block_n(n, num_segments, v, reducer, vals.dtype)
    bn = min(block_n, n)
    n_pad = -(-n // bn) * bn
    ids_p = jnp.pad(ids, (0, n_pad - n), constant_values=-1)
    vals_p = jnp.pad(vals, ((0, n_pad - n), (0, 0)))

    kernel = functools.partial(
        _segment_reduce_kernel, k=num_segments, bn=bn, reducer=reducer,
        acc_dtype=acc,
    )
    return pl.pallas_call(
        kernel,
        grid=(n_pad // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn, v), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_segments, v), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, v), acc),
        interpret=interpret,
    )(ids_p, vals_p)


def segment_reduce_lanes(n: int, num_segments: int, v: int,
                         reducer: str = "sum", dtype=jnp.float32,
                         block_n: int | None = None) -> tuple[int, int]:
    """(block_n, padded lane count) the kernel will process for ``n`` pairs —
    the static half of the occupancy accounting in ``MapReduceStats``."""
    if block_n is None:
        block_n = choose_block_n(n, num_segments, v, reducer, dtype)
    bn = min(block_n, max(n, 1))
    return bn, -(-max(n, 1) // bn) * bn
