"""Pallas segment-reduce: the eager-reduction combiner as a TPU kernel.

Reduces a stream of (id, value-row) pairs into a dense ``[K, V]`` accumulator
that lives in VMEM for the whole pass — the TPU shape of the paper's
*thread-local cache for a small fixed key range* (§2.3.3).  The scatter-add is
expressed as a one-hot matmul so the MXU does the reduction:

    onehot[bn, K] = (ids[:, None] == iota_K)   →   acc += onehotᵀ @ vals

Grid iterates over pair-blocks (sequential on TPU); the output BlockSpec maps
every step to the same ``[K, V]`` tile, so the accumulator never leaves VMEM
between steps.  Negative ids are dropped (masked lanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _segment_reduce_kernel(ids_ref, vals_ref, out_ref, *, k, bn):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]  # [bn]
    vals = vals_ref[...].astype(jnp.float32)  # [bn, V]
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (bn, k), 1)
    onehot = (ids[:, None] == iota_k).astype(jnp.float32)  # [bn, K]
    partial = jax.lax.dot_general(
        onehot, vals, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [K, V]
    out_ref[...] += partial.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("num_segments", "block_n", "interpret")
)
def segment_reduce(
    ids: jax.Array,  # [N] int32, <0 = dropped
    vals: jax.Array,  # [N, V]
    num_segments: int,
    *,
    block_n: int = 1024,
    interpret: bool = True,
) -> jax.Array:
    n, v = vals.shape
    bn = min(block_n, n)
    n_pad = -(-n // bn) * bn
    ids_p = jnp.pad(ids, (0, n_pad - n), constant_values=-1)
    vals_p = jnp.pad(vals, ((0, n_pad - n), (0, 0)))

    kernel = functools.partial(_segment_reduce_kernel, k=num_segments, bn=bn)
    return pl.pallas_call(
        kernel,
        grid=(n_pad // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn, v), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_segments, v), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, v), jnp.float32),
        interpret=interpret,
    )(ids_p, vals_p)
