"""BlazeSession: compiled-executable reuse across iterations, cache-miss
triggers on config changes, and the JAX compat shim on the installed JAX."""
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BlazeSession,
    DistRange,
    data_mesh,
    distribute,
    get_default_session,
    make_dist_hashmap,
    map_reduce,
)
from repro.core.algorithms import (
    gmm_em,
    gmm_em_reference,
    kmeans,
    kmeans_reference,
    pagerank,
    pagerank_reference,
)
from repro.data.synthetic import cluster_points, rmat_edges

import pytest


def _sq_mapper(v, emit):
    emit(v % 4, v * v)


def _first_col_mapper(i, x, emit):
    emit(i % 4, x[0])


def _tok_mapper(i, toks, emit):
    emit(toks, 1, mask=toks >= 0)


# -- compat shim ---------------------------------------------------------------


def test_compat_imports_on_installed_jax():
    # The seed failed `import repro.core` on JAX 0.4.x; the shim must resolve.
    import repro.core  # noqa: F401
    from repro.compat import (  # noqa: F401
        AxisType,
        get_abstract_mesh,
        make_mesh,
        set_mesh,
        shard_map,
    )

    assert callable(shard_map)


def test_compat_shard_map_accepts_either_check_flag():
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    mesh = data_mesh()
    x = jnp.arange(8, dtype=jnp.float32)
    for kw in ({"check_vma": False}, {"check_rep": False}, {}):
        f = shard_map(
            lambda v: v * 2, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
            **kw,
        )
        np.testing.assert_allclose(np.asarray(f(x)), np.arange(8.0) * 2)


def test_compat_make_mesh_and_set_mesh():
    from repro.compat import AxisType, make_mesh, set_mesh

    mesh = make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    assert mesh.axis_names == ("data",)
    with set_mesh(mesh):
        pass  # context form works on every JAX


# -- executable reuse ----------------------------------------------------------


def test_session_reuses_executable_across_iterations():
    sess = BlazeSession()
    for i in range(10):
        out, st = sess.map_reduce(
            DistRange(0, 64, 1), _sq_mapper, "sum", jnp.zeros((4,), jnp.int32),
            return_stats=True,
        )
        assert st.compiles == (1 if i == 0 else 0)
        assert st.cache_hits == (0 if i == 0 else 1)
    assert sess.stats.calls == 10
    assert sess.stats.compiles == 1
    assert sess.stats.cache_hits == 9
    info = sess.cache_info()
    assert info["entries"] == 1 and info["hit_rate"] == 0.9


def test_cache_miss_on_engine_wire_and_shape_change():
    sess = BlazeSession()
    pts = distribute(np.random.RandomState(0).randn(64, 2).astype(np.float32))
    t4 = jnp.zeros((4,), jnp.float32)
    sess.map_reduce(pts, _first_col_mapper, "sum", t4)  # compile 1
    sess.map_reduce(pts, _first_col_mapper, "sum", t4)  # hit
    sess.map_reduce(pts, _first_col_mapper, "sum", t4, engine="naive")  # 2
    sess.map_reduce(pts, _first_col_mapper, "sum", t4, wire="bf16")  # 3
    sess.map_reduce(  # 4: target shape change
        pts, _first_col_mapper, "sum", jnp.zeros((8,), jnp.float32)
    )
    assert sess.stats.compiles == 4
    assert sess.stats.cache_hits == 1


def test_sessions_have_isolated_caches():
    a, b = BlazeSession(), BlazeSession()
    t = jnp.zeros((4,), jnp.int32)
    a.map_reduce(DistRange(0, 32, 1), _sq_mapper, "sum", t)
    b.map_reduce(DistRange(0, 32, 1), _sq_mapper, "sum", t)
    assert a.stats.compiles == 1 and b.stats.compiles == 1
    assert a.stats.cache_hits == 0 and b.stats.cache_hits == 0


def test_hash_target_executable_reuse():
    sess = BlazeSession()
    lines = np.random.RandomState(0).randint(0, 50, (64, 8)).astype(np.int32)
    lv = distribute(lines, sess.mesh)
    for i in range(3):
        hm = make_dist_hashmap(sess.mesh, 256, (), jnp.int32, "sum")
        hm, st = sess.map_reduce(
            lv, _tok_mapper, "sum", hm, return_stats=True
        )
        assert st.compiles == (1 if i == 0 else 0)
    assert sess.stats.compiles == 1 and sess.stats.cache_hits == 2
    import collections

    ref = collections.Counter(lines.reshape(-1).tolist())
    assert {k: int(v) for k, v in hm.to_dict().items()} == dict(ref)


def test_default_session_backs_free_map_reduce():
    base = get_default_session().stats.compiles

    def m(v, emit):  # fresh function object → fresh cache key, isolated test
        emit(0, v)

    _, st1 = map_reduce(
        DistRange(0, 32, 1), m, "sum", jnp.zeros((1,), jnp.int32),
        return_stats=True,
    )
    _, st2 = map_reduce(
        DistRange(0, 32, 1), m, "sum", jnp.zeros((1,), jnp.int32),
        return_stats=True,
    )
    assert st1.compiles == 1 and st2.compiles == 0 and st2.cache_hits == 1
    assert get_default_session().stats.compiles == base + 1


# -- iterative drivers: N iterations, 1 compile per (engine, shape) config ----


def test_pagerank_10_iters_one_compile_per_config():
    sess = BlazeSession()
    edges = rmat_edges(6, 8, seed=3)  # 64 nodes
    res = pagerank(edges, 64, tol=0.0, max_iters=10, session=sess)
    assert res.iterations == 10
    # Exactly 3 configs per iteration (sink sum, contribution sum, delta max):
    # one compile each, every later iteration a cache hit.
    assert res.compiles == 3
    assert sess.stats.calls == 30
    assert sess.stats.cache_hits == 27
    ref = pagerank_reference(edges, 64, tol=0.0, max_iters=10)
    assert float(np.abs(res.scores - ref).max() / ref.max()) < 1e-4


def test_kmeans_10_iters_one_compile_per_config():
    pts, _ = cluster_points(2000, 3, 4, seed=0)
    init = pts[:4].copy()
    sess = BlazeSession()
    res = kmeans(pts, 4, init_centers=init, tol=0.0, max_iters=10, session=sess)
    assert res.iterations == 10
    # 2 configs: the assignment step (10×) and the final inertia pass (1×).
    assert res.compiles == 2
    assert sess.stats.calls == 11
    assert sess.stats.cache_hits == 9
    ref_centers, _ = kmeans_reference(pts, init, tol=0.0, max_iters=10)
    assert float(np.abs(res.centers - ref_centers).max()) < 1e-2


def test_gmm_one_compile_per_config():
    pts, _ = cluster_points(600, 2, 3, seed=1)
    sess = BlazeSession()
    res = gmm_em(pts, 3, init_mu=pts[:3].copy(), tol=0.0, max_iters=5,
                 session=sess)
    assert res.iterations == 5
    # 4 MapReduce configs: log-likelihood, N_k, Σwx, Σw(x−μ)(x−μ)ᵀ.
    assert res.compiles == 4
    assert sess.stats.calls == 20
    assert sess.stats.cache_hits == 16


# -- engine="auto" policy + pallas in the compile cache ------------------------


def _dyn_key_mapper(i, x, emit):
    # key comes from data → dynamic (no static-key fast path)
    emit(x[0].astype(jnp.int32), x[1])


def _pts_rows(n=64, kmod=8, seed=0):
    rng = np.random.RandomState(seed)
    rows = rng.randn(n, 2).astype(np.float32)
    rows[:, 0] = rng.randint(0, kmod, n)
    return rows


def test_auto_picks_pallas_for_small_dense_key_range():
    from repro.core.session import PALLAS_AUTO_MAX_KEYS

    sess = BlazeSession()
    pts = distribute(_pts_rows())
    _, st = sess.map_reduce(
        pts, _dyn_key_mapper, "sum", jnp.zeros((8,), jnp.float32),
        engine="auto", return_stats=True,
    )
    assert st.engine == "pallas"
    # beyond the VMEM-resident bound → eager
    _, st = sess.map_reduce(
        pts, _dyn_key_mapper, "sum",
        jnp.zeros((PALLAS_AUTO_MAX_KEYS + 1,), jnp.float32),
        engine="auto", return_stats=True,
    )
    assert st.engine == "eager"


def test_auto_picks_hash_kernel_and_falls_back_for_custom_reducers():
    from repro.core import custom_reducer, make_dist_hashmap
    from repro.core.session import resolve_engine
    from repro.core.reducers import get_reducer

    sess = BlazeSession()
    pts = distribute(_pts_rows())
    # auto on a VMEM-sized hash target → the hash-aggregation kernel
    hm = make_dist_hashmap(sess.mesh, 128, (), jnp.float32, "sum")
    _, st = sess.map_reduce(
        pts, _dyn_key_mapper, "sum", hm, engine="auto", return_stats=True
    )
    assert st.engine == "pallas"
    # explicit pallas on a hash target runs the kernel too (no fallback)
    hm2 = make_dist_hashmap(sess.mesh, 128, (), jnp.float32, "sum")
    _, st = sess.map_reduce(
        pts, _dyn_key_mapper, "sum", hm2, engine="pallas", return_stats=True
    )
    assert st.engine == "pallas"
    # ... but an over-VMEM-sized table resolves auto to eager
    big = make_dist_hashmap(sess.mesh, 8192, (), jnp.float32, "sum")
    assert resolve_engine("auto", big, get_reducer("sum")) == "eager"
    # custom reducer has no pallas_segment/pallas_hash impl → eager
    maxish = custom_reducer(
        "maxish", jnp.maximum, lambda dt: jnp.asarray(-jnp.inf, dt)
    )
    _, st = sess.map_reduce(
        pts, _dyn_key_mapper, maxish,
        jnp.full((8,), -jnp.inf, jnp.float32),
        engine="auto", return_stats=True,
    )
    assert st.engine == "eager"
    # ... and explicit pallas with a custom reducer also reports the eager
    # plan that actually runs (and reuses its executable, not a duplicate)
    _, st = sess.map_reduce(
        pts, _dyn_key_mapper, maxish,
        jnp.full((8,), -jnp.inf, jnp.float32),
        engine="pallas", return_stats=True,
    )
    assert st.engine == "eager"
    assert st.compiles == 0 and st.cache_hits == 1


def test_unknown_engine_rejected():
    import pytest

    sess = BlazeSession()
    with pytest.raises(ValueError, match="unknown engine"):
        sess.map_reduce(
            DistRange(0, 8, 1), _sq_mapper, "sum", jnp.zeros((4,), jnp.int32),
            engine="spark",
        )


def test_compile_cache_key_includes_engine_choice():
    sess = BlazeSession()
    pts = distribute(_pts_rows())
    t8 = jnp.zeros((8,), jnp.float32)
    sess.map_reduce(pts, _dyn_key_mapper, "sum", t8, engine="eager",
                    return_stats=True)  # compile 1
    sess.map_reduce(pts, _dyn_key_mapper, "sum", t8, engine="pallas",
                    return_stats=True)  # compile 2
    # auto resolves to pallas for K=8 → must HIT the pallas entry, not compile
    _, st = sess.map_reduce(
        pts, _dyn_key_mapper, "sum", t8, engine="auto", return_stats=True
    )
    assert st.engine == "pallas"
    assert st.compiles == 0 and st.cache_hits == 1
    assert sess.stats.compiles == 2
    assert sess.cache_info()["entries"] == 2


def test_pallas_compiles_stay_flat_across_10_iterations():
    sess = BlazeSession()
    pts = distribute(_pts_rows())
    t8 = jnp.zeros((8,), jnp.float32)
    for i in range(10):
        _, st = sess.map_reduce(
            pts, _dyn_key_mapper, "sum", t8, engine="pallas",
            return_stats=True,
        )
        assert st.compiles == (1 if i == 0 else 0)
        assert st.cache_hits == (0 if i == 0 else 1)
        stf = st.finalize()
        assert stf.kernel_block_n is not None
        assert 0.0 < stf.kernel_occupancy <= 1.0
    assert sess.stats.compiles == 1
    assert sess.stats.cache_hits == 9


def test_pagerank_pallas_10_iters_one_compile_per_config():
    """Mirror of the eager PageRank count: pallas keys the same cache."""
    sess = BlazeSession()
    edges = rmat_edges(6, 8, seed=3)  # 64 nodes
    res = pagerank(edges, 64, tol=0.0, max_iters=10, engine="pallas",
                   session=sess)
    assert res.iterations == 10
    assert res.compiles == 3
    assert sess.stats.calls == 30
    assert sess.stats.cache_hits == 27
    ref = pagerank_reference(edges, 64, tol=0.0, max_iters=10)
    assert float(np.abs(res.scores - ref).max() / ref.max()) < 1e-4


def test_kmeans_pallas_matches_eager_and_reference():
    pts, _ = cluster_points(2000, 3, 4, seed=0)
    init = pts[:4].copy()
    sess = BlazeSession()
    res = kmeans(pts, 4, init_centers=init, tol=0.0, max_iters=10,
                 engine="pallas", session=sess)
    assert res.iterations == 10
    assert res.compiles == 2
    ref_centers, _ = kmeans_reference(pts, init, tol=0.0, max_iters=10)
    assert float(np.abs(res.centers - ref_centers).max()) < 1e-2


# -- fused programs: N iterations = 1 program compile, ≤ ⌈N/unroll⌉ dispatches -

PROGRAM_ENGINES = ("eager", "pallas", "naive")


@pytest.mark.parametrize("engine", PROGRAM_ENGINES)
def test_pagerank_program_10_iters_one_compile_two_dispatches(engine):
    sess = BlazeSession()
    edges = rmat_edges(6, 8, seed=3)  # 64 nodes
    res = pagerank(edges, 64, tol=0.0, max_iters=10, engine=engine,
                   session=sess, mode="program", unroll=5)
    assert res.iterations == 10
    # The whole 3-op iteration is ONE executable: a single program compile,
    # and 10 iterations ship as ⌈10/5⌉ = 2 dispatches / 2 host syncs —
    # versus 30 dispatches + 10 syncs for the per-op loop.
    assert res.program_compiles == 1
    assert res.dispatches == 2
    assert res.host_syncs == 2
    assert res.compiles == 0  # no per-op executables were built
    assert sess.stats.calls == 0
    assert sess.stats.program_compiles == 1
    assert sess.stats.program_dispatches == 2
    ref = pagerank_reference(edges, 64, tol=0.0, max_iters=10)
    assert float(np.abs(res.scores - ref).max() / ref.max()) < 1e-4


@pytest.mark.parametrize("engine", PROGRAM_ENGINES)
def test_kmeans_program_10_iters_one_compile_two_dispatches(engine):
    pts, _ = cluster_points(2000, 3, 4, seed=0)
    init = pts[:4].copy()
    sess = BlazeSession()
    res = kmeans(pts, 4, init_centers=init, tol=0.0, max_iters=10,
                 engine=engine, session=sess, mode="program", unroll=5)
    assert res.iterations == 10
    assert res.program_compiles == 1
    # ⌈10/5⌉ = 2 fused-loop dispatches + the final inertia probe, which is
    # one more dispatch of the SAME fused executable (the assignment pass
    # carries the inertia since the plan refactor) — no per-op executable
    # is ever built, and the probe's host materialisation is counted.
    assert res.dispatches == 3
    assert sess.stats.program_dispatches == 3
    assert res.host_syncs == 3
    assert res.compiles == 0
    if engine != "naive":  # naive's wide shuffle is 3 gathers, not one psum
        assert res.collectives_per_iter == 1  # one [K, d+2] psum per iter
    ref_centers, _ = kmeans_reference(pts, init, tol=0.0, max_iters=10)
    assert float(np.abs(res.centers - ref_centers).max()) < 1e-2
    # the probe makes program-mode inertia exact w.r.t. the final centres
    per_op = kmeans(pts, 4, init_centers=init, tol=0.0, max_iters=10,
                    engine=engine, session=BlazeSession())
    assert abs(res.inertia - per_op.inertia) <= 1e-4 * abs(per_op.inertia)


@pytest.mark.parametrize("engine", PROGRAM_ENGINES)
def test_gmm_program_10_iters_one_compile_two_dispatches(engine):
    pts, _ = cluster_points(600, 2, 3, seed=1)
    init = pts[:3].copy()
    sess = BlazeSession()
    res = gmm_em(pts, 3, init_mu=init, tol=0.0, max_iters=10, engine=engine,
                 session=sess, mode="program", unroll=5)
    assert res.iterations == 10
    assert res.program_compiles == 1
    assert res.dispatches == 2
    assert res.host_syncs == 2
    assert res.compiles == 0
    ra, rm, rs, rll, _ = gmm_em_reference(pts, 3, init, tol=0.0, max_iters=10)
    assert float(np.abs(res.mu - rm).max()) < 1e-2
    assert float(np.abs(res.alpha - ra).max()) < 1e-3
    assert abs(res.log_likelihood - rll) / abs(rll) < 1e-3


def test_program_unroll_extremes_match_per_op_counts():
    """unroll=1 → one dispatch+sync per iteration (but still 1 compile);
    unroll=10 → one dispatch+sync total; per-op → 30 dispatches, 10 syncs."""
    edges = rmat_edges(6, 8, seed=3)
    ref = pagerank_reference(edges, 64, tol=0.0, max_iters=10)

    for unroll, want_disp in ((1, 10), (10, 1), (4, 3)):
        sess = BlazeSession()
        res = pagerank(edges, 64, tol=0.0, max_iters=10, session=sess,
                       mode="program", unroll=unroll)
        assert res.program_compiles == 1, unroll
        assert res.dispatches == want_disp, unroll
        assert res.host_syncs == want_disp, unroll
        assert float(np.abs(res.scores - ref).max() / ref.max()) < 1e-4

    sess = BlazeSession()
    res = pagerank(edges, 64, tol=0.0, max_iters=10, session=sess)
    assert res.dispatches == 30  # 3 ops × 10 iterations
    assert res.host_syncs == 10  # one float(delta) per iteration
    assert res.program_compiles == 0


def test_program_int8_wire_pagerank_matches_reference():
    """wire="int8" inside a fused program carries error-feedback residuals
    (quantize_with_feedback) across the device-resident iterations."""
    sess = BlazeSession()
    edges = rmat_edges(6, 8, seed=5)
    res = pagerank(edges, 64, tol=0.0, max_iters=10, wire="int8",
                   session=sess, mode="program", unroll=5)
    ref = pagerank_reference(edges, 64, tol=0.0, max_iters=10)
    assert res.program_compiles == 1 and res.dispatches == 2
    assert float(np.abs(res.scores - ref).max() / ref.max()) < 2e-2


def test_program_convergence_stops_early_on_block_boundary():
    sess = BlazeSession()
    edges = rmat_edges(6, 8, seed=3)
    res = pagerank(edges, 64, tol=1e-3, max_iters=100, session=sess,
                   mode="program", unroll=4)
    assert res.converged
    assert res.iterations % 4 == 0  # host test runs only every `unroll` steps
    assert res.dispatches == res.iterations // 4
    per_op = pagerank(edges, 64, tol=1e-3, max_iters=100)
    # fused loop may overshoot convergence by < one block, never undershoot
    assert per_op.iterations <= res.iterations < per_op.iterations + 4
