"""Sharding-policy unit tests: every rule produces divisibility-valid specs
for every architecture, on both production mesh shapes (abstract — no 512
devices needed: we validate against mesh axis sizes directly)."""
import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_arch, list_archs
from repro.distributed import sharding as SH
from repro.models import model as M


@dataclasses.dataclass(frozen=True)
class FakeMesh:
    shape: dict
    axis_names: tuple


def _mi(multi_pod: bool) -> SH.MeshInfo:
    if multi_pod:
        mesh = FakeMesh({"pod": 2, "data": 16, "model": 16}, ("pod", "data", "model"))
        return SH.MeshInfo(mesh=mesh, fsdp=("pod", "data"))
    mesh = FakeMesh({"data": 16, "model": 16}, ("data", "model"))
    return SH.MeshInfo(mesh=mesh, fsdp=("data",))


def _axis_size(mi, ax):
    if ax is None:
        return 1
    axes = (ax,) if isinstance(ax, str) else ax
    return int(np.prod([mi.mesh.shape[a] for a in axes]))


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible(arch, multi_pod):
    cfg = get_arch(arch)
    mi = _mi(multi_pod)
    shapes = jax.eval_shape(lambda k: M.init(k, cfg), jax.random.PRNGKey(0))
    specs = SH.param_pspecs(cfg, shapes, mi)
    leaves_s, _ = jax.tree_util.tree_flatten(shapes)
    leaves_p = jax.tree_util.tree_flatten(specs, is_leaf=lambda x: isinstance(x, P))[0]
    assert len(leaves_s) == len(leaves_p)
    n_sharded = 0
    for arr, spec in zip(leaves_s, leaves_p):
        assert len(spec) <= len(arr.shape)
        for dim, ax in zip(arr.shape, tuple(spec)):
            size = _axis_size(mi, ax)
            assert dim % size == 0, (arch, arr.shape, spec)
            n_sharded += size > 1
    assert n_sharded > 0, "policy sharded nothing"


@pytest.mark.parametrize("arch", list_archs())
def test_big_params_are_sharded(arch):
    """Every ≥8M-element tensor must be sharded on at least one axis."""
    cfg = get_arch(arch)
    mi = _mi(False)
    shapes = jax.eval_shape(lambda k: M.init(k, cfg), jax.random.PRNGKey(0))
    specs = SH.param_pspecs(cfg, shapes, mi)
    flat_s = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_p = jax.tree_util.tree_flatten(specs, is_leaf=lambda x: isinstance(x, P))[0]
    for (path, arr), spec in zip(flat_s, flat_p):
        if int(np.prod(arr.shape)) >= 8_000_000:
            assert any(ax is not None for ax in tuple(spec)), (
                arch, jax.tree_util.keystr(path), arr.shape,
            )


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("shape_name,batch,seqlen,kind", [
    ("decode_32k", 128, 32768, "decode"),
    ("prefill_32k", 32, 32768, "prefill"),
    ("long_500k", 1, 524288, "decode"),
])
def test_cache_specs_divisible(arch, shape_name, batch, seqlen, kind):
    cfg = get_arch(arch)
    mi = _mi(False)
    cache_shapes = M.make_caches(cfg, batch, seqlen, spec=True)
    specs = SH.cache_pspecs(cfg, batch, seqlen, mi, kind=kind)
    flat_c = jax.tree_util.tree_flatten(cache_shapes)[0]
    flat_p = jax.tree_util.tree_flatten(specs, is_leaf=lambda x: isinstance(x, P))[0]
    assert len(flat_c) == len(flat_p)
    for arr, spec in zip(flat_c, flat_p):
        for dim, ax in zip(arr.shape, tuple(spec)):
            assert dim % _axis_size(mi, ax) == 0, (arch, arr.shape, spec)


def test_serving_policy_drops_fsdp():
    cfg = get_arch("gemma2-9b")
    mi = _mi(False)
    shapes = jax.eval_shape(lambda k: M.init(k, cfg), jax.random.PRNGKey(0))
    train = SH.param_pspecs(cfg, shapes, mi)
    serve = SH.param_pspecs(cfg, shapes, mi, serving=True)
    flat_t = jax.tree_util.tree_flatten(train, is_leaf=lambda x: isinstance(x, P))[0]
    flat_s = jax.tree_util.tree_flatten(serve, is_leaf=lambda x: isinstance(x, P))[0]
    def has_data(spec):
        return any(
            a == "data" or (isinstance(a, tuple) and "data" in a)
            for a in tuple(spec) if a is not None
        )
    assert any(has_data(s) for s in flat_t)
    assert not any(has_data(s) for s in flat_s)
