"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step asserting output shapes + no NaNs, decode-vs-forward consistency, and a
short training run that actually reduces loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, cells, get_arch, list_archs
from repro.models import model as M

KEY = jax.random.PRNGKey(0)
ALL_ARCHS = list_archs()


def _inputs(cfg, b, s):
    if cfg.embed_inputs:
        return jax.random.randint(KEY, (b, s), 0, cfg.vocab, dtype=jnp.int32)
    return jax.random.normal(KEY, (b, s, cfg.d_model), jnp.float32)


def test_pool_complete():
    assert len(ALL_ARCHS) == 10


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = get_arch(arch).reduced()
    params = M.init(KEY, cfg)
    b, s = 2, 24
    x = _inputs(cfg, b, s)
    hidden, _, aux = M.forward(params, cfg, x)
    assert hidden.shape == (b, s, cfg.d_model)
    assert bool(jnp.isfinite(hidden).all()), "NaN in hidden states"
    labels = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    loss = M.loss_fn(params, cfg, x, labels, remat=False)
    assert np.isfinite(float(loss))
    # remat path gives the same loss
    loss_r = M.loss_fn(params, cfg, x, labels, remat=True)
    assert abs(float(loss) - float(loss_r)) < 1e-4


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_grad_finite(arch):
    cfg = get_arch(arch).reduced()
    params = M.init(KEY, cfg)
    x = _inputs(cfg, 2, 16)
    labels = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    g = jax.grad(lambda p: M.loss_fn(p, cfg, x, labels, remat=True))(params)
    norms = [float(jnp.sum(y.astype(jnp.float32) ** 2)) for y in jax.tree.leaves(g)]
    assert all(np.isfinite(n) for n in norms)
    assert sum(norms) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_arch(arch).reduced()
    params = M.init(KEY, cfg)
    x = _inputs(cfg, 2, 12)
    hid, _, _ = M.forward(params, cfg, x)
    full = M.logits_fn(params, cfg, hid[:, -1:])[:, 0]
    caches = M.make_caches(cfg, 2, 16)
    _, caches = M.prefill(params, cfg, x[:, :11], caches)
    step, _ = M.decode_step(params, cfg, x[:, 11:12], caches, 11)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step), atol=2e-3)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_multi_token_decode_chain(arch):
    """Greedy 4-step decode equals teacher-forced forward logits."""
    cfg = get_arch(arch).reduced()
    params = M.init(KEY, cfg)
    x = _inputs(cfg, 1, 12)
    hid, _, _ = M.forward(params, cfg, x)
    caches = M.make_caches(cfg, 1, 16)
    _, caches = M.prefill(params, cfg, x[:, :8], caches)
    for i in range(8, 12):
        step, caches = M.decode_step(params, cfg, x[:, i : i + 1], caches, i)
    full = M.logits_fn(params, cfg, hid[:, -1:])[:, 0]
    np.testing.assert_allclose(np.asarray(full), np.asarray(step), atol=3e-3)


def test_scan_vs_unroll_same_loss():
    cfg = get_arch("gemma2-9b").reduced()
    params = M.init(KEY, cfg)
    x = _inputs(cfg, 2, 16)
    labels = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    l1 = M.loss_fn(params, cfg, x, labels, scan_layers=True, remat=False)
    l2 = M.loss_fn(params, cfg, x, labels, scan_layers=False, remat=False)
    assert abs(float(l1) - float(l2)) < 1e-5


def test_zamba2_shared_attention_is_shared():
    """All SHARED_ATTN applications read the same parameter tensors."""
    cfg = get_arch("zamba2-7b").reduced()
    params = M.init(KEY, cfg)
    assert "shared_attn" in params
    # stage params must NOT contain per-stage attention weights
    stage_keys = set(params["stages"][ "slot0"].keys()) if isinstance(
        params["stages"], dict
    ) else None
    flat = jax.tree_util.tree_flatten_with_path(params["stages"])[0]
    assert not any("attn" in str(p) for p, _ in flat)


def test_moe_dispatch_group_invariance():
    cfg = get_arch("mixtral-8x22b").reduced()
    params = M.init(KEY, cfg)
    x = _inputs(cfg, 2, 12)
    h1, _, _ = M.forward(params, cfg, x, par=M.ParallelCfg(dispatch_groups=1))
    h2, _, _ = M.forward(params, cfg, x, par=M.ParallelCfg(dispatch_groups=2))
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5)


def test_training_reduces_loss():
    import tempfile

    from repro.data.pipeline import TokenPipeline
    from repro.optim.adamw import AdamW
    from repro.runtime.train_loop import train

    cfg = get_arch("qwen3-0.6b").reduced()
    pipe = TokenPipeline(cfg, batch=4, seq_len=16)
    with tempfile.TemporaryDirectory() as d:
        res = train(
            cfg, steps=25, batch=4, seq_len=16, pipeline=pipe, ckpt_dir=d,
            ckpt_every=10, optimizer=AdamW(lr=1e-3),
        )
    assert res.losses[-1] < res.losses[0]


def test_training_restart_resumes_not_restarts():
    import tempfile

    from repro.data.pipeline import TokenPipeline
    from repro.runtime.train_loop import train

    cfg = get_arch("qwen3-0.6b").reduced()
    pipe = TokenPipeline(cfg, batch=2, seq_len=8)
    with tempfile.TemporaryDirectory() as d:
        res = train(
            cfg, steps=20, batch=2, seq_len=8, pipeline=pipe, ckpt_dir=d,
            ckpt_every=5, crash_at_step=12,
        )
    assert res.restarts == 1
    assert res.final_step == 20
    # resumed from step 10 (last ckpt), not from scratch: 12 + (20-10)
    assert res.steps_run == 12 + 10


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_cells_assignment(arch):
    cfg = get_arch(arch)
    names = [s.name for s in cells(cfg)]
    assert ("long_500k" in names) == cfg.supports_long_context
    assert {"train_4k", "prefill_32k", "decode_32k"} <= set(names)


def test_40_cells_total():
    total = sum(len(cells(get_arch(a))) for a in ALL_ARCHS)
    # 10 archs × 3 universal shapes + 3 long-context archs = 33 baseline
    # cells; the harness's "40 cells" count includes the long_500k row for
    # every arch — non-eligible ones are recorded as documented skips.
    assert total == 33
    eligible = [a for a in ALL_ARCHS if get_arch(a).supports_long_context]
    assert sorted(eligible) == ["mixtral-8x22b", "rwkv6-1.6b", "zamba2-7b"]
