"""Unit tests for the Pallas hash-aggregation kernel (interpret mode on CPU):
dict-oracle differentials over the full reducer monoid, init-table merges,
probe/overflow semantics, the capacity autotuner, and parity of the kernel's
hash/sentinel with the containers they must agree with."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import containers as C
from repro.kernels import hash_combine as HK

rng = np.random.RandomState(0)

REDUCERS = ("sum", "prod", "min", "max")

_NP_FN = {
    "sum": np.add,
    "prod": np.multiply,
    "min": np.minimum,
    "max": np.maximum,
}


def _dict_oracle(keys, vals, reducer, dead=None):
    out: dict = {}
    fn = _NP_FN[reducer]
    for i, (k, v) in enumerate(zip(keys.tolist(), vals.tolist())):
        if dead is not None and dead[i]:
            continue
        out[k] = fn(out[k], v) if k in out else v
    return out


def _table_dict(tkeys, tvals):
    tkeys, tvals = np.asarray(tkeys), np.asarray(tvals)
    return {
        int(k): tvals[i, 0]
        for i, k in enumerate(tkeys)
        if k != HK.EMPTY_KEY
    }


def test_kernel_hash_and_sentinel_match_containers():
    """The kernel-side splitmix32 copy and EMPTY_KEY must agree with
    repro.core.containers — slot placement must be bit-identical."""
    assert HK.EMPTY_KEY == C.EMPTY_KEY
    xs = jnp.asarray(
        np.concatenate([rng.randint(-(2**31), 2**31 - 1, 4096),
                        np.arange(-64, 64)]).astype(np.int32)
    )
    np.testing.assert_array_equal(
        np.asarray(HK.hash32(xs)), np.asarray(C.hash32(xs))
    )


@pytest.mark.parametrize("dtype_name", ("f32", "i32", "bf16"))
@pytest.mark.parametrize("reducer", REDUCERS)
def test_kernel_matches_dict_oracle(reducer, dtype_name):
    dtype = {"f32": jnp.float32, "i32": jnp.int32, "bf16": jnp.bfloat16}[
        dtype_name
    ]
    n = 257  # not a block multiple: exercises the padded tail
    keys = rng.randint(0, 60, n).astype(np.int32)
    if reducer == "prod":
        vals = rng.choice([1.0, -1.0], n)
        vals[rng.rand(n) < 0.1] = 2.0
    else:
        vals = rng.randint(-8, 9, n).astype(np.float64)
    dead = rng.rand(n) < 0.25
    mkeys = np.where(dead, HK.EMPTY_KEY, keys).astype(np.int32)
    jvals = jnp.asarray(vals[:, None]).astype(dtype)

    tk, tv, ovf = HK.hash_aggregate(
        jnp.asarray(mkeys), jvals, 256, reducer=reducer, block_n=64
    )
    assert int(ovf) == 0
    got = _table_dict(tk, tv)
    want = _dict_oracle(
        keys, np.asarray(jnp.asarray(vals).astype(dtype), np.float64),
        reducer, dead,
    )
    assert set(got) == set(want)
    tol = 0.25 if dtype_name == "bf16" else 1e-5
    for k in want:
        assert abs(float(got[k]) - want[k]) <= tol, (reducer, dtype_name, k)


@pytest.mark.parametrize("reducer", ("sum", "min"))
def test_kernel_init_merge_equals_two_pass(reducer):
    """Merging stream B into the table built from stream A == aggregating
    A ++ B in one pass (the post-shuffle merge contract)."""
    ka = rng.randint(0, 40, 100).astype(np.int32)
    kb = rng.randint(0, 40, 80).astype(np.int32)
    va = rng.randint(-9, 10, (100, 1)).astype(np.float32)
    vb = rng.randint(-9, 10, (80, 1)).astype(np.float32)
    cap = 128
    tk_a, tv_a, ovf_a = HK.hash_aggregate(
        jnp.asarray(ka), jnp.asarray(va), cap, reducer=reducer
    )
    tk_m, tv_m, ovf_m = HK.hash_aggregate(
        jnp.asarray(kb), jnp.asarray(vb), cap, reducer=reducer,
        init=(tk_a, tv_a, ovf_a),
    )
    tk_1, tv_1, _ = HK.hash_aggregate(
        jnp.asarray(np.concatenate([ka, kb])),
        jnp.asarray(np.concatenate([va, vb])), cap, reducer=reducer,
    )
    assert int(ovf_m) == 0
    assert _table_dict(tk_m, tv_m) == _table_dict(tk_1, tv_1)


def test_kernel_matches_hashmap_insert_layout():
    """Same probe sequence as containers.hashmap_insert: inserting a unique
    batch lands every key in the same slot either way."""
    cap = 64
    keys = np.unique(rng.randint(0, 10_000, 80).astype(np.int32))[:40]
    vals = np.arange(len(keys), dtype=np.float32) + 1.0
    red = __import__(
        "repro.core.reducers", fromlist=["get_reducer"]
    ).get_reducer("sum")
    ref = C.make_table(cap, (), jnp.float32, red)
    ref = C.hashmap_insert(
        ref, jnp.asarray(keys), jnp.asarray(vals),
        jnp.ones(len(keys), bool), red,
    )
    tk, tv, ovf = HK.hash_aggregate(
        jnp.asarray(keys), jnp.asarray(vals[:, None]), cap, reducer="sum",
        max_probes=16,
    )
    np.testing.assert_array_equal(np.asarray(tk), np.asarray(ref.keys))
    np.testing.assert_allclose(
        np.asarray(tv[:, 0]), np.asarray(ref.vals), rtol=1e-6
    )
    assert int(ovf) == int(ref.overflow)


def test_kernel_duplicates_within_one_block_fold():
    """Every lane the same key — the whole block must fold into one row in
    a single probe round (the unique_combine-free claim)."""
    n = 64
    keys = np.full(n, 7, np.int32)
    vals = np.ones((n, 1), np.float32)
    tk, tv, ovf = HK.hash_aggregate(
        jnp.asarray(keys), jnp.asarray(vals), 32, reducer="sum", block_n=64
    )
    got = _table_dict(tk, tv)
    assert got == {7: pytest.approx(64.0)} and int(ovf) == 0


def test_kernel_overflow_counted_never_silent():
    """More distinct keys than table slots: drops are counted exactly and
    surviving rows still hold their exact totals."""
    keys = np.arange(64, dtype=np.int32)
    vals = np.full((64, 1), 3.0, np.float32)
    tk, tv, ovf = HK.hash_aggregate(
        jnp.asarray(keys), jnp.asarray(vals), 16, reducer="sum", max_probes=16
    )
    live = int((np.asarray(tk) != HK.EMPTY_KEY).sum())
    assert live <= 16
    assert live + int(ovf) == 64  # conservation, nothing silent
    for k, v in _table_dict(tk, tv).items():
        assert v == pytest.approx(3.0)


def test_kernel_empty_and_all_dead_streams():
    cap = 64
    tk, tv, ovf = HK.hash_aggregate(
        jnp.zeros((0,), jnp.int32), jnp.zeros((0, 1), jnp.float32), cap
    )
    assert int((np.asarray(tk) != HK.EMPTY_KEY).sum()) == 0 and int(ovf) == 0
    dead = jnp.full((32,), HK.EMPTY_KEY, jnp.int32)
    tk, tv, ovf = HK.hash_aggregate(dead, jnp.ones((32, 1), jnp.float32), cap)
    assert int((np.asarray(tk) != HK.EMPTY_KEY).sum()) == 0 and int(ovf) == 0


def test_kernel_multiblock_stream_equals_single_block():
    """Block size changes insertion order (and therefore may permute which
    slot a colliding key lands in) but never the aggregated *content*."""
    keys = rng.randint(0, 100, 512).astype(np.int32)
    vals = rng.randn(512, 2).astype(np.float32)
    small = HK.hash_aggregate(
        jnp.asarray(keys), jnp.asarray(vals), 256, reducer="sum", block_n=32
    )
    big = HK.hash_aggregate(
        jnp.asarray(keys), jnp.asarray(vals), 256, reducer="sum", block_n=512
    )

    def as_dict(tk, tv):
        tk, tv = np.asarray(tk), np.asarray(tv)
        return {
            int(k): tuple(np.round(tv[i], 4))
            for i, k in enumerate(tk) if k != HK.EMPTY_KEY
        }

    assert int(small[2]) == int(big[2]) == 0
    a, b = as_dict(*small[:2]), as_dict(*big[:2])
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-4, atol=1e-4)


def test_kernel_interpret_flag_equivalence():
    """interpret=True (forced) and the default resolution produce identical
    tables — the BLAZE_PALLAS_INTERPRET CI knob changes nothing semantic."""
    keys = rng.randint(0, 30, 128).astype(np.int32)
    vals = rng.randn(128, 1).astype(np.float32)
    a = HK.hash_aggregate(
        jnp.asarray(keys), jnp.asarray(vals), 128, reducer="sum",
        interpret=True,
    )
    b = HK.hash_aggregate(
        jnp.asarray(keys), jnp.asarray(vals), 128, reducer="sum"
    )
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]), rtol=1e-6)


def test_choose_table_cap_autotuner():
    # power-of-two capacity targeting load factor <= 0.5
    cap, bn, probes = HK.choose_table_cap(100, 1)
    assert cap >= 200 and (cap & (cap - 1)) == 0
    assert bn >= 8 and probes == 16
    # a distinct-key hint shrinks the table below the stream length
    cap_h, _, _ = HK.choose_table_cap(100_000, 1, distinct_hint=500)
    assert cap_h == 1024
    # VMEM budget caps capacity; load factor rises, probe depth follows
    cap_b, bn_b, probes_b = HK.choose_table_cap(
        1_000_000, 8, vmem_budget=1 << 20
    )
    assert cap_b * 9 * 4 <= (1 << 20)
    assert probes_b > 16
    # probe depth never exceeds the table
    assert HK.choose_probe_depth(10, 4) <= 4


def test_kernel_lanes_accounting():
    bn, lanes = HK.hash_aggregate_lanes(100, 256, 1, block_n=64)
    assert bn == 64 and lanes == 128
    bn2, lanes2 = HK.hash_aggregate_lanes(64, 256, 1, block_n=64)
    assert lanes2 == 64
