"""BlazeServe fault-injection suite.

A fault must be exactly as big as the request that carried it: a raising
mapper fails its own query with a typed ``QUERY_ERROR`` while the server
keeps serving and the resident program cache stays uncorrupted (asserted by
a follow-up query that must succeed with zero new compiles).  Transport
faults — malformed bodies, unknown queries, clients disconnecting
mid-flight — are likewise absorbed without taking the service down.
"""
from __future__ import annotations

import json
import re
import socket
import urllib.parse

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic as S
from repro.serve import (
    BadParamsError,
    BlazeClient,
    BlazeServer,
    PreparedQuery,
    QueryExecutionError,
    QuerySpec,
    RemoteServeError,
)
from repro.serve.queries import _int


class FaultyMapperQuery(QuerySpec):
    """A pi-like query whose mapper raises at plan-build time when asked to
    (``params["boom"]``) — the JAX-realistic injection point: user step
    code runs under tracing, so a buggy mapper detonates while the plan is
    being discovered, inside the dispatcher, for one request."""

    name = "faulty"

    def plan_key(self, params):
        # "boom" is structural on purpose: the faulty variant must not be
        # served from the healthy variant's resident program.
        return ("faulty", _int(params, "n_samples", 512, 1),
                bool(params.get("boom", False)))

    def prepare(self, res, params):
        from repro.core.algorithms.pi import _program_step

        n = _int(params, "n_samples", 512, 1)
        if params.get("boom"):
            def bad_step(ctx, s):
                raise ValueError("injected mapper fault")
            step, state0 = bad_step, {"counts": jnp.zeros((1,), jnp.int32)}
        else:
            step, state0 = _program_step(n, "eager")
        prog = res.session.program(step, mesh=res.mesh)
        plan = prog.build(state0)

        def run(p):
            return prog(state0, 1)

        def finish(dev):
            return {"counts": np.asarray(dev["counts"])}

        return PreparedQuery(self.plan_key(params), plan.hash, prog, run,
                             finish)


class FlakyRunQuery(QuerySpec):
    """Same plan for every request; ``params["fail"]`` makes one request's
    dispatch raise — the fault and the healthy requests share one resident
    program, so isolation is about the request, not the plan."""

    name = "flaky"

    def plan_key(self, params):
        return ("flaky", 512)

    def prepare(self, res, params):
        from repro.core.algorithms.pi import _program_step

        step, state0 = _program_step(512, "eager")
        prog = res.session.program(step, mesh=res.mesh)
        plan = prog.build(state0)

        def run(p):
            if p.get("fail"):
                raise RuntimeError("injected dispatch fault")
            return prog(state0, 1)

        def finish(dev):
            return {"counts": np.asarray(dev["counts"])}

        return PreparedQuery(self.plan_key(params), plan.hash, prog, run,
                             finish)


@pytest.fixture()
def server():
    srv = BlazeServer(max_queue=64, per_tenant_inflight=16, max_batch=4)
    lines, _ = S.zipf_corpus(128, 8, 64, seed=3)
    srv.register_dataset("lines", lines, vocab_size=64)
    srv.register_query(FaultyMapperQuery())
    srv.register_query(FlakyRunQuery())
    srv.start()
    yield srv
    srv.stop()


def test_raising_mapper_fails_only_its_request(server):
    # Healthy baseline first: compiles the good plan.
    r1, _ = server.submit_and_wait("alice", "faulty", {"n_samples": 512})
    compiles = server.stats.compiles

    with pytest.raises(QueryExecutionError) as ei:
        server.submit_and_wait("bob", "faulty",
                               {"n_samples": 512, "boom": True})
    assert "injected mapper fault" in str(ei.value)

    # The server keeps serving and the resident cache is uncorrupted:
    # the follow-up healthy query succeeds with ZERO new compiles and the
    # same payload.
    r2, meta2 = server.submit_and_wait("carol", "faulty", {"n_samples": 512})
    assert meta2["cache"] == "hit"
    # The detonation happened during plan build — nothing was compiled by
    # it and nothing needed recompiling after it.
    assert server.stats.compiles == compiles
    assert np.array_equal(r1["counts"], r2["counts"])
    snap = server.stats.snapshot()
    assert snap["failed"] == 1 and snap["completed"] == 2
    assert snap["completed"] + snap["failed"] + snap["queued"] == \
        snap["submitted"]


def test_dispatch_fault_shares_plan_but_not_fate(server):
    r1, _ = server.submit_and_wait("alice", "flaky", {})
    compiles = server.stats.compiles
    with pytest.raises(QueryExecutionError):
        server.submit_and_wait("bob", "flaky", {"fail": True})
    r2, meta2 = server.submit_and_wait("carol", "flaky", {})
    assert meta2["cache"] == "hit"
    assert server.stats.compiles == compiles  # fault compiled nothing new
    assert np.array_equal(r1["counts"], r2["counts"])


def test_fault_in_batch_fails_only_its_group(server):
    """Micro-batched neighbours of a faulty request still complete."""
    server.pause_dispatch()
    good = [server.submit(f"t{i}", "flaky", {"tag": i}) for i in range(3)]
    bad = server.submit("t9", "flaky", {"fail": True})
    server.resume_dispatch()
    for r in good:
        assert r.done.wait(120)
        assert r.error is None, r.error
    assert bad.done.wait(120)
    assert isinstance(bad.error, QueryExecutionError)


def test_malformed_and_typed_http_errors(server):
    client = BlazeClient(server.url, tenant="alice")

    with pytest.raises(RemoteServeError) as ei:
        client.query("no-such-query", {})
    assert ei.value.code == "UNKNOWN_QUERY" and ei.value.status == 404

    with pytest.raises(RemoteServeError) as ei:
        client.query("wordcount", {"dataset": "no-such-dataset"})
    assert ei.value.code == "UNKNOWN_DATASET" and ei.value.status == 400

    with pytest.raises(RemoteServeError) as ei:
        client.query("faulty", {"n_samples": -3})
    assert ei.value.code == "BAD_PARAMS" and ei.value.status == 400

    # Raw malformed JSON body -> typed 400, not a hang or a 500.
    host, port = _host_port(server.url)
    body = b"{this is not json"
    req = (
        b"POST /query HTTP/1.1\r\nHost: x\r\nContent-Type: application/json"
        b"\r\nContent-Length: " + str(len(body)).encode() + b"\r\n\r\n" + body
    )
    with socket.create_connection((host, port), timeout=30) as sock:
        sock.sendall(req)
        resp = _recv_response(sock)
    assert resp.startswith("HTTP/1.1 400")
    payload = json.loads(resp.split("\r\n\r\n", 1)[1])
    assert payload["error"] == "MALFORMED"

    # A non-object body is malformed too (not a crash).
    with socket.create_connection((host, port), timeout=30) as sock:
        good = json.dumps([1, 2, 3]).encode()
        sock.sendall(
            b"POST /query HTTP/1.1\r\nHost: x\r\nContent-Length: "
            + str(len(good)).encode() + b"\r\n\r\n" + good
        )
        resp = _recv_response(sock)
    assert resp.startswith("HTTP/1.1 400")

    # After all that abuse the server still serves real queries.
    r, _ = client.query("faulty", {"n_samples": 512})
    assert r["counts"].shape == (1,)


def test_client_disconnect_mid_flight(server):
    """A client that submits and vanishes must not take the server down —
    its query still completes server-side; later clients are unaffected."""
    completed0 = server.stats.snapshot()["completed"]
    host, port = _host_port(server.url)
    body = json.dumps({
        "tenant": "ghost", "query": "flaky", "params": {"tag": "ghost"},
    }).encode()
    with socket.create_connection((host, port), timeout=30) as sock:
        sock.sendall(
            b"POST /query HTTP/1.1\r\nHost: x\r\nContent-Type: "
            b"application/json\r\nContent-Length: "
            + str(len(body)).encode() + b"\r\n\r\n" + body
        )
        # Hang up without reading the response.
    # The ghost's query still runs to completion server-side.
    deadline = 120.0
    import time
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < deadline:
        if server.stats.snapshot()["completed"] >= completed0 + 1:
            break
        time.sleep(0.05)
    assert server.stats.snapshot()["completed"] >= completed0 + 1
    # And the server is fully healthy for the next client.
    client = BlazeClient(server.url, tenant="alive")
    r, _ = client.query("flaky", {})
    assert r["counts"].shape == (1,)
    snap = server.stats.snapshot()
    assert snap["completed"] + snap["failed"] + snap["queued"] == \
        snap["submitted"]


def test_bad_params_never_reach_the_queue(server):
    """Validation failures are rejected at admission: nothing is queued,
    nothing dispatched, conservation still holds."""
    dispatches0 = server.stats.snapshot()["dispatches"]
    with pytest.raises(BadParamsError):
        server.submit("alice", "faulty", {"n_samples": "lots"})
    snap = server.stats.snapshot()
    assert snap["queued"] == 0
    assert snap["dispatches"] == dispatches0
    assert snap["completed"] + snap["failed"] + snap["queued"] == \
        snap["submitted"]


def _host_port(url: str) -> tuple[str, int]:
    p = urllib.parse.urlparse(url)
    return p.hostname, p.port


def _recv_response(sock: socket.socket) -> str:
    """Read one full HTTP response: headers, then Content-Length bytes of
    body.  A single recv() may return a partial body under load."""
    buf = b""
    while b"\r\n\r\n" not in buf:
        chunk = sock.recv(65536)
        if not chunk:
            return buf.decode()
        buf += chunk
    head, body = buf.split(b"\r\n\r\n", 1)
    m = re.search(rb"content-length:\s*(\d+)", head, re.I)
    want = int(m.group(1)) if m else 0
    while len(body) < want:
        chunk = sock.recv(65536)
        if not chunk:
            break
        body += chunk
    return (head + b"\r\n\r\n" + body).decode()
