"""Cost-model unit tests: the shared candidate grids are bit-compatible with
the pre-PR-8 greedy kernel tuners, the calibrated fallback model reproduces
the PR 2 static engine rule exactly, and TunedConfig/TuningCache round-trip.
"""
import jax.numpy as jnp
import pytest

from repro.core import cost
from repro.core.plan import (
    PALLAS_AUTO_MAX_KEYS,
    node_key_count,
    resolve_engine,
)
from repro.core.reducers import get_reducer
from repro.kernels import hash_combine as HK
from repro.kernels import segment_reduce as SK


# -- candidate grids == the kernels' greedy tuners ---------------------------


@pytest.mark.parametrize("reducer", ["sum", "min", "max", "prod"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32, jnp.bfloat16])
@pytest.mark.parametrize("k,v", [(4, 1), (64, 8), (512, 4), (4096, 128)])
def test_choose_block_n_is_grid_pick(reducer, dtype, k, v):
    for n in (1, 7, 100, 5000):
        grid = cost.segment_block_candidates(n, k, v, reducer, dtype)
        # ascending powers of two starting at 8, scored within budget
        assert [bn for bn, _ in grid] == sorted({bn for bn, _ in grid})
        assert grid[0][0] == 8
        for bn, ws in grid[1:]:
            assert bn & (bn - 1) == 0 and ws <= cost.VMEM_BUDGET
        # the kernel delegate picks the largest candidate, clamped to n
        assert SK.choose_block_n(n, k, v, reducer, dtype) == max(
            8, min(grid[-1][0], max(8, n))
        )


@pytest.mark.parametrize("reducer", ["sum", "min"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
@pytest.mark.parametrize("v", [1, 4, 64])
def test_choose_table_cap_is_grid_pick(reducer, dtype, v):
    for n in (1, 100, 4096):
        for hint in (None, 50, 1000):
            grid = cost.hash_table_candidates(
                n, v, reducer, dtype, distinct_hint=hint
            )
            cap0 = grid[0][0]
            assert all(c == cap0 for c, _, _, _ in grid)  # cap fixed first
            assert all(
                p == cost.choose_probe_depth(n, cap0) for _, _, p, _ in grid
            )
            got = HK.choose_table_cap(
                n, v, reducer, dtype, distinct_hint=hint
            )
            cap, bn, probes, _ = grid[-1]
            assert got == (cap, max(8, min(bn, max(8, n))), probes)


def test_kernel_delegates_share_one_implementation():
    assert SK.choose_block_n(10_000, 128, 8) == cost.choose_block_n(
        10_000, 128, 8
    )
    assert HK.choose_probe_depth(100, 256) == cost.choose_probe_depth(100, 256)
    assert HK.choose_table_cap(100, 4) == cost.choose_table_cap(100, 4)


def test_hash_working_set_monotone_in_block():
    ws = [
        cost.hash_working_set(512, bn, 4) for bn in (8, 16, 32, 64, 128)
    ]
    assert ws == sorted(ws)


# -- calibrated fallback model == the PR 2 static rule -----------------------


def test_pick_engine_crossover_is_the_pr2_threshold():
    # the PR 2 matrix: the static rule was ``pallas iff 0 < K <= 4096``
    for k in (1, 2, 100, 4095, 4096, 4097, 8192, 1 << 20):
        want = "pallas" if k <= PALLAS_AUTO_MAX_KEYS else "eager"
        assert cost.pick_engine(k) == want, k
    assert cost.pick_engine(0) == "eager"
    assert cost.pick_engine(-1) == "eager"


@pytest.mark.parametrize("k", [16, 4096, 4097, 100_000])
def test_resolve_engine_auto_matches_model(k):
    red = get_reducer("sum")
    target = jnp.zeros((k, 2), jnp.float32)
    assert node_key_count(target) == k
    assert resolve_engine("auto", target, red) == cost.pick_engine(k)


def test_node_cost_orders_engines():
    # naive is always modelled worst; crossover ordering flips at 4096
    for k in (10, 4096, 5000):
        assert cost.node_cost("naive", k) > cost.node_cost("eager", k)
        assert cost.node_cost("naive", k) > cost.node_cost("pallas", k)
    assert cost.node_cost("pallas", 100) < cost.node_cost("eager", 100)
    assert cost.node_cost("pallas", 10_000) > cost.node_cost("eager", 10_000)


# -- measurement grids -------------------------------------------------------


def test_dense_tuning_candidates_shape():
    cands = cost.dense_tuning_candidates(64, 8, "sum", jnp.float32)
    assert cands[0] == cost.TunedConfig(engine="eager")
    assert all(c.engine == "pallas" and c.block_n for c in cands[1:])
    assert len({c.block_n for c in cands[1:]}) == len(cands) - 1
    default = cost.segment_block_candidates(1 << 30, 64, 8)[-1][0]
    assert cands[1].block_n == default


def test_hash_tuning_candidates_key_range_gates_cap_pinning():
    # without key_range capacity must follow runtime n: engine-only tuning
    cands = cost.hash_tuning_candidates(1, "sum", jnp.int32, key_range=None)
    assert [c.engine for c in cands] == ["eager", "pallas"]
    assert cands[1].table_cap is None
    # with key_range, full (cap, bn, probes) triples are pinned, cap >= 2x
    cands = cost.hash_tuning_candidates(1, "sum", jnp.int32, key_range=50)
    assert cands[0].engine == "eager"
    for c in cands[1:]:
        assert c.table_cap >= 2 * 50 and c.block_n and c.probe_depth


# -- TunedConfig / TuningCache ----------------------------------------------


def test_tuned_config_identity_excludes_outcomes():
    a = cost.TunedConfig(engine="pallas", block_n=64)
    b = cost.TunedConfig(
        engine="pallas", block_n=64, source="measured", wall_s=0.5
    )
    assert a == b and hash(a) == hash(b)
    assert a != cost.TunedConfig(engine="pallas", block_n=32)
    rt = cost.TunedConfig.from_dict(b.to_dict())
    assert rt == b and rt.source == "measured" and rt.wall_s == 0.5


def test_tuning_cache_counters_and_roundtrip(tmp_path):
    c = cost.TuningCache()
    assert c.get("x") is None and c.misses == 1
    cfg = cost.TunedConfig(
        engine="pallas", block_n=64, source="measured", wall_s=0.01
    )
    c.put("x", cfg)
    assert c.get("x") == cfg and c.hits == 1
    assert c.peek("y") is None and c.misses == 1  # peek never counts
    c.record_measurements(3)
    snap = c.snapshot()
    assert snap["entries"] == 1 and snap["measurements"] == 3
    p = tmp_path / "tuning.json"
    c.save(str(p))
    c2 = cost.TuningCache()
    assert c2.load(str(p)) == 1
    got = c2.peek("x")
    assert got == cfg and got.source == "measured" and got.wall_s == 0.01
