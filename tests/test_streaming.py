"""Out-of-core chunked datasets + double-buffered streaming execution.

The contract under test: a dataset larger than one resident block, streamed
block-at-a-time through ONE compiled executable, produces bit-identical
results to the in-memory path — for standalone map_reduce, for fused
programs driven by ``run_stream``, and for the wordcount / k-means /
PageRank drivers.  (K-means inertia is the one allclose exception: the
min-d² float sums reassociate across blocks.)
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    BlazeSession,
    ChunkedDistVector,
    chunked,
    make_dist_hashmap,
)
from repro.core.algorithms.kmeans import kmeans
from repro.core.algorithms.pagerank import pagerank, pagerank_reference
from repro.core.algorithms.wordcount import counts_dict, wordcount


def _sq_mapper(i, x, emit):
    emit(i % 7, x * x)


def _mod_mapper(i, x, emit):
    emit(x.astype(jnp.int32) % 11, 1)


# -- container ----------------------------------------------------------------


def test_chunked_roundtrip_and_padding():
    sess = BlazeSession()
    x = np.arange(1003, dtype=np.float32)  # deliberately not a block multiple
    cv = sess.chunked(x, block_rows=256)
    assert isinstance(cv, ChunkedDistVector)
    assert cv.n == 1003
    assert cv.n_blocks == 4
    np.testing.assert_array_equal(cv.collect(), x)
    # last block is padded to the block shape but reports its true rows
    assert cv.block_true_rows(3) == 1003 - 3 * 256
    assert cv.block_host(3).shape[0] == 256


def test_chunked_compress_and_spill_lru():
    sess = BlazeSession()
    x = np.arange(5 * 64, dtype=np.float32)
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        cv = sess.chunked(
            x, block_rows=64, compress=True, spill_dir=d, max_resident=2
        )
        assert cv.n_blocks == 5
        np.testing.assert_array_equal(cv.collect(), x)
        st = cv.stats()
        assert st["spill_bytes"] > 0  # LRU evicted past max_resident=2
        assert st["resident_blocks"] <= 2
        # spilled blocks reload transparently (and bit-exactly)
        np.testing.assert_array_equal(cv.collect(), x)


def test_chunked_rejects_bad_block_rows():
    sess = BlazeSession()
    with pytest.raises(ValueError):
        sess.chunked(np.arange(8, dtype=np.float32), block_rows=0)


# -- standalone map_reduce over chunked sources -------------------------------


def test_chunked_map_reduce_dense_bit_equal_one_compile():
    sess = BlazeSession()
    # integer-valued with bounded sums: every partial is exact in f32, so
    # block reassociation cannot perturb the result
    x = (np.arange(1000) % 57).astype(np.float32)
    ref = sess.map_reduce(
        sess.distribute(x), _sq_mapper, "sum", jnp.zeros((7,), jnp.float32)
    )
    cv = sess.chunked(x, block_rows=128)  # 8 blocks
    c0 = sess.stats.compiles
    got, stats = sess.map_reduce(
        cv, _sq_mapper, "sum", jnp.zeros((7,), jnp.float32),
        return_stats=True,
    )
    fs = stats.finalize()
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
    # ONE executable serves all 8 blocks (traced base offset)
    assert sess.stats.compiles - c0 == 1
    assert fs.dispatches == cv.n_blocks


def test_chunked_map_reduce_hash_target_equal():
    sess = BlazeSession()
    x = np.arange(500, dtype=np.float32)
    hm_ref = make_dist_hashmap(sess.mesh, 256, (), jnp.int32, "sum")
    hm_ref = sess.map_reduce(sess.distribute(x), _mod_mapper, "sum", hm_ref)
    cv = sess.chunked(x, block_rows=64)
    hm = make_dist_hashmap(sess.mesh, 256, (), jnp.int32, "sum")
    hm = sess.map_reduce(cv, _mod_mapper, "sum", hm, key_range=11)
    assert hm.to_dict() == hm_ref.to_dict()


# -- fused programs: run_stream ----------------------------------------------


def _stream_sum_program(sess, cv, n_blocks):
    def step(ctx, s):
        part = ctx.map_reduce(
            cv, _sq_mapper, "sum", jnp.zeros((7,), jnp.float32)
        )
        acc = s["acc"] + part
        last = s["blk"] == n_blocks - 1
        return {
            "acc": jnp.where(last, jnp.zeros_like(s["acc"]), acc),
            "out": jnp.where(last, acc, s["out"]),
            "blk": jnp.where(last, 0, s["blk"] + 1),
        }

    state = {
        "acc": jnp.zeros((7,), jnp.float32),
        "out": jnp.zeros((7,), jnp.float32),
        "blk": jnp.zeros((), jnp.int32),
    }
    return sess.program(step), state


@pytest.mark.parametrize("prefetch", [True, False])
def test_run_stream_bit_equal_and_single_compile(prefetch):
    sess = BlazeSession()
    x = (np.arange(1003) % 57).astype(np.float32)  # exact f32 sums
    ref = sess.map_reduce(
        sess.distribute(x), _sq_mapper, "sum", jnp.zeros((7,), jnp.float32)
    )
    cv = sess.chunked(x, block_rows=256)
    prog, state = _stream_sum_program(sess, cv, cv.n_blocks)
    state, info = sess.run_stream(prog, state, prefetch=prefetch)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(state["out"]))
    assert info.compiles == 1
    assert info.epochs == 1
    assert info.n_blocks == cv.n_blocks == 4
    assert info.dispatches == 4
    assert info.prefetch is prefetch
    assert info.bytes_streamed > 0
    # second epoch pass reuses the executable: zero new compiles
    state, info2 = sess.run_stream(prog, state, prefetch=prefetch)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(state["out"]))
    assert info2.compiles == 0


def test_run_stream_block_count_invariant_compiles():
    """1 program compile regardless of how many blocks the dataset splits
    into — the acceptance bar for the streaming mode."""
    sess = BlazeSession()
    x = (np.arange(1024) % 57).astype(np.float32)
    for rows, expect_blocks in ((512, 2), (128, 8)):
        cv = sess.chunked(x, block_rows=rows)
        prog, state = _stream_sum_program(sess, cv, cv.n_blocks)
        c0 = sess.stats.program_compiles
        state, info = sess.run_stream(prog, state)
        assert cv.n_blocks == expect_blocks
        assert info.compiles == 1
        assert sess.stats.program_compiles - c0 == 1


def test_run_stream_spilled_blocks():
    import tempfile

    sess = BlazeSession()
    x = (np.arange(1024) % 57).astype(np.float32)
    ref = sess.map_reduce(
        sess.distribute(x), _sq_mapper, "sum", jnp.zeros((7,), jnp.float32)
    )
    with tempfile.TemporaryDirectory() as d:
        cv = sess.chunked(
            x, block_rows=128, compress=True, spill_dir=d, max_resident=2
        )
        prog, state = _stream_sum_program(sess, cv, cv.n_blocks)
        state, info = sess.run_stream(prog, state)
        np.testing.assert_array_equal(
            np.asarray(ref), np.asarray(state["out"])
        )
        assert cv.stats()["spill_bytes"] > 0


def test_program_call_without_blocks_raises():
    sess = BlazeSession()
    cv = sess.chunked(np.arange(64, dtype=np.float32), block_rows=32)
    prog, state = _stream_sum_program(sess, cv, cv.n_blocks)
    with pytest.raises(ValueError, match="stream"):
        prog(state, 1)


def test_run_stream_without_chunked_sources_raises():
    sess = BlazeSession()
    v = sess.distribute(np.arange(64, dtype=np.float32))

    def step(ctx, s):
        out = ctx.map_reduce(
            v, _sq_mapper, "sum", jnp.zeros((7,), jnp.float32)
        )
        return {"out": out + 0.0 * s["out"]}

    prog = sess.program(step)
    with pytest.raises(ValueError, match="no chunked"):
        sess.run_stream(prog, {"out": jnp.zeros((7,), jnp.float32)})


def test_explain_shows_stream_schedule():
    sess = BlazeSession()
    cv = sess.chunked(np.arange(1003, dtype=np.float32), block_rows=256)
    prog, state = _stream_sum_program(sess, cv, cv.n_blocks)
    txt = sess.explain(prog, state)
    assert "chunked float32[256] n=1003 blocks=4" in txt
    assert "stream schedule" in txt


# -- algorithm drivers over chunked sources -----------------------------------


def test_wordcount_streaming_bit_equal():
    rng = np.random.RandomState(0)
    lines = rng.randint(0, 40, size=(600, 8)).astype(np.int32)
    lines[rng.rand(*lines.shape) < 0.25] = -1
    sess = BlazeSession()
    ref = counts_dict(wordcount(lines, session=sess, vocab_size=40))
    cv = sess.chunked(lines, block_rows=128)  # 5 blocks
    # fused program mode: every block of every pass through ONE executable
    res = wordcount(cv, session=sess, vocab_size=40, mode="program")
    assert counts_dict(res.counts) == ref
    assert res.program_compiles == 1
    # per_op mode: the session's chunked block loop
    hm = wordcount(cv, session=sess, vocab_size=40)
    assert counts_dict(hm) == ref


def test_wordcount_chunked_requires_vocab_size():
    sess = BlazeSession()
    cv = sess.chunked(np.zeros((8, 4), np.int32), block_rows=4)
    with pytest.raises(ValueError, match="vocab_size"):
        wordcount(cv, session=sess)


def test_kmeans_streaming_centers_bit_equal():
    rng = np.random.RandomState(1)
    # integer-valued f32 coords: per-centre sums are exact, so the streamed
    # reassociation across blocks cannot change the centres
    pts = rng.randint(-20, 20, size=(900, 4)).astype(np.float32)
    init = pts[:5].copy()
    sess = BlazeSession()
    ref = kmeans(pts, 5, init_centers=init, max_iters=6, session=sess)
    cv = sess.chunked(pts, block_rows=256)  # 4 blocks
    got = kmeans(
        cv, 5, init_centers=init, max_iters=6, mode="stream", session=sess
    )
    np.testing.assert_array_equal(ref.centers, got.centers)
    assert ref.iterations == got.iterations
    assert ref.converged == got.converged
    # inertia: float min-d2 sums reassociate across blocks -> allclose only
    np.testing.assert_allclose(ref.inertia, got.inertia, rtol=1e-5)
    assert got.program_compiles == 1


def test_kmeans_chunked_program_mode_rejected():
    sess = BlazeSession()
    cv = sess.chunked(np.zeros((64, 2), np.float32), block_rows=32)
    with pytest.raises(ValueError, match="stream"):
        kmeans(cv, 2, mode="program", session=sess)


def test_pagerank_streaming_bit_equal():
    # chain graph: in-degree <= 1, so each page's incoming sum has exactly one
    # non-zero contribution -> block accumulation is exact, and the tail page
    # is a sink so the sink term is exercised too.  The bit-equality baseline
    # is the in-memory fused program (same jitted update arithmetic); per_op
    # computes the update eagerly, so it only agrees to float tolerance.
    n = 48
    edges = np.stack([np.arange(n - 1), np.arange(1, n)], 1).astype(np.int32)
    sess = BlazeSession()
    ref = pagerank(edges, n, max_iters=15, mode="program", session=sess)
    cv = sess.chunked(edges, block_rows=16)  # 3 blocks
    got = pagerank(cv, n, max_iters=15, mode="stream", session=sess)
    np.testing.assert_array_equal(ref.scores, got.scores)
    assert ref.iterations == got.iterations
    assert ref.converged == got.converged
    assert got.program_compiles == 1
    per_op = pagerank(edges, n, max_iters=15, session=sess)
    np.testing.assert_allclose(got.scores, per_op.scores, atol=1e-7)
    np.testing.assert_allclose(
        got.scores, pagerank_reference(edges, n, max_iters=15), atol=1e-5
    )


def test_pagerank_streaming_degrees_from_blocks():
    """Out-degrees are computed host-side block-at-a-time: padding rows in
    the final block must not leak edges into the degree vector."""
    n = 10
    edges = np.asarray([[0, 1], [0, 2], [3, 4]], np.int32)  # deg[0]=2
    sess = BlazeSession()
    cv = sess.chunked(edges, block_rows=2)  # last block padded
    got = pagerank(cv, n, max_iters=8, mode="stream", session=sess)
    ref = pagerank(edges, n, max_iters=8, mode="program", session=sess)
    np.testing.assert_array_equal(ref.scores, got.scores)
