"""Bench-harness tool tests: the unified BENCH_*.json schema checker and the
cross-PR regression comparison logic (no benchmarks are actually run)."""
import json
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)

from benchmarks.bench_regression import (  # noqa: E402
    best_prior,
    check_regressions,
    comparable_metrics,
)
from tools.bench_trends import flatten_walls  # noqa: E402
from tools.check_bench_schema import check_report  # noqa: E402

GOOD = {
    "bench": "BENCH_9",
    "scale": "smoke",
    "workload": {"rows": 128},
    "regression": {
        "algorithms": [{"name": "kmeans", "wall_s": 0.25}],
        "wall_total_s": 0.25,
    },
    "claims": {"bit_equal": True},
}


def _write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


def test_repo_reports_pass_schema():
    # results/ is gitignored — reports exist only after the benchmarks have
    # run (locally or in the bench-smoke CI job), so validate what's there.
    results = os.path.join(REPO, "results")
    reports = [
        os.path.join(results, f)
        for f in sorted(os.listdir(results) if os.path.isdir(results) else [])
        if f.startswith("BENCH_") and f.endswith(".json")
    ]
    if not reports:
        pytest.skip("no benchmark reports generated yet")
    for p in reports:
        assert check_report(p) == [], p


def test_schema_checker_accepts_good(tmp_path):
    assert check_report(_write(tmp_path, "BENCH_9.json", GOOD)) == []


@pytest.mark.parametrize("mutate,fragment", [
    (lambda d: d.update(bench="BENCH_1"), "bench must be"),
    (lambda d: d.pop("scale"), "scale"),
    (lambda d: d.update(workload={}), "workload"),
    (lambda d: d.update(claims={"x": "yes"}), "booleans"),
    (lambda d: d.pop("claims"), "claims"),
    (lambda d: d.pop("regression"), "payload"),
    (lambda d: d.update(extra={"also": {}}), "payload"),
    (lambda d: d.update(regression={"note": "no walls"}), "wall"),
])
def test_schema_checker_rejects_bad(tmp_path, mutate, fragment):
    doc = json.loads(json.dumps(GOOD))
    mutate(doc)
    errors = check_report(_write(tmp_path, "BENCH_9.json", doc))
    assert errors and any(fragment in e for e in errors), errors


def test_comparable_metrics_flatten():
    m = comparable_metrics(GOOD)
    assert m == {"regression.kmeans.wall_s": 0.25}
    # trend flattening includes the same paths plus section scalars
    walls = flatten_walls(GOOD)
    assert walls["regression.kmeans.wall_s"] == 0.25
    assert walls["regression.wall_total_s"] == 0.25


def test_best_prior_and_threshold(tmp_path):
    prior = json.loads(json.dumps(GOOD))
    prior["bench"] = "BENCH_7"
    prior["regression"]["algorithms"][0]["wall_s"] = 0.10
    _write(tmp_path, "BENCH_7.json", prior)
    slower = json.loads(json.dumps(GOOD))
    slower["regression"]["algorithms"][0]["wall_s"] = 0.30
    _write(tmp_path, "BENCH_9.json", slower)
    best = best_prior(str(tmp_path), exclude="BENCH_9.json")
    assert best == {"regression.kmeans.wall_s": 0.10}

    current = comparable_metrics(slower)
    # 3x the best prior: fails a 1.0 threshold (2x), passes a 4.0 one (5x)
    assert check_regressions(current, best, threshold=1.0)
    assert not check_regressions(current, best, threshold=4.0)
    # no prior at all -> baseline, never fails
    assert not check_regressions(current, {}, threshold=0.0)


def test_best_prior_skips_excluded_and_garbage(tmp_path):
    _write(tmp_path, "BENCH_9.json", GOOD)
    (tmp_path / "BENCH_4.json").write_text("{not json")
    best = best_prior(str(tmp_path), exclude="BENCH_9.json")
    assert best == {}
