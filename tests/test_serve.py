"""BlazeServe concurrency suite: plan-cache reuse, micro-batching,
bit-equality with direct session execution, and bounded-queue behaviour.

The acceptance workload (3 tenants x 20 mixed queries over pi / pagerank /
wordcount) must compile exactly 3 programs — one per distinct plan — while
coalescing compatible concurrent queries into micro-batched dispatches, and
every served result must be bit-equal to running the same query directly
against a fresh session.
"""
from __future__ import annotations

import threading
import time

import numpy as np
import pytest

from repro.core.session import BlazeSession
from repro.data import synthetic as S
from repro.serve import (
    BlazeClient,
    BlazeServer,
    QueueFullError,
    RemoteServeError,
    TenantLimitError,
    run_direct,
)

VOCAB = 64


def _register(server: BlazeServer) -> None:
    edges = S.rmat_edges(6, seed=3)
    lines, _ = S.zipf_corpus(128, 8, VOCAB, seed=3)
    server.register_dataset("edges", edges, n_pages=64)
    server.register_dataset("lines", lines, vocab_size=VOCAB)


def _mixed_workload() -> list[tuple[str, dict]]:
    """20 queries over 3 distinct plans (pi, pagerank, wordcount); pagerank
    varies ``iters`` — same plan, different inputs — to exercise honest
    coalescing, not just dedup."""
    work: list[tuple[str, dict]] = []
    for i in range(20):
        kind = i % 3
        if kind == 0:
            work.append(("pi", {"n_samples": 2048, "iters": 1 + i % 2}))
        elif kind == 1:
            work.append(("pagerank", {"iters": 2 + i % 4}))
        else:
            work.append(("wordcount", {"iters": 1}))
    return work


@pytest.fixture()
def server():
    srv = BlazeServer(max_queue=256, per_tenant_inflight=64, max_batch=8)
    _register(srv)
    srv.start()
    yield srv
    srv.stop()


def test_acceptance_three_tenants_twenty_queries(server):
    """The PR's headline contract: 3 tenants x 20 queries, 3 plans ->
    exactly 3 compiles, >= 1 micro-batched dispatch, bit-equal results."""
    tenants = ("alice", "bob", "carol")
    work = _mixed_workload()

    server.pause_dispatch()  # let the backlog form so batches are real
    reqs = [
        (t, q, p, server.submit(t, q, p))
        for t in tenants
        for (q, p) in work
    ]
    assert server.queue_depth == len(tenants) * len(work)
    server.resume_dispatch()
    for _t, _q, _p, r in reqs:
        assert r.done.wait(300), "request never completed"
        assert r.error is None, f"unexpected failure: {r.error}"

    # Exactly one compile per distinct plan — resubmissions and other
    # tenants ride the resident programs.
    assert server.stats.compiles == 3
    assert server.session.stats.program_compiles == 3
    assert server.stats.cache_hits + server.stats.compiles == \
        server.stats.dispatched_plans
    # Concurrent compatible queries really coalesced.
    assert server.stats.batched_dispatches >= 1
    assert server.stats.coalesced_queries >= 1
    assert server.stats.completed == len(reqs)
    assert server.stats.failed == 0

    # Bit-equality: every distinct (query, params) matches a fresh direct
    # session run of the same prepared query.
    distinct = {(q, tuple(sorted(p.items()))): (q, p) for _t, q, p, _r in reqs}
    for q, p in distinct.values():
        direct = run_direct(
            BlazeSession(), server.mesh, server.datasets, q, p
        )
        served = next(
            r.result for _t, q2, p2, r in reqs if (q2, p2) == (q, p)
        )
        for key, want in direct.items():
            got = served[key]
            if isinstance(want, float):
                assert got == want, (q, p, key)
            else:
                assert np.array_equal(np.asarray(got), np.asarray(want)), \
                    (q, p, key)
    # And every request with identical params got the identical payload.
    for _t, q, p, r in reqs:
        ref = next(
            r2.result for _t2, q2, p2, r2 in reqs if (q2, p2) == (q, p)
        )
        for key in ref:
            assert np.array_equal(
                np.asarray(r.result[key]), np.asarray(ref[key])
            )


def test_http_concurrency_stress(server):
    """N client threads x M queries over real HTTP: all succeed, compile
    count == distinct plan count, per-thread results agree."""
    n_threads, m_queries = 6, 5
    work = _mixed_workload()[: m_queries]
    results: dict[int, list] = {}
    errors: list[Exception] = []

    def worker(tid: int):
        client = BlazeClient(server.url, tenant=f"t{tid % 3}")
        out = []
        try:
            for q, p in work:
                r, meta = client.query(q, p)
                out.append((q, r, meta))
        except Exception as e:  # noqa: BLE001 — surfaced via `errors`
            errors.append(e)
        results[tid] = out

    threads = [
        threading.Thread(target=worker, args=(i,)) for i in range(n_threads)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    assert not errors, errors
    assert all(len(results[i]) == len(work) for i in range(n_threads))

    # compile count == number of distinct plans in the workload
    distinct_plans = {q for q, _p in work}
    assert server.stats.compiles == len(distinct_plans)
    # identical queries agree bit-for-bit across threads
    for j in range(len(work)):
        _q, ref, _m = results[0][j]
        for i in range(1, n_threads):
            _q2, got, _m2 = results[i][j]
            for key in ref:
                assert np.array_equal(np.asarray(ref[key]),
                                      np.asarray(got[key]))
    snap = server.stats.snapshot()
    assert snap["completed"] + snap["failed"] + snap["queued"] == \
        snap["submitted"]


def test_cached_resubmit_compiles_nothing(server):
    _r1, meta1 = server.submit_and_wait("alice", "pagerank", {"iters": 3})
    compiles = server.stats.compiles
    _r2, meta2 = server.submit_and_wait("bob", "pagerank", {"iters": 7})
    assert meta1["cache"] == "compile"
    assert meta2["cache"] == "hit"
    assert meta2["plan_hash"] == meta1["plan_hash"]
    assert server.stats.compiles == compiles  # 0 new compiles


def test_identical_concurrent_queries_dedup(server):
    server.pause_dispatch()
    reqs = [
        server.submit(f"t{i}", "pi", {"n_samples": 1024, "iters": 1})
        for i in range(4)
    ]
    server.resume_dispatch()
    for r in reqs:
        assert r.done.wait(120) and r.error is None
    metas = [r.meta["cache"] for r in reqs]
    assert metas.count("dedup") == 3, metas  # one execution served four
    assert server.stats.dedup_hits >= 3
    for r in reqs[1:]:
        assert np.array_equal(r.result["counts"], reqs[0].result["counts"])


def test_queue_saturation_returns_typed_error_fast():
    srv = BlazeServer(max_queue=4, per_tenant_inflight=16, max_batch=4)
    _register(srv)
    srv.start()
    try:
        srv.pause_dispatch()
        held = [
            srv.submit("alice", "pi", {"n_samples": 512, "iters": 1 + i})
            for i in range(4)
        ]
        t0 = time.perf_counter()
        with pytest.raises(QueueFullError):
            srv.submit("bob", "pi", {"n_samples": 512, "iters": 9})
        assert time.perf_counter() - t0 < 1.0, "rejection must not hang"
        # over HTTP the same overload is a typed 429, still bounded time
        client = BlazeClient(srv.url, tenant="carol")
        t0 = time.perf_counter()
        with pytest.raises(RemoteServeError) as ei:
            client.query("pi", {"n_samples": 512, "iters": 8})
        assert ei.value.code == "QUEUE_FULL"
        assert ei.value.status == 429
        assert time.perf_counter() - t0 < 2.0
        srv.resume_dispatch()
        for r in held:
            assert r.done.wait(120) and r.error is None
        snap = srv.stats.snapshot()
        assert snap["rejected_queue_full"] == 2
        assert snap["completed"] + snap["failed"] + snap["queued"] == \
            snap["submitted"]
    finally:
        srv.stop()


def test_per_tenant_limit():
    srv = BlazeServer(max_queue=64, per_tenant_inflight=2, max_batch=4)
    _register(srv)
    srv.start()
    try:
        srv.pause_dispatch()
        held = [
            srv.submit("alice", "pi", {"n_samples": 512, "iters": 1 + i})
            for i in range(2)
        ]
        with pytest.raises(TenantLimitError):
            srv.submit("alice", "pi", {"n_samples": 512, "iters": 9})
        # another tenant is unaffected by alice's budget
        other = srv.submit("bob", "pi", {"n_samples": 512, "iters": 1})
        srv.resume_dispatch()
        for r in held + [other]:
            assert r.done.wait(120) and r.error is None
        # budget released after completion: alice can submit again
        _r, _m = srv.submit_and_wait("alice", "pi",
                                     {"n_samples": 512, "iters": 1})
    finally:
        srv.stop()


def test_stats_endpoint_shape(server):
    server.submit_and_wait("alice", "pi", {"n_samples": 512, "iters": 1})
    snap = BlazeClient(server.url).stats()
    for key in (
        "submitted", "queued", "completed", "failed", "dispatches",
        "batched_dispatches", "coalesced_queries", "dedup_hits",
        "dispatched_plans", "cache_hits", "compiles", "p50_ms", "p99_ms",
        "throughput_qps", "pending_queue", "resident_programs", "session",
    ):
        assert key in snap, key
    assert snap["p50_ms"] <= snap["p99_ms"]
    assert snap["resident_programs"] >= 1
