"""Loop-aware HLO parser: trip-count extraction and dot/collective
accounting on a synthetic module and a real compiled program."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.hlo_analysis import _trip_count, parse_module

_SYNTH = """
HloModule test

%body.1 (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %w = f32[16,16] parameter(1)
  %x = f32[8,16] get-tuple-element(%p), index=1
  %dot.1 = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16] all-reduce(%dot.1), to_apply=%add
}

%cond.1 (arg: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p2), index=0
  %k = s32[] constant(12)
  %lt = pred[] compare(%i, %k), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %t = (s32[], f32[8,16]) tuple(%zero, %a)
  %loop = (s32[], f32[8,16]) while(%t), condition=%cond.1, body=%body.1
}
"""


def test_trip_count_from_cond():
    cond_lines = [
        "  %i = s32[] get-tuple-element(%p2), index=0",
        "  %k = s32[] constant(12)",
        "  %lt = pred[] compare(%i, %k), direction=LT",
    ]
    assert _trip_count(cond_lines) == 12


def test_synthetic_module_weighted():
    res = parse_module(_SYNTH)
    # dot: 2 · (8·16) · 16 = 4096 flops × 12 trips
    assert res["flops"] == 4096 * 12
    # all-reduce payload: 8·16·4 bytes × 12
    assert res["collectives"]["all-reduce"] == 8 * 16 * 4 * 12


def test_real_compiled_scan_matches_analytic():
    """A jitted scan of K matmuls must account K× the dot flops."""
    K, N = 7, 32
    w = jnp.eye(N) * 0.5

    def f(x):
        def body(c, _):
            return c @ w, None

        out, _ = jax.lax.scan(body, x, None, length=K)
        return out

    compiled = jax.jit(f).lower(jnp.ones((N, N))).compile()
    res = parse_module(compiled.as_text())
    expect = 2 * N * N * N * K
    assert res["flops"] == expect, (res["flops"], expect)
