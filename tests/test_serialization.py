"""The host-side serialization reference (paper §2.3.2): varint/tag-free
encode↔decode roundtrip property tests, plus the paper's 2-byte-vs-4-byte
(int, int) message-size claim checked against the tagged (Protobuf-style)
encoding.

Hypothesis gating mirrors tests/test_property.py: skip only when hypothesis
is genuinely absent; FAIL under REQUIRE_HYPOTHESIS (CI installs it)."""
import os

import numpy as np
import pytest

from repro.core.serialization import (
    blaze_decode_pairs,
    blaze_encode_pairs,
    message_sizes,
    protobuf_encode_pairs,
    varint_decode,
    varint_encode,
)

try:
    import hypothesis  # noqa: F401
except ImportError as e:
    if os.environ.get("REQUIRE_HYPOTHESIS"):
        raise ImportError(
            "REQUIRE_HYPOTHESIS is set but hypothesis failed to import — "
            "the property suite must run, not skip, in CI"
        ) from e
    pytest.skip("hypothesis not installed", allow_module_level=True)
from hypothesis import given, settings, strategies as st

I64 = st.integers(min_value=-(2**63), max_value=2**63 - 1)


# -- varint roundtrip ----------------------------------------------------------


@settings(max_examples=200, deadline=None)
@given(I64)
def test_varint_roundtrip_any_int64(v):
    buf = varint_encode(v)
    got, pos = varint_decode(buf, 0)
    assert got == v
    assert pos == len(buf)


@settings(max_examples=100, deadline=None)
@given(st.lists(I64, min_size=1, max_size=50))
def test_varint_stream_roundtrip(vs):
    """Concatenated varints decode back in order with no framing bytes —
    the tag-free property the paper's format relies on."""
    buf = b"".join(varint_encode(v) for v in vs)
    pos, got = 0, []
    for _ in vs:
        v, pos = varint_decode(buf, pos)
        got.append(v)
    assert got == vs and pos == len(buf)


def test_varint_length_brackets():
    """LEB128 length matches the 7-bit-per-byte bound on the wire."""
    for v, want in [(0, 1), (127, 1), (128, 2), (16383, 2), (16384, 3),
                    (2**63 - 1, 9)]:
        assert len(varint_encode(v)) == want, v
    # protobuf semantics: negatives always take the full 10 bytes
    assert len(varint_encode(-1)) == 10


# -- pair-stream roundtrip -----------------------------------------------------


@settings(max_examples=100, deadline=None)
@given(
    st.lists(
        st.tuples(I64, I64), min_size=0, max_size=40,
    )
)
def test_blaze_pairs_roundtrip(pairs):
    keys = np.asarray([p[0] for p in pairs], np.int64)
    vals = np.asarray([p[1] for p in pairs], np.int64)
    buf = blaze_encode_pairs(keys, vals)
    k2, v2 = blaze_decode_pairs(buf, len(pairs))
    np.testing.assert_array_equal(k2, keys)
    np.testing.assert_array_equal(v2, vals)


@settings(max_examples=100, deadline=None)
@given(st.lists(st.tuples(I64, I64), min_size=0, max_size=40))
def test_message_sizes_match_real_encoders(pairs):
    """The analytical byte accounting equals the bytes the encoders emit."""
    keys = np.asarray([p[0] for p in pairs], np.int64)
    vals = np.asarray([p[1] for p in pairs], np.int64)
    sizes = message_sizes(keys, vals)
    assert sizes["blaze_bytes"] == len(blaze_encode_pairs(keys, vals))
    assert sizes["protobuf_bytes"] == len(protobuf_encode_pairs(keys, vals))


# -- the paper's §2.3.2 claim --------------------------------------------------


def test_small_int_pair_is_2_bytes_vs_protobufs_4():
    """The paper's headline: a small (int, int) pair serialises to 2 bytes
    tag-free vs 4 bytes with Protobuf's per-field tag bytes."""
    keys = np.arange(128, dtype=np.int64)  # all single-varint-byte values
    vals = np.ones(128, dtype=np.int64)
    sizes = message_sizes(keys, vals)
    assert sizes["blaze_bytes"] == 2 * len(keys)
    assert sizes["protobuf_bytes"] == 4 * len(keys)
    # and the real encoders agree byte-for-byte with the claim
    assert len(blaze_encode_pairs(keys, vals)) == 2 * len(keys)
    assert len(protobuf_encode_pairs(keys, vals)) == 4 * len(keys)


@settings(max_examples=100, deadline=None)
@given(st.lists(st.tuples(I64, I64), min_size=1, max_size=40))
def test_tag_free_always_two_bytes_per_pair_smaller(pairs):
    """Protobuf's overhead is exactly its tag bytes: one per field, two
    fields per pair — for every payload, not just small ints."""
    keys = np.asarray([p[0] for p in pairs], np.int64)
    vals = np.asarray([p[1] for p in pairs], np.int64)
    sizes = message_sizes(keys, vals)
    assert sizes["protobuf_bytes"] - sizes["blaze_bytes"] == 2 * len(pairs)
