"""Data-pipeline prefetch: both sides of the historical hang.

The old implementation died silently when the worker raised (consumer blocked
forever on ``q.get``) and wedged the worker when the consumer abandoned the
iterator early (worker blocked forever on a full ``q.put``).  These are
regression tests for ``prefetch_iter``'s failure contract.
"""
import threading
import time

import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.data.pipeline import (
    _PREFETCH_THREAD_NAME,
    TokenPipeline,
    prefetch_iter,
)


def _live_prefetch_threads():
    return [
        t for t in threading.enumerate()
        if t.name == _PREFETCH_THREAD_NAME and t.is_alive()
    ]


def _wait_no_prefetch_threads(timeout=5.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if not _live_prefetch_threads():
            return True
        time.sleep(0.02)
    return not _live_prefetch_threads()


def test_prefetch_yields_all_items_in_order():
    got = list(prefetch_iter(lambda i: i * i, range(20), depth=3))
    assert got == [(i, i * i) for i in range(20)]
    assert _wait_no_prefetch_threads()


def test_prefetch_worker_exception_propagates():
    """A producer crash must re-raise at the consumer — not leave it blocked
    on an empty queue forever (the old silent-death hang)."""

    def produce(i):
        if i == 3:
            raise ZeroDivisionError("synthetic producer crash")
        return i * 2

    got = []
    with pytest.raises(ZeroDivisionError, match="synthetic producer crash"):
        for item, val in prefetch_iter(produce, range(10), depth=2):
            got.append(val)
    # everything before the crash was delivered
    assert got == [0, 2, 4]
    assert _wait_no_prefetch_threads()


def test_prefetch_exception_on_first_item():
    def produce(i):
        raise RuntimeError("dead on arrival")

    with pytest.raises(RuntimeError, match="dead on arrival"):
        list(prefetch_iter(produce, range(4)))
    assert _wait_no_prefetch_threads()


def test_prefetch_early_abandon_does_not_wedge_worker():
    """Breaking out of the loop must unblock the worker's bounded ``put``
    (the old consumer-abandonment hang left a thread spinning forever)."""
    produced = []

    def produce(i):
        produced.append(i)
        return i

    it = prefetch_iter(produce, range(10_000), depth=2)
    for item, _ in it:
        if item >= 2:
            break
    it.close()  # runs the generator's finally: stop + join
    assert _wait_no_prefetch_threads()
    # worker stopped long before draining the 10k items
    assert len(produced) < 100


def test_prefetch_abandon_via_gc():
    it = prefetch_iter(lambda i: i, range(10_000), depth=2)
    next(it)
    del it  # generator GC closes it -> finally -> stop/join
    assert _wait_no_prefetch_threads()


def test_token_pipeline_prefetch_matches_direct():
    cfg = get_arch("qwen3-0.6b").reduced()
    pipe = TokenPipeline(cfg, batch=2, seq_len=8, seed=3)
    direct = [pipe.host_batch(s) for s in range(4)]
    got = list(pipe.prefetch(0, 4))
    assert [s for s, _ in got] == [0, 1, 2, 3]
    for (s, b), ref in zip(got, direct):
        np.testing.assert_array_equal(np.asarray(b["inputs"]), ref["inputs"])
        np.testing.assert_array_equal(np.asarray(b["labels"]), ref["labels"])
    assert _wait_no_prefetch_threads()


def test_prefetch_deterministic_across_restart():
    cfg = get_arch("qwen3-0.6b").reduced()
    a = TokenPipeline(cfg, batch=2, seq_len=8, seed=7)
    b = TokenPipeline(cfg, batch=2, seq_len=8, seed=7)
    for (sa, ba), (sb, bb) in zip(a.prefetch(5, 3), b.prefetch(5, 3)):
        assert sa == sb
        np.testing.assert_array_equal(
            np.asarray(ba["inputs"]), np.asarray(bb["inputs"])
        )
