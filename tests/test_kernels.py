"""Per-kernel validation: Pallas (interpret=True) and chunked-jnp paths vs
the pure-jnp oracles, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention
from repro.kernels.kmeans_assign import kmeans_assign
from repro.kernels.segment_reduce import segment_reduce

rng = np.random.RandomState(0)


def t(shape, dtype=np.float32, scale=0.5):
    return jnp.asarray(rng.randn(*shape).astype(dtype) * scale)


ATTN_CASES = [
    # B, Hq, Hkv, Sq, Skv, D, causal, window, softcap
    (2, 4, 2, 64, 64, 32, True, None, 0.0),
    (1, 8, 8, 128, 128, 64, True, None, 0.0),
    (2, 4, 4, 96, 96, 32, True, 32, 0.0),
    (1, 4, 2, 64, 64, 32, False, None, 0.0),
    (1, 4, 2, 64, 64, 32, True, None, 20.0),
    (2, 8, 2, 1, 256, 64, True, None, 0.0),  # decode
    (1, 4, 4, 7, 133, 32, True, None, 0.0),  # ragged
    (1, 2, 1, 33, 65, 16, True, 16, 5.0),  # window + softcap + ragged
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_vs_ref(case):
    b, hq, hkv, sq, skv, d, causal, window, cap = case
    q, k, v = t((b, hq, sq, d)), t((b, hkv, skv, d)), t((b, hkv, skv, d))
    out = flash_attention(
        q, k, v, causal=causal, window=window, softcap=cap,
        block_q=32, block_k=32,
    )
    ref = R.attention_ref(q, k, v, causal=causal, window=window, softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@pytest.mark.parametrize("case", ATTN_CASES)
def test_chunked_attention_vs_ref(case):
    b, hq, hkv, sq, skv, d, causal, window, cap = case
    q, k, v = t((b, hq, sq, d)), t((b, hkv, skv, d)), t((b, hkv, skv, d))
    out = ops.attention_chunked(
        q, k, v, causal=causal, window=window, softcap=cap,
        block_q=32, block_k=32,
    )
    ref = R.attention_ref(q, k, v, causal=causal, window=window, softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_flash_attention_bf16():
    q = t((1, 4, 64, 32)).astype(jnp.bfloat16)
    k = t((1, 2, 64, 32)).astype(jnp.bfloat16)
    v = t((1, 2, 64, 32)).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=32, block_k=32)
    ref = R.attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    )
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), atol=2e-2
    )


def test_chunked_attention_grad_finite():
    q, k, v = t((1, 2, 32, 16)), t((1, 2, 32, 16)), t((1, 2, 32, 16))

    def f(q):
        return jnp.sum(ops.attention_chunked(q, k, v, block_q=16, block_k=16))

    g = jax.grad(f)(q)
    assert bool(jnp.isfinite(g).all())


@pytest.mark.parametrize(
    "n,v,k,bn", [(1000, 4, 8, 256), (37, 1, 3, 16), (4096, 16, 64, 512),
                 (100, 3, 1, 100)]
)
def test_segment_reduce_vs_ref(n, v, k, bn):
    ids = jnp.asarray(rng.randint(-1, k, n).astype(np.int32))
    vals = t((n, v))
    out = segment_reduce(ids, vals, k, block_n=bn)
    ref = R.segment_reduce_ref(ids, vals, k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


# -- generalized (monoid) segment-reduce ---------------------------------------


def _np_segment(ids, vals, k, reducer):
    fn = {"sum": np.add, "prod": np.multiply, "min": np.minimum,
          "max": np.maximum}[reducer]
    if np.issubdtype(vals.dtype, np.floating):
        ident = {"sum": 0.0, "prod": 1.0, "min": np.inf, "max": -np.inf}[reducer]
        acc = np.float64
    else:
        ident = {"sum": 0, "prod": 1, "min": np.iinfo(np.int32).max,
                 "max": np.iinfo(np.int32).min}[reducer]
        acc = np.int64
    out = np.full((k,) + vals.shape[1:], ident, acc)
    for i, s in enumerate(np.asarray(ids)):
        if 0 <= s < k:
            out[s] = fn(out[s], np.asarray(vals[i], acc))
    return out


@pytest.mark.parametrize("reducer", ["sum", "prod", "min", "max"])
@pytest.mark.parametrize(
    "n,v,k,bn",
    [
        (1000, 4, 8, 256),   # pair count not a multiple of the block
        (1023, 2, 13, 128),  # K not a multiple of 8
        (77, 3, 127, 16),    # K not a multiple of 8 or 128
        (513, 1, 129, 64),   # K just past a lane boundary
    ],
)
def test_segment_reduce_monoid_vs_numpy(reducer, n, v, k, bn):
    ids = jnp.asarray(rng.randint(-2, k + 2, n).astype(np.int32))
    if reducer == "prod":
        vals = jnp.asarray(
            rng.choice([1.0, -1.0, 0.5, 2.0], (n, v)).astype(np.float32)
        )
    else:
        vals = t((n, v))
    out = segment_reduce(ids, vals, k, reducer=reducer, block_n=bn)
    ref = _np_segment(np.asarray(ids), np.asarray(vals), k, reducer)
    np.testing.assert_allclose(
        np.asarray(out, np.float64), ref, rtol=2e-4, atol=1e-4
    )


@pytest.mark.parametrize("reducer", ["sum", "min", "max", "prod"])
def test_segment_reduce_int32_exact(reducer):
    n, v, k = 333, 2, 11
    ids = jnp.asarray(rng.randint(-1, k + 1, n).astype(np.int32))
    if reducer == "prod":
        vals = jnp.asarray(rng.choice([1, -1, 2], (n, v)).astype(np.int32))
    else:
        vals = jnp.asarray(rng.randint(-50, 50, (n, v)).astype(np.int32))
    out = segment_reduce(ids, vals, k, reducer=reducer)
    assert out.dtype == jnp.int32
    ref = _np_segment(np.asarray(ids), np.asarray(vals), k, reducer)
    np.testing.assert_array_equal(np.asarray(out, np.int64), ref)


@pytest.mark.parametrize("n", [1, 7, 255, 1025])
def test_segment_reduce_interpret_equals_segment_sum(n):
    """Interpret-mode kernel ≡ jax.ops.segment_sum on the same drop mask."""
    k = 9
    ids = jnp.asarray(rng.randint(-1, k, n).astype(np.int32))
    vals = t((n, 3))
    out = segment_reduce(ids, vals, k, interpret=True)
    safe = jnp.where(ids >= 0, ids, k)
    want = jax.ops.segment_sum(vals, safe, num_segments=k + 1)[:k]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4)


def test_segment_reduce_autotune_and_lanes():
    from repro.kernels.segment_reduce import (
        choose_block_n,
        segment_reduce_lanes,
    )

    # tiny working sets → max block; huge K → small block; floor respected
    assert choose_block_n(100_000, 8, 4) == 2048
    assert choose_block_n(100_000, 20_000, 1, "sum", np.int32) <= 64
    assert choose_block_n(5, 8, 4) >= 8
    bn, lanes = segment_reduce_lanes(1000, 8, 4)
    assert lanes % bn == 0 and lanes >= 1000
    # autotuned call agrees with the oracle
    ids = jnp.asarray(rng.randint(0, 8, 1000).astype(np.int32))
    vals = t((1000, 4))
    out = segment_reduce(ids, vals, 8)  # block_n=None → choose_block_n
    ref = R.segment_reduce_ref(ids, vals, 8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_segment_reduce_nan_in_dropped_lane_stays_out():
    """A non-finite value on a dropped lane (id<0 / id>=K) must not leak:
    0·NaN = NaN through the one-hot matmul unless the lane is zeroed."""
    ids = jnp.asarray(np.array([0, -1, 9], np.int32))  # -1 dropped, 9 >= K
    vals = jnp.asarray(np.array([[1.0], [np.nan], [np.inf]], np.float32))
    out = segment_reduce(ids, vals, 2, reducer="sum")
    np.testing.assert_array_equal(np.asarray(out), [[1.0], [0.0]])


def test_segment_reduce_empty_stream_returns_identity():
    for reducer, ident in [("sum", 0.0), ("prod", 1.0), ("min", np.inf),
                           ("max", -np.inf)]:
        out = segment_reduce(
            jnp.zeros((0,), jnp.int32), jnp.zeros((0, 3), jnp.float32), 4,
            reducer=reducer,
        )
        assert out.shape == (4, 3)
        np.testing.assert_array_equal(np.asarray(out), np.full((4, 3), ident))


def test_segment_reduce_rejects_unknown_reducer():
    ids = jnp.zeros((4,), jnp.int32)
    vals = jnp.zeros((4, 1), jnp.float32)
    with pytest.raises(ValueError, match="unknown reducer"):
        segment_reduce(ids, vals, 2, reducer="mean")


@pytest.mark.parametrize("n,d,k,bn", [(1000, 3, 5, 256), (777, 8, 13, 128),
                                      (64, 2, 2, 64)])
def test_kmeans_assign_vs_ref(n, d, k, bn):
    pts = t((n, d))
    ctr = t((k, d))
    a, stats = kmeans_assign(pts, ctr, block_n=bn)
    a_ref, stats_ref = R.kmeans_assign_ref(pts, ctr)
    assert bool(jnp.all(a == a_ref))
    np.testing.assert_allclose(np.asarray(stats), np.asarray(stats_ref), atol=1e-3)


@pytest.mark.parametrize("chunk", [8, 16, 64])
@pytest.mark.parametrize("with_init", [False, True])
def test_ssd_chunked_vs_ref(chunk, with_init):
    B, S, H, P, G, N = 2, 100, 4, 8, 2, 16
    x = t((B, S, H, P))
    dt = jnp.abs(t((B, S, H), scale=0.3)) + 0.01
    a = -jnp.abs(t((H,), scale=2.0)) - 0.1
    b = t((B, S, G, N))
    c = t((B, S, G, N))
    h0 = t((B, H, P, N)) if with_init else None
    y1, hT1 = ops.ssd_chunked(x, dt, a, b, c, chunk=chunk, init_state=h0)
    y2, hT2 = R.ssd_ref(x, dt, a, b, c, init_state=h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(hT1), np.asarray(hT2), atol=2e-5)


def test_ssd_extreme_decay_no_nan():
    """The inf·0 upper-triangle hazard (regression for the zamba2 NaN)."""
    B, S, H, P, G, N = 1, 64, 2, 4, 1, 8
    x = t((B, S, H, P))
    dt = jnp.abs(t((B, S, H), scale=2.0)) + 1.0  # large steps
    a = jnp.asarray([-16.0, -8.0])
    b, c = t((B, S, G, N)), t((B, S, G, N))
    y, hT = ops.ssd_chunked(x, dt, a, b, c, chunk=16)
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(hT).all())


@pytest.mark.parametrize("chunk", [8, 16, 32])
@pytest.mark.parametrize("with_init", [False, True])
def test_rwkv6_chunked_vs_ref(chunk, with_init):
    B, S, H, K, V = 2, 70, 2, 8, 8
    r, k, v = t((B, S, H, K)), t((B, S, H, K)), t((B, S, H, V))
    w = jax.nn.sigmoid(t((B, S, H, K))) * 0.8 + 0.15
    u = t((H, K))
    s0 = t((B, H, K, V)) if with_init else None
    y1, sT1 = ops.rwkv6_chunked(r, k, v, w, u, chunk=chunk, init_state=s0)
    y2, sT2 = R.rwkv6_ref(r, k, v, w, u, init_state=s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=5e-5)
    np.testing.assert_allclose(np.asarray(sT1), np.asarray(sT2), atol=5e-5)


def test_decode_chaining_equals_full_scan():
    """prefill-chunk + per-token decode == one full pass (SSD + RWKV)."""
    B, S, H, P, G, N = 1, 48, 2, 4, 1, 8
    x = t((B, S, H, P))
    dt = jnp.abs(t((B, S, H), scale=0.2)) + 0.01
    a = -jnp.abs(t((H,))) - 0.1
    b, c = t((B, S, G, N)), t((B, S, G, N))
    y_full, h_full = R.ssd_ref(x, dt, a, b, c)
    y1, h1 = ops.ssd_chunked(x[:, :32], dt[:, :32], a, b[:, :32], c[:, :32], chunk=16)
    ys = [y1]
    h = h1
    for i in range(32, S):
        yi, h = ops.ssd_chunked(
            x[:, i : i + 1], dt[:, i : i + 1], a, b[:, i : i + 1],
            c[:, i : i + 1], chunk=16, init_state=h,
        )
        ys.append(yi)
    y_chain = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chain), np.asarray(y_full), atol=3e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full), atol=3e-5)


@pytest.mark.parametrize("chunk", [16, 32])
def test_ssd_pallas_vs_ref(chunk):
    from repro.kernels.ssd_scan import ssd_scan

    B, S, H, P, G, N = 2, 96, 4, 8, 2, 16
    x = t((B, S, H, P))
    dt = jnp.abs(t((B, S, H), scale=0.3)) + 0.01
    a = -jnp.abs(t((H,), scale=2.0)) - 0.1
    b, c = t((B, S, G, N)), t((B, S, G, N))
    y1, h1 = ssd_scan(x, dt, a, b, c, chunk=chunk)
    y2, h2 = R.ssd_ref(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=3e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=3e-5)


@pytest.mark.parametrize("chunk", [16, 32])
def test_rwkv6_pallas_vs_ref(chunk):
    from repro.kernels.rwkv6_scan import rwkv6_scan

    B, S, H, K, V = 2, 64, 2, 8, 8
    r, k, v = t((B, S, H, K)), t((B, S, H, K)), t((B, S, H, V))
    w = jax.nn.sigmoid(t((B, S, H, K))) * 0.8 + 0.15
    u = t((H, K))
    y1, s1 = rwkv6_scan(r, k, v, w, u, chunk=chunk)
    y2, s2 = R.rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=5e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=5e-5)
