"""Checkpoint manager: atomic commit, keep-N, async save, elastic restore."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    rng = np.random.RandomState(seed)
    return {
        "a": jnp.asarray(rng.randn(4, 8).astype(np.float32)),
        "b": [jnp.asarray(rng.randn(3)), jnp.asarray(7, jnp.int32)],
    }


def test_save_restore_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        t = _tree()
        mgr.save(10, t)
        step, got = mgr.restore_latest(t)
        assert step == 10
        for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_keep_n_garbage_collection():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _tree(s))
        assert mgr.all_steps() == [3, 4]


def test_async_save_and_wait():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(5, _tree(), blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 5


def test_unfinished_tmp_dirs_ignored():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, _tree())
        os.makedirs(os.path.join(d, "step_00000002.tmp-deadbeef"))
        assert mgr.latest_step() == 1
        # gc cleans orphans on the next save
        mgr.save(3, _tree())
        assert not any(".tmp-" in n for n in os.listdir(d))


def test_restore_mismatched_tree_raises():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, _tree())
        with pytest.raises(ValueError):
            mgr.restore(1, {"only_one": jnp.zeros(3)})


def test_elastic_restore_with_explicit_sharding():
    """Checkpoints hold logical arrays: restore onto any device layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.containers import data_mesh

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        t = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
        mgr.save(1, t)
        mesh = data_mesh()
        sh = {"w": NamedSharding(mesh, P("data", None))}
        got = mgr.restore(1, t, shardings=sh)
        np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))
        assert got["w"].sharding == sh["w"]
