"""Checkpoint manager: atomic commit, keep-N, async save, elastic restore."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    rng = np.random.RandomState(seed)
    return {
        "a": jnp.asarray(rng.randn(4, 8).astype(np.float32)),
        "b": [jnp.asarray(rng.randn(3)), jnp.asarray(7, jnp.int32)],
    }


def test_save_restore_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        t = _tree()
        mgr.save(10, t)
        step, got = mgr.restore_latest(t)
        assert step == 10
        for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_keep_n_garbage_collection():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _tree(s))
        assert mgr.all_steps() == [3, 4]


def test_async_save_and_wait():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(5, _tree(), blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 5


def test_unfinished_tmp_dirs_ignored():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, _tree())
        os.makedirs(os.path.join(d, "step_00000002.tmp-deadbeef"))
        assert mgr.latest_step() == 1
        # gc cleans orphans on the next save
        mgr.save(3, _tree())
        assert not any(".tmp-" in n for n in os.listdir(d))


def test_restore_mismatched_tree_raises():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, _tree())
        with pytest.raises(ValueError):
            mgr.restore(1, {"only_one": jnp.zeros(3)})


def test_elastic_restore_with_explicit_sharding():
    """Checkpoints hold logical arrays: restore onto any device layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.containers import data_mesh

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        t = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
        mgr.save(1, t)
        mesh = data_mesh()
        sh = {"w": NamedSharding(mesh, P("data", None))}
        got = mgr.restore(1, t, shardings=sh)
        np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))
        assert got["w"].sharding == sh["w"]


# -- crash injection: the commit swap never loses a complete checkpoint -------


class _SimulatedCrash(RuntimeError):
    pass


def _crashing_rename(monkeypatch, crash_on_call: int):
    """Patch ``os.rename`` so the ``crash_on_call``-th call inside the manager
    raises — simulating death at that instant (later steps never run)."""
    import repro.checkpoint.manager as M

    real = os.rename
    calls = {"n": 0}

    def rename(src, dst):
        calls["n"] += 1
        if calls["n"] == crash_on_call:
            raise _SimulatedCrash(f"died at rename #{calls['n']}")
        return real(src, dst)

    monkeypatch.setattr(M.os, "rename", rename)
    return calls


def test_crash_before_any_rename_keeps_previous(monkeypatch):
    """Death between the tmp write and the first rename: the previous
    checkpoint is untouched and the orphan tmp dir is skipped/cleaned."""
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        t = _tree()
        mgr.save(1, t)
        _crashing_rename(monkeypatch, crash_on_call=1)
        with pytest.raises(_SimulatedCrash):
            mgr.save(2, _tree(2))
        monkeypatch.undo()
        mgr2 = CheckpointManager(d)  # fresh process
        step, got = mgr2.restore_latest(t)
        assert step == 1
        for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_crash_between_swap_renames_rolls_back(monkeypatch):
    """Death after ``rename(final, .old-)`` but before ``rename(tmp, final)``:
    recovery must roll the complete .old- copy back into place.  (The old
    ``rmtree(final); rename(tmp)`` commit lost the checkpoint here.)"""
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        t = _tree()
        mgr.save(1, t)  # overwritten below: same step, new payload
        _crashing_rename(monkeypatch, crash_on_call=2)
        with pytest.raises(_SimulatedCrash):
            mgr.save(1, _tree(99))
        monkeypatch.undo()
        # mid-crash state: step_1 gone, step_1.old-* holds the only copy
        assert any(".old-" in n for n in os.listdir(d))
        mgr2 = CheckpointManager(d)  # fresh process runs _recover()
        step, got = mgr2.restore_latest(t)
        assert step == 1
        for x, y in zip(jax.tree.leaves(_tree()), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert not any(".old-" in n for n in os.listdir(d))


def test_crash_after_commit_drops_old_copy(monkeypatch):
    """Death after ``rename(tmp, final)`` but before the old copy is deleted:
    the NEW checkpoint wins and recovery garbage-collects the .old- dir."""
    import shutil as _shutil

    import repro.checkpoint.manager as M

    real_rmtree = _shutil.rmtree
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        t = _tree()
        mgr.save(1, t)

        def boom(path, ignore_errors=False):
            raise _SimulatedCrash("died before deleting the old copy")

        monkeypatch.setattr(M.shutil, "rmtree", boom)
        with pytest.raises(_SimulatedCrash):
            mgr.save(1, _tree(99))
        monkeypatch.setattr(M.shutil, "rmtree", real_rmtree)
        assert any(".old-" in n for n in os.listdir(d))
        mgr2 = CheckpointManager(d)
        step, got = mgr2.restore_latest(t)
        assert step == 1  # the new payload committed
        for x, y in zip(jax.tree.leaves(_tree(99)), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert not any(".old-" in n for n in os.listdir(d))


def test_restore_latest_skips_partial_dirs():
    """``restore_latest`` never picks a .tmp-/.old-/manifest-less dir even
    when its name sorts above every complete step."""
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        t = _tree()
        mgr.save(3, t)
        os.makedirs(os.path.join(d, "step_00000009.tmp-deadbeef"))
        # torn dir with no manifest (crashed mid-write, pre-rename layout)
        os.makedirs(os.path.join(d, "step_00000007"))
        step, _ = mgr.restore_latest(t)
        assert step == 3
        assert mgr.all_steps() == [3]


def test_concurrent_async_saves_and_restores():
    """Async-save _gc churning old steps must never make restore_latest fail
    or return a torn tree (the retry + _recover contract)."""
    import threading

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=1)
        t = _tree()
        mgr.save(0, t)
        errors = []

        def writer():
            try:
                for s in range(1, 25):
                    mgr.save(s, _tree(s), blocking=False)
                    mgr.wait()
            except Exception as e:  # pragma: no cover - failure path
                errors.append(e)

        wt = threading.Thread(target=writer)
        wt.start()
        try:
            while wt.is_alive():
                step, got = mgr.restore_latest(t)
                assert step is not None
                assert len(jax.tree.leaves(got)) == len(jax.tree.leaves(t))
        finally:
            wt.join()
        assert not errors


def test_blockstore_roundtrip_and_atomicity():
    from repro.checkpoint.manager import BlockStore

    with tempfile.TemporaryDirectory() as d:
        bs = BlockStore(d)
        bs.put("block_000001", b"abc" * 100)
        assert bs.has("block_000001")
        assert bs.get("block_000001") == b"abc" * 100
        bs.put("block_000001", b"xyz")  # overwrite is atomic (os.replace)
        assert bs.get("block_000001") == b"xyz"
        assert bs.bytes_written == 303
        assert not any(".tmp-" in n for n in os.listdir(d))
        bs.delete("block_000001")
        assert not bs.has("block_000001")
        bs.delete("block_000001")  # idempotent
