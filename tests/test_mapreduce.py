"""Unit tests for the MapReduce engine internals."""
import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EMPTY_KEY,
    DistRange,
    custom_reducer,
    data_mesh,
    distribute,
    foreach,
    get_reducer,
    make_dist_hashmap,
    map_reduce,
    topk,
)
from repro.core.containers import (
    HashTable,
    hash32,
    hashmap_insert,
    make_table,
    unique_combine,
)
from repro.core.mapreduce import bucket_by_dest


# -- reducers ----------------------------------------------------------------


@pytest.mark.parametrize("name,fn", [("sum", np.sum), ("min", np.min),
                                     ("max", np.max), ("prod", np.prod)])
def test_builtin_reducer_segment(name, fn):
    red = get_reducer(name)
    rng = np.random.RandomState(0)
    vals = jnp.asarray(rng.rand(64).astype(np.float32) + 0.5)
    ids = jnp.asarray(rng.randint(0, 5, 64))
    out = red.segment(vals, ids, 5)
    for k in range(5):
        ref = fn(np.asarray(vals)[np.asarray(ids) == k])
        assert abs(float(out[k]) - ref) < 1e-3 * max(1, abs(ref))


def test_unknown_reducer_raises():
    with pytest.raises(ValueError):
        get_reducer("bogus")


def test_custom_reducer_segment_and_collective():
    red = custom_reducer(
        "lse", lambda a, b: jnp.logaddexp(a, b),
        lambda dt: jnp.asarray(-jnp.inf, dt),
    )
    vals = jnp.asarray(np.random.RandomState(1).rand(32).astype(np.float32))
    ids = jnp.asarray(np.arange(32) % 3)
    out = red.segment(vals, ids, 3)
    ref = np.full(3, -np.inf)
    for i in range(32):
        ref[i % 3] = np.logaddexp(ref[i % 3], float(vals[i]))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5)


# -- unique_combine (eager reduction primitive) -------------------------------


def test_unique_combine_sums_duplicates():
    red = get_reducer("sum")
    keys = jnp.asarray([5, 3, 5, 3, 5, 9], jnp.int32)
    vals = jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    mask = jnp.asarray([True] * 6)
    k, v, valid = unique_combine(keys, vals, mask, red)
    got = {int(kk): float(vv) for kk, vv, m in zip(k, v, valid) if m}
    assert got == {5: 9.0, 3: 6.0, 9: 6.0}


def test_unique_combine_respects_mask():
    red = get_reducer("sum")
    keys = jnp.asarray([1, 1, 2], jnp.int32)
    vals = jnp.asarray([10.0, 20.0, 30.0])
    mask = jnp.asarray([True, False, True])
    k, v, valid = unique_combine(keys, vals, mask, red)
    got = {int(kk): float(vv) for kk, vv, m in zip(k, v, valid) if m}
    assert got == {1: 10.0, 2: 30.0}


# -- hash table ----------------------------------------------------------------


def test_hashmap_insert_basic_and_merge():
    red = get_reducer("sum")
    t = make_table(64, (), jnp.float32, red)
    keys = jnp.asarray([3, 17, 99], jnp.int32)
    vals = jnp.asarray([1.0, 2.0, 3.0])
    t = hashmap_insert(t, keys, vals, jnp.asarray([True] * 3), red)
    t = hashmap_insert(t, keys, vals, jnp.asarray([True, True, False]), red)
    live = {int(k): float(v) for k, v in zip(t.keys, t.vals) if k != EMPTY_KEY}
    assert live == {3: 2.0, 17: 4.0, 99: 3.0}
    assert int(t.overflow) == 0


def test_hashmap_collision_pressure():
    """Many keys into a small table: correct under heavy probing."""
    red = get_reducer("sum")
    n = 48
    t = make_table(128, (), jnp.float32, red)
    keys = jnp.asarray(np.arange(n) * 7919, jnp.int32)
    vals = jnp.ones((n,), jnp.float32)
    t = hashmap_insert(t, keys, vals, jnp.ones(n, bool), red, max_probes=64)
    live = {int(k) for k in t.keys if k != EMPTY_KEY}
    assert int(t.overflow) == 0
    assert live == {int(k) for k in keys}


def test_hashmap_overflow_counted():
    red = get_reducer("sum")
    t = make_table(8, (), jnp.float32, red)  # capacity 8 < 32 keys
    keys = jnp.asarray(np.arange(32), jnp.int32)
    t = hashmap_insert(t, keys, jnp.ones(32), jnp.ones(32, bool), red, max_probes=8)
    assert int(t.overflow) == 32 - int((np.asarray(t.keys) != EMPTY_KEY).sum())
    assert int(t.overflow) > 0


# -- bucketing ----------------------------------------------------------------


def test_bucket_by_dest_places_all_pairs():
    keys = jnp.asarray(np.arange(40), jnp.int32)
    vals = jnp.asarray(np.arange(40, dtype=np.float32))
    valid = jnp.ones(40, bool)
    bkeys, bvals, dropped = bucket_by_dest(keys, vals, valid, 4, 20, 0.0)
    assert int(dropped) == 0
    live = np.asarray(bkeys).reshape(-1)
    assert sorted(live[live != EMPTY_KEY]) == list(range(40))
    # every pair landed in the bucket its hash owns
    from repro.core.containers import shard_of_key

    dest = np.asarray(shard_of_key(keys, 4))
    for d in range(4):
        row = np.asarray(bkeys[d])
        for k in row[row != EMPTY_KEY]:
            assert dest[int(np.where(np.asarray(keys) == k)[0][0])] == d


def test_bucket_capacity_drops_counted():
    keys = jnp.zeros(32, jnp.int32)  # all same key → same destination
    vals = jnp.ones(32, jnp.float32)
    bkeys, bvals, dropped = bucket_by_dest(keys, vals, jnp.ones(32, bool), 4, 8, 0.0)
    assert int(dropped) == 32 - 8


# -- engine-level --------------------------------------------------------------


def test_engines_agree_on_hash_target():
    rng = np.random.RandomState(0)
    words = rng.randint(0, 40, 500).astype(np.int32)
    wv = distribute(words)

    def m(i, w, emit):
        emit(w, 1)

    outs = {}
    for engine in ("eager", "naive"):
        hm = make_dist_hashmap(data_mesh(), 512, (), jnp.int32, "sum")
        outs[engine] = map_reduce(wv, m, "sum", hm, engine=engine).to_dict()
    assert {k: int(v) for k, v in outs["eager"].items()} == {
        k: int(v) for k, v in outs["naive"].items()
    }


def test_wire_modes_close_to_exact():
    pts = np.random.RandomState(2).randn(256, 4).astype(np.float32)
    v = distribute(pts)

    def m(i, x, emit):
        emit(i % 8, x)

    t = jnp.zeros((8, 4), jnp.float32)
    exact = np.asarray(map_reduce(v, m, "sum", t))
    for wire, tol in [("bf16", 2e-2), ("int8", 2e-2)]:
        got = np.asarray(map_reduce(v, m, "sum", t, wire=wire))
        denom = np.abs(exact).max()
        assert np.abs(got - exact).max() / denom < tol, wire


def test_foreach_env_and_cache_reuse():
    from repro.core.containers import _FOREACH_CACHE

    v = distribute(np.arange(16, dtype=np.float32))
    n0 = len(_FOREACH_CACHE)

    def f(x, env):
        return x * env

    for scale in (2.0, 3.0, 4.0):
        v2 = foreach(v, f, env=jnp.asarray(scale))
    assert len(_FOREACH_CACHE) == n0 + 1
    np.testing.assert_allclose(np.asarray(v2.data)[:16], np.arange(16) * 4.0)


def test_distrange_source():
    def m(v, emit):
        emit(0, v)

    out = map_reduce(DistRange(0, 100, 1), m, "sum", jnp.zeros((1,), jnp.int32))
    assert int(out[0]) == sum(range(100))


def test_emit_batch_with_mask():
    lines = np.asarray([[1, 2, -1], [3, -1, -1]], np.int32)
    v = distribute(lines)

    def m(i, toks, emit):
        emit(toks, 1, mask=toks >= 0)

    out = map_reduce(v, m, "sum", jnp.zeros((8,), jnp.int32))
    assert [int(x) for x in out[:4]] == [0, 1, 1, 1]


# -- unique_combine sentinel boundaries ---------------------------------------
# The sort used to push masked slots to INT32_MAX, conflating them with
# genuine INT32_MAX keys and dropping genuine EMPTY_KEY keys; the mask now
# rides through the sort (lexsort on (key, liveness)) so every int32 key is a
# legal user key.

INT32_MAX = np.iinfo(np.int32).max


def _combine_oracle(keys, vals, mask):
    want: dict = {}
    for k, v, m in zip(keys, vals, mask):
        if m:
            want[int(k)] = want.get(int(k), 0.0) + float(v)
    return want


def _combine_got(keys, vals, mask):
    red = get_reducer("sum")
    k, v, valid = unique_combine(
        jnp.asarray(keys, jnp.int32), jnp.asarray(vals, jnp.float32),
        jnp.asarray(mask, bool), red,
    )
    return {int(a): float(b) for a, b, m in zip(k, v, valid) if m}


@pytest.mark.parametrize(
    "keys,mask",
    [
        # genuine INT32_MAX keys next to masked slots
        ([INT32_MAX, 7, INT32_MAX, 7], [True, True, False, True]),
        # genuine EMPTY_KEY (INT32_MIN) keys must come out valid
        ([EMPTY_KEY, EMPTY_KEY, 3], [True, True, True]),
        # masked slot whose key VALUE collides with a live key
        ([5, 5, 5], [True, False, True]),
        # all masked
        ([1, 2, 3], [False, False, False]),
        # masked INT32_MAX only — must produce nothing
        ([INT32_MAX, 2], [False, True]),
        # both sentinels live at once
        ([EMPTY_KEY, INT32_MAX, EMPTY_KEY, INT32_MAX],
         [True, True, True, False]),
    ],
)
def test_unique_combine_boundary_keys_match_dict_oracle(keys, mask):
    vals = [float(i + 1) for i in range(len(keys))]
    assert _combine_got(keys, vals, mask) == _combine_oracle(keys, vals, mask)


def test_unique_combine_boundary_fuzz():
    rng = np.random.RandomState(11)
    pool = np.asarray(
        [EMPTY_KEY, EMPTY_KEY + 1, -1, 0, 1, INT32_MAX - 1, INT32_MAX],
        np.int64,
    )
    for _ in range(25):
        n = rng.randint(1, 64)
        keys = pool[rng.randint(0, len(pool), n)]
        vals = rng.randint(0, 100, n).astype(np.float64)  # exact in f32
        mask = rng.rand(n) < 0.7
        got = _combine_got(keys, vals, mask)
        assert got == _combine_oracle(keys, vals, mask)
