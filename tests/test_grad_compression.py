"""Compressed gradient all-reduce (the fast-serialization analogue on the
training path): convergence parity vs the exact wire, on a real 8-device
mesh (subprocess), plus wire-byte accounting."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_load_file_roundtrip(tmp_path):
    from repro.core.algorithms import counts_dict, wordcount
    from repro.data.text import load_file

    p = tmp_path / "corpus.txt"
    p.write_text("the cat sat\nthe cat\nthe\n")
    rows, vocab = load_file(str(p))
    assert rows.shape[0] == 3
    hm = wordcount(rows)
    got = {vocab[k]: v for k, v in counts_dict(hm).items()}
    assert got == {"the": 3, "cat": 2, "sat": 1}


def test_grad_wire_bytes_accounting():
    from repro.distributed.dp_train import grad_wire_bytes

    params = {"w": jnp.zeros((1000, 10), jnp.float32)}
    assert grad_wire_bytes(params, "none") == 40_000
    assert grad_wire_bytes(params, "bf16") == 20_000
    # int8 frames ship the shared f32 scale alongside the lattice
    assert grad_wire_bytes(params, "int8") == 10_000 + 4


def test_compressed_training_convergence_parity_8dev():
    code = """
import json, numpy as np, jax, jax.numpy as jnp
from repro.configs.base import get_arch
from repro.core.containers import data_mesh
from repro.distributed.dp_train import init_residuals, make_dp_train_step
from repro.models import model as M
from repro.optim.adamw import AdamW

cfg = get_arch("qwen3-0.6b").reduced()
mesh = data_mesh()
opt = AdamW(lr=2e-3)

def loss_fn(params, inputs, labels):
    return M.loss_fn(params, cfg, inputs, labels, remat=False)

out = {}
for wire in ("none", "int8"):
    params = M.init(jax.random.PRNGKey(0), cfg)
    ostate = opt.init(params)
    resid = init_residuals(params)
    step = make_dp_train_step(loss_fn, opt, mesh, wire=wire)
    rng = np.random.RandomState(0)
    losses = []
    for i in range(20):
        toks = jnp.asarray(rng.randint(0, cfg.vocab, (8, 16)), jnp.int32)
        batch = {"inputs": toks, "labels": toks}
        params, ostate, resid, loss = step(params, ostate, resid, batch)
        losses.append(float(loss))
    out[wire] = losses
print(json.dumps(out))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=900,
    )
    assert p.returncode == 0, p.stderr[-3000:]
    res = json.loads(p.stdout.strip().splitlines()[-1])
    exact, comp = res["none"], res["int8"]
    assert comp[-1] < comp[0], "compressed run must converge"
    # int8 + error feedback tracks the exact wire closely
    assert abs(comp[-1] - exact[-1]) / exact[-1] < 0.05, (exact[-1], comp[-1])
