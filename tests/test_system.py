"""End-to-end behaviour tests: the paper's five applications + π, each
validated against an independent numpy oracle, on both engines."""
import collections

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import data_mesh, distribute, make_dist_hashmap, map_reduce
from repro.core.algorithms import (
    counts_dict,
    estimate_pi,
    estimate_pi_handrolled,
    gmm_em,
    gmm_em_reference,
    kmeans,
    kmeans_reference,
    knn,
    knn_full_sort,
    pagerank,
    pagerank_reference,
    wordcount,
)
from repro.data.synthetic import cluster_points, rmat_edges, zipf_corpus


def test_pi_close():
    pi = estimate_pi(200_000)
    assert abs(pi - np.pi) < 0.02


def test_pi_engines_agree():
    assert estimate_pi(50_000, engine="eager") == estimate_pi(50_000, engine="naive")


def test_pi_handrolled_matches_mapreduce():
    assert abs(estimate_pi(50_000) - estimate_pi_handrolled(50_000)) < 1e-9


@pytest.mark.parametrize("engine", ["eager", "naive"])
def test_wordcount_exact(engine):
    lines, true_counts = zipf_corpus(400, 12, 800, seed=3)
    hm = wordcount(lines, engine=engine)
    got = counts_dict(hm)
    want = {i: int(c) for i, c in enumerate(true_counts) if c}
    assert got == want
    assert hm.total_overflow() == 0


@pytest.mark.parametrize("engine", ["eager", "naive"])
def test_pagerank_matches_reference(engine):
    edges = rmat_edges(7, 8, seed=1)
    n = 128
    res = pagerank(edges, n, tol=1e-7, max_iters=100, engine=engine)
    ref = pagerank_reference(edges, n, tol=1e-7, max_iters=100)
    assert res.converged
    assert np.abs(res.scores - ref).max() / ref.max() < 1e-4


def test_pagerank_eager_ships_fewer_bytes():
    edges = rmat_edges(7, 8, seed=1)
    r_eager = pagerank(edges, 128, max_iters=3, tol=0)
    r_naive = pagerank(edges, 128, max_iters=3, tol=0, engine="naive")
    assert r_eager.shuffle_bytes_per_iter < r_naive.shuffle_bytes_per_iter


def test_kmeans_matches_reference():
    pts, _ = cluster_points(1500, 3, 4, seed=5)
    init = pts[:4].copy()
    res = kmeans(pts, 4, init_centers=init, max_iters=25)
    ref_centers, ref_iters = kmeans_reference(pts, init, max_iters=25)
    assert res.iterations == ref_iters
    assert np.abs(np.sort(res.centers, 0) - np.sort(ref_centers, 0)).max() < 1e-3


def test_gmm_matches_reference():
    pts, _ = cluster_points(800, 2, 3, seed=7)
    init = pts[:3].copy()
    res = gmm_em(pts, 3, init_mu=init, max_iters=8)
    a, mu, sig, ll, it = gmm_em_reference(pts, 3, init, max_iters=8)
    assert abs(res.log_likelihood - ll) / abs(ll) < 1e-3
    assert np.abs(np.sort(res.alpha) - np.sort(a)).max() < 1e-3


def test_knn_matches_full_sort():
    pts, _ = cluster_points(4000, 4, 3, seed=9)
    q = np.zeros(4, np.float32)
    r1 = knn(pts, q, 64)
    r2 = knn_full_sort(pts, q, 64)
    np.testing.assert_allclose(np.sort(r1.distances), np.sort(r2.distances), atol=1e-5)


def test_target_is_merged_not_cleared():
    """Paper contract: map_reduce merges into the target."""
    v = distribute(np.arange(10, dtype=np.float32))

    def m(i, x, emit):
        emit(0, x)

    t = jnp.asarray([100.0])
    out = map_reduce(v, m, "sum", t)
    assert float(out[0]) == 100.0 + sum(range(10))
