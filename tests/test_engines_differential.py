"""Differential harness: every engine agrees with a NumPy oracle.

The full matrix — engine ∈ {eager, naive, pallas} × reducer ∈ {sum, min, max,
prod} × value dtype ∈ {f32, bf16, i32} × key range ∈ {1, 8, 1000} — runs one
MapReduce per cell over a fixed pair stream that includes negative ids,
masked-out lanes and overflow keys (``>= K``), and asserts the dense result
against a float64/int64 NumPy oracle.  A hash-target differential covers the
``DistHashMap`` plan against a dict oracle, and dedicated cases cover empty
shards (every lane masked) and all-overflow streams.

Tolerances (documented, per dtype — engines differ in accumulation order and
width, not in semantics):

* ``i32``  — exact (bit-identical): every engine accumulates in int32.
* ``f32``  — ``rtol=2e-5``: eager/naive use XLA's segmented reduce, pallas
  accumulates through the kernel (one-hot matmul f32); same width, different
  order.
* ``bf16`` — ``rtol/atol=0.25``: eager/naive accumulate *in bf16* (the target
  dtype), while the pallas kernel accumulates in f32 and rounds once at the
  end; with ≤64 pairs per key the bf16 chain can drift by ~2^-8 per step.

One module-level session serves all cells so executable caching across the
matrix is itself exercised.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BlazeSession, distribute, make_dist_hashmap
from repro.core.reducers import get_reducer

ENGINES = ("eager", "naive", "pallas")
REDUCERS = ("sum", "min", "max", "prod")
DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16, "i32": jnp.int32}
KEY_RANGES = (1, 8, 1000)
N_PAIRS = 64

SESS = BlazeSession()

_NP_FN = {
    "sum": np.add,
    "prod": np.multiply,
    "min": np.minimum,
    "max": np.maximum,
}


def _mapper(i, row, emit):
    emit(row[0].astype(jnp.int32), row[1], mask=row[2] > 0)


def _pair_stream(reducer: str, key_range: int, seed: int = 0):
    """(keys, raw f32 values, mask) with negatives, overflow keys and masked
    lanes baked in.  Values are integer-valued floats so the i32 cast is
    exact, and prod values are confined to {±1, 2} (few 2s) so products stay
    far from int32 overflow in every bucket."""
    rng = np.random.RandomState(seed + key_range)
    keys = rng.randint(-2, key_range + 2, N_PAIRS).astype(np.float32)
    if reducer == "prod":
        vals = rng.choice([1.0, -1.0], N_PAIRS).astype(np.float32)
        vals[rng.rand(N_PAIRS) < 0.15] = 2.0
    else:
        vals = rng.randint(-8, 9, N_PAIRS).astype(np.float32)
    mask = (rng.rand(N_PAIRS) > 0.2).astype(np.float32)
    return keys, vals, mask


def _oracle(keys, vals, mask, key_range, reducer, dtype):
    """float64/int64 reference with the engine's drop semantics: masked lanes
    and ids outside [0, K) never reach the accumulator."""
    cast = np.asarray(jnp.asarray(vals).astype(dtype), np.float64)
    red = get_reducer(reducer)
    ident = float(np.asarray(red.identity(jnp.float32)).astype(np.float64)) \
        if reducer in ("sum", "prod") else (
            np.inf if reducer == "min" else -np.inf)
    if dtype == jnp.int32 and reducer in ("min", "max"):
        ident = float(
            np.iinfo(np.int32).max if reducer == "min"
            else np.iinfo(np.int32).min
        )
    out = np.full((key_range,), ident, np.float64)
    fn = _NP_FN[reducer]
    for k, v, m in zip(keys.astype(np.int64), cast, mask):
        if m > 0 and 0 <= k < key_range:
            out[k] = fn(out[k], v)
    return out


def _tolerance(dtype_name: str):
    return {
        "f32": dict(rtol=2e-5, atol=1e-5),
        "bf16": dict(rtol=0.25, atol=0.25),
        "i32": dict(rtol=0, atol=0),
    }[dtype_name]


@pytest.mark.parametrize("key_range", KEY_RANGES)
@pytest.mark.parametrize("dtype_name", sorted(DTYPES))
@pytest.mark.parametrize("reducer", REDUCERS)
@pytest.mark.parametrize("engine", ENGINES)
def test_engine_matches_oracle(engine, reducer, dtype_name, key_range):
    dtype = DTYPES[dtype_name]
    keys, vals, mask = _pair_stream(reducer, key_range)
    rows = distribute(np.stack([keys, vals, mask], axis=1))
    red = get_reducer(reducer)
    target = jnp.full((key_range,), red.identity(dtype), dtype)
    out, st = SESS.map_reduce(
        rows, _mapper, reducer, target, engine=engine, return_stats=True
    )
    assert out.dtype == dtype
    assert st.engine == engine
    ref = _oracle(keys, vals, mask, key_range, reducer, dtype)
    if dtype_name == "i32":
        # exact: go through numpy int64 (jnp would round iinfo bounds to f32)
        np.testing.assert_array_equal(
            np.asarray(out, np.int64), ref.astype(np.int64)
        )
    else:
        got = np.asarray(out, np.float64)
        np.testing.assert_allclose(got, ref, **_tolerance(dtype_name))


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("reducer", ("sum", "min"))
def test_empty_shard_leaves_target_identity(engine, reducer):
    """Every lane masked out — the per-shard combine sees an empty stream and
    the merged target must be exactly the identity it started as."""
    keys = np.arange(N_PAIRS, dtype=np.float32) % 8
    vals = np.ones(N_PAIRS, np.float32)
    rows = distribute(np.stack([keys, vals, np.zeros(N_PAIRS, np.float32)], 1))
    red = get_reducer(reducer)
    target = jnp.full((8,), red.identity(jnp.float32), jnp.float32)
    out = SESS.map_reduce(rows, _mapper, reducer, target, engine=engine)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(target))


@pytest.mark.parametrize("engine", ENGINES)
def test_nonfinite_value_on_masked_lane_never_leaks(engine):
    """A NaN computed on a masked-out lane (the classic padded-row hazard)
    must not contaminate any key under any engine."""
    keys = np.array([0, 1, 2, 3], np.float32)
    vals = np.array([1.0, np.nan, 2.0, np.inf], np.float32)
    mask = np.array([1.0, 0.0, 1.0, 0.0], np.float32)
    rows = distribute(np.stack([keys, vals, mask], 1))
    out = SESS.map_reduce(
        rows, _mapper, "sum", jnp.zeros((4,), jnp.float32), engine=engine
    )
    np.testing.assert_array_equal(np.asarray(out), [1.0, 0.0, 2.0, 0.0])


@pytest.mark.parametrize("engine", ENGINES)
def test_all_overflow_keys_dropped(engine):
    """ids >= K and ids < 0 only — nothing may reach the accumulator."""
    keys = np.concatenate(
        [np.full(N_PAIRS // 2, 8.0), np.full(N_PAIRS // 2, -1.0)]
    ).astype(np.float32)
    vals = np.full(N_PAIRS, 7.0, np.float32)
    rows = distribute(np.stack([keys, vals, np.ones(N_PAIRS, np.float32)], 1))
    out = SESS.map_reduce(
        rows, _mapper, "sum", jnp.zeros((8,), jnp.float32), engine=engine
    )
    np.testing.assert_array_equal(np.asarray(out), np.zeros(8, np.float32))


@pytest.mark.parametrize("reducer", ("sum", "min", "max", "prod"))
def test_hash_target_matches_dict_oracle(reducer):
    """The DistHashMap plan (eager + naive) against a plain dict fold."""
    keys, vals, mask = _pair_stream(reducer, 50, seed=7)
    rows = distribute(np.stack([keys, vals, mask], axis=1))
    want: dict = {}
    fn = _NP_FN[reducer]
    for k, v, m in zip(keys.astype(np.int64), vals.astype(np.float64), mask):
        if m > 0:
            want[int(k)] = fn(want[int(k)], v) if int(k) in want else v
    for engine in ("eager", "naive", "pallas"):  # pallas = the hash kernel
        hm = make_dist_hashmap(SESS.mesh, 256, (), jnp.float32, reducer)
        hm, st = SESS.map_reduce(
            rows, _mapper, reducer, hm, engine=engine, return_stats=True
        )
        assert st.engine == engine  # no hash-target fallback any more
        got = {int(k): float(v) for k, v in hm.to_dict().items()}
        assert set(got) == set(want)
        for k in want:
            assert abs(got[k] - want[k]) < 1e-4, (engine, reducer, k)


def test_naive_hash_target_oracle_equivalence_and_shipping():
    """engine="naive" against a DistHashMap: every raw pair goes on the wire
    (shipped == emitted, ≥ eager's post-combine count), the destination-side
    reduce still matches the dict oracle exactly, and nothing overflows with
    adequate capacity."""
    rng = np.random.RandomState(11)
    keys = rng.randint(0, 20, N_PAIRS).astype(np.float32)  # duplicate-heavy
    vals = rng.randint(1, 5, N_PAIRS).astype(np.float32)
    mask = np.ones(N_PAIRS, np.float32)
    rows = distribute(np.stack([keys, vals, mask], axis=1))
    want: dict = {}
    for k, v in zip(keys.astype(np.int64), vals.astype(np.float64)):
        want[int(k)] = want.get(int(k), 0.0) + v

    results = {}
    for engine in ("eager", "naive"):
        hm = make_dist_hashmap(SESS.mesh, 256, (), jnp.float32, "sum")
        hm, st = SESS.map_reduce(
            rows, _mapper, "sum", hm, engine=engine, return_stats=True
        )
        st = st.finalize()
        results[engine] = (hm, st)
        assert hm.total_overflow() == 0
        got = {int(k): float(v) for k, v in hm.to_dict().items()}
        assert got == pytest.approx(want)

    eager_st, naive_st = results["eager"][1], results["naive"][1]
    n_shards = SESS.mesh.shape["data"]
    assert naive_st.pairs_shipped == naive_st.pairs_emitted == N_PAIRS
    # eager combined before the wire: at most one pair per (key, shard)
    assert eager_st.pairs_shipped <= len(want) * n_shards
    assert naive_st.pairs_shipped > eager_st.pairs_shipped
    assert naive_st.shuffle_payload_bytes > eager_st.shuffle_payload_bytes


@pytest.mark.parametrize("engine", ("eager", "naive"))
def test_hash_target_overflow_accounted_not_silent(engine):
    """A table too small for the key set must *count* what it drops —
    overflow > 0, surviving sums never exceed the oracle, and live entries
    stay within capacity.  (The differential matrix previously skipped the
    naive × DistHashMap overflow cell.)"""
    rng = np.random.RandomState(13)
    n = 128
    keys = np.arange(n, dtype=np.float32)  # 128 distinct keys
    vals = np.ones(n, np.float32)
    rows = distribute(np.stack([keys, vals, np.ones(n, np.float32)], axis=1))
    # capacity 8/shard on a 1-device main process → ≤ 8 live slots
    hm = make_dist_hashmap(SESS.mesh, 8, (), jnp.float32, "sum")
    hm, st = SESS.map_reduce(
        rows, _mapper, "sum", hm, engine=engine, return_stats=True
    )
    st = st.finalize()
    n_shards = hm.n_shards
    assert hm.total_overflow() > 0
    assert hm.size() <= 8 * n_shards
    got = {int(k): float(v) for k, v in hm.to_dict().items()}
    for k, v in got.items():
        assert v <= 1.0 + 1e-6  # unique keys: a survivor holds exactly its sum
    # conservation: live entries + counted drops cover every unique key
    assert hm.size() + hm.total_overflow() >= n / max(1, n_shards)


def test_pallas_occupancy_accounting():
    """kernel_pairs counts only live in-range lanes; occupancy ∈ (0, 1]."""
    keys, vals, mask = _pair_stream("sum", 8)
    rows = distribute(np.stack([keys, vals, mask], axis=1))
    _, st = SESS.map_reduce(
        rows, _mapper, "sum", jnp.zeros((8,), jnp.float32),
        engine="pallas", return_stats=True,
    )
    st = st.finalize()
    live = int(
        ((mask > 0) & (keys >= 0) & (keys < 8)).sum()
    )
    assert st.kernel_pairs == live
    assert st.kernel_lanes >= N_PAIRS
    assert 0.0 < st.kernel_occupancy <= 1.0
    assert st.kernel_occupancy == pytest.approx(live / st.kernel_lanes)
