"""Chaos suite: deterministic fault injection against the supervisors.

Every recovery path PR 9 claims is reproduced here on demand, from seeded
schedules, and held to two laws:

* **bit-equality** — a run that survives injected faults (retry, engine
  degradation, overflow escalation, crash + resume) produces *bit-identical*
  results to the fault-free run.  All fault points fire before the
  executable runs or any carry is written, so a retried dispatch replays
  exactly;
* **conservation** — every injected fault is disposed exactly once:
  ``injected_total == retried + degraded + escalated + fatal + absorbed``
  (``faults.snapshot()["balanced"]``), across threads (prefetch worker,
  serve dispatcher) and across any seeded schedule.

The acceptance proofs from the issue live here too: mid-stream crash at a
checkpointed epoch resumes bit-equal; hash overflow auto-escalates capacity
along the cost grid to a dict-oracle-exact result; an injected Pallas fault
degrades the node to eager with correct results, visible provenance, and no
executable-cache poisoning (the follow-up identical query is a 0-compile
hit).
"""
from __future__ import annotations

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import faults
from repro.core import containers as C
from repro.core.algorithms.kmeans import kmeans
from repro.core.algorithms.pagerank import pagerank
from repro.core.session import BlazeSession

# Fast supervision for tests: no sleeps, no wall-clock deadline.
FAST = faults.RetryPolicy(attempts=3, backoff_s=0.0, multiplier=1.0,
                          deadline_s=None)


@pytest.fixture(autouse=True)
def _clean_registry():
    """Every test starts and ends with a disarmed registry and a zeroed
    ledger (ignoring any ambient BLAZE_FAULTS)."""
    faults.reset(env=False)
    yield
    faults.reset(env=False)


def _sq_mapper(i, x, emit):
    emit(jnp.asarray(x, jnp.int32) % 8, x)


def _sess(**kw):
    kw.setdefault("retry", FAST)
    return BlazeSession(**kw)


def _assert_balanced(**expect):
    snap = faults.snapshot()
    assert snap["balanced"], snap
    for k, v in expect.items():
        assert snap["dispositions"][k] == v, (k, snap)


# -- registry / rule unit behavior --------------------------------------------


def test_rule_needs_exactly_one_trigger():
    with pytest.raises(ValueError):
        faults.FaultRule("dispatch")
    with pytest.raises(ValueError):
        faults.FaultRule("dispatch", at=1, every=2)
    with pytest.raises(ValueError):
        faults.FaultRule("dispatch", at=0)


def test_retry_policy_validation():
    with pytest.raises(ValueError):
        faults.RetryPolicy(attempts=0)
    with pytest.raises(ValueError):
        faults.RetryPolicy(multiplier=0.5)


def test_env_spec_parsing(monkeypatch):
    monkeypatch.setenv(
        faults.ENV_VAR, "dispatch:at=3;kernel.hash:p=0.1,seed=42,fatal"
    )
    faults.reset()
    snap = faults.snapshot()
    assert snap["armed"] and snap["rules"] == 2
    rules = {r.point: r for r in faults.registry._rules}
    assert rules["dispatch"].at == 3 and not rules["dispatch"].fatal
    assert rules["kernel.hash"].p == 0.1
    assert rules["kernel.hash"].seed == 42 and rules["kernel.hash"].fatal


def test_env_spec_rejects_unknown_knob(monkeypatch):
    monkeypatch.setenv(faults.ENV_VAR, "dispatch:bogus=1")
    with pytest.raises(ValueError):
        faults.reset()
    faults.reset(env=False)


def test_probabilistic_schedule_is_deterministic():
    def schedule():
        faults.reset(env=False)
        faults.configure("dispatch", p=0.3, seed=7)
        fired = []
        for i in range(50):
            try:
                faults.fault_point("dispatch")
            except faults.TransientFault:
                fired.append(i)
        return fired

    a, b = schedule(), schedule()
    assert a == b and len(a) > 0  # replayable, and actually fires


def test_ledger_disposes_each_fault_once():
    faults.configure("dispatch", at=1)
    with pytest.raises(faults.TransientFault) as ei:
        faults.fault_point("dispatch")
    faults.record("retried", ei.value)
    faults.record("fatal", ei.value)  # second disposition: no-op
    faults.record("retried", ValueError("real"))  # non-injected: no-op
    _assert_balanced(retried=1, fatal=0)
    with pytest.raises(ValueError):
        faults.record("vanished", ei.value)


def test_inject_scopes_the_rule():
    with faults.inject("dispatch", every=1):
        with pytest.raises(faults.TransientFault):
            faults.fault_point("dispatch")
    faults.fault_point("dispatch")  # disarmed again — must not raise
    assert faults.snapshot()["injected_total"] == 1


# -- supervised per-op dispatch -----------------------------------------------


def test_transient_dispatch_fault_retries_bit_equal():
    sess = _sess()
    src = sess.distribute(np.arange(64, dtype=np.float32))
    target = jnp.zeros((8,), jnp.float32)
    ref = sess.map_reduce(src, _sq_mapper, "sum", target)
    # hits are only counted while armed, so the next dispatch is hit 1
    faults.configure("dispatch", at=1)
    out = sess.map_reduce(src, _sq_mapper, "sum", target)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert sess.stats.retries == 1
    _assert_balanced(retried=1)


def test_retry_budget_exhaustion_is_fatal():
    sess = _sess()
    src = sess.distribute(np.arange(16, dtype=np.float32))
    faults.configure("dispatch", every=1)  # every attempt faults
    with pytest.raises(faults.TransientFault):
        sess.map_reduce(src, _sq_mapper, "sum", jnp.zeros((8,), jnp.float32))
    # attempts=3: two retries, then the third failure is recorded fatal.
    _assert_balanced(retried=2, fatal=1)


def test_fatal_fault_propagates_immediately():
    sess = _sess()
    src = sess.distribute(np.arange(16, dtype=np.float32))
    faults.configure("dispatch", at=1, fatal=True)
    with pytest.raises(faults.FatalFault):
        sess.map_reduce(src, _sq_mapper, "sum", jnp.zeros((8,), jnp.float32))
    assert sess.stats.retries == 0
    _assert_balanced(fatal=1)


def test_unsupervised_session_propagates_raw():
    sess = BlazeSession(retry=None)
    src = sess.distribute(np.arange(16, dtype=np.float32))
    faults.configure("dispatch", at=1)
    with pytest.raises(faults.TransientFault) as ei:
        sess.map_reduce(src, _sq_mapper, "sum", jnp.zeros((8,), jnp.float32))
    faults.record("fatal", ei.value)  # the test is the supervisor here
    _assert_balanced(fatal=1)


# -- engine degradation (acceptance proof c) ----------------------------------


def test_kernel_fault_degrades_to_eager_no_cache_poisoning():
    sess = _sess()
    src = sess.distribute(np.arange(64, dtype=np.float32))
    target = jnp.zeros((8,), jnp.float32)
    ref = sess.map_reduce(src, _sq_mapper, "sum", target)  # eager reference

    faults.configure("kernel.segment", at=1)
    out, st = sess.map_reduce(
        src, _sq_mapper, "sum", target, engine="pallas", return_stats=True
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert st.engine == "eager" and st.degraded_engine == "pallas"
    assert sess.stats.degraded_nodes == 1
    _assert_balanced(degraded=1)

    # Follow-up identical query: served from the degraded node's OWN cache
    # entry — zero new compiles, and the provenance is still visible.
    compiles0 = sess.stats.compiles
    out2, st2 = sess.map_reduce(
        src, _sq_mapper, "sum", target, engine="pallas", return_stats=True
    )
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(ref))
    assert sess.stats.compiles == compiles0  # 0-compile follow-up
    assert st2.cache_hits == 1
    assert st2.degraded_engine == "pallas" and st2.engine == "eager"


def test_hash_kernel_fault_degrades_hash_dispatch():
    sess = _sess()
    n = 64
    rows = sess.distribute(
        np.stack([np.arange(n) % 16, np.ones(n)], axis=1).astype(np.float32)
    )

    def kv_mapper(i, row, emit):
        emit(jnp.asarray(row[0], jnp.int32), row[1])

    hm = C.make_dist_hashmap(sess.mesh, 128, reducer="sum")
    faults.configure("kernel.hash", at=1)
    out, st = sess.map_reduce(
        rows, kv_mapper, "sum", hm, engine="pallas", return_stats=True
    )
    assert st.degraded_engine == "pallas" and st.engine == "eager"
    assert out.to_dict() == {k: 4.0 for k in range(16)}
    _assert_balanced(degraded=1)


def _pallas_step(src):
    def step(ctx, state):
        def mapper(i, x, emit, env):
            emit(jnp.asarray(x, jnp.int32) % 8, x * env[0])

        s = ctx.map_reduce(
            src, mapper, "sum", jnp.zeros((8,), jnp.float32),
            engine="pallas", env=state,
        )
        return state * 0.5 + s[:1] * 1e-3

    return step


def test_program_degradation_shows_in_explain():
    sess = _sess()
    src = sess.distribute(np.arange(64, dtype=np.float32))
    state0 = jnp.ones((1,), jnp.float32)

    prog = sess.program(_pallas_step(src))
    faults.configure("kernel.segment", at=1)
    out, _info = sess.run_loop(prog, state0, max_iters=4)
    assert sess.stats.degraded_nodes >= 1
    _assert_balanced(degraded=1)
    rendered = sess.explain(prog)
    assert "degraded 'pallas' -> 'eager' (kernel fault)" in rendered
    # The fault fired before the first executable ever ran, so the whole
    # run was eager — bit-equal to an all-eager program of the same step.
    eager_sess = _sess()
    eager_src = eager_sess.distribute(np.arange(64, dtype=np.float32))

    def eager_step(ctx, state):
        def mapper(i, x, emit, env):
            emit(jnp.asarray(x, jnp.int32) % 8, x * env[0])

        s = ctx.map_reduce(
            eager_src, mapper, "sum", jnp.zeros((8,), jnp.float32), env=state
        )
        return state * 0.5 + s[:1] * 1e-3

    ref, _ = eager_sess.run_loop(
        eager_sess.program(eager_step), state0, max_iters=4
    )
    assert np.asarray(out).tobytes() == np.asarray(ref).tobytes()


def test_degraded_program_rebuild_is_cached():
    """After a mid-session degradation, re-dispatching the same program
    compiles nothing new (the eager executable is resident)."""
    sess = _sess()
    src = sess.distribute(np.arange(64, dtype=np.float32))

    state0 = jnp.ones((1,), jnp.float32)
    prog = sess.program(_pallas_step(src))
    faults.configure("kernel.segment", at=1)
    out1, _ = sess.run_loop(prog, state0, max_iters=2)
    compiles0 = sess.stats.program_compiles
    out2, _ = sess.run_loop(prog, state0, max_iters=2)
    assert sess.stats.program_compiles == compiles0
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


# -- overflow escalation (acceptance proof b) ---------------------------------


def _kv_rows(sess, n):
    return sess.distribute(
        np.stack([np.arange(n), np.ones(n)], axis=1).astype(np.float32)
    )


def _kv_mapper(i, row, emit):
    emit(jnp.asarray(row[0], jnp.int32), row[1])


def test_overflow_escalates_capacity_to_dict_oracle():
    sess = _sess(escalate_overflow=True)
    n = 300  # far beyond 128 slots/shard
    hm = C.make_dist_hashmap(sess.mesh, 128, reducer="sum")
    out, st = sess.map_reduce(
        _kv_rows(sess, n), _kv_mapper, "sum", hm, return_stats=True
    )
    assert out.total_overflow() == 0
    assert st.escalations >= 1
    assert sess.stats.escalations == st.escalations
    # capacity climbed the shared cost grid (powers of two)
    assert out.capacity_per_shard > 128
    assert out.capacity_per_shard & (out.capacity_per_shard - 1) == 0
    assert out.to_dict() == {k: 1.0 for k in range(n)}


def test_escalation_preserves_existing_entries():
    """Escalation regrows the ORIGINAL target: entries merged before the
    overflowing dispatch survive, exactly."""
    sess = _sess(escalate_overflow=True)
    hm = C.make_dist_hashmap(sess.mesh, 128, reducer="sum")
    hm = sess.map_reduce(_kv_rows(sess, 50), _kv_mapper, "sum", hm)
    assert hm.total_overflow() == 0  # first round fits
    out = sess.map_reduce(_kv_rows(sess, 300), _kv_mapper, "sum", hm)
    assert out.total_overflow() == 0
    want = {k: 2.0 for k in range(50)}
    want.update({k: 1.0 for k in range(50, 300)})
    assert out.to_dict() == want


def test_escalation_is_bounded():
    sess = _sess(escalate_overflow=True, max_escalations=1)
    hm = C.make_dist_hashmap(sess.mesh, 128, reducer="sum")
    out, st = sess.map_reduce(
        _kv_rows(sess, 2000), _kv_mapper, "sum", hm, return_stats=True
    )
    # One doubling (128 -> 256) cannot hold 2000 keys: overflow remains,
    # counted, and escalation stopped at the bound.
    assert st.escalations == 1
    assert out.capacity_per_shard == 256
    assert out.total_overflow() > 0


def test_no_escalation_without_opt_in():
    sess = _sess()  # escalate_overflow defaults False
    hm = C.make_dist_hashmap(sess.mesh, 128, reducer="sum")
    out, st = sess.map_reduce(
        _kv_rows(sess, 300), _kv_mapper, "sum", hm, return_stats=True
    )
    assert st.escalations == 0
    assert out.capacity_per_shard == 128
    assert out.total_overflow() > 0  # the counted-drop contract holds


# -- checkpoint/resume (acceptance proof a) -----------------------------------


def _loop_program(sess):
    src = sess.distribute(np.arange(64, dtype=np.float32))

    def step(ctx, state):
        def mapper(i, x, emit, env):
            emit(jnp.asarray(x, jnp.int32) % 8, x * env[0])

        s = ctx.map_reduce(
            src, mapper, "sum", jnp.zeros((8,), jnp.float32), env=state
        )
        return state * 0.9 + s[:1] * 1e-4

    return sess.program(step)


def _stream_program(sess):
    data = np.arange(512, dtype=np.float32).reshape(-1, 2)
    src = sess.chunked(data, 64)

    def step(ctx, state):
        def mapper(i, x, emit, env):
            emit(jnp.asarray(x[0], jnp.int32) % 4, x[1] * env[0])

        s = ctx.map_reduce(
            src, mapper, "sum", jnp.zeros((4,), jnp.float32), env=state
        )
        return state * 0.8 + s[:1] * 1e-5

    return sess.program(step)


def test_run_loop_resume_bit_equal(tmp_path):
    state0 = jnp.ones((1,), jnp.float32)
    s1 = _sess()
    ref, _ = s1.run_loop(_loop_program(s1), state0, max_iters=8, unroll=2)

    ckpt = str(tmp_path / "loop")
    s2 = _sess()
    s2.run_loop(_loop_program(s2), state0, max_iters=4, unroll=2,
                checkpoint=ckpt, checkpoint_every=2)
    s3 = _sess()
    out, info = s3.run_loop(_loop_program(s3), state0, max_iters=8, unroll=2,
                            checkpoint=ckpt, resume=True)
    assert info.resumed_from == 4 and info.iterations == 4
    assert np.asarray(out).tobytes() == np.asarray(ref).tobytes()


def test_run_loop_resume_requires_checkpoint():
    sess = _sess()
    with pytest.raises(ValueError):
        sess.run_loop(_loop_program(sess), jnp.ones((1,), jnp.float32),
                      max_iters=2, resume=True)


def test_mid_stream_crash_resumes_bit_equal(tmp_path):
    """The headline proof: a fatal fault mid-stream kills the run between
    checkpoints; a FRESH session resumes from the checkpointed epoch and
    finishes bit-equal to the uninterrupted run."""
    state0 = jnp.ones((1,), jnp.float32)
    s1 = _sess()
    ref, _ = s1.run_stream(_stream_program(s1), state0, max_epochs=6)

    ckpt = str(tmp_path / "stream")
    s2 = _sess()
    # 256 rows / 64 per block = 4 blocks per epoch; crash on a dispatch
    # inside epoch 4 (after the epoch-3 checkpoint landed).
    faults.configure("dispatch", at=3 * 4 + 2, fatal=True)
    with pytest.raises(faults.FatalFault):
        s2.run_stream(_stream_program(s2), state0, max_epochs=6,
                      checkpoint=ckpt, checkpoint_every=1)
    _assert_balanced(fatal=1)
    faults.reset(env=False)

    s3 = _sess()
    out, info = s3.run_stream(_stream_program(s3), state0, max_epochs=6,
                              checkpoint=ckpt, resume=True)
    assert info.resumed_from == 3
    assert np.asarray(out).tobytes() == np.asarray(ref).tobytes()


def test_resume_with_empty_dir_starts_fresh(tmp_path):
    state0 = jnp.ones((1,), jnp.float32)
    sess = _sess()
    ref, _ = _sess().run_loop(_loop_program(_sess()), state0, max_iters=4)
    out, info = sess.run_loop(
        _loop_program(sess), state0, max_iters=4,
        checkpoint=str(tmp_path / "empty"), resume=True,
    )
    assert info.resumed_from is None and info.iterations == 4
    assert np.asarray(out).tobytes() == np.asarray(ref).tobytes()


def test_checkpoint_write_fault_is_retried(tmp_path):
    state0 = jnp.ones((1,), jnp.float32)
    sess = _sess()
    faults.configure("checkpoint.write", at=1)
    out, _ = sess.run_loop(
        _loop_program(sess), state0, max_iters=4, unroll=2,
        checkpoint=str(tmp_path / "ck"), checkpoint_every=2,
    )
    _assert_balanced(retried=1)
    # and the retried write really landed: a resume run finds position 4
    s2 = _sess()
    _out, info = s2.run_loop(
        _loop_program(s2), state0, max_iters=4, unroll=2,
        checkpoint=str(tmp_path / "ck"), resume=True,
    )
    assert info.resumed_from == 4 and info.iterations == 0


# -- prefetch + tuning supervisors --------------------------------------------


def test_prefetch_read_fault_retried_in_worker():
    sess = _sess()
    data = np.arange(512, dtype=np.float32)
    cv = sess.chunked(data, 64)
    ref_sess = _sess()
    ref = np.asarray(
        ref_sess.map_reduce(ref_sess.chunked(data, 64), _sq_mapper, "sum",
                            jnp.zeros((8,), jnp.float32))
    )
    faults.configure("prefetch.read", every=3)
    out = sess.map_reduce(cv, _sq_mapper, "sum", jnp.zeros((8,), jnp.float32))
    np.testing.assert_array_equal(np.asarray(out), ref)
    snap = faults.snapshot()
    assert snap["balanced"] and snap["dispositions"]["retried"] >= 1


def test_tuning_measurement_fault_absorbed():
    sess = _sess()
    src = sess.distribute(np.arange(256, dtype=np.float32))
    target = jnp.zeros((8,), jnp.float32)
    faults.configure("tuning.measure", at=1)
    out = sess.map_reduce(src, _sq_mapper, "sum", target, tune=True)
    ref_sess = _sess()
    ref = ref_sess.map_reduce(
        ref_sess.distribute(np.arange(256, dtype=np.float32)),
        _sq_mapper, "sum", target,
    )
    # the faulted candidate lost the race; the winner may be pallas, whose
    # float association differs — allclose, not bit-equal, is the contract
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)
    _assert_balanced(absorbed=1)


# -- corrupt tuning cache (satellite) -----------------------------------------


def test_corrupt_tuning_json_warns_and_starts_empty(tmp_path):
    path = str(tmp_path / "tuning.json")
    with open(path, "w") as f:
        f.write("{definitely not json")
    with pytest.warns(RuntimeWarning, match="unreadable tuning cache"):
        sess = BlazeSession(tuning_path=path)
    assert sess.tuning.snapshot()["entries"] == 0
    with pytest.warns(RuntimeWarning):
        assert sess.load_tuning(path) == 0
    # the session still works and can overwrite the corpse atomically
    sess.save_tuning(path)
    with open(path) as f:
        json.load(f)  # valid JSON again


# -- seeded chaos schedules over real drivers ---------------------------------


def test_chaos_streaming_kmeans_bit_equal():
    rng = np.random.RandomState(3)
    pts = rng.randn(1024, 4).astype(np.float32)
    init = pts[:4].copy()

    def run(session):
        cv = session.chunked(pts, 256)
        return kmeans(cv, 4, init_centers=init, max_iters=6, mode="stream",
                      session=session)

    ref = run(_sess())
    faults.configure("dispatch", p=0.2, seed=11)
    faults.configure("prefetch.read", p=0.1, seed=12)
    got = run(_sess())
    assert np.asarray(got.centers).tobytes() == np.asarray(ref.centers).tobytes()
    snap = faults.snapshot()
    assert snap["balanced"], snap
    assert snap["injected_total"] >= 1  # the schedule really fired
    assert snap["injected_total"] == sum(snap["dispositions"].values())


def test_chaos_pagerank_per_op_bit_equal():
    rng = np.random.RandomState(5)
    edges = rng.randint(0, 64, size=(512, 2)).astype(np.int64)

    def run(session):
        return pagerank(edges, 64, max_iters=8, session=session)

    ref = run(_sess())
    faults.configure("dispatch", p=0.15, seed=21)
    faults.configure("collective", p=0.2, seed=22)
    got = run(_sess())
    assert np.asarray(got.scores).tobytes() == np.asarray(ref.scores).tobytes()
    snap = faults.snapshot()
    assert snap["balanced"] and snap["injected_total"] >= 1


# -- serving under faults ------------------------------------------------------


def _server(**kw):
    from repro.serve import BlazeServer

    sess = BlazeSession(retry=FAST)
    kw.setdefault("max_queue", 64)
    kw.setdefault("per_tenant_inflight", 64)
    return BlazeServer(sess, **kw)


def test_serve_transient_fault_retries_and_reports():
    srv = _server()
    with srv:
        r0, _ = srv.submit_and_wait("t", "pi", {"n_samples": 512, "iters": 1})
        # hits count only while armed: the next dispatch is hit 1
        faults.configure("dispatch", at=1)
        r1, _ = srv.submit_and_wait("t", "pi", {"n_samples": 512, "iters": 1})
        assert r1["pi"] == r0["pi"]
        snap = srv.stats_snapshot()
    rec = snap["recovery"]
    assert rec["retried_batches"] == 1 and rec["balanced"]
    assert rec["dispositions"]["retried"] == 1
    assert snap["completed"] == 2 and snap["failed"] == 0


def test_serve_kernel_fault_degrades_and_keeps_serving():
    srv = _server()
    with srv:
        faults.configure("kernel.segment", at=1)
        r1, _ = srv.submit_and_wait(
            "t", "pi", {"n_samples": 512, "iters": 1, "engine": "pallas"}
        )
        # follow-up identical query: answered from the degraded program,
        # zero new program compiles
        compiles0 = srv.session.stats.program_compiles
        r2, m2 = srv.submit_and_wait(
            "t", "pi", {"n_samples": 512, "iters": 1, "engine": "pallas"}
        )
        assert srv.session.stats.program_compiles == compiles0
        assert m2["cache"] == "hit"
        snap = srv.stats_snapshot()
    assert r1["counts"] is not None and r2["pi"] == r1["pi"]
    rec = snap["recovery"]
    assert rec["degraded_batches"] == 1 and rec["balanced"]
    assert rec["session_degraded_nodes"] == 1
    assert snap["completed"] == 2


def test_serve_shutdown_drains_with_typed_shutdown():
    from repro.serve import BlazeServer  # noqa: F401 — import check

    srv = _server(max_batch=4)
    srv.start()
    srv.pause_dispatch()  # hold the backlog so stop() must drain it
    reqs = [
        srv.submit("t", "pi", {"n_samples": 512, "iters": 1})
        for _ in range(5)
    ]
    srv.stop(drain_timeout=2.0)
    for req in reqs:
        assert req.done.is_set()
        assert req.error is not None and req.error.code == "SHUTDOWN"
    snap = srv.stats.snapshot()
    # conservation after drain: nothing is left queued or unaccounted
    assert snap["queued"] == 0
    assert snap["submitted"] == snap["completed"] + snap["failed"] == 5
    # stop() is idempotent
    srv.stop()
