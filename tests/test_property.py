"""Property-based tests (hypothesis) for the system's invariants.

Gating: skip when hypothesis is genuinely absent (local minimal envs), but
FAIL — never skip — when ``REQUIRE_HYPOTHESIS`` is set, which CI does after
installing hypothesis.  The seed-era bug this guards against: an import-time
skip that silently turns the whole property suite off in CI when an
unrelated dependency issue breaks the hypothesis import.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError as e:
    if os.environ.get("REQUIRE_HYPOTHESIS"):
        raise ImportError(
            "REQUIRE_HYPOTHESIS is set but hypothesis failed to import — "
            "the property suite must run, not skip, in CI"
        ) from e
    pytest.skip("hypothesis not installed", allow_module_level=True)
from hypothesis import given, settings, strategies as st

from repro.core import get_reducer
from repro.core.containers import (
    EMPTY_KEY,
    hashmap_insert,
    make_table,
    unique_combine,
)
from repro.core.mapreduce import bucket_by_dest
from repro.core.serialization import (
    blaze_decode_pairs,
    blaze_encode_pairs,
    dequantize,
    message_sizes,
    protobuf_encode_pairs,
    quantize,
    quantize_with_feedback,
    varint_decode,
    varint_encode,
)

SMALL = settings(max_examples=40, deadline=None)


@SMALL
@given(st.integers(min_value=-(2**63), max_value=2**63 - 1))
def test_varint_roundtrip(v):
    buf = varint_encode(v)
    out, pos = varint_decode(buf, 0)
    assert out == v and pos == len(buf)


@SMALL
@given(
    st.lists(st.integers(min_value=0, max_value=2**31 - 1), min_size=1, max_size=64)
)
def test_blaze_pairs_roundtrip_and_smaller_than_protobuf(keys):
    k = np.asarray(keys, np.int64)
    v = np.ones_like(k)
    buf = blaze_encode_pairs(k, v)
    k2, v2 = blaze_decode_pairs(buf, len(k))
    assert (k2 == k).all() and (v2 == v).all()
    sizes = message_sizes(k, v)
    # tag-free format always saves exactly 2 bytes/pair vs protobuf
    assert sizes["protobuf_bytes"] - sizes["blaze_bytes"] == 2 * len(k)


@SMALL
@given(
    st.lists(
        st.floats(min_value=-100, max_value=100, allow_nan=False),
        min_size=1, max_size=128,
    ),
    st.sampled_from(["bf16", "int8"]),
)
def test_quantize_bounded_error(vals, mode):
    x = jnp.asarray(np.asarray(vals, np.float32))
    q = quantize(x, mode, block=32)
    back = dequantize(q, x)
    scale = float(jnp.max(jnp.abs(x))) or 1.0
    tol = 0.01 if mode == "bf16" else 1.0 / 127.0
    assert float(jnp.max(jnp.abs(back - x))) <= tol * scale + 1e-6


@SMALL
@given(st.integers(min_value=1, max_value=200))
def test_error_feedback_unbiased_over_time(n):
    """Sum of dequantised values + final residual == sum of true values."""
    rng = np.random.RandomState(n)
    xs = rng.randn(8, 16).astype(np.float32)
    resid = jnp.zeros((16,), jnp.float32)
    total_sent = jnp.zeros((16,), jnp.float32)
    for i in range(8):
        q, resid = quantize_with_feedback(jnp.asarray(xs[i]), resid, "int8", block=16)
        total_sent = total_sent + dequantize(q, total_sent)
    np.testing.assert_allclose(
        np.asarray(total_sent + resid), xs.sum(0), rtol=1e-4, atol=1e-4
    )


@SMALL
@given(
    st.lists(st.integers(min_value=0, max_value=30), min_size=1, max_size=100),
    st.sampled_from(["sum", "min", "max"]),
)
def test_unique_combine_equals_dict_semantics(keys, red_name):
    red = get_reducer(red_name)
    rng = np.random.RandomState(42)
    k = jnp.asarray(np.asarray(keys, np.int32))
    v = jnp.asarray(rng.rand(len(keys)).astype(np.float32))
    mask = jnp.ones(len(keys), bool)
    ok, ov, valid = unique_combine(k, v, mask, red)
    got = {int(a): float(b) for a, b, m in zip(ok, ov, valid) if m}
    import collections

    want: dict = {}
    fn = {"sum": lambda a, b: a + b, "min": min, "max": max}[red_name]
    for kk, vv in zip(keys, np.asarray(v)):
        want[kk] = fn(want[kk], float(vv)) if kk in want else float(vv)
    assert set(got) == set(want)
    for kk in want:
        assert abs(got[kk] - want[kk]) < 1e-4


@SMALL
@given(
    st.lists(st.integers(min_value=0, max_value=1000), min_size=1, max_size=80,
             unique=True),
    st.integers(min_value=4, max_value=8),
)
def test_hashmap_insert_equals_dict(keys, logcap):
    red = get_reducer("sum")
    cap = 2**logcap
    t = make_table(cap, (), jnp.float32, red)
    k = jnp.asarray(np.asarray(keys, np.int32))
    v = jnp.ones((len(keys),), jnp.float32)
    t = hashmap_insert(t, k, v, jnp.ones(len(keys), bool), red, max_probes=cap)
    live = {int(a): float(b) for a, b in zip(t.keys, t.vals) if a != EMPTY_KEY}
    if len(keys) <= cap:
        assert int(t.overflow) == 0
        assert live == {kk: 1.0 for kk in keys}


@SMALL
@given(
    st.integers(min_value=1, max_value=64),
    st.integers(min_value=1, max_value=8),
)
def test_bucket_by_dest_conserves_pairs(n, n_dest):
    rng = np.random.RandomState(n * 7 + n_dest)
    keys = jnp.asarray(rng.randint(0, 1000, n).astype(np.int32))
    vals = jnp.asarray(rng.rand(n).astype(np.float32))
    valid = jnp.asarray(rng.rand(n) > 0.3)
    cap = n  # enough for everything
    bk, bv, dropped = bucket_by_dest(keys, vals, valid, n_dest, cap, 0.0)
    assert int(dropped) == 0
    live = np.asarray(bk).reshape(-1)
    assert (live != EMPTY_KEY).sum() == int(np.asarray(valid).sum())
    # value conservation
    total_in = float(np.asarray(vals)[np.asarray(valid)].sum())
    total_out = float(np.asarray(bv).reshape(-1)[live != EMPTY_KEY].sum())
    assert abs(total_in - total_out) < 1e-4


@SMALL
@given(st.integers(min_value=2, max_value=100), st.integers(min_value=1, max_value=20))
def test_topk_matches_sort(n, k):
    from repro.core import distribute, topk

    rng = np.random.RandomState(n * 31 + k)
    x = rng.randn(n).astype(np.float32)
    v = distribute(x)
    got = np.sort(topk(v, min(k, n)))[::-1]
    want = np.sort(x)[::-1][: min(k, n)]
    np.testing.assert_allclose(got, want, atol=1e-6)
