"""Multi-host scale-out: topology helpers, the ``hierarchical-collectives``
pass, and hierarchical-vs-flat equivalence on a simulated 2-D mesh.

In-process tests cover the pure pieces (simulate helpers, wire-byte
accounting, the plan pass, plan hash/render stability on 1-D meshes).
Subprocess tests spawn workers with ``launch.simulate.simulated_env(8)`` —
8 simulated CPU devices arranged as ``("node", "data")`` meshes — and hold
the hierarchical reduce to the same laws the fault suite uses: bit-equality
with the flat wire (integer-valued payloads), dict/NumPy-oracle exactness,
and intra/inter wire-byte accounting that matches the combine-edge model.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.launch import simulate

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, n_devices: int = 8) -> dict:
    env = simulate.simulated_env(
        n_devices, pythonpath=os.path.join(ROOT, "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# -- launch/simulate helpers --------------------------------------------------


def test_host_device_flags_fresh_and_replace():
    assert simulate.host_device_flags(8) == (
        "--xla_force_host_platform_device_count=8"
    )
    # an existing count is replaced, unrelated flags survive
    got = simulate.host_device_flags(
        4, "--xla_cpu_foo=1 --xla_force_host_platform_device_count=512"
    )
    assert got.split() == [
        "--xla_cpu_foo=1", "--xla_force_host_platform_device_count=4"
    ]
    with pytest.raises(ValueError):
        simulate.host_device_flags(0)


def test_forced_host_device_count_parses_env():
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=16"}
    assert simulate.forced_host_device_count(env) == 16
    assert simulate.forced_host_device_count({"XLA_FLAGS": ""}) is None
    assert simulate.forced_host_device_count({}) is None


def test_simulated_env_is_the_worker_recipe():
    base = {"XLA_FLAGS": "--xla_cpu_foo=1", "PYTHONPATH": "/elsewhere"}
    env = simulate.simulated_env(8, base, pythonpath="/src")
    assert "--xla_force_host_platform_device_count=8" in env["XLA_FLAGS"]
    assert "--xla_cpu_foo=1" in env["XLA_FLAGS"]
    assert env["PYTHONPATH"].split(os.pathsep) == ["/src", "/elsewhere"]
    assert base == {"XLA_FLAGS": "--xla_cpu_foo=1", "PYTHONPATH": "/elsewhere"}


def test_force_host_device_count_after_backend_init_raises():
    import jax

    jax.devices()  # ensure the backend is up in this process
    with pytest.raises(RuntimeError, match="backend"):
        simulate.force_host_device_count(8)


# -- wire-byte accounting -----------------------------------------------------


def test_wire_bytes_derive_from_dtype():
    from repro.distributed.collectives import wire_bytes

    x32 = jnp.zeros((100,), jnp.float32)
    assert wire_bytes(x32, "none") == 400
    # "none" reads the element width off the dtype — no hardcoded 4
    assert wire_bytes(np.zeros((100,), np.float64), "none") == 800
    assert wire_bytes(np.zeros((100,), np.int16), "none") == 200
    assert wire_bytes(x32, "bf16") == 200


def test_wire_bytes_int8_frames_ship_their_scales():
    from repro.distributed.collectives import wire_bytes

    x = jnp.zeros((100,), jnp.float32)
    assert wire_bytes(x, "int8") == 100 + 4  # lattice + one shared f32 scale
    assert wire_bytes(x, "int8", n_scales=3) == 100 + 12  # per-block format
    with pytest.raises(ValueError):
        wire_bytes(x, "int8", n_scales=0)
    with pytest.raises(ValueError):
        wire_bytes(x, "fp4")


def test_reduce_edge_bytes_combine_edge_model():
    from repro.core.mapreduce import reduce_edge_bytes

    # 1-node mesh: every edge intra, inter is exactly 0
    assert reduce_edge_bytes(10, 4, 4, 8, 1, False) == (10 * 4 * 7, 0)
    assert reduce_edge_bytes(10, 4, 4, 8, 1, True) == (10 * 4 * 7, 0)
    # flat on 2 nodes: topology-oblivious, all 7 edges inter
    assert reduce_edge_bytes(10, 4, 4, 8, 2, False) == (0, 10 * 4 * 7)
    # hier on 2 nodes: 6 intra edges full width, 1 inter edge wire width
    assert reduce_edge_bytes(10, 4, 1, 8, 2, True) == (10 * 4 * 6, 10 * 1)
    # hier on 4 nodes: 4 intra, 3 inter
    assert reduce_edge_bytes(10, 4, 2, 8, 4, True) == (10 * 4 * 4, 10 * 2 * 3)


# -- the hierarchical-collectives pass (plan layer, no devices needed) --------


def _node(n_nodes, *, engine="eager", hierarchical=True, wire="none",
          red_name="sum"):
    from repro.core.plan import build_mapreduce_node
    from repro.core.reducers import get_reducer

    return build_mapreduce_node(
        idx=0, kind="range", src="range[0:64:1]", source_key=None,
        mapper=lambda v, emit: emit(0, v), red=get_reducer(red_name),
        target=jnp.zeros((4,), jnp.float32), engine=engine, wire=wire,
        key_range=None, env=None, n_nodes=n_nodes, hierarchical=hierarchical,
    )


def test_pass_rewrites_eligible_nodes_only():
    assert _node(1).hier is False  # 1-D mesh: strict no-op
    n = _node(2)
    assert n.hier is True
    assert n.collective == "psum[node×data, hier]"
    assert _node(2, engine="naive").hier is False  # no reduction tree
    assert _node(2, hierarchical=False).hier is False  # A/B baseline off
    n8 = _node(4, wire="int8")
    assert n8.collective == "psum[node×data, hier, wire=int8@inter]"
    # non-sum wired reduces never narrow — no @inter suffix
    assert _node(2, red_name="min").collective == "min-reduce[node×data, hier]"


def test_hier_node_is_a_distinct_plan_identity():
    """The hier rewrite lands BEFORE tune_key/stable_desc capture: a
    hierarchical node must not alias the flat node's tuning winners or plan
    hash (they compile different collectives)."""
    flat, hier = _node(1), _node(2)
    assert flat.stable_desc() != hier.stable_desc()
    assert flat.tune_key != hier.tune_key
    assert hier.stable_desc().endswith(" hier")


def test_plan_hash_and_render_multinode():
    from repro.core.plan import single_op_plan

    p1 = single_op_plan(_node(1), n_shards=8)
    p2 = single_op_plan(_node(2), n_shards=8, n_nodes=2)
    assert p1.hash != p2.hash
    r1, r2 = p1.render(), p2.render()
    # legacy 1-D rendering is untouched (explain goldens pin this)
    assert "node[" not in r1 and "hierarchical-collectives" not in r1
    assert "mesh: node[2]×data[4]" in r2
    assert "passes: resolve-engines, hierarchical-collectives" in r2
    assert "psum[node×data, hier]" in r2


# -- compat + mesh construction -----------------------------------------------


def test_distributed_initialize_single_process_noop():
    from repro import compat

    assert compat.distributed_initialize() is False
    assert compat.process_count() == 1
    assert compat.process_index() == 0


def test_make_node_data_mesh_shapes_8dev():
    res = _run(
        """
import json, jax
from repro.launch.mesh import make_node_data_mesh, init_distributed
import repro.core.containers as C
assert len(jax.devices()) == 8
out = {"shapes": {}, "err": None}
for n in (1, 2, 4, 8):
    m = make_node_data_mesh(n)
    out["shapes"][str(n)] = [dict(m.shape)["node"], dict(m.shape)["data"]]
    assert C.n_nodes(m) == n and C.shard_count(m) == 8
    assert C.data_axes(m) == ("node", "data")
try:
    make_node_data_mesh(3)
except ValueError as e:
    out["err"] = str(e)
out["initialized"] = init_distributed()  # single process: graceful no-op
print(json.dumps(out))
"""
    )
    assert res["shapes"] == {
        "1": [1, 8], "2": [2, 4], "4": [4, 2], "8": [8, 1]
    }
    assert "3 node" in res["err"]  # the error names the bad split
    assert res["initialized"] is False


# -- hierarchical vs flat on a simulated 2-D mesh -----------------------------


def test_hier_matches_flat_and_oracle_8dev():
    """Per-op dense reduces on (2,4) and (4,2) meshes: the hierarchical wire
    is bit-equal to the flat wire and to the NumPy oracle for sum (integer-
    valued floats — associativity-proof), min and max; stats report the
    intra/inter split of the combine-edge model; explain renders the
    topology."""
    res = _run(
        """
import json, numpy as np, jax, jax.numpy as jnp
from repro.core.session import BlazeSession
from repro.launch.mesh import make_node_data_mesh

vals = np.random.RandomState(0).randint(-50, 50, (64, 4)).astype(np.float32)

def m(i, row, emit):
    emit(0, row)

out = {}
for n_nodes in (2, 4):
    s = BlazeSession(mesh=make_node_data_mesh(n_nodes))
    v = s.distribute(vals)
    r = {}
    for red, oracle in (("sum", vals.sum(0)), ("min", vals.min(0)),
                        ("max", vals.max(0))):
        t = jnp.zeros((1, 4), jnp.float32) if red == "sum" else (
            jnp.full((1, 4), np.inf if red == "min" else -np.inf, jnp.float32))
        hier, st_h = s.map_reduce(v, m, red, t, return_stats=True)
        flat, st_f = s.map_reduce(v, m, red, t, return_stats=True,
                                  hierarchical=False)
        st_h, st_f = st_h.finalize(), st_f.finalize()
        r[red] = {
            "bit_equal": np.asarray(hier).tobytes() == np.asarray(flat).tobytes(),
            "oracle": bool(np.array_equal(np.asarray(hier)[0], oracle)),
            "intra": int(st_h.intra_bytes), "inter": int(st_h.inter_bytes),
            "flat_intra": int(st_f.intra_bytes),
            "flat_inter": int(st_f.inter_bytes),
            "coll": st_h.collective, "flat_coll": st_f.collective,
        }
    out[str(n_nodes)] = r
print(json.dumps(out))
"""
    )
    for n_nodes in (2, 4):
        r = res[str(n_nodes)]
        for red in ("sum", "min", "max"):
            assert r[red]["bit_equal"], (n_nodes, red, r[red])
            assert r[red]["oracle"], (n_nodes, red)
            # combine-edge model: 4 f32 elements, 8 shards
            assert r[red]["intra"] == 16 * (8 - n_nodes)
            assert r[red]["inter"] == 16 * (n_nodes - 1)
            assert r[red]["flat_intra"] == 0
            assert r[red]["flat_inter"] == 16 * 7
            assert "hier" in r[red]["coll"]
            assert "hier" not in r[red]["flat_coll"]
        # hier moves strictly fewer inter-node bytes than flat
        assert r["sum"]["inter"] < r["sum"]["flat_inter"]


def test_hier_int8_wire_narrows_inter_only_8dev():
    """A wired hierarchical sum quantises the inter-node hop only: fewer
    quantisation addends (one per node) than the flat compressed wire, so
    the error can only shrink — and inter bytes drop to the int8 frame."""
    res = _run(
        """
import json, numpy as np, jax, jax.numpy as jnp
from repro.core.session import BlazeSession
from repro.launch.mesh import make_node_data_mesh

vals = np.random.RandomState(1).randn(64, 8).astype(np.float32)
exact = vals.sum(0)

def m(i, row, emit):
    emit(0, row)

s = BlazeSession(mesh=make_node_data_mesh(2))
v = s.distribute(vals)
t = jnp.zeros((1, 8), jnp.float32)
hier, st_h = s.map_reduce(v, m, "sum", t, wire="int8", return_stats=True)
flat, st_f = s.map_reduce(v, m, "sum", t, wire="int8", return_stats=True,
                          hierarchical=False)
st_h, st_f = st_h.finalize(), st_f.finalize()
scale = float(np.abs(exact).max())
print(json.dumps({
    "hier_err": float(np.abs(np.asarray(hier)[0] - exact).max()) / scale,
    "flat_err": float(np.abs(np.asarray(flat)[0] - exact).max()) / scale,
    "intra": int(st_h.intra_bytes), "inter": int(st_h.inter_bytes),
    "flat_inter": int(st_f.inter_bytes),
    "coll": st_h.collective,
}))
"""
    )
    assert res["hier_err"] < 0.05 and res["flat_err"] < 0.05
    assert res["coll"] == "psum[node×data, hier, wire=int8@inter]"
    # intra edges at full f32 width, the single inter edge at int8 width
    assert res["intra"] == 8 * 4 * 6
    assert res["inter"] == 8 * 1 * 1
    assert res["inter"] < res["flat_inter"] == 8 * 1 * 7


def test_program_hier_vs_flat_bit_equal_8dev():
    """The fused-program path on a (2,4) mesh: hierarchical and flat builds
    of the same step converge bit-equal on integer-valued sums, and the
    plans differ exactly by the hierarchical-collectives pass."""
    res = _run(
        """
import json, numpy as np, jax, jax.numpy as jnp
from repro.core.session import BlazeSession
from repro.launch.mesh import make_node_data_mesh

vals = np.random.RandomState(2).randint(0, 100, (64, 4)).astype(np.float32)

def m(i, row, emit):
    emit(0, row)

s = BlazeSession(mesh=make_node_data_mesh(2))
v = s.distribute(vals)

def step(ctx, state):
    t = ctx.map_reduce(v, m, "sum", jnp.zeros((1, 4), jnp.float32))
    return {"acc": state["acc"] + t[0]}

state0 = {"acc": jnp.zeros((4,), jnp.float32)}
p_h = s.program(step)
p_f = s.program(step, hierarchical=False)
out_h = p_h(dict(state0), 3)
out_f = p_f(dict(state0), 3)
exp = 3 * vals.sum(0)
print(json.dumps({
    "bit_equal": np.asarray(out_h["acc"]).tobytes()
                 == np.asarray(out_f["acc"]).tobytes(),
    "oracle": bool(np.array_equal(np.asarray(out_h["acc"]), exp)),
    "hash_differs": p_h.plan.hash != p_f.plan.hash,
    "render_h": s.explain(p_h, dict(state0)),
    "render_f": s.explain(p_f, dict(state0)),
}))
"""
    )
    assert res["bit_equal"] and res["oracle"] and res["hash_differs"]
    assert "hierarchical-collectives" in res["render_h"]
    assert "psum[node×data, hier]" in res["render_h"]
    assert "hierarchical-collectives" not in res["render_f"]


def test_collective_inter_fault_retries_bit_equal_8dev():
    """``collective.inter`` (the slow cross-host hop) is a supervised fault
    point: an injected transient on the inter-node leg retries and the
    retried dispatch is bit-identical to the fault-free run."""
    res = _run(
        """
import json, numpy as np, jax, jax.numpy as jnp
from repro.core import faults
from repro.core.session import BlazeSession
from repro.launch.mesh import make_node_data_mesh

faults.reset(env=False)
FAST = faults.RetryPolicy(attempts=3, backoff_s=0.0, multiplier=1.0,
                          deadline_s=None)
vals = np.random.RandomState(3).randint(0, 100, (64, 4)).astype(np.float32)

def m(i, row, emit):
    emit(0, row)

mesh = make_node_data_mesh(2)
t = jnp.zeros((1, 4), jnp.float32)
ref_s = BlazeSession(mesh=mesh, retry=FAST)
ref = ref_s.map_reduce(ref_s.distribute(vals), m, "sum", t)
# The point fires while the hierarchical reduce traces, so arm it before
# the session's first compile of this op (a cache hit never re-traces).
s = BlazeSession(mesh=mesh, retry=FAST)
v = s.distribute(vals)
faults.configure("collective.inter", at=1)
got = s.map_reduce(v, m, "sum", t)
snap = faults.snapshot()
print(json.dumps({
    "bit_equal": np.asarray(got).tobytes() == np.asarray(ref).tobytes(),
    "retries": s.stats.retries,
    "balanced": snap["balanced"],
    "retried": snap["dispositions"]["retried"],
}))
"""
    )
    assert res["bit_equal"]
    assert res["retries"] == 1
    assert res["balanced"] and res["retried"] == 1


def test_compressed_psum_hierarchical_8dev():
    """``compressed_psum(..., intra_axis=)`` under shard_map on a (2,4)
    mesh: exact for wire="none" (bit-equal to the flat psum), close for
    int8, and ``psum_with_feedback``'s hierarchical residual is replicated
    within each node (every member computes the same node-level error)."""
    res = _run(
        """
import json, numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.distributed.collectives import compressed_psum, psum_with_feedback
from repro.launch.mesh import make_node_data_mesh

mesh = make_node_data_mesh(2)
x = jnp.asarray(np.random.RandomState(0).randn(8, 128).astype(np.float32))
exact = np.asarray(x).sum(0)
spec = P(("node", "data"))
out = {}
for wire in ("none", "int8"):
    def hier_fn(v):
        return compressed_psum(v[0], "node", wire=wire, intra_axis="data")[None]
    def flat_fn(v):
        return compressed_psum(v[0], ("node", "data"), wire=wire)[None]
    got_h = jax.jit(shard_map(hier_fn, mesh=mesh, in_specs=spec,
                              out_specs=spec, check_vma=False))(x)
    got_f = jax.jit(shard_map(flat_fn, mesh=mesh, in_specs=spec,
                              out_specs=spec, check_vma=False))(x)
    scale = float(np.abs(exact).max())
    out[wire] = {
        "hier_err": float(np.abs(np.asarray(got_h)[0] - exact).max()) / scale,
        "flat_err": float(np.abs(np.asarray(got_f)[0] - exact).max()) / scale,
    }

def fb(v, r):
    red, nr = psum_with_feedback(v[0], r[0], "node", wire="int8",
                                 intra_axis="data")
    return red[None], nr[None]
res_fb, resid = jax.jit(shard_map(fb, mesh=mesh, in_specs=(spec, spec),
                                  out_specs=(spec, spec),
                                  check_vma=False))(x, jnp.zeros_like(x))
resid = np.asarray(resid)
# residual replicated within a node: shards (0..3) and (4..7) agree
out["resid_replicated"] = bool(
    np.array_equal(resid[0], resid[1]) and np.array_equal(resid[4], resid[7])
    and np.array_equal(resid[1], resid[3])
)
print(json.dumps(out))
"""
    )
    # full-precision hier psum reassociates the same addends: ulp-level only
    assert res["none"]["hier_err"] < 1e-6
    assert res["int8"]["hier_err"] < 0.05
    assert res["int8"]["flat_err"] < 0.05
    assert res["resid_replicated"]
