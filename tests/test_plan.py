"""The logical-plan IR: per-node engine resolution, plan-hash agreement
between the per-op and program paths, collective batching (GMM's 4 psums →
2), CSE, dead-source pruning, explain goldens, and the pi/knn planner
routing with honest host-sync accounting."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BlazeSession, DistRange, distribute
from repro.core.algorithms import (
    estimate_pi,
    gmm_em,
    gmm_em_reference,
    kmeans,
    knn,
    knn_full_sort,
    pagerank,
    pagerank_reference,
)
from repro.data.synthetic import cluster_points, rmat_edges

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "goldens")


def _dyn_mapper(i, x, emit):
    emit(x[0].astype(jnp.int32) % 8, x[1])


def _dyn4_mapper(i, x, emit):
    emit(x[0].astype(jnp.int32) % 4, x[1] * 2.0)


def _rows(n=64, seed=0):
    rows = np.random.RandomState(seed).randn(n, 2).astype(np.float32)
    rows[:, 0] = np.random.RandomState(seed + 1).randint(0, 8, n)
    return rows


def _sum_oracle(rows, kmod=8, scale=1.0):
    out = np.zeros(kmod)
    for r in rows:
        out[int(np.int32(r[0])) % kmod] += r[1] * scale
    return out


# -- plan hashes: the per-op and program paths provably agree ------------------


def test_per_op_and_program_plan_hashes_agree_for_pi():
    """The acceptance property: the same op gets the same plan-node hash
    whether it runs standalone (single-node plan) or inside a program."""
    from repro.core.algorithms.pi import _program_step, pi_mapper

    sess = BlazeSession()
    _, st = sess.map_reduce(
        DistRange(0, 10_000, 1), pi_mapper, "sum", jnp.zeros((1,), jnp.int32),
        return_stats=True,
    )
    assert st.plan_hash is not None

    step, state = _program_step(10_000, "eager")
    prog = sess.program(step)
    plan = prog.build(state)
    (node,) = plan.mapreduce_nodes()
    assert node.hash == st.plan_hash


def test_per_op_and_program_plan_hashes_agree_for_hash_targets():
    from repro.core import make_dist_hashmap
    from repro.core.algorithms.wordcount import _program_step, wordcount_mapper

    sess = BlazeSession()
    lines = np.random.RandomState(0).randint(0, 50, (32, 8)).astype(np.int32)
    lv = distribute(lines, sess.mesh)
    hm = make_dist_hashmap(sess.mesh, 256, (), jnp.int32, "sum")
    _, st = sess.map_reduce(
        lv, wordcount_mapper, "sum", hm, key_range=50, return_stats=True
    )
    step, state = _program_step(lv, hm, 50, "eager")
    plan = sess.program(step).build(state)
    (node,) = plan.mapreduce_nodes()
    assert node.hash == st.plan_hash


def test_plan_hash_distinguishes_engine_wire_and_mapper():
    from repro.core.algorithms.pi import pi_mapper

    def other_mapper(v, emit):
        emit(0, jnp.where(v % 2 == 0, 1, 0))

    sess = BlazeSession()
    src = DistRange(0, 1000, 1)
    t = jnp.zeros((1,), jnp.int32)
    _, a = sess.map_reduce(src, pi_mapper, "sum", t, return_stats=True)
    _, b = sess.map_reduce(
        src, pi_mapper, "sum", t, engine="naive", return_stats=True
    )
    _, c = sess.map_reduce(src, other_mapper, "sum", t, return_stats=True)
    assert a.plan_hash != b.plan_hash
    assert a.plan_hash != c.plan_hash  # same shape, different mapper


def test_resolve_engine_importable_from_plan_and_session():
    """The policy moved to the plan layer; the session spelling survives."""
    from repro.core.plan import PALLAS_AUTO_MAX_KEYS as P1, resolve_engine as r1
    from repro.core.session import PALLAS_AUTO_MAX_KEYS as P2, resolve_engine as r2

    assert r1 is r2 and P1 == P2


# -- collective batching -------------------------------------------------------


def test_independent_sums_batch_into_one_collective():
    sess = BlazeSession()
    rows = _rows()
    pts = distribute(rows, sess.mesh)

    def step(ctx, s):
        a = ctx.map_reduce(pts, _dyn_mapper, "sum", jnp.zeros((8,), jnp.float32))
        b = ctx.map_reduce(pts, _dyn4_mapper, "sum", jnp.zeros((4,), jnp.float32))
        # first consumption AFTER both ops -> they flush as one psum
        return {"a": jnp.asarray(a), "b": jnp.asarray(b)}

    prog = sess.program(step)
    state = {"a": jnp.zeros((8,), jnp.float32), "b": jnp.zeros((4,), jnp.float32)}
    plan = prog.build(state)
    assert plan.collectives_per_iter == 1
    assert plan.collectives_unbatched == 2
    assert len(plan.groups) == 1 and sorted(plan.groups[0]) == [0, 1]
    out = prog(state, 1)
    np.testing.assert_allclose(np.asarray(out["a"]), _sum_oracle(rows), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(out["b"]), _sum_oracle(rows, 4, 2.0), rtol=1e-5
    )


def test_batching_respects_reducer_and_dtype_boundaries():
    """sum f32, sum i32 and max f32 partials cannot share a collective."""
    sess = BlazeSession()
    rows = _rows()
    pts = distribute(rows, sess.mesh)

    def int_mapper(i, x, emit):
        emit(x[0].astype(jnp.int32) % 4, 1)

    def step(ctx, s):
        a = ctx.map_reduce(pts, _dyn_mapper, "sum", jnp.zeros((8,), jnp.float32))
        b = ctx.map_reduce(pts, int_mapper, "sum", jnp.zeros((4,), jnp.int32))
        c = ctx.map_reduce(
            pts, _dyn_mapper, "max", jnp.full((8,), -jnp.inf, jnp.float32)
        )
        return {"a": jnp.asarray(a), "b": jnp.asarray(b), "c": jnp.asarray(c)}

    prog = sess.program(step)
    state = {
        "a": jnp.zeros((8,), jnp.float32),
        "b": jnp.zeros((4,), jnp.int32),
        "c": jnp.zeros((8,), jnp.float32),
    }
    plan = prog.build(state)
    assert plan.collectives_per_iter == 3  # no shareable pair
    assert not plan.groups
    out = prog(state, 1)
    np.testing.assert_allclose(np.asarray(out["a"]), _sum_oracle(rows), rtol=1e-5)
    counts = np.zeros(4)
    mx = np.full(8, -np.inf)
    for r in rows:
        counts[int(np.int32(r[0])) % 4] += 1
        k = int(np.int32(r[0])) % 8
        mx[k] = max(mx[k], r[1])
    np.testing.assert_array_equal(np.asarray(out["b"]), counts)
    np.testing.assert_allclose(np.asarray(out["c"]), mx, rtol=1e-6)


@pytest.mark.parametrize("engine", ("eager", "pallas", "naive"))
def test_gmm_program_issues_fewer_collectives_and_stays_exact(engine):
    """THE acceptance criterion: GMM's EM round used to issue 4 separate
    psums; the batching pass fuses ll/N_k/Σwx into one (Σw(x−μ)(x−μ)ᵀ
    depends on the new mean and ships alone) — while staying oracle-exact
    on every engine.  naive ops are not batchable (wide shuffle), so the
    optimized count equals the unbatched one there."""
    pts, _ = cluster_points(600, 2, 3, seed=1)
    init = pts[:3].copy()
    sess = BlazeSession()
    res = gmm_em(pts, 3, init_mu=init, tol=0.0, max_iters=10, engine=engine,
                 session=sess, mode="program", unroll=5)
    if engine in ("eager", "pallas"):
        assert res.collectives_per_iter == 2
    else:
        assert res.collectives_per_iter > 2
    ra, rm, rs, rll, _ = gmm_em_reference(pts, 3, init, tol=0.0, max_iters=10)
    assert float(np.abs(res.mu - rm).max()) < 1e-2
    assert float(np.abs(res.alpha - ra).max()) < 1e-3
    assert abs(res.log_likelihood - rll) / abs(rll) < 1e-3


def test_gmm_batched_vs_unoptimized_plans_agree_exactly():
    """passes=() disables the optimizer: same step, 4 collectives instead of
    2, bit-equal results (concatenated psum == separate psums)."""
    from repro.core.algorithms.gmm import _program_step

    pts, _ = cluster_points(400, 2, 3, seed=2)
    rows0 = np.concatenate([pts, np.zeros((400, 3), np.float32)], axis=1)
    sess = BlazeSession()
    rows_v = distribute(rows0.astype(np.float32), sess.mesh)
    step, state0 = _program_step(rows_v, 3, 2, 400, "eager")
    init = state0(
        np.full(3, 1 / 3, np.float32), pts[:3].copy(),
        np.tile(np.eye(2, dtype=np.float32), (3, 1, 1)),
    )
    opt = sess.program(step)
    unopt = sess.program(step, passes=())
    assert opt.build(init).collectives_per_iter == 2
    assert unopt.build(init).collectives_per_iter == 4
    assert unopt.build(init).collectives_unbatched == 4
    a = opt(init, 5)
    b = unopt(init, 5)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_pagerank_program_batches_sink_and_contribution():
    sess = BlazeSession()
    edges = rmat_edges(6, 8, seed=3)
    res = pagerank(edges, 64, tol=0.0, max_iters=10, session=sess,
                   mode="program", unroll=5)
    # sink-sum + contribution-sum share one psum; the delta pmax is alone
    assert res.collectives_per_iter == 2
    ref = pagerank_reference(edges, 64, tol=0.0, max_iters=10)
    assert float(np.abs(res.scores - ref).max() / ref.max()) < 1e-4


def test_kmeans_program_single_collective_carries_inertia():
    pts, _ = cluster_points(1000, 3, 4, seed=0)
    init = pts[:4].copy()
    res = kmeans(pts, 4, init_centers=init, tol=0.0, max_iters=10,
                 session=BlazeSession(), mode="program", unroll=5)
    assert res.collectives_per_iter == 1  # sums+counts+inertia in one psum
    assert res.compiles == 0  # no per-op inertia executable anymore
    per_op = kmeans(pts, 4, init_centers=init, tol=0.0, max_iters=10,
                    session=BlazeSession())
    assert abs(res.inertia - per_op.inertia) <= 1e-4 * abs(per_op.inertia)


# -- CSE -----------------------------------------------------------------------


def test_identical_ops_cse_even_with_different_targets():
    """Two ops with the same (source, mapper, reducer, engine, wire, env)
    compute once; each still merges into its OWN target (totals are shared,
    merges are not)."""
    sess = BlazeSession()
    rows = _rows()
    pts = distribute(rows, sess.mesh)

    def step(ctx, s):
        a = ctx.map_reduce(pts, _dyn_mapper, "sum", jnp.zeros((8,), jnp.float32))
        b = ctx.map_reduce(pts, _dyn_mapper, "sum", jnp.full((8,), 5.0, jnp.float32))
        return {"a": jnp.asarray(a), "b": jnp.asarray(b)}

    prog = sess.program(step)
    state = {"a": jnp.zeros((8,), jnp.float32), "b": jnp.zeros((8,), jnp.float32)}
    plan = prog.build(state)
    assert plan.cse_hits == 1
    assert plan.collectives_per_iter == 1
    assert plan.mapreduce_nodes()[1].cse_of == 0
    out = prog(state, 1)
    ref = _sum_oracle(rows)
    np.testing.assert_allclose(np.asarray(out["a"]), ref, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out["b"]), ref + 5.0, rtol=1e-5)


def test_different_env_values_do_not_cse():
    sess = BlazeSession()
    rows = _rows()
    pts = distribute(rows, sess.mesh)

    def scaled(i, x, emit, env):
        emit(x[0].astype(jnp.int32) % 8, x[1] * env)

    def step(ctx, s):
        a = ctx.map_reduce(
            pts, scaled, "sum", jnp.zeros((8,), jnp.float32), env=s["u"]
        )
        b = ctx.map_reduce(
            pts, scaled, "sum", jnp.zeros((8,), jnp.float32), env=s["u"] * 2.0
        )
        return {"a": jnp.asarray(a), "b": jnp.asarray(b), "u": s["u"]}

    prog = sess.program(step)
    state = {
        "a": jnp.zeros((8,), jnp.float32),
        "b": jnp.zeros((8,), jnp.float32),
        "u": jnp.asarray(1.0, jnp.float32),
    }
    plan = prog.build(state)
    assert plan.cse_hits == 0
    out = prog(state, 1)
    ref = _sum_oracle(rows)
    np.testing.assert_allclose(np.asarray(out["a"]), ref, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out["b"]), 2 * ref, rtol=1e-5)


# -- dead-op / dead-source pruning ---------------------------------------------


def test_dead_op_and_its_source_are_pruned():
    """An op whose result is never consumed is dropped from the plan, and a
    source only it read is never shipped into the executable."""
    sess = BlazeSession()
    rows = _rows()
    pts = distribute(rows, sess.mesh)
    unused = distribute(np.ones((16, 2), np.float32), sess.mesh)

    def step(ctx, s):
        a = ctx.map_reduce(pts, _dyn_mapper, "sum", jnp.zeros((8,), jnp.float32))
        got = jnp.asarray(a)  # flush a before the dead op exists
        _ = ctx.map_reduce(unused, _dyn_mapper, "sum", jnp.zeros((8,), jnp.float32))
        return {"a": got}

    prog = sess.program(step)
    state = {"a": jnp.zeros((8,), jnp.float32)}
    plan = prog.build(state)
    assert plan.dead_ops == 1
    assert plan.pruned_sources == 1
    assert [s.desc for s in plan.sources if s.pruned] == [
        "vector float32[16x2] n=16"
    ]
    # only the live source's operand is shipped into the executable
    _fn, operands = prog._cache[list(prog._cache)[0]]
    assert len(operands) == 1
    out = prog(state, 2)
    np.testing.assert_allclose(
        np.asarray(out["a"]), _sum_oracle(rows), rtol=1e-5
    )


def test_pruning_disabled_ships_and_runs_everything():
    sess = BlazeSession()
    pts = distribute(_rows(), sess.mesh)
    unused = distribute(np.ones((16, 2), np.float32), sess.mesh)

    def step(ctx, s):
        a = ctx.map_reduce(pts, _dyn_mapper, "sum", jnp.zeros((8,), jnp.float32))
        got = jnp.asarray(a)
        _ = ctx.map_reduce(unused, _dyn_mapper, "sum", jnp.zeros((8,), jnp.float32))
        return {"a": got}

    prog = sess.program(step, passes=())
    state = {"a": jnp.zeros((8,), jnp.float32)}
    plan = prog.build(state)
    assert plan.dead_ops == 0 and plan.pruned_sources == 0
    _fn, operands = prog._cache[list(prog._cache)[0]]
    assert len(operands) == 2
    prog(state, 1)  # runs fine with both operands


# -- explain -------------------------------------------------------------------


def test_explain_golden_snapshots():
    """The checked-in EXPLAIN goldens for all six paper algorithms match the
    current planner output (CI also diffs these via
    tools/check_explain_goldens.py)."""
    from tools.check_explain_goldens import build_plans

    plans = build_plans()
    assert sorted(plans) == ["gmm", "kmeans", "knn", "pagerank", "pi", "wordcount"]
    for name, text in plans.items():
        path = os.path.join(GOLDEN_DIR, f"explain_{name}.txt")
        assert os.path.exists(path), f"missing golden {path}"
        want = open(path).read().rstrip("\n")
        assert text == want, (
            f"explain golden for {name} is stale — regenerate with "
            "PYTHONPATH=src python tools/check_explain_goldens.py --update\n"
            f"{text}"
        )


def test_explain_requires_a_built_plan():
    sess = BlazeSession()

    def step(ctx, s):
        t = ctx.map_reduce(
            DistRange(0, 8, 1), lambda v, emit: emit(0, v), "sum",
            jnp.zeros((1,), jnp.int32),
        )
        return {"t": jnp.asarray(t)}

    prog = sess.program(step)
    with pytest.raises(ValueError, match="plan"):
        sess.explain(prog)
    text = sess.explain(prog, state={"t": jnp.zeros((1,), jnp.int32)})
    assert "Blaze logical plan" in text and "map_reduce sum" in text


def test_explain_shows_mixed_engines_per_node():
    """One program mixing eager and pallas ops: the plan resolves engines
    per node, and explain shows both."""
    sess = BlazeSession()
    pts = distribute(_rows(), sess.mesh)

    def step(ctx, s):
        a = ctx.map_reduce(
            pts, _dyn_mapper, "sum", jnp.zeros((8,), jnp.float32),
            engine="eager",
        )
        b = ctx.map_reduce(
            pts, _dyn_mapper, "sum", jnp.zeros((8,), jnp.float32),
            engine="pallas",
        )
        return {"a": jnp.asarray(a), "b": jnp.asarray(b)}

    prog = sess.program(step)
    state = {"a": jnp.zeros((8,), jnp.float32), "b": jnp.zeros((8,), jnp.float32)}
    plan = prog.build(state)
    engines = [n.engine for n in plan.mapreduce_nodes()]
    assert engines == ["eager", "pallas"]
    text = sess.explain(prog)
    assert "engine=eager" in text and "engine=pallas" in text


def test_plan_value_equality_is_elementwise():
    """== / != on a lazy plan value compare values (forcing the flush), not
    Python identity — `result == 0` must be usable in step glue."""
    sess = BlazeSession()

    def parity(v, emit):
        emit(v % 2, 1)

    def step(ctx, s):
        c = ctx.map_reduce(
            DistRange(0, 9, 1), parity, "sum", jnp.zeros((2,), jnp.int32)
        )
        is_five = c[0] == 5  # evens in [0, 9): 0,2,4,6,8
        diff = c[0] != c[1]
        return {"five": jnp.asarray(is_five), "diff": jnp.asarray(diff)}

    prog = sess.program(step)
    state = {"five": jnp.asarray(False), "diff": jnp.asarray(False)}
    out = prog(state, 1)
    assert bool(out["five"]) is True
    assert bool(out["diff"]) is True


def test_pi_program_rejects_return_stats():
    with pytest.raises(ValueError, match="per-op"):
        estimate_pi(1000, mode="program", return_stats=True)


# -- pi / knn through the planner ----------------------------------------------


def test_pi_program_equals_per_op_and_counts_host_syncs():
    sess = BlazeSession()
    a = estimate_pi(50_000, session=sess)
    assert sess.stats.host_syncs == 1  # used to bypass session.host_value
    b = estimate_pi(50_000, session=sess, mode="program")
    assert a == b
    assert sess.stats.host_syncs == 2
    assert sess.stats.program_compiles == 1


def test_knn_program_matches_per_op_and_full_sort():
    pts = np.random.RandomState(0).randn(512, 3).astype(np.float32)
    q = np.full(3, 0.5, np.float32)
    sess = BlazeSession()
    per_op = knn(pts, q, k=16, session=sess)
    assert sess.stats.host_syncs == 1
    prog = knn(pts, q, k=16, session=sess, mode="program")
    ref = knn_full_sort(pts, q, k=16)
    np.testing.assert_allclose(
        np.sort(per_op.distances), np.sort(ref.distances), rtol=1e-5
    )
    np.testing.assert_allclose(
        np.sort(prog.distances), np.sort(ref.distances), rtol=1e-5
    )
    assert sess.stats.host_syncs == 2


def test_knn_surfaces_ignored_engine_request():
    """knn's plan is container-level: the engine request is surfaced in the
    result (and on the plan node in explain), never silently dropped."""
    pts = np.random.RandomState(1).randn(128, 3).astype(np.float32)
    res = knn(pts, np.zeros(3, np.float32), k=4, engine="pallas")
    assert res.engine == "container:topk"
    assert res.engine_requested == "pallas"
    with pytest.raises(ValueError, match="unknown engine"):
        knn(pts, np.zeros(3, np.float32), k=4, engine="spark")
    golden = open(os.path.join(GOLDEN_DIR, "explain_knn.txt")).read()
    assert "ignored (container-level plan)" in golden
