"""Property suite: random interleavings of {submit, stats, cached-resubmit}
preserve the ``ServerStats`` invariants.

The counters form small conservation laws (see ``repro/serve/stats.py``):

* ``completed + failed + queued == submitted`` — every request that reached
  admission is in exactly one bucket at every instant;
* ``cache_hits + compiles == dispatched_plans`` — every executed plan
  resolution either hit the resident program cache or compiled;
* ``p50_ms <= p99_ms`` — both cut from one snapshot.

Ops run against one live server (dispatcher racing the submitting thread),
so the snapshots genuinely interleave with admission and dispatch.

Hypothesis gating follows tests/test_serialization.py: FAIL under
REQUIRE_HYPOTHESIS (CI installs hypothesis, so the suite must run there,
never skip).  Without hypothesis the same invariants run over seeded
pseudo-random interleavings instead, so the module still tests — rather
than skips — in minimal environments."""
import os
import random

import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError as e:
    if os.environ.get("REQUIRE_HYPOTHESIS"):
        raise ImportError(
            "REQUIRE_HYPOTHESIS is set but hypothesis failed to import — "
            "the property suite must run, not skip, in CI"
        ) from e
    HAVE_HYPOTHESIS = False

from repro.serve import BlazeServer  # noqa: E402

# Three tiny pi plans; repeats across and within examples are the
# "cached resubmit" op by construction (the program cache is resident).
SIZES = (256, 512, 1024)


@pytest.fixture(scope="module")
def server():
    srv = BlazeServer(max_queue=256, per_tenant_inflight=256, max_batch=4)
    srv.start()
    yield srv
    srv.stop()


def check_invariants(snap: dict) -> None:
    assert snap["completed"] + snap["failed"] + snap["queued"] == \
        snap["submitted"], snap
    assert snap["cache_hits"] + snap["compiles"] == \
        snap["dispatched_plans"], snap
    assert snap["p50_ms"] <= snap["p99_ms"], snap
    assert snap["queued"] >= 0, snap


def run_ops(server: BlazeServer, ops: list[tuple]) -> None:
    """Execute one interleaving, checking invariants after every op and
    after the example fully drains."""
    pending = []
    last = ("submit", SIZES[0], 1)
    for op in ops:
        if op[0] == "stats":
            check_invariants(server.stats_snapshot())
            continue
        if op[0] == "resubmit":
            op = last  # identical (query, params): exercises cache + dedup
        last = op
        _tag, n_samples, iters = op
        pending.append(server.submit(
            "prop", "pi", {"n_samples": n_samples, "iters": iters}
        ))
        check_invariants(server.stats_snapshot())
    for req in pending:
        assert req.done.wait(300), "request never completed"
        assert req.error is None, req.error
    snap = server.stats_snapshot()
    check_invariants(snap)
    # Everything admitted in this example has drained.
    assert snap["queued"] == 0
    # The whole module compiles at most one program per distinct plan
    # (``iters`` is NOT structural — it never forces a compile).
    assert snap["compiles"] <= len(SIZES)
    assert snap["compiles"] <= snap["dispatched_plans"]


if HAVE_HYPOTHESIS:
    ops_strategy = st.lists(
        st.one_of(
            st.tuples(st.just("submit"), st.sampled_from(SIZES),
                      st.integers(min_value=1, max_value=2)),
            st.tuples(st.just("resubmit")),
            st.tuples(st.just("stats")),
        ),
        min_size=1,
        max_size=12,
    )

    @settings(
        max_examples=15,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(ops=ops_strategy)
    def test_interleavings_preserve_stats_invariants(server, ops):
        run_ops(server, ops)

else:

    @pytest.mark.parametrize("seed", range(8))
    def test_interleavings_preserve_stats_invariants(server, seed):
        rng = random.Random(seed)
        ops = []
        for _ in range(rng.randint(1, 12)):
            kind = rng.choice(("submit", "resubmit", "stats"))
            if kind == "submit":
                ops.append(("submit", rng.choice(SIZES), rng.randint(1, 2)))
            else:
                ops.append((kind,))
        run_ops(server, ops)
