"""Fused iteration programs: one executable per iteration *program*, device-
resident unrolled loops, dispatch/host-sync accounting, error-feedback wire
residuals, and the composable-stage seams they ride on."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BlazeSession,
    DistRange,
    distribute,
    make_dist_hashmap,
)
from repro.core.serialization import dequantize, quantize_with_feedback


def _sq_env_mapper(v, emit, env):
    emit(v % 4, v * v + 0.0 * env)


def _dyn_mapper(i, x, emit):
    emit(x[0].astype(jnp.int32) % 8, x[1])


def _sum_rows_oracle(rows, kmod=8):
    out = np.zeros(kmod)
    for r in rows:
        out[int(np.int32(r[0])) % kmod] += r[1]
    return out


# -- program basics ------------------------------------------------------------


def test_program_single_compile_many_blocks():
    sess = BlazeSession()

    def step(ctx, s):
        t = ctx.map_reduce(
            DistRange(0, 64, 1), _sq_env_mapper, "sum",
            jnp.zeros((4,), jnp.float32), env=s["x"],
        )
        return {"x": s["x"] + t[0], "t": t}

    prog = sess.program(step)
    state = {"x": jnp.zeros((), jnp.float32), "t": jnp.zeros((4,), jnp.float32)}
    state, info = sess.run_loop(prog, state, max_iters=7, unroll=3)
    # 7 iterations = blocks of 3+3+1, all served by ONE executable (the trip
    # count is traced, so the remainder block does not recompile).
    assert info.iterations == 7
    assert info.dispatches == 3
    assert info.compiles == 1 and prog.stats.compiles == 1
    assert info.host_syncs == 0  # no cond given
    ref = float(np.sum((np.arange(64) ** 2)[np.arange(64) % 4 == 0]))
    assert float(state["x"]) == pytest.approx(7 * ref)
    assert sess.stats.program_compiles == 1
    assert sess.stats.program_dispatches == 3
    assert sess.stats.dispatches == 3


def test_program_cond_stops_at_block_boundary():
    sess = BlazeSession()

    def step(ctx, s):
        t = ctx.map_reduce(
            DistRange(0, 8, 1), _sq_env_mapper, "sum",
            jnp.zeros((4,), jnp.float32), env=s["x"],
        )
        return {"x": s["x"] + 1.0, "t": t}

    prog = sess.program(step)
    state = {"x": jnp.zeros((), jnp.float32), "t": jnp.zeros((4,), jnp.float32)}
    state, info = sess.run_loop(
        prog, state, cond=lambda s: float(s["x"]) >= 4, max_iters=100, unroll=4,
    )
    assert info.converged
    assert info.iterations == 4 and info.dispatches == 1
    assert info.host_syncs == 1
    assert sess.stats.host_syncs == 1


def test_program_multiple_ops_engines_and_sources_fuse():
    """Three ops over two sources and both combine engines in ONE program."""
    sess = BlazeSession()
    rows = np.random.RandomState(0).randn(64, 2).astype(np.float32)
    rows[:, 0] = np.random.RandomState(1).randint(0, 8, 64)
    pts = distribute(rows, sess.mesh)

    def step(ctx, s):
        a = ctx.map_reduce(
            pts, _dyn_mapper, "sum", jnp.zeros((8,), jnp.float32),
            engine="eager",
        )
        b = ctx.map_reduce(
            pts, _dyn_mapper, "sum", jnp.zeros((8,), jnp.float32),
            engine="pallas",
        )
        c = ctx.map_reduce(
            DistRange(0, 64, 1), _sq_env_mapper, "sum",
            jnp.zeros((4,), jnp.float32), env=s["acc"][0],
        )
        return {"acc": s["acc"] + a + b + c[0] * 0.0}

    prog = sess.program(step)
    out = prog({"acc": jnp.zeros((8,), jnp.float32)}, 2)
    assert prog.stats.compiles == 1 and prog.stats.dispatches == 1
    assert prog.stats.iterations == 2
    ref = _sum_rows_oracle(rows)
    np.testing.assert_allclose(np.asarray(out["acc"]), 4 * ref, rtol=1e-5)


def test_program_foreach_localvector_chain():
    """foreach output (LocalVector) feeds a later op without leaving shard."""
    sess = BlazeSession()
    rows = np.random.RandomState(0).randn(64, 2).astype(np.float32)
    rows[:, 0] = np.random.RandomState(1).randint(0, 8, 64)
    pts = distribute(rows, sess.mesh)

    def step(ctx, s):
        doubled = ctx.foreach(pts, lambda x, e: x * e, env=s["scale"])
        quad = ctx.foreach(doubled, lambda x: x * 2.0)  # LocalVector source
        out = ctx.map_reduce(
            quad, _dyn_mapper, "sum", jnp.zeros((8,), jnp.float32)
        )
        return {"scale": s["scale"], "out": out}

    prog = sess.program(step)
    state = {
        "scale": jnp.asarray(2.0, jnp.float32),
        "out": jnp.zeros((8,), jnp.float32),
    }
    out = prog(state, 1)
    # keys are scaled by 4 too, but k*4 % 8 keeps parity with k when k even…
    # use the real semantic: mapper sees the *scaled* rows.
    ref = _sum_rows_oracle(rows * 4.0)
    np.testing.assert_allclose(np.asarray(out["out"]), ref, rtol=1e-5)


def test_program_recompiles_only_on_state_signature_change():
    sess = BlazeSession()

    def step(ctx, s):
        t = ctx.map_reduce(
            DistRange(0, 32, 1), _sq_env_mapper, "sum",
            jnp.zeros((4,), jnp.float32), env=s["x"],
        )
        return {"x": s["x"] + t[0], "t": t}

    prog = sess.program(step)
    s32 = {"x": jnp.zeros((), jnp.float32), "t": jnp.zeros((4,), jnp.float32)}
    prog(s32, 2)
    prog(s32, 5)  # different block size, same executable
    assert prog.stats.compiles == 1
    fresh = {"x": jnp.ones((), jnp.float32), "t": jnp.ones((4,), jnp.float32)}
    prog(fresh, 1)  # new values, same signature → still no recompile
    assert prog.stats.compiles == 1
    wider = {"x": jnp.zeros((2,), jnp.float32), "t": jnp.zeros((4,), jnp.float32)}

    def ok_step(ctx, s):
        t = ctx.map_reduce(
            DistRange(0, 32, 1), _sq_env_mapper, "sum",
            jnp.zeros((4,), jnp.float32), env=s["x"][0],
        )
        return {"x": s["x"] + t[0], "t": t}

    prog2 = BlazeSession().program(ok_step)
    prog2(wider, 1)
    prog2({"x": jnp.zeros((3,), jnp.float32), "t": jnp.zeros((4,), jnp.float32)}, 1)
    assert prog2.stats.compiles == 2  # state signature change → deliberate miss


def test_program_hash_target_threads_per_shard_state():
    """Hash targets fuse: the table is threaded through the loop carry and
    accumulates across fused iterations (previously a NotImplementedError)."""
    sess = BlazeSession()
    hm = make_dist_hashmap(sess.mesh, 64, (), jnp.float32, "sum")

    def hash_step(ctx, s):
        ctx.map_reduce(
            DistRange(0, 8, 1), _sq_env_mapper, "sum", hm, env=s,
        )
        return s

    prog = sess.program(hash_step)
    prog(jnp.zeros((), jnp.float32), 3)
    assert prog.hash_slots == 1
    got = {int(k): float(v) for k, v in prog.hash_result(hm).to_dict().items()}
    want = {k: 3.0 * sum(v * v for v in range(8) if v % 4 == k) for k in range(4)}
    assert got == want
    # the original container is never mutated
    assert hm.size() == 0


def test_program_rejects_bad_state():
    sess = BlazeSession()

    def shape_shifting_step(ctx, s):
        t = ctx.map_reduce(
            DistRange(0, 8, 1), _sq_env_mapper, "sum",
            jnp.zeros((4,), jnp.float32), env=s[0],
        )
        return t  # [4] out of a scalar state

    with pytest.raises(ValueError, match="state"):
        sess.program(shape_shifting_step)(jnp.zeros((1,), jnp.float32), 1)


# -- error-feedback int8 wire --------------------------------------------------


def test_quantize_with_feedback_telescopes_exactly():
    """Over N rounds, Σ recovered + final residual == Σ targets (telescoping):
    the narrowing error never accumulates — it is always re-injected."""
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(300).astype(np.float32))
    residual = jnp.zeros_like(x)
    total = jnp.zeros_like(x)
    for _ in range(10):
        q, residual = quantize_with_feedback(x, residual, "int8")
        total = total + dequantize(q, x)
    np.testing.assert_allclose(
        np.asarray(total + residual), np.asarray(10.0 * x), rtol=1e-4, atol=1e-4
    )
    # and the residual itself stays bounded by one round's quantization step
    step = np.abs(np.asarray(x)).max() / 127.0
    assert float(jnp.abs(residual).max()) <= 2 * step


def test_quantize_feedback_beats_no_feedback_over_rounds():
    """Accumulated round-off with feedback is strictly smaller than without
    (the unbiasedness the iterative path relies on)."""
    rng = np.random.RandomState(1)
    x = jnp.asarray((rng.rand(512).astype(np.float32) - 0.3) * 1e-2)
    exact = np.asarray(10.0 * x)
    residual = jnp.zeros_like(x)
    with_fb = jnp.zeros_like(x)
    without = jnp.zeros_like(x)
    for _ in range(10):
        q, residual = quantize_with_feedback(x, residual, "int8")
        with_fb = with_fb + dequantize(q, x)
        q2, _ = quantize_with_feedback(x, jnp.zeros_like(x), "int8")
        without = without + dequantize(q2, x)
    err_fb = np.abs(np.asarray(with_fb) - exact).max()
    err_no = np.abs(np.asarray(without) - exact).max()
    assert err_fb <= err_no


def test_program_int8_wire_carries_residual_and_stays_accurate():
    sess = BlazeSession()
    rows = np.random.RandomState(0).randn(64, 2).astype(np.float32)
    rows[:, 0] = np.random.RandomState(1).randint(0, 8, 64)
    pts = distribute(rows, sess.mesh)

    def step(ctx, s):
        inc = ctx.map_reduce(
            pts, _dyn_mapper, "sum", jnp.zeros((8,), jnp.float32),
            wire="int8",
        )
        return {"acc": s["acc"] + inc}

    prog = sess.program(step)
    out = prog({"acc": jnp.zeros((8,), jnp.float32)}, 10)
    assert prog.feedback_slots == 1  # one residual carried through the loop
    ref = 10.0 * _sum_rows_oracle(rows)
    got = np.asarray(out["acc"])
    denom = np.abs(ref).max()
    assert np.abs(got - ref).max() / denom < 2e-2


def test_program_int8_residual_survives_across_dispatches():
    """Error feedback must stay live across blocks (even unroll=1): the exact
    telescoping identity acc + Σ_shards residual == N · exact holds after any
    mix of dispatch sizes only if the residual is fed back between them."""
    sess = BlazeSession()
    rows = np.random.RandomState(2).randn(64, 2).astype(np.float32)
    rows[:, 0] = np.random.RandomState(3).randint(0, 8, 64)
    pts = distribute(rows, sess.mesh)

    def step(ctx, s):
        inc = ctx.map_reduce(
            pts, _dyn_mapper, "sum", jnp.zeros((8,), jnp.float32),
            wire="int8",
        )
        return {"acc": s["acc"] + inc}

    prog = sess.program(step)
    state = {"acc": jnp.zeros((8,), jnp.float32)}
    for _ in range(7):  # seven unroll=1 dispatches
        state = prog(state, 1)
    state = prog(state, 3)  # plus one unroll=3 block — 10 iterations total
    assert prog.stats.dispatches == 8 and prog.stats.iterations == 10

    (residual,) = prog._residual_state[list(prog._residual_state)[0]]
    res_sum = np.asarray(residual).sum(axis=0)  # Σ over shards
    assert float(np.abs(np.asarray(residual)).max()) > 0.0  # carry is live
    exact = 10.0 * _sum_rows_oracle(rows)
    got = np.asarray(state["acc"])
    np.testing.assert_allclose(got + res_sum, exact, rtol=1e-4, atol=1e-3)


# -- satellite: memoized topk --------------------------------------------------


def test_topk_executable_memoized_across_calls():
    from repro.core import containers as C
    from repro.core import topk

    C._TOPK_CACHE.clear()
    rng = np.random.RandomState(0)
    v = distribute(rng.randn(256).astype(np.float32))
    out0 = topk(v, 5)
    n_after_first = len(C._TOPK_CACHE)
    assert n_after_first == 1
    for i in range(5):
        w = distribute(rng.randn(256).astype(np.float32))
        topk(w, 5)
    assert len(C._TOPK_CACHE) == n_after_first  # no fresh closures → no re-jit
    (fn,) = C._TOPK_CACHE.values()
    if hasattr(fn, "_cache_size"):  # jit traces stay flat too
        assert fn._cache_size() == 1
    # different k → a second (deliberate) entry; same-k correctness holds
    topk(v, 3)
    assert len(C._TOPK_CACHE) == 2
    from repro.core import collect

    np.testing.assert_allclose(
        np.sort(out0), np.sort(collect(v))[-5:], rtol=1e-6
    )


def test_knn_reuses_topk_executable_across_queries():
    """The query flows through env (a traced operand), so repeated kNN calls
    with different query points share one cached executable."""
    from repro.core import containers as C
    from repro.core.algorithms import knn, knn_full_sort

    C._TOPK_CACHE.clear()
    pts = np.random.RandomState(0).randn(512, 3).astype(np.float32)
    for i in range(4):
        q = np.full(3, float(i), np.float32)
        got = knn(pts, q, k=8)
        ref = knn_full_sort(pts, q, k=8)
        np.testing.assert_allclose(
            np.sort(got.distances), np.sort(ref.distances), rtol=1e-5
        )
    assert len(C._TOPK_CACHE) == 1
    (fn,) = C._TOPK_CACHE.values()
    if hasattr(fn, "_cache_size"):
        assert fn._cache_size() == 1


def test_topk_correct_with_score_fn_after_memoization():
    from repro.core import topk

    rows = np.stack([np.arange(64.0), 64.0 - np.arange(64.0)], 1).astype(
        np.float32
    )
    v = distribute(rows)

    def score(r):
        return r[1]

    got = topk(v, 4, score_fn=score)
    got2 = topk(v, 4, score_fn=score)  # memoized path
    np.testing.assert_array_equal(got, got2)
    assert set(got[:, 0].astype(int).tolist()) == {0, 1, 2, 3}


# -- satellite: vectorized DistHashMap accessors -------------------------------


def test_hashmap_items_matches_to_dict():
    import collections

    sess = BlazeSession()
    lines = np.random.RandomState(0).randint(0, 50, (64, 8)).astype(np.int32)
    lv = distribute(lines, sess.mesh)

    def tok(i, toks, emit):
        emit(toks, 1, mask=toks >= 0)

    hm = make_dist_hashmap(sess.mesh, 256, (), jnp.int32, "sum")
    hm = sess.map_reduce(lv, tok, "sum", hm)
    keys, vals = hm.items()
    assert keys.shape[0] == hm.size() == len(hm.to_dict())
    ref = collections.Counter(lines.reshape(-1).tolist())
    got = {int(k): int(v) for k, v in zip(keys, vals)}
    assert got == dict(ref)
    # to_dict is built on items() — same content
    assert {k: int(v) for k, v in hm.to_dict().items()} == got
